//! End-to-end full-system driver — proves all layers compose on a real
//! small workload:
//!
//!   1. **Data**: generate two paper-shaped sparse workloads (News20-like
//!      text and URL-like mixed dense/sparse), train/test split.
//!   2. **Non-private**: Alg 1 vs Alg 2+3 — trajectory agreement (Fig 1)
//!      and the FLOP reduction (Fig 2) at e2e scale.
//!   3. **DP grid through the coordinator**: {Alg1+noisy-max, Alg2+noisy-max,
//!      Alg2+BSLS} × ε ∈ {1, 0.1} in parallel workers → a Table-3-shaped
//!      speedup report and a Table-4-shaped utility report.
//!   4. **PJRT oracle**: load the JAX/Pallas-AOT'd artifacts, cross-check
//!      the Rust solver's gradient against the XLA-computed dense α, and
//!      score the DP model with the Pallas `predict` kernel.
//!
//! Results are written to `e2e_out/` (CSV + JSON) and summarized on
//! stdout; EXPERIMENTS.md records a reference run.
//!
//! Run: `make artifacts && cargo run --release --example e2e_full_repro`

use std::sync::Arc;

use dpfw::coordinator::{Algo, Coordinator, JobSpec, Registry};
use dpfw::fw::fast::FastFrankWolfe;
use dpfw::fw::standard::StandardFrankWolfe;
use dpfw::prelude::*;
use dpfw::runtime::oracle::DenseOracle;
use dpfw::testkit::assert_slices_close;

fn main() -> anyhow::Result<()> {
    let out_dir = std::path::PathBuf::from("e2e_out");
    std::fs::create_dir_all(&out_dir)?;
    let t_iters = 1000;

    // ------------------------------------------------------------ stage 1
    println!("=== stage 1: workloads ===");
    let news = Arc::new(SynthConfig::preset(DatasetPreset::News20).scale(0.04).generate(42));
    let url = Arc::new(SynthConfig::preset(DatasetPreset::Url).scale(0.003).generate(43));
    for ds in [&news, &url] {
        println!(
            "  {:<8} N={:<7} D={:<8} nnz={:<9} S_c={:<6.1} S_r={:.2}",
            ds.name,
            ds.n_rows(),
            ds.n_cols(),
            ds.nnz(),
            ds.avg_row_nnz(),
            ds.avg_col_nnz()
        );
    }

    // ------------------------------------------------------------ stage 2
    println!("\n=== stage 2: non-private equivalence + FLOPs (Figs 1-2) ===");
    for ds in [&news, &url] {
        let cfg = FwConfig {
            iters: t_iters,
            lambda: 50.0,
            trace_every: t_iters / 10,
            ..Default::default()
        };
        let a1 = StandardFrankWolfe::new(ds, cfg.clone()).run();
        let a23 = FastFrankWolfe::new(
            ds,
            FwConfig { selector: SelectorKind::FibHeap, ..cfg },
        )
        .run();
        let flop_ratio = a1.flops as f64 / a23.flops as f64;
        println!(
            "  {:<8} gap: alg1 {:.3e} / alg2+3 {:.3e} | FLOPs {:.2e} vs {:.2e} ({:.0}x fewer) | pops/select {:.2}",
            ds.name,
            a1.final_gap,
            a23.final_gap,
            a1.flops as f64,
            a23.flops as f64,
            flop_ratio,
            a23.selector_stats.pops as f64 / a23.selector_stats.selects.max(1) as f64
        );
        anyhow::ensure!(
            a23.final_gap < a1.final_gap * 3.0 + 1.0,
            "fast solver failed to track the standard one"
        );
    }

    // ------------------------------------------------------------ stage 3
    println!("\n=== stage 3: DP grid through the coordinator (Tables 3-4) ===");
    let mut coord = Coordinator::new(
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4),
    );
    let mut jobs = Vec::new();
    let mut id = 0;
    for ds in [&news, &url] {
        let (train, test) = ds.split(0.2);
        let (train, test) = (Arc::new(train), Arc::new(test));
        for eps in [1.0, 0.1] {
            for (algo, sel, tag) in [
                (Algo::Standard, SelectorKind::NoisyMax, "alg1"),
                (Algo::Fast, SelectorKind::NoisyMax, "alg2"),
                (Algo::Fast, SelectorKind::Bsls, "alg2+4"),
            ] {
                jobs.push(JobSpec {
                    id,
                    label: format!("{}|{}|{}", ds.name, eps, tag),
                    data: train.clone(),
                    algo,
                    cfg: FwConfig {
                        iters: t_iters,
                        lambda: 50.0,
                        privacy: Some(PrivacyParams { epsilon: eps, delta: 1e-6 }),
                        selector: sel,
                        seed: 5,
                        ..Default::default()
                    },
                    test_data: Some(test.clone()),
                });
                id += 1;
            }
        }
    }
    let results = coord.run_all(jobs);
    let mut registry = Registry::new();
    for r in results {
        registry.add(r.map_err(|e| anyhow::anyhow!("DP job failed: {e}"))?);
    }
    registry.write_csv(out_dir.join("e2e_dp_grid.csv"))?;
    registry.write_json(out_dir.join("e2e_dp_grid.json"))?;

    println!(
        "  {:<22} {:>9} {:>9} {:>7} {:>7}",
        "cell", "wall_ms", "speedup", "acc%", "auc%"
    );
    let wall = |label: &str| registry.find(label).map(|r| r.output.wall_ms).unwrap_or(f64::NAN);
    for ds in [&news, &url] {
        for eps in [1.0, 0.1] {
            let base = wall(&format!("{}|{}|alg1", ds.name, eps));
            for tag in ["alg1", "alg2", "alg2+4"] {
                let label = format!("{}|{}|{}", ds.name, eps, tag);
                let r = registry.find(&label).unwrap();
                println!(
                    "  {:<22} {:>9.1} {:>9.2} {:>7.2} {:>7.2}",
                    label,
                    r.output.wall_ms,
                    base / r.output.wall_ms,
                    r.accuracy.unwrap_or(f64::NAN),
                    r.auc.unwrap_or(f64::NAN)
                );
            }
        }
    }
    println!("  coordinator: {}", coord.metrics.summary());
    // headline assertion: the paper's method wins on the high-D dataset
    let sp = wall("news20|0.1|alg1") / wall("news20|0.1|alg2+4");
    println!("  headline: news20 @ eps=0.1 speedup (Alg2+4 over Alg1) = {sp:.1}x");
    anyhow::ensure!(sp > 1.0, "expected a speedup, got {sp}");

    // ------------------------------------------------------------ stage 4
    println!("\n=== stage 4: PJRT dense oracle (JAX+Pallas artifacts) ===");
    let mut oracle = match DenseOracle::open_default() {
        Ok(o) => o,
        Err(e) => {
            eprintln!("  SKIPPED: {e}\n  (run `make artifacts` first)");
            return Ok(());
        }
    };
    println!("  oracle tile {}x{}", oracle.n_tile(), oracle.d_tile());
    // tile-sized workload; exercise alpha + predict against the Rust side
    let small = SynthConfig {
        name: "e2e-oracle".into(),
        n_rows: oracle.n_tile() * 3,
        n_cols: oracle.d_tile(),
        avg_row_nnz: 30.0,
        zipf_exponent: 1.2,
        n_informative: 32,
        n_dense: 0,
        label_noise: 0.05,
            bias_col: true,
    }
    .generate(44);
    let dp_model = FastFrankWolfe::new(
        &small,
        FwConfig {
            iters: 400,
            lambda: 20.0,
            privacy: Some(PrivacyParams { epsilon: 1.0, delta: 1e-6 }),
            selector: SelectorKind::Bsls,
            seed: 6,
            ..Default::default()
        },
    )
    .run();
    let w = dp_model.weights.as_slice();
    // rust-side alpha vs Pallas/XLA alpha
    let mut v = vec![0.0f64; small.n_rows()];
    small.csr.matvec(w, &mut v);
    let q: Vec<f64> = v
        .iter()
        .zip(&small.labels)
        .map(|(&vi, &yi)| dpfw::fw::loss::sigmoid(vi) - yi as f64)
        .collect();
    let mut a_rust = vec![0.0f64; small.n_cols()];
    small.csr.matvec_t_add(&q, &mut a_rust);
    let a_xla = oracle.alpha(&small, w)?;
    assert_slices_close(&a_rust, &a_xla, 5e-4, 5e-4);
    let p = oracle.predict(&small, w)?;
    let (loss, gap) = oracle.loss_and_gap(&small, w, 20.0)?;
    println!(
        "  alpha agrees (D={}); oracle-scored DP model: acc {:.2}%, auc {:.2}%, loss {:.4}, gap {:.3e}",
        small.n_cols(),
        accuracy(&p, &small.labels),
        auc(&p, &small.labels),
        loss,
        gap
    );
    println!("\nE2E OK — outputs in {}", out_dir.display());
    Ok(())
}
