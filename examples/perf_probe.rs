//! Perf probe: a heavy DP fast-solver run for profiling (pair with
//! DPFW_PHASE_TIMING=1 or `perf record`). Used by the §Perf pass.
//!
//! Hot loops dispatch through the §6.7 segment-adaptive scan kernels —
//! sweep the fused/scratch threshold via `direct_max_nnz` here (or
//! `DPFW_DIRECT_MAX_NNZ` when it is `None`) and read the resulting
//! direct/scratch segment split off the output, instead of hand-rolling
//! `resolve` + gather pairs.
use dpfw::prelude::*;
fn main() {
    let ds = SynthConfig::preset(DatasetPreset::News20).scale(0.1).generate(7);
    let out = FastFrankWolfe::new(&ds, FwConfig {
        iters: 20_000, lambda: 50.0,
        privacy: Some(PrivacyParams { epsilon: 0.5, delta: 1e-6 }),
        selector: SelectorKind::Bsls, seed: 1,
        ..Default::default()
    }).run();
    println!(
        "gap {:.3e} wall {:.0} ms flops {:.2e} bytes {:.2e} ({})",
        out.final_gap, out.wall_ms, out.flops as f64, out.bytes_moved as f64, ds.index_kind(),
    );
    println!(
        "scan tier: {} direct / {} scratch segments, {:.2e} L1 scratch bytes",
        out.direct_segments, out.scratch_segments, out.scratch_bytes as f64,
    );
    if let Some(p) = out.phase {
        println!("phase ns: select {} update {} notify {}", p.select_ns, p.update_ns, p.notify_ns);
    }
}
