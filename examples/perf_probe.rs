//! Perf probe: a heavy DP fast-solver run for profiling (pair with
//! DPFW_PHASE_TIMING=1 or `perf record`). Used by the §Perf pass.
use dpfw::prelude::*;
fn main() {
    let ds = SynthConfig::preset(DatasetPreset::News20).scale(0.1).generate(7);
    let out = FastFrankWolfe::new(&ds, FwConfig {
        iters: 20_000, lambda: 50.0,
        privacy: Some(PrivacyParams { epsilon: 0.5, delta: 1e-6 }),
        selector: SelectorKind::Bsls, seed: 1, trace_every: 0, lipschitz: None, threads: 0,
    }).run();
    println!(
        "gap {:.3e} wall {:.0} ms flops {:.2e} bytes {:.2e} ({})",
        out.final_gap, out.wall_ms, out.flops as f64, out.bytes_moved as f64, ds.index_kind(),
    );
    if let Some(p) = out.phase {
        println!("phase ns: select {} update {} notify {}", p.select_ns, p.update_ns, p.notify_ns);
    }
}
