//! Quickstart: train a non-private LASSO logistic regression with the fast
//! sparse Frank-Wolfe solver (Algorithm 2 + the Fibonacci-heap queue of
//! Algorithm 3) on a News20-shaped synthetic dataset, and compare against
//! the standard implementation (Algorithm 1).
//!
//! Run: `cargo run --release --example quickstart`

use dpfw::fw::fast::FastFrankWolfe;
use dpfw::fw::standard::StandardFrankWolfe;
use dpfw::prelude::*;

fn main() {
    // 1. A high-dimensional sparse dataset (News20 preset, scaled down).
    let ds = SynthConfig::preset(DatasetPreset::News20).scale(0.02).generate(42);
    println!(
        "dataset: {}  N={}  D={}  nnz={}  (S_c={:.0}, S_r={:.2})",
        ds.name,
        ds.n_rows(),
        ds.n_cols(),
        ds.nnz(),
        ds.avg_row_nnz(),
        ds.avg_col_nnz()
    );

    // 2. Configure: T iterations on the λ-ball, non-private.
    let cfg = FwConfig {
        iters: 500,
        lambda: 50.0,
        trace_every: 100,
        ..Default::default()
    };

    // 3. Algorithm 1 (standard) vs Algorithm 2+3 (fast).
    let std_out = StandardFrankWolfe::new(&ds, cfg.clone()).run();
    let fast_out = FastFrankWolfe::new(
        &ds,
        FwConfig { selector: SelectorKind::FibHeap, ..cfg },
    )
    .run();

    println!("\n            {:>14} {:>14}", "Alg 1 (std)", "Alg 2+3 (fast)");
    println!(
        "wall (ms)   {:>14.1} {:>14.1}",
        std_out.wall_ms, fast_out.wall_ms
    );
    println!(
        "FLOPs       {:>14.3e} {:>14.3e}",
        std_out.flops as f64, fast_out.flops as f64
    );
    println!(
        "final gap   {:>14.4e} {:>14.4e}",
        std_out.final_gap, fast_out.final_gap
    );
    println!(
        "nnz(w)      {:>14} {:>14}",
        std_out.weights.nnz(),
        fast_out.weights.nnz()
    );
    println!(
        "\nFLOP reduction: {:.1}x  (heap pops/select: {:.2})",
        std_out.flops as f64 / fast_out.flops as f64,
        fast_out.selector_stats.pops as f64 / fast_out.selector_stats.selects.max(1) as f64,
    );

    // 4. Training-set accuracy via the sparse scorer.
    let p = dpfw::coordinator::job::score(&ds, fast_out.weights.as_slice());
    println!(
        "train accuracy {:.2}%, AUC {:.2}%, solution sparsity {:.2}%",
        accuracy(&p, &ds.labels),
        auc(&p, &ds.labels),
        sparsity_pct(fast_out.weights.as_slice())
    );
}
