//! Differentially private training: sweep ε and compare the three DP
//! selection mechanisms of Table 3 —
//!   * Alg 1 + report-noisy-max (the standard DP Frank-Wolfe baseline),
//!   * Alg 2 + noisy-max        (sparse updates, dense selection — ablation),
//!   * Alg 2 + BSLS             (the paper's full method, Algorithm 4).
//!
//! Shows the paper's two headline effects: the fast solver's wall-clock
//! advantage, and utility degrading gracefully as ε shrinks. All runs go
//! through the coordinator's worker pool.
//!
//! Run: `cargo run --release --example dp_training`

use std::sync::Arc;

use dpfw::coordinator::{Algo, Coordinator, JobSpec};
use dpfw::prelude::*;

fn main() {
    let ds = Arc::new(SynthConfig::preset(DatasetPreset::Rcv1).scale(0.15).generate(7));
    let (train, test) = ds.split(0.2);
    let (train, test) = (Arc::new(train), Arc::new(test));
    println!(
        "dataset {}: train N={} / test N={}, D={}",
        ds.name,
        train.n_rows(),
        test.n_rows(),
        train.n_cols()
    );

    let mut coord = Coordinator::new(6);
    let mut jobs = Vec::new();
    let mut id = 0;
    let epsilons = [10.0, 1.0, 0.1];
    for &eps in &epsilons {
        for (algo, sel, tag) in [
            (Algo::Standard, SelectorKind::NoisyMax, "alg1+noisymax"),
            (Algo::Fast, SelectorKind::NoisyMax, "alg2+noisymax"),
            (Algo::Fast, SelectorKind::Bsls, "alg2+bsls"),
        ] {
            jobs.push(JobSpec {
                id,
                label: format!("eps={eps} {tag}"),
                data: train.clone(),
                algo,
                cfg: FwConfig {
                    iters: 800,
                    lambda: 50.0,
                    privacy: Some(PrivacyParams { epsilon: eps, delta: 1e-6 }),
                    selector: sel,
                    seed: 11,
                    ..Default::default()
                },
                test_data: Some(test.clone()),
            });
            id += 1;
        }
    }
    let results = coord.run_all(jobs);

    println!(
        "\n{:<24} {:>10} {:>10} {:>8} {:>8} {:>10}",
        "config", "wall_ms", "flops", "acc%", "auc%", "nnz(w)"
    );
    for r in &results {
        let r = r.as_ref().expect("job failed");
        println!(
            "{:<24} {:>10.1} {:>10.2e} {:>8.2} {:>8.2} {:>10}",
            r.label,
            r.output.wall_ms,
            r.output.flops as f64,
            r.accuracy.unwrap_or(f64::NAN),
            r.auc.unwrap_or(f64::NAN),
            r.output.weights.nnz()
        );
    }
    println!("\ncoordinator: {}", coord.metrics.summary());

    // headline: speedup of the paper's method over the baseline per ε
    for &eps in &epsilons {
        let wall = |tag: &str| {
            results
                .iter()
                .filter_map(|r| r.as_ref().ok())
                .find(|r| r.label == format!("eps={eps} {tag}"))
                .unwrap()
                .output
                .wall_ms
        };
        println!(
            "eps={eps}: Alg2+BSLS is {:.1}x faster than standard DP-FW",
            wall("alg1+noisymax") / wall("alg2+bsls")
        );
    }
}
