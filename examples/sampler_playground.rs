//! Sampler playground: the Big-Step Little-Step sampler (Algorithm 4)
//! head-to-head with the naive O(D) exponential mechanism — draw-time
//! scaling with D, distributional agreement, and the big-step/little-step
//! telemetry that explains *why* it is fast (cache-friendly linear scans,
//! O(√D) work per draw).
//!
//! Run: `cargo run --release --example sampler_playground`

use std::time::Instant;

use dpfw::rng::Xoshiro256pp;
use dpfw::sampler::bsls::BslsSampler;
use dpfw::sampler::naive::NaiveExpSampler;
use dpfw::sampler::WeightedSampler;

fn time_draws<S: WeightedSampler>(s: &mut S, rng: &mut Xoshiro256pp, draws: usize) -> f64 {
    let t0 = Instant::now();
    let mut sink = 0usize;
    for _ in 0..draws {
        sink ^= s.sample(rng);
    }
    std::hint::black_box(sink);
    t0.elapsed().as_secs_f64() * 1e6 / draws as f64
}

fn main() {
    println!("== draw-time scaling (1000 draws each, peaked weights) ==");
    println!("{:>10} {:>14} {:>14} {:>9}", "D", "BSLS (us)", "naive (us)", "ratio");
    for &d in &[1_000usize, 10_000, 100_000, 1_000_000] {
        let mut bsls = BslsSampler::new(d, 0.0);
        let mut naive = NaiveExpSampler::new(d, 0.0);
        // realistic gradient profile: a few heavy coordinates, long tail
        for j in (0..d).step_by(d / 50) {
            bsls.update(j, 5.0 + (j % 7) as f64);
            naive.update(j, 5.0 + (j % 7) as f64);
        }
        let mut rng = Xoshiro256pp::seeded(1);
        let b = time_draws(&mut bsls, &mut rng, 1000);
        let mut rng = Xoshiro256pp::seeded(1);
        let n = time_draws(&mut naive, &mut rng, 1000);
        println!("{:>10} {:>14.2} {:>14.2} {:>9.1}x", d, b, n, n / b);
    }

    println!("\n== distributional agreement at D=256 (100k draws) ==");
    let d = 256;
    let mut bsls = BslsSampler::new(d, 0.0);
    let mut naive = NaiveExpSampler::new(d, 0.0);
    for j in 0..d {
        let w = ((j * 37) % 13) as f64 * 0.4;
        bsls.update(j, w);
        naive.update(j, w);
    }
    let mut cb = vec![0u64; d];
    let mut cn = vec![0u64; d];
    let mut r1 = Xoshiro256pp::seeded(2);
    let mut r2 = Xoshiro256pp::seeded(3);
    let draws = 100_000;
    for _ in 0..draws {
        cb[bsls.sample(&mut r1)] += 1;
        cn[naive.sample(&mut r2)] += 1;
    }
    let chi2: f64 = (0..d)
        .map(|j| {
            let (a, b) = (cb[j] as f64, cn[j] as f64);
            if a + b == 0.0 { 0.0 } else { (a - b).powi(2) / (a + b) }
        })
        .sum();
    println!("two-sample chi^2 = {chi2:.1}  (df={}, ~{} expected if identical)", d - 1, d - 1);

    let st = bsls.stats;
    println!("\n== BSLS telemetry ==");
    println!(
        "draws {}, big-steps {} ({:.1}/draw), little-steps {} ({:.1}/draw), rebuilds {}/{}",
        st.draws,
        st.big_steps,
        st.big_steps as f64 / st.draws as f64,
        st.little_steps,
        st.little_steps as f64 / st.draws as f64,
        st.group_rebuilds,
        st.global_rebuilds,
    );
    println!("log-total drift check: z = {:.6}", bsls.log_total());
}
