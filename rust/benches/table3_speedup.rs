//! Bench: **Table 3** — end-to-end DP training wall-clock for the three
//! configurations {Alg1+noisy-max, Alg2+noisy-max, Alg2+BSLS} at
//! ε ∈ {1, 0.1} on every scaled preset, reporting the speedup factors the
//! paper's Table 3 reports. Also regenerable via `repro exp table3`.

mod bench_harness;

use bench_harness::{section, Bench};
use dpfw::dp::accounting::PrivacyParams;
use dpfw::fw::config::{FwConfig, SelectorKind};
use dpfw::fw::fast::FastFrankWolfe;
use dpfw::fw::standard::StandardFrankWolfe;
use dpfw::sparse::synth::{DatasetPreset, SynthConfig};

fn main() {
    // keep bench wall-time sane: modest scales + T
    let iters = 300;
    let presets: &[(DatasetPreset, f64)] = &[
        (DatasetPreset::Rcv1, 0.1),
        (DatasetPreset::News20, 0.02),
        (DatasetPreset::Url, 0.0015),
        (DatasetPreset::Web, 0.001),
        (DatasetPreset::Kdda, 0.0006),
    ];
    println!("Table 3 bench: T={iters}, lambda=50, delta=1e-6");
    for &(p, sc) in presets {
        let ds = SynthConfig::preset(p).scale(sc).generate(42);
        section(&format!(
            "{} (N={}, D={}, nnz={})",
            p.name(),
            ds.n_rows(),
            ds.n_cols(),
            ds.nnz()
        ));
        for eps in [1.0, 0.1] {
            let cfg = |sel| FwConfig {
                iters,
                lambda: 50.0,
                privacy: Some(PrivacyParams::new(eps, 1e-6)),
                selector: sel,
                seed: 9,
                trace_every: 0,
                ..Default::default()
            };
            let t_alg1 = Bench::new(format!("{} eps={eps} alg1+noisymax", p.name()))
                .runs(3)
                .run(|| StandardFrankWolfe::new(&ds, cfg(SelectorKind::NoisyMax)).run().flops);
            let t_alg2 = Bench::new(format!("{} eps={eps} alg2+noisymax", p.name()))
                .runs(3)
                .run(|| FastFrankWolfe::new(&ds, cfg(SelectorKind::NoisyMax)).run().flops);
            let t_alg24 = Bench::new(format!("{} eps={eps} alg2+bsls (paper)", p.name()))
                .runs(3)
                .run(|| FastFrankWolfe::new(&ds, cfg(SelectorKind::Bsls)).run().flops);
            println!(
                "  --> speedups over standard DP-FW: Alg2+4 = {:.2}x, Alg2-only = {:.2}x",
                t_alg1 / t_alg24,
                t_alg1 / t_alg2
            );
        }
    }
}
