//! Bench: **Figures 2 & 4 (wall-clock view)** — per-iteration cost of
//! Alg 1 vs Alg 2 as D grows at fixed sparsity, demonstrating the paper's
//! headline complexity claim: Alg 1 scales O(D) per iteration while
//! Alg 2+BSLS scales ~O(√D). The printed `us/iter vs D` series is the
//! scaling law the paper's Table 1 promises.
//!
//! Results are also persisted to `BENCH_iteration_cost.json` at the repo
//! root (override/disable via `DPFW_BENCH_JSON`, see `bench_harness`), so
//! the perf trajectory of the fused-scan engine is tracked across PRs. The
//! `news20-bsls` entries are the canonical regression series: the fast
//! solver on the News20 preset with the DP BSLS selector, both cold
//! (per-run workspace) and warm (reused workspace).
//!
//! A second report, `BENCH_path_sweep.json` (override via
//! `DPFW_BENCH_PATH_JSON`), tracks the regularization-path engine: per-λ
//! wall time of a 10-point λ-path on the News20-shaped synth + BSLS,
//! independent runs vs `run_path`, cold and warm workspace. `run_path`
//! per-λ must sit strictly below independent per-λ for K ≥ 3 — the
//! shared-bootstrap acceptance line.
//!
//! `DPFW_BENCH_SMOKE=1` shrinks every workload to CI-smoke size (the JSON
//! emitters still run end-to-end; the numbers are not comparable).

mod bench_harness;

use bench_harness::{section, smoke_mode, Bench, JsonReport};
use dpfw::dp::accounting::PrivacyParams;
use dpfw::fw::config::{FwConfig, SelectorKind};
use dpfw::fw::fast::FastFrankWolfe;
use dpfw::fw::standard::StandardFrankWolfe;
use dpfw::fw::workspace::FwWorkspace;
use dpfw::sparse::synth::{DatasetPreset, SynthConfig};
use dpfw::sparse::Dataset;

fn dataset(d: usize, seed: u64) -> Dataset {
    SynthConfig {
        name: format!("scale-d{d}"),
        n_rows: 2000,
        n_cols: d,
        avg_row_nnz: 40.0,
        zipf_exponent: 1.2,
        n_informative: 32,
        n_dense: 0,
        label_noise: 0.05,
        bias_col: true,
    }
    .generate(seed)
}

fn main() {
    let smoke = smoke_mode();
    let mut report = JsonReport::new("BENCH_iteration_cost.json");
    let iters = if smoke { 40 } else { 200 };
    let runs = if smoke { 1 } else { 3 };
    section("per-iteration cost vs D (N=2000, S_c=40, eps=1)");
    println!(
        "{:>10} {:>16} {:>16} {:>16} {:>10}",
        "D", "alg1 us/iter", "alg2+bsls us/it", "alg2+fib us/it", "speedup"
    );
    let d_grid: &[usize] = if smoke { &[4_000] } else { &[4_000, 16_000, 64_000, 256_000] };
    for &d in d_grid {
        let ds = dataset(d, 7);
        let dp = Some(PrivacyParams::new(1.0, 1e-6));
        let cfg = |sel, privacy| FwConfig {
            iters,
            lambda: 30.0,
            privacy,
            selector: sel,
            seed: 3,
            trace_every: 0,
            lipschitz: None,
            threads: 0,
        };
        let extra_owned = |sel: &str| -> Vec<(&'static str, String)> {
            vec![
                ("dataset", format!("synth-d{d}")),
                ("selector", sel.to_string()),
                ("iters", iters.to_string()),
            ]
        };
        let s1 = Bench::new(format!("alg1+noisymax D={d}")).runs(runs).run_stats(|| {
            StandardFrankWolfe::new(&ds, cfg(SelectorKind::NoisyMax, dp)).run().flops
        });
        report.record(&format!("alg1-noisymax-d{d}"), s1, &extra_owned("noisymax"));
        let s2 = Bench::new(format!("alg2+bsls     D={d}"))
            .runs(runs)
            .run_stats(|| FastFrankWolfe::new(&ds, cfg(SelectorKind::Bsls, dp)).run().flops);
        report.record(&format!("alg2-bsls-d{d}"), s2, &extra_owned("bsls"));
        let s3 = Bench::new(format!("alg2+fibheap  D={d} (non-private)"))
            .runs(runs)
            .run_stats(|| FastFrankWolfe::new(&ds, cfg(SelectorKind::FibHeap, None)).run().flops);
        report.record(&format!("alg2-fibheap-d{d}"), s3, &extra_owned("fibheap"));
        println!(
            "{:>10} {:>16.1} {:>16.1} {:>16.1} {:>9.1}x",
            d,
            s1.mean_s * 1e6 / iters as f64,
            s2.mean_s * 1e6 / iters as f64,
            s3.mean_s * 1e6 / iters as f64,
            s1.mean_s / s2.mean_s
        );
    }
    println!(
        "\nExpect: alg1 column ~4x per D step (O(D)); alg2+bsls column ~2x per D \
         step (O(sqrt(D))) — the paper's Table 1 scaling separation."
    );

    // ---- the cross-PR regression series: News20 preset + BSLS ----------
    section("news20 preset + BSLS (fused-scan regression series)");
    let n20_scale = if smoke { 0.01 } else { 0.05 };
    let ds = SynthConfig::preset(DatasetPreset::News20).scale(n20_scale).generate(42);
    println!(
        "workload: news20@{n20_scale}  N={} D={} nnz={}",
        ds.n_rows(),
        ds.n_cols(),
        ds.nnz()
    );
    let n20_iters = if smoke { 200 } else { 2000usize };
    let mk = || FwConfig {
        iters: n20_iters,
        lambda: 50.0,
        privacy: Some(PrivacyParams::new(1.0, 1e-6)),
        selector: SelectorKind::Bsls,
        seed: 9,
        trace_every: 0,
        lipschitz: None,
        threads: 0,
    };
    let n20_extra = |variant: &str| -> Vec<(&'static str, String)> {
        vec![
            ("dataset", format!("news20@{n20_scale}")),
            ("selector", "bsls".into()),
            ("iters", n20_iters.to_string()),
            ("variant", variant.into()),
        ]
    };
    let n20_runs = if smoke { 1 } else { 5 };
    let cold = Bench::new(format!("news20 alg2+bsls T={n20_iters} (cold workspace)"))
        .runs(n20_runs)
        .run_stats(|| FastFrankWolfe::new(&ds, mk()).run().flops);
    report.record("news20-bsls-cold", cold, &n20_extra("cold"));
    let mut ws = FwWorkspace::new();
    let warm = Bench::new(format!("news20 alg2+bsls T={n20_iters} (warm workspace)"))
        .runs(n20_runs)
        .run_stats(|| FastFrankWolfe::new(&ds, mk()).run_in(&mut ws).flops);
    report.record("news20-bsls-warm", warm, &n20_extra("warm"));
    println!(
        "  per-iteration: cold {:.2} us, warm {:.2} us",
        cold.mean_s * 1e6 / n20_iters as f64,
        warm.mean_s * 1e6 / n20_iters as f64
    );

    report.write().expect("write bench json");

    // ---- the path-engine series: 10-point λ path, independent vs
    // run_path, on the same News20-shaped synth + BSLS -------------------
    let mut path_report = JsonReport::with_env("BENCH_path_sweep.json", "DPFW_BENCH_PATH_JSON");
    section("10-point lambda path: independent runs vs run_path (news20 + BSLS)");
    let k_points = 10usize;
    // geometric grid 5 → 500, bracketing the paper's λ regimes
    let lambdas: Vec<f64> =
        (0..k_points).map(|i| 5.0 * 100.0f64.powf(i as f64 / (k_points - 1) as f64)).collect();
    let path_iters = if smoke { 100 } else { 1000 };
    let path_cfg = |lambda: f64| FwConfig {
        iters: path_iters,
        lambda,
        privacy: Some(PrivacyParams::new(1.0, 1e-6)),
        selector: SelectorKind::Bsls,
        seed: 9,
        trace_every: 0,
        lipschitz: None,
        threads: 0,
    };
    let path_extra = |variant: &str, per_lambda_us: f64| -> Vec<(&'static str, String)> {
        vec![
            ("dataset", format!("news20@{n20_scale}")),
            ("selector", "bsls".into()),
            ("iters", path_iters.to_string()),
            ("k", k_points.to_string()),
            ("variant", variant.into()),
            ("per_lambda_us", format!("{per_lambda_us:.1}")),
        ]
    };
    let path_runs = if smoke { 1 } else { 5 };
    let per_lam = |s: bench_harness::BenchStats| s.mean_s * 1e6 / k_points as f64;
    // independent: one fresh run (and workspace) per λ — the pre-path
    // consumption mode every (λ, ε) grid sweep used to pay
    let ind = Bench::new("independent per-λ runs").runs(path_runs).run_stats(|| {
        lambdas
            .iter()
            .map(|&lam| FastFrankWolfe::new(&ds, path_cfg(lam)).run().flops)
            .sum::<u64>()
    });
    path_report.record("path-independent", ind, &path_extra("independent", per_lam(ind)));
    // run_path, cold: a fresh workspace per timed call (first λ pays the
    // bootstrap, the other K−1 share it)
    let cold_path = Bench::new("run_path (cold workspace)").runs(path_runs).run_stats(|| {
        let mut ws = FwWorkspace::new();
        FastFrankWolfe::new(&ds, path_cfg(lambdas[0])).run_path(&lambdas, &mut ws).len()
    });
    path_report.record(
        "path-run-path-cold",
        cold_path,
        &path_extra("run_path-cold", per_lam(cold_path)),
    );
    // run_path, warm: one workspace across timed calls (primed by the
    // harness warmup, so even the first λ hits the bootstrap cache)
    let mut path_ws = FwWorkspace::new();
    let warm_path = Bench::new("run_path (warm workspace)").runs(path_runs).run_stats(|| {
        FastFrankWolfe::new(&ds, path_cfg(lambdas[0])).run_path(&lambdas, &mut path_ws).len()
    });
    path_report.record(
        "path-run-path-warm",
        warm_path,
        &path_extra("run_path-warm", per_lam(warm_path)),
    );
    println!(
        "  per-λ: independent {:.1} us, run_path cold {:.1} us, warm {:.1} us \
         (speedup cold {:.2}x, warm {:.2}x)",
        per_lam(ind),
        per_lam(cold_path),
        per_lam(warm_path),
        ind.mean_s / cold_path.mean_s,
        ind.mean_s / warm_path.mean_s
    );
    path_report.write().expect("write path sweep json");
}
