//! Bench: **Figures 2 & 4 (wall-clock view)** — per-iteration cost of
//! Alg 1 vs Alg 2 as D grows at fixed sparsity, demonstrating the paper's
//! headline complexity claim: Alg 1 scales O(D) per iteration while
//! Alg 2+BSLS scales ~O(√D). The printed `us/iter vs D` series is the
//! scaling law the paper's Table 1 promises.

mod bench_harness;

use bench_harness::{section, Bench};
use dpfw::dp::accounting::PrivacyParams;
use dpfw::fw::config::{FwConfig, SelectorKind};
use dpfw::fw::fast::FastFrankWolfe;
use dpfw::fw::standard::StandardFrankWolfe;
use dpfw::sparse::synth::SynthConfig;
use dpfw::sparse::Dataset;

fn dataset(d: usize, seed: u64) -> Dataset {
    SynthConfig {
        name: format!("scale-d{d}"),
        n_rows: 2000,
        n_cols: d,
        avg_row_nnz: 40.0,
        zipf_exponent: 1.2,
        n_informative: 32,
        n_dense: 0,
        label_noise: 0.05,
        bias_col: true,
    }
    .generate(seed)
}

fn main() {
    let iters = 200;
    section("per-iteration cost vs D (N=2000, S_c=40, T=200, eps=1)");
    println!(
        "{:>10} {:>16} {:>16} {:>16} {:>10}",
        "D", "alg1 us/iter", "alg2+bsls us/it", "alg2+fib us/it", "speedup"
    );
    for d in [4_000usize, 16_000, 64_000, 256_000] {
        let ds = dataset(d, 7);
        let dp = Some(PrivacyParams::new(1.0, 1e-6));
        let cfg = |sel, privacy| FwConfig {
            iters,
            lambda: 30.0,
            privacy,
            selector: sel,
            seed: 3,
            trace_every: 0,
            lipschitz: None,
        };
        let t1 = Bench::new(format!("alg1+noisymax D={d}"))
            .runs(3)
            .run(|| StandardFrankWolfe::new(&ds, cfg(SelectorKind::NoisyMax, dp)).run().flops);
        let t2 = Bench::new(format!("alg2+bsls     D={d}"))
            .runs(3)
            .run(|| FastFrankWolfe::new(&ds, cfg(SelectorKind::Bsls, dp)).run().flops);
        let t3 = Bench::new(format!("alg2+fibheap  D={d} (non-private)"))
            .runs(3)
            .run(|| FastFrankWolfe::new(&ds, cfg(SelectorKind::FibHeap, None)).run().flops);
        println!(
            "{:>10} {:>16.1} {:>16.1} {:>16.1} {:>9.1}x",
            d,
            t1 * 1e6 / iters as f64,
            t2 * 1e6 / iters as f64,
            t3 * 1e6 / iters as f64,
            t1 / t2
        );
    }
    println!(
        "\nExpect: alg1 column ~4x per D step (O(D)); alg2+bsls column ~2x per D \
         step (O(sqrt(D))) — the paper's Table 1 scaling separation."
    );
}
