//! Bench: **Figures 2 & 4 (wall-clock view)** — per-iteration cost of
//! Alg 1 vs Alg 2 as D grows at fixed sparsity, demonstrating the paper's
//! headline complexity claim: Alg 1 scales O(D) per iteration while
//! Alg 2+BSLS scales ~O(√D). The printed `us/iter vs D` series is the
//! scaling law the paper's Table 1 promises.
//!
//! Results are also persisted to `BENCH_iteration_cost.json` at the repo
//! root (override/disable via `DPFW_BENCH_JSON`, see `bench_harness`), so
//! the perf trajectory of the fused-scan engine is tracked across PRs. The
//! `news20-bsls` entries are the canonical regression series: the fast
//! solver on the News20 preset with the DP BSLS selector, both cold
//! (per-run workspace) and warm (reused workspace).
//!
//! A second report, `BENCH_path_sweep.json` (override via
//! `DPFW_BENCH_PATH_JSON`), tracks the regularization-path engine: per-λ
//! wall time of a 10-point λ-path on the News20-shaped synth + BSLS,
//! independent runs vs `run_path`, cold and warm workspace. `run_path`
//! per-λ must sit strictly below independent per-λ for K ≥ 3 — the
//! shared-bootstrap acceptance line.
//!
//! `DPFW_BENCH_SMOKE=1` shrinks every workload to CI-smoke size (the JSON
//! emitters still run end-to-end; the numbers are not comparable).

mod bench_harness;

use bench_harness::{section, smoke_mode, Bench, JsonReport};
use dpfw::dp::accounting::PrivacyParams;
use dpfw::fw::config::{FwConfig, SelectorKind};
use dpfw::fw::fast::FastFrankWolfe;
use dpfw::fw::standard::StandardFrankWolfe;
use dpfw::fw::workspace::FwWorkspace;
use dpfw::sparse::synth::{DatasetPreset, SynthConfig};
use dpfw::sparse::Dataset;

fn dataset(d: usize, seed: u64) -> Dataset {
    SynthConfig {
        name: format!("scale-d{d}"),
        n_rows: 2000,
        n_cols: d,
        avg_row_nnz: 40.0,
        zipf_exponent: 1.2,
        n_informative: 32,
        n_dense: 0,
        label_noise: 0.05,
        bias_col: true,
    }
    .generate(seed)
}

fn main() {
    let smoke = smoke_mode();
    let mut report = JsonReport::new("BENCH_iteration_cost.json");
    let iters = if smoke { 40 } else { 200 };
    let runs = if smoke { 1 } else { 3 };
    section("per-iteration cost vs D (N=2000, S_c=40, eps=1)");
    println!(
        "{:>10} {:>16} {:>16} {:>16} {:>10}",
        "D", "alg1 us/iter", "alg2+bsls us/it", "alg2+fib us/it", "speedup"
    );
    let d_grid: &[usize] = if smoke { &[4_000] } else { &[4_000, 16_000, 64_000, 256_000] };
    for &d in d_grid {
        let ds = dataset(d, 7);
        let dp = Some(PrivacyParams::new(1.0, 1e-6));
        let cfg = |sel, privacy| FwConfig {
            iters,
            lambda: 30.0,
            privacy,
            selector: sel,
            seed: 3,
            trace_every: 0,
            ..Default::default()
        };
        let extra_owned = |sel: &str| -> Vec<(&'static str, String)> {
            vec![
                ("dataset", format!("synth-d{d}")),
                ("selector", sel.to_string()),
                ("iters", iters.to_string()),
            ]
        };
        let s1 = Bench::new(format!("alg1+noisymax D={d}")).runs(runs).run_stats(|| {
            StandardFrankWolfe::new(&ds, cfg(SelectorKind::NoisyMax, dp)).run().flops
        });
        report.record(&format!("alg1-noisymax-d{d}"), s1, &extra_owned("noisymax"));
        let s2 = Bench::new(format!("alg2+bsls     D={d}"))
            .runs(runs)
            .run_stats(|| FastFrankWolfe::new(&ds, cfg(SelectorKind::Bsls, dp)).run().flops);
        report.record(&format!("alg2-bsls-d{d}"), s2, &extra_owned("bsls"));
        let s3 = Bench::new(format!("alg2+fibheap  D={d} (non-private)"))
            .runs(runs)
            .run_stats(|| FastFrankWolfe::new(&ds, cfg(SelectorKind::FibHeap, None)).run().flops);
        report.record(&format!("alg2-fibheap-d{d}"), s3, &extra_owned("fibheap"));
        println!(
            "{:>10} {:>16.1} {:>16.1} {:>16.1} {:>9.1}x",
            d,
            s1.mean_s * 1e6 / iters as f64,
            s2.mean_s * 1e6 / iters as f64,
            s3.mean_s * 1e6 / iters as f64,
            s1.mean_s / s2.mean_s
        );
    }
    println!(
        "\nExpect: alg1 column ~4x per D step (O(D)); alg2+bsls column ~2x per D \
         step (O(sqrt(D))) — the paper's Table 1 scaling separation."
    );

    // ---- the cross-PR regression series: News20 preset + BSLS ----------
    section("news20 preset + BSLS (fused-scan regression series)");
    let n20_scale = if smoke { 0.01 } else { 0.05 };
    let ds = SynthConfig::preset(DatasetPreset::News20).scale(n20_scale).generate(42);
    println!(
        "workload: news20@{n20_scale}  N={} D={} nnz={} index={}",
        ds.n_rows(),
        ds.n_cols(),
        ds.nnz(),
        ds.index_kind()
    );
    let n20_iters = if smoke { 200 } else { 2000usize };
    let mk = || FwConfig {
        iters: n20_iters,
        lambda: 50.0,
        privacy: Some(PrivacyParams::new(1.0, 1e-6)),
        selector: SelectorKind::Bsls,
        seed: 9,
        trace_every: 0,
        ..Default::default()
    };
    let n20_extra = |variant: &str| -> Vec<(&'static str, String)> {
        vec![
            ("dataset", format!("news20@{n20_scale}")),
            ("selector", "bsls".into()),
            ("iters", n20_iters.to_string()),
            ("variant", variant.into()),
        ]
    };
    let n20_runs = if smoke { 1 } else { 5 };
    let cold = Bench::new(format!("news20 alg2+bsls T={n20_iters} (cold workspace)"))
        .runs(n20_runs)
        .run_stats(|| FastFrankWolfe::new(&ds, mk()).run().flops);
    report.record("news20-bsls-cold", cold, &n20_extra("cold"));
    let mut ws = FwWorkspace::new();
    let warm = Bench::new(format!("news20 alg2+bsls T={n20_iters} (warm workspace)"))
        .runs(n20_runs)
        .run_stats(|| FastFrankWolfe::new(&ds, mk()).run_in(&mut ws).flops);
    report.record("news20-bsls-warm", warm, &n20_extra("warm"));
    println!(
        "  per-iteration: cold {:.2} us, warm {:.2} us",
        cold.mean_s * 1e6 / n20_iters as f64,
        warm.mean_s * 1e6 / n20_iters as f64
    );

    // ---- bytes-moved series: compact u16-delta vs stripped u32 ---------
    // (DESIGN.md §6.6). `bytes_moved` is deterministic, so the reduction
    // assert runs even in smoke mode; wall-clock is recorded alongside so
    // CI hardware accumulates the traffic-vs-time trajectory.
    section("news20 + BSLS: compact u16-delta vs u32 substrate");
    let mut ds_u32 = ds.clone();
    ds_u32.strip_compact();
    let mut traffic = (0u64, 0u64); // (compact, u32) bytes_moved
    // (direct_segments, scratch_segments, scratch_bytes) of the last
    // compact run — the §6.7 dispatcher split the JSON series tracks
    let mut split = (0u64, 0u64, 0u64);
    let compact_stats =
        Bench::new(format!("news20 alg2+bsls T={n20_iters} (u16-delta substrate)"))
            .runs(n20_runs)
            .run_stats(|| {
                let out = FastFrankWolfe::new(&ds, mk()).run();
                traffic.0 = out.bytes_moved;
                split = (out.direct_segments, out.scratch_segments, out.scratch_bytes);
                out.flops
            });
    let u32_stats = Bench::new(format!("news20 alg2+bsls T={n20_iters} (u32 substrate)"))
        .runs(n20_runs)
        .run_stats(|| {
            let out = FastFrankWolfe::new(&ds_u32, mk()).run();
            traffic.1 = out.bytes_moved;
            out.flops
        });
    let per_iter = |b: u64| b as f64 / n20_iters as f64;
    assert!(
        traffic.0 < traffic.1,
        "sanity: compact substrate must move fewer bytes ({} vs {})",
        traffic.0,
        traffic.1
    );
    let traffic_extra = |variant: &str, bytes: u64| {
        let mut e = n20_extra(variant);
        e.push(("index_kind", if variant == "u16-delta" { "u16-delta" } else { "u32" }.into()));
        e.push(("bytes_moved", bytes.to_string()));
        e.push(("bytes_per_iter", format!("{:.1}", per_iter(bytes))));
        if variant == "u16-delta" {
            e.push(("direct_segments", split.0.to_string()));
            e.push(("scratch_segments", split.1.to_string()));
            e.push(("scratch_bytes", split.2.to_string()));
        }
        e
    };
    report.record(
        "news20-bsls-compact-substrate",
        compact_stats,
        &traffic_extra("u16-delta", traffic.0),
    );
    report.record("news20-bsls-u32-substrate", u32_stats, &traffic_extra("u32", traffic.1));
    println!(
        "  bytes/iter: u16-delta {:.0}, u32 {:.0} ({:.1}% of baseline)",
        per_iter(traffic.0),
        per_iter(traffic.1),
        100.0 * traffic.0 as f64 / traffic.1 as f64
    );

    // ---- §6.7 direct-decode dispatcher: all-fused vs all-scratch -------
    // Wall-clock is the measurable win on CI hardware; the modeled-bytes
    // invariants are deterministic and guard the tier even in smoke mode:
    // the trajectory and DRAM byte model are threshold-invariant, the
    // all-fused run pays zero scratch round-trips, and fused total
    // modeled traffic (DRAM + L1 scratch) can never exceed scratch's.
    section("news20 + BSLS: direct-decode dispatcher (fused vs scratch arms)");
    let run_thr = |thr: Option<usize>| {
        FastFrankWolfe::new(&ds, FwConfig { direct_max_nnz: thr, ..mk() }).run()
    };
    let mut fused_probe: Option<dpfw::fw::trace::FwOutput> = None;
    let fused_stats = Bench::new(format!("news20 alg2+bsls T={n20_iters} (all-fused)"))
        .runs(n20_runs)
        .run_stats(|| {
            let out = run_thr(Some(usize::MAX));
            let f = out.flops;
            fused_probe = Some(out);
            f
        });
    let fused_probe = fused_probe.expect("bench ran at least once");
    let mut scratch_probe: Option<dpfw::fw::trace::FwOutput> = None;
    let scratch_stats = Bench::new(format!("news20 alg2+bsls T={n20_iters} (all-scratch)"))
        .runs(n20_runs)
        .run_stats(|| {
            let out = run_thr(Some(0));
            let f = out.flops;
            scratch_probe = Some(out);
            f
        });
    let scratch_probe = scratch_probe.expect("bench ran at least once");
    let default_probe = run_thr(None);
    assert_eq!(
        fused_probe.flops, scratch_probe.flops,
        "sanity: the dispatcher threshold must not change counted work"
    );
    assert_eq!(
        fused_probe.bytes_moved, scratch_probe.bytes_moved,
        "sanity: the DRAM byte model is threshold-invariant"
    );
    assert_eq!(fused_probe.scratch_bytes, 0, "sanity: all-fused pays no scratch round-trips");
    assert!(
        scratch_probe.scratch_segments > 0 && scratch_probe.scratch_bytes > 0,
        "sanity: all-scratch must record the round-trips it pays"
    );
    assert!(
        fused_probe.bytes_moved + fused_probe.scratch_bytes
            <= scratch_probe.bytes_moved + scratch_probe.scratch_bytes,
        "sanity: fused-kernel modeled bytes must not exceed scratch-kernel modeled bytes"
    );
    let tier_extra = |variant: &str, out: &dpfw::fw::trace::FwOutput| {
        let mut e = n20_extra(variant);
        e.push(("direct_segments", out.direct_segments.to_string()));
        e.push(("scratch_segments", out.scratch_segments.to_string()));
        e.push(("scratch_bytes", out.scratch_bytes.to_string()));
        e.push(("bytes_moved", out.bytes_moved.to_string()));
        e
    };
    report.record("news20-bsls-all-fused", fused_stats, &tier_extra("all-fused", &fused_probe));
    report.record(
        "news20-bsls-all-scratch",
        scratch_stats,
        &tier_extra("all-scratch", &scratch_probe),
    );
    println!(
        "  dispatcher: default split {} direct / {} scratch segments \
         ({:.2e} scratch bytes); all-fused {:.2} us/iter vs all-scratch {:.2} us/iter",
        default_probe.direct_segments,
        default_probe.scratch_segments,
        default_probe.scratch_bytes as f64,
        fused_stats.mean_s * 1e6 / n20_iters as f64,
        scratch_stats.mean_s * 1e6 / n20_iters as f64
    );

    // ---- phase breakdown (structured, from FwOutput::phase) ------------
    // One instrumented probe run outside the timed series, so the
    // Instant reads never pollute the regression numbers.
    std::env::set_var("DPFW_PHASE_TIMING", "1");
    let probe = FastFrankWolfe::new(&ds, mk()).run();
    std::env::remove_var("DPFW_PHASE_TIMING");
    let phase = probe.phase.expect("DPFW_PHASE_TIMING was set");
    let probe_stats = bench_harness::BenchStats {
        mean_s: probe.wall_ms / 1e3,
        min_s: probe.wall_ms / 1e3,
        stddev_s: 0.0,
        runs: 1,
    };
    report.record(
        "news20-bsls-phases",
        probe_stats,
        &[
            ("dataset", format!("news20@{n20_scale}")),
            ("selector", "bsls".into()),
            ("iters", n20_iters.to_string()),
            ("select_ns", phase.select_ns.to_string()),
            ("update_ns", phase.update_ns.to_string()),
            ("notify_ns", phase.notify_ns.to_string()),
            ("bytes_moved", probe.bytes_moved.to_string()),
        ],
    );
    println!(
        "  phase ns/iter: select {:.0}, update {:.0}, notify {:.0}",
        phase.select_ns as f64 / n20_iters as f64,
        phase.update_ns as f64 / n20_iters as f64,
        phase.notify_ns as f64 / n20_iters as f64
    );

    report.write().expect("write bench json");

    // ---- the path-engine series: 10-point λ path, independent vs
    // run_path, on the same News20-shaped synth + BSLS -------------------
    let mut path_report = JsonReport::with_env("BENCH_path_sweep.json", "DPFW_BENCH_PATH_JSON");
    section("10-point lambda path: independent runs vs run_path (news20 + BSLS)");
    let k_points = 10usize;
    // geometric grid 5 → 500, bracketing the paper's λ regimes
    let lambdas: Vec<f64> =
        (0..k_points).map(|i| 5.0 * 100.0f64.powf(i as f64 / (k_points - 1) as f64)).collect();
    let path_iters = if smoke { 100 } else { 1000 };
    let path_cfg = |lambda: f64| FwConfig {
        iters: path_iters,
        lambda,
        privacy: Some(PrivacyParams::new(1.0, 1e-6)),
        selector: SelectorKind::Bsls,
        seed: 9,
        trace_every: 0,
        ..Default::default()
    };
    let path_extra = |variant: &str, per_lambda_us: f64| -> Vec<(&'static str, String)> {
        vec![
            ("dataset", format!("news20@{n20_scale}")),
            ("selector", "bsls".into()),
            ("iters", path_iters.to_string()),
            ("k", k_points.to_string()),
            ("variant", variant.into()),
            ("per_lambda_us", format!("{per_lambda_us:.1}")),
        ]
    };
    let path_runs = if smoke { 1 } else { 5 };
    let per_lam = |s: bench_harness::BenchStats| s.mean_s * 1e6 / k_points as f64;
    // independent: one fresh run (and workspace) per λ — the pre-path
    // consumption mode every (λ, ε) grid sweep used to pay
    let ind = Bench::new("independent per-λ runs").runs(path_runs).run_stats(|| {
        lambdas
            .iter()
            .map(|&lam| FastFrankWolfe::new(&ds, path_cfg(lam)).run().flops)
            .sum::<u64>()
    });
    path_report.record("path-independent", ind, &path_extra("independent", per_lam(ind)));
    // run_path, cold: a fresh workspace per timed call (first λ pays the
    // bootstrap, the other K−1 share it)
    let mut path_flops = (0u64, 0u64); // (cold, warm) summed FLOP totals
    let cold_path = Bench::new("run_path (cold workspace)").runs(path_runs).run_stats(|| {
        let mut ws = FwWorkspace::new();
        let outs = FastFrankWolfe::new(&ds, path_cfg(lambdas[0])).run_path(&lambdas, &mut ws);
        path_flops.0 = outs.iter().map(|o| o.flops).sum();
        outs.len()
    });
    path_report.record(
        "path-run-path-cold",
        cold_path,
        &path_extra("run_path-cold", per_lam(cold_path)),
    );
    // run_path, warm: one workspace across timed calls (primed by the
    // harness warmup, so even the first λ hits the bootstrap cache)
    let mut path_ws = FwWorkspace::new();
    let warm_path = Bench::new("run_path (warm workspace)").runs(path_runs).run_stats(|| {
        let outs =
            FastFrankWolfe::new(&ds, path_cfg(lambdas[0])).run_path(&lambdas, &mut path_ws);
        path_flops.1 = outs.iter().map(|o| o.flops).sum();
        outs.len()
    });
    path_report.record(
        "path-run-path-warm",
        warm_path,
        &path_extra("run_path-warm", per_lam(warm_path)),
    );
    // Sanity (deterministic, so it holds even under DPFW_BENCH_SMOKE=1
    // where wall-clock would be noise): a warm path skips the one cold
    // bootstrap, so its total counted work must be strictly lower.
    assert!(
        path_flops.1 < path_flops.0,
        "sanity: warm path totals ({}) must be below cold totals ({})",
        path_flops.1,
        path_flops.0
    );
    println!(
        "  per-λ: independent {:.1} us, run_path cold {:.1} us, warm {:.1} us \
         (speedup cold {:.2}x, warm {:.2}x)",
        per_lam(ind),
        per_lam(cold_path),
        per_lam(warm_path),
        ind.mean_s / cold_path.mean_s,
        ind.mean_s / warm_path.mean_s
    );
    path_report.write().expect("write path sweep json");
}
