//! Bench: **Figures 2 & 4 (wall-clock view)** — per-iteration cost of
//! Alg 1 vs Alg 2 as D grows at fixed sparsity, demonstrating the paper's
//! headline complexity claim: Alg 1 scales O(D) per iteration while
//! Alg 2+BSLS scales ~O(√D). The printed `us/iter vs D` series is the
//! scaling law the paper's Table 1 promises.
//!
//! Results are also persisted to `BENCH_iteration_cost.json` at the repo
//! root (override/disable via `DPFW_BENCH_JSON`, see `bench_harness`), so
//! the perf trajectory of the fused-scan engine is tracked across PRs. The
//! `news20-bsls` entries are the canonical regression series: the fast
//! solver on the News20 preset with the DP BSLS selector, both cold
//! (per-run workspace) and warm (reused workspace).

mod bench_harness;

use bench_harness::{section, Bench, JsonReport};
use dpfw::dp::accounting::PrivacyParams;
use dpfw::fw::config::{FwConfig, SelectorKind};
use dpfw::fw::fast::FastFrankWolfe;
use dpfw::fw::standard::StandardFrankWolfe;
use dpfw::fw::workspace::FwWorkspace;
use dpfw::sparse::synth::{DatasetPreset, SynthConfig};
use dpfw::sparse::Dataset;

fn dataset(d: usize, seed: u64) -> Dataset {
    SynthConfig {
        name: format!("scale-d{d}"),
        n_rows: 2000,
        n_cols: d,
        avg_row_nnz: 40.0,
        zipf_exponent: 1.2,
        n_informative: 32,
        n_dense: 0,
        label_noise: 0.05,
        bias_col: true,
    }
    .generate(seed)
}

fn main() {
    let mut report = JsonReport::new("BENCH_iteration_cost.json");
    let iters = 200;
    section("per-iteration cost vs D (N=2000, S_c=40, T=200, eps=1)");
    println!(
        "{:>10} {:>16} {:>16} {:>16} {:>10}",
        "D", "alg1 us/iter", "alg2+bsls us/it", "alg2+fib us/it", "speedup"
    );
    for d in [4_000usize, 16_000, 64_000, 256_000] {
        let ds = dataset(d, 7);
        let dp = Some(PrivacyParams::new(1.0, 1e-6));
        let cfg = |sel, privacy| FwConfig {
            iters,
            lambda: 30.0,
            privacy,
            selector: sel,
            seed: 3,
            trace_every: 0,
            lipschitz: None,
            threads: 0,
        };
        let extra_owned = |sel: &str| -> Vec<(&'static str, String)> {
            vec![
                ("dataset", format!("synth-d{d}")),
                ("selector", sel.to_string()),
                ("iters", iters.to_string()),
            ]
        };
        let s1 = Bench::new(format!("alg1+noisymax D={d}")).runs(3).run_stats(|| {
            StandardFrankWolfe::new(&ds, cfg(SelectorKind::NoisyMax, dp)).run().flops
        });
        report.record(&format!("alg1-noisymax-d{d}"), s1, &extra_owned("noisymax"));
        let s2 = Bench::new(format!("alg2+bsls     D={d}"))
            .runs(3)
            .run_stats(|| FastFrankWolfe::new(&ds, cfg(SelectorKind::Bsls, dp)).run().flops);
        report.record(&format!("alg2-bsls-d{d}"), s2, &extra_owned("bsls"));
        let s3 = Bench::new(format!("alg2+fibheap  D={d} (non-private)"))
            .runs(3)
            .run_stats(|| FastFrankWolfe::new(&ds, cfg(SelectorKind::FibHeap, None)).run().flops);
        report.record(&format!("alg2-fibheap-d{d}"), s3, &extra_owned("fibheap"));
        println!(
            "{:>10} {:>16.1} {:>16.1} {:>16.1} {:>9.1}x",
            d,
            s1.mean_s * 1e6 / iters as f64,
            s2.mean_s * 1e6 / iters as f64,
            s3.mean_s * 1e6 / iters as f64,
            s1.mean_s / s2.mean_s
        );
    }
    println!(
        "\nExpect: alg1 column ~4x per D step (O(D)); alg2+bsls column ~2x per D \
         step (O(sqrt(D))) — the paper's Table 1 scaling separation."
    );

    // ---- the cross-PR regression series: News20 preset + BSLS ----------
    section("news20 preset + BSLS (fused-scan regression series)");
    let ds = SynthConfig::preset(DatasetPreset::News20).scale(0.05).generate(42);
    println!(
        "workload: news20@0.05  N={} D={} nnz={}",
        ds.n_rows(),
        ds.n_cols(),
        ds.nnz()
    );
    let n20_iters = 2000usize;
    let mk = || FwConfig {
        iters: n20_iters,
        lambda: 50.0,
        privacy: Some(PrivacyParams::new(1.0, 1e-6)),
        selector: SelectorKind::Bsls,
        seed: 9,
        trace_every: 0,
        lipschitz: None,
        threads: 0,
    };
    let n20_extra = |variant: &str| -> Vec<(&'static str, String)> {
        vec![
            ("dataset", "news20@0.05".into()),
            ("selector", "bsls".into()),
            ("iters", n20_iters.to_string()),
            ("variant", variant.into()),
        ]
    };
    let cold = Bench::new("news20 alg2+bsls T=2000 (cold workspace)")
        .runs(5)
        .run_stats(|| FastFrankWolfe::new(&ds, mk()).run().flops);
    report.record("news20-bsls-cold", cold, &n20_extra("cold"));
    let mut ws = FwWorkspace::new();
    let warm = Bench::new("news20 alg2+bsls T=2000 (warm workspace)")
        .runs(5)
        .run_stats(|| FastFrankWolfe::new(&ds, mk()).run_in(&mut ws).flops);
    report.record("news20-bsls-warm", warm, &n20_extra("warm"));
    println!(
        "  per-iteration: cold {:.2} us, warm {:.2} us",
        cold.mean_s * 1e6 / n20_iters as f64,
        warm.mean_s * 1e6 / n20_iters as f64
    );

    report.write().expect("write bench json");
}
