//! Bench: **serving coordinator under an overload burst** (DESIGN.md §6.9).
//!
//! Fires a burst of jobs at a small worker pool — a mix of clean cells,
//! λ-paths, jobs with deadlines tight enough to shed or timeout, and
//! panic-faulted jobs running under the seed-pinned retry policy — then
//! drains and reports the resilience surface: queue-inclusive p50/p99
//! latency per job class plus shed/retry/timeout/respawn counts. Emits
//! `BENCH_coordinator.json` so CI tracks the serving story across PRs.
//!
//! Like the other benches, the run doubles as an invariant check: every
//! submitted id must resolve (Ok or a structured error), the retried jobs
//! must succeed with the shed/retry counters matching the injected load,
//! and the drain must finish without a coordinator panic.

mod bench_harness;

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use bench_harness::{section, smoke_mode, Bench, JsonReport};
use dpfw::coordinator::scheduler::RetryPolicy;
use dpfw::coordinator::{Algo, Coordinator, JobError, JobSpec, PathJob};
use dpfw::dp::accounting::PrivacyParams;
use dpfw::fw::cancel::CancelToken;
use dpfw::fw::config::{FwConfig, SelectorKind};
use dpfw::sparse::synth::{DatasetPreset, SynthConfig};
use dpfw::sparse::Dataset;
use dpfw::testkit::faults::{FaultKind, FaultPlan};

struct BurstShape {
    clean: usize,
    paths: usize,
    shed: usize,
    faulted: usize,
    iters: usize,
}

/// One overload burst: submit everything at once, drain, sanity-check the
/// outcome ledger. Returns (results_drained, coordinator) so the caller
/// can read the metrics surface after timing.
fn run_burst(ds: &Arc<Dataset>, workers: usize, shape: &BurstShape) -> Coordinator {
    let mut c = Coordinator::with_retry(
        workers,
        RetryPolicy { retry_limit: 2, backoff_base: Duration::from_millis(1) },
    );
    let cfg = |seed: u64| FwConfig {
        iters: shape.iters,
        lambda: 8.0,
        privacy: Some(PrivacyParams::new(1.0, 1e-6)),
        selector: SelectorKind::Bsls,
        seed,
        ..Default::default()
    };
    let mut id = 0usize;
    for k in 0..shape.clean {
        c.submit(JobSpec {
            id,
            label: format!("clean{k}"),
            data: ds.clone(),
            algo: Algo::Fast,
            cfg: cfg(k as u64),
            test_data: None,
        });
        id += 1;
    }
    for k in 0..shape.paths {
        let lambdas = vec![4.0, 8.0, 16.0];
        c.submit_path(PathJob {
            base_id: id,
            label: format!("path{k}"),
            data: ds.clone(),
            algo: Algo::Fast,
            cfg: cfg(100 + k as u64),
            lambdas: lambdas.clone(),
            test_data: None,
        });
        id += lambdas.len();
    }
    for k in 0..shape.shed {
        // already-expired deadline: the scheduler must shed these unrun
        let mut doomed = cfg(200 + k as u64);
        doomed.cancel = CancelToken::deadline_in(Duration::ZERO);
        c.submit(JobSpec {
            id,
            label: format!("shed{k}"),
            data: ds.clone(),
            algo: Algo::Fast,
            cfg: doomed,
            test_data: None,
        });
        id += 1;
    }
    for k in 0..shape.faulted {
        // one mid-run panic each; the seed-pinned retry succeeds
        let mut faulted = cfg(300 + k as u64);
        faulted.fault = FaultPlan::once(FaultKind::PanicAt { iter: 3 });
        c.submit(JobSpec {
            id,
            label: format!("fault{k}"),
            data: ds.clone(),
            algo: Algo::Fast,
            cfg: faulted,
            test_data: None,
        });
        id += 1;
    }

    let results = c.drain();
    assert_eq!(results.len(), id, "every owed id must resolve");
    let shed = results.iter().filter(|r| matches!(r, Err(JobError::Expired))).count();
    assert_eq!(shed, shape.shed, "expired-at-submit jobs must all shed");
    let failed = results.iter().filter(|r| r.is_err()).count();
    assert_eq!(failed, shape.shed, "faulted jobs must recover via retry");
    c
}

fn main() {
    let smoke = smoke_mode();
    let scale = if smoke { 0.01 } else { 0.05 };
    let runs = if smoke { 2 } else { 5 };
    let shape = BurstShape {
        clean: if smoke { 6 } else { 24 },
        paths: if smoke { 2 } else { 6 },
        shed: if smoke { 2 } else { 8 },
        faulted: if smoke { 2 } else { 6 },
        iters: if smoke { 40 } else { 150 },
    };
    let ds = Arc::new(
        SynthConfig::preset(DatasetPreset::News20).scale(scale).generate(42),
    );
    println!(
        "coordinator burst: News20-synth scale={scale} (N={}, D={}, nnz={})",
        ds.n_rows(),
        ds.n_cols(),
        ds.nnz()
    );

    let mut report = JsonReport::with_env("BENCH_coordinator.json", "DPFW_BENCH_COORDINATOR_JSON");
    for workers in [1usize, 4] {
        section(&format!(
            "overload burst: {} cells + {} paths + {} shed + {} faulted, {} workers",
            shape.clean, shape.paths, shape.shed, shape.faulted, workers
        ));
        let stats = Bench::new(format!("burst-{workers}w"))
            .warmup(1)
            .runs(runs)
            .run_stats(|| run_burst(&ds, workers, &shape));
        // metrics from a fresh, untimed burst (the timed ones are dropped)
        let c = run_burst(&ds, workers, &shape);
        let m = &c.metrics;
        println!(
            "  {} | cell p50/p99 {}/{} µs, path p50/p99 {}/{} µs",
            m.summary(),
            m.cell_latency.p50_us(),
            m.cell_latency.p99_us(),
            m.path_latency.p50_us(),
            m.path_latency.p99_us(),
        );
        report.record(
            &format!("coordinator-burst-{workers}w"),
            stats,
            &[
                ("workers", workers.to_string()),
                ("jobs_submitted", m.jobs_submitted.load(Ordering::Relaxed).to_string()),
                ("cell_p50_us", m.cell_latency.p50_us().to_string()),
                ("cell_p99_us", m.cell_latency.p99_us().to_string()),
                ("path_p50_us", m.path_latency.p50_us().to_string()),
                ("path_p99_us", m.path_latency.p99_us().to_string()),
                ("sheds", m.sheds.load(Ordering::Relaxed).to_string()),
                ("retries", m.retries.load(Ordering::Relaxed).to_string()),
                ("timeouts", m.timeouts.load(Ordering::Relaxed).to_string()),
                ("respawns", m.workers_respawned.load(Ordering::Relaxed).to_string()),
            ],
        );
    }
    report.write().expect("failed to write coordinator JSON");
}
