//! Bench: substrate microbenchmarks — sparse matvec / transpose-matvec /
//! column scans (the building blocks whose costs appear in every line of
//! the paper's complexity annotations), the §6.7 direct-decode kernel
//! tier by segment length, CSR↔CSC conversion, LIBSVM parse, and
//! synthetic generation throughput.
//!
//! Results are persisted to `BENCH_substrates.json` at the repo root
//! (override/disable via `DPFW_BENCH_SUBSTRATES_JSON`). The
//! per-segment-length series (nnz ∈ {4, 8, 16, 40, 200, 2000}; scratch
//! vs. fused vs. u32 for both `dot_gather` and `update_touch`) is the
//! empirical basis for the `DIRECT_MAX_NNZ` dispatcher threshold: the
//! fused arm should win below the threshold and lose above it on CI
//! hardware. `DPFW_BENCH_SMOKE=1` shrinks every workload to CI-smoke
//! size (the JSON emitter still runs end-to-end).

mod bench_harness;

use bench_harness::{section, smoke_mode, Bench, JsonReport};
use dpfw::fw::scan::{self, ScanKernel};
use dpfw::sparse::compact::{CompactIndices, IndexSeg};
use dpfw::sparse::csc::CscMatrix;
use dpfw::sparse::libsvm;
use dpfw::sparse::synth::{DatasetPreset, SynthConfig};

/// A synthetic index structure of `n_segs` segments of `nnz` indices
/// each: paper-shaped small deltas within a segment, per-segment base
/// offsets spread across `dim` (often ≥ 2¹⁶, so escape blocks occur at
/// realistic density). Returns `(indptr, indices, values)`.
fn uniform_segments(n_segs: usize, nnz: usize, dim: usize) -> (Vec<usize>, Vec<u32>, Vec<f32>) {
    let mut indptr = Vec::with_capacity(n_segs + 1);
    let mut indices = Vec::with_capacity(n_segs * nnz);
    let mut values = Vec::with_capacity(n_segs * nnz);
    let mut state = 0x9e3779b97f4a7c15u64;
    indptr.push(0);
    for s in 0..n_segs {
        let mut j = ((s * 9973) % (dim - 10 * nnz - 1)) as u32;
        for _ in 0..nnz {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
            j += 1 + (state >> 40) as u32 % 9;
            indices.push(j);
            values.push(((state >> 20) as f32 / 2.0_f32.powi(30)) - 2.0);
        }
        indptr.push(indices.len());
    }
    (indptr, indices, values)
}

fn kernel_tier_series(report: &mut JsonReport, smoke: bool) {
    section("direct-decode kernel tier: scratch vs fused vs u32 by segment nnz (DESIGN.md 6.7)");
    let dim = 1 << 20; // 8 MB gather target: genuinely out of cache
    let w: Vec<f64> = (0..dim).map(|k| (k as f64 * 0.13).sin()).collect();
    let total_nnz: usize = if smoke { 20_000 } else { 2_000_000 };
    let runs = if smoke { 1 } else { 5 };
    let fused = ScanKernel::with_threshold(usize::MAX);
    let scratchy = ScanKernel::with_threshold(0);
    println!(
        "{:>8} {:>14} {:>14} {:>14}  (ns/element, dot_gather)",
        "nnz", "scratch", "fused", "u32"
    );
    for &nnz in &[4usize, 8, 16, 40, 200, 2000] {
        let n_segs = (total_nnz / nnz).max(8);
        let (indptr, indices, values) = uniform_segments(n_segs, nnz, dim);
        let compact =
            CompactIndices::build(&indptr, &indices).expect("small-delta segments must qualify");
        let elems = (n_segs * nnz) as f64;
        let extra = |arm: &str, kernel: &str| -> Vec<(&'static str, String)> {
            vec![
                ("kernel", kernel.to_string()),
                ("arm", arm.to_string()),
                ("seg_nnz", nnz.to_string()),
                ("n_segs", n_segs.to_string()),
            ]
        };

        // ---- dot_gather: the matvec/column-sweep kernel -----------------
        let mut scratch = Vec::new();
        let dot_sweep = |kern: ScanKernel, scratch: &mut Vec<u32>| {
            let mut acc = 0.0f64;
            for s in 0..n_segs {
                let seg = IndexSeg::U16 {
                    words: compact.seg_words(s),
                    nnz,
                };
                acc += kern.dot(seg, &values[indptr[s]..indptr[s + 1]], &w, scratch);
            }
            acc
        };
        let t_scr = Bench::new(format!("dot scratch nnz={nnz}"))
            .runs(runs)
            .run_stats(|| dot_sweep(scratchy, &mut scratch));
        report.record(&format!("dot-scratch-nnz{nnz}"), t_scr, &extra("scratch", "dot"));
        let t_fus = Bench::new(format!("dot fused   nnz={nnz}"))
            .runs(runs)
            .run_stats(|| dot_sweep(fused, &mut scratch));
        report.record(&format!("dot-fused-nnz{nnz}"), t_fus, &extra("fused", "dot"));
        let t_u32 = Bench::new(format!("dot u32     nnz={nnz}")).runs(runs).run_stats(|| {
            let mut acc = 0.0f64;
            for s in 0..n_segs {
                acc += scan::dot_gather(
                    &indices[indptr[s]..indptr[s + 1]],
                    &values[indptr[s]..indptr[s + 1]],
                    &w,
                );
            }
            acc
        });
        report.record(&format!("dot-u32-nnz{nnz}"), t_u32, &extra("u32", "dot"));
        println!(
            "{:>8} {:>14.2} {:>14.2} {:>14.2}",
            nnz,
            t_scr.mean_s * 1e9 / elems,
            t_fus.mean_s * 1e9 / elems,
            t_u32.mean_s * 1e9 / elems
        );

        // ---- update_touch: the Alg 2 fused row kernel -------------------
        let mut alpha = vec![0.0f64; dim];
        let mut stamp = vec![0u32; dim];
        let mut touched: Vec<u32> = Vec::new();
        let mut epoch = 0u32;
        let mut ut_sweep = |kern: ScanKernel, scratch: &mut Vec<u32>| {
            epoch = epoch.wrapping_add(1);
            if epoch == 0 {
                stamp.fill(0);
                epoch = 1;
            }
            touched.clear();
            for s in 0..n_segs {
                let seg = IndexSeg::U16 {
                    words: compact.seg_words(s),
                    nnz,
                };
                kern.update_touch(
                    seg,
                    &values[indptr[s]..indptr[s + 1]],
                    0.37,
                    &mut alpha,
                    &mut stamp,
                    epoch,
                    &mut touched,
                    scratch,
                );
            }
            touched.len()
        };
        let t_scr = Bench::new(format!("update_touch scratch nnz={nnz}"))
            .runs(runs)
            .run_stats(|| ut_sweep(scratchy, &mut scratch));
        report.record(
            &format!("update-touch-scratch-nnz{nnz}"),
            t_scr,
            &extra("scratch", "update_touch"),
        );
        let t_fus = Bench::new(format!("update_touch fused   nnz={nnz}"))
            .runs(runs)
            .run_stats(|| ut_sweep(fused, &mut scratch));
        report.record(
            &format!("update-touch-fused-nnz{nnz}"),
            t_fus,
            &extra("fused", "update_touch"),
        );
        // u32 reference arm: the same sweep on the raw index stream
        let t_u32 =
            Bench::new(format!("update_touch u32     nnz={nnz}")).runs(runs).run_stats(|| {
                epoch = epoch.wrapping_add(1);
                if epoch == 0 {
                    stamp.fill(0);
                    epoch = 1;
                }
                touched.clear();
                for s in 0..n_segs {
                    scan::update_touch(
                        &indices[indptr[s]..indptr[s + 1]],
                        &values[indptr[s]..indptr[s + 1]],
                        0.37,
                        &mut alpha,
                        &mut stamp,
                        epoch,
                        &mut touched,
                    );
                }
                touched.len()
            });
        report.record(&format!("update-touch-u32-nnz{nnz}"), t_u32, &extra("u32", "update_touch"));
        println!(
            "{:>8} {:>14.2} {:>14.2} {:>14.2}  (ns/element, update_touch)",
            nnz,
            t_scr.mean_s * 1e9 / elems,
            t_fus.mean_s * 1e9 / elems,
            t_u32.mean_s * 1e9 / elems
        );
    }
    println!(
        "\nExpect: fused beats scratch at small nnz (the store+load round-trip \
         dominates), scratch catches up as the decode amortizes — the crossover \
         justifies DIRECT_MAX_NNZ = {}.",
        scan::DIRECT_MAX_NNZ
    );
}

fn main() {
    let smoke = smoke_mode();
    let mut report = JsonReport::with_env("BENCH_substrates.json", "DPFW_BENCH_SUBSTRATES_JSON");
    let scale = if smoke { 0.02 } else { 0.25 };
    let runs = if smoke { 1 } else { 10 };
    let ds = SynthConfig::preset(DatasetPreset::Rcv1).scale(scale).generate(5);
    println!(
        "workload: rcv1@{scale}  N={} D={} nnz={}",
        ds.n_rows(),
        ds.n_cols(),
        ds.nnz()
    );

    section("sparse kernels");
    let w = vec![0.01f64; ds.n_cols()];
    let mut v = vec![0.0f64; ds.n_rows()];
    Bench::new("csr matvec (v = Xw)").runs(runs).run(|| {
        ds.csr.matvec(&w, &mut v);
        v[0]
    });
    let q = vec![0.1f64; ds.n_rows()];
    let mut alpha = vec![0.0f64; ds.n_cols()];
    Bench::new("csr matvec_t_add (alpha += X^T q)").runs(runs).run(|| {
        alpha.iter_mut().for_each(|a| *a = 0.0);
        ds.csr.matvec_t_add(&q, &mut alpha);
        alpha[0]
    });
    Bench::new("csc full column sweep (S_r loop x D)").runs(runs).run(|| {
        let mut acc = 0.0f64;
        for j in 0..ds.n_cols() {
            for (_, x) in ds.csc.col(j) {
                acc += x as f64;
            }
        }
        acc
    });
    Bench::new("row_dot over all rows").runs(runs).run(|| {
        let mut acc = 0.0;
        for i in 0..ds.n_rows() {
            acc += ds.csr.row_dot(i, &w);
        }
        acc
    });

    section("compact u16-delta substrate vs u32 (same kernels)");
    let mut plain = ds.clone();
    plain.strip_compact();
    println!(
        "index bytes: {} ({}) vs {} (u32) — {:.1}%",
        ds.csr.index_bytes_total(),
        ds.index_kind(),
        plain.csr.index_bytes_total(),
        100.0 * ds.csr.index_bytes_total() as f64 / plain.csr.index_bytes_total().max(1) as f64
    );
    let s = Bench::new("csr matvec (u16-delta)").runs(runs).run_stats(|| {
        ds.csr.matvec(&w, &mut v);
        v[0]
    });
    report.record("matvec-u16-delta", s, &[("kernel", "matvec".into()), ("arm", "dispatch".into())]);
    let s = Bench::new("csr matvec (u32)").runs(runs).run_stats(|| {
        plain.csr.matvec(&w, &mut v);
        v[0]
    });
    report.record("matvec-u32", s, &[("kernel", "matvec".into()), ("arm", "u32".into())]);
    let s = Bench::new("csc matvec_t (u16-delta)").runs(runs).run_stats(|| {
        ds.csc.matvec_t(&q, &mut alpha);
        alpha[0]
    });
    report.record(
        "matvec-t-u16-delta",
        s,
        &[("kernel", "matvec_t".into()), ("arm", "dispatch".into())],
    );
    let s = Bench::new("csc matvec_t (u32)").runs(runs).run_stats(|| {
        plain.csc.matvec_t(&q, &mut alpha);
        alpha[0]
    });
    report.record("matvec-t-u32", s, &[("kernel", "matvec_t".into()), ("arm", "u32".into())]);

    kernel_tier_series(&mut report, smoke);

    section("construction");
    let c_runs = if smoke { 1 } else { 5 };
    Bench::new("csc from_csr (counting sort)")
        .runs(c_runs)
        .run(|| CscMatrix::from_csr(&ds.csr).nnz());
    let g_scale = if smoke { 0.02 } else { 0.1 };
    Bench::new(format!("synth generate rcv1@{g_scale}")).runs(if smoke { 1 } else { 3 }).run(|| {
        SynthConfig::preset(DatasetPreset::Rcv1).scale(g_scale).generate(9).nnz()
    });

    section("LIBSVM I/O");
    let io_runs = if smoke { 1 } else { 3 };
    let path = std::env::temp_dir().join("dpfw_bench_io.svm");
    Bench::new("write").runs(io_runs).run(|| {
        libsvm::write_file(&ds, &path).unwrap();
        0
    });
    Bench::new("read+index (csr+csc)")
        .runs(io_runs)
        .run(|| libsvm::read_file(&path).unwrap().nnz());
    std::fs::remove_file(&path).ok();

    report.write().expect("write substrates bench json");
}
