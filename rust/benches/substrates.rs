//! Bench: substrate microbenchmarks — sparse matvec / transpose-matvec /
//! column scans (the building blocks whose costs appear in every line of
//! the paper's complexity annotations), CSR↔CSC conversion, LIBSVM parse,
//! and synthetic generation throughput.

mod bench_harness;

use bench_harness::{section, Bench};
use dpfw::sparse::csc::CscMatrix;
use dpfw::sparse::libsvm;
use dpfw::sparse::synth::{DatasetPreset, SynthConfig};

fn main() {
    let ds = SynthConfig::preset(DatasetPreset::Rcv1).scale(0.25).generate(5);
    println!(
        "workload: rcv1@0.25  N={} D={} nnz={}",
        ds.n_rows(),
        ds.n_cols(),
        ds.nnz()
    );

    section("sparse kernels");
    let w = vec![0.01f64; ds.n_cols()];
    let mut v = vec![0.0f64; ds.n_rows()];
    Bench::new("csr matvec (v = Xw)").runs(10).run(|| {
        ds.csr.matvec(&w, &mut v);
        v[0]
    });
    let q = vec![0.1f64; ds.n_rows()];
    let mut alpha = vec![0.0f64; ds.n_cols()];
    Bench::new("csr matvec_t_add (alpha += X^T q)").runs(10).run(|| {
        alpha.iter_mut().for_each(|a| *a = 0.0);
        ds.csr.matvec_t_add(&q, &mut alpha);
        alpha[0]
    });
    Bench::new("csc full column sweep (S_r loop x D)").runs(10).run(|| {
        let mut acc = 0.0f64;
        for j in 0..ds.n_cols() {
            for (_, x) in ds.csc.col(j) {
                acc += x as f64;
            }
        }
        acc
    });
    Bench::new("row_dot over all rows").runs(10).run(|| {
        let mut acc = 0.0;
        for i in 0..ds.n_rows() {
            acc += ds.csr.row_dot(i, &w);
        }
        acc
    });

    section("compact u16-delta substrate vs u32 (same kernels)");
    let mut plain = ds.clone();
    plain.strip_compact();
    println!(
        "index bytes: {} ({}) vs {} (u32) — {:.1}%",
        ds.csr.index_bytes_total(),
        ds.index_kind(),
        plain.csr.index_bytes_total(),
        100.0 * ds.csr.index_bytes_total() as f64 / plain.csr.index_bytes_total().max(1) as f64
    );
    Bench::new("csr matvec (u16-delta)").runs(10).run(|| {
        ds.csr.matvec(&w, &mut v);
        v[0]
    });
    Bench::new("csr matvec (u32)").runs(10).run(|| {
        plain.csr.matvec(&w, &mut v);
        v[0]
    });
    Bench::new("csc matvec_t (u16-delta)").runs(10).run(|| {
        ds.csc.matvec_t(&q, &mut alpha);
        alpha[0]
    });
    Bench::new("csc matvec_t (u32)").runs(10).run(|| {
        plain.csc.matvec_t(&q, &mut alpha);
        alpha[0]
    });

    section("construction");
    Bench::new("csc from_csr (counting sort)").runs(5).run(|| CscMatrix::from_csr(&ds.csr).nnz());
    Bench::new("synth generate rcv1@0.1").runs(3).run(|| {
        SynthConfig::preset(DatasetPreset::Rcv1).scale(0.1).generate(9).nnz()
    });

    section("LIBSVM I/O");
    let path = std::env::temp_dir().join("dpfw_bench_io.svm");
    Bench::new("write").runs(3).run(|| {
        libsvm::write_file(&ds, &path).unwrap();
        0
    });
    Bench::new("read+index (csr+csc)").runs(3).run(|| libsvm::read_file(&path).unwrap().nnz());
    std::fs::remove_file(&path).ok();
}
