//! Bench: **ingress service under a coalescing overload burst** (DESIGN.md
//! §6.10).
//!
//! Fires a same-dataset burst through the long-lived [`Ingress`] — clean
//! DP solves that coalesce their dense bootstrap through the shared
//! [`BootHub`], batch predictions on the open predict class, and an
//! overflow tail past the solve class's hard watermark — with the soft
//! watermark tuned so the brownout controller arms mid-burst. Reports the
//! serving surface: admit/shed/brownout counts, hub lead/attach telemetry
//! (the coalesce rate), per-class queue-inclusive p50/p99 latency, and
//! bytes-per-request. Emits `BENCH_ingress.json` so CI tracks the §6.10
//! story across PRs.
//!
//! Like the other benches, the run doubles as an invariant check: every
//! accepted id must resolve Ok (a browned-out run is a degraded *answer*,
//! not an error), the overflow tail must shed exactly, and the hub must
//! have led the shared bootstrap exactly once per burst.

mod bench_harness;

use std::sync::atomic::Ordering;
use std::sync::Arc;

use bench_harness::{section, smoke_mode, Bench, JsonReport};
use dpfw::coordinator::{
    Admit, Algo, ClassPolicy, Ingress, IngressConfig, JobSpec, PredictJob, Request,
};
use dpfw::dp::accounting::PrivacyParams;
use dpfw::fw::cancel::CancelToken;
use dpfw::fw::config::{FwConfig, SelectorKind};
use dpfw::sparse::synth::{DatasetPreset, SynthConfig};
use dpfw::sparse::Dataset;
use dpfw::testkit::faults::FaultPlan;

struct BurstShape {
    /// Same-dataset DP solves (the coalescing population).
    solves: usize,
    /// Batch predictions on the open predict class.
    predicts: usize,
    /// Solves submitted past the hard watermark — must all shed.
    overflow: usize,
    iters: usize,
}

/// One ingress burst: admit everything, drain, reconcile the admission
/// ledger. Returns the ingress so the caller can read the metrics and hub
/// surface after timing.
fn run_burst(ds: &Arc<Dataset>, workers: usize, shape: &BurstShape) -> Ingress {
    let mut ing = Ingress::new(IngressConfig {
        workers,
        solve: ClassPolicy {
            queue_hard: shape.solves,
            // arm brownout once the queue is half full: the back half of
            // the burst runs degraded — still answered, cheaper
            queue_soft: shape.solves / 2,
            ..Default::default()
        },
        brownout_after: 2,
        ..Default::default()
    });
    let cfg = |seed: u64| FwConfig {
        iters: shape.iters,
        lambda: 8.0,
        privacy: Some(PrivacyParams::new(1.0, 1e-6)),
        selector: SelectorKind::Bsls,
        seed,
        ..Default::default()
    };
    let mut owed = 0usize;
    let mut browned = 0usize;
    for k in 0..shape.solves + shape.overflow {
        let admit = ing.submit(Request::Solve(JobSpec {
            id: 0,
            label: format!("s{k}"),
            data: ds.clone(),
            algo: Algo::Fast,
            cfg: cfg(k as u64),
            test_data: None,
        }));
        match admit {
            Admit::Accepted { ids, browned_out } => {
                owed += ids.len();
                browned += browned_out as usize;
            }
            Admit::Shed(_) => assert!(k >= shape.solves, "shed inside the watermark"),
            Admit::Redirected { .. } => panic!("no rate limit configured"),
        }
    }
    assert_eq!(owed, shape.solves, "overflow tail must shed exactly");
    assert!(browned > 0, "the soft watermark must arm brownout mid-burst");
    let w = Arc::new(vec![0.01; ds.csr.n_cols()]);
    for k in 0..shape.predicts {
        let admit = ing.submit(Request::Predict(PredictJob {
            id: 0,
            label: format!("p{k}"),
            data: ds.clone(),
            weights: w.clone(),
            threads: 0,
            cancel: CancelToken::none(),
            fault: FaultPlan::none(),
        }));
        assert!(admit.is_accepted(), "predict class is open");
        owed += 1;
    }

    let out = ing.drain();
    assert_eq!(out.len(), owed, "every accepted id must resolve");
    assert!(out.iter().all(|(_, o)| o.is_ok()), "burst has no failing jobs");
    assert_eq!(ing.hub().leads(), 1, "one shared bootstrap per burst");
    ing
}

fn main() {
    let smoke = smoke_mode();
    let scale = if smoke { 0.01 } else { 0.05 };
    let runs = if smoke { 2 } else { 5 };
    let shape = BurstShape {
        solves: if smoke { 8 } else { 24 },
        predicts: if smoke { 4 } else { 12 },
        overflow: if smoke { 3 } else { 8 },
        iters: if smoke { 40 } else { 150 },
    };
    let ds = Arc::new(
        SynthConfig::preset(DatasetPreset::News20).scale(scale).generate(42),
    );
    println!(
        "ingress burst: News20-synth scale={scale} (N={}, D={}, nnz={})",
        ds.n_rows(),
        ds.n_cols(),
        ds.nnz()
    );

    let mut report = JsonReport::with_env("BENCH_ingress.json", "DPFW_BENCH_INGRESS_JSON");
    for workers in [1usize, 4] {
        section(&format!(
            "ingress burst: {} solves (+{} overflow) + {} predicts, {} workers",
            shape.solves, shape.overflow, shape.predicts, workers
        ));
        let stats = Bench::new(format!("ingress-{workers}w"))
            .warmup(1)
            .runs(runs)
            .run_stats(|| run_burst(&ds, workers, &shape));
        // metrics from a fresh, untimed burst (the timed ones are dropped)
        let ing = run_burst(&ds, workers, &shape);
        let m = ing.metrics();
        let hub = ing.hub();
        println!(
            "  {} | solve p50/p99 {}/{} µs, predict p50/p99 {}/{} µs, \
             hub leads/attaches {}/{}",
            m.summary(),
            m.cell_latency.p50_us(),
            m.cell_latency.p99_us(),
            m.predict_latency.p50_us(),
            m.predict_latency.p99_us(),
            hub.leads(),
            hub.attaches(),
        );
        report.record(
            &format!("ingress-burst-{workers}w"),
            stats,
            &[
                ("workers", workers.to_string()),
                ("admits", m.admits.load(Ordering::Relaxed).to_string()),
                ("sheds", m.admission_sheds.load(Ordering::Relaxed).to_string()),
                ("redirects", m.redirects.load(Ordering::Relaxed).to_string()),
                ("brownout_jobs", m.brownout_jobs.load(Ordering::Relaxed).to_string()),
                ("hub_leads", hub.leads().to_string()),
                ("hub_attaches", hub.attaches().to_string()),
                ("solve_p50_us", m.cell_latency.p50_us().to_string()),
                ("solve_p99_us", m.cell_latency.p99_us().to_string()),
                ("predict_p50_us", m.predict_latency.p50_us().to_string()),
                ("predict_p99_us", m.predict_latency.p99_us().to_string()),
                ("bytes_per_request", m.bytes_per_request().to_string()),
            ],
        );
    }
    report.write().expect("failed to write ingress JSON");
}
