//! Bench: **row-shard sweep** (DESIGN.md §6.8) — both solvers on the
//! News20-synth preset at P ∈ {1, 2, 4, 8} shards, cold (fresh workspace,
//! shard build included) and warm (pooled workspace, cached `ShardedDataset`
//! and bootstrap). Emits `BENCH_shard_sweep.json` with per-iteration wall
//! time so CI tracks the scaling curve across PRs.
//!
//! The sweep doubles as a determinism check: before timing, every P's
//! output is compared against the P=1 run — weights bit-for-bit, FLOPs and
//! modeled bytes exactly equal (the §6.8 contract: sharding changes who
//! computes, never what). A violation aborts the bench, so the CI smoke
//! run enforces the invariant on every push.

mod bench_harness;

use bench_harness::{section, smoke_mode, Bench, JsonReport};
use dpfw::fw::config::FwConfig;
use dpfw::fw::fast::FastFrankWolfe;
use dpfw::fw::standard::StandardFrankWolfe;
use dpfw::fw::trace::FwOutput;
use dpfw::fw::workspace::FwWorkspace;
use dpfw::sparse::synth::{DatasetPreset, SynthConfig};
use dpfw::sparse::Dataset;

const SHARD_COUNTS: [usize; 4] = [1, 2, 4, 8];

fn assert_matches_p1(p1: &FwOutput, out: &FwOutput, what: &str) {
    for (i, (a, b)) in
        p1.weights.as_slice().iter().zip(out.weights.as_slice()).enumerate()
    {
        assert_eq!(a.to_bits(), b.to_bits(), "{what}: weight {i} diverged: {a} vs {b}");
    }
    assert_eq!(p1.flops, out.flops, "{what}: FLOP model must be P-invariant");
    assert_eq!(p1.bytes_moved, out.bytes_moved, "{what}: byte model must be P-invariant");
    assert_eq!(
        p1.final_gap.to_bits(),
        out.final_gap.to_bits(),
        "{what}: final gap diverged"
    );
}

fn sweep_solver(
    report: &mut JsonReport,
    ds: &Dataset,
    solver: &str,
    iters: usize,
    runs: usize,
) {
    section(&format!("{solver}: shard sweep (T={iters})"));
    let run_once = |p: usize, ws: &mut FwWorkspace| -> FwOutput {
        let cfg = FwConfig {
            iters,
            lambda: 30.0,
            shards: Some(p),
            ..Default::default()
        };
        match solver {
            "standard" => StandardFrankWolfe::new(ds, cfg).run_in(ws),
            _ => FastFrankWolfe::new(ds, cfg).run_in(ws),
        }
    };
    // determinism gate first: every P must reproduce the P=1 bits/counts
    let p1 = run_once(1, &mut FwWorkspace::new());
    for &p in &SHARD_COUNTS[1..] {
        let out = run_once(p, &mut FwWorkspace::new());
        assert_matches_p1(&p1, &out, &format!("{solver} p={p}"));
    }
    println!("  P-invariance verified: flops={} bytes={}", p1.flops, p1.bytes_moved);

    for &p in &SHARD_COUNTS {
        // cold: fresh workspace per run — pays the shard build + bootstrap
        let cold = Bench::new(format!("{solver}-cold-p{p}"))
            .warmup(1)
            .runs(runs)
            .run_stats(|| run_once(p, &mut FwWorkspace::new()));
        // warm: pooled workspace — cached ShardedDataset, pooled buffers
        let mut ws = FwWorkspace::new();
        run_once(p, &mut ws); // populate the caches outside the timer
        let warm = Bench::new(format!("{solver}-warm-p{p}"))
            .warmup(1)
            .runs(runs)
            .run_stats(|| run_once(p, &mut ws));
        let probe = run_once(p, &mut ws);
        for (stats, phase) in [(cold, "cold"), (warm, "warm")] {
            report.record(
                &format!("shard-sweep-{solver}-{phase}-p{p}"),
                stats,
                &[
                    ("solver", solver.to_string()),
                    ("phase", phase.to_string()),
                    ("shards_requested", p.to_string()),
                    ("shards_effective", probe.effective_shards.to_string()),
                    ("threads_effective", probe.effective_threads.to_string()),
                    ("iters", iters.to_string()),
                    (
                        "per_iter_ns",
                        format!("{:.1}", stats.mean_s * 1e9 / iters.max(1) as f64),
                    ),
                    ("flops", probe.flops.to_string()),
                    ("bytes_moved", probe.bytes_moved.to_string()),
                ],
            );
        }
    }
}

fn main() {
    let smoke = smoke_mode();
    // News20-synth: the paper's wide-and-sparse shape. Smoke shrinks the
    // scale so CI exercises the sweep + JSON emitter in seconds.
    let scale = if smoke { 0.02 } else { 0.3 };
    let iters = if smoke { 8 } else { 60 };
    let runs = if smoke { 2 } else { 5 };
    let ds = SynthConfig::preset(DatasetPreset::News20).scale(scale).generate(42);
    println!(
        "shard sweep: News20-synth scale={scale} (N={}, D={}, nnz={}), P={SHARD_COUNTS:?}",
        ds.n_rows(),
        ds.n_cols(),
        ds.nnz()
    );

    let mut report = JsonReport::with_env("BENCH_shard_sweep.json", "DPFW_BENCH_SHARD_JSON");
    sweep_solver(&mut report, &ds, "standard", iters, runs);
    sweep_solver(&mut report, &ds, "fast", iters, runs);
    report.write().expect("failed to write shard-sweep JSON");
}
