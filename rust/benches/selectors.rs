//! Bench: selection-structure microbenchmarks — the per-operation costs
//! behind the paper's complexity table: Fibonacci vs binary heap
//! (push/pop/decrease-key), BSLS vs naive exponential sampling (draw and
//! update), and report-noisy-max scans, as D grows. This is the
//! substrate-level evidence for Fig 2's "heap is algorithmically better
//! but constant-factor worse" and Alg 4's O(√D) draw.
//!
//! Results are persisted to `BENCH_selectors.json` at the repo root
//! (override/disable via `DPFW_BENCH_SELECTORS_JSON`), so the selector
//! substrate has the same cross-PR perf series as the solver benches.
//! `DPFW_BENCH_SMOKE=1` shrinks the D grids and draw counts to CI-smoke
//! size (the JSON emitter still runs end-to-end).

mod bench_harness;

use bench_harness::{section, smoke_mode, Bench, JsonReport};
use dpfw::heap::binary::IndexedBinaryHeap;
use dpfw::heap::fibonacci::FibonacciHeap;
use dpfw::heap::DecreaseKeyHeap;
use dpfw::rng::Xoshiro256pp;
use dpfw::sampler::bsls::BslsSampler;
use dpfw::sampler::naive::NaiveExpSampler;
use dpfw::sampler::{noisy_max, WeightedSampler};

fn bench_heap<H: DecreaseKeyHeap>(
    mut h: H,
    n: usize,
    label: &str,
    slug: &str,
    runs: usize,
    report: &mut JsonReport,
) {
    let mut rng = Xoshiro256pp::seeded(1);
    let stats = Bench::new(format!("{label} D={n}: build+churn+drain")).runs(runs).run_stats(|| {
        for j in 0..n {
            h.push(j, rng.next_f64());
        }
        // churn: decrease-keys (the Alg 3 notify pattern)
        for _ in 0..n {
            let j = rng.next_below(n as u64) as usize;
            if let Some(k) = h.key_of(j) {
                h.decrease_key(j, k - rng.next_f64());
            }
        }
        let mut acc = 0.0;
        while let Some((_, k)) = h.pop_min() {
            acc += k;
        }
        acc
    });
    report.record(
        &format!("heap-{slug}-d{n}"),
        stats,
        &[("structure", slug.to_string()), ("d", n.to_string())],
    );
}

fn main() {
    let smoke = smoke_mode();
    let mut report = JsonReport::with_env("BENCH_selectors.json", "DPFW_BENCH_SELECTORS_JSON");
    let runs = if smoke { 1 } else { 3 };

    section("heaps (Alg 3 substrate)");
    let heap_grid: &[usize] = if smoke { &[10_000] } else { &[10_000, 100_000] };
    for &n in heap_grid {
        bench_heap(FibonacciHeap::with_capacity(n), n, "fibonacci", "fib", runs, &mut report);
        bench_heap(IndexedBinaryHeap::with_capacity(n), n, "binary   ", "bin", runs, &mut report);
    }

    section("exponential-mechanism draws (Alg 4 vs naive)");
    let draw_grid: &[usize] = if smoke { &[10_000] } else { &[10_000, 100_000, 1_000_000] };
    for &d in draw_grid {
        let mut bsls = BslsSampler::new(d, 0.0);
        let mut naive = NaiveExpSampler::new(d, 0.0);
        for j in (0..d).step_by((d / 64).max(1)) {
            bsls.update(j, (j % 9) as f64);
            naive.update(j, (j % 9) as f64);
        }
        let bsls_draws = if smoke { 10 } else { 100 };
        let mut rng = Xoshiro256pp::seeded(2);
        let stats =
            Bench::new(format!("bsls  D={d}: {bsls_draws} draws")).runs(runs.max(3)).run_stats(
                || {
                    let mut acc = 0usize;
                    for _ in 0..bsls_draws {
                        acc ^= bsls.sample(&mut rng);
                    }
                    acc
                },
            );
        report.record(
            &format!("bsls-draw-d{d}"),
            stats,
            &[("sampler", "bsls".into()), ("d", d.to_string()), ("draws", bsls_draws.to_string())],
        );
        let draws = if smoke || d > 100_000 { 3 } else { 100 };
        let mut rng = Xoshiro256pp::seeded(2);
        let stats = Bench::new(format!("naive D={d}: {draws} draws")).runs(runs).run_stats(|| {
            let mut acc = 0usize;
            for _ in 0..draws {
                acc ^= naive.sample(&mut rng);
            }
            acc
        });
        report.record(
            &format!("naive-draw-d{d}"),
            stats,
            &[("sampler", "naive".into()), ("d", d.to_string()), ("draws", draws.to_string())],
        );
    }

    section("sampler updates (Alg 2 line 29 notify path)");
    let upd_grid: &[usize] = if smoke { &[100_000] } else { &[100_000, 1_000_000] };
    let updates = if smoke { 1_000 } else { 10_000 };
    for &d in upd_grid {
        let mut bsls = BslsSampler::new(d, 0.0);
        let mut rng = Xoshiro256pp::seeded(3);
        let stats =
            Bench::new(format!("bsls D={d}: {updates} updates")).runs(runs.max(5)).run_stats(|| {
                for _ in 0..updates {
                    let j = rng.next_below(d as u64) as usize;
                    bsls.update(j, rng.next_f64() * 8.0);
                }
                bsls.log_total()
            });
        report.record(
            &format!("bsls-update-d{d}"),
            stats,
            &[("sampler", "bsls".into()), ("d", d.to_string()), ("updates", updates.to_string())],
        );
    }

    section("report-noisy-max scan (Alg 1 DP selection)");
    let nm_grid: &[usize] = if smoke { &[10_000] } else { &[10_000, 100_000, 1_000_000] };
    let selections = if smoke { 3 } else { 10 };
    for &d in nm_grid {
        let alpha: Vec<f64> = (0..d).map(|j| ((j * 31) % 17) as f64).collect();
        let mut rng = Xoshiro256pp::seeded(4);
        let stats = Bench::new(format!("noisy-max D={d}: {selections} selections"))
            .runs(runs)
            .run_stats(|| {
                let mut acc = 0usize;
                for _ in 0..selections {
                    acc ^= noisy_max::noisy_max(&alpha, 1.0, &mut rng).0;
                }
                acc
            });
        report.record(
            &format!("noisymax-d{d}"),
            stats,
            &[
                ("selector", "noisymax".into()),
                ("d", d.to_string()),
                ("selections", selections.to_string()),
            ],
        );
    }

    report.write().expect("write selectors bench json");
}
