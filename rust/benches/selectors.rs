//! Bench: selection-structure microbenchmarks — the per-operation costs
//! behind the paper's complexity table: Fibonacci vs binary heap
//! (push/pop/decrease-key), BSLS vs naive exponential sampling (draw and
//! update), and report-noisy-max scans, as D grows. This is the
//! substrate-level evidence for Fig 2's "heap is algorithmically better
//! but constant-factor worse" and Alg 4's O(√D) draw.

mod bench_harness;

use bench_harness::{section, Bench};
use dpfw::heap::binary::IndexedBinaryHeap;
use dpfw::heap::fibonacci::FibonacciHeap;
use dpfw::heap::DecreaseKeyHeap;
use dpfw::rng::Xoshiro256pp;
use dpfw::sampler::bsls::BslsSampler;
use dpfw::sampler::naive::NaiveExpSampler;
use dpfw::sampler::{noisy_max, WeightedSampler};

fn bench_heap<H: DecreaseKeyHeap>(mut h: H, n: usize, label: &str) {
    let mut rng = Xoshiro256pp::seeded(1);
    Bench::new(format!("{label} D={n}: build+churn+drain")).runs(3).run(|| {
        for j in 0..n {
            h.push(j, rng.next_f64());
        }
        // churn: decrease-keys (the Alg 3 notify pattern)
        for _ in 0..n {
            let j = rng.next_below(n as u64) as usize;
            if let Some(k) = h.key_of(j) {
                h.decrease_key(j, k - rng.next_f64());
            }
        }
        let mut acc = 0.0;
        while let Some((_, k)) = h.pop_min() {
            acc += k;
        }
        acc
    });
}

fn main() {
    section("heaps (Alg 3 substrate)");
    for n in [10_000usize, 100_000] {
        bench_heap(FibonacciHeap::with_capacity(n), n, "fibonacci");
        bench_heap(IndexedBinaryHeap::with_capacity(n), n, "binary   ");
    }

    section("exponential-mechanism draws (Alg 4 vs naive)");
    for d in [10_000usize, 100_000, 1_000_000] {
        let mut bsls = BslsSampler::new(d, 0.0);
        let mut naive = NaiveExpSampler::new(d, 0.0);
        for j in (0..d).step_by((d / 64).max(1)) {
            bsls.update(j, (j % 9) as f64);
            naive.update(j, (j % 9) as f64);
        }
        let mut rng = Xoshiro256pp::seeded(2);
        Bench::new(format!("bsls  D={d}: 100 draws")).runs(5).run(|| {
            let mut acc = 0usize;
            for _ in 0..100 {
                acc ^= bsls.sample(&mut rng);
            }
            acc
        });
        let draws = if d > 100_000 { 3 } else { 100 };
        let mut rng = Xoshiro256pp::seeded(2);
        let t = Bench::new(format!("naive D={d}: {draws} draws")).runs(3).run(|| {
            let mut acc = 0usize;
            for _ in 0..draws {
                acc ^= naive.sample(&mut rng);
            }
            acc
        });
        let _ = t;
    }

    section("sampler updates (Alg 2 line 29 notify path)");
    for d in [100_000usize, 1_000_000] {
        let mut bsls = BslsSampler::new(d, 0.0);
        let mut rng = Xoshiro256pp::seeded(3);
        Bench::new(format!("bsls D={d}: 10k updates")).runs(5).run(|| {
            for _ in 0..10_000 {
                let j = rng.next_below(d as u64) as usize;
                bsls.update(j, rng.next_f64() * 8.0);
            }
            bsls.log_total()
        });
    }

    section("report-noisy-max scan (Alg 1 DP selection)");
    for d in [10_000usize, 100_000, 1_000_000] {
        let alpha: Vec<f64> = (0..d).map(|j| ((j * 31) % 17) as f64).collect();
        let mut rng = Xoshiro256pp::seeded(4);
        Bench::new(format!("noisy-max D={d}: 10 selections")).runs(3).run(|| {
            let mut acc = 0usize;
            for _ in 0..10 {
                acc ^= noisy_max::noisy_max(&alpha, 1.0, &mut rng).0;
            }
            acc
        });
    }
}
