//! Bench: **the §6.11/§6.12 durability plane's overhead and recovery
//! latency**.
//!
//! Five measurements carry the story:
//!
//! 1. **Ledger append throughput by fsync policy** — the write-ahead ε
//!    ledger sits on the solver's release path, so the
//!    `Always`/`EveryN`/`Never` sweep is the latency-vs-loss-window trade
//!    (DESIGN.md §6.11) in numbers.
//! 2. **Checkpoint write/read cost vs iterate size** — snapshots are O(t)
//!    in the completed iteration count (the LASSO-ball sparsity bound),
//!    so the cost should scale with t, not with the feature count D.
//! 3. **Crash-recovery latency** — resume-from-checkpoint (replay the
//!    recorded prefix, then finish) vs the uninterrupted run, on a real
//!    DP solve. The gap between the two is what a crash actually costs.
//! 4. **Compaction latency vs log size** — the §6.12 periodic rewrite
//!    (one max-merged frame per request id) must stay cheap enough to run
//!    on a live pool; the series pins its cost per frame.
//! 5. **Recovery-scan time vs orphan count** — the restart-time
//!    `RecoveryManager::scan` walks, decodes, and WAL-cross-checks every
//!    orphan a dead process left; its cost sets how fast a service comes
//!    back.
//!
//! Like the other benches, the run doubles as an invariant check: the
//! resumed output must be bit-identical to the uninterrupted run's, and
//! every frame written must survive a reopen.

mod bench_harness;

use std::sync::Arc;

use bench_harness::{section, smoke_mode, Bench, JsonReport};
use dpfw::coordinator::{Algo, JobSpec, RecoveryManager};
use dpfw::dp::accounting::PrivacyParams;
use dpfw::dp::ledger::{EpsLedger, FsyncPolicy, LedgerRecord};
use dpfw::fw::cancel::StopReason;
use dpfw::fw::checkpoint::{FwCheckpoint, RunDurability};
use dpfw::fw::config::{FwConfig, SelectorKind};
use dpfw::fw::queue::SelectorStats;
use dpfw::fw::trace::TraceRecord;
use dpfw::sparse::synth::{DatasetPreset, SynthConfig};
use dpfw::testkit::io_faults::IoFaultPlane;

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("dpfw-bench-durab-{}-{name}", std::process::id()))
}

/// A synthetic snapshot shaped like a run after `t` iterations: t history
/// entries, ≤ t distinct weights, a full per-iteration trace.
fn synthetic_ckpt(t: usize) -> FwCheckpoint {
    let history: Vec<(u32, i8)> =
        (0..t).map(|i| ((i % 997) as u32, if i % 2 == 0 { 1 } else { -1 })).collect();
    let weights = FwCheckpoint::sparse_weights(&history, |j| j as f64 * 1e-3);
    let trace: Vec<TraceRecord> = (1..=t)
        .map(|i| TraceRecord {
            iter: i,
            gap: 1.0 / i as f64,
            flops: (i * 100) as u64,
            bytes: (i * 800) as u64,
            pops: i as u64,
            selected: i % 997,
            wall_ns: i as u128 * 1_000,
        })
        .collect();
    FwCheckpoint {
        fingerprint: 0x5EED,
        dataset_fp: 1,
        seed: 7,
        t_planned: (t * 2) as u64,
        iter: t as u64,
        rng: [1, 2, 3, 4],
        flops: [1, 2, 3, 4, 5, 6, 7],
        stats: SelectorStats {
            selects: t as u64,
            pops: t as u64,
            reinserts: 0,
            big_steps: 0,
            little_steps: 0,
        },
        gap: 0.5,
        history,
        weights,
        trace,
    }
}

fn main() {
    let smoke = smoke_mode();
    let runs = if smoke { 2 } else { 5 };
    let mut report =
        JsonReport::with_env("BENCH_durability.json", "DPFW_BENCH_DURABILITY_JSON");

    // ---- 1. ledger append throughput by fsync policy -------------------
    let appends = if smoke { 100usize } else { 500 };
    section(&format!("ε-ledger appends ({appends} frames per run)"));
    for (name, policy) in [
        ("always", FsyncPolicy::Always),
        ("every8", FsyncPolicy::EveryN(8)),
        ("never", FsyncPolicy::Never),
    ] {
        let path = tmp(&format!("wal-{name}"));
        let stats = Bench::new(format!("ledger-append-fsync-{name}"))
            .warmup(1)
            .runs(runs)
            .run_stats(|| {
                let _ = std::fs::remove_file(&path);
                let l = EpsLedger::open(&path, policy).unwrap();
                for k in 0..appends {
                    l.append(LedgerRecord {
                        request: k as u64,
                        token: 1,
                        planned: 4000,
                        released: 100,
                        eps: 0.01,
                    })
                    .unwrap();
                }
                l.frames()
            });
        // recovery scan: reopen the populated log (replay + torn-tail scan)
        let l = EpsLedger::open(&path, policy).unwrap();
        assert_eq!(l.frames(), appends as u64, "every frame must survive reopen");
        drop(l);
        let open_stats = Bench::new(format!("ledger-reopen-{name}"))
            .warmup(1)
            .runs(runs)
            .run_stats(|| EpsLedger::open(&path, policy).unwrap().frames());
        let per_append_us = stats.mean_s * 1e6 / appends as f64;
        println!("  {name}: {per_append_us:.2} µs/append");
        report.record(
            &format!("ledger-append-{name}"),
            stats,
            &[
                ("appends", appends.to_string()),
                ("per_append_us", format!("{per_append_us:.3}")),
                ("reopen_mean_s", format!("{:.6}", open_stats.mean_s)),
            ],
        );
        let _ = std::fs::remove_file(&path);
    }

    // ---- 2. checkpoint write/read cost vs iterate size ------------------
    section("checkpoint write/read vs completed iterations t (O(t) frames)");
    let sizes: &[usize] = if smoke { &[100, 1000] } else { &[100, 1000, 10000] };
    for &t in sizes {
        let ck = synthetic_ckpt(t);
        let path = tmp(&format!("ckpt-{t}"));
        let w = Bench::new(format!("ckpt-write-t{t}"))
            .warmup(1)
            .runs(runs)
            .run_stats(|| ck.write_to(&path).unwrap());
        let r = Bench::new(format!("ckpt-read-t{t}"))
            .warmup(1)
            .runs(runs)
            .run_stats(|| FwCheckpoint::read_from(&path).unwrap().iter);
        assert_eq!(FwCheckpoint::read_from(&path).unwrap(), ck, "lossless round trip");
        let bytes = std::fs::metadata(&path).unwrap().len();
        report.record(
            &format!("ckpt-write-t{t}"),
            w,
            &[("t", t.to_string()), ("frame_bytes", bytes.to_string())],
        );
        report.record(&format!("ckpt-read-t{t}"), r, &[("t", t.to_string())]);
        let _ = std::fs::remove_file(&path);
    }

    // ---- 3. crash-recovery latency on a real DP solve -------------------
    let scale = if smoke { 0.01 } else { 0.05 };
    let iters = if smoke { 60 } else { 300 };
    let cut_at = iters / 2;
    let ds = Arc::new(
        SynthConfig::preset(DatasetPreset::News20).scale(scale).generate(42),
    );
    section(&format!(
        "crash recovery: resume at t={cut_at} vs uninterrupted (T={iters}, N={}, D={})",
        ds.n_rows(),
        ds.n_cols()
    ));
    let cfg = FwConfig {
        iters,
        lambda: 8.0,
        privacy: Some(PrivacyParams::new(1.0, 1e-6)),
        selector: SelectorKind::Bsls,
        seed: 7,
        ..Default::default()
    };
    let job = |cfg: FwConfig| JobSpec {
        id: 0,
        label: "durab".into(),
        data: ds.clone(),
        algo: Algo::Fast,
        cfg,
        test_data: None,
    };
    // produce the mid-run snapshot once (brownout at the cut point)
    let ck_path = tmp("resume-ckpt");
    let mut capped = cfg.clone();
    capped.iter_cap = Some(cut_at);
    capped.durability = Some(Arc::new(RunDurability {
        request_id: 1,
        path: ck_path.clone(),
        ledger: None,
        every_k: 0,
        io: IoFaultPlane::none(),
    }));
    let cut = job(capped).run();
    assert_eq!(cut.output.stopped, StopReason::Brownout);
    let ck = Arc::new(FwCheckpoint::read_from(&ck_path).unwrap());

    let full_stats = Bench::new("solve-uninterrupted")
        .warmup(1)
        .runs(runs)
        .run_stats(|| job(cfg.clone()).run().output.flops);
    let mut resume_cfg = cfg.clone();
    resume_cfg.resume = Some(ck.clone());
    let resume_stats = Bench::new(format!("solve-resume-from-t{cut_at}"))
        .warmup(1)
        .runs(runs)
        .run_stats(|| job(resume_cfg.clone()).run().output.flops);
    // the invariant the whole plane exists for: same bits either way
    let full = job(cfg.clone()).run();
    let resumed = job(resume_cfg.clone()).run();
    assert_eq!(resumed.output.weights, full.output.weights, "resume diverged");
    assert_eq!(resumed.output.eps_spent, full.output.eps_spent);
    report.record(
        "solve-uninterrupted",
        full_stats,
        &[("iters", iters.to_string())],
    );
    report.record(
        "solve-resume",
        resume_stats,
        &[
            ("iters", iters.to_string()),
            ("resume_from", cut_at.to_string()),
            (
                "recovery_ratio",
                format!("{:.3}", resume_stats.mean_s / full_stats.mean_s.max(1e-12)),
            ),
        ],
    );
    let _ = std::fs::remove_file(&ck_path);

    // ---- 4. compaction latency vs log size ------------------------------
    // Cadence replays inflate the log to `cadence` frames per request;
    // compaction rewrites it as one frame per request. Each timed run
    // restores the inflated log (byte copy + reopen) and compacts it, so
    // the `restore_mean_s` note (the same restore without the compact) is
    // the baseline to subtract for the net rewrite cost.
    let cadence = 20usize;
    let req_counts: &[usize] = if smoke { &[50] } else { &[50, 500] };
    section(&format!("ledger compaction ({cadence} cadence frames per request)"));
    for &reqs in req_counts {
        let path = tmp(&format!("compact-{reqs}"));
        {
            let _ = std::fs::remove_file(&path);
            let l = EpsLedger::open(&path, FsyncPolicy::Never).unwrap();
            for r in 0..reqs {
                for step in 1..=cadence {
                    l.append(LedgerRecord {
                        request: r as u64,
                        token: 1,
                        planned: 4000,
                        released: (step * 10) as u32,
                        eps: step as f64 * 1e-3,
                    })
                    .unwrap();
                }
            }
            l.sync().unwrap();
        }
        let inflated = std::fs::read(&path).unwrap();
        let restore = Bench::new(format!("ledger-restore-r{reqs}"))
            .warmup(1)
            .runs(runs)
            .run_stats(|| {
                std::fs::write(&path, &inflated).unwrap();
                EpsLedger::open(&path, FsyncPolicy::Never).unwrap().frames()
            });
        let stats = Bench::new(format!("ledger-compact-r{reqs}"))
            .warmup(1)
            .runs(runs)
            .run_stats(|| {
                std::fs::write(&path, &inflated).unwrap();
                let l = EpsLedger::open(&path, FsyncPolicy::Never).unwrap();
                let s = l.compact().unwrap();
                assert_eq!(s.frames_after, reqs as u64, "one frame per request");
                s.bytes_reclaimed
            });
        let frames = reqs * cadence;
        let net_s = (stats.mean_s - restore.mean_s).max(0.0);
        println!(
            "  {reqs} requests ({frames} frames): {:.2} ms net compact",
            net_s * 1e3
        );
        report.record(
            &format!("ledger-compact-r{reqs}"),
            stats,
            &[
                ("requests", reqs.to_string()),
                ("frames_before", frames.to_string()),
                ("restore_mean_s", format!("{:.6}", restore.mean_s)),
                ("net_compact_s", format!("{net_s:.6}")),
            ],
        );
        let _ = std::fs::remove_file(&path);
    }

    // ---- 5. recovery-scan time vs orphan count --------------------------
    // A dead process's durability dir: K resumable orphans (decodable
    // snapshots whose dataset fingerprint matches the WAL) plus the WAL
    // itself. scan() decodes and cross-checks every one; with nothing to
    // quarantine the pass is idempotent, so one dir serves all runs.
    let orphan_counts: &[usize] = if smoke { &[10, 50] } else { &[10, 100, 1000] };
    section("recovery scan vs orphan count (resumable snapshots, t=100 each)");
    for &orphans in orphan_counts {
        let dir = tmp(&format!("scan-{orphans}"));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let ledger =
            Arc::new(EpsLedger::open(dir.join("eps.wal"), FsyncPolicy::Never).unwrap());
        let ck = synthetic_ckpt(100);
        for r in 0..orphans {
            ck.write_to(dir.join(format!("ckpt-{r}.bin"))).unwrap();
            ledger
                .append(LedgerRecord {
                    request: r as u64,
                    token: ck.dataset_fp,
                    planned: 200,
                    released: 100,
                    eps: 0.01,
                })
                .unwrap();
        }
        let mgr = RecoveryManager::new(&dir, Some(ledger));
        let stats = Bench::new(format!("recovery-scan-o{orphans}"))
            .warmup(1)
            .runs(runs)
            .run_stats(|| {
                let m = mgr.scan().unwrap();
                assert_eq!(m.resumable().count(), orphans, "all orphans resumable");
                assert_eq!(m.quarantined, 0, "nothing to quarantine: scan idempotent");
                m.orphans.len()
            });
        let per_orphan_us = stats.mean_s * 1e6 / orphans as f64;
        println!("  {orphans} orphans: {per_orphan_us:.1} µs/orphan");
        report.record(
            &format!("recovery-scan-o{orphans}"),
            stats,
            &[
                ("orphans", orphans.to_string()),
                ("per_orphan_us", format!("{per_orphan_us:.3}")),
            ],
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    report.write().expect("failed to write durability JSON");
}
