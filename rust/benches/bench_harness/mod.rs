//! Minimal benchmark harness (criterion is not in the offline crate set).
//!
//! Provides warmup + repeated timed runs with mean/min/stddev reporting,
//! in a criterion-like output format. Used by every `harness = false`
//! bench target.
//!
//! ## Machine-readable results
//!
//! [`JsonReport`] optionally persists each benchmark's statistics as a
//! JSON file so the perf trajectory is tracked across PRs (the
//! `BENCH_iteration_cost.json` at the repo root is the canonical
//! instance). The file carries `git describe` output so a result can be
//! tied to the commit that produced it. Destination resolution:
//! `DPFW_BENCH_JSON=<path>` overrides, `DPFW_BENCH_JSON=0` disables, and
//! the default is `<repo root>/<name>` (one directory above the crate's
//! manifest).
#![allow(dead_code)] // each bench uses a subset of the harness

use std::path::PathBuf;
use std::time::Instant;

/// Summary statistics of one benchmark, in seconds.
#[derive(Clone, Copy, Debug)]
pub struct BenchStats {
    pub mean_s: f64,
    pub min_s: f64,
    pub stddev_s: f64,
    pub runs: usize,
}

pub struct Bench {
    name: String,
    warmup: usize,
    runs: usize,
}

impl Bench {
    pub fn new(name: impl Into<String>) -> Self {
        Self { name: name.into(), warmup: 1, runs: 5 }
    }

    pub fn runs(mut self, runs: usize) -> Self {
        self.runs = runs.max(1);
        self
    }

    pub fn warmup(mut self, warmup: usize) -> Self {
        self.warmup = warmup;
        self
    }

    /// Time `f` (which should return something to keep the optimizer
    /// honest); prints stats and returns the mean seconds.
    pub fn run<T>(&self, f: impl FnMut() -> T) -> f64 {
        self.run_stats(f).mean_s
    }

    /// Like [`Bench::run`] but returns the full statistics (for
    /// [`JsonReport::record`]).
    pub fn run_stats<T>(&self, mut f: impl FnMut() -> T) -> BenchStats {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut times = Vec::with_capacity(self.runs);
        for _ in 0..self.runs {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
        let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>()
            / times.len() as f64;
        println!(
            "{:<52} mean {:>10} min {:>10} ±{:>8}",
            self.name,
            fmt_time(mean),
            fmt_time(min),
            fmt_time(var.sqrt())
        );
        BenchStats { mean_s: mean, min_s: min, stddev_s: var.sqrt(), runs: self.runs }
    }

    pub fn name(&self) -> &str {
        &self.name
    }
}

pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// True when `DPFW_BENCH_SMOKE` is set: benches shrink their workloads to
/// seconds so CI can exercise every code path and JSON emitter without
/// paying full measurement cost. Smoke numbers are not comparable to real
/// runs — the emitted JSON exists to prove the emitters still work.
pub fn smoke_mode() -> bool {
    std::env::var_os("DPFW_BENCH_SMOKE").is_some()
}

// ------------------------------------------------------------------------
// JSON persistence
// ------------------------------------------------------------------------

/// Accumulates benchmark entries and writes them as a single JSON document
/// (hand-rolled — serde is not in the offline crate set).
pub struct JsonReport {
    /// `None` = disabled via `DPFW_BENCH_JSON=0`.
    path: Option<PathBuf>,
    entries: Vec<String>,
}

impl JsonReport {
    /// Resolve the destination for a report named e.g.
    /// `"BENCH_iteration_cost.json"` (see module docs) and start an empty
    /// report.
    pub fn new(default_name: &str) -> Self {
        Self::with_env(default_name, "DPFW_BENCH_JSON")
    }

    /// Like [`JsonReport::new`] but resolving the override/disable from a
    /// custom environment variable, so one bench binary can emit several
    /// reports (e.g. `BENCH_iteration_cost.json` *and*
    /// `BENCH_path_sweep.json`) without the overrides colliding.
    pub fn with_env(default_name: &str, env_key: &str) -> Self {
        let path = match std::env::var(env_key) {
            Ok(v) if v == "0" => None,
            Ok(v) => Some(PathBuf::from(v)),
            Err(_) => {
                // <crate>/.. is the repo root in this workspace layout
                let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..");
                Some(root.join(default_name))
            }
        };
        Self { path, entries: Vec::new() }
    }

    /// Record one benchmark's statistics plus free-form key/value context
    /// (dataset preset, selector, D, ...). Values are stored as strings.
    pub fn record(&mut self, name: &str, stats: BenchStats, extra: &[(&str, String)]) {
        let mut fields = vec![
            format!("\"name\": {}", json_string(name)),
            format!("\"mean_ns\": {:.1}", stats.mean_s * 1e9),
            format!("\"min_ns\": {:.1}", stats.min_s * 1e9),
            format!("\"stddev_ns\": {:.1}", stats.stddev_s * 1e9),
            format!("\"runs\": {}", stats.runs),
        ];
        for (k, v) in extra {
            fields.push(format!("{}: {}", json_string(k), json_string(v)));
        }
        self.entries.push(format!("    {{{}}}", fields.join(", ")));
    }

    /// Write the report; returns the path written (None when disabled).
    pub fn write(&self) -> std::io::Result<Option<PathBuf>> {
        let Some(path) = &self.path else { return Ok(None) };
        let doc = format!(
            "{{\n  \"schema\": \"dpfw-bench-v1\",\n  \"git\": {},\n  \"results\": [\n{}\n  ]\n}}\n",
            json_string(&git_describe()),
            self.entries.join(",\n")
        );
        std::fs::write(path, doc)?;
        println!("\nwrote {}", path.display());
        Ok(Some(path.clone()))
    }
}

fn git_describe() -> String {
    std::process::Command::new("git")
        .args(["describe", "--tags", "--always", "--dirty"])
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .ok()
        .filter(|o| o.status.success())
        .map(|o| String::from_utf8_lossy(&o.stdout).trim().to_string())
        .unwrap_or_else(|| "unknown".into())
}

fn json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}
