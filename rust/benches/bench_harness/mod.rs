//! Minimal benchmark harness (criterion is not in the offline crate set).
//!
//! Provides warmup + repeated timed runs with mean/min/stddev reporting,
//! in a criterion-like output format. Used by every `harness = false`
//! bench target.
#![allow(dead_code)] // each bench uses a subset of the harness

use std::time::Instant;

pub struct Bench {
    name: String,
    warmup: usize,
    runs: usize,
}

impl Bench {
    pub fn new(name: impl Into<String>) -> Self {
        Self { name: name.into(), warmup: 1, runs: 5 }
    }

    pub fn runs(mut self, runs: usize) -> Self {
        self.runs = runs.max(1);
        self
    }

    pub fn warmup(mut self, warmup: usize) -> Self {
        self.warmup = warmup;
        self
    }

    /// Time `f` (which should return something to keep the optimizer
    /// honest); prints stats and returns the mean seconds.
    pub fn run<T>(&self, mut f: impl FnMut() -> T) -> f64 {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut times = Vec::with_capacity(self.runs);
        for _ in 0..self.runs {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
        let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>()
            / times.len() as f64;
        println!(
            "{:<52} mean {:>10} min {:>10} ±{:>8}",
            self.name,
            fmt_time(mean),
            fmt_time(min),
            fmt_time(var.sqrt())
        );
        mean
    }
}

pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

pub fn section(title: &str) {
    println!("\n=== {title} ===");
}
