//! Offline shim for the `anyhow` crate — exactly the subset `dpfw` uses.
//!
//! The build container has no crates.io access, so this path dependency
//! stands in for the real crate. API-compatible for: `Result`, `Error`,
//! `anyhow!`, `bail!`, and the `Context` extension trait on both
//! `Result<T, E>` and `Option<T>`. Error values are a message string plus
//! the stringified cause chain (`{:#}` prints `context: cause`, matching
//! anyhow's alternate formatting closely enough for CLI output).
//!
//! Like the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error`: that keeps the blanket `From<E: std::error::Error>`
//! conversion (which powers `?`) coherent with the reflexive
//! `From<Error> for Error` impl in core.

/// Dynamic error type: a message plus an optional stringified cause.
pub struct Error {
    msg: String,
    cause: Option<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: std::fmt::Display>(message: M) -> Self {
        Self { msg: message.to_string(), cause: None }
    }

    fn with_cause<M: std::fmt::Display, C: std::fmt::Display>(message: M, cause: C) -> Self {
        Self { msg: message.to_string(), cause: Some(cause.to_string()) }
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match (&self.cause, f.alternate()) {
            (Some(cause), true) => write!(f, "{}: {}", self.msg, cause),
            _ => f.write_str(&self.msg),
        }
    }
}

impl std::fmt::Debug for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.msg)?;
        if let Some(cause) = &self.cause {
            write!(f, "\n\nCaused by:\n    {cause}")?;
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Self::msg(e)
    }
}

/// `anyhow::Result<T>` — `std::result::Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::Error::msg(::std::format!($($arg)*)) };
}

/// Return early with an [`Error`] built from format arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return ::std::result::Result::Err($crate::anyhow!($($arg)*)) };
}

/// Attach context to failures: `result.context("msg")?` /
/// `option.with_context(|| format!(...))?`.
pub trait Context<T> {
    fn context<C: std::fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: std::fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: std::fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::with_cause(context, e))
    }

    fn with_context<C: std::fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::with_cause(f(), e))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: std::fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: std::fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<i32> {
        let n: i32 = s.parse().with_context(|| format!("bad int {s:?}"))?;
        if n < 0 {
            bail!("negative: {n}");
        }
        Ok(n)
    }

    #[test]
    fn question_mark_and_bail() {
        assert_eq!(parse("3").unwrap(), 3);
        let e = parse("x").unwrap_err();
        assert!(e.to_string().contains("bad int"));
        assert!(format!("{e:#}").contains("invalid digit"));
        assert!(parse("-1").unwrap_err().to_string().contains("negative"));
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        assert!(v.context("missing").is_err());
        assert_eq!(Some(1u8).context("missing").unwrap(), 1);
    }
}
