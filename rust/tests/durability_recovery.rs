//! §6.11 durability suite: checkpoint-then-resume is bitwise identical to
//! the uninterrupted run across solvers, selectors, shard counts, and
//! thread counts; a crash-killed worker's job resumes through the pool
//! with exactly-once ε accounting; and a torn ledger tail recovers to the
//! last valid frame without ever double-charging a replayed request.
//!
//! Run serially (`--test-threads=1` in CI): the tests create and tear
//! down on-disk ledgers/checkpoints and measure pool-level recovery.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use dpfw::coordinator::{Algo, Coordinator, DurabilityOptions, JobSpec, PoolOptions};
use dpfw::dp::accounting::PrivacyParams;
use dpfw::dp::ledger::{EpsLedger, FsyncPolicy};
use dpfw::fw::cancel::StopReason;
use dpfw::fw::checkpoint::{FwCheckpoint, RunDurability};
use dpfw::fw::config::{FwConfig, SelectorKind};
use dpfw::fw::trace::TraceRecord;
use dpfw::sparse::synth::SynthConfig;
use dpfw::sparse::Dataset;
use dpfw::testkit::faults::{self, FaultKind, FaultPlan};
use dpfw::testkit::io_faults::IoFaultPlane;

fn dataset(seed: u64) -> Arc<Dataset> {
    Arc::new(
        SynthConfig {
            name: format!("durab{seed}"),
            n_rows: 120,
            n_cols: 60,
            avg_row_nnz: 7.0,
            zipf_exponent: 1.2,
            n_informative: 10,
            n_dense: 0,
            label_noise: 0.02,
            bias_col: true,
        }
        .generate(seed),
    )
}

/// 60-iteration config; privacy params ride along iff the selector is a
/// DP mechanism (`FwConfig::validate` enforces the pairing).
fn cfg(selector: SelectorKind, seed: u64) -> FwConfig {
    FwConfig {
        iters: 60,
        lambda: 6.0,
        privacy: selector.is_private().then(|| PrivacyParams::new(1.0, 1e-6)),
        selector,
        seed,
        trace_every: 1,
        ..Default::default()
    }
}

fn job(id: usize, data: Arc<Dataset>, algo: Algo, cfg: FwConfig) -> JobSpec {
    JobSpec { id, label: format!("d{id}"), data, algo, cfg, test_data: None }
}

/// Deterministic trace fields — everything but the wall clock, the one
/// field outside the bitwise resume contract.
fn trace_key(r: &TraceRecord) -> (usize, f64, u64, u64, u64, usize) {
    (r.iter, r.gap, r.flops, r.bytes, r.pops, r.selected)
}

fn tmpdir(name: &str) -> std::path::PathBuf {
    let p = std::env::temp_dir()
        .join(format!("dpfw-durab-{}-{}", std::process::id(), name));
    let _ = std::fs::remove_dir_all(&p);
    std::fs::create_dir_all(&p).unwrap();
    p
}

// ---------------------------------------------------------------------------
// The resume matrix: checkpoint at the monolithic 1-thread topology, then
// finish the run at every (shards, threads) combination — each must be
// bitwise identical to the uninterrupted run at that same topology.
// ---------------------------------------------------------------------------

#[test]
fn checkpoint_resume_is_bitwise_identical_across_topologies() {
    let dir = tmpdir("resume-matrix");
    let d = dataset(21);
    // heap selectors exist only on the fast solver (Alg 3 rides Alg 2)
    let combos = [
        (Algo::Fast, SelectorKind::Argmax),
        (Algo::Fast, SelectorKind::FibHeap),
        (Algo::Fast, SelectorKind::Bsls),
        (Algo::Standard, SelectorKind::Argmax),
        (Algo::Standard, SelectorKind::Bsls),
    ];
    for (algo, selector) in combos {
        let base = cfg(selector, 31);
        // producer run: brownout at t = 23 persists the stop-point
        // snapshot (cadence snapshots at 7, 14, 21 are overwritten)
        let ck_path = dir.join(format!("ckpt-{algo:?}-{}.bin", selector.name()));
        let mut capped = base.clone();
        capped.threads = 1;
        capped.iter_cap = Some(23);
        capped.durability = Some(Arc::new(RunDurability {
            request_id: 1,
            path: ck_path.clone(),
            ledger: None,
            every_k: 7,
            io: IoFaultPlane::none(),
        }));
        let cut = job(0, d.clone(), algo, capped).run();
        assert_eq!(cut.output.stopped, StopReason::Brownout);
        assert_eq!(cut.output.iters_run, 23);
        let ck = Arc::new(FwCheckpoint::read_from(&ck_path).unwrap());
        assert_eq!(ck.replay_to(), 23);
        assert_eq!(ck.dataset_fp, d.fingerprint());

        for shards in [None, Some(3)] {
            for threads in [1usize, 4] {
                let ctx = format!(
                    "algo={algo:?} sel={} P={shards:?} threads={threads}",
                    selector.name()
                );
                let mut full_cfg = base.clone();
                full_cfg.shards = shards;
                full_cfg.threads = threads;
                let full = job(0, d.clone(), algo, full_cfg.clone()).run();

                let mut resume_cfg = full_cfg;
                resume_cfg.resume = Some(ck.clone());
                let resumed = job(0, d.clone(), algo, resume_cfg).run();

                assert_eq!(
                    resumed.output.weights, full.output.weights,
                    "{ctx}: weights diverged"
                );
                assert_eq!(
                    resumed.output.final_gap.to_bits(),
                    full.output.final_gap.to_bits(),
                    "{ctx}: gap diverged"
                );
                assert_eq!(resumed.output.flops, full.output.flops, "{ctx}: flops");
                assert_eq!(
                    resumed.output.bytes_moved, full.output.bytes_moved,
                    "{ctx}: bytes"
                );
                assert_eq!(
                    resumed.output.eps_spent, full.output.eps_spent,
                    "{ctx}: ε spend"
                );
                assert_eq!(
                    resumed.output.iters_run, full.output.iters_run,
                    "{ctx}: iterations"
                );
                assert_eq!(
                    resumed.output.trace.len(),
                    full.output.trace.len(),
                    "{ctx}: trace length"
                );
                for (a, b) in resumed.output.trace.iter().zip(&full.output.trace) {
                    assert_eq!(trace_key(a), trace_key(b), "{ctx}: trace diverged");
                }
            }
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// End-to-end crash recovery through the pool: a CrashAt-killed worker's
// job resumes from its cadence checkpoint, lands the same bits as a run
// that never crashed, and the ε ledger charges the dataset exactly once.
// ---------------------------------------------------------------------------

#[test]
fn crash_killed_solve_resumes_through_pool_with_exactly_once_accounting() {
    let dir = tmpdir("pool-crash");
    let wal = dir.join("eps.wal");
    let ledger = Arc::new(EpsLedger::open(&wal, FsyncPolicy::Always).unwrap());
    let d = dataset(22);
    let base = cfg(SelectorKind::Bsls, 33);
    let clean = job(0, d.clone(), Algo::Fast, base.clone()).run();
    let full_eps = clean.output.eps_spent.expect("DP run reports spend");

    let mut c = Coordinator::with_options(
        1,
        PoolOptions {
            durability: Some(DurabilityOptions {
                ledger: Some(ledger.clone()),
                dir: dir.clone(),
                every_k: 10,
                resume_in_process: true,
            }),
            ..Default::default()
        },
    );
    let mut doomed = base.clone();
    doomed.fault = FaultPlan::once(FaultKind::CrashAt { iter: 45 });
    c.submit(job(0, d.clone(), Algo::Fast, doomed));
    let results = c.drain();
    let r = results[0].as_ref().expect("crash-killed job must resume to Ok");
    assert_eq!(c.metrics.jobs_resumed.load(Ordering::Relaxed), 1);
    assert_eq!(c.metrics.jobs_failed.load(Ordering::Relaxed), 0);
    assert_eq!(r.output.weights, clean.output.weights, "resume diverged");
    assert_eq!(r.output.eps_spent, clean.output.eps_spent);
    assert_eq!(r.output.flops, clean.output.flops);

    // exactly-once: crash + resume replayed the cadence charges, but the
    // max-merge pins the request at one full run's spend
    let (released, eps) = ledger.spent_for_request(0).expect("request recorded");
    assert_eq!(released as usize, base.iters - 1);
    assert!((eps - full_eps).abs() < 1e-12, "{eps} vs {full_eps}");
    assert!((ledger.spent_for_dataset(d.fingerprint()) - full_eps).abs() < 1e-12);

    // the record survives a reopen intact (no torn tail: fsync-always)
    drop(c);
    let reopened = EpsLedger::open(&wal, FsyncPolicy::Always).unwrap();
    assert_eq!(reopened.truncated_frames(), 0);
    assert!((reopened.spent_for_dataset(d.fingerprint()) - full_eps).abs() < 1e-12);
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// Torn ledger tail: recovery truncates to the last valid frame, and the
// seed-pinned re-run of the same logical request tops the spend back to
// exactly one full run — never a double charge.
// ---------------------------------------------------------------------------

#[test]
fn torn_ledger_tail_recovers_and_rerun_never_double_charges() {
    let dir = tmpdir("torn-ledger");
    let wal = dir.join("eps.wal");
    let d = dataset(23);
    let base = cfg(SelectorKind::Bsls, 44);
    let run_with = |ledger: Arc<EpsLedger>| {
        let mut c = base.clone();
        c.durability = Some(Arc::new(RunDurability {
            request_id: 9,
            path: dir.join("ckpt-9.bin"),
            ledger: Some(ledger),
            every_k: 10,
            io: IoFaultPlane::none(),
        }));
        job(0, d.clone(), Algo::Fast, c).run()
    };

    let ledger = Arc::new(EpsLedger::open(&wal, FsyncPolicy::EveryN(4)).unwrap());
    let first = run_with(ledger.clone());
    let full_eps = first.output.eps_spent.unwrap();
    let (released, eps) = ledger.spent_for_request(9).unwrap();
    assert_eq!(released as usize, base.iters - 1);
    assert_eq!(eps.to_bits(), full_eps.to_bits());
    let frames_before = ledger.frames();
    drop(ledger);

    // crash mid-append: shear the final (completion) frame
    let len = std::fs::metadata(&wal).unwrap().len();
    faults::truncate_file(&wal, len - 10).unwrap();
    let ledger = Arc::new(EpsLedger::open(&wal, FsyncPolicy::EveryN(4)).unwrap());
    assert_eq!(ledger.truncated_frames(), 1);
    assert_eq!(ledger.frames(), frames_before - 1);
    let (released, eps) = ledger.spent_for_request(9).unwrap();
    assert_eq!(released, 50, "last surviving cadence record");
    assert!(eps < full_eps);

    // the same logical request re-runs after recovery (seed-pinned, same
    // request id): bit-identical output, and the merged spend lands at
    // exactly one full run — not cadence + rerun summed
    let second = run_with(ledger.clone());
    assert_eq!(second.output.weights, first.output.weights);
    let (released, eps) = ledger.spent_for_request(9).unwrap();
    assert_eq!(released as usize, base.iters - 1);
    assert_eq!(eps.to_bits(), full_eps.to_bits());
    assert!((ledger.spent_for_dataset(d.fingerprint()) - full_eps).abs() < 1e-12);
    std::fs::remove_dir_all(&dir).ok();
}
