//! §6.12 restart-time recovery suite: a pool is crash-killed with
//! in-process resume disabled — so the kill leaves the durability dir
//! (WAL + orphaned checkpoints) exactly as a dead process would — then a
//! *new* `RecoveryManager` over the same dir classifies the orphans and
//! a fresh pool resubmits the work via `submit_recovered`, reusing the
//! dead process's durable request ids. The recovered outputs must be
//! bitwise identical to an uninterrupted run, and the WAL must hold
//! exactly one run's spend per request — however the kill landed.
//!
//! Run serially (`--test-threads=1` in CI): every test owns an on-disk
//! durability dir and asserts on supervisor timing.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use dpfw::coordinator::{
    Algo, Coordinator, DurabilityOptions, Job, JobError, JobResult, JobSpec,
    OrphanKind, OrphanState, PathJob, PoolOptions, RecoveryManager,
};
use dpfw::dp::accounting::PrivacyParams;
use dpfw::dp::ledger::{EpsLedger, FsyncPolicy};
use dpfw::fw::config::{FwConfig, SelectorKind};
use dpfw::fw::trace::TraceRecord;
use dpfw::sparse::synth::SynthConfig;
use dpfw::sparse::Dataset;
use dpfw::testkit::faults::{FaultKind, FaultPlan};

fn dataset(seed: u64) -> Arc<Dataset> {
    Arc::new(
        SynthConfig {
            name: format!("restart{seed}"),
            n_rows: 120,
            n_cols: 60,
            avg_row_nnz: 7.0,
            zipf_exponent: 1.2,
            n_informative: 10,
            n_dense: 0,
            label_noise: 0.02,
            bias_col: true,
        }
        .generate(seed),
    )
}

fn cfg(selector: SelectorKind, seed: u64) -> FwConfig {
    FwConfig {
        iters: 60,
        lambda: 6.0,
        privacy: selector.is_private().then(|| PrivacyParams::new(1.0, 1e-6)),
        selector,
        seed,
        trace_every: 1,
        ..Default::default()
    }
}

fn job(id: usize, data: Arc<Dataset>, algo: Algo, cfg: FwConfig) -> JobSpec {
    JobSpec { id, label: format!("r{id}"), data, algo, cfg, test_data: None }
}

fn tmpdir(name: &str) -> std::path::PathBuf {
    let p = std::env::temp_dir()
        .join(format!("dpfw-restart-{}-{}", std::process::id(), name));
    let _ = std::fs::remove_dir_all(&p);
    std::fs::create_dir_all(&p).unwrap();
    p
}

fn durable_pool(ledger: &Arc<EpsLedger>, dir: &std::path::Path) -> Coordinator {
    Coordinator::with_options(
        1,
        PoolOptions {
            durability: Some(DurabilityOptions {
                ledger: Some(ledger.clone()),
                dir: dir.to_path_buf(),
                every_k: 10,
                // the point of this suite: a kill must leave the on-disk
                // state for restart-time recovery, not resume in-process
                resume_in_process: false,
            }),
            ..Default::default()
        },
    )
}

/// Deterministic trace fields — everything but the wall clock, the one
/// field outside the bitwise recovery contract.
fn trace_key(r: &TraceRecord) -> (usize, f64, u64, u64, u64, usize) {
    (r.iter, r.gap, r.flops, r.bytes, r.pops, r.selected)
}

fn assert_bitwise(ctx: &str, got: &JobResult, want: &JobResult) {
    assert_eq!(got.output.weights, want.output.weights, "{ctx}: weights");
    assert_eq!(
        got.output.final_gap.to_bits(),
        want.output.final_gap.to_bits(),
        "{ctx}: gap"
    );
    assert_eq!(got.output.flops, want.output.flops, "{ctx}: flops");
    assert_eq!(got.output.bytes_moved, want.output.bytes_moved, "{ctx}: bytes");
    assert_eq!(got.output.eps_spent, want.output.eps_spent, "{ctx}: ε spend");
    assert_eq!(got.output.iters_run, want.output.iters_run, "{ctx}: iterations");
    assert_eq!(got.output.trace.len(), want.output.trace.len(), "{ctx}: trace len");
    for (a, b) in got.output.trace.iter().zip(&want.output.trace) {
        assert_eq!(trace_key(a), trace_key(b), "{ctx}: trace diverged");
    }
}

// ---------------------------------------------------------------------------
// The kill-restart matrix: (solver) × (shards) × (threads), alternating
// the kill shape between a mid-solve crash (leaves a resumable cadence
// snapshot) and an abrupt pre-work death (leaves nothing — recovery
// degrades to a seed-pinned fresh rerun). Either way the recovered run
// must land the uninterrupted run's bits with exactly-once ε.
// ---------------------------------------------------------------------------

#[test]
fn kill_restart_matrix_is_bitwise_identical_with_exactly_once_eps() {
    let d = dataset(51);
    let mut combo = 0usize;
    for algo in [Algo::Fast, Algo::Standard] {
        for shards in [None, Some(3)] {
            for threads in [1usize, 4] {
                combo += 1;
                let crash_mid_solve = combo % 2 == 0;
                let ctx = format!(
                    "algo={algo:?} P={shards:?} threads={threads} \
                     kill={}",
                    if crash_mid_solve { "CrashAt(45)" } else { "DieAbruptly" }
                );
                let mut base = cfg(SelectorKind::Bsls, 61);
                base.shards = shards;
                base.threads = threads;
                let clean = job(0, d.clone(), algo, base.clone()).run();
                let full_eps = clean.output.eps_spent.expect("private run");

                let dir = tmpdir(&format!("matrix-{combo}"));
                let wal = dir.join("eps.wal");
                // ---- process one: killed ------------------------------
                {
                    let ledger =
                        Arc::new(EpsLedger::open(&wal, FsyncPolicy::Always).unwrap());
                    let mut pool = durable_pool(&ledger, &dir);
                    let mut doomed = base.clone();
                    doomed.fault = FaultPlan::once(if crash_mid_solve {
                        FaultKind::CrashAt { iter: 45 }
                    } else {
                        FaultKind::DieAbruptly
                    });
                    pool.submit(job(0, d.clone(), algo, doomed));
                    let results = pool.drain();
                    assert!(
                        matches!(results[0], Err(JobError::WorkerDied)),
                        "{ctx}: with in-process resume off the kill must fail the id"
                    );
                    assert_eq!(
                        pool.metrics.jobs_resumed.load(Ordering::Relaxed),
                        0,
                        "{ctx}"
                    );
                }
                // ---- "restart": fresh ledger handle, recovery scan ----
                let ledger =
                    Arc::new(EpsLedger::open(&wal, FsyncPolicy::Always).unwrap());
                let manifest =
                    RecoveryManager::new(&dir, Some(ledger.clone())).scan().unwrap();
                assert_eq!(manifest.quarantined, 0, "{ctx}");
                assert_eq!(
                    manifest.resumable().count(),
                    crash_mid_solve as usize,
                    "{ctx}: a mid-solve crash orphans its cadence snapshot; \
                     a pre-work death leaves nothing"
                );
                // the dead process's one cell was its first allocation on a
                // fresh ledger: durable request id 0
                let slots = manifest.slots_for(&[0]);
                assert_eq!(slots[0].resume.is_some(), crash_mid_solve, "{ctx}");

                let mut pool = durable_pool(&ledger, &dir);
                pool.submit_recovered(
                    Job::Cell(job(0, d.clone(), algo, base.clone())),
                    &slots,
                );
                let results = pool.drain();
                let r = results[0].as_ref().expect("recovered run must land");
                assert_bitwise(&ctx, r, &clean);

                // exactly-once WAL spend: cadence charges from the killed
                // attempt and the recovered run's re-charges max-merge to
                // one full run for the one request id
                let (released, eps) =
                    ledger.spent_for_request(0).expect("request recorded");
                assert_eq!(released as usize, base.iters - 1, "{ctx}");
                assert!((eps - full_eps).abs() < 1e-12, "{ctx}: {eps} vs {full_eps}");
                assert!(
                    (ledger.spent_for_dataset(d.fingerprint()) - full_eps).abs()
                        < 1e-12,
                    "{ctx}"
                );
                assert_eq!(ledger.n_requests(), 1, "{ctx}: one request, ever");
                std::fs::remove_dir_all(&dir).ok();
            }
        }
    }
}

// ---------------------------------------------------------------------------
// λ-path restart: the crash hits grid point 0 mid-solve, so the dead
// process leaves one orphaned `ckpt-0-0.bin` and nothing for points 1-2.
// Recovery resumes point 0 at its snapshot and runs the rest fresh — all
// three land the uninterrupted path's bits, each λ's ε charged once.
// ---------------------------------------------------------------------------

#[test]
fn killed_path_resumes_at_its_last_completed_lambda_across_restart() {
    let d = dataset(52);
    let base = cfg(SelectorKind::Bsls, 62);
    let lambdas = vec![8.0, 6.0, 4.0];
    let path = |cfg: FwConfig| PathJob {
        base_id: 0,
        label: "restart-path".into(),
        data: d.clone(),
        algo: Algo::Fast,
        cfg,
        lambdas: lambdas.clone(),
        test_data: None,
    };
    // baseline: the uninterrupted path through a plain pool
    let clean: Vec<JobResult> = {
        let mut pool = Coordinator::new(1);
        pool.submit_path(path(base.clone()));
        pool.drain().into_iter().map(|r| r.unwrap()).collect()
    };

    let dir = tmpdir("path-restart");
    let wal = dir.join("eps.wal");
    {
        let ledger = Arc::new(EpsLedger::open(&wal, FsyncPolicy::Always).unwrap());
        let mut pool = durable_pool(&ledger, &dir);
        let mut doomed = base.clone();
        doomed.fault = FaultPlan::once(FaultKind::CrashAt { iter: 45 });
        pool.submit_path(path(doomed));
        for r in pool.drain() {
            assert!(matches!(r, Err(JobError::WorkerDied)));
        }
    }
    let ledger = Arc::new(EpsLedger::open(&wal, FsyncPolicy::Always).unwrap());
    let manifest = RecoveryManager::new(&dir, Some(ledger.clone())).scan().unwrap();
    assert_eq!(manifest.quarantined, 0);
    assert_eq!(manifest.resumable().count(), 1, "only point 0 got far enough");
    let o = manifest.find(0).unwrap();
    assert_eq!(o.kind, OrphanKind::PathPoint { k: 0 });
    assert_eq!(o.state, OrphanState::Resumable);
    let ck = o.checkpoint.as_ref().unwrap();
    assert_eq!(ck.replay_to(), 40, "last cadence boundary before the crash");
    assert_eq!(ck.dataset_fp, d.fingerprint());
    assert!(o.spent.is_some(), "the WAL already holds point 0's cadence spend");

    // the dead process's path was its first submission on a fresh ledger:
    // its three grid points hold consecutive durable request ids 0, 1, 2
    let slots = manifest.slots_for(&[0, 1, 2]);
    assert!(slots[0].resume.is_some());
    assert!(slots[1].resume.is_none() && slots[2].resume.is_none());

    let mut pool = durable_pool(&ledger, &dir);
    pool.submit_recovered(Job::Path(path(base.clone())), &slots);
    let results = pool.drain();
    assert_eq!(results.len(), 3);
    for (k, (r, want)) in results.iter().zip(&clean).enumerate() {
        let r = r.as_ref().expect("recovered path point must land");
        assert_bitwise(&format!("lambda[{k}]"), r, want);
    }
    // exactly-once per grid point, and completion GC'd the checkpoints
    for k in 0..3u64 {
        let want = clean[k as usize].output.eps_spent.unwrap();
        let (released, eps) = ledger.spent_for_request(k).unwrap();
        assert_eq!(released as usize, base.iters - 1, "lambda[{k}]");
        assert!((eps - want).abs() < 1e-12, "lambda[{k}]");
        assert!(!dir.join(format!("ckpt-{k}-{k}.bin")).exists());
    }
    assert!(!dir.join("ckpt-0-0.bin").exists(), "resumed point GC'd on success");
    let total: f64 = clean.iter().map(|c| c.output.eps_spent.unwrap()).sum();
    assert!((ledger.spent_for_dataset(d.fingerprint()) - total).abs() < 1e-12);
    assert_eq!(ledger.n_requests(), 3);

    // compaction after recovery preserves the restart-surviving totals
    // and the request-id high-water mark bit-for-bit
    let before = ledger.spent_for_dataset(d.fingerprint());
    ledger.compact().unwrap();
    drop(ledger);
    let ledger = EpsLedger::open(&wal, FsyncPolicy::Always).unwrap();
    assert_eq!(ledger.spent_for_dataset(d.fingerprint()).to_bits(), before.to_bits());
    assert_eq!(ledger.allocate_request_id(), 3);
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// An orphan that rotted on disk after the crash: the scan quarantines it
// (never deletes), the job degrades to a seed-pinned fresh rerun, and
// the ε accounting still lands at exactly one run.
// ---------------------------------------------------------------------------

#[test]
fn corrupt_orphan_quarantines_and_fresh_rerun_stays_exactly_once() {
    let d = dataset(53);
    let base = cfg(SelectorKind::Bsls, 63);
    let clean = job(0, d.clone(), Algo::Fast, base.clone()).run();
    let full_eps = clean.output.eps_spent.unwrap();

    let dir = tmpdir("corrupt-orphan");
    let wal = dir.join("eps.wal");
    {
        let ledger = Arc::new(EpsLedger::open(&wal, FsyncPolicy::Always).unwrap());
        let mut pool = durable_pool(&ledger, &dir);
        let mut doomed = base.clone();
        doomed.fault = FaultPlan::once(FaultKind::CrashAt { iter: 45 });
        pool.submit(job(0, d.clone(), Algo::Fast, doomed));
        assert!(matches!(pool.drain()[0], Err(JobError::WorkerDied)));
    }
    // bit rot between death and restart
    let orphan = dir.join("ckpt-0.bin");
    let mut bytes = std::fs::read(&orphan).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x01;
    std::fs::write(&orphan, &bytes).unwrap();

    let ledger = Arc::new(EpsLedger::open(&wal, FsyncPolicy::Always).unwrap());
    let manifest = RecoveryManager::new(&dir, Some(ledger.clone())).scan().unwrap();
    assert_eq!(manifest.quarantined, 1);
    let o = manifest.find(0).unwrap();
    assert_eq!(o.state, OrphanState::Corrupt);
    assert!(o.spent.is_some(), "the WAL record outlives the rotten snapshot");
    let quarantined = dir.join("quarantine").join("ckpt-0.bin");
    assert_eq!(o.path, quarantined);
    assert_eq!(std::fs::read(&quarantined).unwrap(), bytes, "evidence preserved");

    let slots = manifest.slots_for(&[0]);
    assert!(slots[0].resume.is_none(), "a quarantined orphan seeds nothing");
    let mut pool = durable_pool(&ledger, &dir);
    pool.submit_recovered(Job::Cell(job(0, d.clone(), Algo::Fast, base.clone())), &slots);
    let results = pool.drain();
    let r = results[0].as_ref().expect("fresh rerun must land");
    assert_bitwise("fresh-rerun", r, &clean);

    let (released, eps) = ledger.spent_for_request(0).unwrap();
    assert_eq!(released as usize, base.iters - 1);
    assert!((eps - full_eps).abs() < 1e-12);
    assert!((ledger.spent_for_dataset(d.fingerprint()) - full_eps).abs() < 1e-12);
    assert_eq!(ledger.n_requests(), 1);
    assert!(quarantined.exists(), "quarantine is forever, deletion never");
    std::fs::remove_dir_all(&dir).ok();
}
