//! Adversarial shard-boundary tests (DESIGN.md §6.8): the row partition
//! must stay layout- and trajectory-identical on inputs engineered to
//! stress `balanced_ranges` — nnz so skewed that shards come out empty,
//! slabs of all-empty rows, one dense row swallowing a boundary, and more
//! shards requested than rows exist. The synth-backed property tests
//! cover the statistically typical shapes; these fixtures pin the corners
//! a generator essentially never draws.

use dpfw::fw::config::FwConfig;
use dpfw::fw::fast::FastFrankWolfe;
use dpfw::fw::standard::StandardFrankWolfe;
use dpfw::fw::trace::FwOutput;
use dpfw::sparse::coo::CooBuilder;
use dpfw::sparse::sharded::ShardedDataset;
use dpfw::sparse::Dataset;

/// Bit-level trajectory identity: weights, gap, FLOPs, bytes, telemetry.
fn assert_trajectory_identical(a: &FwOutput, b: &FwOutput, what: &str) {
    for (i, (x, y)) in a.weights.as_slice().iter().zip(b.weights.as_slice()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: weight {i}: {x} vs {y}");
    }
    assert_eq!(a.final_gap.to_bits(), b.final_gap.to_bits(), "{what}: final gap");
    assert_eq!(a.flops, b.flops, "{what}: flops");
    assert_eq!(a.bytes_moved, b.bytes_moved, "{what}: bytes");
    assert_eq!(a.selector_stats, b.selector_stats, "{what}: selector stats");
}

/// Every shard view must reproduce the parent's rows verbatim, and the
/// union of row ranges must tile `0..n` in order.
fn assert_layout_identical(ds: &Dataset, sharded: &ShardedDataset, what: &str) {
    let mut next = 0usize;
    for (si, s) in sharded.shards().iter().enumerate() {
        assert_eq!(s.rows.start, next, "{what}: shard {si} range gap");
        next = s.rows.end;
        assert_eq!(s.csr.n_rows(), s.rows.len(), "{what}: shard {si} view height");
        assert_eq!(s.csr.n_cols(), ds.n_cols(), "{what}: shard {si} must keep global cols");
        for (local, global) in s.rows.clone().enumerate() {
            assert_eq!(
                s.csr.row(local).collect::<Vec<_>>(),
                ds.csr.row(global).collect::<Vec<_>>(),
                "{what}: shard {si} row {global} differs"
            );
            assert_eq!(s.labels[local], ds.labels[global], "{what}: label {global}");
        }
    }
    assert_eq!(next, ds.n_rows(), "{what}: shards must cover every row");
}

/// Run the boundary fixture through both solvers at P ∈ {1, 3, 16} and
/// demand bit-identity against the monolithic path (fast solver) and
/// across partitions (standard solver — its byte model legitimately
/// differs from the legacy engine's, see DESIGN.md §6.8).
fn assert_solvers_partition_invariant(ds: &Dataset, what: &str) {
    let cfg = FwConfig { iters: 40, lambda: 4.0, ..Default::default() };
    let fast_legacy = FastFrankWolfe::new(ds, cfg.clone()).run();
    let std_p1 = StandardFrankWolfe::new(
        ds,
        FwConfig { shards: Some(1), ..cfg.clone() },
    )
    .run();
    for p in [1usize, 3, 16] {
        let sharded_cfg = FwConfig { shards: Some(p), ..cfg.clone() };
        let fast = FastFrankWolfe::new(ds, sharded_cfg.clone()).run();
        assert!(fast.effective_shards >= 1 && fast.effective_shards <= p, "{what}: p={p}");
        assert_trajectory_identical(&fast_legacy, &fast, &format!("{what}: fast p={p}"));
        let std_p = StandardFrankWolfe::new(ds, sharded_cfg).run();
        assert_trajectory_identical(&std_p1, &std_p, &format!("{what}: std p={p}"));
    }
}

/// One 400-nnz row in an otherwise 1-nnz matrix: nnz-balanced partitioning
/// wants to split *inside* that row, which the row-granular boundary may
/// not do — the dense row must land whole in exactly one shard, starving
/// its neighbors down to empty ranges, and nothing may change bits.
#[test]
fn dense_row_straddling_boundary() {
    let mut b = CooBuilder::new(12, 401);
    for i in 0..12usize {
        b.push(i, (i * 7) % 11, 1.0 + i as f32 * 0.25);
    }
    for j in 0..400usize {
        b.push(5, j, ((j as f32) * 0.01).sin() + 1.5);
    }
    let labels = (0..12).map(|i| (i % 2) as f32).collect();
    let ds = Dataset::new(b.to_csr(), labels, "dense-straddle");
    for p in [1usize, 3, 16] {
        let sharded = ShardedDataset::build(&ds, p);
        assert_layout_identical(&ds, &sharded, &format!("straddle p={p}"));
        // the dense row is indivisible: exactly one shard holds row 5
        let holders = sharded
            .shards()
            .iter()
            .filter(|s| s.rows.contains(&5))
            .count();
        assert_eq!(holders, 1, "p={p}: dense row must live in exactly one shard");
    }
    assert_solvers_partition_invariant(&ds, "straddle");
}

/// A slab of all-empty rows mid-matrix: the partition may hand entire
/// shards nothing but zero-nnz rows (or nothing at all). Their views must
/// build, scan as no-ops, and leave the trajectory untouched.
#[test]
fn all_empty_row_slab_is_inert() {
    let mut b = CooBuilder::new(0, 40);
    for i in 0..6usize {
        b.push(i, i * 5, 1.0 + i as f32);
        b.push(i, i * 5 + 2, 0.5);
    }
    // rows 6..26 stay empty; a tail of populated rows follows
    for i in 26..30usize {
        b.push(i, (i * 3) % 40, 2.0 - i as f32 * 0.05);
    }
    b.set_shape(30, 40);
    let labels = (0..30).map(|i| ((i / 3) % 2) as f32).collect();
    let ds = Dataset::new(b.to_csr(), labels, "empty-slab");
    for p in [1usize, 3, 16] {
        let sharded = ShardedDataset::build(&ds, p);
        assert_layout_identical(&ds, &sharded, &format!("slab p={p}"));
        let covered: usize = sharded.shards().iter().map(|s| s.nnz()).sum();
        assert_eq!(covered, ds.nnz(), "slab p={p}: nnz must be conserved");
    }
    assert_solvers_partition_invariant(&ds, "slab");
}

/// P far beyond N: the partition clamps to at most one row per shard and
/// reports the clamped count; the solve is still bit-identical.
#[test]
fn more_shards_than_rows_clamps() {
    let mut b = CooBuilder::new(5, 24);
    for i in 0..5usize {
        for k in 0..3usize {
            b.push(i, (i * 5 + k * 7) % 24, 1.0 + (i + k) as f32 * 0.125);
        }
    }
    let labels = vec![0.0, 1.0, 1.0, 0.0, 1.0];
    let ds = Dataset::new(b.to_csr(), labels, "tiny");
    let sharded = ShardedDataset::build(&ds, 64);
    assert!(sharded.n_shards() <= 5, "cannot have more shards than rows");
    assert_layout_identical(&ds, &sharded, "clamp");
    let cfg = FwConfig { iters: 30, lambda: 2.0, shards: Some(64), ..Default::default() };
    let out = FastFrankWolfe::new(&ds, cfg.clone()).run();
    assert!(out.effective_shards <= 5);
    let legacy =
        FastFrankWolfe::new(&ds, FwConfig { shards: None, ..cfg }).run();
    assert_trajectory_identical(&legacy, &out, "clamp fast");
}

/// An entirely empty matrix (every row zero-nnz) is the degenerate
/// extreme: the gradient never moves, every α stays zero, and the sharded
/// engines must agree with the monolithic one on doing nothing.
#[test]
fn fully_empty_matrix_degenerate() {
    let mut b = CooBuilder::new(0, 8);
    b.set_shape(9, 8);
    let labels = (0..9).map(|i| (i % 2) as f32).collect();
    let ds = Dataset::new(b.to_csr(), labels, "all-empty");
    assert_eq!(ds.nnz(), 0);
    for p in [1usize, 3, 16] {
        let sharded = ShardedDataset::build(&ds, p);
        assert_layout_identical(&ds, &sharded, &format!("degenerate p={p}"));
    }
    assert_solvers_partition_invariant(&ds, "degenerate");
}
