//! Integration tests for the coordinator: a realistic experiment grid run
//! through the worker pool, registry exports, and failure injection under
//! load.

use std::sync::Arc;

use dpfw::coordinator::{Algo, Coordinator, JobSpec, Registry};
use dpfw::dp::accounting::PrivacyParams;
use dpfw::fw::config::{FwConfig, SelectorKind};
use dpfw::sparse::synth::{DatasetPreset, SynthConfig};
use dpfw::sparse::Dataset;

fn small(p: DatasetPreset, seed: u64) -> Arc<Dataset> {
    let sc = match p {
        DatasetPreset::Rcv1 => 0.02,
        DatasetPreset::News20 => 0.005,
        _ => 0.0005,
    };
    Arc::new(SynthConfig::preset(p).scale(sc).generate(seed))
}

/// A mini Table-3 grid: 2 datasets × 2 ε × 3 configs = 12 jobs across 4
/// workers, all succeed, results land in the registry with sane fields.
#[test]
fn mini_table3_grid() {
    let mut coord = Coordinator::new(4);
    let mut jobs = Vec::new();
    let mut id = 0;
    for p in [DatasetPreset::Rcv1, DatasetPreset::News20] {
        let ds = small(p, 3);
        let (train, test) = ds.split(0.25);
        let (train, test) = (Arc::new(train), Arc::new(test));
        for eps in [1.0, 0.1] {
            for (algo, sel) in [
                (Algo::Standard, SelectorKind::NoisyMax),
                (Algo::Fast, SelectorKind::NoisyMax),
                (Algo::Fast, SelectorKind::Bsls),
            ] {
                jobs.push(JobSpec {
                    id,
                    label: format!("{}|{}|{}|{}", p.name(), eps, algo.name(), sel.name()),
                    data: train.clone(),
                    algo,
                    cfg: FwConfig {
                        iters: 100,
                        lambda: 10.0,
                        privacy: Some(PrivacyParams::new(eps, 1e-6)),
                        selector: sel,
                        seed: 17,
                        trace_every: 25,
                        ..Default::default()
                    },
                    test_data: Some(test.clone()),
                });
                id += 1;
            }
        }
    }
    let n_jobs = jobs.len();
    let results = coord.run_all(jobs);
    assert_eq!(results.len(), n_jobs);
    let mut reg = Registry::new();
    for r in results {
        let r = r.expect("grid job failed");
        assert!(r.output.wall_ms > 0.0);
        assert!(r.output.flops > 0);
        assert!(r.accuracy.is_some() && r.auc.is_some());
        assert!(!r.output.trace.is_empty());
        reg.add(r);
    }
    assert_eq!(reg.len(), n_jobs);
    // exports
    let dir = std::env::temp_dir().join("dpfw_coord_it");
    std::fs::create_dir_all(&dir).unwrap();
    reg.write_csv(dir.join("grid.csv")).unwrap();
    reg.write_json(dir.join("grid.json")).unwrap();
    let csv = std::fs::read_to_string(dir.join("grid.csv")).unwrap();
    assert_eq!(csv.lines().count(), n_jobs + 1);
    let json = std::fs::read_to_string(dir.join("grid.json")).unwrap();
    assert!(json.contains("\"jobs\":["));
    // metrics
    let done = coord.metrics.jobs_completed.load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(done as usize, n_jobs);
}

/// Failures mid-grid don't lose the other results or wedge the pool, and
/// the pool stays usable for a second wave.
#[test]
fn failures_are_isolated_and_pool_reusable() {
    let mut coord = Coordinator::new(3);
    let ds = small(DatasetPreset::Rcv1, 5);
    let good = |id: usize| JobSpec {
        id,
        label: format!("good{id}"),
        data: ds.clone(),
        algo: Algo::Fast,
        cfg: FwConfig { iters: 50, lambda: 5.0, ..Default::default() },
        test_data: None,
    };
    let mut bad = good(1);
    bad.cfg.iters = 0; // validate() panics in the worker
    coord.submit(good(0));
    coord.submit(bad);
    coord.submit(good(2));
    let wave1 = coord.drain();
    assert!(wave1[0].is_ok());
    assert!(wave1[1].is_err());
    assert!(wave1[2].is_ok());
    // second wave on the same pool
    let wave2 = coord.run_all((10..14).map(good).collect());
    assert!(wave2.iter().all(|r| r.is_ok()));
}

/// Worker parallelism actually overlaps work: pool busy-time exceeds
/// wall-clock elapsed on a multi-job run (i.e. >1 core really used).
#[test]
fn pool_runs_concurrently() {
    let mut coord = Coordinator::new(4);
    let ds = small(DatasetPreset::News20, 7);
    let jobs: Vec<JobSpec> = (0..8)
        .map(|id| JobSpec {
            id,
            label: format!("par{id}"),
            data: ds.clone(),
            algo: Algo::Standard, // deliberately slow: dense per-iter work
            cfg: FwConfig { iters: 150, lambda: 5.0, ..Default::default() },
            test_data: None,
        })
        .collect();
    let t0 = std::time::Instant::now();
    let results = coord.run_all(jobs);
    let elapsed = t0.elapsed().as_secs_f64();
    assert!(results.iter().all(|r| r.is_ok()));
    let busy =
        coord.metrics.busy_us.load(std::sync::atomic::Ordering::Relaxed) as f64 / 1e6;
    assert!(
        busy > 1.2 * elapsed,
        "no overlap: busy {busy:.2}s vs elapsed {elapsed:.2}s"
    );
}
