//! §6.9 resilience suite: the fault-injection matrix, deadline semantics
//! (queued → shed, running → anytime partial), supervised worker respawn,
//! and the two privacy-critical properties — a seed-pinned retry is
//! bit-identical to its first attempt (zero extra ε), and a
//! deadline-cancelled trajectory is a prefix of the uncancelled one —
//! at any (shards P, threads) combination.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use dpfw::coordinator::scheduler::RetryPolicy;
use dpfw::coordinator::{Algo, Coordinator, JobError, JobSpec, PathJob};
use dpfw::dp::accounting::PrivacyParams;
use dpfw::fw::cancel::{CancelToken, StopReason};
use dpfw::fw::config::{FwConfig, SelectorKind};
use dpfw::fw::trace::TraceRecord;
use dpfw::sparse::synth::SynthConfig;
use dpfw::sparse::Dataset;
use dpfw::testkit::faults::{FaultKind, FaultPlan};

fn dataset(seed: u64) -> Arc<Dataset> {
    Arc::new(
        SynthConfig {
            name: format!("faults{seed}"),
            n_rows: 120,
            n_cols: 60,
            avg_row_nnz: 7.0,
            zipf_exponent: 1.2,
            n_informative: 10,
            n_dense: 0,
            label_noise: 0.02,
            bias_col: true,
        }
        .generate(seed),
    )
}

/// A DP job (Bsls selector) so the mechanism stream — the thing retries
/// must not double-spend — is actually exercised.
fn dp_cfg(seed: u64) -> FwConfig {
    FwConfig {
        iters: 80,
        lambda: 6.0,
        privacy: Some(PrivacyParams::new(1.0, 1e-6)),
        selector: SelectorKind::Bsls,
        seed,
        ..Default::default()
    }
}

fn job(id: usize, data: Arc<Dataset>, cfg: FwConfig) -> JobSpec {
    JobSpec { id, label: format!("f{id}"), data, algo: Algo::Fast, cfg, test_data: None }
}

/// Deterministic trace fields — everything but the wall clock.
fn trace_key(r: &TraceRecord) -> (usize, f64, u64, u64, u64, usize) {
    (r.iter, r.gap, r.flops, r.bytes, r.pops, r.selected)
}

// ---------------------------------------------------------------------------
// The fault matrix: every FaultKind × {1, 4} workers must complete drain()
// without a coordinator panic, with every owed id resolved Ok or Err.
// ---------------------------------------------------------------------------

#[test]
fn fault_matrix_drains_every_owed_id() {
    let d = dataset(1);
    for n_workers in [1usize, 4] {
        for kind in [
            FaultKind::PanicAt { iter: 5 },
            FaultKind::StallAt { iter: 5, ms: 10 },
            FaultKind::PoisonWorkspace,
            FaultKind::DieAbruptly,
        ] {
            let mut c = Coordinator::new(n_workers);
            let n_jobs = 6usize;
            for id in 0..n_jobs {
                let mut cfg = dp_cfg(7);
                if id == 0 {
                    cfg.fault = FaultPlan::once(kind);
                }
                c.submit(job(id, d.clone(), cfg));
            }
            let results = c.drain();
            assert_eq!(
                results.len(),
                n_jobs,
                "{kind:?} x {n_workers} workers: every owed id must resolve"
            );
            // id 0 carried the fault; its outcome shape depends on the kind
            match kind {
                FaultKind::PanicAt { .. } => {
                    assert!(
                        matches!(results[0], Err(JobError::Panicked(_))),
                        "{kind:?}: {:?}",
                        results[0].as_ref().err()
                    );
                }
                FaultKind::StallAt { .. } | FaultKind::PoisonWorkspace => {
                    assert!(results[0].is_ok(), "{kind:?} must not fail the job");
                }
                FaultKind::DieAbruptly => {
                    assert_eq!(results[0].as_ref().unwrap_err(), &JobError::WorkerDied);
                    assert!(
                        c.metrics.workers_respawned.load(Ordering::Relaxed) >= 1,
                        "supervisor must have respawned the dead worker"
                    );
                }
            }
            // every other job survives whatever happened to id 0
            for (id, r) in results.iter().enumerate().skip(1) {
                assert!(r.is_ok(), "{kind:?} x {n_workers}: job {id} lost: {r:?}");
            }
        }
    }
}

#[test]
fn poisoned_workspace_output_is_bit_identical_to_clean() {
    // The workspace-reuse contract: a correct solver fully reinitializes
    // every buffer it takes, so pre-scribbled pools must not change a bit.
    let d = dataset(2);
    let clean = job(0, d.clone(), dp_cfg(3)).run();
    let mut cfg = dp_cfg(3);
    cfg.fault = FaultPlan::once(FaultKind::PoisonWorkspace);
    let mut c = Coordinator::new(1);
    c.submit(job(0, d, cfg));
    let poisoned = c.drain().remove(0).expect("poisoned-workspace job must succeed");
    assert_eq!(poisoned.output.weights, clean.output.weights);
    assert_eq!(poisoned.output.flops, clean.output.flops);
}

// ---------------------------------------------------------------------------
// Worker death mid-queue: owed ids fail, the rest of the queue completes.
// ---------------------------------------------------------------------------

#[test]
fn worker_death_mid_queue_fails_owed_ids_and_respawns() {
    let d = dataset(4);
    let mut c = Coordinator::new(1); // one worker: the queue is strictly ordered
    let mut doomed = dp_cfg(5);
    doomed.fault = FaultPlan::once(FaultKind::DieAbruptly);
    c.submit(job(0, d.clone(), doomed));
    for id in 1..5 {
        c.submit(job(id, d.clone(), dp_cfg(5)));
    }
    let results = c.drain();
    assert_eq!(results.len(), 5);
    assert_eq!(results[0].as_ref().unwrap_err(), &JobError::WorkerDied);
    for r in &results[1..] {
        assert!(r.is_ok(), "respawned worker must finish the remaining queue");
    }
    assert_eq!(c.metrics.workers_respawned.load(Ordering::Relaxed), 1);
    assert_eq!(c.metrics.jobs_failed.load(Ordering::Relaxed), 1);

    // a whole path owed by the dead worker fails every λ, then the pool heals
    let mut doomed_path = dp_cfg(5);
    doomed_path.fault = FaultPlan::once(FaultKind::DieAbruptly);
    c.submit_path(PathJob {
        base_id: 0,
        label: "p".into(),
        data: d.clone(),
        algo: Algo::Fast,
        cfg: doomed_path,
        lambdas: vec![3.0, 6.0],
        test_data: None,
    });
    c.submit(job(2, d, dp_cfg(5)));
    let results = c.drain();
    assert_eq!(results.len(), 3);
    assert_eq!(results[0].as_ref().unwrap_err(), &JobError::WorkerDied);
    assert_eq!(results[1].as_ref().unwrap_err(), &JobError::WorkerDied);
    assert!(results[2].is_ok());
}

// ---------------------------------------------------------------------------
// Deadlines: queued → shed without solver work; running → anytime partial.
// ---------------------------------------------------------------------------

#[test]
fn deadline_expired_while_queued_is_shed_without_solver_work() {
    let d = dataset(6);
    let mut c = Coordinator::new(1);
    // occupy the single worker long enough for the second job's deadline
    // to lapse in the queue
    let mut slow = dp_cfg(8);
    slow.fault = FaultPlan::once(FaultKind::StallAt { iter: 1, ms: 120 });
    c.submit(job(0, d.clone(), slow));
    let mut doomed = dp_cfg(8);
    doomed.cancel = CancelToken::deadline_in(Duration::from_millis(20));
    c.submit(job(1, d, doomed));
    let results = c.drain();
    assert!(results[0].is_ok(), "the stalled job itself had no deadline");
    assert_eq!(results[1].as_ref().unwrap_err(), &JobError::Expired);
    assert_eq!(c.metrics.sheds.load(Ordering::Relaxed), 1);
    assert_eq!(c.metrics.jobs_failed.load(Ordering::Relaxed), 1);
    // shed ≠ timeout: no solver ran, so nothing stopped on a deadline
    assert_eq!(c.metrics.timeouts.load(Ordering::Relaxed), 0);
}

#[test]
fn cancelled_while_queued_is_shed() {
    let d = dataset(6);
    let mut c = Coordinator::new(1);
    let mut slow = dp_cfg(8);
    slow.fault = FaultPlan::once(FaultKind::StallAt { iter: 1, ms: 80 });
    c.submit(job(0, d.clone(), slow));
    let token = CancelToken::new();
    let mut doomed = dp_cfg(8);
    doomed.cancel = token.clone();
    c.submit(job(1, d, doomed));
    token.cancel(); // client hangs up while the job is still queued
    let results = c.drain();
    assert_eq!(results[1].as_ref().unwrap_err(), &JobError::Expired);
    assert_eq!(c.metrics.sheds.load(Ordering::Relaxed), 1);
}

#[test]
fn deadline_while_running_returns_anytime_partial_output() {
    let d = dataset(7);
    let mut cfg = dp_cfg(9);
    // stall inside iteration 5 past the deadline, so the t=6 poll fires
    cfg.fault = FaultPlan::once(FaultKind::StallAt { iter: 5, ms: 60 });
    cfg.cancel = CancelToken::deadline_in(Duration::from_millis(25));
    let mut c = Coordinator::new(1);
    c.submit(job(0, d, cfg.clone()));
    let results = c.drain();
    let r = results[0].as_ref().expect("a mid-run deadline is a partial Ok, not an Err");
    assert_eq!(r.output.stopped, StopReason::Deadline);
    assert!(
        r.output.iters_run < cfg.iters - 1,
        "must have stopped early: ran {} of {}",
        r.output.iters_run,
        cfg.iters - 1
    );
    assert!(r.output.weights.nnz() > 0, "best-so-far weights, not a blank");
    let spent = r.output.eps_spent.expect("DP run reports spend");
    let full = PrivacyParams::new(1.0, 1e-6).spent_epsilon(cfg.iters, cfg.iters - 1);
    assert!(spent < full, "truncated run must spend less: {spent} vs {full}");
    assert_eq!(c.metrics.timeouts.load(Ordering::Relaxed), 1);
    assert_eq!(c.metrics.sheds.load(Ordering::Relaxed), 0);
}

// ---------------------------------------------------------------------------
// Retries: exhaustion surfaces the last panic; success is bit-identical.
// ---------------------------------------------------------------------------

#[test]
fn retry_exhaustion_surfaces_last_panic_message() {
    let d = dataset(10);
    let mut cfg = dp_cfg(11);
    // fires on every attempt: 1 original + 2 retries, all panic
    cfg.fault = FaultPlan::times(FaultKind::PanicAt { iter: 2 }, 10);
    let mut c = Coordinator::with_retry(
        1,
        RetryPolicy { retry_limit: 2, backoff_base: Duration::from_millis(1) },
    );
    c.submit(job(0, d, cfg));
    let results = c.drain();
    match results[0].as_ref().unwrap_err() {
        JobError::RetriesExhausted { attempts, last } => {
            assert_eq!(*attempts, 3);
            assert!(last.contains("iteration 2"), "last panic message lost: {last}");
        }
        other => panic!("expected RetriesExhausted, got {other:?}"),
    }
    assert_eq!(c.metrics.retries.load(Ordering::Relaxed), 2);
    assert_eq!(c.metrics.jobs_failed.load(Ordering::Relaxed), 1);
}

/// The privacy-critical property (§6.9): a retried job reuses its original
/// seed, so the successful attempt's mechanism stream — weights, trace,
/// and ε spend — is bit-identical to a run that never failed. Swept over
/// shard counts P and thread counts, both solvers' engines.
#[test]
fn seed_pinned_retry_is_bit_identical_to_unfaulted_run() {
    let d = dataset(12);
    for shards in [None, Some(1), Some(3)] {
        for threads in [1usize, 4] {
            for algo in [Algo::Fast, Algo::Standard] {
                let mut base = dp_cfg(13);
                base.shards = shards;
                base.threads = threads;
                base.trace_every = 1;
                let clean = JobSpec {
                    id: 0,
                    label: "clean".into(),
                    data: d.clone(),
                    algo,
                    cfg: base.clone(),
                    test_data: None,
                }
                .run();

                let mut faulted = base.clone();
                // one panic mid-run; the shared firing budget is spent, so
                // the in-place retry (same seed, same worker) runs clean
                faulted.fault = FaultPlan::once(FaultKind::PanicAt { iter: 7 });
                let mut c = Coordinator::with_retry(
                    1,
                    RetryPolicy { retry_limit: 1, backoff_base: Duration::from_millis(1) },
                );
                c.submit(JobSpec {
                    id: 0,
                    label: "retried".into(),
                    data: d.clone(),
                    algo,
                    cfg: faulted,
                    test_data: None,
                });
                let results = c.drain();
                let retried = results[0]
                    .as_ref()
                    .unwrap_or_else(|e| panic!("P={shards:?} threads={threads}: {e}"));
                assert_eq!(c.metrics.retries.load(Ordering::Relaxed), 1);

                let ctx = format!("P={shards:?} threads={threads} algo={algo:?}");
                assert_eq!(
                    retried.output.weights, clean.output.weights,
                    "{ctx}: retry diverged from first-attempt stream"
                );
                assert_eq!(
                    retried.output.trace.len(),
                    clean.output.trace.len(),
                    "{ctx}: trace length"
                );
                for (a, b) in retried.output.trace.iter().zip(&clean.output.trace) {
                    assert_eq!(trace_key(a), trace_key(b), "{ctx}: trace diverged");
                }
                assert_eq!(
                    retried.output.eps_spent, clean.output.eps_spent,
                    "{ctx}: a retry must not change the privacy spend"
                );
            }
        }
    }
}

/// The anytime property (§6.9): stopping on a deadline yields a trajectory
/// that is a *prefix* of the uncancelled run's — same selections, same
/// gaps, same FLOP counts, just fewer of them. Swept over (P, threads).
#[test]
fn deadline_cancelled_trajectory_is_prefix_of_uncancelled() {
    let d = dataset(14);
    for shards in [None, Some(2)] {
        for threads in [1usize, 2] {
            let mut base = dp_cfg(15);
            base.shards = shards;
            base.threads = threads;
            base.trace_every = 1;
            let full = JobSpec {
                id: 0,
                label: "full".into(),
                data: d.clone(),
                algo: Algo::Fast,
                cfg: base.clone(),
                test_data: None,
            }
            .run();

            let mut cut = base.clone();
            // stall through the deadline mid-run so the stop fires at
            // whatever iteration the clock says — the property must hold
            // for any k, so the test doesn't pin one
            cut.fault = FaultPlan::once(FaultKind::StallAt { iter: 6, ms: 40 });
            cut.cancel = CancelToken::deadline_in(Duration::from_millis(15));
            let partial = JobSpec {
                id: 0,
                label: "cut".into(),
                data: d.clone(),
                algo: Algo::Fast,
                cfg: cut,
                test_data: None,
            }
            .run();

            let ctx = format!("P={shards:?} threads={threads}");
            assert_eq!(partial.output.stopped, StopReason::Deadline, "{ctx}");
            assert!(
                partial.output.iters_run < full.output.iters_run,
                "{ctx}: expected a truncated run"
            );
            // drop each run's post-loop summary record (a duplicate of its
            // last in-loop point): everything before it must match the
            // uncancelled run point-for-point
            let n = partial.output.trace.len().saturating_sub(1);
            assert!(n > 0, "{ctx}: expected some completed iterations before the stop");
            for i in 0..n {
                assert_eq!(
                    trace_key(&partial.output.trace[i]),
                    trace_key(&full.output.trace[i]),
                    "{ctx}: trajectory diverged at trace index {i}"
                );
            }
            // ε monotonicity: the prefix spends strictly less
            assert!(partial.output.eps_spent.unwrap() < full.output.eps_spent.unwrap(), "{ctx}");
        }
    }
}

// ---------------------------------------------------------------------------
// Explicit cancellation from another thread while the solve is running.
// ---------------------------------------------------------------------------

#[test]
fn cross_thread_cancel_stops_a_running_solve() {
    let d = dataset(16);
    let token = CancelToken::new();
    let mut cfg = dp_cfg(17);
    cfg.iters = 100_000; // far more than fits in the stall window
    cfg.fault = FaultPlan::once(FaultKind::StallAt { iter: 3, ms: 50 });
    cfg.cancel = token.clone();
    let mut c = Coordinator::new(1);
    c.submit(job(0, d, cfg));
    std::thread::sleep(Duration::from_millis(10)); // let the stall start
    token.cancel();
    let results = c.drain();
    let r = results[0].as_ref().expect("cancel is a partial Ok");
    assert_eq!(r.output.stopped, StopReason::Cancelled);
    assert!(r.output.iters_run < 99_999);
}
