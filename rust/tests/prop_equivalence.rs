//! Property tests (randomized invariants over many seeded cases) for the
//! paper's core equivalence and correctness claims. `proptest` is not in
//! the offline crate set; `dpfw::testkit::forall` provides seeded
//! generation with failing-seed replay (`DPFW_PROP_SEED=<seed>`).

use dpfw::dp::accounting::PrivacyParams;
use dpfw::fw::config::{FwConfig, SelectorKind};
use dpfw::fw::fast::FastFrankWolfe;
use dpfw::fw::standard::StandardFrankWolfe;
use dpfw::fw::trace::FwOutput;
use dpfw::fw::workspace::FwWorkspace;
use dpfw::heap::binary::IndexedBinaryHeap;
use dpfw::heap::fibonacci::FibonacciHeap;
use dpfw::heap::DecreaseKeyHeap;
use dpfw::rng::Xoshiro256pp;
use dpfw::sampler::bsls::BslsSampler;
use dpfw::sampler::{log_sum_exp, WeightedSampler};
use dpfw::sparse::synth::SynthConfig;
use dpfw::sparse::Dataset;
use dpfw::testkit::{assert_close, assert_slices_close, forall};

fn random_dataset(rng: &mut Xoshiro256pp) -> Dataset {
    let n_rows = 40 + rng.next_below(160) as usize;
    let n_cols = 30 + rng.next_below(300) as usize;
    SynthConfig {
        name: "prop".into(),
        n_rows,
        n_cols,
        avg_row_nnz: 3.0 + rng.next_f64() * 12.0,
        zipf_exponent: 1.05 + rng.next_f64() * 0.5,
        n_informative: 8 + rng.next_below(16) as usize,
        n_dense: if rng.next_below(3) == 0 { 4 } else { 0 },
        label_noise: rng.next_f64() * 0.1,
        bias_col: rng.next_below(2) == 0,
    }
    .generate(rng.next_u64())
}

/// Like [`random_dataset`] but with nnz safely above `PAR_MIN_NNZ`, so the
/// in-kernel serial-fallback gate (moved inside the `_par` entry points in
/// PR 4) does not serialize the run: tests that claim thread coverage must
/// use this at least part of the time or they compare serial to serial.
fn big_dataset(rng: &mut Xoshiro256pp) -> Dataset {
    let ds = SynthConfig {
        name: "prop-big".into(),
        n_rows: 3000 + rng.next_below(400) as usize,
        n_cols: 400 + rng.next_below(300) as usize,
        avg_row_nnz: 14.0 + rng.next_f64() * 4.0,
        zipf_exponent: 1.05 + rng.next_f64() * 0.5,
        n_informative: 8 + rng.next_below(16) as usize,
        n_dense: if rng.next_below(3) == 0 { 4 } else { 0 },
        label_noise: rng.next_f64() * 0.1,
        bias_col: rng.next_below(2) == 0,
    }
    .generate(rng.next_u64());
    assert!(ds.nnz() >= dpfw::sparse::PAR_MIN_NNZ, "fixture must clear the gate");
    ds
}

/// Alg 2's maintained state equals a dense recompute of its own stored
/// quantities after every iteration, for random datasets/configs.
#[test]
fn prop_fast_state_invariants() {
    forall(12, |rng| {
        let ds = random_dataset(rng);
        let lam = 1.0 + rng.next_f64() * 30.0;
        let iters = 20 + rng.next_below(80) as usize;
        let cfg = FwConfig { iters, lambda: lam, ..Default::default() };
        // The observer hook is crate-private; validate through outputs:
        // run twice (determinism) and check feasibility + gap consistency.
        let out = FastFrankWolfe::new(&ds, cfg.clone()).run();
        let out2 = FastFrankWolfe::new(&ds, cfg).run();
        assert_eq!(out.weights, out2.weights, "nondeterministic run");
        assert!(out.weights.l1_norm() <= lam + 1e-6, "left the L1 ball");
        assert!(out.weights.nnz() <= iters, "more nonzeros than iterations");
        // reported final gap must equal the gap recomputed from the final
        // trace entry
        let last = out.trace.last().unwrap();
        assert_close(last.gap, out.final_gap, 1e-12, 1e-12);
    });
}

/// On dense-column data (every row refreshed every iteration) Alg 2 must
/// track Alg 1 exactly — the paper's mathematical-equivalence claim in the
/// regime where the lazy gradient cache is always fresh.
#[test]
fn prop_dense_data_exact_equivalence() {
    forall(8, |rng| {
        let n_cols = 8 + rng.next_below(24) as usize;
        let ds = SynthConfig {
            name: "dense".into(),
            n_rows: 30 + rng.next_below(60) as usize,
            n_cols,
            avg_row_nnz: n_cols as f64,
            zipf_exponent: 1.2,
            n_informative: 4,
            n_dense: n_cols, // all columns dense
            label_noise: 0.05,
            bias_col: false,
        }
        .generate(rng.next_u64());
        let cfg = FwConfig {
            iters: 30 + rng.next_below(120) as usize,
            lambda: 1.0 + rng.next_f64() * 10.0,
            trace_every: 1,
            ..Default::default()
        };
        let fast = FastFrankWolfe::new(&ds, cfg.clone()).run();
        let std_ = StandardFrankWolfe::new(&ds, cfg).run();
        assert_slices_close(fast.weights.as_slice(), std_.weights.as_slice(), 1e-6, 1e-9);
        for (a, b) in fast.trace.iter().zip(&std_.trace) {
            if a.selected != usize::MAX {
                assert_eq!(a.selected, b.selected, "selection diverged at t={}", a.iter);
            }
            // post-fusion the incrementally maintained gap must still track
            // Alg 1's densely recomputed one
            assert!(
                (a.gap - b.gap).abs() < 1e-6 * (1.0 + b.gap.abs()),
                "gap diverged at t={}: fast {} vs std {}",
                a.iter,
                a.gap,
                b.gap
            );
        }
    });
}

/// Heap-backed queue maintenance (Alg 3) must agree exactly with the
/// argmax selector inside Alg 2, on both heap implementations.
#[test]
fn prop_heap_selectors_equal_argmax() {
    forall(10, |rng| {
        let ds = random_dataset(rng);
        let cfg = FwConfig {
            iters: 20 + rng.next_below(100) as usize,
            lambda: 1.0 + rng.next_f64() * 20.0,
            ..Default::default()
        };
        let am = FastFrankWolfe::new(&ds, cfg.clone()).run();
        for sel in [SelectorKind::FibHeap, SelectorKind::BinHeap] {
            let h = FastFrankWolfe::new(&ds, FwConfig { selector: sel, ..cfg.clone() }).run();
            assert_slices_close(am.weights.as_slice(), h.weights.as_slice(), 1e-9, 1e-12);
        }
    });
}

/// Both heaps pop identical key sequences under identical random
/// workloads (differential test at the substrate level).
#[test]
fn prop_heaps_agree() {
    forall(20, |rng| {
        let n = 10 + rng.next_below(100) as usize;
        let mut fib = FibonacciHeap::with_capacity(n);
        let mut bin = IndexedBinaryHeap::with_capacity(n);
        let mut present = vec![false; n];
        for _ in 0..600 {
            match rng.next_below(6) {
                0..=2 => {
                    let item = rng.next_below(n as u64) as usize;
                    if !present[item] {
                        let key = rng.next_f64();
                        fib.push(item, key);
                        bin.push(item, key);
                        present[item] = true;
                    }
                }
                3 => {
                    let item = rng.next_below(n as u64) as usize;
                    if present[item] {
                        let nk = bin.key_of(item).unwrap() - rng.next_f64();
                        fib.decrease_key(item, nk);
                        bin.decrease_key(item, nk);
                    }
                }
                _ => {
                    let a = fib.pop_min();
                    let b = bin.pop_min();
                    match (a, b) {
                        (None, None) => {}
                        (Some((ia, ka)), Some((_, kb))) => {
                            assert_eq!(ka, kb, "popped keys diverged");
                            present[ia] = false;
                        }
                        other => panic!("divergence: {other:?}"),
                    }
                }
            }
            assert_eq!(fib.len(), bin.len());
        }
    });
}

/// The BSLS sampler's log-total must track the exact log-sum-exp of its
/// weights through arbitrary update storms (numerical-drift invariant).
#[test]
fn prop_bsls_log_total_exact() {
    forall(15, |rng| {
        let d = 2 + rng.next_below(300) as usize;
        let mut s = BslsSampler::new(d, 0.0);
        let mut w = vec![0.0f64; d];
        for _ in 0..2000 {
            let j = rng.next_below(d as u64) as usize;
            w[j] = (rng.next_f64() - 0.5) * 40.0;
            s.update(j, w[j]);
        }
        assert_close(s.log_total(), log_sum_exp(&w), 1e-7, 1e-7);
    });
}

/// The BSLS sampler and the exact inverse-CDF agree in distribution: the
/// empirical frequency of the *modal* item matches its true probability.
#[test]
fn prop_bsls_modal_probability() {
    forall(6, |rng| {
        let d = 16 + rng.next_below(64) as usize;
        let mut s = BslsSampler::new(d, 0.0);
        let mut w = vec![0.0f64; d];
        for (j, wj) in w.iter_mut().enumerate() {
            *wj = rng.next_f64() * 3.0;
            s.update(j, *wj);
        }
        let z = log_sum_exp(&w);
        let modal = (0..d).max_by(|&a, &b| w[a].partial_cmp(&w[b]).unwrap()).unwrap();
        let p_true = (w[modal] - z).exp();
        let trials = 30_000;
        let mut hits = 0;
        for _ in 0..trials {
            hits += (s.sample(rng) == modal) as usize;
        }
        let p_emp = hits as f64 / trials as f64;
        assert!(
            (p_emp - p_true).abs() < 0.02 + 3.0 * (p_true * (1.0 - p_true) / trials as f64).sqrt(),
            "modal prob: emp {p_emp} vs true {p_true}"
        );
    });
}

/// DP runs are deterministic given a seed and differ across seeds
/// (mechanism noise must come only from the seeded generator).
#[test]
fn prop_dp_seed_determinism() {
    forall(6, |rng| {
        let ds = random_dataset(rng);
        let seed = rng.next_u64();
        let mk = |s: u64, sel: SelectorKind| FwConfig {
            iters: 60,
            lambda: 5.0,
            privacy: Some(PrivacyParams::new(1.0, 1e-6)),
            selector: sel,
            seed: s,
            trace_every: 0,
            ..Default::default()
        };
        for sel in [SelectorKind::Bsls, SelectorKind::NoisyMax, SelectorKind::NaiveExp] {
            let a = FastFrankWolfe::new(&ds, mk(seed, sel)).run();
            let b = FastFrankWolfe::new(&ds, mk(seed, sel)).run();
            assert_eq!(a.weights, b.weights, "{sel:?} nondeterministic");
            let c = FastFrankWolfe::new(&ds, mk(seed ^ 0x1234, sel)).run();
            // different seed should (almost surely) change the trajectory
            if a.weights == c.weights {
                // tolerate rare coincidences on tiny problems
                assert!(ds.n_cols() < 40, "{sel:?} ignored the seed");
            }
        }
    });
}

/// Bit-level output equality (stricter than `==`, which would conflate
/// `0.0` and `-0.0`) for everything *except* the byte-traffic accounting:
/// weights, final gap, FLOPs, selector telemetry, and the full trace
/// except wall-clock. Split out so the compact-vs-u32 substrate test can
/// assert trajectory identity while byte totals legitimately differ.
fn assert_outputs_bit_identical_modulo_traffic(a: &FwOutput, b: &FwOutput, what: &str) {
    assert_eq!(a.weights.dim(), b.weights.dim(), "{what}: dim");
    for (i, (x, y)) in a.weights.as_slice().iter().zip(b.weights.as_slice()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: weight {i} differs: {x} vs {y}");
    }
    assert_eq!(a.final_gap.to_bits(), b.final_gap.to_bits(), "{what}: final gap");
    assert_eq!(a.flops, b.flops, "{what}: flops");
    assert_eq!(a.bootstrap_flops, b.bootstrap_flops, "{what}: bootstrap flops");
    assert_eq!(a.selector_stats, b.selector_stats, "{what}: selector stats");
    assert_eq!(a.trace.len(), b.trace.len(), "{what}: trace length");
    for (ta, tb) in a.trace.iter().zip(&b.trace) {
        assert_eq!(ta.iter, tb.iter, "{what}: trace iter");
        assert_eq!(ta.selected, tb.selected, "{what}: trace selection");
        assert_eq!(ta.gap.to_bits(), tb.gap.to_bits(), "{what}: trace gap");
        assert_eq!(ta.flops, tb.flops, "{what}: trace flops");
    }
}

/// Full bit-level equality: the modulo-traffic check plus identical byte
/// accounting — DRAM model, L1 scratch round-trips, and the §6.7
/// dispatcher split (same substrate and threshold on both sides).
fn assert_outputs_bit_identical(a: &FwOutput, b: &FwOutput, what: &str) {
    assert_outputs_bit_identical_modulo_traffic(a, b, what);
    assert_eq!(a.bytes_moved, b.bytes_moved, "{what}: bytes moved");
    assert_eq!(a.bootstrap_bytes, b.bootstrap_bytes, "{what}: bootstrap bytes");
    assert_eq!(a.scratch_bytes, b.scratch_bytes, "{what}: scratch bytes");
    assert_eq!(a.direct_segments, b.direct_segments, "{what}: direct segments");
    assert_eq!(a.scratch_segments, b.scratch_segments, "{what}: scratch segments");
    for (ta, tb) in a.trace.iter().zip(&b.trace) {
        assert_eq!(ta.bytes, tb.bytes, "{what}: trace bytes");
    }
}

fn random_selector_cfg(rng: &mut Xoshiro256pp, iters: usize, lam: f64) -> FwConfig {
    let selectors = [
        SelectorKind::Argmax,
        SelectorKind::FibHeap,
        SelectorKind::BinHeap,
        SelectorKind::Bsls,
        SelectorKind::NoisyMax,
        SelectorKind::NaiveExp,
    ];
    let sel = selectors[rng.next_below(selectors.len() as u64) as usize];
    FwConfig {
        iters,
        lambda: lam,
        privacy: sel.is_private().then(|| PrivacyParams::new(0.5 + rng.next_f64(), 1e-6)),
        selector: sel,
        seed: rng.next_u64(),
        trace_every: 10,
        ..Default::default()
    }
}

/// **Workspace reuse is bit-exact**: `run_in` on a dirty workspace — one
/// that just executed a *different* dataset/selector/shape — produces
/// output identical to a fresh `run`, for both solvers. This is the
/// contract that makes the coordinator's per-worker workspaces and the
/// warm-bench series trustworthy.
#[test]
fn prop_workspace_reuse_bit_identical() {
    forall(8, |rng| {
        let mut ws = FwWorkspace::new();
        // three back-to-back runs through the same workspace, each with a
        // fresh dataset and random selector: every run after the first
        // sees dirty buffers and (sometimes) a cached selector
        for round in 0..3 {
            let ds = random_dataset(rng);
            let iters = 20 + rng.next_below(60) as usize;
            let cfg = random_selector_cfg(rng, iters, 1.0 + rng.next_f64() * 10.0);
            let fresh = FastFrankWolfe::new(&ds, cfg.clone()).run();
            let reused = FastFrankWolfe::new(&ds, cfg.clone()).run_in(&mut ws);
            assert_outputs_bit_identical(&fresh, &reused, &format!("fast round {round}"));
            if !matches!(cfg.selector, SelectorKind::FibHeap | SelectorKind::BinHeap) {
                let fresh_s = StandardFrankWolfe::new(&ds, cfg.clone()).run();
                let reused_s = StandardFrankWolfe::new(&ds, cfg).run_in(&mut ws);
                assert_outputs_bit_identical(
                    &fresh_s,
                    &reused_s,
                    &format!("standard round {round}"),
                );
            }
        }
    });
}

/// A `run_path` output must equal an independent fresh run at the same λ
/// bit-for-bit, except that every cumulative FLOP count is lower by
/// exactly the bootstrap work the warm run skipped (zero for a cold one).
fn assert_path_output_matches(fresh: &FwOutput, warm: &FwOutput, what: &str) {
    assert!(
        fresh.bootstrap_flops >= warm.bootstrap_flops,
        "{what}: a path run cannot do more bootstrap work than a fresh one"
    );
    let offset = fresh.bootstrap_flops - warm.bootstrap_flops;
    assert_eq!(fresh.weights.dim(), warm.weights.dim(), "{what}: dim");
    let pairs = fresh.weights.as_slice().iter().zip(warm.weights.as_slice());
    for (i, (x, y)) in pairs.enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: weight {i} differs: {x} vs {y}");
    }
    assert_eq!(fresh.final_gap.to_bits(), warm.final_gap.to_bits(), "{what}: final gap");
    assert_eq!(warm.flops + offset, fresh.flops, "{what}: flops modulo bootstrap");
    // byte traffic obeys the identical warm-run contract
    assert!(
        fresh.bootstrap_bytes >= warm.bootstrap_bytes,
        "{what}: warm bootstrap bytes exceed fresh"
    );
    let boffset = fresh.bootstrap_bytes - warm.bootstrap_bytes;
    assert_eq!(warm.bytes_moved + boffset, fresh.bytes_moved, "{what}: bytes modulo bootstrap");
    // the §6.7 iteration-tier split excludes the bootstrap entirely, so a
    // warm run must match a fresh one exactly — no offset
    assert_eq!(fresh.scratch_bytes, warm.scratch_bytes, "{what}: scratch bytes");
    assert_eq!(fresh.direct_segments, warm.direct_segments, "{what}: direct segments");
    assert_eq!(fresh.scratch_segments, warm.scratch_segments, "{what}: scratch segments");
    assert_eq!(fresh.selector_stats, warm.selector_stats, "{what}: selector stats");
    assert_eq!(fresh.trace.len(), warm.trace.len(), "{what}: trace length");
    for (ta, tb) in fresh.trace.iter().zip(&warm.trace) {
        assert_eq!(ta.iter, tb.iter, "{what}: trace iter");
        assert_eq!(ta.selected, tb.selected, "{what}: trace selection");
        assert_eq!(ta.gap.to_bits(), tb.gap.to_bits(), "{what}: trace gap");
        assert_eq!(tb.flops + offset, ta.flops, "{what}: trace flops modulo bootstrap");
        assert_eq!(tb.bytes + boffset, ta.bytes, "{what}: trace bytes modulo bootstrap");
    }
}

/// **The path engine is a pure amortization**: for λ grids of length
/// {1, 3, 7}, every `run_path` output is bit-identical to the
/// corresponding independent `run` with a fresh workspace (modulo the
/// skipped-bootstrap FLOP offset, which the helper pins down exactly), on
/// both solvers and across random selectors. Exactly one bootstrap is
/// performed per (workspace, dataset): the first fast λ is cold, every
/// later λ — and the standard solver's whole path, which reuses the fast
/// solver's cached bootstrap through the same workspace — records zero
/// bootstrap FLOPs.
#[test]
fn prop_run_path_bit_identical_and_single_bootstrap() {
    forall(6, |rng| {
        let ds = random_dataset(rng);
        let iters = 20 + rng.next_below(60) as usize;
        let base = random_selector_cfg(rng, iters, 1.0 + rng.next_f64() * 10.0);
        for k in [1usize, 3, 7] {
            let lambdas: Vec<f64> =
                (0..k).map(|i| 1.0 + i as f64 + rng.next_f64() * 3.0).collect();
            let mut ws = FwWorkspace::new();
            let outs = FastFrankWolfe::new(&ds, base.clone()).run_path(&lambdas, &mut ws);
            assert_eq!(outs.len(), k);
            assert!(outs[0].bootstrap_flops > 0, "first λ must be the one cold bootstrap");
            assert!(
                outs[1..].iter().all(|o| o.bootstrap_flops == 0),
                "warm λ solves must do zero bootstrap work"
            );
            for (i, (out, &lam)) in outs.iter().zip(&lambdas).enumerate() {
                let fresh =
                    FastFrankWolfe::new(&ds, FwConfig { lambda: lam, ..base.clone() }).run();
                assert_path_output_matches(&fresh, out, &format!("fast k={k} i={i}"));
            }
            if !matches!(base.selector, SelectorKind::FibHeap | SelectorKind::BinHeap) {
                // same workspace, same dataset+loss: the standard solver's
                // t = 1 dense recompute is served entirely from the cache
                // the fast path just populated (cross-solver sharing is
                // bit-safe because the CSC- and CSR-driven α₀ agree
                // bitwise — property-tested in sparse::csc).
                let outs =
                    StandardFrankWolfe::new(&ds, base.clone()).run_path(&lambdas, &mut ws);
                assert!(outs.iter().all(|o| o.bootstrap_flops == 0));
                for (i, (out, &lam)) in outs.iter().zip(&lambdas).enumerate() {
                    let fresh =
                        StandardFrankWolfe::new(&ds, FwConfig { lambda: lam, ..base.clone() })
                            .run();
                    assert_path_output_matches(&fresh, out, &format!("std k={k} i={i}"));
                }
            }
        }
    });
}

/// **Single-read CSC scatter**: the cursor-based `from_csr_threaded` must
/// produce a layout-identical matrix to the serial counting sort at any
/// thread count, on ragged/empty-column inputs.
#[test]
fn prop_csc_threaded_scatter_layout_identical() {
    use dpfw::sparse::csc::CscMatrix;
    forall(6, |rng| {
        // small datasets exercise the in-kernel PAR_MIN_NNZ gate; big ones
        // clear it, so the parallel scatter genuinely runs
        for big in [false, true] {
            let ds = if big { big_dataset(rng) } else { random_dataset(rng) };
            let serial = CscMatrix::from_csr(&ds.csr);
            for threads in [1usize, 4, 16] {
                assert_eq!(
                    CscMatrix::from_csr_threaded(&ds.csr, threads),
                    serial,
                    "big={big} threads={threads}"
                );
            }
        }
    });
}

/// **Compact u16-delta substrate is trajectory-invisible** (the DESIGN.md
/// §6.6 zero-tolerance guarantee): for random datasets, selectors, dirty
/// workspaces, and threads ∈ {1, 4, 16}, a run on the compact index
/// substrate is bit-identical to the same run on the stripped u32
/// substrate — weights, gaps, FLOPs, selector telemetry, traces — while
/// moving strictly fewer modeled bytes. Both solvers.
#[test]
fn prop_compact_substrate_bit_identical_to_u32() {
    forall(4, |rng| {
        // one below-gate and one above-gate dataset per case, so the
        // threads ∈ {4, 16} legs genuinely exercise the parallel
        // bootstrap on the compact substrate
        for big in [false, true] {
            let ds = if big { big_dataset(rng) } else { random_dataset(rng) };
            assert_eq!(ds.index_kind(), "u16-delta", "small-delta synth must qualify");
            let mut plain = ds.clone();
            plain.strip_compact();
            assert_eq!(plain.index_kind(), "u32");
            // shared (dirty) workspaces across rounds, one per substrate
            let mut ws_c = FwWorkspace::new();
            let mut ws_p = FwWorkspace::new();
            for round in 0..2 {
                let iters = 20 + rng.next_below(60) as usize;
                let base = random_selector_cfg(rng, iters, 1.0 + rng.next_f64() * 10.0);
                for threads in [1usize, 4, 16] {
                    let cfg = FwConfig { threads, ..base.clone() };
                    let what = format!("fast big={big} round {round} threads {threads}");
                    let a = FastFrankWolfe::new(&ds, cfg.clone()).run_in(&mut ws_c);
                    let b = FastFrankWolfe::new(&plain, cfg.clone()).run_in(&mut ws_p);
                    assert_outputs_bit_identical_modulo_traffic(&a, &b, &what);
                    assert!(
                        a.bytes_moved < b.bytes_moved,
                        "{what}: compact must move fewer bytes ({} vs {})",
                        a.bytes_moved,
                        b.bytes_moved
                    );
                    if !matches!(cfg.selector, SelectorKind::FibHeap | SelectorKind::BinHeap) {
                        let what = format!("std big={big} round {round} threads {threads}");
                        let a = StandardFrankWolfe::new(&ds, cfg.clone()).run_in(&mut ws_c);
                        let b = StandardFrankWolfe::new(&plain, cfg).run_in(&mut ws_p);
                        assert_outputs_bit_identical_modulo_traffic(&a, &b, &what);
                        assert!(a.bytes_moved < b.bytes_moved, "{what}: bytes not reduced");
                    }
                }
            }
        }
    });
}

/// **Thread-count invariance**: the block-parallel bootstrap (and the
/// parallel CSC build underneath `Dataset::new`) must produce bit-identical
/// runs for `threads ∈ {1, 4}` — parallelism may only change who computes
/// each value, never the value.
#[test]
fn prop_parallel_bootstrap_thread_invariant() {
    forall(6, |rng| {
        // alternate below-gate (gate path) and above-gate (genuinely
        // parallel bootstrap + CSC build) datasets
        for big in [false, true] {
            let ds = if big { big_dataset(rng) } else { random_dataset(rng) };
            let iters = 20 + rng.next_below(60) as usize;
            let base = random_selector_cfg(rng, iters, 1.0 + rng.next_f64() * 10.0);
            let serial = FastFrankWolfe::new(&ds, FwConfig { threads: 1, ..base.clone() }).run();
            for threads in [4usize, 16] {
                let par =
                    FastFrankWolfe::new(&ds, FwConfig { threads, ..base.clone() }).run();
                assert_outputs_bit_identical(&serial, &par, &format!("big={big} t={threads}"));
            }
            // auto (0) resolves to available parallelism — still identical
            let auto = FastFrankWolfe::new(&ds, FwConfig { threads: 0, ..base }).run();
            assert_outputs_bit_identical(&serial, &auto, &format!("big={big} t=auto"));
        }
    });
}

/// **Fused vs. scratch vs. u32 at kernel granularity** (§6.7): for random
/// segments — every tail length `n mod 4`, deltas mostly small with
/// escape-sized (≥ 2¹⁶) jumps mixed in — the direct-decode kernels, the
/// decode-to-scratch pairing, and the raw `u32` gather produce
/// bit-identical dots, AXPYs, and update+touch effects (values, stamps,
/// and touched order).
#[test]
fn prop_fused_scratch_u32_kernels_bit_identical() {
    use dpfw::fw::scan::{self, ScanKernel};
    use dpfw::sparse::compact::{CompactIndices, IndexSeg};
    forall(30, |rng| {
        let n = rng.next_below(120) as usize;
        let mut idx = Vec::with_capacity(n);
        let mut j = 0u32;
        for _ in 0..n {
            j += if rng.next_below(8) == 0 {
                65_536 + rng.next_below(5_000) as u32 // forces an escape block
            } else {
                1 + rng.next_below(9) as u32
            };
            idx.push(j);
        }
        let vals: Vec<f32> = (0..n).map(|_| (rng.next_f64() * 4.0 - 2.0) as f32).collect();
        let dim = idx.last().map_or(1, |&m| m as usize + 1);
        let w: Vec<f64> = (0..dim).map(|k| (k as f64 * 0.37).sin()).collect();
        let indptr = [0usize, n];
        let Some(c) = CompactIndices::build(&indptr, &idx) else {
            return; // an escape-heavy draw failed the qualifier: skip
        };
        let seg16 = IndexSeg::U16 { words: c.seg_words(0), nnz: n };
        let seg32 = IndexSeg::U32(&idx);
        let fused = ScanKernel::with_threshold(usize::MAX);
        let scratchy = ScanKernel::with_threshold(0);
        let mut scratch = Vec::new();

        let want = scan::dot_gather(&idx, &vals, &w);
        for (k, what) in [(fused, "fused"), (scratchy, "scratch")] {
            assert_eq!(k.dot(seg16, &vals, &w, &mut scratch).to_bits(), want.to_bits(), "{what} dot");
            assert_eq!(k.dot(seg32, &vals, &w, &mut scratch).to_bits(), want.to_bits(), "u32 dot");
        }

        let mut out_ref = w.clone();
        scan::axpy_gather(&idx, &vals, 1.3, &mut out_ref);
        for (k, what) in [(fused, "fused"), (scratchy, "scratch")] {
            let mut out = w.clone();
            k.axpy(seg16, &vals, 1.3, &mut out, &mut scratch);
            for (s, (x, y)) in out_ref.iter().zip(&out).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "{what} axpy slot {s}");
            }
        }

        let (mut al_ref, mut st_ref, mut t_ref) = (vec![0.0f64; dim], vec![0u32; dim], Vec::new());
        scan::update_touch(&idx, &vals, -0.57, &mut al_ref, &mut st_ref, 3, &mut t_ref);
        for (k, what) in [(fused, "fused"), (scratchy, "scratch")] {
            let (mut al, mut stp, mut tch) = (vec![0.0f64; dim], vec![0u32; dim], Vec::new());
            k.update_touch(seg16, &vals, -0.57, &mut al, &mut stp, 3, &mut tch, &mut scratch);
            assert_eq!(t_ref, tch, "{what} touched order");
            assert_eq!(st_ref, stp, "{what} stamps");
            for (s, (x, y)) in al_ref.iter().zip(&al).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "{what} alpha slot {s}");
            }
        }
    });
}

/// **The §6.7 dispatcher threshold is trajectory-invisible**: sweeping
/// `direct_max_nnz` over {0, 8, default, ∞} changes which kernel arm runs
/// each compact segment — nothing else. Weights, gaps, FLOPs, selector
/// telemetry, traces, and the DRAM byte model are bit-identical; only the
/// L1 scratch category and the direct/scratch split move, with the total
/// count of scanned compact segments invariant. Both solvers.
#[test]
fn prop_direct_dispatcher_threshold_invisible() {
    forall(5, |rng| {
        let ds = random_dataset(rng);
        assert_eq!(ds.index_kind(), "u16-delta");
        let iters = 20 + rng.next_below(60) as usize;
        let base = random_selector_cfg(rng, iters, 1.0 + rng.next_f64() * 10.0);
        let run_at = |thr: Option<usize>| {
            FastFrankWolfe::new(&ds, FwConfig { direct_max_nnz: thr, ..base.clone() }).run()
        };
        let all_scratch = run_at(Some(0));
        let all_fused = run_at(Some(usize::MAX));
        let thr8 = run_at(Some(8));
        let default = run_at(None);
        for (out, what) in [(&all_fused, "fused"), (&thr8, "thr=8"), (&default, "default")] {
            assert_outputs_bit_identical_modulo_traffic(&all_scratch, out, what);
            assert_eq!(all_scratch.bytes_moved, out.bytes_moved, "{what}: DRAM model moved");
            assert_eq!(
                all_scratch.direct_segments + all_scratch.scratch_segments,
                out.direct_segments + out.scratch_segments,
                "{what}: total scanned compact segments must be threshold-invariant"
            );
        }
        // the extremes pin the split: threshold 0 never fuses, ∞ never
        // touches the scratch — and fused total modeled traffic can only
        // be lower (the CI smoke invariant at property scale)
        assert_eq!(all_scratch.direct_segments, 0, "thr=0 must not fuse");
        assert_eq!(all_fused.scratch_segments, 0, "thr=∞ must not use scratch");
        assert_eq!(all_fused.scratch_bytes, 0);
        assert!(
            all_fused.bytes_moved + all_fused.scratch_bytes
                <= all_scratch.bytes_moved + all_scratch.scratch_bytes,
            "fused modeled traffic must not exceed scratch's"
        );
        if !matches!(base.selector, SelectorKind::FibHeap | SelectorKind::BinHeap) {
            let run_std = |thr: Option<usize>| {
                StandardFrankWolfe::new(&ds, FwConfig { direct_max_nnz: thr, ..base.clone() })
                    .run()
            };
            let s_scratch = run_std(Some(0));
            let s_fused = run_std(Some(usize::MAX));
            assert_outputs_bit_identical_modulo_traffic(&s_scratch, &s_fused, "std extremes");
            assert_eq!(s_scratch.bytes_moved, s_fused.bytes_moved);
            assert_eq!(s_scratch.direct_segments, 0);
            // Alg 1 sweeps every row each iteration, so the thr=0 run
            // provably pays scratch round-trips and the thr=∞ run none
            assert!(s_scratch.scratch_segments > 0, "std thr=0 must hit the scratch arm");
            assert!(s_scratch.scratch_bytes > 0);
            assert_eq!(s_fused.scratch_segments, 0);
            assert_eq!(s_fused.scratch_bytes, 0);
            assert!(s_fused.direct_segments > 0);
            assert_eq!(
                s_fused.direct_segments,
                s_scratch.direct_segments + s_scratch.scratch_segments
            );
        }
    });
}

/// **Row sharding is trajectory-invisible** (DESIGN.md §6.8): for random
/// datasets, selectors, and threads ∈ {1, 4}, a run partitioned into
/// P ∈ {1, 3, 16} row shards is bit-identical to the monolithic
/// `shards: None` run — weights, gaps, FLOPs, selector telemetry, traces,
/// and (fast solver) the full byte model. The standard solver's sharded
/// engine deviates from its legacy byte model by exactly the documented
/// CSC-for-CSR index-stream substitution, so its legacy comparison is
/// modulo traffic while its cross-P comparison is full bit identity.
#[test]
fn prop_sharded_bit_identical_any_partition() {
    forall(3, |rng| {
        // below-gate datasets exercise the serial fallbacks; the big
        // fixture clears every parallel gate in the sharded engines —
        // PAR_MIN_NNZ for the bootstrap/pass-1 phases AND the fast
        // solver's per-column gate (dense columns of ~5k nnz ≥ 2¹²), so
        // the genuinely threaded legs run and must still be bit-identical
        for big in [false, true] {
            let ds = if big {
                SynthConfig {
                    name: "prop-shard-big".into(),
                    n_rows: 5000 + rng.next_below(400) as usize,
                    n_cols: 300 + rng.next_below(200) as usize,
                    avg_row_nnz: 10.0 + rng.next_f64() * 4.0,
                    zipf_exponent: 1.05 + rng.next_f64() * 0.5,
                    n_informative: 8 + rng.next_below(16) as usize,
                    n_dense: 2,
                    label_noise: rng.next_f64() * 0.1,
                    bias_col: true,
                }
                .generate(rng.next_u64())
            } else {
                random_dataset(rng)
            };
            let iters = 20 + rng.next_below(40) as usize;
            let base = random_selector_cfg(rng, iters, 1.0 + rng.next_f64() * 10.0);
            for threads in [1usize, 4] {
                let cfg = FwConfig { threads, ..base.clone() };
                let legacy = FastFrankWolfe::new(&ds, cfg.clone()).run();
                assert_eq!(legacy.effective_shards, 0, "legacy path must report 0 shards");
                assert_eq!(legacy.effective_threads, threads);
                for p in [1usize, 3, 16] {
                    let what = format!("fast big={big} t={threads} p={p}");
                    let out = FastFrankWolfe::new(
                        &ds,
                        FwConfig { shards: Some(p), ..cfg.clone() },
                    )
                    .run();
                    assert!(
                        out.effective_shards >= 1 && out.effective_shards <= p,
                        "{what}: effective shards {} outside 1..={p}",
                        out.effective_shards
                    );
                    assert_outputs_bit_identical(&legacy, &out, &what);
                    // the per-shard ledger is attribution, not new work:
                    // it must sum to within the global totals
                    assert_eq!(out.shard_flops.len(), out.effective_shards, "{what}");
                    assert!(
                        out.shard_flops.iter().sum::<u64>() <= out.flops,
                        "{what}: shard flops exceed the run total"
                    );
                    assert!(
                        out.shard_bytes.iter().sum::<u64>() <= out.bytes_moved,
                        "{what}: shard bytes exceed the run total"
                    );
                }
                if !matches!(cfg.selector, SelectorKind::FibHeap | SelectorKind::BinHeap) {
                    let legacy_s = StandardFrankWolfe::new(&ds, cfg.clone()).run();
                    let run_p = |p: usize| {
                        StandardFrankWolfe::new(
                            &ds,
                            FwConfig { shards: Some(p), ..cfg.clone() },
                        )
                        .run()
                    };
                    let p1 = run_p(1);
                    // trajectory/FLOP identity against the legacy engine;
                    // byte totals differ by the documented substitution
                    assert_outputs_bit_identical_modulo_traffic(
                        &legacy_s,
                        &p1,
                        &format!("std-vs-legacy big={big} t={threads}"),
                    );
                    for p in [3usize, 16] {
                        let what = format!("std big={big} t={threads} p={p}");
                        let out = run_p(p);
                        assert_outputs_bit_identical(&p1, &out, &what);
                    }
                }
            }
        }
    });
}

/// **The sharded engines compose with the path cache**: `run_path` across
/// P ∈ {1, 3, 16} shards performs exactly one cold bootstrap per
/// workspace, serves every later λ (and the standard solver's whole path,
/// through the same `BootKey`) from the cache, and the fast solver's
/// outputs stay bit-identical to the legacy path engine's — cold and warm
/// legs alike. The standard sharded path is bit-identical across P.
#[test]
fn prop_sharded_run_path_warm_cache_invariant() {
    forall(4, |rng| {
        let ds = random_dataset(rng);
        let iters = 20 + rng.next_below(40) as usize;
        let base = random_selector_cfg(rng, iters, 1.0 + rng.next_f64() * 10.0);
        let lambdas: Vec<f64> = vec![2.0 + rng.next_f64(), 5.0, 9.0];
        let mut ws_legacy = FwWorkspace::new();
        let legacy =
            FastFrankWolfe::new(&ds, base.clone()).run_path(&lambdas, &mut ws_legacy);
        let mut std_ref: Option<Vec<FwOutput>> = None;
        for p in [1usize, 3, 16] {
            let mut ws = FwWorkspace::new();
            let cfg = FwConfig { shards: Some(p), ..base.clone() };
            let outs = FastFrankWolfe::new(&ds, cfg.clone()).run_path(&lambdas, &mut ws);
            assert!(outs[0].bootstrap_flops > 0, "p={p}: first λ must bootstrap cold");
            assert!(
                outs[1..].iter().all(|o| o.bootstrap_flops == 0),
                "p={p}: warm λ solves must hit the cache"
            );
            for (i, (a, b)) in legacy.iter().zip(&outs).enumerate() {
                assert_outputs_bit_identical(a, b, &format!("fast path p={p} i={i}"));
            }
            if !matches!(base.selector, SelectorKind::FibHeap | SelectorKind::BinHeap) {
                // same workspace: the standard sharded path draws the
                // bootstrap the fast sharded path just cached (the BootKey
                // is shard-agnostic by design)
                let outs_s =
                    StandardFrankWolfe::new(&ds, cfg).run_path(&lambdas, &mut ws);
                assert!(
                    outs_s.iter().all(|o| o.bootstrap_flops == 0),
                    "p={p}: cache must cross solvers at any shard count"
                );
                match &std_ref {
                    None => std_ref = Some(outs_s),
                    Some(r) => {
                        for (i, (a, b)) in r.iter().zip(&outs_s).enumerate() {
                            assert_outputs_bit_identical(
                                a,
                                b,
                                &format!("std path p={p} i={i}"),
                            );
                        }
                    }
                }
            }
        }
    });
}

/// Solution sparsity: ≤ one new coordinate per iteration, always inside
/// the L1 ball — for every selector, private or not.
#[test]
fn prop_sparsity_and_feasibility_all_selectors() {
    forall(6, |rng| {
        let ds = random_dataset(rng);
        let lam = 1.0 + rng.next_f64() * 10.0;
        let iters = 20 + rng.next_below(60) as usize;
        for sel in [
            SelectorKind::Argmax,
            SelectorKind::FibHeap,
            SelectorKind::BinHeap,
            SelectorKind::Bsls,
            SelectorKind::NoisyMax,
            SelectorKind::NaiveExp,
        ] {
            let privacy = sel.is_private().then(|| PrivacyParams::new(1.0, 1e-6));
            let cfg = FwConfig {
                iters,
                lambda: lam,
                privacy,
                selector: sel,
                seed: rng.next_u64(),
                trace_every: 0,
                ..Default::default()
            };
            let out = FastFrankWolfe::new(&ds, cfg).run();
            assert!(out.weights.l1_norm() <= lam + 1e-6, "{sel:?} left the ball");
            assert!(out.weights.nnz() <= iters, "{sel:?} too dense");
        }
    });
}
