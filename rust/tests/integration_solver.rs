//! Integration tests across solver + data + eval modules: end-to-end
//! training behaviour on each paper-preset workload, LIBSVM round-trips
//! through the real solver, and the DP speed/utility shape at test scale.

use std::sync::Arc;

use dpfw::coordinator::job::score;
use dpfw::dp::accounting::PrivacyParams;
use dpfw::eval::{accuracy, auc};
use dpfw::fw::config::{FwConfig, SelectorKind};
use dpfw::fw::fast::FastFrankWolfe;
use dpfw::fw::standard::StandardFrankWolfe;
use dpfw::sparse::synth::{DatasetPreset, SynthConfig};
use dpfw::sparse::{libsvm, Dataset};

fn preset_small(p: DatasetPreset) -> Dataset {
    let sc = match p {
        DatasetPreset::Rcv1 => 0.02,
        DatasetPreset::News20 => 0.01,
        DatasetPreset::Url => 0.0006,
        DatasetPreset::Web => 0.0008,
        DatasetPreset::Kdda => 0.0002,
    };
    SynthConfig::preset(p).scale(sc).generate(99)
}

/// Non-private training learns every preset's planted signal well above
/// chance — the precondition for any of the paper's utility claims.
#[test]
fn nonprivate_learns_every_preset() {
    for p in DatasetPreset::ALL {
        let ds = preset_small(p);
        let (train, test) = ds.split(0.25);
        let out = FastFrankWolfe::new(
            &train,
            FwConfig {
                iters: 1500,
                lambda: 30.0,
                selector: SelectorKind::FibHeap,
                ..Default::default()
            },
        )
        .run();
        let pr = score(&test, out.weights.as_slice());
        let a = auc(&pr, &test.labels);
        assert!(a > 65.0, "{}: AUC {a}", p.name());
    }
}

/// Moderate privacy costs some utility but must stay above chance, and
/// strong privacy must not *crash* — the paper's Table 4 regime.
#[test]
fn dp_utility_degrades_gracefully() {
    let ds = preset_small(DatasetPreset::Rcv1);
    let (train, test) = ds.split(0.25);
    let run = |eps: f64| {
        let out = FastFrankWolfe::new(
            &train,
            FwConfig {
                iters: 1500,
                lambda: 30.0,
                privacy: Some(PrivacyParams::new(eps, 1e-6)),
                selector: SelectorKind::Bsls,
                seed: 3,
                trace_every: 0,
                ..Default::default()
            },
        )
        .run();
        let p = score(&test, out.weights.as_slice());
        auc(&p, &test.labels)
    };
    let auc_loose = run(50.0); // nearly non-private
    let auc_tight = run(0.1);
    assert!(auc_loose > 70.0, "eps=50 AUC {auc_loose}");
    assert!(auc_tight >= 35.0, "eps=0.1 AUC collapsed: {auc_tight}");
    assert!(auc_loose >= auc_tight - 8.0, "more privacy gave better AUC?");
}

/// Wall-clock: Alg 2+BSLS beats Alg 1+noisy-max on a high-D sparse
/// workload (Table 3's direction, at test scale).
#[test]
fn dp_fast_solver_is_faster() {
    let ds = SynthConfig::preset(DatasetPreset::News20).scale(0.02).generate(5);
    let privacy = Some(PrivacyParams::new(0.5, 1e-6));
    let base = FwConfig {
        iters: 300,
        lambda: 30.0,
        privacy,
        selector: SelectorKind::NoisyMax,
        seed: 1,
        trace_every: 0,
        ..Default::default()
    };
    let slow = StandardFrankWolfe::new(&ds, base.clone()).run();
    let fast = FastFrankWolfe::new(
        &ds,
        FwConfig { selector: SelectorKind::Bsls, ..base },
    )
    .run();
    assert!(
        fast.wall_ms < slow.wall_ms,
        "no speedup: fast {} ms vs std {} ms",
        fast.wall_ms,
        slow.wall_ms
    );
    // and by a meaningful factor on D≈27k
    assert!(slow.wall_ms / fast.wall_ms > 3.0, "speedup only {:.2}x", slow.wall_ms / fast.wall_ms);
}

/// A dataset written to LIBSVM text and read back trains to the same
/// model (full-pipeline persistence round-trip).
#[test]
fn libsvm_roundtrip_preserves_training() {
    let ds = preset_small(DatasetPreset::Rcv1);
    let path = std::env::temp_dir().join("dpfw_integration_roundtrip.svm");
    libsvm::write_file(&ds, &path).unwrap();
    let ds2 = libsvm::read_file(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(ds.labels, ds2.labels);
    let cfg = FwConfig { iters: 200, lambda: 10.0, ..Default::default() };
    let a = FastFrankWolfe::new(&ds, cfg.clone()).run();
    let b = FastFrankWolfe::new(&ds2, cfg).run();
    // f32 text round-trip is exact for our generated values
    assert_eq!(a.weights, b.weights);
}

/// The 2016-style large-T DP regime: many iterations at strong privacy
/// still produce a sparse solution with nnz ≤ T and nontrivial signal —
/// the mechanism behind the paper's Table 4.
#[test]
fn dp_large_t_stays_sparse() {
    let ds = preset_small(DatasetPreset::News20);
    let out = FastFrankWolfe::new(
        &ds,
        FwConfig {
            iters: 4000,
            lambda: 100.0,
            privacy: Some(PrivacyParams::new(0.1, 1e-6)),
            selector: SelectorKind::Bsls,
            seed: 8,
            trace_every: 0,
            ..Default::default()
        },
    )
    .run();
    let d = ds.n_cols() as f64;
    let sparsity = 100.0 * (d - out.weights.nnz() as f64) / d;
    assert!(sparsity > 50.0, "solution not sparse: {sparsity}%");
    assert!(out.weights.nnz() <= 4000);
}

/// Accuracy metric plumbing: a model scored through the coordinator's
/// sparse scorer matches a hand-rolled sigmoid pass.
#[test]
fn scorer_matches_manual_sigmoid() {
    let ds = preset_small(DatasetPreset::Url);
    let out = FastFrankWolfe::new(
        &ds,
        FwConfig { iters: 300, lambda: 10.0, ..Default::default() },
    )
    .run();
    let p = score(&ds, out.weights.as_slice());
    let mut v = vec![0.0f64; ds.n_rows()];
    ds.csr.matvec(out.weights.as_slice(), &mut v);
    for (pi, vi) in p.iter().zip(&v) {
        let want = 1.0 / (1.0 + (-vi).exp());
        assert!((pi - want).abs() < 1e-12);
    }
    let acc = accuracy(&p, &ds.labels);
    assert!((0.0..=100.0).contains(&acc));
}

/// Compact-substrate adversarial shapes, end-to-end through the real
/// solvers: a hand-built matrix whose rows force **escape blocks** (index
/// deltas ≥ 2¹⁶ on a D = 200k feature space), a URL-style **dense
/// column** every row hits, empty CSC columns in between, and all three
/// paper selectors (Alg 3 heap, BSLS, noisy-max) at threads ∈ {1, 4, 16}
/// (below PAR_MIN_NNZ, so the thread legs exercise the in-kernel gate;
/// genuine parallel thread coverage lives in
/// `prop_equivalence::prop_compact_substrate_bit_identical_to_u32`).
/// The compact run must be bit-identical to the stripped-u32 run while
/// reporting strictly fewer modeled bytes.
#[test]
fn compact_escape_blocks_dense_column_bit_identical_end_to_end() {
    use dpfw::sparse::coo::CooBuilder;
    let n_rows = 80usize;
    let d = 200_000usize;
    let mut b = CooBuilder::new(0, d);
    let mut labels = Vec::new();
    for r in 0..n_rows {
        let row = b.add_row();
        b.push(row, 0, 1.0); // dense column: every row
        b.push(row, 40 + r % 7, 0.5 + r as f32 * 0.01); // small-delta region
        // escape block: a jump of ≥ 2^16 from the previous index
        b.push(row, 70_000 + r * 997, if r % 2 == 0 { 1.0 } else { -1.0 });
        if r % 3 == 0 {
            b.push(row, 199_990 + r % 9, 0.25); // second escape-sized jump
        }
        labels.push((r % 2) as f32);
    }
    b.set_shape(n_rows, d);
    let ds = Dataset::new(b.to_csr(), labels, "escape-adversarial");
    assert_eq!(ds.index_kind(), "u16-delta", "escape-sparse matrix must still qualify");
    let mut plain = ds.clone();
    plain.strip_compact();
    for sel in [SelectorKind::FibHeap, SelectorKind::Bsls, SelectorKind::NoisyMax] {
        for threads in [1usize, 4, 16] {
            let cfg = FwConfig {
                iters: 120,
                lambda: 5.0,
                privacy: sel.is_private().then(|| PrivacyParams::new(1.0, 1e-6)),
                selector: sel,
                seed: 11,
                trace_every: 10,
                threads,
                ..Default::default()
            };
            let a = FastFrankWolfe::new(&ds, cfg.clone()).run();
            let c = FastFrankWolfe::new(&plain, cfg.clone()).run();
            assert_eq!(a.weights, c.weights, "{sel:?} threads={threads}: weights diverged");
            assert_eq!(
                a.final_gap.to_bits(),
                c.final_gap.to_bits(),
                "{sel:?} threads={threads}: gap diverged"
            );
            assert_eq!(a.flops, c.flops, "{sel:?} threads={threads}: flops diverged");
            assert!(
                a.bytes_moved < c.bytes_moved,
                "{sel:?} threads={threads}: compact moved no fewer bytes"
            );
            if sel != SelectorKind::FibHeap {
                let a = StandardFrankWolfe::new(&ds, cfg.clone()).run();
                let c = StandardFrankWolfe::new(&plain, cfg.clone()).run();
                assert_eq!(a.weights, c.weights, "std {sel:?} threads={threads}");
                assert!(a.bytes_moved < c.bytes_moved, "std {sel:?} threads={threads}: bytes");
            }
        }
    }
}

/// §6.7 dispatcher end-to-end: a D = 200k dataset with a URL-style dense
/// column whose planted signal guarantees selection at t = 1, plus short
/// escape-block rows — so one solve provably drives BOTH dispatcher arms:
/// the 80-nnz dense-column scan decodes to scratch (nnz > the 64
/// threshold) while every 3-nnz row scan rides the fused direct tier.
/// Bit-identical to the stripped-u32 run and across thresholds; the split
/// counters prove which arms ran.
#[test]
fn direct_dispatcher_both_arms_in_one_solve() {
    use dpfw::sparse::coo::CooBuilder;
    let n_rows = 80usize;
    let d = 200_000usize;
    let mut b = CooBuilder::new(0, d);
    let mut labels = Vec::new();
    for r in 0..n_rows {
        let row = b.add_row();
        // dense column with uniform labels: |α₀[0]| = Σ|σ(0) − 1| = 40
        // dominates every other column (≤ ~3), so t = 1 selects it
        b.push(row, 0, 1.0);
        b.push(row, 40 + r % 7, 0.5);
        b.push(row, 70_000 + r * 997, 1.0); // escape-sized delta (≥ 2¹⁶)
        labels.push(1.0);
    }
    b.set_shape(n_rows, d);
    let ds = Dataset::new(b.to_csr(), labels, "direct-dispatch");
    assert_eq!(ds.index_kind(), "u16-delta");
    let mut plain = ds.clone();
    plain.strip_compact();
    let cfg = FwConfig {
        iters: 40,
        lambda: 5.0,
        selector: SelectorKind::FibHeap,
        direct_max_nnz: Some(64), // pin the default explicitly (env-proof)
        ..Default::default()
    };
    let a = FastFrankWolfe::new(&ds, cfg.clone()).run();
    assert!(a.scratch_segments > 0, "dense column must decode to scratch");
    assert!(a.direct_segments > 0, "short rows must ride the fused tier");
    assert!(a.scratch_bytes > 0);
    let p = FastFrankWolfe::new(&plain, cfg.clone()).run();
    assert_eq!(a.weights, p.weights, "substrate must be trajectory-invisible");
    assert_eq!(a.final_gap.to_bits(), p.final_gap.to_bits());
    assert_eq!(a.flops, p.flops);
    assert!(a.bytes_moved < p.bytes_moved, "compact must move fewer bytes");
    assert_eq!(p.direct_segments, 0, "u32 substrate has no decode arms");
    assert_eq!(p.scratch_segments, 0);
    assert_eq!(p.scratch_bytes, 0);
    // threshold ∞: same trajectory, same scanned segments, all fused
    let fused = FastFrankWolfe::new(
        &ds,
        FwConfig { direct_max_nnz: Some(usize::MAX), ..cfg },
    )
    .run();
    assert_eq!(fused.weights, a.weights);
    assert_eq!(fused.bytes_moved, a.bytes_moved, "DRAM model is threshold-invariant");
    assert_eq!(fused.scratch_segments, 0);
    assert_eq!(fused.scratch_bytes, 0);
    assert_eq!(fused.direct_segments, a.direct_segments + a.scratch_segments);
}

/// Arc-shared datasets across threads: the solver is Sync-safe over
/// read-only data (what the coordinator relies on).
#[test]
fn concurrent_training_on_shared_data() {
    let ds = Arc::new(preset_small(DatasetPreset::Rcv1));
    let mut handles = Vec::new();
    for seed in 0..4u64 {
        let ds = Arc::clone(&ds);
        handles.push(std::thread::spawn(move || {
            FastFrankWolfe::new(
                &ds,
                FwConfig {
                    iters: 150,
                    lambda: 8.0,
                    privacy: Some(PrivacyParams::new(1.0, 1e-6)),
                    selector: SelectorKind::Bsls,
                    seed,
                    trace_every: 0,
                    ..Default::default()
                },
            )
            .run()
            .weights
        }));
    }
    let outs: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    // different seeds should give different DP trajectories
    assert!(outs.windows(2).any(|w| w[0] != w[1]));
}
