//! §6.10 coalescing equivalence suite: solves whose dense bootstrap was
//! folded into one ingress-hub leader compute are *bit-identical* —
//! weights, trace, and `eps_spent` — to independent solves, at every
//! (shards P, threads) combination; each follower is charged only its
//! own ε; and a leader that panics mid-bootstrap never strands its
//! followers (they detach and re-lead, seed-pinned).

use std::sync::Arc;
use std::time::Duration;

use dpfw::coordinator::{Admit, Algo, Ingress, IngressConfig, JobSpec, Request};
use dpfw::dp::accounting::PrivacyParams;
use dpfw::fw::config::{FwConfig, SelectorKind};
use dpfw::fw::trace::TraceRecord;
use dpfw::sparse::synth::SynthConfig;
use dpfw::sparse::Dataset;
use dpfw::testkit::faults::{FaultKind, FaultPlan};

fn dataset(seed: u64) -> Arc<Dataset> {
    Arc::new(
        SynthConfig {
            name: format!("coal{seed}"),
            n_rows: 120,
            n_cols: 60,
            avg_row_nnz: 7.0,
            zipf_exponent: 1.2,
            n_informative: 10,
            n_dense: 0,
            label_noise: 0.02,
            bias_col: true,
        }
        .generate(seed),
    )
}

/// A DP config (Bsls selector) so the mechanism stream — the thing
/// coalescing must not share — is actually exercised.
fn dp_cfg(seed: u64, shards: Option<usize>, threads: usize) -> FwConfig {
    FwConfig {
        iters: 80,
        lambda: 6.0,
        privacy: Some(PrivacyParams::new(1.0, 1e-6)),
        selector: SelectorKind::Bsls,
        seed,
        shards,
        threads,
        ..Default::default()
    }
}

fn spec(data: Arc<Dataset>, cfg: FwConfig) -> JobSpec {
    JobSpec { id: 0, label: "c".into(), data, algo: Algo::Fast, cfg, test_data: None }
}

/// Deterministic trace fields — everything but the wall clock.
fn trace_key(r: &TraceRecord) -> (usize, f64, u64, u64, u64, usize) {
    (r.iter, r.gap, r.flops, r.bytes, r.pops, r.selected)
}

/// Six concurrent same-dataset solves (distinct seeds → distinct
/// mechanism streams) through the ingress coalesce into exactly one
/// bootstrap compute, and every output is bit-identical to the same job
/// run independently — weights, trace, ε — with each follower's `flops`
/// lower than its independent run's by exactly the skipped bootstrap.
#[test]
fn coalesced_solves_are_bit_identical_to_independent_runs() {
    for shards in [None, Some(3)] {
        for threads in [1usize, 4] {
            let d = dataset(11);
            let mut ing =
                Ingress::new(IngressConfig { workers: 4, ..Default::default() });
            let seeds: Vec<u64> = (100..106).collect();
            for &seed in &seeds {
                let admit =
                    ing.submit(Request::Solve(spec(d.clone(), dp_cfg(seed, shards, threads))));
                assert!(admit.is_accepted(), "{admit:?}");
            }
            let out = ing.drain();
            assert_eq!(out.len(), seeds.len());

            let mut cold = 0usize;
            for ((_, outcome), &seed) in out.iter().zip(&seeds) {
                let got = outcome.as_ref().expect("coalesced solve failed");
                let fresh = spec(d.clone(), dp_cfg(seed, shards, threads)).run();
                assert_eq!(
                    got.output.weights, fresh.output.weights,
                    "weights differ (P={shards:?}, threads={threads}, seed={seed})"
                );
                assert_eq!(
                    got.output.trace.iter().map(trace_key).collect::<Vec<_>>(),
                    fresh.output.trace.iter().map(trace_key).collect::<Vec<_>>(),
                    "trace differs (P={shards:?}, threads={threads}, seed={seed})"
                );
                // follower ε is its own full spend — coalescing shares the
                // bootstrap compute, never the mechanism releases
                assert_eq!(got.output.eps_spent, fresh.output.eps_spent);
                assert!(fresh.output.bootstrap_flops > 0);
                // honest accounting: a warm run's flops omit exactly the
                // bootstrap it skipped
                assert_eq!(
                    got.output.flops + (fresh.output.bootstrap_flops
                        - got.output.bootstrap_flops),
                    fresh.output.flops
                );
                if got.output.bootstrap_flops > 0 {
                    cold += 1;
                    assert_eq!(got.output.bootstrap_flops, fresh.output.bootstrap_flops);
                }
            }
            assert_eq!(
                cold, 1,
                "exactly one bootstrap compute per hub key (P={shards:?}, threads={threads})"
            );
            // one hub lead, one published slot; the five warm runs got
            // their bootstrap from the hub or their worker's local cache
            // (which scheduling decides — both are coalesced paths)
            assert_eq!(ing.hub().leads(), 1);
            assert_eq!(ing.hub().ready_len(), 1);
        }
    }
}

/// A leader that panics inside the bootstrap (while holding the hub
/// lease) fails only its own job: waiting followers observe the aborted
/// lease, detach, re-lead seed-pinned, and still produce bit-identical
/// output.
#[test]
fn followers_survive_a_leader_panic_mid_bootstrap() {
    let d = dataset(12);
    let mut ing = Ingress::new(IngressConfig { workers: 4, ..Default::default() });

    // the doomed leader: claims hub leadership, stalls 150 ms (the
    // followers' window to attach), then panics; no retries configured
    let mut doomed = spec(d.clone(), dp_cfg(7, None, 1));
    doomed.cfg.fault = FaultPlan::once(FaultKind::PanicInBootstrap { after_ms: 150 });
    let Admit::Accepted { ids: doomed_ids, .. } =
        ing.submit(Request::Solve(doomed))
    else {
        panic!("leader must be accepted")
    };
    // let a worker pick it up and claim the lease before the followers
    std::thread::sleep(Duration::from_millis(30));

    let seeds = [200u64, 201, 202];
    for &seed in &seeds {
        assert!(ing
            .submit(Request::Solve(spec(d.clone(), dp_cfg(seed, None, 1))))
            .is_accepted());
    }
    let out = ing.drain();
    assert_eq!(out.len(), 4);
    let doomed_id = doomed_ids.start;
    for (id, outcome) in &out {
        if *id == doomed_id {
            let err = outcome.as_ref().unwrap_err();
            assert!(
                format!("{err}").contains("bootstrap"),
                "leader must fail with the injected bootstrap panic: {err}"
            );
        } else {
            let got = outcome.as_ref().expect("follower stranded by leader panic");
            let seed = seeds[*id - 1]; // ids 1..=3 in submission order
            let fresh = spec(d.clone(), dp_cfg(seed, None, 1)).run();
            assert_eq!(got.output.weights, fresh.output.weights);
            assert_eq!(got.output.eps_spent, fresh.output.eps_spent);
        }
    }
    // the doomed leader led once; a follower re-led after the abort
    assert_eq!(ing.hub().leads(), 2, "abort must hand leadership over");
    assert!(
        ing.hub().detaches() >= 1,
        "at least one waiting follower must have detached from the dead lease"
    );
}
