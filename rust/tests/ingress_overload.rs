//! §6.10 overload soak (serial; CI runs it with `--test-threads=1`):
//! under fault-injected overload — panics, abrupt worker deaths, expired
//! deadlines, watermark sheds, brownout, breaker quarantine — every
//! *accepted* request still resolves to exactly one structured outcome,
//! the admission counters match the `Admit` decisions handed back, and
//! the queue gauge returns to zero after every wave.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use dpfw::coordinator::{
    Admit, Algo, ClassPolicy, Ingress, IngressConfig, JobError, JobSpec, PredictJob,
    Request,
};
use dpfw::dp::accounting::PrivacyParams;
use dpfw::fw::cancel::{CancelToken, StopReason};
use dpfw::fw::config::{FwConfig, SelectorKind};
use dpfw::sparse::synth::SynthConfig;
use dpfw::sparse::Dataset;
use dpfw::testkit::faults::{FaultKind, FaultPlan};

fn dataset(seed: u64) -> Arc<Dataset> {
    Arc::new(
        SynthConfig {
            name: format!("soak{seed}"),
            n_rows: 120,
            n_cols: 60,
            avg_row_nnz: 7.0,
            zipf_exponent: 1.2,
            n_informative: 10,
            n_dense: 0,
            label_noise: 0.02,
            bias_col: true,
        }
        .generate(seed),
    )
}

fn dp_cfg(seed: u64) -> FwConfig {
    FwConfig {
        iters: 60,
        lambda: 6.0,
        privacy: Some(PrivacyParams::new(1.0, 1e-6)),
        selector: SelectorKind::Bsls,
        seed,
        ..Default::default()
    }
}

fn solve(data: Arc<Dataset>, cfg: FwConfig) -> Request {
    Request::Solve(JobSpec {
        id: 0,
        label: "s".into(),
        data,
        algo: Algo::Fast,
        cfg,
        test_data: None,
    })
}

fn predict(data: Arc<Dataset>) -> Request {
    let w = Arc::new(vec![0.01; data.csr.n_cols()]);
    Request::Predict(PredictJob {
        id: 0,
        label: "p".into(),
        data,
        weights: w,
        threads: 0,
        cancel: CancelToken::none(),
        fault: FaultPlan::none(),
    })
}

/// The acceptance property verbatim: a burst over the hard watermark,
/// laced with every §6.9 fault shape, and each accepted id resolves —
/// `Ok`, `Panicked`, `WorkerDied`, or `Expired` — while sheds enqueue
/// nothing and the counters reconcile exactly.
#[test]
fn faulted_overload_burst_resolves_every_accepted_id() {
    let d = dataset(1);
    let mut ing = Ingress::new(IngressConfig {
        workers: 3,
        solve: ClassPolicy { queue_hard: 8, ..Default::default() },
        ..Default::default()
    });

    let mut owed: Vec<usize> = Vec::new();
    let mut sheds = 0u64;
    let mut panicky: Vec<usize> = Vec::new();
    let mut mortal: Vec<usize> = Vec::new();
    let mut expired: Vec<usize> = Vec::new();
    for k in 0..12u64 {
        let mut cfg = dp_cfg(100 + k);
        let kind = k % 4;
        match kind {
            1 => cfg.fault = FaultPlan::once(FaultKind::PanicAt { iter: 3 }),
            2 => cfg.fault = FaultPlan::once(FaultKind::DieAbruptly),
            3 => cfg.cancel = CancelToken::deadline_in(Duration::ZERO),
            _ => {}
        }
        match ing.submit(solve(d.clone(), cfg)) {
            Admit::Accepted { ids, .. } => {
                let id = ids.start;
                owed.extend(ids);
                match kind {
                    1 => panicky.push(id),
                    2 => mortal.push(id),
                    3 => expired.push(id),
                    _ => {}
                }
            }
            Admit::Shed(_) => sheds += 1,
            Admit::Redirected { .. } => panic!("no rate limit configured"),
        }
    }
    // predictions ride the same pool on their own (open) class
    for _ in 0..3 {
        match ing.submit(predict(d.clone())) {
            Admit::Accepted { ids, .. } => owed.extend(ids),
            other => panic!("predict class is open: {other:?}"),
        }
    }
    assert!(sheds > 0, "12 solves past queue_hard=8 must shed some");

    let out = ing.drain();
    assert_eq!(out.len(), owed.len(), "every accepted id is owed an outcome");
    assert_eq!(out.iter().map(|(id, _)| *id).collect::<Vec<_>>(), owed);
    for (id, outcome) in &out {
        match outcome {
            Ok(r) => assert!(
                !panicky.contains(id) && !expired.contains(id),
                "id {id} should have failed, got Ok ({})",
                r.label
            ),
            Err(JobError::Panicked(msg)) => {
                assert!(panicky.contains(id), "unexpected panic on id {id}: {msg}");
            }
            Err(JobError::WorkerDied) => {
                assert!(mortal.contains(id), "unexpected worker death on id {id}");
            }
            Err(JobError::Expired) => {
                assert!(expired.contains(id), "unexpected shed of running id {id}");
            }
            Err(other) => panic!("unstructured outcome for id {id}: {other:?}"),
        }
    }

    let m = ing.metrics();
    assert_eq!(m.admits.load(Ordering::Relaxed), owed.len() as u64);
    assert_eq!(m.admission_sheds.load(Ordering::Relaxed), sheds);
    assert_eq!(m.queue_depth.load(Ordering::Relaxed), 0, "gauge must return to 0");
    assert!(
        m.workers_respawned.load(Ordering::Relaxed) >= mortal.len() as u64,
        "each abrupt death is supervised back into rotation"
    );
    assert!(m.bytes_per_request() > 0);
}

/// Sustained overload arms the brownout; drained queues disarm it. The
/// degraded runs stay honest end to end: `StopReason::Brownout`, the
/// capped iteration count, and `eps_spent` at exactly the anytime rate.
#[test]
fn brownout_arms_under_pressure_and_recovers_after_drain() {
    let d = dataset(2);
    let iters = 60usize;
    let pp = PrivacyParams::new(1.0, 1e-6);
    let mut ing = Ingress::new(IngressConfig {
        workers: 2,
        solve: ClassPolicy { queue_soft: 2, ..Default::default() },
        brownout_after: 2,
        brownout_frac: 0.5,
        brownout_min_iters: 8,
        ..Default::default()
    });
    // depth 0,1 admit below the soft mark; depths 2 and 3 breach twice —
    // the 4th and later admissions are browned out
    let mut browned: Vec<usize> = Vec::new();
    for k in 0..6 {
        match ing.submit(solve(d.clone(), dp_cfg(200 + k))) {
            Admit::Accepted { ids, browned_out } => {
                assert_eq!(browned_out, k >= 3, "admission {k}");
                if browned_out {
                    browned.extend(ids);
                }
            }
            other => panic!("{other:?}"),
        }
    }
    assert!(ing.brownout_active());

    let cap = ((iters - 1) as f64 * 0.5).floor() as usize;
    let out = ing.drain();
    assert_eq!(out.len(), 6);
    for (id, o) in &out {
        let r = o.as_ref().expect("degraded, not dropped");
        if browned.contains(id) {
            assert_eq!(r.output.stopped, StopReason::Brownout);
            assert_eq!(r.output.iters_run, cap);
            assert_eq!(r.output.eps_spent, Some(pp.spent_epsilon(iters, cap)));
        } else {
            assert_eq!(r.output.stopped, StopReason::IterBudget);
        }
    }
    assert_eq!(
        ing.metrics().brownout_jobs.load(Ordering::Relaxed),
        browned.len() as u64
    );

    // the drain reset the queues; the next admission sits below the soft
    // watermark and deactivates the controller — full budgets again
    match ing.submit(solve(d.clone(), dp_cfg(299))) {
        Admit::Accepted { browned_out, .. } => assert!(!browned_out),
        other => panic!("{other:?}"),
    }
    assert!(!ing.brownout_active(), "recovery must disarm the controller");
    let out = ing.drain();
    assert_eq!(out[0].1.as_ref().unwrap().output.stopped, StopReason::IterBudget);
}

/// A worker that keeps destroying jobs is quarantined out of rotation
/// (breaker at K consecutive failures) and the shrunken pool keeps
/// serving; every poisoned id still resolves structurally.
#[test]
fn circuit_breaker_quarantines_and_pool_keeps_serving() {
    let d = dataset(3);
    let mut ing = Ingress::new(IngressConfig {
        workers: 2,
        breaker_k: 2,
        ..Default::default()
    });
    // λ ≤ 0 fails config validation inside the worker — a deterministic
    // panic on whichever worker picks the job up
    let poison = || {
        solve(d.clone(), FwConfig { iters: 40, lambda: -1.0, ..Default::default() })
    };
    let mut owed = Vec::new();
    for _ in 0..6 {
        match ing.submit(poison()) {
            Admit::Accepted { ids, .. } => owed.extend(ids),
            other => panic!("{other:?}"),
        }
    }
    let out = ing.drain();
    assert_eq!(out.len(), owed.len());
    for (id, o) in &out {
        assert!(
            matches!(o, Err(JobError::Panicked(_))),
            "poison id {id} must fail structurally: {o:?}"
        );
    }
    assert!(
        ing.metrics().workers_quarantined.load(Ordering::Relaxed) >= 1,
        "two strikes must quarantine at least one worker"
    );
    assert!(ing.live_workers() >= 1, "the pool never empties itself");

    // the survivor still serves clean work
    assert!(ing.submit(solve(d, dp_cfg(300))).is_accepted());
    let out = ing.drain();
    assert!(out[0].1.is_ok(), "{:?}", out[0].1);
}

/// Three consecutive waves through one long-lived ingress: admission
/// accounting and the §6.9 resolution contract hold wave after wave
/// (nothing leaks across drains).
#[test]
fn repeated_waves_keep_the_accounting_exact() {
    let d = dataset(4);
    let mut ing = Ingress::new(IngressConfig {
        workers: 2,
        solve: ClassPolicy { queue_hard: 4, ..Default::default() },
        ..Default::default()
    });
    let mut total_admits = 0u64;
    let mut total_sheds = 0u64;
    for wave in 0..3u64 {
        let mut owed = Vec::new();
        for k in 0..6u64 {
            let mut cfg = dp_cfg(wave * 10 + k);
            if k == 1 {
                cfg.fault = FaultPlan::once(FaultKind::PanicAt { iter: 2 });
            }
            match ing.submit(solve(d.clone(), cfg)) {
                Admit::Accepted { ids, .. } => owed.extend(ids),
                Admit::Shed(_) => total_sheds += 1,
                Admit::Redirected { .. } => panic!("no rate limit configured"),
            }
        }
        assert_eq!(owed.len(), 4, "wave {wave}: hard watermark admits exactly 4");
        total_admits += owed.len() as u64;
        let out = ing.drain();
        assert_eq!(out.len(), owed.len(), "wave {wave}");
        assert_eq!(out.iter().map(|(id, _)| *id).collect::<Vec<_>>(), owed);
        assert_eq!(
            ing.metrics().queue_depth.load(Ordering::Relaxed),
            0,
            "wave {wave}: gauge must return to zero"
        );
    }
    let m = ing.metrics();
    assert_eq!(m.admits.load(Ordering::Relaxed), total_admits);
    assert_eq!(m.admission_sheds.load(Ordering::Relaxed), total_sheds);
    assert_eq!(total_sheds, 6, "2 sheds per wave, watermark resets per drain");
}
