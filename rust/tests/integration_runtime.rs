//! Integration tests for the PJRT runtime + dense oracle: the Rust side
//! of the three-layer contract. These require `artifacts/` (built by
//! `make artifacts`); they are skipped (with a loud message) when the
//! artifacts are absent so `cargo test` works in a fresh checkout.

use dpfw::fw::config::FwConfig;
use dpfw::fw::fast::FastFrankWolfe;
use dpfw::fw::loss::{sigmoid, Logistic, Loss};
use dpfw::runtime::oracle::DenseOracle;
use dpfw::sparse::synth::SynthConfig;
use dpfw::sparse::Dataset;
use dpfw::testkit::assert_slices_close;

fn oracle() -> Option<DenseOracle> {
    match DenseOracle::open("artifacts") {
        Ok(o) => Some(o),
        Err(e) => {
            eprintln!("SKIP runtime tests: {e}");
            None
        }
    }
}

fn tile_dataset(o: &DenseOracle, n_rows: usize, seed: u64) -> Dataset {
    SynthConfig {
        name: "rt".into(),
        n_rows,
        n_cols: o.d_tile(),
        avg_row_nnz: 25.0,
        zipf_exponent: 1.2,
        n_informative: 24,
        n_dense: 0,
        label_noise: 0.05,
        bias_col: true,
    }
    .generate(seed)
}

fn rust_alpha(ds: &Dataset, w: &[f64]) -> Vec<f64> {
    let mut v = vec![0.0f64; ds.n_rows()];
    ds.csr.matvec(w, &mut v);
    let q: Vec<f64> = v
        .iter()
        .zip(&ds.labels)
        .map(|(&vi, &yi)| sigmoid(vi) - yi as f64)
        .collect();
    let mut a = vec![0.0f64; ds.n_cols()];
    ds.csr.matvec_t_add(&q, &mut a);
    a
}

/// α from the Pallas/XLA artifact == α from the sparse Rust path, at the
/// zero vector, at a trained model, and at a random point.
#[test]
fn oracle_alpha_matches_rust() {
    let Some(mut o) = oracle() else { return };
    let ds = tile_dataset(&o, o.n_tile() * 2, 7);
    let d = ds.n_cols();
    let zero = vec![0.0f64; d];
    assert_slices_close(&rust_alpha(&ds, &zero), &o.alpha(&ds, &zero).unwrap(), 5e-4, 5e-4);

    let trained = FastFrankWolfe::new(
        &ds,
        FwConfig { iters: 200, lambda: 10.0, ..Default::default() },
    )
    .run();
    let w = trained.weights.as_slice();
    assert_slices_close(&rust_alpha(&ds, w), &o.alpha(&ds, w).unwrap(), 5e-4, 5e-4);

    let mut rnd = vec![0.0f64; d];
    for (i, r) in rnd.iter_mut().enumerate() {
        *r = ((i % 13) as f64 - 6.0) / 10.0;
    }
    assert_slices_close(&rust_alpha(&ds, &rnd), &o.alpha(&ds, &rnd).unwrap(), 5e-4, 5e-4);
}

/// Row-tile accumulation: a dataset spanning several tiles with a ragged
/// final tile gives the same α as the single-row-block case.
#[test]
fn oracle_handles_ragged_tiles() {
    let Some(mut o) = oracle() else { return };
    // 2.5 tiles worth of rows
    let ds = tile_dataset(&o, o.n_tile() * 5 / 2, 11);
    let w = vec![0.05f64; ds.n_cols()];
    assert_slices_close(&rust_alpha(&ds, &w), &o.alpha(&ds, &w).unwrap(), 5e-4, 5e-4);
}

/// predict == sigmoid(Xw) elementwise, across tile boundaries.
#[test]
fn oracle_predict_matches_rust() {
    let Some(mut o) = oracle() else { return };
    let ds = tile_dataset(&o, o.n_tile() + 17, 13);
    let w: Vec<f64> = (0..ds.n_cols()).map(|j| ((j % 7) as f64 - 3.0) / 8.0).collect();
    let p = o.predict(&ds, &w).unwrap();
    assert_eq!(p.len(), ds.n_rows());
    let mut v = vec![0.0f64; ds.n_rows()];
    ds.csr.matvec(&w, &mut v);
    for (pi, vi) in p.iter().zip(&v) {
        assert!((pi - sigmoid(*vi)).abs() < 1e-4, "{pi} vs {}", sigmoid(*vi));
    }
}

/// loss_and_gap: mean loss matches the Rust loss; gap matches the α-based
/// formula.
#[test]
fn oracle_loss_gap_consistent() {
    let Some(mut o) = oracle() else { return };
    let ds = tile_dataset(&o, o.n_tile() * 2 - 31, 17);
    let out = FastFrankWolfe::new(
        &ds,
        FwConfig { iters: 150, lambda: 8.0, ..Default::default() },
    )
    .run();
    let w = out.weights.as_slice();
    let lam = 8.0;
    let (loss, gap) = o.loss_and_gap(&ds, w, lam).unwrap();
    // rust loss
    let mut v = vec![0.0f64; ds.n_rows()];
    ds.csr.matvec(w, &mut v);
    let want_loss: f64 = v
        .iter()
        .zip(&ds.labels)
        .map(|(&vi, &yi)| Logistic.value(vi, yi as f64))
        .sum::<f64>()
        / ds.n_rows() as f64;
    assert!((loss - want_loss).abs() < 1e-3, "loss {loss} vs {want_loss}");
    // rust gap
    let alpha = rust_alpha(&ds, w);
    let aw: f64 = alpha.iter().zip(w).map(|(&a, &wk)| a * wk).sum();
    let amax = alpha.iter().fold(0.0f64, |m, &a| m.max(a.abs()));
    let want_gap = aw + lam * amax;
    assert!(
        (gap - want_gap).abs() < 1e-3 * (1.0 + want_gap.abs()),
        "gap {gap} vs {want_gap}"
    );
}

/// Oracle dimension guard: datasets wider than the tile are rejected with
/// a helpful error, not wrong numbers.
#[test]
fn oracle_rejects_oversized_d() {
    let Some(mut o) = oracle() else { return };
    let ds = SynthConfig {
        name: "too-wide".into(),
        n_rows: 8,
        n_cols: o.d_tile() + 1,
        avg_row_nnz: 4.0,
        zipf_exponent: 1.2,
        n_informative: 4,
        n_dense: 0,
        label_noise: 0.0,
        bias_col: false,
    }
    .generate(1);
    let w = vec![0.0; ds.n_cols()];
    let err = o.alpha(&ds, &w).unwrap_err().to_string();
    assert!(err.contains("regenerate artifacts"), "unhelpful error: {err}");
}
