//! PJRT runtime: load the JAX/Pallas-AOT'd HLO text artifacts and execute
//! them from Rust. Python never runs here — `make artifacts` produced the
//! `.hlo.txt` files at build time; this module compiles them once on the
//! PJRT CPU client and executes them with concrete buffers.
//!
//! * [`client`] — artifact discovery (manifest), compilation, executable
//!   cache, typed execute helpers.
//! * [`oracle`] — the dense oracle over a [`crate::sparse::Dataset`]:
//!   `α = Xᵀ(σ(Xw) − y)`, batch prediction and loss, computed by the
//!   Pallas kernel through XLA and used to cross-check the sparse Rust
//!   solver and to score models in the experiments.
//!
//! ## Feature gating
//!
//! The PJRT path needs the `xla` bindings crate, which cannot be vendored
//! into the offline build container. It is therefore compiled only under
//! the `pjrt` cargo feature (see `rust/Cargo.toml` and DESIGN.md §6.4).
//! Without the feature, [`oracle::DenseOracle`] is a stub whose `open`
//! returns an explanatory error — every oracle consumer (the
//! `oracle-check` CLI command, `tests/integration_runtime.rs`, the e2e
//! example) already treats "oracle unavailable" as a soft skip, so the
//! rest of the system builds and runs unchanged.

#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(feature = "pjrt")]
pub mod oracle;

#[cfg(not(feature = "pjrt"))]
pub mod oracle {
    //! Stub [`DenseOracle`] compiled when the `pjrt` feature is off.

    use anyhow::{bail, Result};

    use crate::sparse::Dataset;

    /// API-compatible stand-in for the PJRT-backed dense oracle. Every
    /// constructor fails with a pointer at the `pjrt` feature; the
    /// accessors exist so downstream code type-checks identically under
    /// both configurations.
    pub struct DenseOracle {
        never: std::convert::Infallible,
    }

    impl DenseOracle {
        fn unavailable<T>() -> Result<T> {
            bail!(
                "PJRT dense oracle unavailable: dpfw was built without the \
                 `pjrt` feature (the `xla` bindings crate is not in the \
                 offline crate set — see rust/DESIGN.md §6.4)"
            )
        }

        pub fn open(_dir: impl AsRef<std::path::Path>) -> Result<Self> {
            Self::unavailable()
        }

        pub fn open_default() -> Result<Self> {
            Self::unavailable()
        }

        pub fn n_tile(&self) -> usize {
            match self.never {}
        }

        pub fn d_tile(&self) -> usize {
            match self.never {}
        }

        pub fn alpha(&mut self, _ds: &Dataset, _w: &[f64]) -> Result<Vec<f64>> {
            match self.never {}
        }

        pub fn predict(&mut self, _ds: &Dataset, _w: &[f64]) -> Result<Vec<f64>> {
            match self.never {}
        }

        pub fn loss_and_gap(
            &mut self,
            _ds: &Dataset,
            _w: &[f64],
            _lam: f64,
        ) -> Result<(f64, f64)> {
            match self.never {}
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn stub_open_reports_missing_feature() {
            let err = DenseOracle::open("artifacts").err().expect("stub must fail");
            assert!(err.to_string().contains("pjrt"));
        }
    }
}
