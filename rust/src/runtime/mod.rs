//! PJRT runtime: load the JAX/Pallas-AOT'd HLO text artifacts and execute
//! them from Rust. Python never runs here — `make artifacts` produced the
//! `.hlo.txt` files at build time; this module compiles them once on the
//! PJRT CPU client and executes them with concrete buffers.
//!
//! * [`client`] — artifact discovery (manifest), compilation, executable
//!   cache, typed execute helpers.
//! * [`oracle`] — the dense oracle over a [`crate::sparse::Dataset`]:
//!   `α = Xᵀ(σ(Xw) − y)`, batch prediction and loss, computed by the
//!   Pallas kernel through XLA and used to cross-check the sparse Rust
//!   solver and to score models in the experiments.

pub mod client;
pub mod oracle;
