//! PJRT client wrapper: compile HLO-text artifacts once, execute many.
//!
//! Interchange is HLO *text* (see `python/compile/aot.py` and
//! /opt/xla-example/README.md): `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` → `execute`. All
//! artifact entry computations return tuples (the lowering uses
//! `return_tuple=True`), so results are decomposed before returning.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

/// Compiled-artifact cache over one PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Oracle tile shape from the manifest.
    pub n_tile: usize,
    pub d_tile: usize,
    dir: PathBuf,
}

impl Runtime {
    /// Open the artifact directory (default `artifacts/`), read the
    /// manifest, and create the PJRT CPU client. Compilation is lazy: an
    /// artifact is compiled on first [`Runtime::execute`].
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest).with_context(|| {
            format!("read {} — run `make artifacts` first", manifest.display())
        })?;
        let mut n_tile = 0usize;
        let mut d_tile = 0usize;
        for line in text.lines() {
            if let Some(v) = line.strip_prefix("n_tile=") {
                n_tile = v.parse().context("bad n_tile in manifest")?;
            } else if let Some(v) = line.strip_prefix("d_tile=") {
                d_tile = v.parse().context("bad d_tile in manifest")?;
            }
        }
        if n_tile == 0 || d_tile == 0 {
            bail!("manifest missing n_tile/d_tile");
        }
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(Self { client, executables: HashMap::new(), n_tile, d_tile, dir })
    }

    /// Default location relative to the repo root.
    pub fn open_default() -> Result<Self> {
        Self::open("artifacts")
    }

    /// Compile (or fetch the cached) artifact `<name>.hlo.txt`.
    fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.executables.contains_key(name) {
            let path = self.dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {name}: {e}"))?;
            self.executables.insert(name.to_string(), exe);
        }
        Ok(&self.executables[name])
    }

    /// Execute artifact `name` with the given inputs; returns the tuple
    /// elements of the (single-device) result.
    pub fn execute(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute {name}: {e}"))?;
        let literal = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| anyhow!("execute {name}: empty result"))?
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {name} result: {e}"))?;
        literal.to_tuple().map_err(|e| anyhow!("untuple {name}: {e}"))
    }

    /// Build an f32 matrix literal of shape `(rows, cols)` from row-major
    /// data.
    pub fn literal_matrix(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
        assert_eq!(data.len(), rows * cols);
        xla::Literal::vec1(data)
            .reshape(&[rows as i64, cols as i64])
            .map_err(|e| anyhow!("reshape literal: {e}"))
    }

    pub fn literal_vec(data: &[f32]) -> xla::Literal {
        xla::Literal::vec1(data)
    }

    pub fn literal_scalar(v: f32) -> xla::Literal {
        xla::Literal::scalar(v)
    }
}
