//! The dense oracle: paper quantities recomputed *densely* through the
//! AOT-compiled JAX/Pallas artifacts, over a sparse [`Dataset`].
//!
//! Used for (a) cross-checking the sparse Rust solver's incremental state
//! (integration tests), and (b) scoring trained models (accuracy/AUC in
//! Table 4 / the e2e example). Rows are processed in tiles of the
//! artifact's fixed `n_tile`; the last tile is zero-padded (zero rows are
//! exact no-ops for `α`, and the row mask removes them from the loss).
//! Requires `D ≤ d_tile` — the oracle is a small-scale correctness tool,
//! not the training path.

use anyhow::{bail, Result};

use super::client::Runtime;
use crate::sparse::Dataset;

pub struct DenseOracle {
    rt: Runtime,
}

impl DenseOracle {
    pub fn new(rt: Runtime) -> Self {
        Self { rt }
    }

    pub fn open(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        Ok(Self::new(Runtime::open(dir)?))
    }

    pub fn open_default() -> Result<Self> {
        Ok(Self::new(Runtime::open_default()?))
    }

    pub fn n_tile(&self) -> usize {
        self.rt.n_tile
    }

    pub fn d_tile(&self) -> usize {
        self.rt.d_tile
    }

    fn check_dims(&self, ds: &Dataset) -> Result<()> {
        if ds.n_cols() > self.rt.d_tile {
            bail!(
                "oracle tile supports D ≤ {}, dataset has D = {} — regenerate \
                 artifacts with a larger --d",
                self.rt.d_tile,
                ds.n_cols()
            );
        }
        Ok(())
    }

    /// Pad `w` (f64) to the tile width as f32.
    fn w_literal(&self, w: &[f64]) -> Result<xla::Literal> {
        let mut wf = vec![0.0f32; self.rt.d_tile];
        for (dst, &src) in wf.iter_mut().zip(w) {
            *dst = src as f32;
        }
        Ok(Runtime::literal_vec(&wf))
    }

    /// Densify rows `[lo, hi)` into an `(n_tile, d_tile)` f32 tile plus
    /// the matching label and mask vectors.
    fn tile(&self, ds: &Dataset, lo: usize, hi: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let nt = self.rt.n_tile;
        let dt = self.rt.d_tile;
        let mut x = vec![0.0f32; nt * dt];
        let mut y = vec![0.0f32; nt];
        let mut m = vec![0.0f32; nt];
        for (r, i) in (lo..hi).enumerate() {
            for (j, v) in ds.csr.row(i) {
                x[r * dt + j] = v;
            }
            y[r] = ds.labels[i];
            m[r] = 1.0;
        }
        (x, y, m)
    }

    /// Dense `α = Xᵀ(σ(Xw) − y)`, accumulated over row tiles (α is
    /// additive across row blocks). Returns length-D f64.
    pub fn alpha(&mut self, ds: &Dataset, w: &[f64]) -> Result<Vec<f64>> {
        self.check_dims(ds)?;
        assert_eq!(w.len(), ds.n_cols());
        let nt = self.rt.n_tile;
        let wl = self.w_literal(w)?;
        let mut alpha = vec![0.0f64; ds.n_cols()];
        let mut lo = 0;
        while lo < ds.n_rows() {
            let hi = (lo + nt).min(ds.n_rows());
            let (x, y, m) = self.tile(ds, lo, hi);
            let xl = Runtime::literal_matrix(&x, nt, self.rt.d_tile)?;
            let out = self.rt.execute(
                "alpha",
                &[
                    xl,
                    wl.reshape(&[self.rt.d_tile as i64]).unwrap(),
                    Runtime::literal_vec(&y),
                    Runtime::literal_vec(&m),
                ],
            )?;
            let a: Vec<f32> = out[0].to_vec().map_err(|e| anyhow::anyhow!("{e}"))?;
            for (acc, &v) in alpha.iter_mut().zip(&a) {
                *acc += v as f64;
            }
            lo = hi;
        }
        Ok(alpha)
    }

    /// Batch scores `p_i = σ(x_i · w)` for every row.
    pub fn predict(&mut self, ds: &Dataset, w: &[f64]) -> Result<Vec<f64>> {
        self.check_dims(ds)?;
        let nt = self.rt.n_tile;
        let wl = self.w_literal(w)?;
        let mut p = Vec::with_capacity(ds.n_rows());
        let mut lo = 0;
        while lo < ds.n_rows() {
            let hi = (lo + nt).min(ds.n_rows());
            let (x, _, _) = self.tile(ds, lo, hi);
            let xl = Runtime::literal_matrix(&x, nt, self.rt.d_tile)?;
            let out = self.rt.execute(
                "predict",
                &[xl, wl.reshape(&[self.rt.d_tile as i64]).unwrap()],
            )?;
            let tile_p: Vec<f32> = out[0].to_vec().map_err(|e| anyhow::anyhow!("{e}"))?;
            p.extend(tile_p[..hi - lo].iter().map(|&v| v as f64));
            lo = hi;
        }
        Ok(p)
    }

    /// `(mean logistic loss, FW gap)` — loss summed over tiles then
    /// divided by N; the gap recomputed from the tile-accumulated α.
    pub fn loss_and_gap(&mut self, ds: &Dataset, w: &[f64], lam: f64) -> Result<(f64, f64)> {
        self.check_dims(ds)?;
        let nt = self.rt.n_tile;
        let wl = self.w_literal(w)?;
        let mut loss_sum = 0.0f64;
        let mut lo = 0;
        while lo < ds.n_rows() {
            let hi = (lo + nt).min(ds.n_rows());
            let (x, y, m) = self.tile(ds, lo, hi);
            let xl = Runtime::literal_matrix(&x, nt, self.rt.d_tile)?;
            let out = self.rt.execute(
                "loss_gap",
                &[
                    xl,
                    wl.reshape(&[self.rt.d_tile as i64]).unwrap(),
                    Runtime::literal_vec(&y),
                    Runtime::literal_vec(&m),
                    Runtime::literal_scalar(lam as f32),
                ],
            )?;
            let l: f32 = out[0].get_first_element().map_err(|e| anyhow::anyhow!("{e}"))?;
            loss_sum += l as f64;
            lo = hi;
        }
        let alpha = self.alpha(ds, w)?;
        let aw: f64 = alpha.iter().zip(w).map(|(&a, &wk)| a * wk).sum();
        let amax = alpha.iter().fold(0.0f64, |m, &a| m.max(a.abs()));
        Ok((loss_sum / ds.n_rows() as f64, aw + lam * amax))
    }
}
