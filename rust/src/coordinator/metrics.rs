//! Coordinator metrics: lock-free counters shared by workers.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

#[derive(Debug)]
pub struct Metrics {
    pub jobs_submitted: AtomicU64,
    pub jobs_completed: AtomicU64,
    pub jobs_failed: AtomicU64,
    pub iters_total: AtomicU64,
    pub flops_total: AtomicU64,
    /// Worker-side wall time in microseconds (sums across workers, so it
    /// can exceed elapsed wall time — that ratio is pool utilization).
    pub busy_us: AtomicU64,
    started: Instant,
}

impl Default for Metrics {
    fn default() -> Self {
        Self {
            jobs_submitted: AtomicU64::new(0),
            jobs_completed: AtomicU64::new(0),
            jobs_failed: AtomicU64::new(0),
            iters_total: AtomicU64::new(0),
            flops_total: AtomicU64::new(0),
            busy_us: AtomicU64::new(0),
            started: Instant::now(),
        }
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_completion(&self, iters: u64, flops: u64, busy_us: u64) {
        self.jobs_completed.fetch_add(1, Ordering::Relaxed);
        self.iters_total.fetch_add(iters, Ordering::Relaxed);
        self.flops_total.fetch_add(flops, Ordering::Relaxed);
        self.busy_us.fetch_add(busy_us, Ordering::Relaxed);
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Completed solver iterations per wall-clock second.
    pub fn iters_per_sec(&self) -> f64 {
        self.iters_total.load(Ordering::Relaxed) as f64 / self.elapsed_secs().max(1e-9)
    }

    pub fn summary(&self) -> String {
        format!(
            "jobs {}/{} ({} failed), {:.2e} iters, {:.2e} flops, {:.1} iters/s, pool busy {:.2}s",
            self.jobs_completed.load(Ordering::Relaxed),
            self.jobs_submitted.load(Ordering::Relaxed),
            self.jobs_failed.load(Ordering::Relaxed),
            self.iters_total.load(Ordering::Relaxed) as f64,
            self.flops_total.load(Ordering::Relaxed) as f64,
            self.iters_per_sec(),
            self.busy_us.load(Ordering::Relaxed) as f64 / 1e6,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let m = Metrics::new();
        m.jobs_submitted.fetch_add(2, Ordering::Relaxed);
        m.record_completion(100, 5000, 1234);
        m.record_completion(50, 1000, 100);
        assert_eq!(m.jobs_completed.load(Ordering::Relaxed), 2);
        assert_eq!(m.iters_total.load(Ordering::Relaxed), 150);
        assert_eq!(m.flops_total.load(Ordering::Relaxed), 6000);
        let s = m.summary();
        assert!(s.contains("jobs 2/2"), "{s}");
    }
}
