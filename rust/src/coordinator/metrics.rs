//! Coordinator metrics: lock-free counters shared by workers, plus the
//! §6.9 serving surface — queue depth, retry/shed/timeout counters, and
//! fixed-bucket latency histograms exposing p50/p99 per job class. All
//! atomics; recording from N workers never takes a lock.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Log2 µs buckets: bucket 0 holds 0 µs, bucket k holds
/// [2^(k−1), 2^k) µs. 40 buckets cover ~6.4 days — beyond any job.
const HIST_BUCKETS: usize = 40;

/// Fixed-bucket log2 latency histogram over microseconds. Recording is
/// one `fetch_add`; quantiles walk the 40 buckets and return the bucket's
/// inclusive upper bound, so a reported p99 is an overestimate by at most
/// 2× (the bucket width) — plenty for the serving dashboards, and the
/// fixed layout means zero allocation and no coordination between the
/// recording workers and the reading supervisor.
#[derive(Debug)]
pub struct LatencyHisto {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
}

impl Default for LatencyHisto {
    fn default() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
        }
    }
}

impl LatencyHisto {
    pub fn new() -> Self {
        Self::default()
    }

    fn bucket_of(us: u64) -> usize {
        // 0 → 0; [2^(k−1), 2^k) → k; everything past the last bucket clamps
        ((u64::BITS - us.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
    }

    pub fn record_us(&self, us: u64) {
        self.buckets[Self::bucket_of(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// The inclusive upper bound (µs) of the bucket containing the
    /// `q`-quantile sample (0 < q ≤ 1); 0 when nothing was recorded.
    pub fn quantile_us(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let rank = ((q * n as f64).ceil() as u64).clamp(1, n);
        let mut seen = 0u64;
        for (k, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= rank {
                return if k == 0 { 0 } else { (1u64 << k) - 1 };
            }
        }
        (1u64 << (HIST_BUCKETS - 1)) - 1
    }

    pub fn p50_us(&self) -> u64 {
        self.quantile_us(0.50)
    }

    pub fn p99_us(&self) -> u64 {
        self.quantile_us(0.99)
    }
}

#[derive(Debug)]
pub struct Metrics {
    pub jobs_submitted: AtomicU64,
    pub jobs_completed: AtomicU64,
    pub jobs_failed: AtomicU64,
    pub iters_total: AtomicU64,
    pub flops_total: AtomicU64,
    /// Modeled bytes moved by completed jobs (§6.6 traffic model, summed
    /// over solve/path/predict outputs) — the numerator of the ingress
    /// bytes-per-request figure.
    pub bytes_total: AtomicU64,
    /// Worker-side wall time in microseconds (sums across workers, so it
    /// can exceed elapsed wall time — that ratio is pool utilization).
    pub busy_us: AtomicU64,
    /// Jobs (queue entries — a path is one entry) accepted but not yet
    /// picked up by a worker.
    pub queue_depth: AtomicU64,
    /// Seed-pinned in-place retries after a panicked attempt (§6.9); the
    /// DP mechanism stream is bit-identical, so retries cost zero extra ε.
    pub retries: AtomicU64,
    /// Results shed because their cancel token had already fired while
    /// the job was still queued (no solver work spent).
    pub sheds: AtomicU64,
    /// Results whose solve stopped on its wall-clock deadline mid-run
    /// (`StopReason::Deadline` — anytime partial output, not a failure).
    pub timeouts: AtomicU64,
    /// Dead workers the supervisor replaced.
    pub workers_respawned: AtomicU64,
    /// Workers taken out of rotation by the circuit breaker after K
    /// consecutive panicking/dying jobs (DESIGN.md §6.10) — not respawned.
    pub workers_quarantined: AtomicU64,
    /// Quarantined slots re-spawned by the load-driven regrowth policy
    /// (DESIGN.md §6.11): queue backlog over the soft threshold, cooldown
    /// elapsed, pool below strength.
    pub workers_regrown: AtomicU64,
    /// Crashed jobs the supervisor resubmitted from their durable
    /// checkpoint (or from scratch when the crash predated the first
    /// cadence snapshot) instead of failing them (§6.11).
    pub jobs_resumed: AtomicU64,
    /// Explicit ε-ledger fsyncs the pool issued outside the ledger's own
    /// policy — today the graceful-shutdown flush that keeps a clean exit
    /// under `FsyncPolicy::Never`/`EveryN` from looking like a crash at
    /// the next start (§6.12).
    pub flushes: AtomicU64,
    /// Requests the ingress accepted (every one resolves to a structured
    /// outcome; `Admit::Accepted`).
    pub admits: AtomicU64,
    /// Requests the ingress refused outright (`Admit::Shed` — hard queue
    /// watermark or pool down). Distinct from `sheds`, which counts jobs
    /// accepted earlier whose cancel token fired while still queued.
    pub admission_sheds: AtomicU64,
    /// Requests bounced with a retry-after (`Admit::Redirected` — class
    /// token bucket empty).
    pub redirects: AtomicU64,
    /// Jobs admitted with a brownout-reduced iteration cap.
    pub brownout_jobs: AtomicU64,
    /// Times the brownout controller switched from normal to degraded
    /// mode (sustained soft-watermark breach).
    pub brownout_entries: AtomicU64,
    /// Queue-inclusive latency (enqueue → results reported) of
    /// single-cell jobs.
    pub cell_latency: LatencyHisto,
    /// Queue-inclusive latency of whole-path jobs (one sample per path,
    /// not per λ — the path is the unit a client waits on).
    pub path_latency: LatencyHisto,
    /// Queue-inclusive latency of predict jobs.
    pub predict_latency: LatencyHisto,
    started: Instant,
}

impl Default for Metrics {
    fn default() -> Self {
        Self {
            jobs_submitted: AtomicU64::new(0),
            jobs_completed: AtomicU64::new(0),
            jobs_failed: AtomicU64::new(0),
            iters_total: AtomicU64::new(0),
            flops_total: AtomicU64::new(0),
            bytes_total: AtomicU64::new(0),
            busy_us: AtomicU64::new(0),
            queue_depth: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            sheds: AtomicU64::new(0),
            timeouts: AtomicU64::new(0),
            workers_respawned: AtomicU64::new(0),
            workers_quarantined: AtomicU64::new(0),
            workers_regrown: AtomicU64::new(0),
            jobs_resumed: AtomicU64::new(0),
            flushes: AtomicU64::new(0),
            admits: AtomicU64::new(0),
            admission_sheds: AtomicU64::new(0),
            redirects: AtomicU64::new(0),
            brownout_jobs: AtomicU64::new(0),
            brownout_entries: AtomicU64::new(0),
            cell_latency: LatencyHisto::new(),
            path_latency: LatencyHisto::new(),
            predict_latency: LatencyHisto::new(),
            started: Instant::now(),
        }
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_completion(&self, iters: u64, flops: u64, bytes: u64, busy_us: u64) {
        self.jobs_completed.fetch_add(1, Ordering::Relaxed);
        self.iters_total.fetch_add(iters, Ordering::Relaxed);
        self.flops_total.fetch_add(flops, Ordering::Relaxed);
        self.bytes_total.fetch_add(bytes, Ordering::Relaxed);
        self.busy_us.fetch_add(busy_us, Ordering::Relaxed);
    }

    /// Modeled bytes moved per completed request — the ingress cost
    /// figure the roadmap asks for (`0` before anything completes).
    pub fn bytes_per_request(&self) -> u64 {
        let done = self.jobs_completed.load(Ordering::Relaxed);
        self.bytes_total.load(Ordering::Relaxed) / done.max(1)
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Completed solver iterations per wall-clock second.
    pub fn iters_per_sec(&self) -> f64 {
        self.iters_total.load(Ordering::Relaxed) as f64 / self.elapsed_secs().max(1e-9)
    }

    pub fn summary(&self) -> String {
        format!(
            "jobs {}/{} ({} failed), {:.2e} iters, {:.2e} flops, {:.1} iters/s, \
             pool busy {:.2}s, {} B/req | depth {} retries {} sheds {} timeouts {} \
             respawns {} quarantined {} regrown {} resumed {} flushes {} | \
             admit {} shed {} redirect {} brownout {} (entries {}) | \
             cell p50/p99 {}/{} µs, path p50/p99 {}/{} µs, predict p50/p99 {}/{} µs",
            self.jobs_completed.load(Ordering::Relaxed),
            self.jobs_submitted.load(Ordering::Relaxed),
            self.jobs_failed.load(Ordering::Relaxed),
            self.iters_total.load(Ordering::Relaxed) as f64,
            self.flops_total.load(Ordering::Relaxed) as f64,
            self.iters_per_sec(),
            self.busy_us.load(Ordering::Relaxed) as f64 / 1e6,
            self.bytes_per_request(),
            self.queue_depth.load(Ordering::Relaxed),
            self.retries.load(Ordering::Relaxed),
            self.sheds.load(Ordering::Relaxed),
            self.timeouts.load(Ordering::Relaxed),
            self.workers_respawned.load(Ordering::Relaxed),
            self.workers_quarantined.load(Ordering::Relaxed),
            self.workers_regrown.load(Ordering::Relaxed),
            self.jobs_resumed.load(Ordering::Relaxed),
            self.flushes.load(Ordering::Relaxed),
            self.admits.load(Ordering::Relaxed),
            self.admission_sheds.load(Ordering::Relaxed),
            self.redirects.load(Ordering::Relaxed),
            self.brownout_jobs.load(Ordering::Relaxed),
            self.brownout_entries.load(Ordering::Relaxed),
            self.cell_latency.p50_us(),
            self.cell_latency.p99_us(),
            self.path_latency.p50_us(),
            self.path_latency.p99_us(),
            self.predict_latency.p50_us(),
            self.predict_latency.p99_us(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let m = Metrics::new();
        m.jobs_submitted.fetch_add(2, Ordering::Relaxed);
        m.record_completion(100, 5000, 800, 1234);
        m.record_completion(50, 1000, 200, 100);
        assert_eq!(m.jobs_completed.load(Ordering::Relaxed), 2);
        assert_eq!(m.iters_total.load(Ordering::Relaxed), 150);
        assert_eq!(m.flops_total.load(Ordering::Relaxed), 6000);
        assert_eq!(m.bytes_total.load(Ordering::Relaxed), 1000);
        assert_eq!(m.bytes_per_request(), 500);
        let s = m.summary();
        assert!(s.contains("jobs 2/2"), "{s}");
        assert!(s.contains("retries 0"), "{s}");
        assert!(s.contains("500 B/req"), "{s}");
    }

    #[test]
    fn bytes_per_request_is_zero_before_any_completion() {
        let m = Metrics::new();
        assert_eq!(m.bytes_per_request(), 0);
    }

    #[test]
    fn histo_buckets_are_log2_us() {
        assert_eq!(LatencyHisto::bucket_of(0), 0);
        assert_eq!(LatencyHisto::bucket_of(1), 1);
        assert_eq!(LatencyHisto::bucket_of(2), 2);
        assert_eq!(LatencyHisto::bucket_of(3), 2);
        assert_eq!(LatencyHisto::bucket_of(4), 3);
        assert_eq!(LatencyHisto::bucket_of(1023), 10);
        assert_eq!(LatencyHisto::bucket_of(1024), 11);
        assert_eq!(LatencyHisto::bucket_of(u64::MAX), HIST_BUCKETS - 1);
    }

    #[test]
    fn histo_quantiles_walk_the_buckets() {
        let h = LatencyHisto::new();
        assert_eq!(h.p50_us(), 0, "empty histogram reports 0");
        // 98 fast samples (~100 µs) + 2 slow (~100 ms)
        for _ in 0..98 {
            h.record_us(100);
        }
        h.record_us(100_000);
        h.record_us(100_000);
        assert_eq!(h.count(), 100);
        // p50 lands in the [64,128) bucket → upper bound 127
        assert_eq!(h.p50_us(), 127);
        // p99 lands in the slow bucket [65536,131072) → upper bound 131071
        assert_eq!(h.p99_us(), 131_071);
        // extreme quantiles stay in range
        assert_eq!(h.quantile_us(0.01), 127);
        assert_eq!(h.quantile_us(1.0), 131_071);
    }

    #[test]
    fn histo_p99_overestimates_by_at_most_bucket_width() {
        let h = LatencyHisto::new();
        for us in [5u64, 9, 17, 33, 1000, 5000] {
            h.record_us(us);
            assert!(h.quantile_us(1.0) >= us);
            assert!(h.quantile_us(1.0) < us * 2);
            // fresh histogram per sample: drain by rebuilding
            for b in &h.buckets {
                b.store(0, Ordering::Relaxed);
            }
            h.count.store(0, Ordering::Relaxed);
        }
    }
}
