//! Long-lived ingress service in front of the coordinator (DESIGN.md
//! §6.10).
//!
//! The worker pool (§6.9) already guarantees that every *dispatched* job
//! id resolves to a structured outcome. This layer adds the missing
//! serving half: what happens *before* dispatch, when request volume
//! exceeds what the pool can absorb. Every [`Ingress::submit`] returns an
//! explicit [`Admit`] — the caller is never silently dropped:
//!
//! * **Bounded admission.** Each job class ([`JobClass`]: solve / path /
//!   predict) carries its own [`ClassPolicy`]: a hard queue watermark
//!   past which new requests are shed with a reason
//!   ([`Admit::Shed`]), and an optional token-bucket rate limit that
//!   bounces bursts with a computed retry-after ([`Admit::Redirected`]).
//! * **Request coalescing.** The pool's workers share one
//!   [`BootHub`]: concurrent solves over the same [`Dataset`] token fold
//!   their dense bootstrap `α = Xᵀq̄` into a single leader compute that
//!   followers attach to — bit-identical to independent solves (the
//!   bootstrap is deterministic and thread-invariant), with each
//!   follower still charged only its own ε (coalescing shares *compute*,
//!   never mechanism releases).
//! * **Brownout.** Under sustained soft-watermark breach the controller
//!   degrades new solve/path admissions instead of shedding them:
//!   `FwConfig::iter_cap` truncates the run, the result honestly reports
//!   [`StopReason::Brownout`](crate::fw::cancel::StopReason) with
//!   best-so-far weights, and `eps_spent` charges exactly the released
//!   iterations at the noise scale calibrated for the *planned* budget
//!   (`ε·√(cap/T)` — the §6.9 anytime contract).
//! * **Circuit breaker.** [`IngressConfig::breaker_k`] forwards to the
//!   pool's per-worker breaker ([`super::scheduler::PoolOptions`]).
//! * **Budget gate (§6.11).** With a durable ε ledger configured
//!   ([`IngressConfig::durability`]) and a per-dataset budget
//!   ([`IngressConfig::dataset_budget`]), private requests against a
//!   dataset whose cumulative spend cannot absorb their ask are refused
//!   at admission ([`ShedReason::BudgetExhausted`]) — before any
//!   mechanism runs. The gate is planned-spend-inclusive: it checks the
//!   ledger's durable figure (keyed by the dataset's stable content
//!   fingerprint, so refusals survive restarts) *plus* the asks of
//!   requests already admitted this drain cycle but not yet charged, so
//!   a burst of concurrent admissions cannot collectively overshoot the
//!   budget. Private λ-paths are metered like everything else (§6.12):
//!   each grid point runs under its own durable request id, so a path's
//!   ask — the per-run ε once per λ — flows through the same gate and
//!   reservation. And the gate *fails closed*: once the ledger has
//!   refused a write ([`crate::dp::ledger::EpsLedger::failed`]), private
//!   requests are shed ([`ShedReason::LedgerFailed`]) rather than run
//!   with spend the WAL can no longer record.
//!
//! Everything is observable on the shared [`Metrics`]: admit / shed /
//! redirect / brownout counters, per-class queue-inclusive latency, and
//! bytes-per-request.

use std::collections::HashMap;
use std::ops::Range;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::job::{JobSpec, PathJob, PredictJob};
use super::metrics::Metrics;
use super::scheduler::{
    Coordinator, DurabilityOptions, JobOutcome, PoolOptions, RegrowPolicy, RetryPolicy,
};
use crate::fw::config::FwConfig;
use crate::fw::workspace::BootHub;
use crate::sparse::Dataset;

/// Admission class of a request: each class has its own policy, queue
/// accounting, and latency histogram.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobClass {
    /// Single-cell training solve.
    Solve,
    /// Whole λ-path (one queue entry, many results).
    Path,
    /// Batch prediction over frozen weights.
    Predict,
}

impl JobClass {
    pub fn name(&self) -> &'static str {
        match self {
            JobClass::Solve => "solve",
            JobClass::Path => "path",
            JobClass::Predict => "predict",
        }
    }

    fn idx(&self) -> usize {
        match self {
            JobClass::Solve => 0,
            JobClass::Path => 1,
            JobClass::Predict => 2,
        }
    }
}

/// Why a request was refused outright.
#[derive(Clone, Debug, PartialEq)]
pub enum ShedReason {
    /// The class's queue depth reached its hard watermark.
    QueueFull { class: JobClass, depth: usize, watermark: usize },
    /// The ingress was shut down; nothing is dispatched anymore.
    PoolDown,
    /// §6.11 budget gate: the write-ahead ε ledger already records
    /// `spent` against this dataset (keyed by its stable content
    /// fingerprint), another `pending` is reserved by requests admitted
    /// this drain cycle whose charges have not landed yet, and admitting
    /// this request's `ask` on top would exceed
    /// [`IngressConfig::dataset_budget`]. Refused *before* any mechanism
    /// runs — the ledger is the durable source of truth, so the refusal
    /// survives restarts.
    BudgetExhausted { fingerprint: u64, spent: f64, pending: f64, ask: f64, budget: f64 },
    /// §6.12 degradation contract: the write-ahead ε ledger refused a
    /// write earlier and marked itself failed, so new private spend can
    /// no longer be durably recorded. The gate fails *closed* — the
    /// request is shed rather than run unmetered — until an operator
    /// repairs the storage and reopens the ledger. Non-private work
    /// (predictions, non-DP solves) is unaffected.
    LedgerFailed { fingerprint: u64, ask: f64 },
}

/// The admission decision for one request — every call to
/// [`Ingress::submit`] resolves to exactly one of these, so callers
/// always learn what happened (no silent drops).
#[derive(Clone, Debug, PartialEq)]
pub enum Admit {
    /// Enqueued; the ids will each resolve to `Ok`/`Err` in
    /// [`Ingress::drain`] (the §6.9 contract). `browned_out` reports
    /// whether the brownout controller reduced this run's iteration
    /// budget — the result will carry `StopReason::Brownout` and a
    /// correspondingly smaller `eps_spent`.
    Accepted { ids: Range<usize>, browned_out: bool },
    /// Refused with a reason; nothing was enqueued and no id exists.
    Shed(ShedReason),
    /// Rate-limited: nothing was enqueued; retry no sooner than
    /// `retry_after`.
    Redirected { retry_after: Duration },
}

impl Admit {
    /// The admitted ids, if any.
    pub fn ids(&self) -> Option<Range<usize>> {
        match self {
            Admit::Accepted { ids, .. } => Some(ids.clone()),
            _ => None,
        }
    }

    pub fn is_accepted(&self) -> bool {
        matches!(self, Admit::Accepted { .. })
    }
}

/// One request, before the ingress assigns ids. The `id` / `base_id`
/// fields of the payload are overwritten at admission — the ingress owns
/// the id space so outcomes route back unambiguously.
pub enum Request {
    Solve(JobSpec),
    Path(PathJob),
    Predict(PredictJob),
}

impl Request {
    pub fn class(&self) -> JobClass {
        match self {
            Request::Solve(_) => JobClass::Solve,
            Request::Path(_) => JobClass::Path,
            Request::Predict(_) => JobClass::Predict,
        }
    }

    fn n_results(&self) -> usize {
        match self {
            Request::Solve(_) | Request::Predict(_) => 1,
            Request::Path(p) => p.lambdas.len(),
        }
    }

    /// The dataset this request reads (coalescing key material).
    pub fn dataset(&self) -> &Arc<Dataset> {
        match self {
            Request::Solve(s) => &s.data,
            Request::Path(p) => &p.data,
            Request::Predict(p) => &p.data,
        }
    }
}

/// Classic token bucket: `rate` tokens/s refill up to `burst`; one token
/// per admitted request. Deterministic given the wall clock — no RNG.
struct TokenBucket {
    rate: f64,
    burst: f64,
    tokens: f64,
    last: Instant,
}

impl TokenBucket {
    fn new(rate: f64, burst: f64) -> Self {
        // start full so the first burst up to `burst` passes
        Self { rate, burst: burst.max(1.0), tokens: burst.max(1.0), last: Instant::now() }
    }

    /// Take one token, or report how long until one accrues.
    fn try_take(&mut self) -> Result<(), Duration> {
        let now = Instant::now();
        let dt = now.duration_since(self.last).as_secs_f64();
        self.last = now;
        self.tokens = (self.tokens + dt * self.rate).min(self.burst);
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            Ok(())
        } else {
            Err(Duration::from_secs_f64((1.0 - self.tokens) / self.rate.max(1e-9)))
        }
    }
}

/// Per-class admission policy. The default is fully open: no rate limit,
/// no watermarks.
#[derive(Clone, Copy, Debug)]
pub struct ClassPolicy {
    /// Token-bucket refill rate (requests/s); `None` = unlimited.
    pub rate_per_sec: Option<f64>,
    /// Token-bucket capacity (instantaneous burst allowance, min 1).
    pub burst: f64,
    /// Queue depth at and past which new requests of this class are shed
    /// ([`Admit::Shed`] / [`ShedReason::QueueFull`]).
    pub queue_hard: usize,
    /// Queue depth at and past which admissions count as watermark
    /// breaches toward brownout (must be ≤ `queue_hard` to matter).
    pub queue_soft: usize,
}

impl Default for ClassPolicy {
    fn default() -> Self {
        Self {
            rate_per_sec: None,
            burst: 8.0,
            queue_hard: usize::MAX,
            queue_soft: usize::MAX,
        }
    }
}

/// Ingress construction knobs.
#[derive(Clone, Debug)]
pub struct IngressConfig {
    pub solve: ClassPolicy,
    pub path: ClassPolicy,
    pub predict: ClassPolicy,
    /// Consecutive soft-watermark breaches before brownout activates.
    pub brownout_after: u32,
    /// Fraction of the planned update steps (`iters − 1`) a browned-out
    /// run keeps (floored, then clamped up to `brownout_min_iters`).
    pub brownout_frac: f64,
    /// Floor on the browned-out iteration cap — degraded answers must
    /// still be answers.
    pub brownout_min_iters: usize,
    /// Per-worker circuit breaker threshold (0 = disabled); forwarded to
    /// [`PoolOptions::breaker_k`].
    pub breaker_k: u32,
    /// Worker pool size (min 1).
    pub workers: usize,
    /// Seed-pinned retry policy for panicked jobs.
    pub retry: RetryPolicy,
    /// §6.11/§6.12 durability plane, forwarded to
    /// [`PoolOptions::durability`]: cadence checkpoints, the write-ahead
    /// ε ledger, and crash resume for cell solves and λ-path grid points.
    pub durability: Option<DurabilityOptions>,
    /// §6.11 load-driven regrowth of quarantined worker slots, forwarded
    /// to [`PoolOptions::regrow`].
    pub regrow: Option<RegrowPolicy>,
    /// Per-dataset cumulative ε budget. With a ledger configured, a
    /// private request whose ask would push the dataset's durable spend
    /// past this is refused at admission
    /// ([`ShedReason::BudgetExhausted`]). `None` = unmetered.
    pub dataset_budget: Option<f64>,
}

impl Default for IngressConfig {
    fn default() -> Self {
        Self {
            solve: ClassPolicy::default(),
            path: ClassPolicy::default(),
            predict: ClassPolicy::default(),
            brownout_after: 3,
            brownout_frac: 0.5,
            brownout_min_iters: 8,
            breaker_k: 0,
            workers: 2,
            retry: RetryPolicy::default(),
            durability: None,
            regrow: None,
            dataset_budget: None,
        }
    }
}

/// The long-lived ingress: owns the coordinator, the id space, the
/// per-class admission state, and the bootstrap-coalescing hub its
/// workers share.
pub struct Ingress {
    coord: Coordinator,
    cfg: IngressConfig,
    hub: Arc<BootHub>,
    /// Per-class token buckets (index = [`JobClass::idx`]).
    buckets: [Option<TokenBucket>; 3],
    /// Requests admitted this drain cycle, per class (the queue-depth
    /// figure the watermarks compare against; reset by [`Self::drain`]).
    pending: [usize; 3],
    /// §6.11 planned-spend reservations: dataset fingerprint → Σ of the ε
    /// asks of private requests admitted this drain cycle. The ledger only
    /// records spend as runs release selections (with `every_k = 0`, only
    /// at completion), so without this the gate would let a burst of
    /// concurrent admissions each see the same `spent` figure and
    /// collectively overshoot the budget. Cleared by [`Self::drain`]: once
    /// every admitted id has resolved, the real charges are in the ledger
    /// and the reservation hands off to the durable figure.
    inflight_eps: HashMap<u64, f64>,
    next_id: usize,
    /// Consecutive soft-watermark breaches (brownout arms at
    /// `cfg.brownout_after`).
    breaches: u32,
    brownout_active: bool,
    down: bool,
}

impl Ingress {
    pub fn new(cfg: IngressConfig) -> Self {
        let hub = Arc::new(BootHub::new());
        let coord = Coordinator::with_options(
            cfg.workers,
            PoolOptions {
                retry: cfg.retry,
                breaker_k: cfg.breaker_k,
                boot_hub: Some(Arc::clone(&hub)),
                durability: cfg.durability.clone(),
                regrow: cfg.regrow,
            },
        );
        let mk = |p: &ClassPolicy| p.rate_per_sec.map(|r| TokenBucket::new(r, p.burst));
        let buckets = [mk(&cfg.solve), mk(&cfg.path), mk(&cfg.predict)];
        Self {
            coord,
            cfg,
            hub,
            buckets,
            pending: [0; 3],
            inflight_eps: HashMap::new(),
            next_id: 0,
            breaches: 0,
            brownout_active: false,
            down: false,
        }
    }

    fn policy(&self, class: JobClass) -> &ClassPolicy {
        match class {
            JobClass::Solve => &self.cfg.solve,
            JobClass::Path => &self.cfg.path,
            JobClass::Predict => &self.cfg.predict,
        }
    }

    /// Admit or refuse one request. Every accepted id is owed exactly one
    /// outcome from [`Self::drain`]; a shed or redirect enqueues nothing.
    pub fn submit(&mut self, req: Request) -> Admit {
        let m = Arc::clone(&self.coord.metrics);
        let class = req.class();
        if self.down {
            m.admission_sheds.fetch_add(1, Ordering::Relaxed);
            return Admit::Shed(ShedReason::PoolDown);
        }
        let pol = *self.policy(class);
        let depth = self.pending[class.idx()];
        if depth >= pol.queue_hard {
            m.admission_sheds.fetch_add(1, Ordering::Relaxed);
            return Admit::Shed(ShedReason::QueueFull {
                class,
                depth,
                watermark: pol.queue_hard,
            });
        }
        // ---- §6.11/§6.12 budget gate ----------------------------------
        // Refuse private work against a dataset whose ε spend — the
        // write-ahead ledger's durable figure (keyed by content
        // fingerprint, so it includes everything charged before any crash
        // or restart) plus the planned asks of requests admitted this
        // cycle but not yet charged — cannot absorb this request's ask.
        // Checked before the token bucket so a doomed request never
        // consumes rate budget. On acceptance the ask is reserved in
        // `inflight_eps` so the next admission sees it.
        let mut reserve: Option<(u64, f64)> = None;
        if let Some(ledger) =
            self.cfg.durability.as_ref().and_then(|d| d.ledger.as_ref())
        {
            let ask = match &req {
                Request::Solve(s) => s.cfg.privacy.map(|pp| pp.epsilon),
                // every λ cell runs its own mechanism stream under its own
                // durable request id (§6.12): a path asks for the full
                // per-run ε once per λ
                Request::Path(p) => {
                    p.cfg.privacy.map(|pp| pp.epsilon * p.lambdas.len() as f64)
                }
                Request::Predict(_) => None, // post-processing: spends nothing
            };
            if let Some(ask) = ask {
                let fingerprint = req.dataset().fingerprint();
                // §6.12 degradation contract, independent of any budget:
                // a failed ledger can no longer record spend, so private
                // work is shed, never run unmetered (fail closed).
                if ledger.failed() {
                    m.admission_sheds.fetch_add(1, Ordering::Relaxed);
                    return Admit::Shed(ShedReason::LedgerFailed { fingerprint, ask });
                }
                if let Some(budget) = self.cfg.dataset_budget {
                    let spent = ledger.spent_for_dataset(fingerprint);
                    let pending =
                        self.inflight_eps.get(&fingerprint).copied().unwrap_or(0.0);
                    if spent + pending + ask > budget {
                        m.admission_sheds.fetch_add(1, Ordering::Relaxed);
                        return Admit::Shed(ShedReason::BudgetExhausted {
                            fingerprint,
                            spent,
                            pending,
                            ask,
                            budget,
                        });
                    }
                    reserve = Some((fingerprint, ask));
                }
            }
        }
        if let Some(bucket) = &mut self.buckets[class.idx()] {
            if let Err(retry_after) = bucket.try_take() {
                m.redirects.fetch_add(1, Ordering::Relaxed);
                return Admit::Redirected { retry_after };
            }
        }

        // ---- brownout controller (soft watermark) ----------------------
        if depth >= pol.queue_soft {
            self.breaches += 1;
            if self.breaches >= self.cfg.brownout_after && !self.brownout_active {
                self.brownout_active = true;
                m.brownout_entries.fetch_add(1, Ordering::Relaxed);
            }
        } else {
            self.breaches = 0;
            self.brownout_active = false;
        }

        let n = req.n_results();
        let ids = self.next_id..self.next_id + n;
        self.next_id += n;
        let mut browned = false;
        match req {
            Request::Solve(mut s) => {
                s.id = ids.start;
                if self.brownout_active {
                    browned = apply_brownout(&mut s.cfg, &self.cfg);
                }
                self.coord.submit(s);
            }
            Request::Path(mut p) => {
                p.base_id = ids.start;
                if self.brownout_active {
                    browned = apply_brownout(&mut p.cfg, &self.cfg);
                }
                self.coord.submit_path(p);
            }
            Request::Predict(mut p) => {
                // predictions have no iteration budget to degrade
                p.id = ids.start;
                self.coord.submit_predict(p);
            }
        }
        if browned {
            m.brownout_jobs.fetch_add(1, Ordering::Relaxed);
        }
        if let Some((fingerprint, ask)) = reserve {
            *self.inflight_eps.entry(fingerprint).or_insert(0.0) += ask;
        }
        self.pending[class.idx()] += 1;
        m.admits.fetch_add(1, Ordering::Relaxed);
        Admit::Accepted { ids, browned_out: browned }
    }

    /// Block until every admitted id has an outcome; `(id, outcome)`
    /// pairs sorted by id. Resets the per-class queue accounting — a
    /// drained ingress is back below every watermark.
    pub fn drain(&mut self) -> Vec<(usize, JobOutcome)> {
        let out = self.coord.drain_with_ids();
        self.pending = [0; 3];
        // every admitted id has resolved: completed private runs have
        // their charges in the ledger now (the solver appends its
        // completion record before the result leaves the worker), so the
        // planned-spend reservations hand off to the durable figure
        self.inflight_eps.clear();
        out
    }

    /// Stop admitting and tear the pool down; later submissions shed as
    /// [`ShedReason::PoolDown`]. Idempotent.
    pub fn shutdown(&mut self) {
        self.down = true;
        self.coord.shutdown();
    }

    /// Requests of `class` admitted and not yet drained.
    pub fn queue_depth(&self, class: JobClass) -> usize {
        self.pending[class.idx()]
    }

    /// Is the brownout controller currently degrading new admissions?
    pub fn brownout_active(&self) -> bool {
        self.brownout_active
    }

    /// The shared serving metrics (same object the pool records into).
    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.coord.metrics
    }

    /// The bootstrap-coalescing hub (lead/attach/detach telemetry).
    pub fn hub(&self) -> &Arc<BootHub> {
        &self.hub
    }

    /// Workers currently in rotation (shrinks under quarantine).
    pub fn live_workers(&self) -> usize {
        self.coord.live_workers()
    }

    pub fn summary(&self) -> String {
        self.coord.metrics.summary()
    }
}

impl Drop for Ingress {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Tighten `cfg.iter_cap` to the brownout budget: a fraction of the
/// planned update steps (`iters − 1`), floored at `brownout_min_iters`.
/// Returns whether the cap actually reduced this run (a submitter cap
/// that is already tighter is left alone — never raise a cap).
fn apply_brownout(cfg: &mut FwConfig, icfg: &IngressConfig) -> bool {
    let planned = cfg.iters.saturating_sub(1);
    let cap = ((planned as f64) * icfg.brownout_frac).floor() as usize;
    let cap = cap.max(icfg.brownout_min_iters);
    if cap >= planned {
        return false; // tiny runs are cheaper to finish than to degrade
    }
    match cfg.iter_cap {
        Some(existing) if existing <= cap => false,
        _ => {
            cfg.iter_cap = Some(cap);
            true
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::Algo;
    use crate::dp::accounting::PrivacyParams;
    use crate::dp::ledger::{EpsLedger, FsyncPolicy};
    use crate::fw::cancel::{CancelToken, StopReason};
    use crate::fw::config::SelectorKind;
    use crate::sparse::synth::SynthConfig;
    use crate::testkit::faults::FaultPlan;

    fn ds(seed: u64) -> Arc<Dataset> {
        Arc::new(
            SynthConfig {
                name: format!("ing{seed}"),
                n_rows: 80,
                n_cols: 40,
                avg_row_nnz: 6.0,
                zipf_exponent: 1.2,
                n_informative: 8,
                n_dense: 0,
                label_noise: 0.02,
                bias_col: true,
            }
            .generate(seed),
        )
    }

    fn solve_req(data: Arc<Dataset>, iters: usize) -> Request {
        Request::Solve(JobSpec {
            id: 0, // ingress overwrites
            label: "s".into(),
            data,
            algo: Algo::Fast,
            cfg: FwConfig { iters, lambda: 4.0, ..Default::default() },
            test_data: None,
        })
    }

    #[test]
    fn accepts_and_resolves_every_admitted_id() {
        let mut ing = Ingress::new(IngressConfig { workers: 2, ..Default::default() });
        let d = ds(1);
        let mut owed = Vec::new();
        for _ in 0..4 {
            match ing.submit(solve_req(d.clone(), 40)) {
                Admit::Accepted { ids, browned_out } => {
                    assert!(!browned_out);
                    owed.extend(ids);
                }
                other => panic!("open policy must accept: {other:?}"),
            }
        }
        let w = Arc::new(vec![0.0; d.csr.n_cols()]);
        let Admit::Accepted { ids, .. } = ing.submit(Request::Predict(PredictJob {
            id: 0,
            label: "p".into(),
            data: d.clone(),
            weights: w,
            threads: 0,
            cancel: CancelToken::none(),
            fault: FaultPlan::none(),
        })) else {
            panic!("predict must be accepted")
        };
        owed.extend(ids);
        let out = ing.drain();
        assert_eq!(out.len(), owed.len());
        for ((id, outcome), want) in out.iter().zip(&owed) {
            assert_eq!(id, want);
            assert!(outcome.is_ok(), "{outcome:?}");
        }
        let m = ing.metrics();
        assert_eq!(m.admits.load(Ordering::Relaxed), 5);
        assert_eq!(m.admission_sheds.load(Ordering::Relaxed), 0);
        assert_eq!(m.queue_depth.load(Ordering::Relaxed), 0);
        assert!(m.bytes_per_request() > 0);
    }

    #[test]
    fn hard_watermark_sheds_with_reason() {
        let mut ing = Ingress::new(IngressConfig {
            workers: 1,
            solve: ClassPolicy { queue_hard: 2, ..Default::default() },
            ..Default::default()
        });
        let d = ds(2);
        assert!(ing.submit(solve_req(d.clone(), 40)).is_accepted());
        assert!(ing.submit(solve_req(d.clone(), 40)).is_accepted());
        match ing.submit(solve_req(d.clone(), 40)) {
            Admit::Shed(ShedReason::QueueFull { class, depth, watermark }) => {
                assert_eq!(class, JobClass::Solve);
                assert_eq!((depth, watermark), (2, 2));
            }
            other => panic!("expected queue-full shed, got {other:?}"),
        }
        assert_eq!(ing.metrics().admission_sheds.load(Ordering::Relaxed), 1);
        // both accepted ids still resolve; the shed enqueued nothing
        let out = ing.drain();
        assert_eq!(out.len(), 2);
        assert!(out.iter().all(|(_, o)| o.is_ok()));
        // drain resets the class queue: admissions flow again
        assert!(ing.submit(solve_req(d, 40)).is_accepted());
    }

    #[test]
    fn token_bucket_redirects_with_retry_after() {
        let mut ing = Ingress::new(IngressConfig {
            workers: 1,
            predict: ClassPolicy {
                rate_per_sec: Some(0.001),
                burst: 1.0,
                ..Default::default()
            },
            ..Default::default()
        });
        let d = ds(3);
        let w = Arc::new(vec![0.0; d.csr.n_cols()]);
        let req = |d: &Arc<Dataset>, w: &Arc<Vec<f64>>| {
            Request::Predict(PredictJob {
                id: 0,
                label: "p".into(),
                data: d.clone(),
                weights: w.clone(),
                threads: 0,
                cancel: CancelToken::none(),
                fault: FaultPlan::none(),
            })
        };
        assert!(ing.submit(req(&d, &w)).is_accepted(), "burst of 1 admits the first");
        match ing.submit(req(&d, &w)) {
            Admit::Redirected { retry_after } => {
                assert!(retry_after > Duration::ZERO);
            }
            other => panic!("expected redirect, got {other:?}"),
        }
        assert_eq!(ing.metrics().redirects.load(Ordering::Relaxed), 1);
        // solves use a different bucket: unaffected
        assert!(ing.submit(solve_req(d, 40)).is_accepted());
        let out = ing.drain();
        assert_eq!(out.len(), 2);
    }

    #[test]
    fn brownout_degrades_honestly_with_exact_eps_accounting() {
        let mut ing = Ingress::new(IngressConfig {
            workers: 1,
            // soft watermark 0: every admission breaches; brownout arms on
            // the third consecutive breach
            solve: ClassPolicy { queue_soft: 0, ..Default::default() },
            brownout_after: 3,
            brownout_frac: 0.5,
            brownout_min_iters: 8,
            ..Default::default()
        });
        let d = ds(4);
        let iters = 80;
        let pp = PrivacyParams::new(1.0, 1e-6);
        let req = || {
            Request::Solve(JobSpec {
                id: 0,
                label: "b".into(),
                data: d.clone(),
                algo: Algo::Fast,
                cfg: FwConfig {
                    iters,
                    lambda: 4.0,
                    privacy: Some(pp),
                    selector: SelectorKind::Bsls,
                    ..Default::default()
                },
                test_data: None,
            })
        };
        let mut browned_ids = Vec::new();
        for k in 0..5 {
            match ing.submit(req()) {
                Admit::Accepted { ids, browned_out } => {
                    // breaches arm the controller at the 3rd admission
                    assert_eq!(browned_out, k >= 2, "admission {k}");
                    if browned_out {
                        browned_ids.extend(ids);
                    }
                }
                other => panic!("{other:?}"),
            }
        }
        assert!(ing.brownout_active());
        let cap = (((iters - 1) as f64) * 0.5).floor() as usize; // 39
        let out = ing.drain();
        assert_eq!(out.len(), 5);
        for (id, o) in &out {
            let r = o.as_ref().expect("browned-out runs still succeed");
            if browned_ids.contains(id) {
                assert_eq!(r.output.stopped, StopReason::Brownout);
                assert_eq!(r.output.iters_run, cap);
                // exact accounting: the ε of `cap` releases at the noise
                // scale calibrated for the planned T — bitwise
                assert_eq!(r.output.eps_spent, Some(pp.spent_epsilon(iters, cap)));
            } else {
                assert_eq!(r.output.stopped, StopReason::IterBudget);
                assert_eq!(r.output.iters_run, iters - 1);
            }
        }
        assert_eq!(ing.metrics().brownout_jobs.load(Ordering::Relaxed), 3);
        assert_eq!(ing.metrics().brownout_entries.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn brownout_never_raises_an_existing_cap() {
        let icfg = IngressConfig {
            brownout_frac: 0.5,
            brownout_min_iters: 8,
            ..Default::default()
        };
        let mut cfg = FwConfig { iters: 100, ..Default::default() };
        assert!(apply_brownout(&mut cfg, &icfg));
        assert_eq!(cfg.iter_cap, Some(49));
        // a tighter submitter cap survives
        let mut tight = FwConfig { iters: 100, iter_cap: Some(10), ..Default::default() };
        assert!(!apply_brownout(&mut tight, &icfg));
        assert_eq!(tight.iter_cap, Some(10));
        // a looser cap is tightened
        let mut loose = FwConfig { iters: 100, iter_cap: Some(90), ..Default::default() };
        assert!(apply_brownout(&mut loose, &icfg));
        assert_eq!(loose.iter_cap, Some(49));
        // tiny runs are not degraded below the floor
        let mut tiny = FwConfig { iters: 9, ..Default::default() };
        assert!(!apply_brownout(&mut tiny, &icfg));
        assert_eq!(tiny.iter_cap, None);
    }

    #[test]
    fn budget_gate_refuses_private_work_on_an_exhausted_dataset() {
        let dir = std::env::temp_dir()
            .join(format!("dpfw-ing-budget-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let ledger = Arc::new(
            EpsLedger::open(dir.join("eps.wal"), FsyncPolicy::Never).unwrap(),
        );
        let mut ing = Ingress::new(IngressConfig {
            workers: 1,
            durability: Some(DurabilityOptions {
                ledger: Some(Arc::clone(&ledger)),
                dir: dir.clone(),
                every_k: 0,
                resume_in_process: true,
            }),
            dataset_budget: Some(1.5),
            ..Default::default()
        });
        let d = ds(6);
        let pp = PrivacyParams::new(1.0, 1e-6);
        let req = || {
            Request::Solve(JobSpec {
                id: 0,
                label: "q".into(),
                data: d.clone(),
                algo: Algo::Fast,
                cfg: FwConfig {
                    iters: 40,
                    lambda: 4.0,
                    privacy: Some(pp),
                    selector: SelectorKind::Bsls,
                    ..Default::default()
                },
                test_data: None,
            })
        };
        // first request fits (nothing spent yet) and runs to completion,
        // charging ε·√((T−1)/T) ≈ 0.987 against the dataset in the ledger
        assert!(ing.submit(req()).is_accepted());
        let out = ing.drain();
        assert!(out[0].1.is_ok(), "{:?}", out[0].1);
        let spent = ledger.spent_for_dataset(d.fingerprint());
        assert!(spent > 0.9 && spent < 1.0, "spent {spent}");
        // second request asks for another 1.0: 0.987 + 1.0 > 1.5 → shed
        match ing.submit(req()) {
            Admit::Shed(ShedReason::BudgetExhausted {
                fingerprint,
                spent: s,
                pending,
                ask,
                budget,
            }) => {
                assert_eq!(fingerprint, d.fingerprint());
                assert_eq!(s, spent);
                assert_eq!(pending, 0.0, "drained ingress holds no reservations");
                assert_eq!(ask, 1.0);
                assert_eq!(budget, 1.5);
            }
            other => panic!("expected budget shed, got {other:?}"),
        }
        assert_eq!(ing.metrics().admission_sheds.load(Ordering::Relaxed), 1);
        // non-private work on the same dataset stays unmetered
        let w = Arc::new(vec![0.0; d.csr.n_cols()]);
        assert!(ing
            .submit(Request::Predict(PredictJob {
                id: 0,
                label: "p".into(),
                data: d.clone(),
                weights: w,
                threads: 0,
                cancel: CancelToken::none(),
                fault: FaultPlan::none(),
            }))
            .is_accepted());
        let out = ing.drain();
        assert!(out.iter().all(|(_, o)| o.is_ok()));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn budget_gate_counts_admitted_but_uncharged_asks() {
        let dir = std::env::temp_dir()
            .join(format!("dpfw-ing-inflight-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let ledger = Arc::new(
            EpsLedger::open(dir.join("eps.wal"), FsyncPolicy::Never).unwrap(),
        );
        // every_k = 0: nothing reaches the ledger until a run completes,
        // so only the in-flight reservations can stop a same-cycle burst
        let mut ing = Ingress::new(IngressConfig {
            workers: 1,
            durability: Some(DurabilityOptions {
                ledger: Some(Arc::clone(&ledger)),
                dir: dir.clone(),
                every_k: 0,
                resume_in_process: true,
            }),
            dataset_budget: Some(1.5),
            ..Default::default()
        });
        let d = ds(7);
        let pp = PrivacyParams::new(1.0, 1e-6);
        let req = || {
            Request::Solve(JobSpec {
                id: 0,
                label: "q".into(),
                data: d.clone(),
                algo: Algo::Fast,
                cfg: FwConfig {
                    iters: 40,
                    lambda: 4.0,
                    privacy: Some(pp),
                    selector: SelectorKind::Bsls,
                    ..Default::default()
                },
                test_data: None,
            })
        };
        // the first admission reserves its full ask of 1.0 ...
        assert!(ing.submit(req()).is_accepted());
        assert_eq!(ledger.spent_for_dataset(d.fingerprint()), 0.0, "nothing charged yet");
        // ... so the second — same cycle, ledger still empty — must see
        // 0.0 spent + 1.0 pending + 1.0 ask > 1.5 and shed
        match ing.submit(req()) {
            Admit::Shed(ShedReason::BudgetExhausted { spent, pending, ask, .. }) => {
                assert_eq!(spent, 0.0);
                assert_eq!(pending, 1.0);
                assert_eq!(ask, 1.0);
            }
            other => panic!("expected planned-spend shed, got {other:?}"),
        }
        let out = ing.drain();
        assert_eq!(out.len(), 1);
        assert!(out[0].1.is_ok());
        // after the drain the real charge (≈0.987) is durable and the
        // reservation is released; the gate now works off the ledger alone
        let spent = ledger.spent_for_dataset(d.fingerprint());
        assert!(spent > 0.9 && spent < 1.0, "spent {spent}");
        match ing.submit(req()) {
            Admit::Shed(ShedReason::BudgetExhausted { spent: s, pending, .. }) => {
                assert_eq!(s, spent);
                assert_eq!(pending, 0.0);
            }
            other => panic!("expected ledger-backed shed, got {other:?}"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn budget_gate_meters_private_paths_per_lambda() {
        let dir = std::env::temp_dir()
            .join(format!("dpfw-ing-pathmeter-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let ledger = Arc::new(
            EpsLedger::open(dir.join("eps.wal"), FsyncPolicy::Never).unwrap(),
        );
        let mut ing = Ingress::new(IngressConfig {
            workers: 1,
            durability: Some(DurabilityOptions {
                ledger: Some(Arc::clone(&ledger)),
                dir: dir.clone(),
                every_k: 0,
                resume_in_process: true,
            }),
            dataset_budget: Some(100.0),
            ..Default::default()
        });
        let d = ds(8);
        let iters = 40;
        let pp = PrivacyParams::new(1.0, 1e-6);
        let path = |privacy: Option<PrivacyParams>| {
            Request::Path(PathJob {
                base_id: 0,
                label: "p".into(),
                data: d.clone(),
                algo: Algo::Fast,
                cfg: FwConfig {
                    iters,
                    lambda: 1.0,
                    privacy,
                    selector: if privacy.is_some() {
                        SelectorKind::Bsls
                    } else {
                        SelectorKind::Argmax
                    },
                    ..Default::default()
                },
                lambdas: vec![2.0, 4.0, 8.0],
                test_data: None,
            })
        };
        // §6.12: every grid point runs under its own durable request id,
        // so a private path is admitted and metered — the ask (ε per λ,
        // three λs) reserved up front, the real charges durable by drain
        let admit = ing.submit(path(Some(pp)));
        assert!(admit.is_accepted(), "{admit:?}");
        let out = ing.drain();
        assert_eq!(out.len(), 3);
        assert!(out.iter().all(|(_, o)| o.is_ok()));
        let per_run = pp.spent_epsilon(iters, iters - 1);
        let spent = ledger.spent_for_dataset(d.fingerprint());
        assert!(
            (spent - 3.0 * per_run).abs() < 1e-12,
            "three λ charges, one per request id: {spent} vs {}",
            3.0 * per_run
        );
        assert_eq!(ledger.n_requests(), 3, "one WAL request per grid point");
        // a path whose full ask no longer fits is refused up front
        let mut tight = ing;
        tight.cfg.dataset_budget = Some(spent + 2.0 * per_run);
        match tight.submit(path(Some(pp))) {
            Admit::Shed(ShedReason::BudgetExhausted { ask, .. }) => {
                assert_eq!(ask, 3.0, "the gate sees the whole grid's ask");
            }
            other => panic!("expected budget shed, got {other:?}"),
        }
        // non-private paths spend nothing and stay admissible
        assert!(tight.submit(path(None)).is_accepted());
        let out = tight.drain();
        assert_eq!(out.len(), 3);
        assert!(out.iter().all(|(_, o)| o.is_ok()));
        assert!((ledger.spent_for_dataset(d.fingerprint()) - spent).abs() < 1e-12);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn failed_ledger_fails_closed_at_admission() {
        use crate::testkit::io_faults::{IoFaultKind, IoFaultPlane};

        let dir = std::env::temp_dir()
            .join(format!("dpfw-ing-failclosed-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let ledger = Arc::new(
            EpsLedger::open(dir.join("eps.wal"), FsyncPolicy::Always).unwrap(),
        );
        let mut ing = Ingress::new(IngressConfig {
            workers: 1,
            durability: Some(DurabilityOptions {
                ledger: Some(Arc::clone(&ledger)),
                dir: dir.clone(),
                every_k: 0,
                resume_in_process: true,
            }),
            dataset_budget: Some(100.0),
            ..Default::default()
        });
        let d = ds(9);
        let pp = PrivacyParams::new(1.0, 1e-6);
        let private = || {
            Request::Solve(JobSpec {
                id: 0,
                label: "q".into(),
                data: d.clone(),
                algo: Algo::Fast,
                cfg: FwConfig {
                    iters: 40,
                    lambda: 4.0,
                    privacy: Some(pp),
                    selector: SelectorKind::Bsls,
                    ..Default::default()
                },
                test_data: None,
            })
        };
        // break the disk under the WAL: the next write latches `failed`
        ledger.arm_io_faults(IoFaultPlane::once(IoFaultKind::Enospc));
        use crate::dp::ledger::LedgerRecord;
        assert!(ledger
            .append(LedgerRecord {
                request: ledger.allocate_request_id(),
                token: d.fingerprint(),
                planned: 39,
                released: 1,
                eps: 0.1,
            })
            .is_err());
        assert!(ledger.failed());
        // §6.12 degradation contract: private work is shed, never run
        // unmetered against a WAL that can no longer record it
        match ing.submit(private()) {
            Admit::Shed(ShedReason::LedgerFailed { fingerprint, ask }) => {
                assert_eq!(fingerprint, d.fingerprint());
                assert_eq!(ask, 1.0);
            }
            other => panic!("expected fail-closed shed, got {other:?}"),
        }
        assert_eq!(ing.metrics().admission_sheds.load(Ordering::Relaxed), 1);
        // non-private work spends nothing and still flows
        let w = Arc::new(vec![0.0; d.csr.n_cols()]);
        assert!(ing
            .submit(Request::Predict(PredictJob {
                id: 0,
                label: "p".into(),
                data: d.clone(),
                weights: w,
                threads: 0,
                cancel: CancelToken::none(),
                fault: FaultPlan::none(),
            }))
            .is_accepted());
        let out = ing.drain();
        assert_eq!(out.len(), 1);
        assert!(out[0].1.is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shutdown_sheds_as_pool_down() {
        let mut ing = Ingress::new(IngressConfig { workers: 1, ..Default::default() });
        let d = ds(5);
        ing.shutdown();
        match ing.submit(solve_req(d, 40)) {
            Admit::Shed(ShedReason::PoolDown) => {}
            other => panic!("{other:?}"),
        }
        assert_eq!(ing.metrics().admission_sheds.load(Ordering::Relaxed), 1);
    }
}
