//! The worker-pool scheduler: a supervised job queue over std threads
//! (DESIGN.md §6.9, §6.10).
//!
//! Design: one `mpsc` job channel (shared by workers behind a mutex — the
//! jobs are seconds-long solver runs, so receiver contention is
//! irrelevant), one event channel back. Panics in a job are caught and
//! reported as failures rather than poisoning the pool — a failed grid
//! cell must not take down a week-long experiment sweep.
//!
//! Jobs come in three shapes ([`Job`]): single grid cells, whole
//! regularization paths ([`super::job::PathJob`]) that the scheduler
//! deliberately keeps on **one** worker so every λ shares that worker's
//! workspace — and therefore its cached bootstrap (DESIGN.md §6.5) — and
//! batch predictions ([`super::job::PredictJob`]). A path counts as
//! `lambdas.len()` submissions: its per-λ results come back through the
//! same channel with consecutive ids, so [`Coordinator::drain`] and the
//! registry treat path cells and independent cells uniformly.
//!
//! The resilience layer on top (§6.9, §6.10):
//!
//! * **Event-driven supervision.** Worker threads send
//!   [`WorkerEvent::Exited`] from a drop guard the moment they unwind or
//!   return, so `drain` reacts to a death immediately instead of polling
//!   on a tick: it fails the dead worker's in-flight ids as
//!   [`JobError::WorkerDied`] and respawns a replacement on the same
//!   channels — a dead worker costs its current job, never the pool.
//!   Events carry a per-spawn epoch so a stale exit from a replaced
//!   worker can never double-fail a live one; a coarse fallback tick
//!   (1 s) keeps a belt-and-braces `is_finished` scan for the
//!   cannot-happen case of a lost event. The coordinator keeps its own
//!   `result_tx`/`job_rx` clones, so channel disconnects cannot race the
//!   supervisor.
//! * **Shedding.** A job whose cancel token has already fired when a
//!   worker picks it up is failed as [`JobError::Expired`] without any
//!   solver work — the deadline-aware admission half of the serving story
//!   (a deadline that fires *mid-run* instead degrades to the solver's
//!   anytime partial output, which is an `Ok`).
//! * **Seed-pinned retries.** With a retry policy configured, a panicked
//!   job is re-run *in place* (same worker, same workspace) with bounded
//!   exponential backoff. The config — including `FwConfig::seed` — is
//!   untouched between attempts, so the DP mechanism stream of the retry
//!   is bit-identical to the first attempt and the privacy spend does not
//!   grow (property-tested in `tests/coordinator_faults.rs`).
//! * **Circuit breaker.** With [`PoolOptions::breaker_k`] set, a worker
//!   whose jobs panic or die K times *consecutively* (strikes reset on
//!   any success) is quarantined — removed from rotation instead of
//!   respawned — so a persistently poisoned worker stops eating jobs.
//!   The last live worker is never quarantined: the pool degrades, it
//!   does not die.
//! * **Bootstrap coalescing.** With [`PoolOptions::boot_hub`] set, every
//!   worker workspace attaches to the shared [`BootHub`], so concurrent
//!   same-dataset solves fold into one leader bootstrap (§6.10).
//! * **Every owed id resolves.** Each submission ends as exactly one
//!   `Ok(JobResult)` or `Err(JobError)` from `drain`, whatever combination
//!   of panics, deadlines, sheds, quarantines, or worker deaths occurred.

use std::collections::HashMap;
use std::panic::AssertUnwindSafe;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::job::{Job, JobError, JobResult, JobSpec, PathJob, PredictJob};
use super::metrics::Metrics;
use crate::dp::ledger::EpsLedger;
use crate::fw::cancel::StopReason;
use crate::fw::checkpoint::{FwCheckpoint, PathDurability, RunDurability};
use crate::fw::workspace::{BootHub, FwWorkspace};
use crate::testkit::faults::CrashPayload;
use crate::testkit::io_faults::IoFaultPlane;

/// Outcome of one job id: the result, or a structured [`JobError`].
pub type JobOutcome = Result<JobResult, JobError>;

/// Fallback supervisor tick: how long `drain` waits on the event channel
/// before running the belt-and-braces `is_finished` scan. Worker exits
/// are event-driven (the drop guard wakes `drain` immediately), so this
/// only bounds recovery from a lost exit event — which requires the
/// event channel itself to fail — and can afford to be coarse.
const FALLBACK_TICK: Duration = Duration::from_secs(1);

/// Ceiling on the per-retry backoff sleep (the policy doubles from
/// [`RetryPolicy::backoff_base`] per attempt).
const RETRY_BACKOFF_CAP: Duration = Duration::from_millis(250);

/// How panicked jobs are retried (§6.9). Retries happen in place on the
/// worker with the job's config untouched, so the DP mechanism stream —
/// and hence the ε spend — is bit-identical across attempts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Additional attempts after the first (0 = fail on first panic,
    /// reporting [`JobError::Panicked`] exactly as the pre-§6.9 pool did).
    pub retry_limit: u32,
    /// First backoff sleep; doubles per attempt, capped at
    /// [`RETRY_BACKOFF_CAP`].
    pub backoff_base: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self { retry_limit: 0, backoff_base: Duration::from_millis(5) }
    }
}

impl RetryPolicy {
    pub fn retries(retry_limit: u32) -> Self {
        Self { retry_limit, ..Default::default() }
    }

    fn backoff(&self, attempt: u32) -> Duration {
        let mult = 1u32 << attempt.min(16);
        (self.backoff_base * mult).min(RETRY_BACKOFF_CAP)
    }
}

/// §6.11/§6.12 durability plane: arm cadence checkpoints and write-ahead
/// ε-ledger records on every solve the pool runs — single cells and λ-path
/// grid points alike — and let the supervisor resume a crashed worker's
/// job from its latest checkpoints instead of failing it.
#[derive(Clone, Debug)]
pub struct DurabilityOptions {
    /// Write-ahead ε ledger, shared with ingress admission (which refuses
    /// new work once a dataset's budget is exhausted). `None` = checkpoint
    /// without accounting.
    pub ledger: Option<Arc<EpsLedger>>,
    /// Directory for per-job checkpoint files, named by durable ledger
    /// request id — `ckpt-<req>.bin` for cells, `ckpt-<req>-<k>.bin` for
    /// grid point `k` of a λ-path — never by the per-process result id,
    /// which a restarted service would reuse. Must exist.
    pub dir: PathBuf,
    /// Checkpoint cadence in solver iterations (0 = only at interruption
    /// stop points).
    pub every_k: usize,
    /// When `true` (production default), a crashed worker's armed job is
    /// resubmitted in-process from its latest checkpoints. Restart tests
    /// set `false` so a kill leaves the on-disk state (checkpoints + WAL)
    /// exactly as a dead process would, for
    /// [`super::recovery::RecoveryManager`] to pick up.
    pub resume_in_process: bool,
}

/// Load-driven regrowth of quarantined worker slots (§6.11). Quarantine
/// (the §6.9 circuit breaker) permanently shrinks the pool; with a regrow
/// policy set, the supervisor re-spawns one fresh worker — clean strike
/// record — whenever the pool is below strength, the queue is deeper than
/// `queue_soft`, and `cooldown` has elapsed since the last regrowth. One
/// slot per cooldown window, so a genuinely poisoned environment
/// re-quarantines at a bounded rate instead of flapping.
#[derive(Clone, Copy, Debug)]
pub struct RegrowPolicy {
    /// Minimum time between regrow events.
    pub cooldown: Duration,
    /// Regrow only while `queue_depth` exceeds this backlog.
    pub queue_soft: usize,
}

impl Default for RegrowPolicy {
    fn default() -> Self {
        Self { cooldown: Duration::from_secs(5), queue_soft: 0 }
    }
}

/// Pool construction knobs beyond the worker count (§6.10).
#[derive(Clone, Default)]
pub struct PoolOptions {
    /// Seed-pinned in-place retry policy for panicked jobs.
    pub retry: RetryPolicy,
    /// Circuit breaker: quarantine a worker after this many *consecutive*
    /// failed (panicked or died) jobs; `0` disables. Strikes reset on any
    /// successful job, and the last live worker is never quarantined.
    pub breaker_k: u32,
    /// Ingress-scoped bootstrap coalescing hub, installed into every
    /// worker's workspace so concurrent same-dataset solves share one
    /// leader bootstrap.
    pub boot_hub: Option<Arc<BootHub>>,
    /// §6.11 durability plane (checkpoints + ε ledger + crash resume).
    pub durability: Option<DurabilityOptions>,
    /// §6.11 load-driven regrowth of quarantined slots.
    pub regrow: Option<RegrowPolicy>,
}

/// What travels down the job channel: the job plus its enqueue time, so
/// the latency histograms measure queue wait + solve, not solve alone.
struct Dispatch {
    job: Job,
    enqueued_at: Instant,
}

/// One durability-armed job parked by the supervisor until every one of
/// its result ids resolves (§6.11/§6.12). Holds the armed clone for crash
/// resubmission, plus the per-result checkpoint file and durable request
/// id — parallel to the job's id range — for resume lookup and GC.
struct PendingJob {
    job: Job,
    /// Checkpoint file per result id (`files[id - base]`).
    files: Vec<PathBuf>,
    /// Durable ledger request id per result id.
    request_ids: Vec<u64>,
    /// Result ids not yet resolved; the entry is dropped at zero.
    unresolved: usize,
    /// In-process recovery already used its one attempt.
    resumed: bool,
}

/// What travels back up from the workers.
enum WorkerEvent {
    /// One job id resolved.
    Result(usize, JobOutcome),
    /// A worker thread is exiting (sent from a drop guard, so it fires on
    /// clean return, self-quarantine, and abrupt death alike). `epoch`
    /// pins the event to one spawn: a stale exit from an already-replaced
    /// worker is ignored instead of double-failing its successor.
    Exited { worker_id: usize, epoch: u64, cause: ExitCause },
}

/// Why a worker thread exited.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ExitCause {
    /// Job channel closed (coordinator shutdown) — expected, no respawn.
    Shutdown,
    /// The thread died without finishing its job (fault-injected abrupt
    /// death, or a bug): fail the owed ids, strike, respawn or quarantine.
    Died,
    /// The worker tripped its own circuit breaker after reporting K
    /// consecutive failures (all ids already resolved — nothing owed).
    Quarantine,
}

/// Sends [`WorkerEvent::Exited`] however the worker body ends — clean
/// return sets `cause` first; an unwind leaves the `Died` default.
struct ExitGuard {
    tx: mpsc::Sender<WorkerEvent>,
    worker_id: usize,
    epoch: u64,
    cause: ExitCause,
}

impl Drop for ExitGuard {
    fn drop(&mut self) {
        let _ = self.tx.send(WorkerEvent::Exited {
            worker_id: self.worker_id,
            epoch: self.epoch,
            cause: self.cause,
        });
    }
}

/// One worker thread plus the in-flight slot the supervisor reads when
/// the thread dies: the result ids of the job it was running, `None`
/// between jobs. The slot is set *before* the job starts and cleared
/// only after every result was sent, so a death at any point in between
/// leaves exactly the owed ids behind. `strikes` (consecutive failures,
/// shared with the thread) survives respawn so a worker that keeps dying
/// still walks toward the breaker.
struct WorkerSlot {
    handle: JoinHandle<()>,
    inflight: Arc<Mutex<Option<std::ops::Range<usize>>>>,
    worker_id: usize,
    epoch: u64,
    strikes: Arc<AtomicU32>,
}

/// Everything one worker thread needs (bundled so the spawn site stays
/// readable).
struct WorkerCtx {
    rx: Arc<Mutex<mpsc::Receiver<Dispatch>>>,
    tx: mpsc::Sender<WorkerEvent>,
    metrics: Arc<Metrics>,
    inflight: Arc<Mutex<Option<std::ops::Range<usize>>>>,
    n_workers: usize,
    retry: RetryPolicy,
    breaker_k: u32,
    strikes: Arc<AtomicU32>,
    boot_hub: Option<Arc<BootHub>>,
}

pub struct Coordinator {
    job_tx: Option<mpsc::Sender<Dispatch>>,
    /// Kept so worker deaths can never disconnect the job channel out
    /// from under `drain` (the supervisor, not channel state, decides
    /// what a missing result means).
    job_rx: Arc<Mutex<mpsc::Receiver<Dispatch>>>,
    result_tx: mpsc::Sender<WorkerEvent>,
    result_rx: mpsc::Receiver<WorkerEvent>,
    workers: Vec<WorkerSlot>,
    pub metrics: Arc<Metrics>,
    n_workers: usize,
    opts: PoolOptions,
    /// Monotone spawn counter: each (re)spawn gets a fresh epoch so exit
    /// events can be matched to exactly one thread generation.
    epochs: u64,
    submitted: usize,
    /// Outcomes produced without a worker (e.g. submissions after
    /// shutdown → [`JobError::PoolDied`]), merged into the next `drain`.
    local: Vec<(usize, JobOutcome)>,
    /// §6.11/§6.12 crash-recovery ledger: durability-armed jobs keyed by
    /// their base result id, kept until every id resolves (completed ids
    /// GC their checkpoint files as they land). A crashed worker's owed
    /// job is resubmitted once, whole, from its latest checkpoints; the
    /// `resumed` flag is what bounds recovery to one in-process attempt.
    pending: HashMap<usize, PendingJob>,
    /// Result id → base id of its [`PendingJob`] (a path owes many ids).
    pending_index: HashMap<usize, usize>,
    /// Durable request-id source when no ledger is configured: seeded
    /// lazily from the checkpoint dir's filename high-water mark so a
    /// restarted process never reuses a dead process's checkpoint names.
    next_fallback_req: Option<u64>,
    /// When the last regrow event fired (rate limit).
    last_regrow: Option<Instant>,
    /// Monotone id source for regrown workers (original ids stay taken by
    /// their quarantined threads' late events).
    next_worker_id: usize,
}

impl Coordinator {
    /// Spawn `n_workers` worker threads (min 1) with default options.
    pub fn new(n_workers: usize) -> Self {
        Self::with_options(n_workers, PoolOptions::default())
    }

    /// Spawn `n_workers` worker threads (min 1) with the given retry
    /// policy for panicked jobs.
    pub fn with_retry(n_workers: usize, retry: RetryPolicy) -> Self {
        Self::with_options(n_workers, PoolOptions { retry, ..Default::default() })
    }

    /// Spawn `n_workers` worker threads (min 1) with full pool options
    /// (retry policy, circuit breaker, bootstrap coalescing hub).
    pub fn with_options(n_workers: usize, opts: PoolOptions) -> Self {
        let n_workers = n_workers.max(1);
        let (job_tx, job_rx) = mpsc::channel::<Dispatch>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let (result_tx, result_rx) = mpsc::channel();
        let metrics = Arc::new(Metrics::new());
        let mut this = Self {
            job_tx: Some(job_tx),
            job_rx,
            result_tx,
            result_rx,
            workers: Vec::with_capacity(n_workers),
            metrics,
            n_workers,
            opts,
            epochs: 0,
            submitted: 0,
            local: Vec::new(),
            pending: HashMap::new(),
            pending_index: HashMap::new(),
            next_fallback_req: None,
            last_regrow: None,
            next_worker_id: n_workers,
        };
        for worker_id in 0..n_workers {
            let slot = this.spawn_worker(worker_id, Arc::new(AtomicU32::new(0)));
            this.workers.push(slot);
        }
        this
    }

    /// How many workers are currently in rotation (shrinks under
    /// quarantine, never below one).
    pub fn live_workers(&self) -> usize {
        self.workers.len()
    }

    fn spawn_worker(&mut self, worker_id: usize, strikes: Arc<AtomicU32>) -> WorkerSlot {
        self.epochs += 1;
        let epoch = self.epochs;
        let inflight: Arc<Mutex<Option<std::ops::Range<usize>>>> =
            Arc::new(Mutex::new(None));
        let ctx = WorkerCtx {
            rx: Arc::clone(&self.job_rx),
            tx: self.result_tx.clone(),
            metrics: Arc::clone(&self.metrics),
            inflight: Arc::clone(&inflight),
            n_workers: self.n_workers,
            retry: self.opts.retry,
            breaker_k: self.opts.breaker_k,
            strikes: Arc::clone(&strikes),
            boot_hub: self.opts.boot_hub.clone(),
        };
        let guard_tx = self.result_tx.clone();
        let handle = std::thread::Builder::new()
            .name(format!("dpfw-worker-{worker_id}"))
            .spawn(move || {
                let mut guard =
                    ExitGuard { tx: guard_tx, worker_id, epoch, cause: ExitCause::Died };
                guard.cause = worker_loop(ctx);
            })
            .expect("spawn worker");
        WorkerSlot { handle, inflight, worker_id, epoch, strikes }
    }

    /// Enqueue a single-cell job (non-blocking).
    pub fn submit(&mut self, job: JobSpec) {
        self.submit_job(Job::Cell(job));
    }

    /// Enqueue a whole λ-path as one unit of work: it will run on a single
    /// worker, sharing that worker's workspace (and bootstrap cache)
    /// across every λ. Counts as `lambdas.len()` submissions — `drain`
    /// returns one outcome per λ, ids `base_id..base_id + len`.
    pub fn submit_path(&mut self, path: PathJob) {
        assert!(!path.lambdas.is_empty(), "empty lambda grid");
        self.submit_job(Job::Path(path));
    }

    /// Enqueue a batch prediction (§6.10 job class three).
    pub fn submit_predict(&mut self, job: PredictJob) {
        self.submit_job(Job::Predict(job));
    }

    pub(crate) fn submit_job(&mut self, mut job: Job) {
        let n = job.n_results();
        self.metrics.jobs_submitted.fetch_add(n as u64, Ordering::Relaxed);
        self.submitted += n;
        // ---- §6.11/§6.12 durability arming ------------------------------
        // The armed clone is parked in `pending` so a crashed worker's
        // owed job can be resubmitted from its checkpoints.
        if self.opts.durability.is_some() {
            if let Some(entry) = self.arm_job(&mut job) {
                let base = job.result_ids().start;
                for id in job.result_ids() {
                    self.pending_index.insert(id, base);
                }
                self.pending.insert(base, entry);
            }
        }
        // Gauge up BEFORE the send: the instant the job hits the channel a
        // worker may pick it up and gauge down, and a decrement racing
        // ahead of its increment would wrap the unsigned gauge upward.
        self.metrics.queue_depth.fetch_add(1, Ordering::Relaxed);
        let dispatch = Dispatch { job, enqueued_at: Instant::now() };
        let undelivered = match &self.job_tx {
            Some(tx) => tx.send(dispatch).err().map(|e| e.0),
            None => Some(dispatch),
        };
        if let Some(d) = undelivered {
            // pool gone (shutdown): the job degrades to per-id PoolDied
            // outcomes instead of panicking the caller
            self.metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
            for id in d.job.result_ids() {
                self.resolve_pending(id, false);
                self.metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
                self.local.push((id, Err(JobError::PoolDied)));
            }
        }
    }

    /// §6.12 restart-time resubmission: enqueue `job` armed under the
    /// durable request ids and resume snapshots a
    /// [`super::recovery::RecoveryManifest`] recovered from a dead
    /// process's durability dir ([`super::recovery::RecoveredSlot`], one
    /// per result id in result order — [`RecoveryManifest::slots_for`]
    /// builds them). Reusing the *original* request ids is what makes
    /// the rerun exactly-once in ε: every re-charge max-merges into the
    /// WAL record the dead process already wrote, so the request's total
    /// stays one run's worth however many times it crashed. Slots with a
    /// snapshot resume mid-solve (bitwise identical to the uninterrupted
    /// run); slots without one — crash before the first cadence
    /// boundary, or a quarantined orphan — rerun fresh, seed-pinned.
    ///
    /// Panics if the pool has no durability plane, the slot count
    /// doesn't match the job's result count, or the job is a prediction
    /// (stateless; nothing to recover).
    ///
    /// [`RecoveryManifest::slots_for`]: super::recovery::RecoveryManifest::slots_for
    pub fn submit_recovered(
        &mut self,
        mut job: Job,
        slots: &[super::recovery::RecoveredSlot],
    ) {
        let n = job.n_results();
        assert_eq!(slots.len(), n, "one recovered slot per result id");
        let dur = self
            .opts
            .durability
            .as_ref()
            .expect("submit_recovered requires a durability-armed pool");
        let (ledger, dir, every_k) = (dur.ledger.clone(), dur.dir.clone(), dur.every_k);
        let entry = match &job {
            Job::Predict(_) => panic!("predictions are stateless; nothing to recover"),
            Job::Cell(_) => {
                let slot = &slots[0];
                let path = dir.join(format!("ckpt-{}.bin", slot.request_id));
                let run = Arc::new(RunDurability {
                    request_id: slot.request_id,
                    path: path.clone(),
                    ledger,
                    every_k,
                    io: IoFaultPlane::none(),
                });
                job.arm_durability(run);
                if let Some(ck) = &slot.resume {
                    job.set_resume(ck.clone());
                }
                PendingJob {
                    job: job.clone(),
                    files: vec![path],
                    request_ids: vec![slot.request_id],
                    unresolved: 1,
                    resumed: false,
                }
            }
            Job::Path(_) => {
                let files: Vec<PathBuf> = slots
                    .iter()
                    .enumerate()
                    .map(|(k, s)| dir.join(format!("ckpt-{}-{k}.bin", s.request_id)))
                    .collect();
                let cells = slots
                    .iter()
                    .zip(&files)
                    .map(|(s, f)| {
                        Arc::new(RunDurability {
                            request_id: s.request_id,
                            path: f.clone(),
                            ledger: ledger.clone(),
                            every_k,
                            io: IoFaultPlane::none(),
                        })
                    })
                    .collect();
                let resumes = slots.iter().map(|s| s.resume.clone()).collect();
                job.arm_path_durability(Arc::new(PathDurability { cells, resumes }));
                PendingJob {
                    job: job.clone(),
                    files,
                    request_ids: slots.iter().map(|s| s.request_id).collect(),
                    unresolved: n,
                    resumed: false,
                }
            }
        };
        self.metrics.jobs_submitted.fetch_add(n as u64, Ordering::Relaxed);
        self.submitted += n;
        let base = job.result_ids().start;
        for id in job.result_ids() {
            self.pending_index.insert(id, base);
        }
        self.pending.insert(base, entry);
        self.metrics.queue_depth.fetch_add(1, Ordering::Relaxed);
        let dispatch = Dispatch { job, enqueued_at: Instant::now() };
        let undelivered = match &self.job_tx {
            Some(tx) => tx.send(dispatch).err().map(|e| e.0),
            None => Some(dispatch),
        };
        if let Some(d) = undelivered {
            self.metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
            for id in d.job.result_ids() {
                self.resolve_pending(id, false);
                self.metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
                self.local.push((id, Err(JobError::PoolDied)));
            }
        }
    }

    /// Arm durability on one job: a durable request id, a
    /// request-id-named checkpoint file, and a cadence/ledger plan per
    /// solve — one [`RunDurability`] for a cell, one per grid point
    /// (via [`PathDurability`]) for a λ-path. Predictions are stateless
    /// and spend nothing, so they stay unarmed (`None`).
    fn arm_job(&mut self, job: &mut Job) -> Option<PendingJob> {
        let n = job.n_results();
        let dur = self.opts.durability.as_ref().expect("arming requires durability");
        let (ledger, dir, every_k) = (dur.ledger.clone(), dur.dir.clone(), dur.every_k);
        match job {
            Job::Predict(_) => None,
            Job::Cell(_) => {
                let req = self.durable_request_id();
                let path = dir.join(format!("ckpt-{req}.bin"));
                let run = Arc::new(RunDurability {
                    request_id: req,
                    path: path.clone(),
                    ledger,
                    every_k,
                    io: IoFaultPlane::none(),
                });
                job.arm_durability(run);
                Some(PendingJob {
                    job: job.clone(),
                    files: vec![path],
                    request_ids: vec![req],
                    unresolved: 1,
                    resumed: false,
                })
            }
            Job::Path(_) => {
                // One durable request id per grid point: each λ spends its
                // own ε and checkpoints into its own file, so a crashed
                // path resumes at its last completed point with the WAL
                // holding exactly one charge per point.
                let reqs: Vec<u64> = (0..n).map(|_| self.durable_request_id()).collect();
                let files: Vec<PathBuf> = reqs
                    .iter()
                    .enumerate()
                    .map(|(k, req)| dir.join(format!("ckpt-{req}-{k}.bin")))
                    .collect();
                let cells = reqs
                    .iter()
                    .zip(&files)
                    .map(|(&req, f)| {
                        Arc::new(RunDurability {
                            request_id: req,
                            path: f.clone(),
                            ledger: ledger.clone(),
                            every_k,
                            io: IoFaultPlane::none(),
                        })
                    })
                    .collect();
                let plan = Arc::new(PathDurability { cells, resumes: vec![None; n] });
                job.arm_path_durability(plan);
                Some(PendingJob {
                    job: job.clone(),
                    files,
                    request_ids: reqs,
                    unresolved: n,
                    resumed: false,
                })
            }
        }
    }

    /// The ledger file outlives this process, so the idempotency key (and
    /// the checkpoint filename) cannot be the per-process result id — a
    /// restarted service would reuse a dead process's id and the
    /// max-merge would swallow the new request's charge as a stale
    /// replay. The ledger allocates above its durable high-water mark;
    /// with no ledger the checkpoint dir's filename high-water mark
    /// stands in.
    fn durable_request_id(&mut self) -> u64 {
        let dur = self.opts.durability.as_ref().expect("arming requires durability");
        if let Some(ledger) = &dur.ledger {
            return ledger.allocate_request_id();
        }
        let next = match self.next_fallback_req {
            Some(n) => n,
            None => checkpoint_dir_high_water(&dur.dir) + 1,
        };
        self.next_fallback_req = Some(next + 1);
        next
    }

    /// Resolve one result id against the pending ledger: a completed id
    /// GCs its checkpoint file (the snapshot exists to survive a crash,
    /// not to outlive success); a failed id keeps the file on disk for
    /// restart-time recovery. The entry is dropped once every id
    /// resolved.
    fn resolve_pending(&mut self, id: usize, completed: bool) {
        let Some(base) = self.pending_index.remove(&id) else { return };
        let Some(entry) = self.pending.get_mut(&base) else { return };
        if completed {
            if let Some(f) = entry.files.get(id - base) {
                let _ = std::fs::remove_file(f);
            }
        }
        entry.unresolved = entry.unresolved.saturating_sub(1);
        if entry.unresolved == 0 {
            self.pending.remove(&base);
        }
    }

    /// Close the job queue and join every worker (queued jobs still run
    /// to completion first; their results remain drainable). Later
    /// submissions resolve as [`JobError::PoolDied`]. Idempotent; `Drop`
    /// calls it.
    ///
    /// A graceful shutdown also flushes the ε ledger: under
    /// [`crate::dp::ledger::FsyncPolicy::Never`]/`EveryN` the tail of the
    /// WAL may still sit in the page cache, and losing completion records
    /// on a *clean* exit would make every restart look like a crash.
    pub fn shutdown(&mut self) {
        let first = self.job_tx.take().is_some();
        for w in self.workers.drain(..) {
            let _ = w.handle.join();
        }
        if first {
            if let Some(ledger) =
                self.opts.durability.as_ref().and_then(|d| d.ledger.as_ref())
            {
                if ledger.sync().is_ok() {
                    self.metrics.flushes.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
    }

    /// Block until every submitted id has an outcome; results are
    /// returned sorted by job id. Never panics on worker death: the
    /// exit event fails the dead worker's owed ids as
    /// [`JobError::WorkerDied`] and respawns (or quarantines) it.
    pub fn drain(&mut self) -> Vec<JobOutcome> {
        self.drain_with_ids().into_iter().map(|(_, o)| o).collect()
    }

    /// [`Self::drain`], keeping each outcome's job id (the ingress needs
    /// the pairing to route outcomes back to admissions). Sorted by id.
    pub fn drain_with_ids(&mut self) -> Vec<(usize, JobOutcome)> {
        let mut out: Vec<(usize, JobOutcome)> = std::mem::take(&mut self.local);
        while out.len() < self.submitted {
            self.maybe_regrow();
            match self.result_rx.recv_timeout(FALLBACK_TICK) {
                Ok(WorkerEvent::Result(id, outcome)) => {
                    self.resolve_pending(id, outcome.is_ok());
                    out.push((id, outcome));
                }
                Ok(WorkerEvent::Exited { worker_id, epoch, cause }) => {
                    self.on_worker_exit(worker_id, epoch, cause, &mut out);
                }
                // we hold a result_tx clone, so Disconnected is
                // unreachable; either way fall back to the liveness scan
                Err(_) => self.supervise(&mut out),
            }
        }
        self.submitted = 0;
        out.sort_by_key(|(id, _)| *id);
        out
    }

    /// Handle one worker-exit event. Stale epochs (a replaced worker's
    /// event arriving late) match no slot and are ignored.
    fn on_worker_exit(
        &mut self,
        worker_id: usize,
        epoch: u64,
        cause: ExitCause,
        out: &mut Vec<(usize, JobOutcome)>,
    ) {
        let Some(pos) = self
            .workers
            .iter()
            .position(|w| w.worker_id == worker_id && w.epoch == epoch)
        else {
            return;
        };
        let slot = self.workers.swap_remove(pos);
        let _ = slot.handle.join();
        match cause {
            // expected teardown: nothing owed, nothing to replace
            ExitCause::Shutdown => {}
            ExitCause::Died => {
                let owed =
                    slot.inflight.lock().unwrap_or_else(|e| e.into_inner()).take();
                if let Some(ids) = owed {
                    // The owed range is exactly one job's ids (the
                    // in-flight slot is per-dispatch). §6.11/§6.12: a
                    // durability-armed job gets one whole-job resume
                    // attempt from its latest checkpoints — covering every
                    // owed id at once — before the ids are failed the
                    // pre-durability way.
                    if !self.try_resume(ids.start) {
                        for id in ids {
                            self.resolve_pending(id, false);
                            self.metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
                            out.push((id, Err(JobError::WorkerDied)));
                        }
                    }
                }
                let strikes = slot.strikes;
                strikes.fetch_add(1, Ordering::Relaxed);
                let tripped = self.opts.breaker_k > 0
                    && strikes.load(Ordering::Relaxed) >= self.opts.breaker_k;
                if tripped && !self.workers.is_empty() {
                    self.metrics.workers_quarantined.fetch_add(1, Ordering::Relaxed);
                } else {
                    if tripped {
                        // forced respawn (last live worker): clean slate so
                        // the replacement isn't pre-tripped
                        strikes.store(0, Ordering::Relaxed);
                    }
                    self.metrics.workers_respawned.fetch_add(1, Ordering::Relaxed);
                    let replacement = self.spawn_worker(worker_id, strikes);
                    self.workers.push(replacement);
                }
            }
            ExitCause::Quarantine => {
                // the worker resolved all its ids before exiting
                if !self.workers.is_empty() {
                    self.metrics.workers_quarantined.fetch_add(1, Ordering::Relaxed);
                } else {
                    slot.strikes.store(0, Ordering::Relaxed);
                    self.metrics.workers_respawned.fetch_add(1, Ordering::Relaxed);
                    let replacement = self.spawn_worker(worker_id, slot.strikes);
                    self.workers.push(replacement);
                }
            }
        }
    }

    /// Belt-and-braces liveness scan, run only on the fallback tick: a
    /// finished thread whose exit event was somehow lost is treated as
    /// died. (Normally the event arrives first and removes the slot, so
    /// this scan finds nothing; a later duplicate event then matches no
    /// slot and is ignored — the two paths cannot double-handle a worker.)
    fn supervise(&mut self, out: &mut Vec<(usize, JobOutcome)>) {
        let finished: Vec<(usize, u64)> = self
            .workers
            .iter()
            .filter(|w| w.handle.is_finished())
            .map(|w| (w.worker_id, w.epoch))
            .collect();
        for (worker_id, epoch) in finished {
            self.on_worker_exit(worker_id, epoch, ExitCause::Died, out);
        }
    }

    /// §6.11/§6.12 crash recovery: if `id` belongs to a durability-armed
    /// job still in `pending`, resubmit the whole job — each solve
    /// resuming from its latest on-disk checkpoint when one exists, from
    /// scratch otherwise (a crash before the first cadence boundary
    /// leaves no file; a seed-pinned fresh run is the correct recovery
    /// and the ledger's max-merge keeps the ε accounting exactly-once
    /// either way). A λ-path resumes at its last completed grid point:
    /// already-finished points replay their final snapshots (bitwise
    /// no-ops), the interrupted point resumes mid-solve, and the
    /// never-started points run fresh. Setting `resumed` here is what
    /// bounds recovery to a single in-process attempt: a second crash
    /// finds the flag set and fails as [`JobError::WorkerDied`]. With
    /// [`DurabilityOptions::resume_in_process`] off, crashes are left for
    /// restart-time recovery instead.
    fn try_resume(&mut self, id: usize) -> bool {
        if !self.opts.durability.as_ref().is_some_and(|d| d.resume_in_process) {
            return false;
        }
        let Some(tx) = self.job_tx.clone() else { return false };
        let Some(&base) = self.pending_index.get(&id) else { return false };
        let Some(entry) = self.pending.get_mut(&base) else { return false };
        if entry.resumed {
            return false;
        }
        entry.resumed = true;
        let mut job = entry.job.clone();
        let snapshots: Vec<Option<Arc<FwCheckpoint>>> = entry
            .files
            .iter()
            .map(|path| {
                if !path.exists() {
                    return None;
                }
                match FwCheckpoint::read_from(path) {
                    Ok(ck) => Some(Arc::new(ck)),
                    Err(e) => {
                        // torn/corrupt snapshot: recover from scratch
                        // rather than refuse recovery (the CRC already
                        // dropped it)
                        eprintln!(
                            "[dpfw] checkpoint {path:?} unreadable ({e}); \
                             resuming from scratch"
                        );
                        None
                    }
                }
            })
            .collect();
        match &mut job {
            Job::Cell(_) => {
                if let Some(ck) = snapshots.into_iter().next().flatten() {
                    job.set_resume(ck);
                }
            }
            Job::Path(p) => {
                let cells = p
                    .cfg
                    .path_durability
                    .as_ref()
                    .map(|plan| plan.cells.clone())
                    .unwrap_or_default();
                let plan = Arc::new(PathDurability { cells, resumes: snapshots });
                job.arm_path_durability(plan);
            }
            Job::Predict(_) => return false,
        }
        self.metrics.jobs_resumed.fetch_add(1, Ordering::Relaxed);
        self.metrics.queue_depth.fetch_add(1, Ordering::Relaxed);
        if tx.send(Dispatch { job, enqueued_at: Instant::now() }).is_err() {
            self.metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
            return false;
        }
        true
    }

    /// §6.11 load-driven regrowth: re-spawn one fresh worker slot when the
    /// pool is below strength (quarantine shrank it), the queue backlog
    /// exceeds the policy's soft threshold, and the cooldown has elapsed.
    fn maybe_regrow(&mut self) {
        let Some(policy) = self.opts.regrow else { return };
        if self.workers.len() >= self.n_workers {
            return;
        }
        if self.metrics.queue_depth.load(Ordering::Relaxed) <= policy.queue_soft as u64 {
            return;
        }
        if let Some(last) = self.last_regrow {
            if last.elapsed() < policy.cooldown {
                return;
            }
        }
        self.last_regrow = Some(Instant::now());
        let worker_id = self.next_worker_id;
        self.next_worker_id += 1;
        self.metrics.workers_regrown.fetch_add(1, Ordering::Relaxed);
        // fresh strike record: the slot earns its own way back to the
        // breaker instead of inheriting the quarantined thread's record
        let slot = self.spawn_worker(worker_id, Arc::new(AtomicU32::new(0)));
        self.workers.push(slot);
    }

    /// Convenience: submit everything, drain, unwrap failures into `Err`.
    pub fn run_all(&mut self, jobs: Vec<JobSpec>) -> Vec<JobOutcome> {
        for j in jobs {
            self.submit(j);
        }
        self.drain()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Highest request id named by any `ckpt-<req>[-<k>].bin` file (or stale
/// `.ckpt-tmp`) in the checkpoint dir; 0 when the dir is empty or
/// unreadable. The no-ledger request-id fallback seeds from this so a
/// restarted process allocates above every name a dead process left.
fn checkpoint_dir_high_water(dir: &std::path::Path) -> u64 {
    let Ok(entries) = std::fs::read_dir(dir) else { return 0 };
    entries
        .flatten()
        .filter_map(|e| {
            let name = e.file_name();
            super::recovery::parse_checkpoint_name(&name.to_string_lossy())
                .map(|(req, _)| req)
        })
        .max()
        .unwrap_or(0)
}

fn panic_message(p: Box<dyn std::any::Any + Send>) -> String {
    p.downcast_ref::<String>()
        .cloned()
        .or_else(|| p.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_else(|| "<non-string panic>".into())
}

/// The worker body. One workspace per worker: every job this thread
/// executes reuses the same solver buffers and selector storage
/// (bit-exact; a panicking job merely drops its taken buffers, so the
/// pool self-heals on the next run). Returns why the thread is exiting;
/// the spawn-site drop guard forwards that to the supervisor.
fn worker_loop(ctx: WorkerCtx) -> ExitCause {
    let WorkerCtx {
        rx,
        tx,
        metrics,
        inflight,
        n_workers,
        retry,
        breaker_k,
        strikes,
        boot_hub,
    } = ctx;
    let mut ws = FwWorkspace::new();
    if let Some(hub) = &boot_hub {
        ws.set_boot_hub(Arc::clone(hub));
    }
    loop {
        let dispatch = {
            // a poisoned queue mutex only means some worker died while
            // holding it; the receiver state is still coherent — recover
            // instead of cascading the panic across the pool
            let guard = rx.lock().unwrap_or_else(|e| e.into_inner());
            guard.recv()
        };
        let Ok(mut d) = dispatch else { return ExitCause::Shutdown };
        metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
        let ids = d.job.result_ids();

        // ---- §6.9 shed: expired while queued → no solver work ----------
        if d.job.cancel().expired() {
            let mut hung_up = false;
            for id in ids {
                metrics.sheds.fetch_add(1, Ordering::Relaxed);
                metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
                if tx.send(WorkerEvent::Result(id, Err(JobError::Expired))).is_err() {
                    hung_up = true;
                    break;
                }
            }
            if hung_up {
                return ExitCause::Shutdown;
            }
            continue;
        }

        // The in-flight slot is set before any fallible work and cleared
        // only after every result was sent: whatever kills this thread in
        // between, the supervisor finds exactly the owed ids.
        *inflight.lock().unwrap_or_else(|e| e.into_inner()) = Some(ids.clone());

        // ---- fault injection (tests/benches only) ----------------------
        if d.job.fault().take_worker_death() {
            // die without unwinding and without reporting — the shape
            // supervision exists for
            return ExitCause::Died;
        }
        if d.job.fault().take_poison() {
            ws.poison_buffers();
        }

        // The pool already saturates the machine; stop auto-threaded jobs
        // from oversubscribing it during their parallel bootstrap (output
        // is bit-identical at any thread count, so this is safe — and
        // that includes sharded jobs, which are thread-invariant at any
        // P). `cfg.shards` is deliberately NOT touched here: forcing a
        // job on or off the sharded engine would change its byte/segment
        // model (DESIGN.md §6.8), which only the submitter may choose.
        if n_workers > 1 {
            d.job.pin_threads();
        }

        let start = Instant::now();
        // ---- run, with seed-pinned in-place retries --------------------
        // Nothing in the job is mutated between attempts — same config,
        // same seed, same workspace pool — so a retry's mechanism stream
        // (and ε spend) is bit-identical to the first attempt's.
        let mut attempt = 0u32;
        let outcome = loop {
            match std::panic::catch_unwind(AssertUnwindSafe(|| d.job.run_in(&mut ws))) {
                Ok(results) => break Ok(results),
                Err(p) => {
                    // a leader that panicked mid-bootstrap still holds the
                    // hub lease; release it so followers detach and re-lead
                    ws.boot_lease_abort();
                    // §6.11 simulated crash: the typed marker means "this
                    // worker is dead", not "this job panicked" — leave the
                    // in-flight slot set and exit without reporting, so
                    // the supervisor recovers the owed job from its
                    // durable checkpoint instead of retrying in place.
                    if p.downcast_ref::<CrashPayload>().is_some() {
                        return ExitCause::Died;
                    }
                    let msg = panic_message(p);
                    if attempt >= retry.retry_limit {
                        break Err(if retry.retry_limit == 0 {
                            JobError::Panicked(msg)
                        } else {
                            JobError::RetriesExhausted { attempts: attempt + 1, last: msg }
                        });
                    }
                    metrics.retries.fetch_add(1, Ordering::Relaxed);
                    std::thread::sleep(retry.backoff(attempt));
                    attempt += 1;
                }
            }
        };

        // Per-result busy time: a path's wall time is attributed evenly
        // across its λ cells, with the integer-division remainder going
        // to the last cell so Σ busy_us is exact (utilization totals must
        // not drift low on long paths).
        let ids = d.job.result_ids();
        let n_ids = ids.len().max(1) as u64;
        let elapsed_us = start.elapsed().as_micros() as u64;
        let busy_each = elapsed_us / n_ids;
        let busy_rem = elapsed_us % n_ids;
        let latency_us = d.enqueued_at.elapsed().as_micros() as u64;
        let histo = match &d.job {
            Job::Cell(_) => &metrics.cell_latency,
            Job::Path(_) => &metrics.path_latency,
            Job::Predict(_) => &metrics.predict_latency,
        };

        let mut hung_up = false;
        let mut tripped = false;
        match outcome {
            Ok(results) => {
                strikes.store(0, Ordering::Relaxed);
                let last = results.len().saturating_sub(1);
                for (k, res) in results.into_iter().enumerate() {
                    if res.output.stopped == StopReason::Deadline {
                        metrics.timeouts.fetch_add(1, Ordering::Relaxed);
                    }
                    metrics.record_completion(
                        res.output.iters_run as u64,
                        res.output.flops,
                        res.output.bytes_moved,
                        busy_each + if k == last { busy_rem } else { 0 },
                    );
                    let id = res.id;
                    if tx.send(WorkerEvent::Result(id, Ok(res))).is_err() {
                        hung_up = true; // coordinator dropped
                        break;
                    }
                }
            }
            Err(err) => {
                // every result this job owed becomes a failure (a path
                // panic fails all its λs) — and it counts one strike
                // toward the circuit breaker
                let s = strikes.fetch_add(1, Ordering::Relaxed) + 1;
                tripped = breaker_k > 0 && s >= breaker_k && n_workers > 1;
                for id in ids {
                    metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
                    if tx.send(WorkerEvent::Result(id, Err(err.clone()))).is_err() {
                        hung_up = true;
                        break;
                    }
                }
            }
        }
        if !hung_up {
            histo.record_us(latency_us);
        }
        *inflight.lock().unwrap_or_else(|e| e.into_inner()) = None;
        if hung_up {
            return ExitCause::Shutdown;
        }
        if tripped {
            // self-quarantine: all ids resolved, strikes stay ≥ K as the
            // record of why; the supervisor decides whether a replacement
            // is needed (only when this was the last live worker)
            return ExitCause::Quarantine;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::Algo;
    use crate::fw::config::FwConfig;
    use crate::sparse::synth::SynthConfig;
    use crate::sparse::Dataset;
    use crate::testkit::faults::{FaultKind, FaultPlan};

    fn ds(seed: u64) -> Arc<Dataset> {
        Arc::new(
            SynthConfig {
                name: format!("sched{seed}"),
                n_rows: 80,
                n_cols: 40,
                avg_row_nnz: 6.0,
                zipf_exponent: 1.2,
                n_informative: 8,
                n_dense: 0,
                label_noise: 0.02,
                bias_col: true,
            }
            .generate(seed),
        )
    }

    fn job(id: usize, data: Arc<Dataset>) -> JobSpec {
        JobSpec {
            id,
            label: format!("j{id}"),
            data,
            algo: Algo::Fast,
            cfg: FwConfig { iters: 60, lambda: 4.0, ..Default::default() },
            test_data: None,
        }
    }

    #[test]
    fn runs_jobs_in_parallel_and_orders_results() {
        let mut c = Coordinator::new(4);
        let d = ds(1);
        let jobs: Vec<JobSpec> = (0..12).map(|i| job(i, d.clone())).collect();
        let results = c.run_all(jobs);
        assert_eq!(results.len(), 12);
        for (i, r) in results.iter().enumerate() {
            let r = r.as_ref().expect("job failed");
            assert_eq!(r.id, i);
            assert!(r.output.flops > 0);
        }
        assert_eq!(c.metrics.jobs_completed.load(Ordering::Relaxed), 12);
        assert_eq!(c.metrics.queue_depth.load(Ordering::Relaxed), 0);
        assert_eq!(c.metrics.cell_latency.count(), 12);
        assert!(c.metrics.bytes_total.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn identical_jobs_identical_results_across_workers() {
        // determinism survives the thread pool (no hidden global RNG)
        let mut c = Coordinator::new(3);
        let d = ds(2);
        let results = c.run_all((0..6).map(|i| job(i, d.clone())).collect());
        let w0 = &results[0].as_ref().unwrap().output.weights;
        for r in &results[1..] {
            assert_eq!(&r.as_ref().unwrap().output.weights, w0);
        }
    }

    #[test]
    fn failure_injection_does_not_poison_pool() {
        let mut c = Coordinator::new(2);
        let d = ds(3);
        let mut bad = job(0, d.clone());
        bad.cfg.lambda = -1.0; // validate() panics inside the worker
        c.submit(bad);
        c.submit(job(1, d.clone()));
        c.submit(job(2, d));
        let results = c.drain();
        assert!(matches!(results[0], Err(JobError::Panicked(_))), "{:?}", results[0]);
        assert!(results[1].is_ok());
        assert!(results[2].is_ok());
        assert_eq!(c.metrics.jobs_failed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn path_jobs_interleave_with_cells_and_order_results() {
        let mut c = Coordinator::new(3);
        let d = ds(5);
        c.submit(job(0, d.clone()));
        c.submit_path(PathJob {
            base_id: 1,
            label: "path".into(),
            data: d.clone(),
            algo: Algo::Fast,
            cfg: FwConfig { iters: 60, lambda: 1.0, ..Default::default() },
            lambdas: vec![2.0, 4.0, 8.0],
            test_data: None,
        });
        c.submit(job(4, d));
        let results = c.drain();
        assert_eq!(results.len(), 5);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.as_ref().expect("job failed").id, i);
        }
        // the path ran on one worker/workspace: its warm λs skipped the
        // bootstrap entirely
        assert!(results[1].as_ref().unwrap().output.bootstrap_flops > 0);
        assert_eq!(results[2].as_ref().unwrap().output.bootstrap_flops, 0);
        assert_eq!(results[3].as_ref().unwrap().output.bootstrap_flops, 0);
        assert_eq!(c.metrics.jobs_submitted.load(Ordering::Relaxed), 5);
        assert_eq!(c.metrics.jobs_completed.load(Ordering::Relaxed), 5);
        // one latency sample per queue entry, split by class
        assert_eq!(c.metrics.cell_latency.count(), 2);
        assert_eq!(c.metrics.path_latency.count(), 1);
    }

    #[test]
    fn path_panic_fails_every_lambda_without_poisoning_pool() {
        let mut c = Coordinator::new(2);
        let d = ds(6);
        c.submit_path(PathJob {
            base_id: 0,
            label: "bad".into(),
            data: d.clone(),
            algo: Algo::Fast,
            cfg: FwConfig { iters: 60, lambda: 1.0, ..Default::default() },
            lambdas: vec![2.0, -1.0, 3.0], // second λ panics mid-path
            test_data: None,
        });
        c.submit(job(3, d));
        let results = c.drain();
        assert_eq!(results.len(), 4);
        for r in &results[..3] {
            assert!(r.is_err(), "a path panic must fail all its λ cells");
        }
        assert!(results[3].is_ok(), "pool must survive a failed path");
        assert_eq!(c.metrics.jobs_failed.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn single_worker_pool_works() {
        let mut c = Coordinator::new(0); // clamped to 1
        let d = ds(4);
        let results = c.run_all(vec![job(0, d)]);
        assert!(results[0].is_ok());
    }

    #[test]
    fn submit_after_shutdown_degrades_to_pool_died() {
        let mut c = Coordinator::new(2);
        let d = ds(7);
        c.submit(job(0, d.clone()));
        let first = c.drain();
        assert!(first[0].is_ok());
        c.shutdown();
        c.submit(job(1, d.clone()));
        c.submit_path(PathJob {
            base_id: 2,
            label: "late".into(),
            data: d,
            algo: Algo::Fast,
            cfg: FwConfig { iters: 60, lambda: 1.0, ..Default::default() },
            lambdas: vec![2.0, 4.0],
            test_data: None,
        });
        let results = c.drain();
        assert_eq!(results.len(), 3);
        for r in &results {
            assert_eq!(r.as_ref().unwrap_err(), &JobError::PoolDied);
        }
        assert_eq!(c.metrics.jobs_failed.load(Ordering::Relaxed), 3);
        assert_eq!(c.metrics.queue_depth.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn predict_jobs_run_on_the_pool() {
        let mut c = Coordinator::new(2);
        let d = ds(8);
        // train once to get a plausible weight vector
        let trained = job(0, d.clone()).run();
        let w = Arc::new(trained.output.weights.as_slice().to_vec());
        c.submit(job(0, d.clone()));
        c.submit_predict(PredictJob {
            id: 1,
            label: "score".into(),
            data: d.clone(),
            weights: w.clone(),
            threads: 0,
            cancel: Default::default(),
            fault: FaultPlan::none(),
        });
        let results = c.drain_with_ids();
        assert_eq!(results.len(), 2);
        assert_eq!(results[1].0, 1);
        let pred = results[1].1.as_ref().expect("predict failed");
        assert_eq!(pred.algo, Algo::Predict);
        let p = pred.predictions.as_ref().expect("predictions missing");
        assert_eq!(p.len(), d.csr.n_rows());
        assert!(pred.output.flops > 0 && pred.output.bytes_moved > 0);
        assert_eq!(pred.output.iters_run, 0, "no solver work, no ε spend");
        assert_eq!(pred.output.eps_spent, None);
        assert_eq!(c.metrics.predict_latency.count(), 1);
        assert_eq!(c.metrics.timeouts.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn circuit_breaker_quarantines_repeat_offender_worker() {
        let mut c = Coordinator::with_options(
            2,
            PoolOptions { breaker_k: 2, ..Default::default() },
        );
        let d = ds(9);
        // 6 poison jobs: each panics (validate: negative λ); with K = 2
        // some worker must hit two consecutive failures and self-quarantine
        for i in 0..6 {
            let mut bad = job(i, d.clone());
            bad.cfg.lambda = -1.0;
            c.submit(bad);
        }
        let results = c.drain();
        assert!(results.iter().all(|r| r.is_err()));
        assert!(
            c.metrics.workers_quarantined.load(Ordering::Relaxed) >= 1,
            "quarantined {}",
            c.metrics.workers_quarantined.load(Ordering::Relaxed)
        );
        assert!(c.live_workers() >= 1, "pool must never quarantine to empty");
        // the surviving pool still serves clean work
        let after = c.run_all(vec![job(10, d)]);
        assert!(after[0].is_ok());
    }

    #[test]
    fn worker_death_strikes_toward_the_breaker() {
        let mut c = Coordinator::with_options(
            1,
            PoolOptions { breaker_k: 2, ..Default::default() },
        );
        let d = ds(10);
        let mut doomed = job(0, d.clone());
        doomed.cfg.fault = FaultPlan::once(FaultKind::DieAbruptly);
        c.submit(doomed);
        let results = c.drain();
        assert_eq!(results[0].as_ref().unwrap_err(), &JobError::WorkerDied);
        // single-worker pool: death respawns (never quarantines to empty)
        assert_eq!(c.metrics.workers_respawned.load(Ordering::Relaxed), 1);
        assert_eq!(c.metrics.workers_quarantined.load(Ordering::Relaxed), 0);
        let after = c.run_all(vec![job(1, d)]);
        assert!(after[0].is_ok());
    }

    #[test]
    fn crash_mid_solve_resumes_from_checkpoint_bitwise() {
        let dir = std::env::temp_dir()
            .join(format!("dpfw-sched-crash-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let d = ds(11);
        // clean in-process reference run: what the pool must reproduce
        let clean = job(0, d.clone()).run();
        let mut c = Coordinator::with_options(
            1,
            PoolOptions {
                durability: Some(DurabilityOptions {
                    ledger: None,
                    dir: dir.clone(),
                    every_k: 10,
                    resume_in_process: true,
                }),
                ..Default::default()
            },
        );
        let mut doomed = job(0, d);
        doomed.cfg.fault = FaultPlan::once(FaultKind::CrashAt { iter: 37 });
        c.submit(doomed);
        let results = c.drain();
        let r = results[0].as_ref().expect("crashed job must resume to Ok");
        // the crash killed the worker (not a retry-in-place panic) and the
        // supervisor resumed the owed id from its checkpoint
        assert_eq!(c.metrics.jobs_resumed.load(Ordering::Relaxed), 1);
        assert_eq!(c.metrics.workers_respawned.load(Ordering::Relaxed), 1);
        assert_eq!(c.metrics.jobs_failed.load(Ordering::Relaxed), 0);
        assert_eq!(c.metrics.retries.load(Ordering::Relaxed), 0);
        // bitwise identical to the uninterrupted run
        assert_eq!(r.output.weights, clean.output.weights);
        assert_eq!(r.output.final_gap.to_bits(), clean.output.final_gap.to_bits());
        assert_eq!(r.output.flops, clean.output.flops);
        assert_eq!(r.output.iters_run, clean.output.iters_run);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn regrow_policy_refills_quarantined_slots_under_backlog() {
        let mut c = Coordinator::with_options(
            2,
            PoolOptions {
                breaker_k: 1,
                regrow: Some(RegrowPolicy {
                    cooldown: Duration::ZERO,
                    queue_soft: 0,
                }),
                ..Default::default()
            },
        );
        let d = ds(12);
        // two poison jobs: with K = 1 at least one worker quarantines
        // (the last live worker respawns instead — the pool never empties)
        for i in 0..2 {
            let mut bad = job(i, d.clone());
            bad.cfg.lambda = -1.0;
            c.submit(bad);
        }
        let first = c.drain();
        assert!(first.iter().all(|r| r.is_err()));
        assert!(c.metrics.workers_quarantined.load(Ordering::Relaxed) >= 1);
        // a backlog of clean work: the drain loop's regrow check sees
        // pool-below-strength + queue over the soft mark + cooldown clear
        for i in 2..8 {
            c.submit(job(i, d.clone()));
        }
        let after = c.drain();
        assert!(after.iter().all(|r| r.is_ok()), "regrown pool must serve");
        assert!(
            c.metrics.workers_regrown.load(Ordering::Relaxed) >= 1,
            "regrown {}",
            c.metrics.workers_regrown.load(Ordering::Relaxed)
        );
        assert!(c.live_workers() >= 1);
    }

    #[test]
    fn retry_policy_backoff_is_bounded() {
        let p = RetryPolicy { retry_limit: 10, backoff_base: Duration::from_millis(5) };
        assert_eq!(p.backoff(0), Duration::from_millis(5));
        assert_eq!(p.backoff(1), Duration::from_millis(10));
        assert_eq!(p.backoff(5), Duration::from_millis(160));
        assert_eq!(p.backoff(6), RETRY_BACKOFF_CAP);
        assert_eq!(p.backoff(60), RETRY_BACKOFF_CAP, "shift must not overflow");
    }
}
