//! The worker-pool scheduler: a bounded job queue over std threads.
//!
//! Design: one `mpsc` job channel (shared by workers behind a mutex — the
//! jobs are seconds-long solver runs, so receiver contention is
//! irrelevant), one result channel back. Panics in a job are caught and
//! reported as failures rather than poisoning the pool — a failed grid
//! cell must not take down a week-long experiment sweep.
//!
//! Jobs come in two shapes ([`Job`]): single grid cells, and whole
//! regularization paths ([`super::job::PathJob`]) that the scheduler
//! deliberately keeps on **one** worker so every λ shares that worker's
//! workspace — and therefore its cached bootstrap (DESIGN.md §6.5). A
//! path counts as `lambdas.len()` submissions: its per-λ results come back
//! through the same channel with consecutive ids, so [`Coordinator::drain`]
//! and the registry treat path cells and independent cells uniformly.

use std::panic::AssertUnwindSafe;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use super::job::{Job, JobResult, JobSpec, PathJob};
use super::metrics::Metrics;
use crate::fw::workspace::FwWorkspace;

/// Outcome of one job: the result, or the panic message.
pub type JobOutcome = Result<JobResult, String>;

pub struct Coordinator {
    job_tx: Option<mpsc::Sender<Job>>,
    result_rx: mpsc::Receiver<(usize, JobOutcome)>,
    workers: Vec<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    submitted: usize,
}

impl Coordinator {
    /// Spawn `n_workers` worker threads (min 1).
    pub fn new(n_workers: usize) -> Self {
        let n_workers = n_workers.max(1);
        let (job_tx, job_rx) = mpsc::channel::<Job>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let (result_tx, result_rx) = mpsc::channel();
        let metrics = Arc::new(Metrics::new());
        let mut workers = Vec::with_capacity(n_workers);
        for worker_id in 0..n_workers {
            let rx = Arc::clone(&job_rx);
            let tx = result_tx.clone();
            let metrics = Arc::clone(&metrics);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("dpfw-worker-{worker_id}"))
                    .spawn(move || {
                        // One workspace per worker: every job this thread
                        // executes reuses the same solver buffers and
                        // selector storage (bit-exact; a panicking job
                        // merely drops its taken buffers, so the pool
                        // self-heals on the next run).
                        let mut ws = FwWorkspace::new();
                        loop {
                            let job = {
                                let guard = rx.lock().expect("job queue poisoned");
                                guard.recv()
                            };
                            let Ok(mut job) = job else { break }; // channel closed
                            // The pool already saturates the machine; stop
                            // auto-threaded jobs from oversubscribing it
                            // during their parallel bootstrap (output is
                            // bit-identical at any thread count, so this is
                            // safe — and that includes sharded jobs, which
                            // are thread-invariant at any P). `cfg.shards`
                            // is deliberately NOT touched here: forcing a
                            // job on or off the sharded engine would change
                            // its byte/segment model (DESIGN.md §6.8), which
                            // only the submitter may choose.
                            if n_workers > 1 && job.cfg_mut().threads == 0 {
                                job.cfg_mut().threads = 1;
                            }
                            let ids = job.result_ids();
                            let start = Instant::now();
                            let outcome = std::panic::catch_unwind(AssertUnwindSafe(|| {
                                job.run_in(&mut ws)
                            }));
                            // Per-result busy time: a path's wall time is
                            // attributed evenly across its λ cells.
                            let busy_us = start.elapsed().as_micros() as u64
                                / ids.len().max(1) as u64;
                            let mut hung_up = false;
                            match outcome {
                                Ok(results) => {
                                    for res in results {
                                        metrics.record_completion(
                                            res.output.iters_run as u64,
                                            res.output.flops,
                                            busy_us,
                                        );
                                        let id = res.id;
                                        if tx.send((id, Ok(res))).is_err() {
                                            hung_up = true; // coordinator dropped
                                            break;
                                        }
                                    }
                                }
                                Err(p) => {
                                    let msg = p
                                        .downcast_ref::<String>()
                                        .cloned()
                                        .or_else(|| {
                                            p.downcast_ref::<&str>().map(|s| s.to_string())
                                        })
                                        .unwrap_or_else(|| "<non-string panic>".into());
                                    // every result this job owed becomes a
                                    // failure (a path panic fails all its λs)
                                    for id in ids {
                                        metrics
                                            .jobs_failed
                                            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                                        if tx.send((id, Err(msg.clone()))).is_err() {
                                            hung_up = true;
                                            break;
                                        }
                                    }
                                }
                            }
                            if hung_up {
                                break;
                            }
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        Self { job_tx: Some(job_tx), result_rx, workers, metrics, submitted: 0 }
    }

    /// Enqueue a single-cell job (non-blocking).
    pub fn submit(&mut self, job: JobSpec) {
        self.submit_job(Job::Cell(job));
    }

    /// Enqueue a whole λ-path as one unit of work: it will run on a single
    /// worker, sharing that worker's workspace (and bootstrap cache)
    /// across every λ. Counts as `lambdas.len()` submissions — `drain`
    /// returns one outcome per λ, ids `base_id..base_id + len`.
    pub fn submit_path(&mut self, path: PathJob) {
        assert!(!path.lambdas.is_empty(), "empty lambda grid");
        self.submit_job(Job::Path(path));
    }

    fn submit_job(&mut self, job: Job) {
        let n = job.n_results();
        self.metrics
            .jobs_submitted
            .fetch_add(n as u64, std::sync::atomic::Ordering::Relaxed);
        self.submitted += n;
        self.job_tx
            .as_ref()
            .expect("coordinator already shut down")
            .send(job)
            .expect("worker pool hung up");
    }

    /// Block until every submitted job has finished; results are returned
    /// sorted by job id.
    pub fn drain(&mut self) -> Vec<JobOutcome> {
        let mut out: Vec<(usize, JobOutcome)> = Vec::with_capacity(self.submitted);
        for _ in 0..self.submitted {
            let item = self.result_rx.recv().expect("workers all died");
            out.push(item);
        }
        self.submitted = 0;
        out.sort_by_key(|(id, _)| *id);
        out.into_iter().map(|(_, o)| o).collect()
    }

    /// Convenience: submit everything, drain, unwrap failures into `Err`.
    pub fn run_all(&mut self, jobs: Vec<JobSpec>) -> Vec<JobOutcome> {
        for j in jobs {
            self.submit(j);
        }
        self.drain()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.job_tx.take(); // close the queue → workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::Algo;
    use crate::fw::config::FwConfig;
    use crate::sparse::synth::SynthConfig;
    use crate::sparse::Dataset;

    fn ds(seed: u64) -> Arc<Dataset> {
        Arc::new(
            SynthConfig {
                name: format!("sched{seed}"),
                n_rows: 80,
                n_cols: 40,
                avg_row_nnz: 6.0,
                zipf_exponent: 1.2,
                n_informative: 8,
                n_dense: 0,
                label_noise: 0.02,
            bias_col: true,
            }
            .generate(seed),
        )
    }

    fn job(id: usize, data: Arc<Dataset>) -> JobSpec {
        JobSpec {
            id,
            label: format!("j{id}"),
            data,
            algo: Algo::Fast,
            cfg: FwConfig { iters: 60, lambda: 4.0, ..Default::default() },
            test_data: None,
        }
    }

    #[test]
    fn runs_jobs_in_parallel_and_orders_results() {
        let mut c = Coordinator::new(4);
        let d = ds(1);
        let jobs: Vec<JobSpec> = (0..12).map(|i| job(i, d.clone())).collect();
        let results = c.run_all(jobs);
        assert_eq!(results.len(), 12);
        for (i, r) in results.iter().enumerate() {
            let r = r.as_ref().expect("job failed");
            assert_eq!(r.id, i);
            assert!(r.output.flops > 0);
        }
        assert_eq!(
            c.metrics.jobs_completed.load(std::sync::atomic::Ordering::Relaxed),
            12
        );
    }

    #[test]
    fn identical_jobs_identical_results_across_workers() {
        // determinism survives the thread pool (no hidden global RNG)
        let mut c = Coordinator::new(3);
        let d = ds(2);
        let results = c.run_all((0..6).map(|i| job(i, d.clone())).collect());
        let w0 = &results[0].as_ref().unwrap().output.weights;
        for r in &results[1..] {
            assert_eq!(&r.as_ref().unwrap().output.weights, w0);
        }
    }

    #[test]
    fn failure_injection_does_not_poison_pool() {
        let mut c = Coordinator::new(2);
        let d = ds(3);
        let mut bad = job(0, d.clone());
        bad.cfg.lambda = -1.0; // validate() panics inside the worker
        c.submit(bad);
        c.submit(job(1, d.clone()));
        c.submit(job(2, d));
        let results = c.drain();
        assert!(results[0].is_err());
        assert!(results[1].is_ok());
        assert!(results[2].is_ok());
        assert_eq!(
            c.metrics.jobs_failed.load(std::sync::atomic::Ordering::Relaxed),
            1
        );
    }

    #[test]
    fn path_jobs_interleave_with_cells_and_order_results() {
        let mut c = Coordinator::new(3);
        let d = ds(5);
        c.submit(job(0, d.clone()));
        c.submit_path(PathJob {
            base_id: 1,
            label: "path".into(),
            data: d.clone(),
            algo: Algo::Fast,
            cfg: FwConfig { iters: 60, lambda: 1.0, ..Default::default() },
            lambdas: vec![2.0, 4.0, 8.0],
            test_data: None,
        });
        c.submit(job(4, d));
        let results = c.drain();
        assert_eq!(results.len(), 5);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.as_ref().expect("job failed").id, i);
        }
        // the path ran on one worker/workspace: its warm λs skipped the
        // bootstrap entirely
        assert!(results[1].as_ref().unwrap().output.bootstrap_flops > 0);
        assert_eq!(results[2].as_ref().unwrap().output.bootstrap_flops, 0);
        assert_eq!(results[3].as_ref().unwrap().output.bootstrap_flops, 0);
        let ord = std::sync::atomic::Ordering::Relaxed;
        assert_eq!(c.metrics.jobs_submitted.load(ord), 5);
        assert_eq!(c.metrics.jobs_completed.load(ord), 5);
    }

    #[test]
    fn path_panic_fails_every_lambda_without_poisoning_pool() {
        let mut c = Coordinator::new(2);
        let d = ds(6);
        c.submit_path(PathJob {
            base_id: 0,
            label: "bad".into(),
            data: d.clone(),
            algo: Algo::Fast,
            cfg: FwConfig { iters: 60, lambda: 1.0, ..Default::default() },
            lambdas: vec![2.0, -1.0, 3.0], // second λ panics mid-path
            test_data: None,
        });
        c.submit(job(3, d));
        let results = c.drain();
        assert_eq!(results.len(), 4);
        for r in &results[..3] {
            assert!(r.is_err(), "a path panic must fail all its λ cells");
        }
        assert!(results[3].is_ok(), "pool must survive a failed path");
        assert_eq!(
            c.metrics.jobs_failed.load(std::sync::atomic::Ordering::Relaxed),
            3
        );
    }

    #[test]
    fn single_worker_pool_works() {
        let mut c = Coordinator::new(0); // clamped to 1
        let d = ds(4);
        let results = c.run_all(vec![job(0, d)]);
        assert!(results[0].is_ok());
    }
}
