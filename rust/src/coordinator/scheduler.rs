//! The worker-pool scheduler: a bounded job queue over std threads.
//!
//! Design: one `mpsc` job channel (shared by workers behind a mutex — the
//! jobs are seconds-long solver runs, so receiver contention is
//! irrelevant), one result channel back. Panics in a job are caught and
//! reported as failures rather than poisoning the pool — a failed grid
//! cell must not take down a week-long experiment sweep.

use std::panic::AssertUnwindSafe;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use super::job::{JobResult, JobSpec};
use super::metrics::Metrics;
use crate::fw::workspace::FwWorkspace;

/// Outcome of one job: the result, or the panic message.
pub type JobOutcome = Result<JobResult, String>;

pub struct Coordinator {
    job_tx: Option<mpsc::Sender<JobSpec>>,
    result_rx: mpsc::Receiver<(usize, JobOutcome)>,
    workers: Vec<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    submitted: usize,
}

impl Coordinator {
    /// Spawn `n_workers` worker threads (min 1).
    pub fn new(n_workers: usize) -> Self {
        let n_workers = n_workers.max(1);
        let (job_tx, job_rx) = mpsc::channel::<JobSpec>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let (result_tx, result_rx) = mpsc::channel();
        let metrics = Arc::new(Metrics::new());
        let mut workers = Vec::with_capacity(n_workers);
        for worker_id in 0..n_workers {
            let rx = Arc::clone(&job_rx);
            let tx = result_tx.clone();
            let metrics = Arc::clone(&metrics);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("dpfw-worker-{worker_id}"))
                    .spawn(move || {
                        // One workspace per worker: every job this thread
                        // executes reuses the same solver buffers and
                        // selector storage (bit-exact; a panicking job
                        // merely drops its taken buffers, so the pool
                        // self-heals on the next run).
                        let mut ws = FwWorkspace::new();
                        loop {
                            let job = {
                                let guard = rx.lock().expect("job queue poisoned");
                                guard.recv()
                            };
                            let Ok(mut job) = job else { break }; // channel closed
                            // The pool already saturates the machine; stop
                            // auto-threaded jobs from oversubscribing it
                            // during their parallel bootstrap (output is
                            // bit-identical at any thread count, so this is
                            // safe).
                            if n_workers > 1 && job.cfg.threads == 0 {
                                job.cfg.threads = 1;
                            }
                            let id = job.id;
                            let start = Instant::now();
                            let outcome = std::panic::catch_unwind(AssertUnwindSafe(|| {
                                job.run_in(&mut ws)
                            }));
                            let busy_us = start.elapsed().as_micros() as u64;
                            let outcome = match outcome {
                                Ok(res) => {
                                    metrics.record_completion(
                                        res.output.iters_run as u64,
                                        res.output.flops,
                                        busy_us,
                                    );
                                    Ok(res)
                                }
                                Err(p) => {
                                    metrics
                                        .jobs_failed
                                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                                    let msg = p
                                        .downcast_ref::<String>()
                                        .cloned()
                                        .or_else(|| {
                                            p.downcast_ref::<&str>().map(|s| s.to_string())
                                        })
                                        .unwrap_or_else(|| "<non-string panic>".into());
                                    Err(msg)
                                }
                            };
                            if tx.send((id, outcome)).is_err() {
                                break; // coordinator dropped
                            }
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        Self { job_tx: Some(job_tx), result_rx, workers, metrics, submitted: 0 }
    }

    /// Enqueue a job (non-blocking).
    pub fn submit(&mut self, job: JobSpec) {
        self.metrics
            .jobs_submitted
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.submitted += 1;
        self.job_tx
            .as_ref()
            .expect("coordinator already shut down")
            .send(job)
            .expect("worker pool hung up");
    }

    /// Block until every submitted job has finished; results are returned
    /// sorted by job id.
    pub fn drain(&mut self) -> Vec<JobOutcome> {
        let mut out: Vec<(usize, JobOutcome)> = Vec::with_capacity(self.submitted);
        for _ in 0..self.submitted {
            let item = self.result_rx.recv().expect("workers all died");
            out.push(item);
        }
        self.submitted = 0;
        out.sort_by_key(|(id, _)| *id);
        out.into_iter().map(|(_, o)| o).collect()
    }

    /// Convenience: submit everything, drain, unwrap failures into `Err`.
    pub fn run_all(&mut self, jobs: Vec<JobSpec>) -> Vec<JobOutcome> {
        for j in jobs {
            self.submit(j);
        }
        self.drain()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.job_tx.take(); // close the queue → workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::Algo;
    use crate::fw::config::FwConfig;
    use crate::sparse::synth::SynthConfig;
    use crate::sparse::Dataset;

    fn ds(seed: u64) -> Arc<Dataset> {
        Arc::new(
            SynthConfig {
                name: format!("sched{seed}"),
                n_rows: 80,
                n_cols: 40,
                avg_row_nnz: 6.0,
                zipf_exponent: 1.2,
                n_informative: 8,
                n_dense: 0,
                label_noise: 0.02,
            bias_col: true,
            }
            .generate(seed),
        )
    }

    fn job(id: usize, data: Arc<Dataset>) -> JobSpec {
        JobSpec {
            id,
            label: format!("j{id}"),
            data,
            algo: Algo::Fast,
            cfg: FwConfig { iters: 60, lambda: 4.0, ..Default::default() },
            test_data: None,
        }
    }

    #[test]
    fn runs_jobs_in_parallel_and_orders_results() {
        let mut c = Coordinator::new(4);
        let d = ds(1);
        let jobs: Vec<JobSpec> = (0..12).map(|i| job(i, d.clone())).collect();
        let results = c.run_all(jobs);
        assert_eq!(results.len(), 12);
        for (i, r) in results.iter().enumerate() {
            let r = r.as_ref().expect("job failed");
            assert_eq!(r.id, i);
            assert!(r.output.flops > 0);
        }
        assert_eq!(
            c.metrics.jobs_completed.load(std::sync::atomic::Ordering::Relaxed),
            12
        );
    }

    #[test]
    fn identical_jobs_identical_results_across_workers() {
        // determinism survives the thread pool (no hidden global RNG)
        let mut c = Coordinator::new(3);
        let d = ds(2);
        let results = c.run_all((0..6).map(|i| job(i, d.clone())).collect());
        let w0 = &results[0].as_ref().unwrap().output.weights;
        for r in &results[1..] {
            assert_eq!(&r.as_ref().unwrap().output.weights, w0);
        }
    }

    #[test]
    fn failure_injection_does_not_poison_pool() {
        let mut c = Coordinator::new(2);
        let d = ds(3);
        let mut bad = job(0, d.clone());
        bad.cfg.lambda = -1.0; // validate() panics inside the worker
        c.submit(bad);
        c.submit(job(1, d.clone()));
        c.submit(job(2, d));
        let results = c.drain();
        assert!(results[0].is_err());
        assert!(results[1].is_ok());
        assert!(results[2].is_ok());
        assert_eq!(
            c.metrics.jobs_failed.load(std::sync::atomic::Ordering::Relaxed),
            1
        );
    }

    #[test]
    fn single_worker_pool_works() {
        let mut c = Coordinator::new(0); // clamped to 1
        let d = ds(4);
        let results = c.run_all(vec![job(0, d)]);
        assert!(results[0].is_ok());
    }
}
