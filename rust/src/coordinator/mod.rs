//! L3 coordination: a multi-threaded training orchestrator.
//!
//! The paper's experiments are a grid over
//! {dataset × solver × selector × ε × seed}; the coordinator runs that
//! grid as a job queue over a worker pool (std threads + channels — tokio
//! is not in the offline crate set, and the workload is CPU-bound batch
//! compute, not I/O concurrency), collects [`job::JobResult`]s, tracks
//! [`metrics::Metrics`], and lands everything in a [`registry::Registry`]
//! for CSV/JSON export. The experiment harness (`experiments/`) and the
//! e2e example drive all runs through this path.
//!
//! Work comes in two granularities: single cells ([`job::JobSpec`]) and
//! whole regularization paths ([`job::PathJob`]) — a λ-grid the scheduler
//! pins to one worker so every λ shares the workspace's cached bootstrap
//! (DESIGN.md §6.5) instead of paying the `O(N·S_c)` dense first
//! iteration per cell.
//!
//! The serving tier (DESIGN.md §6.9) makes the pool resilient: each job
//! id resolves to `Ok` or a structured [`job::JobError`] — never a pool
//! panic — with deadline shedding, supervised worker respawn, and
//! seed-pinned retries ([`scheduler::RetryPolicy`]) whose DP mechanism
//! stream is bit-identical to the first attempt.
//!
//! The long-lived ingress service ([`ingress::Ingress`], DESIGN.md §6.10)
//! fronts the pool with bounded admission (explicit
//! [`ingress::Admit`] accept/shed/redirect — callers are never silently
//! dropped), per-class rate limits and queue watermarks, cross-request
//! bootstrap coalescing through the workspace
//! [`crate::fw::workspace::BootHub`], a brownout controller that degrades
//! iteration budgets honestly under sustained overload, and a per-worker
//! circuit breaker.
//!
//! The durability plane (DESIGN.md §6.11, §6.12) adds crash consistency
//! on top: [`scheduler::DurabilityOptions`] arms cadence checkpoints
//! ([`crate::fw::checkpoint`]) and the write-ahead ε ledger
//! ([`crate::dp::ledger`]) on every cell solve and every λ-path grid
//! point, the supervisor resumes a crashed worker's job from its latest
//! checkpoints (bitwise identical to the uninterrupted run, exactly-once
//! accounting), ingress refuses private work on budget-exhausted
//! datasets and fails closed when the ledger can no longer record spend,
//! and [`scheduler::RegrowPolicy`] regrows quarantined worker slots
//! under queue backlog. Across process lifetimes,
//! [`recovery::RecoveryManager`] scans the checkpoint dir a dead process
//! left behind, cross-checks each orphan against the WAL, and hands back
//! a [`recovery::RecoveryManifest`] of resumable jobs whose reruns reuse
//! the original durable request ids — restart-survivable exactly-once ε.

pub mod ingress;
pub mod job;
pub mod metrics;
pub mod recovery;
pub mod registry;
pub mod scheduler;

pub use ingress::{
    Admit, ClassPolicy, Ingress, IngressConfig, JobClass, Request, ShedReason,
};
pub use job::{Algo, Job, JobError, JobResult, JobSpec, PathJob, PredictJob};
pub use metrics::{LatencyHisto, Metrics};
pub use recovery::{
    Orphan, OrphanKind, OrphanState, RecoveredSlot, RecoveryManager, RecoveryManifest,
};
pub use registry::Registry;
pub use scheduler::{
    Coordinator, DurabilityOptions, JobOutcome, PoolOptions, RegrowPolicy, RetryPolicy,
};
