//! Job specifications and results for the training coordinator.

use std::sync::Arc;

use crate::eval;
use crate::fw::cancel::CancelToken;
use crate::fw::checkpoint::{FwCheckpoint, PathDurability, RunDurability};
use crate::fw::config::FwConfig;
use crate::fw::fast::FastFrankWolfe;
use crate::fw::flops::{BYTES_F32_READ, BYTES_F64_READ, FLOPS_SIGMOID};
use crate::fw::standard::StandardFrankWolfe;
use crate::fw::trace::FwOutput;
use crate::fw::workspace::FwWorkspace;
use crate::sparse::Dataset;
use crate::testkit::faults::FaultPlan;

/// Which solver implementation to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Algo {
    /// Algorithm 1 — standard sparse-aware FW (dense per-iteration work).
    Standard,
    /// Algorithm 2 — fast sparse-aware FW.
    Fast,
    /// Not a solver: batch inference over frozen weights (a
    /// [`PredictJob`]). Lives in `Algo` so [`JobResult::algo`] can label
    /// all three serving classes uniformly.
    Predict,
}

impl Algo {
    pub fn name(&self) -> &'static str {
        match self {
            Algo::Standard => "alg1",
            Algo::Fast => "alg2",
            Algo::Predict => "predict",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "alg1" | "standard" => Some(Algo::Standard),
            "alg2" | "fast" => Some(Algo::Fast),
            "predict" => Some(Algo::Predict),
            _ => None,
        }
    }
}

/// One training job: a dataset (shared, read-only), a solver, a config,
/// and a label for reporting.
#[derive(Clone)]
pub struct JobSpec {
    pub id: usize,
    pub label: String,
    pub data: Arc<Dataset>,
    pub algo: Algo,
    pub cfg: FwConfig,
    /// Optional held-out set: when present, the result carries
    /// accuracy/AUC on it (computed with the sparse scorer; the PJRT
    /// oracle path is exercised separately in tests/examples).
    pub test_data: Option<Arc<Dataset>>,
}

impl JobSpec {
    /// Execute synchronously with a one-shot workspace.
    pub fn run(&self) -> JobResult {
        self.run_in(&mut FwWorkspace::new())
    }

    /// Execute inside a reusable workspace — the coordinator keeps one per
    /// worker thread so a grid sweep's hundreds of runs share solver
    /// buffers and selector storage instead of reallocating per job.
    /// Bit-exactly equivalent to [`JobSpec::run`].
    pub fn run_in(&self, ws: &mut FwWorkspace) -> JobResult {
        // On a hub-connected workspace (ingress pool, DESIGN.md §6.10) the
        // bootstrap runs in Shared mode so concurrent same-dataset solves
        // coalesce into one leader compute; output stays bit-identical.
        let shared = ws.has_boot_hub();
        let out = match self.algo {
            Algo::Standard => {
                let s = StandardFrankWolfe::new(&self.data, self.cfg.clone());
                if shared { s.run_in_shared(ws) } else { s.run_in(ws) }
            }
            Algo::Fast => {
                let s = FastFrankWolfe::new(&self.data, self.cfg.clone());
                if shared { s.run_in_shared(ws) } else { s.run_in(ws) }
            }
            Algo::Predict => {
                panic!("Algo::Predict is not a solver; submit a PredictJob")
            }
        };
        finish_result(
            self.id,
            self.label.clone(),
            self.algo,
            &self.cfg,
            self.test_data.as_deref(),
            out,
        )
    }
}

/// Score (when a held-out set is present) and package one solver output.
fn finish_result(
    id: usize,
    label: String,
    algo: Algo,
    cfg: &FwConfig,
    test_data: Option<&Dataset>,
    out: FwOutput,
) -> JobResult {
    let (accuracy, auc) = match test_data {
        Some(test) => {
            // Respect the job's thread budget: pooled jobs arrive with
            // threads pinned to 1 by the scheduler, so scoring must not
            // fan back out underneath the worker pool.
            let threads = match cfg.threads {
                0 => crate::sparse::auto_threads(test.nnz()),
                t => t,
            };
            let p = score_with_threads(test, out.weights.as_slice(), threads);
            (Some(eval::accuracy(&p, &test.labels)), Some(eval::auc(&p, &test.labels)))
        }
        None => (None, None),
    };
    JobResult {
        id,
        label,
        algo,
        selector: cfg.selector.name().to_string(),
        accuracy,
        auc,
        sparsity_pct: eval::sparsity_pct(out.weights.as_slice()),
        predictions: None,
        output: out,
    }
}

/// One regularization-path job: a whole λ-grid over one dataset,
/// dispatched to a single worker/workspace so the dense bootstrap
/// `α = Xᵀq̄` — identical for every λ — is computed once per path (the
/// solvers' `run_path`, DESIGN.md §6.5) instead of once per cell. Produces
/// one [`JobResult`] per λ, with ids `base_id .. base_id + lambdas.len()`
/// and labels `"{label}|lam{λ}"`.
#[derive(Clone)]
pub struct PathJob {
    /// Id of the first λ's result; later points get consecutive ids.
    pub base_id: usize,
    pub label: String,
    pub data: Arc<Dataset>,
    pub algo: Algo,
    /// Per-run config; its `lambda` is ignored in favour of `lambdas`.
    pub cfg: FwConfig,
    /// The λ grid, trained in order through one workspace.
    pub lambdas: Vec<f64>,
    pub test_data: Option<Arc<Dataset>>,
}

impl PathJob {
    /// Execute synchronously with a one-shot workspace.
    pub fn run(&self) -> Vec<JobResult> {
        self.run_in(&mut FwWorkspace::new())
    }

    /// Execute inside a reusable workspace. Every output is bit-identical
    /// to the corresponding independent [`JobSpec`] at that λ (modulo the
    /// skipped bootstrap FLOPs — see `FwOutput::bootstrap_flops`).
    ///
    /// When the config carries a [`PathDurability`] plan (§6.12, armed by
    /// the scheduler), each grid point runs as its own durable solve —
    /// cadence checkpoints under that point's `ckpt-<req>-<k>.bin`,
    /// write-ahead ε records under that point's request id, and an
    /// optional per-point resume snapshot. Both branches route every λ
    /// through `run_core(ws, λ, Bootstrap::Shared)`, so the armed loop is
    /// bit-identical to the plain `run_path` sweep.
    pub fn run_in(&self, ws: &mut FwWorkspace) -> Vec<JobResult> {
        if let Some(plan) = self.cfg.path_durability.clone() {
            return self.run_in_durable(ws, &plan);
        }
        let outs = match self.algo {
            Algo::Standard => StandardFrankWolfe::new(&self.data, self.cfg.clone())
                .run_path(&self.lambdas, ws),
            Algo::Fast => {
                FastFrankWolfe::new(&self.data, self.cfg.clone()).run_path(&self.lambdas, ws)
            }
            Algo::Predict => {
                panic!("Algo::Predict is not a solver; submit a PredictJob")
            }
        };
        outs.into_iter()
            .zip(&self.lambdas)
            .enumerate()
            .map(|(k, (out, &lam))| {
                finish_result(
                    self.base_id + k,
                    format!("{}|lam{}", self.label, lam),
                    self.algo,
                    &self.cfg,
                    self.test_data.as_deref(),
                    out,
                )
            })
            .collect()
    }

    /// The durable λ-grid sweep: per-point configs (λ pinned, that point's
    /// [`RunDurability`] cell and resume snapshot attached, the path plan
    /// itself stripped so the inner solve can't recurse), all sharing one
    /// workspace so the dense bootstrap is still computed at most once.
    fn run_in_durable(&self, ws: &mut FwWorkspace, plan: &PathDurability) -> Vec<JobResult> {
        self.lambdas
            .iter()
            .enumerate()
            .map(|(k, &lam)| {
                assert!(lam > 0.0, "path lambda must be positive");
                let mut cfg_k = self.cfg.clone();
                cfg_k.lambda = lam;
                cfg_k.durability = plan.cell(k).cloned();
                cfg_k.resume = plan.resume(k);
                cfg_k.path_durability = None;
                let out = match self.algo {
                    Algo::Standard => {
                        StandardFrankWolfe::new(&self.data, cfg_k.clone()).run_in_shared(ws)
                    }
                    Algo::Fast => {
                        FastFrankWolfe::new(&self.data, cfg_k.clone()).run_in_shared(ws)
                    }
                    Algo::Predict => {
                        panic!("Algo::Predict is not a solver; submit a PredictJob")
                    }
                };
                finish_result(
                    self.base_id + k,
                    format!("{}|lam{}", self.label, lam),
                    self.algo,
                    &cfg_k,
                    self.test_data.as_deref(),
                    out,
                )
            })
            .collect()
    }
}

/// One batch-inference job: score a frozen weight vector over a dataset
/// (`p_i = σ(x_i·w)`) with no solver work and no privacy spend — the
/// third ingress job class (DESIGN.md §6.10), cheap and latency-bound,
/// scheduled on the same worker pool as solves.
#[derive(Clone)]
pub struct PredictJob {
    pub id: usize,
    pub label: String,
    pub data: Arc<Dataset>,
    /// Frozen model; length must equal the dataset's column count.
    pub weights: Arc<Vec<f64>>,
    /// Scoring thread budget; `0` = auto (the pool pins pooled jobs to 1).
    pub threads: usize,
    pub cancel: CancelToken,
    pub fault: FaultPlan,
}

impl PredictJob {
    /// Score synchronously. The result's `output` carries the §6.6 flop /
    /// byte model of the single CSR sweep (index stream + per-nonzero
    /// value read and `w` gather + per-row sigmoid) so ingress
    /// bytes-per-request accounting covers predictions too.
    pub fn run(&self) -> JobResult {
        let start = std::time::Instant::now();
        assert_eq!(
            self.weights.len(),
            self.data.csr.n_cols(),
            "weight/feature dimension mismatch"
        );
        let threads = match self.threads {
            0 => crate::sparse::auto_threads(self.data.nnz()),
            t => t,
        };
        let p = score_with_threads(&self.data, &self.weights, threads);
        let n = self.data.csr.n_rows() as u64;
        let nnz = self.data.nnz() as u64;
        let flops = 2 * nnz + n * FLOPS_SIGMOID;
        let bytes = self.data.csr.index_bytes_total()
            + (BYTES_F32_READ + BYTES_F64_READ) * nnz
            + BYTES_F64_READ * n;
        let out = FwOutput::scored(
            self.weights.as_ref().clone(),
            flops,
            bytes,
            start.elapsed().as_secs_f64() * 1e3,
            threads,
        );
        JobResult {
            id: self.id,
            label: self.label.clone(),
            algo: Algo::Predict,
            selector: "none".into(),
            accuracy: Some(eval::accuracy(&p, &self.data.labels)),
            auc: Some(eval::auc(&p, &self.data.labels)),
            sparsity_pct: eval::sparsity_pct(&self.weights),
            predictions: Some(p),
            output: out,
        }
    }
}

/// What the scheduler dispatches: one grid cell, a whole λ-path that
/// must stay on one worker to share its workspace's bootstrap cache, or
/// a batch prediction.
#[derive(Clone)]
pub enum Job {
    Cell(JobSpec),
    Path(PathJob),
    Predict(PredictJob),
}

impl Job {
    /// How many [`JobResult`]s this job produces.
    pub fn n_results(&self) -> usize {
        match self {
            Job::Cell(_) | Job::Predict(_) => 1,
            Job::Path(p) => p.lambdas.len(),
        }
    }

    /// The result ids this job will emit (used to report per-result
    /// failures when a job panics).
    pub fn result_ids(&self) -> std::ops::Range<usize> {
        match self {
            Job::Cell(c) => c.id..c.id + 1,
            Job::Path(p) => p.base_id..p.base_id + p.lambdas.len(),
            Job::Predict(p) => p.id..p.id + 1,
        }
    }

    /// Execute inside a reusable workspace.
    pub fn run_in(&self, ws: &mut FwWorkspace) -> Vec<JobResult> {
        match self {
            Job::Cell(c) => vec![c.run_in(ws)],
            Job::Path(p) => p.run_in(ws),
            Job::Predict(p) => vec![p.run()],
        }
    }

    /// The job's stop signal (shed-while-queued, deadline supervision).
    pub(crate) fn cancel(&self) -> &CancelToken {
        match self {
            Job::Cell(c) => &c.cfg.cancel,
            Job::Path(p) => &p.cfg.cancel,
            Job::Predict(p) => &p.cancel,
        }
    }

    /// The job's fault-injection plan (tests/benches only; defaults
    /// disarmed).
    pub(crate) fn fault(&self) -> &FaultPlan {
        match self {
            Job::Cell(c) => &c.cfg.fault,
            Job::Path(p) => &p.cfg.fault,
            Job::Predict(p) => &p.fault,
        }
    }

    /// The job's privacy parameters, when it is a private solve (predict
    /// jobs spend nothing; the ingress budget gate keys off this).
    pub(crate) fn privacy(&self) -> Option<&crate::dp::accounting::PrivacyParams> {
        match self {
            Job::Cell(c) => c.cfg.privacy.as_ref(),
            Job::Path(p) => p.cfg.privacy.as_ref(),
            Job::Predict(_) => None,
        }
    }

    /// Arm §6.11 durability on a single-cell solve: cadence checkpoints +
    /// write-ahead ε-ledger records. Predictions are stateless and path
    /// jobs are armed per grid point through [`Job::arm_path_durability`]
    /// (§6.12) instead, so both decline (`false`) here.
    pub(crate) fn arm_durability(&mut self, dur: Arc<RunDurability>) -> bool {
        match self {
            Job::Cell(c) => {
                c.cfg.durability = Some(dur);
                true
            }
            Job::Path(_) | Job::Predict(_) => false,
        }
    }

    /// Arm §6.12 durability on a λ-path job: one [`RunDurability`] cell
    /// (own ledger request id, own `ckpt-<req>-<k>.bin` file) plus an
    /// optional resume snapshot per grid point, carried on the job's
    /// config so the exhaustive pub [`PathJob`] literal stays stable.
    /// Returns `false` for non-path jobs.
    pub(crate) fn arm_path_durability(&mut self, plan: Arc<PathDurability>) -> bool {
        match self {
            Job::Path(p) => {
                p.cfg.path_durability = Some(plan);
                true
            }
            Job::Cell(_) | Job::Predict(_) => false,
        }
    }

    /// Attach a resume checkpoint to a single-cell solve (the supervisor's
    /// crash-recovery path). Returns `false` for non-cell jobs — a path's
    /// per-point resumes ride in its [`PathDurability`] plan.
    pub(crate) fn set_resume(&mut self, ck: Arc<FwCheckpoint>) -> bool {
        match self {
            Job::Cell(c) => {
                c.cfg.resume = Some(ck);
                true
            }
            Job::Path(_) | Job::Predict(_) => false,
        }
    }

    /// Pin auto-threaded jobs to one thread so a multi-worker pool doesn't
    /// oversubscribe the machine (explicit budgets are respected).
    pub(crate) fn pin_threads(&mut self) {
        let t = match self {
            Job::Cell(c) => &mut c.cfg.threads,
            Job::Path(p) => &mut p.cfg.threads,
            Job::Predict(p) => &mut p.threads,
        };
        if *t == 0 {
            *t = 1;
        }
    }
}

/// Why a job id resolved to `Err` (DESIGN.md §6.9). Replaces the old
/// bare panic-message `String`: callers can now distinguish "this cell's
/// solve panicked" from scheduler-level outcomes (shed, worker death,
/// pool gone) that say nothing about the cell itself.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum JobError {
    /// The solve panicked (no retries configured); carries the panic
    /// message.
    Panicked(String),
    /// The solve panicked on every attempt up to the retry limit; carries
    /// the attempt count and the *last* panic message.
    RetriesExhausted { attempts: u32, last: String },
    /// The job's cancel token had already fired while it was still
    /// queued, so the scheduler shed it without doing any solver work.
    Expired,
    /// The worker thread executing the job died without reporting; the
    /// supervisor failed the owed ids and respawned the worker.
    WorkerDied,
    /// The worker pool is gone (coordinator shut down), so the job was
    /// never dispatched.
    PoolDied,
}

impl std::fmt::Display for JobError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JobError::Panicked(msg) => write!(f, "job panicked: {msg}"),
            JobError::RetriesExhausted { attempts, last } => {
                write!(f, "job panicked on all {attempts} attempts; last: {last}")
            }
            JobError::Expired => write!(f, "job expired while queued (shed unrun)"),
            JobError::WorkerDied => write!(f, "worker died while running the job"),
            JobError::PoolDied => write!(f, "worker pool is shut down"),
        }
    }
}

impl std::error::Error for JobError {}

/// Sparse scorer `p_i = σ(x_i·w)` (training path: no Python, no XLA).
/// Row-block parallel for paper-scale datasets; bit-identical to the
/// serial matvec at any thread count.
pub fn score(ds: &Dataset, w: &[f64]) -> Vec<f64> {
    score_with_threads(ds, w, crate::sparse::auto_threads(ds.nnz()))
}

/// [`score`] with an explicit thread budget (the coordinator passes the
/// job's pinned count so pooled scoring doesn't oversubscribe the pool).
pub fn score_with_threads(ds: &Dataset, w: &[f64], threads: usize) -> Vec<f64> {
    let mut v = vec![0.0f64; ds.n_rows()];
    ds.csr.matvec_par(w, &mut v, threads);
    v.iter().map(|&vi| crate::fw::loss::sigmoid(vi)).collect()
}

/// Completed-job record.
#[derive(Clone, Debug)]
pub struct JobResult {
    pub id: usize,
    pub label: String,
    pub algo: Algo,
    pub selector: String,
    pub accuracy: Option<f64>,
    pub auc: Option<f64>,
    pub sparsity_pct: f64,
    /// Per-row scores `σ(x_i·w)` — populated only by [`PredictJob`]
    /// (solve/path results never carry them; predictions for a trained
    /// model are a separate predict request).
    pub predictions: Option<Vec<f64>>,
    pub output: FwOutput,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::synth::SynthConfig;

    fn ds() -> Arc<Dataset> {
        Arc::new(
            SynthConfig {
                name: "job".into(),
                n_rows: 100,
                n_cols: 50,
                avg_row_nnz: 8.0,
                zipf_exponent: 1.2,
                n_informative: 10,
                n_dense: 0,
                label_noise: 0.02,
            bias_col: true,
            }
            .generate(3),
        )
    }

    #[test]
    fn job_runs_and_scores() {
        let d = ds();
        let spec = JobSpec {
            id: 0,
            label: "t".into(),
            data: d.clone(),
            algo: Algo::Fast,
            cfg: FwConfig { iters: 150, lambda: 6.0, ..Default::default() },
            test_data: Some(d),
        };
        let r = spec.run();
        // trains on the same data it scores: must beat chance comfortably
        assert!(r.accuracy.unwrap() > 60.0, "acc={:?}", r.accuracy);
        assert!(r.auc.unwrap() > 60.0);
        assert!(r.sparsity_pct > 0.0);
    }

    #[test]
    fn path_job_matches_independent_cells() {
        let d = ds();
        let lambdas = vec![3.0, 6.0];
        let pj = PathJob {
            base_id: 10,
            label: "p".into(),
            data: d.clone(),
            algo: Algo::Fast,
            cfg: FwConfig { iters: 80, lambda: 1.0, ..Default::default() },
            lambdas: lambdas.clone(),
            test_data: Some(d.clone()),
        };
        let rs = pj.run();
        assert_eq!(rs.len(), 2);
        assert_eq!((rs[0].id, rs[1].id), (10, 11));
        assert!(rs[1].label.ends_with("|lam6"), "{}", rs[1].label);
        assert!(rs[1].output.bootstrap_flops == 0, "second λ must be warm");
        for (r, &lam) in rs.iter().zip(&lambdas) {
            let cell = JobSpec {
                id: 0,
                label: "c".into(),
                data: d.clone(),
                algo: Algo::Fast,
                cfg: FwConfig { iters: 80, lambda: lam, ..Default::default() },
                test_data: Some(d.clone()),
            }
            .run();
            assert_eq!(cell.output.weights, r.output.weights);
            assert_eq!(cell.accuracy, r.accuracy);
            assert_eq!(cell.auc, r.auc);
        }
    }

    #[test]
    fn algo_name_roundtrip() {
        assert_eq!(Algo::from_name("alg1"), Some(Algo::Standard));
        assert_eq!(Algo::from_name("fast"), Some(Algo::Fast));
        assert_eq!(Algo::from_name("x"), None);
    }
}
