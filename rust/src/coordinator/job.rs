//! Job specifications and results for the training coordinator.

use std::sync::Arc;

use crate::eval;
use crate::fw::config::FwConfig;
use crate::fw::fast::FastFrankWolfe;
use crate::fw::standard::StandardFrankWolfe;
use crate::fw::trace::FwOutput;
use crate::fw::workspace::FwWorkspace;
use crate::sparse::Dataset;

/// Which solver implementation to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Algo {
    /// Algorithm 1 — standard sparse-aware FW (dense per-iteration work).
    Standard,
    /// Algorithm 2 — fast sparse-aware FW.
    Fast,
}

impl Algo {
    pub fn name(&self) -> &'static str {
        match self {
            Algo::Standard => "alg1",
            Algo::Fast => "alg2",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "alg1" | "standard" => Some(Algo::Standard),
            "alg2" | "fast" => Some(Algo::Fast),
            _ => None,
        }
    }
}

/// One training job: a dataset (shared, read-only), a solver, a config,
/// and a label for reporting.
#[derive(Clone)]
pub struct JobSpec {
    pub id: usize,
    pub label: String,
    pub data: Arc<Dataset>,
    pub algo: Algo,
    pub cfg: FwConfig,
    /// Optional held-out set: when present, the result carries
    /// accuracy/AUC on it (computed with the sparse scorer; the PJRT
    /// oracle path is exercised separately in tests/examples).
    pub test_data: Option<Arc<Dataset>>,
}

impl JobSpec {
    /// Execute synchronously with a one-shot workspace.
    pub fn run(&self) -> JobResult {
        self.run_in(&mut FwWorkspace::new())
    }

    /// Execute inside a reusable workspace — the coordinator keeps one per
    /// worker thread so a grid sweep's hundreds of runs share solver
    /// buffers and selector storage instead of reallocating per job.
    /// Bit-exactly equivalent to [`JobSpec::run`].
    pub fn run_in(&self, ws: &mut FwWorkspace) -> JobResult {
        let out = match self.algo {
            Algo::Standard => {
                StandardFrankWolfe::new(&self.data, self.cfg.clone()).run_in(ws)
            }
            Algo::Fast => FastFrankWolfe::new(&self.data, self.cfg.clone()).run_in(ws),
        };
        finish_result(
            self.id,
            self.label.clone(),
            self.algo,
            &self.cfg,
            self.test_data.as_deref(),
            out,
        )
    }
}

/// Score (when a held-out set is present) and package one solver output.
fn finish_result(
    id: usize,
    label: String,
    algo: Algo,
    cfg: &FwConfig,
    test_data: Option<&Dataset>,
    out: FwOutput,
) -> JobResult {
    let (accuracy, auc) = match test_data {
        Some(test) => {
            // Respect the job's thread budget: pooled jobs arrive with
            // threads pinned to 1 by the scheduler, so scoring must not
            // fan back out underneath the worker pool.
            let threads = match cfg.threads {
                0 => crate::sparse::auto_threads(test.nnz()),
                t => t,
            };
            let p = score_with_threads(test, out.weights.as_slice(), threads);
            (Some(eval::accuracy(&p, &test.labels)), Some(eval::auc(&p, &test.labels)))
        }
        None => (None, None),
    };
    JobResult {
        id,
        label,
        algo,
        selector: cfg.selector.name().to_string(),
        accuracy,
        auc,
        sparsity_pct: eval::sparsity_pct(out.weights.as_slice()),
        output: out,
    }
}

/// One regularization-path job: a whole λ-grid over one dataset,
/// dispatched to a single worker/workspace so the dense bootstrap
/// `α = Xᵀq̄` — identical for every λ — is computed once per path (the
/// solvers' `run_path`, DESIGN.md §6.5) instead of once per cell. Produces
/// one [`JobResult`] per λ, with ids `base_id .. base_id + lambdas.len()`
/// and labels `"{label}|lam{λ}"`.
#[derive(Clone)]
pub struct PathJob {
    /// Id of the first λ's result; later points get consecutive ids.
    pub base_id: usize,
    pub label: String,
    pub data: Arc<Dataset>,
    pub algo: Algo,
    /// Per-run config; its `lambda` is ignored in favour of `lambdas`.
    pub cfg: FwConfig,
    /// The λ grid, trained in order through one workspace.
    pub lambdas: Vec<f64>,
    pub test_data: Option<Arc<Dataset>>,
}

impl PathJob {
    /// Execute synchronously with a one-shot workspace.
    pub fn run(&self) -> Vec<JobResult> {
        self.run_in(&mut FwWorkspace::new())
    }

    /// Execute inside a reusable workspace. Every output is bit-identical
    /// to the corresponding independent [`JobSpec`] at that λ (modulo the
    /// skipped bootstrap FLOPs — see `FwOutput::bootstrap_flops`).
    pub fn run_in(&self, ws: &mut FwWorkspace) -> Vec<JobResult> {
        let outs = match self.algo {
            Algo::Standard => StandardFrankWolfe::new(&self.data, self.cfg.clone())
                .run_path(&self.lambdas, ws),
            Algo::Fast => {
                FastFrankWolfe::new(&self.data, self.cfg.clone()).run_path(&self.lambdas, ws)
            }
        };
        outs.into_iter()
            .zip(&self.lambdas)
            .enumerate()
            .map(|(k, (out, &lam))| {
                finish_result(
                    self.base_id + k,
                    format!("{}|lam{}", self.label, lam),
                    self.algo,
                    &self.cfg,
                    self.test_data.as_deref(),
                    out,
                )
            })
            .collect()
    }
}

/// What the scheduler dispatches: one grid cell, or a whole λ-path that
/// must stay on one worker to share its workspace's bootstrap cache.
#[derive(Clone)]
pub enum Job {
    Cell(JobSpec),
    Path(PathJob),
}

impl Job {
    /// How many [`JobResult`]s this job produces.
    pub fn n_results(&self) -> usize {
        match self {
            Job::Cell(_) => 1,
            Job::Path(p) => p.lambdas.len(),
        }
    }

    /// The result ids this job will emit (used to report per-result
    /// failures when a job panics).
    pub fn result_ids(&self) -> std::ops::Range<usize> {
        match self {
            Job::Cell(c) => c.id..c.id + 1,
            Job::Path(p) => p.base_id..p.base_id + p.lambdas.len(),
        }
    }

    /// Execute inside a reusable workspace.
    pub fn run_in(&self, ws: &mut FwWorkspace) -> Vec<JobResult> {
        match self {
            Job::Cell(c) => vec![c.run_in(ws)],
            Job::Path(p) => p.run_in(ws),
        }
    }

    pub(crate) fn cfg_mut(&mut self) -> &mut FwConfig {
        match self {
            Job::Cell(c) => &mut c.cfg,
            Job::Path(p) => &mut p.cfg,
        }
    }

    pub(crate) fn cfg(&self) -> &FwConfig {
        match self {
            Job::Cell(c) => &c.cfg,
            Job::Path(p) => &p.cfg,
        }
    }
}

/// Why a job id resolved to `Err` (DESIGN.md §6.9). Replaces the old
/// bare panic-message `String`: callers can now distinguish "this cell's
/// solve panicked" from scheduler-level outcomes (shed, worker death,
/// pool gone) that say nothing about the cell itself.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum JobError {
    /// The solve panicked (no retries configured); carries the panic
    /// message.
    Panicked(String),
    /// The solve panicked on every attempt up to the retry limit; carries
    /// the attempt count and the *last* panic message.
    RetriesExhausted { attempts: u32, last: String },
    /// The job's cancel token had already fired while it was still
    /// queued, so the scheduler shed it without doing any solver work.
    Expired,
    /// The worker thread executing the job died without reporting; the
    /// supervisor failed the owed ids and respawned the worker.
    WorkerDied,
    /// The worker pool is gone (coordinator shut down), so the job was
    /// never dispatched.
    PoolDied,
}

impl std::fmt::Display for JobError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JobError::Panicked(msg) => write!(f, "job panicked: {msg}"),
            JobError::RetriesExhausted { attempts, last } => {
                write!(f, "job panicked on all {attempts} attempts; last: {last}")
            }
            JobError::Expired => write!(f, "job expired while queued (shed unrun)"),
            JobError::WorkerDied => write!(f, "worker died while running the job"),
            JobError::PoolDied => write!(f, "worker pool is shut down"),
        }
    }
}

impl std::error::Error for JobError {}

/// Sparse scorer `p_i = σ(x_i·w)` (training path: no Python, no XLA).
/// Row-block parallel for paper-scale datasets; bit-identical to the
/// serial matvec at any thread count.
pub fn score(ds: &Dataset, w: &[f64]) -> Vec<f64> {
    score_with_threads(ds, w, crate::sparse::auto_threads(ds.nnz()))
}

/// [`score`] with an explicit thread budget (the coordinator passes the
/// job's pinned count so pooled scoring doesn't oversubscribe the pool).
pub fn score_with_threads(ds: &Dataset, w: &[f64], threads: usize) -> Vec<f64> {
    let mut v = vec![0.0f64; ds.n_rows()];
    ds.csr.matvec_par(w, &mut v, threads);
    v.iter().map(|&vi| crate::fw::loss::sigmoid(vi)).collect()
}

/// Completed-job record.
#[derive(Clone, Debug)]
pub struct JobResult {
    pub id: usize,
    pub label: String,
    pub algo: Algo,
    pub selector: String,
    pub accuracy: Option<f64>,
    pub auc: Option<f64>,
    pub sparsity_pct: f64,
    pub output: FwOutput,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::synth::SynthConfig;

    fn ds() -> Arc<Dataset> {
        Arc::new(
            SynthConfig {
                name: "job".into(),
                n_rows: 100,
                n_cols: 50,
                avg_row_nnz: 8.0,
                zipf_exponent: 1.2,
                n_informative: 10,
                n_dense: 0,
                label_noise: 0.02,
            bias_col: true,
            }
            .generate(3),
        )
    }

    #[test]
    fn job_runs_and_scores() {
        let d = ds();
        let spec = JobSpec {
            id: 0,
            label: "t".into(),
            data: d.clone(),
            algo: Algo::Fast,
            cfg: FwConfig { iters: 150, lambda: 6.0, ..Default::default() },
            test_data: Some(d),
        };
        let r = spec.run();
        // trains on the same data it scores: must beat chance comfortably
        assert!(r.accuracy.unwrap() > 60.0, "acc={:?}", r.accuracy);
        assert!(r.auc.unwrap() > 60.0);
        assert!(r.sparsity_pct > 0.0);
    }

    #[test]
    fn path_job_matches_independent_cells() {
        let d = ds();
        let lambdas = vec![3.0, 6.0];
        let pj = PathJob {
            base_id: 10,
            label: "p".into(),
            data: d.clone(),
            algo: Algo::Fast,
            cfg: FwConfig { iters: 80, lambda: 1.0, ..Default::default() },
            lambdas: lambdas.clone(),
            test_data: Some(d.clone()),
        };
        let rs = pj.run();
        assert_eq!(rs.len(), 2);
        assert_eq!((rs[0].id, rs[1].id), (10, 11));
        assert!(rs[1].label.ends_with("|lam6"), "{}", rs[1].label);
        assert!(rs[1].output.bootstrap_flops == 0, "second λ must be warm");
        for (r, &lam) in rs.iter().zip(&lambdas) {
            let cell = JobSpec {
                id: 0,
                label: "c".into(),
                data: d.clone(),
                algo: Algo::Fast,
                cfg: FwConfig { iters: 80, lambda: lam, ..Default::default() },
                test_data: Some(d.clone()),
            }
            .run();
            assert_eq!(cell.output.weights, r.output.weights);
            assert_eq!(cell.accuracy, r.accuracy);
            assert_eq!(cell.auc, r.auc);
        }
    }

    #[test]
    fn algo_name_roundtrip() {
        assert_eq!(Algo::from_name("alg1"), Some(Algo::Standard));
        assert_eq!(Algo::from_name("fast"), Some(Algo::Fast));
        assert_eq!(Algo::from_name("x"), None);
    }
}
