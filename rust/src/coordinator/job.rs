//! Job specifications and results for the training coordinator.

use std::sync::Arc;

use crate::eval;
use crate::fw::config::FwConfig;
use crate::fw::fast::FastFrankWolfe;
use crate::fw::standard::StandardFrankWolfe;
use crate::fw::trace::FwOutput;
use crate::fw::workspace::FwWorkspace;
use crate::sparse::Dataset;

/// Which solver implementation to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Algo {
    /// Algorithm 1 — standard sparse-aware FW (dense per-iteration work).
    Standard,
    /// Algorithm 2 — fast sparse-aware FW.
    Fast,
}

impl Algo {
    pub fn name(&self) -> &'static str {
        match self {
            Algo::Standard => "alg1",
            Algo::Fast => "alg2",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "alg1" | "standard" => Some(Algo::Standard),
            "alg2" | "fast" => Some(Algo::Fast),
            _ => None,
        }
    }
}

/// One training job: a dataset (shared, read-only), a solver, a config,
/// and a label for reporting.
#[derive(Clone)]
pub struct JobSpec {
    pub id: usize,
    pub label: String,
    pub data: Arc<Dataset>,
    pub algo: Algo,
    pub cfg: FwConfig,
    /// Optional held-out set: when present, the result carries
    /// accuracy/AUC on it (computed with the sparse scorer; the PJRT
    /// oracle path is exercised separately in tests/examples).
    pub test_data: Option<Arc<Dataset>>,
}

impl JobSpec {
    /// Execute synchronously with a one-shot workspace.
    pub fn run(&self) -> JobResult {
        self.run_in(&mut FwWorkspace::new())
    }

    /// Execute inside a reusable workspace — the coordinator keeps one per
    /// worker thread so a grid sweep's hundreds of runs share solver
    /// buffers and selector storage instead of reallocating per job.
    /// Bit-exactly equivalent to [`JobSpec::run`].
    pub fn run_in(&self, ws: &mut FwWorkspace) -> JobResult {
        let out = match self.algo {
            Algo::Standard => {
                StandardFrankWolfe::new(&self.data, self.cfg.clone()).run_in(ws)
            }
            Algo::Fast => FastFrankWolfe::new(&self.data, self.cfg.clone()).run_in(ws),
        };
        let (accuracy, auc) = match &self.test_data {
            Some(test) => {
                // Respect the job's thread budget: pooled jobs arrive with
                // threads pinned to 1 by the scheduler, so scoring must not
                // fan back out underneath the worker pool.
                let threads = match self.cfg.threads {
                    0 => crate::sparse::auto_threads(test.nnz()),
                    t => t,
                };
                let p = score_with_threads(test, out.weights.as_slice(), threads);
                (Some(eval::accuracy(&p, &test.labels)), Some(eval::auc(&p, &test.labels)))
            }
            None => (None, None),
        };
        JobResult {
            id: self.id,
            label: self.label.clone(),
            algo: self.algo,
            selector: self.cfg.selector.name().to_string(),
            accuracy,
            auc,
            sparsity_pct: eval::sparsity_pct(out.weights.as_slice()),
            output: out,
        }
    }
}

/// Sparse scorer `p_i = σ(x_i·w)` (training path: no Python, no XLA).
/// Row-block parallel for paper-scale datasets; bit-identical to the
/// serial matvec at any thread count.
pub fn score(ds: &Dataset, w: &[f64]) -> Vec<f64> {
    score_with_threads(ds, w, crate::sparse::auto_threads(ds.nnz()))
}

/// [`score`] with an explicit thread budget (the coordinator passes the
/// job's pinned count so pooled scoring doesn't oversubscribe the pool).
pub fn score_with_threads(ds: &Dataset, w: &[f64], threads: usize) -> Vec<f64> {
    let mut v = vec![0.0f64; ds.n_rows()];
    ds.csr.matvec_par(w, &mut v, threads);
    v.iter().map(|&vi| crate::fw::loss::sigmoid(vi)).collect()
}

/// Completed-job record.
#[derive(Clone, Debug)]
pub struct JobResult {
    pub id: usize,
    pub label: String,
    pub algo: Algo,
    pub selector: String,
    pub accuracy: Option<f64>,
    pub auc: Option<f64>,
    pub sparsity_pct: f64,
    pub output: FwOutput,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::synth::SynthConfig;

    fn ds() -> Arc<Dataset> {
        Arc::new(
            SynthConfig {
                name: "job".into(),
                n_rows: 100,
                n_cols: 50,
                avg_row_nnz: 8.0,
                zipf_exponent: 1.2,
                n_informative: 10,
                n_dense: 0,
                label_noise: 0.02,
            bias_col: true,
            }
            .generate(3),
        )
    }

    #[test]
    fn job_runs_and_scores() {
        let d = ds();
        let spec = JobSpec {
            id: 0,
            label: "t".into(),
            data: d.clone(),
            algo: Algo::Fast,
            cfg: FwConfig { iters: 150, lambda: 6.0, ..Default::default() },
            test_data: Some(d),
        };
        let r = spec.run();
        // trains on the same data it scores: must beat chance comfortably
        assert!(r.accuracy.unwrap() > 60.0, "acc={:?}", r.accuracy);
        assert!(r.auc.unwrap() > 60.0);
        assert!(r.sparsity_pct > 0.0);
    }

    #[test]
    fn algo_name_roundtrip() {
        assert_eq!(Algo::from_name("alg1"), Some(Algo::Standard));
        assert_eq!(Algo::from_name("fast"), Some(Algo::Fast));
        assert_eq!(Algo::from_name("x"), None);
    }
}
