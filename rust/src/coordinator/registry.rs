//! Results registry: collects [`JobResult`]s and exports CSV/JSON reports
//! (the persistence layer behind every experiment table).

use std::collections::HashMap;
use std::path::Path;

use anyhow::Result;

use super::job::JobResult;
use crate::textio::{CsvTable, Json};

#[derive(Default)]
pub struct Registry {
    results: Vec<JobResult>,
    /// Path-label index: `"{base}|lam{λ}"` results grouped by `base` at
    /// insert time, so [`Registry::find_path`] is a hash lookup instead of
    /// a full scan (values are indices into `results`).
    path_index: HashMap<String, Vec<usize>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, r: JobResult) {
        if let Some(cut) = r.label.rfind("|lam") {
            self.path_index
                .entry(r.label[..cut].to_string())
                .or_default()
                .push(self.results.len());
        }
        self.results.push(r);
    }

    pub fn extend(&mut self, rs: impl IntoIterator<Item = JobResult>) {
        for r in rs {
            self.add(r);
        }
    }

    pub fn len(&self) -> usize {
        self.results.len()
    }

    pub fn is_empty(&self) -> bool {
        self.results.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &JobResult> {
        self.results.iter()
    }

    pub fn find(&self, label: &str) -> Option<&JobResult> {
        self.results.iter().find(|r| r.label == label)
    }

    /// All cells of a λ-path, in submission (id) order: path results carry
    /// labels `"{base}|lam{λ}"` (see [`super::job::PathJob`]), indexed by
    /// `base` at insert time — a hash lookup plus the per-path sort, not a
    /// scan of every result the registry holds.
    pub fn find_path(&self, base: &str) -> Vec<&JobResult> {
        let Some(ix) = self.path_index.get(base) else { return Vec::new() };
        let mut out: Vec<&JobResult> = ix.iter().map(|&i| &self.results[i]).collect();
        out.sort_by_key(|r| r.id);
        out
    }

    /// Flat per-job summary table.
    pub fn to_csv(&self) -> CsvTable {
        let mut t = CsvTable::new([
            "id", "label", "algo", "selector", "iters", "wall_ms", "flops",
            "final_gap", "nnz", "sparsity_pct", "accuracy", "auc",
        ]);
        for r in &self.results {
            t.push_row([
                r.id.to_string(),
                r.label.clone(),
                r.algo.name().to_string(),
                r.selector.clone(),
                r.output.iters_run.to_string(),
                format!("{:.3}", r.output.wall_ms),
                r.output.flops.to_string(),
                format!("{:.6e}", r.output.final_gap),
                r.output.weights.nnz().to_string(),
                format!("{:.2}", r.sparsity_pct),
                r.accuracy.map(|a| format!("{a:.2}")).unwrap_or_default(),
                r.auc.map(|a| format!("{a:.2}")).unwrap_or_default(),
            ]);
        }
        t
    }

    pub fn write_csv(&self, path: impl AsRef<Path>) -> Result<()> {
        self.to_csv().write_file(path)
    }

    /// JSON export, traces included (figure regeneration input).
    pub fn to_json(&self) -> Json {
        let jobs: Vec<Json> = self
            .results
            .iter()
            .map(|r| {
                let trace: Vec<Json> = r
                    .output
                    .trace
                    .iter()
                    .map(|t| {
                        Json::obj()
                            .set("iter", t.iter)
                            .set("gap", t.gap)
                            .set("flops", t.flops)
                            .set("pops", t.pops)
                    })
                    .collect();
                Json::obj()
                    .set("id", r.id)
                    .set("label", r.label.as_str())
                    .set("algo", r.algo.name())
                    .set("selector", r.selector.as_str())
                    .set("wall_ms", r.output.wall_ms)
                    .set("flops", r.output.flops)
                    .set("final_gap", r.output.final_gap)
                    .set("nnz", r.output.weights.nnz())
                    .set("sparsity_pct", r.sparsity_pct)
                    .set(
                        "accuracy",
                        r.accuracy.map(Json::Num).unwrap_or(Json::Null),
                    )
                    .set("auc", r.auc.map(Json::Num).unwrap_or(Json::Null))
                    .set("trace", Json::Arr(trace))
            })
            .collect();
        Json::obj().set("jobs", Json::Arr(jobs))
    }

    pub fn write_json(&self, path: impl AsRef<Path>) -> Result<()> {
        self.to_json().write_file(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::{Algo, JobSpec};
    use crate::fw::config::FwConfig;
    use crate::sparse::synth::SynthConfig;
    use std::sync::Arc;

    fn one_result() -> JobResult {
        let ds = Arc::new(
            SynthConfig {
                name: "reg".into(),
                n_rows: 50,
                n_cols: 30,
                avg_row_nnz: 5.0,
                zipf_exponent: 1.2,
                n_informative: 6,
                n_dense: 0,
                label_noise: 0.02,
            bias_col: true,
            }
            .generate(5),
        );
        JobSpec {
            id: 7,
            label: "cell-a".into(),
            data: ds.clone(),
            algo: Algo::Fast,
            cfg: FwConfig { iters: 40, lambda: 3.0, trace_every: 10, ..Default::default() },
            test_data: Some(ds),
        }
        .run()
    }

    #[test]
    fn csv_and_json_exports() {
        let mut reg = Registry::new();
        reg.add(one_result());
        assert_eq!(reg.len(), 1);
        let csv = reg.to_csv().to_string();
        assert!(csv.starts_with("id,label,algo"));
        assert!(csv.contains("cell-a"));
        let json = reg.to_json().render();
        assert!(json.contains("\"label\":\"cell-a\""));
        assert!(json.contains("\"trace\":["));
        assert!(reg.find("cell-a").is_some());
        assert!(reg.find("nope").is_none());
    }

    #[test]
    fn find_path_collects_lambda_cells_in_id_order() {
        use crate::coordinator::job::PathJob;
        let ds = Arc::new(
            SynthConfig {
                name: "regpath".into(),
                n_rows: 50,
                n_cols: 30,
                avg_row_nnz: 5.0,
                zipf_exponent: 1.2,
                n_informative: 6,
                n_dense: 0,
                label_noise: 0.02,
                bias_col: true,
            }
            .generate(6),
        );
        let mut reg = Registry::new();
        reg.extend(
            PathJob {
                base_id: 3,
                label: "news".into(),
                data: ds,
                algo: Algo::Fast,
                cfg: FwConfig { iters: 30, lambda: 1.0, ..Default::default() },
                lambdas: vec![2.0, 4.0],
                test_data: None,
            }
            .run(),
        );
        let path = reg.find_path("news");
        assert_eq!(path.len(), 2);
        assert_eq!((path[0].id, path[1].id), (3, 4));
        assert!(path[0].label.ends_with("|lam2"));
        assert!(reg.find_path("nope").is_empty());
        // non-path results interleave without polluting the index, and
        // plain `add` (not just `extend`) keeps it current
        reg.add(one_result());
        assert_eq!(reg.find_path("news").len(), 2);
        assert!(reg.find_path("cell-a").is_empty());
    }
}
