//! Restart-time recovery of the §6.12 durability plane.
//!
//! The in-process half of crash recovery (the supervisor's
//! [`super::scheduler::Coordinator`] resume path) dies with the process.
//! What a dead process leaves behind is the durability directory: the
//! write-ahead ε ledger plus whatever `ckpt-*.bin` snapshots its armed
//! jobs had persisted — *orphans*, files no live supervisor owns. This
//! module turns that debris back into work:
//!
//! 1. [`RecoveryManager::scan`] walks the directory, classifies every
//!    orphan ([`OrphanState`]), and cross-checks each readable snapshot
//!    against the WAL — the dataset token the ledger recorded for the
//!    orphan's request id must equal the snapshot's `dataset_fp`, or the
//!    file cannot belong to the spend it claims to continue.
//! 2. The result is a [`RecoveryManifest`]: per durable request id, a
//!    resumable snapshot or the reason there isn't one, plus the spend
//!    the WAL already holds for it.
//! 3. The caller rebuilds its jobs and hands them back to a fresh pool
//!    via [`super::scheduler::Coordinator::submit_recovered`] with
//!    [`RecoveryManifest::slots_for`] — **reusing the original request
//!    ids**, so every re-charge max-merges into the record the dead
//!    process already wrote and the total ε per request stays exactly
//!    one run's worth, however many times it crashed.
//!
//! Nothing is ever deleted. A snapshot that cannot be trusted — torn
//! writer tmp, CRC/decode failure, dataset-fingerprint mismatch — is
//! *quarantined*: moved into `dir/quarantine/` where an operator can do
//! forensics, while the job it belonged to degrades to a seed-pinned
//! fresh rerun (bit-identical to the run that crashed, and exactly-once
//! in ε for the same reuse-the-id reason).

use std::io;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::dp::ledger::EpsLedger;
use crate::fw::checkpoint::FwCheckpoint;

/// Parse a durability-plane filename into (request id, grid index).
/// Accepts the four shapes the plane writes — `ckpt-<req>.bin` (cell),
/// `ckpt-<req>-<k>.bin` (λ-path grid point `k`), and their `.ckpt-tmp`
/// torn-writer temporaries — and nothing else (`None` for the WAL file,
/// the quarantine dir, or any foreign name).
pub(crate) fn parse_checkpoint_name(name: &str) -> Option<(u64, Option<usize>)> {
    let rest = name.strip_prefix("ckpt-")?;
    let stem = rest
        .strip_suffix(".bin")
        .or_else(|| rest.strip_suffix(".ckpt-tmp"))?;
    match stem.split_once('-') {
        None => stem.parse().ok().map(|req| (req, None)),
        Some((req, k)) => Some((req.parse().ok()?, Some(k.parse().ok()?))),
    }
}

/// Which kind of solve an orphaned snapshot belonged to (recovered from
/// its filename).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OrphanKind {
    /// `ckpt-<req>.bin`: a single-cell solve.
    Cell,
    /// `ckpt-<req>-<k>.bin`: grid point `k` of a λ-path.
    PathPoint { k: usize },
}

/// What the scan concluded about one orphan.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OrphanState {
    /// The snapshot decoded cleanly and agrees with the WAL: resubmit
    /// with it and the rerun fast-forwards through the replay prefix.
    Resumable,
    /// CRC or decode failure — the file is quarantined and the job
    /// degrades to a seed-pinned fresh rerun.
    Corrupt,
    /// The snapshot's dataset fingerprint disagrees with the token the
    /// WAL recorded for this request id: the file cannot belong to the
    /// spend it claims to continue. Quarantined; fresh rerun.
    DatasetMismatch { wal_token: u64, ckpt_token: u64 },
    /// A `.ckpt-tmp` writer temporary — a crash landed between tmp write
    /// and rename, so the file is at best a torn prefix. Quarantined;
    /// the adjacent `.bin` (the previous intact snapshot, if any) still
    /// stands.
    TornTmp,
}

/// One file a dead process left in the durability dir, classified.
#[derive(Clone, Debug)]
pub struct Orphan {
    /// Durable ledger request id from the filename — the idempotency key
    /// a rerun must reuse for exactly-once ε.
    pub request_id: u64,
    pub kind: OrphanKind,
    pub state: OrphanState,
    /// Where the file is *now*: in place for [`OrphanState::Resumable`],
    /// its quarantine location otherwise.
    pub path: PathBuf,
    /// The decoded snapshot (`Some` iff resumable).
    pub checkpoint: Option<Arc<FwCheckpoint>>,
    /// The WAL's `(released, ε)` high-water record for this request id,
    /// when a ledger was given and holds one — what the max-merge will
    /// absorb the rerun's re-charges into.
    pub spent: Option<(u32, f64)>,
}

/// Per-result-id recovery instruction for
/// [`super::scheduler::Coordinator::submit_recovered`]: the original
/// durable request id to re-arm under, and the snapshot to resume from
/// (`None` = seed-pinned fresh rerun).
#[derive(Clone, Debug)]
pub struct RecoveredSlot {
    pub request_id: u64,
    pub resume: Option<Arc<FwCheckpoint>>,
}

/// Everything one [`RecoveryManager::scan`] found, sorted by request id
/// (grid index breaking ties).
#[derive(Clone, Debug, Default)]
pub struct RecoveryManifest {
    pub orphans: Vec<Orphan>,
    /// How many files the scan moved into `dir/quarantine/`.
    pub quarantined: usize,
}

impl RecoveryManifest {
    /// The orphans whose snapshots can seed a resume.
    pub fn resumable(&self) -> impl Iterator<Item = &Orphan> {
        self.orphans.iter().filter(|o| o.state == OrphanState::Resumable)
    }

    /// The orphan for `request_id`, preferring the resumable record when
    /// the id also has quarantined artifacts (e.g. a torn tmp next to an
    /// intact `.bin`).
    pub fn find(&self, request_id: u64) -> Option<&Orphan> {
        self.resumable()
            .find(|o| o.request_id == request_id)
            .or_else(|| self.orphans.iter().find(|o| o.request_id == request_id))
    }

    /// Build the [`RecoveredSlot`]s for a job whose result ids map to
    /// `reqs` (one durable request id per result, in result order — a
    /// cell passes one, a λ-path its per-point ids). Ids the scan found
    /// a resumable snapshot for resume; the rest run fresh.
    pub fn slots_for(&self, reqs: &[u64]) -> Vec<RecoveredSlot> {
        reqs.iter()
            .map(|&request_id| RecoveredSlot {
                request_id,
                resume: self
                    .resumable()
                    .find(|o| o.request_id == request_id)
                    .and_then(|o| o.checkpoint.clone()),
            })
            .collect()
    }
}

/// Scans a dead process's durability directory and classifies what it
/// left behind (module docs for the full lifecycle).
pub struct RecoveryManager {
    dir: PathBuf,
    /// The reopened WAL, for the dataset-token cross-check and the spend
    /// column of the manifest. `None` skips both (checkpoint-only
    /// deployments): every readable snapshot is then trusted as
    /// resumable.
    ledger: Option<Arc<EpsLedger>>,
}

impl RecoveryManager {
    pub fn new(dir: impl Into<PathBuf>, ledger: Option<Arc<EpsLedger>>) -> Self {
        Self { dir: dir.into(), ledger }
    }

    /// Walk the durability dir once: classify every orphan, quarantine
    /// everything untrustworthy, and return the manifest. Idempotent —
    /// a second scan over the same dir finds only the survivors (the
    /// resumable snapshots), since quarantined files moved out of it.
    /// Errors only if the directory itself is unreadable; per-file
    /// problems are what the orphan states are for.
    pub fn scan(&self) -> io::Result<RecoveryManifest> {
        // Deterministic processing order regardless of readdir order.
        let mut names: Vec<String> = std::fs::read_dir(&self.dir)?
            .flatten()
            .filter(|e| e.file_type().is_ok_and(|t| t.is_file()))
            .filter_map(|e| e.file_name().into_string().ok())
            .collect();
        names.sort();

        let mut manifest = RecoveryManifest::default();
        for name in names {
            let Some((request_id, k)) = parse_checkpoint_name(&name) else {
                continue; // the WAL, a lock file, anything foreign
            };
            let kind = match k {
                None => OrphanKind::Cell,
                Some(k) => OrphanKind::PathPoint { k },
            };
            let src = self.dir.join(&name);
            let spent =
                self.ledger.as_ref().and_then(|l| l.spent_for_request(request_id));

            let (state, path, checkpoint) = if name.ends_with(".ckpt-tmp") {
                (OrphanState::TornTmp, self.quarantine(&src, &name, &mut manifest), None)
            } else {
                match FwCheckpoint::read_from(&src) {
                    Err(_) => (
                        OrphanState::Corrupt,
                        self.quarantine(&src, &name, &mut manifest),
                        None,
                    ),
                    Ok(ck) => {
                        let wal_token = self
                            .ledger
                            .as_ref()
                            .and_then(|l| l.token_for_request(request_id));
                        match wal_token {
                            Some(tok) if tok != ck.dataset_fp => (
                                OrphanState::DatasetMismatch {
                                    wal_token: tok,
                                    ckpt_token: ck.dataset_fp,
                                },
                                self.quarantine(&src, &name, &mut manifest),
                                None,
                            ),
                            _ => (OrphanState::Resumable, src, Some(Arc::new(ck))),
                        }
                    }
                }
            };
            manifest.orphans.push(Orphan {
                request_id,
                kind,
                state,
                path,
                checkpoint,
                spent,
            });
        }
        manifest.orphans.sort_by_key(|o| {
            (o.request_id, match o.kind {
                OrphanKind::Cell => 0,
                OrphanKind::PathPoint { k } => k,
            })
        });
        Ok(manifest)
    }

    /// Move an untrustworthy file into `dir/quarantine/` (created on
    /// demand; numeric suffix on name collision) and return where it
    /// ended up. Never deletes: if even the rename fails the file stays
    /// put, still counted as quarantined-in-intent by its orphan state —
    /// the scan will just reclassify it next time.
    fn quarantine(
        &self,
        src: &Path,
        name: &str,
        manifest: &mut RecoveryManifest,
    ) -> PathBuf {
        let qdir = self.dir.join("quarantine");
        let _ = std::fs::create_dir_all(&qdir);
        let mut dst = qdir.join(name);
        let mut n = 1u32;
        while dst.exists() {
            dst = qdir.join(format!("{name}.{n}"));
            n += 1;
        }
        match std::fs::rename(src, &dst) {
            Ok(()) => {
                manifest.quarantined += 1;
                dst
            }
            Err(_) => src.to_path_buf(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dp::ledger::{FsyncPolicy, LedgerRecord};
    use crate::fw::checkpoint::config_fingerprint;
    use crate::fw::config::FwConfig;

    fn tmpdir(name: &str) -> PathBuf {
        let p = std::env::temp_dir()
            .join(format!("dpfw-recov-{}-{}", std::process::id(), name));
        let _ = std::fs::remove_dir_all(&p);
        std::fs::create_dir_all(&p).unwrap();
        p
    }

    /// A minimal decodable snapshot claiming dataset `dataset_fp`.
    fn snapshot(dataset_fp: u64) -> FwCheckpoint {
        let cfg = FwConfig { iters: 40, lambda: 4.0, ..Default::default() };
        FwCheckpoint {
            fingerprint: config_fingerprint(&cfg),
            dataset_fp,
            seed: cfg.seed,
            t_planned: 40,
            iter: 12,
            rng: [1, 2, 3, 4],
            flops: [0; 7],
            stats: Default::default(),
            gap: 0.5,
            history: vec![(3, 1)],
            weights: vec![(3, 4.0)],
            trace: vec![],
        }
    }

    fn charge(ledger: &EpsLedger, request: u64, token: u64) {
        ledger
            .append(LedgerRecord { request, token, planned: 39, released: 10, eps: 0.25 })
            .unwrap();
    }

    #[test]
    fn parses_every_name_shape_and_rejects_foreign_ones() {
        assert_eq!(parse_checkpoint_name("ckpt-7.bin"), Some((7, None)));
        assert_eq!(parse_checkpoint_name("ckpt-7-3.bin"), Some((7, Some(3))));
        assert_eq!(parse_checkpoint_name("ckpt-7.ckpt-tmp"), Some((7, None)));
        assert_eq!(parse_checkpoint_name("ckpt-7-3.ckpt-tmp"), Some((7, Some(3))));
        assert_eq!(
            parse_checkpoint_name("ckpt-184467440737095516.bin"),
            Some((184467440737095516, None))
        );
        for foreign in
            ["eps.wal", "ckpt-.bin", "ckpt-x.bin", "ckpt-7.bin.bak", "quarantine", "ckpt-7-x.bin"]
        {
            assert_eq!(parse_checkpoint_name(foreign), None, "{foreign}");
        }
    }

    #[test]
    fn empty_dir_scans_to_empty_manifest() {
        let dir = tmpdir("empty");
        let m = RecoveryManager::new(&dir, None).scan().unwrap();
        assert!(m.orphans.is_empty());
        assert_eq!(m.quarantined, 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn wal_matched_snapshot_is_resumable_with_spend() {
        let dir = tmpdir("resumable");
        let ledger =
            Arc::new(EpsLedger::open(dir.join("eps.wal"), FsyncPolicy::Always).unwrap());
        charge(&ledger, 5, 42);
        snapshot(42).write_to(dir.join("ckpt-5.bin")).unwrap();

        let m = RecoveryManager::new(&dir, Some(ledger)).scan().unwrap();
        assert_eq!(m.orphans.len(), 1);
        let o = m.find(5).unwrap();
        assert_eq!(o.state, OrphanState::Resumable);
        assert_eq!(o.kind, OrphanKind::Cell);
        assert_eq!(o.spent, Some((10, 0.25)));
        assert_eq!(o.checkpoint.as_ref().unwrap().dataset_fp, 42);
        assert!(o.path.exists(), "resumable snapshot stays in place");
        assert_eq!(m.quarantined, 0);
        assert_eq!(m.resumable().count(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_snapshot_is_quarantined_never_deleted() {
        let dir = tmpdir("corrupt");
        snapshot(42).write_to(dir.join("ckpt-3.bin")).unwrap();
        // flip one payload byte: CRC rejects the decode
        let f = dir.join("ckpt-3.bin");
        let mut bytes = std::fs::read(&f).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&f, &bytes).unwrap();

        let m = RecoveryManager::new(&dir, None).scan().unwrap();
        let o = m.find(3).unwrap();
        assert_eq!(o.state, OrphanState::Corrupt);
        assert!(o.checkpoint.is_none());
        assert!(!f.exists(), "moved out of the scan path");
        assert_eq!(o.path, dir.join("quarantine").join("ckpt-3.bin"));
        assert_eq!(std::fs::read(&o.path).unwrap(), bytes, "preserved bit-for-bit");
        assert_eq!(m.quarantined, 1);
        assert_eq!(m.resumable().count(), 0);

        // idempotent: the survivor-free dir rescans clean
        let m2 = RecoveryManager::new(&dir, None).scan().unwrap();
        assert!(m2.orphans.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn dataset_mismatch_against_wal_is_quarantined() {
        let dir = tmpdir("mismatch");
        let ledger =
            Arc::new(EpsLedger::open(dir.join("eps.wal"), FsyncPolicy::Always).unwrap());
        charge(&ledger, 8, 42);
        snapshot(99).write_to(dir.join("ckpt-8-0.bin")).unwrap();

        let m = RecoveryManager::new(&dir, Some(ledger)).scan().unwrap();
        let o = m.find(8).unwrap();
        assert_eq!(
            o.state,
            OrphanState::DatasetMismatch { wal_token: 42, ckpt_token: 99 }
        );
        assert_eq!(o.kind, OrphanKind::PathPoint { k: 0 });
        assert!(o.checkpoint.is_none());
        assert_eq!(o.spent, Some((10, 0.25)), "the WAL record itself still stands");
        assert_eq!(m.quarantined, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_tmp_quarantined_while_adjacent_bin_still_resumes() {
        let dir = tmpdir("torn-tmp");
        snapshot(42).write_to(dir.join("ckpt-6.bin")).unwrap();
        // a crash between tmp write and rename leaves a torn prefix
        std::fs::write(dir.join("ckpt-6.ckpt-tmp"), b"DPFWCKPT\x01torn").unwrap();

        let m = RecoveryManager::new(&dir, None).scan().unwrap();
        assert_eq!(m.orphans.len(), 2);
        assert_eq!(m.quarantined, 1);
        let states: Vec<OrphanState> = m.orphans.iter().map(|o| o.state).collect();
        assert!(states.contains(&OrphanState::TornTmp));
        assert!(states.contains(&OrphanState::Resumable));
        // find() prefers the resumable record for the shared id
        assert_eq!(m.find(6).unwrap().state, OrphanState::Resumable);
        let slots = m.slots_for(&[6]);
        assert!(slots[0].resume.is_some(), "the intact .bin seeds the resume");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn quarantine_name_collisions_get_numeric_suffixes() {
        let dir = tmpdir("collide");
        let qdir = dir.join("quarantine");
        std::fs::create_dir_all(&qdir).unwrap();
        std::fs::write(qdir.join("ckpt-4.ckpt-tmp"), b"earlier incident").unwrap();
        std::fs::write(dir.join("ckpt-4.ckpt-tmp"), b"new torn tmp").unwrap();

        let m = RecoveryManager::new(&dir, None).scan().unwrap();
        let o = m.find(4).unwrap();
        assert_eq!(o.path, qdir.join("ckpt-4.ckpt-tmp.1"));
        assert_eq!(std::fs::read(&o.path).unwrap(), b"new torn tmp");
        assert_eq!(std::fs::read(qdir.join("ckpt-4.ckpt-tmp")).unwrap(), b"earlier incident");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn slots_for_maps_grid_points_to_their_resumes() {
        let dir = tmpdir("slots");
        // path of 3: point 0 finished+GC'd (no file), point 1 snapshotted,
        // point 2 never started
        snapshot(42).write_to(dir.join("ckpt-11-1.bin")).unwrap();
        let m = RecoveryManager::new(&dir, None).scan().unwrap();
        let slots = m.slots_for(&[10, 11, 12]);
        assert_eq!(slots.len(), 3);
        assert_eq!(slots[0].request_id, 10);
        assert!(slots[0].resume.is_none());
        assert!(slots[1].resume.is_some());
        assert!(slots[2].resume.is_none());
        std::fs::remove_dir_all(&dir).ok();
    }
}
