//! `repro` — the leader binary: train models, generate data, regenerate
//! the paper's tables/figures, and cross-check against the PJRT oracle.
//!
//! ```text
//! repro train      --dataset rcv1 --scale 0.1 --algo alg2 --selector bsls \
//!                  --eps 1 --delta 1e-6 --iters 1000 --lambda 50 [--libsvm f]
//! repro gen-data   --dataset news20 --scale 0.01 --seed 1 --out data.svm
//! repro exp        <datasets|fig1|fig2|fig3|fig4|table3|table4|eps-sweep|all>
//!                  [--scale 1.0] [--iters 1000] [--out exp_out] [--workers N]
//! repro oracle-check [--artifacts artifacts] [--scale 0.05]
//! ```

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use dpfw::cli::Args;
use dpfw::coordinator::{Algo, JobSpec};
use dpfw::dp::accounting::PrivacyParams;
use dpfw::experiments::{figures, tables, ExpConfig};
use dpfw::fw::config::{FwConfig, SelectorKind};
use dpfw::runtime::oracle::DenseOracle;
use dpfw::sparse::synth::{DatasetPreset, SynthConfig};
use dpfw::sparse::{libsvm, Dataset};
use dpfw::testkit::assert_slices_close;

const USAGE: &str = "\
repro — DP LASSO logistic regression via fast Frank-Wolfe (NeurIPS 2023 repro)

COMMANDS
  train         train one model (prints metrics; --help-flags below)
  gen-data      generate a synthetic preset as a LIBSVM file
  exp NAME      regenerate a paper table/figure:
                datasets fig1 fig2 fig3 fig4 table3 table4 eps-sweep
                lambda-path all
  oracle-check  verify the sparse solver against the PJRT dense oracle

COMMON FLAGS
  --dataset P   preset: rcv1 news20 url web kdda        [rcv1]
  --libsvm F    train on a real LIBSVM file instead of a preset
  --scale S     preset scale factor                      [0.05]
  --algo A      alg1 (standard) | alg2 (fast)            [alg2]
  --selector K  argmax fibheap binheap noisymax bsls naive-exp [argmax]
  --eps E --delta D   privacy (selector must be a DP kind)
  --iters T --lambda L --seed N --trace-every K
  --threads N   solver threads for the parallel bootstrap (0 = auto)
  --out PATH    output dir (exp) / file (gen-data)
  --workers N   coordinator threads (exp)
";

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    let result = match args.command() {
        Some("train") => cmd_train(&args),
        Some("gen-data") => cmd_gen_data(&args),
        Some("exp") => cmd_exp(&args),
        Some("oracle-check") => cmd_oracle_check(&args),
        Some("help") | None => {
            println!("{USAGE}");
            Ok(())
        }
        Some(other) => {
            eprintln!("unknown command {other:?}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn load_dataset(args: &Args) -> Result<Arc<Dataset>> {
    if let Some(path) = args.get("libsvm") {
        let mut ds = libsvm::read_file(path)?;
        ds.csr.normalize_inf();
        return Ok(Arc::new(Dataset::new(
            ds.csr.clone(),
            ds.labels.clone(),
            ds.name.clone(),
        )));
    }
    let name = args.get_or("dataset", "rcv1");
    let preset = DatasetPreset::from_name(&name)
        .with_context(|| format!("unknown dataset {name:?}"))?;
    let scale = args.get_f64("scale", 0.05)?;
    let seed = args.get_u64("seed", 42)?;
    Ok(Arc::new(SynthConfig::preset(preset).scale(scale).generate(seed)))
}

fn cmd_train(args: &Args) -> Result<()> {
    let data = load_dataset(args)?;
    let selector = SelectorKind::from_name(&args.get_or("selector", "argmax"))
        .context("bad --selector")?;
    let privacy = match args.get("eps") {
        Some(_) => Some(PrivacyParams::new(
            args.get_f64("eps", 1.0)?,
            args.get_f64("delta", 1e-6)?,
        )),
        None => None,
    };
    let cfg = FwConfig {
        iters: args.get_usize("iters", 1000)?,
        lambda: args.get_f64("lambda", 50.0)?,
        privacy,
        selector,
        seed: args.get_u64("seed", 0)?,
        trace_every: args.get_usize("trace-every", 0)?,
        threads: args.get_usize("threads", 0)?,
        // everything else (lipschitz, direct_max_nnz, shards, cancel, …)
        // keeps its default / process-wide resolution
        ..Default::default()
    };
    let algo = Algo::from_name(&args.get_or("algo", "alg2")).context("bad --algo")?;
    println!(
        "dataset {} N={} D={} nnz={} (S_c={:.1}, S_r={:.2})",
        data.name,
        data.n_rows(),
        data.n_cols(),
        data.nnz(),
        data.avg_row_nnz(),
        data.avg_col_nnz()
    );
    let (train, test) = data.split(args.get_f64("test-frac", 0.2)?);
    let job = JobSpec {
        id: 0,
        label: "train".into(),
        data: Arc::new(train),
        algo,
        cfg,
        test_data: Some(Arc::new(test)),
    };
    let r = job.run();
    println!(
        "{} + {}: {} iters in {:.1} ms ({:.2e} flops)",
        r.algo.name(),
        r.selector,
        r.output.iters_run,
        r.output.wall_ms,
        r.output.flops as f64
    );
    println!(
        "final gap {:.4e}, ||w||_0 = {} ({:.2}% sparse), acc {:.2}%, auc {:.2}%",
        r.output.final_gap,
        r.output.weights.nnz(),
        r.sparsity_pct,
        r.accuracy.unwrap_or(f64::NAN),
        r.auc.unwrap_or(f64::NAN)
    );
    if let Some(path) = args.get("dump-weights") {
        let mut t = dpfw::textio::CsvTable::new(["index", "weight"]);
        for (j, v) in r.output.weights.nonzeros() {
            t.push_row([j.to_string(), format!("{v:.6e}")]);
        }
        t.write_file(path)?;
        println!("wrote nonzero weights to {path}");
    }
    if let Some(out) = args.get("out") {
        let mut reg = dpfw::coordinator::Registry::new();
        reg.add(r);
        reg.write_json(out)?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_gen_data(args: &Args) -> Result<()> {
    let ds = load_dataset(args)?;
    let out = args.get("out").context("gen-data requires --out FILE")?;
    libsvm::write_file(&ds, out)?;
    println!(
        "wrote {} ({} rows, {} cols, {} nnz)",
        out,
        ds.n_rows(),
        ds.n_cols(),
        ds.nnz()
    );
    Ok(())
}

fn cmd_exp(args: &Args) -> Result<()> {
    let which = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .context(
            "exp requires a name: datasets fig1..fig4 table3 table4 eps-sweep lambda-path all",
        )?;
    let cfg = ExpConfig {
        scale: args.get_f64("scale", 1.0)?,
        iters: args.get_usize("iters", 1000)?,
        seed: args.get_u64("seed", 42)?,
        out_dir: args.get_or("out", "exp_out").into(),
        workers: args.get_usize(
            "workers",
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4),
        )?,
    };
    std::fs::create_dir_all(&cfg.out_dir)?;
    let run = |name: &str, cfg: &ExpConfig| -> Result<()> {
        let t = match name {
            "datasets" => tables::datasets_table(cfg)?,
            "fig1" => figures::fig1_convergence(cfg)?,
            "fig2" => figures::fig2_flops_ratio(cfg)?,
            "fig3" => figures::fig3_pops_ratio(cfg)?,
            "fig4" => figures::fig4_gap_vs_flops(cfg)?,
            "table3" => tables::table3_speedup(cfg)?,
            "table4" => tables::table4_utility(cfg)?,
            "eps-sweep" => tables::eps_sweep(cfg)?,
            "lambda-path" => tables::lambda_path(cfg)?,
            other => bail!("unknown experiment {other:?}"),
        };
        println!("== {name} ==");
        println!("{}", t.to_pretty());
        Ok(())
    };
    if which == "all" {
        for name in [
            "datasets",
            "fig1",
            "fig2",
            "fig3",
            "fig4",
            "table3",
            "table4",
            "eps-sweep",
            "lambda-path",
        ] {
            run(name, &cfg)?;
        }
    } else {
        run(which, &cfg)?;
    }
    println!("CSV output in {}", cfg.out_dir.display());
    Ok(())
}

fn cmd_oracle_check(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let mut oracle = DenseOracle::open(&dir)?;
    println!(
        "oracle tile: {}×{} (from {dir}/manifest.txt)",
        oracle.n_tile(),
        oracle.d_tile()
    );
    // RCV1-shaped workload sized to the oracle tile: D = d_tile exactly,
    // N spanning several row tiles (exercises the tiled accumulation).
    let ds = SynthConfig {
        name: "oracle-check".into(),
        n_cols: oracle.d_tile(),
        n_rows: oracle.n_tile() * 5 / 2,
        avg_row_nnz: 40.0,
        zipf_exponent: 1.2,
        n_informative: 40,
        n_dense: 0,
        label_noise: 0.05,
            bias_col: true,
    }
    .generate(args.get_u64("seed", 42)?);
    // Train briefly, then compare the solver's dense-recomputed alpha to
    // the Pallas/XLA oracle's alpha at the trained weights.
    let cfg = FwConfig { iters: 100, lambda: 10.0, ..Default::default() };
    let out = dpfw::fw::fast::FastFrankWolfe::new(&ds, cfg).run();
    let w = out.weights.as_slice();
    let a_oracle = oracle.alpha(&ds, w)?;
    let mut q = vec![0.0f64; ds.n_rows()];
    let mut v = vec![0.0f64; ds.n_rows()];
    ds.csr.matvec(w, &mut v);
    for i in 0..ds.n_rows() {
        q[i] = dpfw::fw::loss::sigmoid(v[i]) - ds.labels[i] as f64;
    }
    let mut a_rust = vec![0.0f64; ds.n_cols()];
    ds.csr.matvec_t_add(&q, &mut a_rust);
    assert_slices_close(&a_rust, &a_oracle, 5e-4, 5e-4);
    let p = oracle.predict(&ds, w)?;
    let acc = dpfw::eval::accuracy(&p, &ds.labels);
    let (loss, gap) = oracle.loss_and_gap(&ds, w, 10.0)?;
    println!(
        "oracle-check OK: alpha agrees (D={}), oracle acc {:.2}%, loss {:.4}, gap {:.4e} \
         (solver's final gap {:.4e})",
        ds.n_cols(),
        acc,
        loss,
        gap,
        out.final_gap
    );
    Ok(())
}
