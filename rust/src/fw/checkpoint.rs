//! Crash-consistent solver checkpoints (DESIGN.md §6.11).
//!
//! After `t` Frank-Wolfe iterations the iterate has at most `t` nonzero
//! coordinates — the sparsity property the paper's LASSO-ball constraint
//! buys — so a snapshot is O(t), not O(D): the selection history, the
//! sparse weights it induces, the RNG stream position, and the telemetry
//! counters. A [`FwCheckpoint`] is written atomically (temp file +
//! `sync_all` + rename) in a dependency-free framed binary format, and
//! [`FwConfig::resume`] feeds one back into either solver such that
//! *checkpoint-at-t-then-resume is bitwise identical to the uninterrupted
//! run* — weights, trace, flops, selector stats, and ε spend — at any
//! (shards, threads) combination.
//!
//! ## How resume restores solver state
//!
//! The fast solver's incremental state (`hat_v`, `q`, `alpha`, `g_base`,
//! heap bounds) is large and substrate-shaped, so the checkpoint does not
//! persist it. Instead resume **replays** iterations `1..=t` against the
//! same dataset: update scans and notify drains run normally (rebuilding
//! axis state and heap/sampler structures exactly), while the recorded
//! selection history supplies each iteration's coordinate for selectors
//! whose `select` either consumes randomness or is pure (DP mechanisms,
//! argmax) — heap selectors re-run `select` live, which is deterministic
//! and keeps their pop/reinsert structure honest. At the replay→live
//! boundary [`FwCheckpoint::restore_into`] overwrites the RNG, the flop
//! counter, the selector telemetry, the gap, and the trace prefix with the
//! recorded values, so the continuation reports the logical uninterrupted
//! trajectory (replay work is deliberately *not* double-counted — it is
//! post-processing of already-released selections and spends zero ε, see
//! `dp/ledger.rs`).
//!
//! The standard solver recomputes its dense state from `w` every
//! iteration, so its resume is direct: restore the sparse weights, seed
//! the selector from the recorded history/stats, and continue at `t + 1`.
//!
//! ## What the fingerprint covers
//!
//! [`config_fingerprint`] hashes exactly the trajectory-defining fields:
//! `iters` (the noise calibration T), `lambda`, the privacy parameters,
//! the selector kind, `seed`, `lipschitz`, and `trace_every`. It
//! deliberately **excludes** `threads`, `shards`, and `direct_max_nnz`
//! (bit-identical performance knobs — resuming on a different topology is
//! the point) and the stop criteria (`iter_cap`, `gap_tol`, `cancel`): a
//! browned-out run's prefix is bit-identical to the uncapped run's, so
//! finishing it later under a different cap is a legitimate — indeed the
//! motivating — use of resume.

use std::fs::{File, OpenOptions};
use std::io::{self, Read};
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::dp::ledger::{crc32, EpsLedger, LedgerRecord};
use crate::fw::config::FwConfig;
use crate::fw::flops::FlopCounter;
use crate::fw::queue::{CoordinateSelector, SelectorStats};
use crate::fw::trace::TraceRecord;
use crate::rng::Xoshiro256pp;
use crate::testkit::io_faults::IoFaultPlane;

/// On-disk magic for a checkpoint frame.
pub const CKPT_MAGIC: [u8; 8] = *b"DPFWCKPT";
/// Format version; bump on any layout change.
pub const CKPT_VERSION: u32 = 1;

/// Decode guard: no length field may claim more than this many elements
/// (a torn/corrupt frame must fail cleanly, not allocate gigabytes).
const MAX_LEN: u32 = 1 << 27;

/// FNV-1a over the trajectory-defining [`FwConfig`] fields (see the
/// module docs for the include/exclude rationale). Stable across runs and
/// processes — it is part of the on-disk format.
pub fn config_fingerprint(cfg: &FwConfig) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
    };
    eat(&(cfg.iters as u64).to_le_bytes());
    eat(&cfg.lambda.to_bits().to_le_bytes());
    match &cfg.privacy {
        Some(p) => {
            eat(&[1]);
            eat(&p.epsilon.to_bits().to_le_bytes());
            eat(&p.delta.to_bits().to_le_bytes());
        }
        None => eat(&[0]),
    }
    eat(cfg.selector.name().as_bytes());
    eat(&cfg.seed.to_le_bytes());
    match cfg.lipschitz {
        Some(l) => {
            eat(&[1]);
            eat(&l.to_bits().to_le_bytes());
        }
        None => eat(&[0]),
    }
    eat(&(cfg.trace_every as u64).to_le_bytes());
    h
}

/// One crash-consistent solver snapshot at an iteration boundary.
#[derive(Clone, Debug, PartialEq)]
pub struct FwCheckpoint {
    /// [`config_fingerprint`] of the run that wrote this snapshot.
    pub fingerprint: u64,
    /// [`crate::sparse::Dataset::fingerprint`] — the *stable content*
    /// identity, not the process-local token: a checkpoint is a durable
    /// artifact, and a restarted process must still be able to prove the
    /// snapshot belongs to the dataset it is resuming against.
    pub dataset_fp: u64,
    /// RNG seed of the run (redundant with the fingerprint; kept explicit
    /// for diagnostics).
    pub seed: u64,
    /// Planned iteration budget T (the noise scale's calibration).
    pub t_planned: u64,
    /// Last completed iteration `t` — `history.len() == iter`.
    pub iter: u64,
    /// Xoshiro256++ state *after* iteration `iter`.
    pub rng: [u64; 4],
    /// [`FlopCounter::to_words`] snapshot after iteration `iter`.
    pub flops: [u64; 7],
    /// Selector telemetry after iteration `iter`.
    pub stats: SelectorStats,
    /// Gap recorded at the last completed iteration.
    pub gap: f64,
    /// Selection history: `(coordinate, step sign)` per iteration, in
    /// order. The sign disambiguates the vertex `s = ∓λ·e_j` so replay can
    /// assert it reproduces the recorded step.
    pub history: Vec<(u32, i8)>,
    /// Sparse iterate: `(coordinate, weight)` for every coordinate the
    /// history ever touched (≤ `iter` entries, sorted by coordinate;
    /// zeros from cancelling steps are kept — the set matters, not just
    /// the support).
    pub weights: Vec<(u32, f64)>,
    /// Trace prefix recorded up to and including iteration `iter`.
    pub trace: Vec<TraceRecord>,
}

impl FwCheckpoint {
    /// Iterations a resuming run must replay (the last completed `t`).
    pub fn replay_to(&self) -> usize {
        self.iter as usize
    }

    /// Panic unless this snapshot belongs to (`cfg`, `dataset_fp`) —
    /// resuming against the wrong config or dataset would silently produce
    /// garbage with a bogus privacy claim, so fail loudly (the
    /// `FwConfig::validate` idiom). `dataset_fp` is the dataset's stable
    /// content fingerprint ([`crate::sparse::Dataset::fingerprint`]).
    pub fn validate_for(&self, cfg: &FwConfig, dataset_fp: u64) {
        assert_eq!(
            self.fingerprint,
            config_fingerprint(cfg),
            "checkpoint fingerprint mismatch: snapshot is from a run with \
             different trajectory-defining config"
        );
        assert_eq!(
            self.dataset_fp, dataset_fp,
            "checkpoint dataset fingerprint mismatch: snapshot is for a \
             different dataset"
        );
        assert_eq!(self.history.len() as u64, self.iter, "corrupt history length");
        assert!(
            (self.iter as usize) < cfg.iters,
            "checkpoint at iteration {} but the plan only has {} iterations",
            self.iter,
            cfg.iters
        );
    }

    /// Overwrite the live solver's carry-state at the replay→live
    /// boundary: RNG stream position, flop counter, selector telemetry,
    /// gap, and the trace prefix. After this call the continuation is
    /// indistinguishable from the uninterrupted run (replayed trace
    /// entries keep their original `wall_ns` — wall clock is the one field
    /// outside the bitwise contract).
    pub fn restore_into(
        &self,
        rng: &mut Xoshiro256pp,
        flops: &mut FlopCounter,
        selector: &mut dyn CoordinateSelector,
        gap: &mut f64,
        trace: &mut Vec<TraceRecord>,
    ) {
        *rng = Xoshiro256pp::from_state(self.rng);
        *flops = FlopCounter::from_words(self.flops);
        selector.restore_stats(self.stats);
        *gap = self.gap;
        trace.clear();
        trace.extend_from_slice(&self.trace);
    }

    /// Collect the sparse iterate from a selection history: the distinct
    /// coordinates ever selected, sorted, each with its current weight
    /// (`value_at(j)` — the caller supplies `w[j]`, or `w_m · ŵ[j]` for
    /// the fast solver's scaled representation).
    pub fn sparse_weights(
        history: &[(u32, i8)],
        value_at: impl Fn(usize) -> f64,
    ) -> Vec<(u32, f64)> {
        let mut coords: Vec<u32> = history.iter().map(|&(j, _)| j).collect();
        coords.sort_unstable();
        coords.dedup();
        coords.into_iter().map(|j| (j, value_at(j as usize))).collect()
    }

    // ---- framed binary encoding (no serde in-tree) ----

    fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(
            128 + self.history.len() * 5
                + self.weights.len() * 12
                + self.trace.len() * 64,
        );
        buf.extend_from_slice(&CKPT_MAGIC);
        buf.extend_from_slice(&CKPT_VERSION.to_le_bytes());
        for v in [self.fingerprint, self.dataset_fp, self.seed, self.t_planned, self.iter] {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        for v in self.rng {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        for v in self.flops {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        for v in [
            self.stats.selects,
            self.stats.pops,
            self.stats.reinserts,
            self.stats.big_steps,
            self.stats.little_steps,
        ] {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        buf.extend_from_slice(&self.gap.to_bits().to_le_bytes());
        buf.extend_from_slice(&(self.history.len() as u32).to_le_bytes());
        for &(j, sign) in &self.history {
            buf.extend_from_slice(&j.to_le_bytes());
            buf.push(if sign >= 0 { 1 } else { 0 });
        }
        buf.extend_from_slice(&(self.weights.len() as u32).to_le_bytes());
        for &(j, w) in &self.weights {
            buf.extend_from_slice(&j.to_le_bytes());
            buf.extend_from_slice(&w.to_bits().to_le_bytes());
        }
        buf.extend_from_slice(&(self.trace.len() as u32).to_le_bytes());
        for r in &self.trace {
            for v in [r.iter as u64, r.gap.to_bits(), r.flops, r.bytes, r.pops, r.selected as u64]
            {
                buf.extend_from_slice(&v.to_le_bytes());
            }
            buf.extend_from_slice(&(r.wall_ns as u64).to_le_bytes());
            buf.extend_from_slice(&((r.wall_ns >> 64) as u64).to_le_bytes());
        }
        let crc = crc32(&buf);
        buf.extend_from_slice(&crc.to_le_bytes());
        buf
    }

    fn decode(bytes: &[u8]) -> io::Result<Self> {
        let bad = |msg: &str| io::Error::new(io::ErrorKind::InvalidData, msg.to_string());
        if bytes.len() < CKPT_MAGIC.len() + 4 + 4 {
            return Err(bad("checkpoint frame too short"));
        }
        let (body, crc_bytes) = bytes.split_at(bytes.len() - 4);
        let crc = u32::from_le_bytes(crc_bytes.try_into().unwrap());
        if crc != crc32(body) {
            return Err(bad("checkpoint CRC mismatch (torn or corrupt frame)"));
        }
        let mut off = 0usize;
        let mut take = |n: usize| -> io::Result<&[u8]> {
            if off + n > body.len() {
                return Err(bad("checkpoint frame truncated"));
            }
            let s = &body[off..off + n];
            off += n;
            Ok(s)
        };
        if take(8)? != CKPT_MAGIC {
            return Err(bad("not a checkpoint file (bad magic)"));
        }
        let version = u32::from_le_bytes(take(4)?.try_into().unwrap());
        if version != CKPT_VERSION {
            return Err(bad("unsupported checkpoint version"));
        }
        macro_rules! read_u64 {
            () => {
                u64::from_le_bytes(take(8)?.try_into().unwrap())
            };
        }
        macro_rules! read_u32 {
            () => {
                u32::from_le_bytes(take(4)?.try_into().unwrap())
            };
        }
        let fingerprint = read_u64!();
        let dataset_fp = read_u64!();
        let seed = read_u64!();
        let t_planned = read_u64!();
        let iter = read_u64!();
        let mut rng = [0u64; 4];
        for r in &mut rng {
            *r = read_u64!();
        }
        let mut flops = [0u64; 7];
        for f in &mut flops {
            *f = read_u64!();
        }
        let stats = SelectorStats {
            selects: read_u64!(),
            pops: read_u64!(),
            reinserts: read_u64!(),
            big_steps: read_u64!(),
            little_steps: read_u64!(),
        };
        let gap = f64::from_bits(read_u64!());
        let n_hist = read_u32!();
        if n_hist > MAX_LEN {
            return Err(bad("implausible history length"));
        }
        let mut history = Vec::with_capacity(n_hist as usize);
        for _ in 0..n_hist {
            let j = read_u32!();
            let sign = if take(1)?[0] != 0 { 1i8 } else { -1i8 };
            history.push((j, sign));
        }
        let n_w = read_u32!();
        if n_w > MAX_LEN {
            return Err(bad("implausible weight count"));
        }
        let mut weights = Vec::with_capacity(n_w as usize);
        for _ in 0..n_w {
            let j = read_u32!();
            let w = f64::from_bits(read_u64!());
            weights.push((j, w));
        }
        let n_tr = read_u32!();
        if n_tr > MAX_LEN {
            return Err(bad("implausible trace length"));
        }
        let mut trace = Vec::with_capacity(n_tr as usize);
        for _ in 0..n_tr {
            let iter_t = read_u64!() as usize;
            let gap_t = f64::from_bits(read_u64!());
            let flops_t = read_u64!();
            let bytes_t = read_u64!();
            let pops_t = read_u64!();
            let selected = read_u64!() as usize;
            let lo = read_u64!() as u128;
            let hi = read_u64!() as u128;
            trace.push(TraceRecord {
                iter: iter_t,
                gap: gap_t,
                flops: flops_t,
                bytes: bytes_t,
                pops: pops_t,
                selected,
                wall_ns: (hi << 64) | lo,
            });
        }
        if off != body.len() {
            return Err(bad("trailing bytes after checkpoint frame"));
        }
        Ok(Self {
            fingerprint,
            dataset_fp,
            seed,
            t_planned,
            iter,
            rng,
            flops,
            stats,
            gap,
            history,
            weights,
            trace,
        })
    }

    /// Atomically persist to `path`: write the frame to a sibling temp
    /// file, `sync_all`, then rename over the target — a crash at any
    /// point leaves either the old snapshot or the new one, never a torn
    /// mix. Best-effort directory sync after the rename.
    pub fn write_to(&self, path: impl AsRef<Path>) -> io::Result<()> {
        self.write_to_with(path, &IoFaultPlane::none())
    }

    /// [`Self::write_to`] with every write/fsync/rename threaded through a
    /// storage-fault plane (DESIGN.md §6.12). On failure the sibling
    /// `.ckpt-tmp` file is deliberately left on disk — that is exactly
    /// what a process dying at that point leaves behind, and the
    /// restart-time recovery scan quarantines it.
    pub fn write_to_with(
        &self,
        path: impl AsRef<Path>,
        io_faults: &IoFaultPlane,
    ) -> io::Result<()> {
        let path = path.as_ref();
        let tmp = path.with_extension("ckpt-tmp");
        {
            let mut f =
                OpenOptions::new().write(true).create(true).truncate(true).open(&tmp)?;
            io_faults.write_all(&mut f, &self.encode())?;
            io_faults.on_fsync()?;
            f.sync_all()?;
        }
        io_faults.before_rename()?;
        std::fs::rename(&tmp, path)?;
        io_faults.after_rename()?;
        if let Some(dir) = path.parent() {
            if let Ok(d) = File::open(dir) {
                let _ = d.sync_all();
            }
        }
        Ok(())
    }

    /// Read and verify a snapshot written by [`FwCheckpoint::write_to`].
    pub fn read_from(path: impl AsRef<Path>) -> io::Result<Self> {
        let mut bytes = Vec::new();
        File::open(path)?.read_to_end(&mut bytes)?;
        Self::decode(&bytes)
    }
}

/// Per-run durability plumbing, armed through
/// [`FwConfig::durability`]: where to checkpoint, at
/// what cadence, and which ε ledger to charge. Shared by reference so the
/// coordinator can hand one to a worker per job.
#[derive(Debug)]
pub struct RunDurability {
    /// Ledger idempotency key for this logical request — replays after a
    /// crash reuse it, which is what makes the ledger's max-merge
    /// exactly-once. When a ledger is charged, the id must come from
    /// [`EpsLedger::allocate_request_id`] so it is unique across process
    /// lifetimes — the ledger file outlives the process, and a reused id
    /// would make a fresh request's charge look like a stale replay.
    pub request_id: u64,
    /// Snapshot target path (one file, atomically replaced each time).
    pub path: PathBuf,
    /// Write-ahead ε ledger to charge at each release point; `None` for
    /// non-private or accounting-free runs.
    pub ledger: Option<Arc<EpsLedger>>,
    /// Checkpoint every `every_k` completed iterations (0 = only at stop
    /// points).
    pub every_k: usize,
    /// Storage-fault injection for this run's checkpoint writes
    /// (disarmed in production; DESIGN.md §6.12).
    pub io: IoFaultPlane,
}

impl RunDurability {
    /// Is `t` a checkpoint boundary?
    #[inline]
    pub fn should_checkpoint(&self, t: usize) -> bool {
        self.every_k > 0 && t % self.every_k == 0
    }

    /// Persist a snapshot. Loud on failure: a durability-armed run that
    /// cannot checkpoint is misconfigured, and silently continuing would
    /// void the resume contract the caller thinks it has.
    pub fn persist(&self, ck: &FwCheckpoint) {
        ck.write_to_with(&self.path, &self.io)
            .unwrap_or_else(|e| panic!("checkpoint write to {:?} failed: {e}", self.path));
    }

    /// Charge `released` selections (cumulative ε `eps`) against the
    /// ledger, write-ahead of the release. `dataset_fp` is the dataset's
    /// stable content fingerprint — the durable spend key. No-op without a
    /// ledger. Loud on I/O failure — releasing without a durable record
    /// would break the write-ahead contract.
    pub fn charge(&self, dataset_fp: u64, planned: usize, released: usize, eps: f64) {
        if let Some(ledger) = &self.ledger {
            ledger
                .append(LedgerRecord {
                    request: self.request_id,
                    token: dataset_fp,
                    planned: planned as u32,
                    released: released as u32,
                    eps,
                })
                .unwrap_or_else(|e| panic!("eps ledger append failed: {e}"));
        }
    }
}

/// Per-grid-point durability plan for one λ-path job (DESIGN.md §6.12),
/// carried by [`FwConfig::path_durability`]. Built by the scheduler when
/// a durability-armed pool admits a `PathJob`: each grid point gets its
/// own [`RunDurability`] — a durable ledger request id of its own and a
/// `ckpt-<req>-<k>.bin` snapshot file — plus an optional per-cell resume
/// snapshot, so a crashed path restarts at its last completed λ instead
/// of from λ₀, and every cell's ε spend is metered exactly once.
#[derive(Clone, Debug, Default)]
pub struct PathDurability {
    /// One durability arm per λ, in `PathJob::lambdas` order.
    pub cells: Vec<Arc<RunDurability>>,
    /// Per-λ resume snapshots (`None` starts that cell fresh); same
    /// length and order as `cells`.
    pub resumes: Vec<Option<Arc<FwCheckpoint>>>,
}

impl PathDurability {
    /// The durability arm for grid point `k`, if the plan covers it.
    pub fn cell(&self, k: usize) -> Option<&Arc<RunDurability>> {
        self.cells.get(k)
    }

    /// The resume snapshot for grid point `k`, if any.
    pub fn resume(&self, k: usize) -> Option<Arc<FwCheckpoint>> {
        self.resumes.get(k).and_then(|r| r.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fw::config::SelectorKind;

    fn sample() -> FwCheckpoint {
        FwCheckpoint {
            fingerprint: 0xDEAD_BEEF_1234_5678,
            dataset_fp: 42,
            seed: 7,
            t_planned: 4000,
            iter: 3,
            rng: [1, 2, 3, 4],
            flops: [10, 20, 30, 40, 50, 60, 70],
            stats: SelectorStats {
                selects: 3,
                pops: 5,
                reinserts: 4,
                big_steps: 0,
                little_steps: 0,
            },
            gap: 0.125,
            history: vec![(17, 1), (3, -1), (17, 1)],
            weights: vec![(3, -0.5), (17, 1.25)],
            trace: vec![TraceRecord {
                iter: 2,
                gap: 0.5,
                flops: 15,
                bytes: 99,
                pops: 2,
                selected: 3,
                wall_ns: (7u128 << 64) | 11,
            }],
        }
    }

    fn tmp(name: &str) -> PathBuf {
        let p = std::env::temp_dir()
            .join(format!("dpfw-ckpt-{}-{}.bin", std::process::id(), name));
        let _ = std::fs::remove_file(&p);
        p
    }

    #[test]
    fn frame_round_trip_is_lossless() {
        let ck = sample();
        let p = tmp("round-trip");
        ck.write_to(&p).unwrap();
        let back = FwCheckpoint::read_from(&p).unwrap();
        assert_eq!(ck, back);
        // the temp file never survives a successful write
        assert!(!p.with_extension("ckpt-tmp").exists());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn corrupt_frame_is_rejected() {
        let ck = sample();
        let p = tmp("corrupt");
        ck.write_to(&p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&p, &bytes).unwrap();
        assert!(FwCheckpoint::read_from(&p).is_err());
        // truncation (a torn write) is also rejected, never mis-decoded
        let ok = ck.encode();
        std::fs::write(&p, &ok[..ok.len() - 9]).unwrap();
        assert!(FwCheckpoint::read_from(&p).is_err());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn fingerprint_tracks_trajectory_fields_only() {
        let base = FwConfig::default();
        let f = config_fingerprint(&base);
        // trajectory-defining fields move the fingerprint
        assert_ne!(f, config_fingerprint(&FwConfig { seed: 1, ..base.clone() }));
        assert_ne!(f, config_fingerprint(&FwConfig { lambda: 51.0, ..base.clone() }));
        assert_ne!(f, config_fingerprint(&FwConfig { iters: 4001, ..base.clone() }));
        assert_ne!(
            f,
            config_fingerprint(&FwConfig {
                selector: SelectorKind::FibHeap,
                ..base.clone()
            })
        );
        // topology and stop criteria do not: resuming a browned-out run
        // under a different cap / shard count is the motivating use case
        assert_eq!(f, config_fingerprint(&FwConfig { threads: 8, ..base.clone() }));
        assert_eq!(f, config_fingerprint(&FwConfig { shards: Some(3), ..base.clone() }));
        assert_eq!(f, config_fingerprint(&FwConfig { iter_cap: Some(5), ..base.clone() }));
        assert_eq!(f, config_fingerprint(&FwConfig { gap_tol: Some(1e-9), ..base }));
    }

    #[test]
    fn validate_for_rejects_mismatches() {
        let cfg = FwConfig::default();
        let mut ck = sample();
        ck.fingerprint = config_fingerprint(&cfg);
        ck.dataset_fp = 42;
        ck.validate_for(&cfg, 42);
        let wrong_ds = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            ck.validate_for(&cfg, 43)
        }));
        assert!(wrong_ds.is_err());
        let other = FwConfig { seed: 99, ..cfg.clone() };
        let wrong_cfg = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            ck.validate_for(&other, 42)
        }));
        assert!(wrong_cfg.is_err());
    }

    #[test]
    fn sparse_weights_dedupes_and_sorts() {
        let hist = vec![(9u32, 1i8), (2, -1), (9, -1), (5, 1)];
        let w = FwCheckpoint::sparse_weights(&hist, |j| j as f64 * 10.0);
        assert_eq!(w, vec![(2, 20.0), (5, 50.0), (9, 90.0)]);
    }

    #[test]
    fn should_checkpoint_cadence() {
        let d = RunDurability {
            request_id: 1,
            path: PathBuf::from("/tmp/x"),
            ledger: None,
            every_k: 4,
            io: IoFaultPlane::none(),
        };
        assert!(!d.should_checkpoint(1));
        assert!(d.should_checkpoint(4));
        assert!(!d.should_checkpoint(5));
        assert!(d.should_checkpoint(8));
        let never = RunDurability { every_k: 0, ..d };
        assert!(!never.should_checkpoint(4));
    }
}
