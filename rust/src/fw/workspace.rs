//! Reusable solver workspaces: run-to-run buffer pooling for the
//! Frank-Wolfe engines.
//!
//! The coordinator's grid sweeps (Table 3/4, ε-sweeps) run the solver
//! hundreds of times over the same dataset. Before this module every run
//! allocated its full state from scratch — five `O(N)`/`O(D)` vectors plus
//! the selector's heap/sampler storage — which at News20 scale is tens of
//! MB of allocator traffic per grid cell. [`FwWorkspace`] keeps those
//! buffers (and the selector, including its heap arena / group-sum arrays)
//! alive between runs:
//!
//! * [`FwWorkspace::take_f64`] / [`FwWorkspace::take_u32`] hand out
//!   cleared, right-sized buffers that reuse retained capacity — after the
//!   first run on a given problem shape, **no solver-state allocation
//!   happens at all** (the returned `FwOutput` still owns its weight
//!   vector, which must escape the run).
//! * [`FwWorkspace::take_selector`] caches the boxed
//!   [`CoordinateSelector`] from the previous run. When the next run asks
//!   for the same `(kind, D, scales)` configuration the cached selector is
//!   [`CoordinateSelector::reset`] — restoring its exactly-fresh logical
//!   state while keeping every internal allocation (Fibonacci-heap arena,
//!   binary-heap storage, BSLS group arrays) — instead of rebuilt.
//!
//! Reuse is **bit-exact**: a `run_in` on a dirty workspace must produce
//! output identical to a fresh `run` (enforced by
//! `tests/prop_equivalence.rs::prop_workspace_reuse_bit_identical`). The
//! pool is therefore purely an allocation cache; nothing about the
//! trajectory may depend on what a buffer previously held.
//!
//! One workspace per worker thread is the intended topology (see
//! `coordinator/scheduler.rs`); the type is deliberately `!Sync` — cheap
//! single-owner mutation, no locking.

use crate::fw::config::SelectorKind;
use crate::fw::queue::{build_selector, CoordinateSelector};

/// A cached selector plus the configuration key it was built for.
struct CachedSelector {
    kind: SelectorKind,
    n_items: usize,
    /// Exponential-mechanism scale the selector was built with. Compared
    /// bitwise: a selector built for a different privacy budget must not
    /// be reused.
    exp_scale: u64,
    /// Noisy-max Laplace scale, compared bitwise like `exp_scale`.
    nm_scale: u64,
    sel: Box<dyn CoordinateSelector>,
}

/// Reusable buffer pool for [`crate::fw::fast::FastFrankWolfe`] and
/// [`crate::fw::standard::StandardFrankWolfe`] runs. See the module docs.
#[derive(Default)]
pub struct FwWorkspace {
    f64_pool: Vec<Vec<f64>>,
    u32_pool: Vec<Vec<u32>>,
    selector: Option<CachedSelector>,
}

impl FwWorkspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// A length-`len` buffer filled with `fill`, reusing pooled capacity
    /// when available.
    pub(crate) fn take_f64(&mut self, len: usize, fill: f64) -> Vec<f64> {
        let mut v = self.f64_pool.pop().unwrap_or_default();
        v.clear();
        v.resize(len, fill);
        v
    }

    /// A length-`len` `u32` buffer filled with `fill` (the stamp array and
    /// the `touched` scratch both live here).
    pub(crate) fn take_u32(&mut self, len: usize, fill: u32) -> Vec<u32> {
        let mut v = self.u32_pool.pop().unwrap_or_default();
        v.clear();
        v.resize(len, fill);
        v
    }

    /// An empty `u32` scratch vector with retained capacity (for the
    /// fused-scan `touched` list, which grows and clears every iteration).
    pub(crate) fn take_u32_scratch(&mut self) -> Vec<u32> {
        let mut v = self.u32_pool.pop().unwrap_or_default();
        v.clear();
        v
    }

    pub(crate) fn recycle_f64(&mut self, v: Vec<f64>) {
        self.f64_pool.push(v);
    }

    pub(crate) fn recycle_u32(&mut self, v: Vec<u32>) {
        self.u32_pool.push(v);
    }

    /// The selector for `(kind, n_items, scales)`: the cached one (reset to
    /// fresh logical state, allocations retained) when the key matches,
    /// otherwise a newly built one.
    pub(crate) fn take_selector(
        &mut self,
        kind: SelectorKind,
        n_items: usize,
        exp_scale: f64,
        nm_scale: f64,
    ) -> Box<dyn CoordinateSelector> {
        if let Some(c) = self.selector.take() {
            if c.kind == kind
                && c.n_items == n_items
                && c.exp_scale == exp_scale.to_bits()
                && c.nm_scale == nm_scale.to_bits()
            {
                let mut sel = c.sel;
                sel.reset();
                return sel;
            }
        }
        build_selector(kind, n_items, exp_scale, nm_scale)
    }

    /// Return a selector to the cache for the next run.
    pub(crate) fn recycle_selector(
        &mut self,
        sel: Box<dyn CoordinateSelector>,
        n_items: usize,
        exp_scale: f64,
        nm_scale: f64,
    ) {
        self.selector = Some(CachedSelector {
            kind: sel.kind(),
            n_items,
            exp_scale: exp_scale.to_bits(),
            nm_scale: nm_scale.to_bits(),
            sel,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffers_are_reused_not_reallocated() {
        let mut ws = FwWorkspace::new();
        let a = ws.take_f64(1000, 0.0);
        let ptr = a.as_ptr();
        ws.recycle_f64(a);
        // same-or-smaller sizes must come back from the pool (same block)
        let b = ws.take_f64(500, 1.0);
        assert_eq!(b.as_ptr(), ptr);
        assert!(b.iter().all(|&x| x == 1.0), "stale contents leaked");
        ws.recycle_f64(b);
        let c = ws.take_f64(1000, 2.0);
        assert_eq!(c.as_ptr(), ptr);
        assert!(c.iter().all(|&x| x == 2.0));
    }

    #[test]
    fn u32_scratch_keeps_capacity_and_clears() {
        let mut ws = FwWorkspace::new();
        let mut t = ws.take_u32_scratch();
        t.extend(0..256u32);
        let cap = t.capacity();
        ws.recycle_u32(t);
        let t2 = ws.take_u32_scratch();
        assert!(t2.is_empty());
        assert!(t2.capacity() >= cap);
    }

    #[test]
    fn selector_cache_hits_on_matching_key_only() {
        let mut ws = FwWorkspace::new();
        let s = ws.take_selector(SelectorKind::FibHeap, 64, 0.0, 0.0);
        let ptr = &*s as *const dyn CoordinateSelector as *const u8;
        ws.recycle_selector(s, 64, 0.0, 0.0);
        // same key: cached instance comes back
        let s2 = ws.take_selector(SelectorKind::FibHeap, 64, 0.0, 0.0);
        assert_eq!(&*s2 as *const dyn CoordinateSelector as *const u8, ptr);
        ws.recycle_selector(s2, 64, 0.0, 0.0);
        // different D: rebuilt
        let s3 = ws.take_selector(SelectorKind::FibHeap, 65, 0.0, 0.0);
        assert_eq!(s3.kind(), SelectorKind::FibHeap);
        // different kind after recycling: rebuilt
        ws.recycle_selector(s3, 65, 0.0, 0.0);
        let s4 = ws.take_selector(SelectorKind::BinHeap, 65, 0.0, 0.0);
        assert_eq!(s4.kind(), SelectorKind::BinHeap);
    }
}
