//! Reusable solver workspaces: run-to-run buffer pooling for the
//! Frank-Wolfe engines.
//!
//! The coordinator's grid sweeps (Table 3/4, ε-sweeps) run the solver
//! hundreds of times over the same dataset. Before this module every run
//! allocated its full state from scratch — five `O(N)`/`O(D)` vectors plus
//! the selector's heap/sampler storage — which at News20 scale is tens of
//! MB of allocator traffic per grid cell. [`FwWorkspace`] keeps those
//! buffers (and the selector, including its heap arena / group-sum arrays)
//! alive between runs:
//!
//! * [`FwWorkspace::take_f64`] / [`FwWorkspace::take_u32`] hand out
//!   cleared, right-sized buffers that reuse retained capacity — after the
//!   first run on a given problem shape, **no solver-state allocation
//!   happens at all** (the returned `FwOutput` still owns its weight
//!   vector, which must escape the run). Selection is best-fit, not LIFO:
//!   the smallest pooled buffer whose capacity already covers the request,
//!   else the largest available, so a small buffer can never shadow a
//!   fitting one and force a realloc.
//! * [`FwWorkspace::take_selector`] caches the boxed
//!   [`CoordinateSelector`] from the previous run. When the next run asks
//!   for the same `(kind, D, scales)` configuration the cached selector is
//!   [`CoordinateSelector::reset`] — restoring its exactly-fresh logical
//!   state while keeping every internal allocation (Fibonacci-heap arena,
//!   binary-heap storage, BSLS group arrays) — instead of rebuilt.
//! * The workspace also owns the **path-engine bootstrap cache**
//!   ([`BootstrapCache`], DESIGN.md §6.5): `run_path` stores the dense
//!   `q̄₀` / `α₀ = Xᵀq̄₀` of the first λ it solves, keyed by a dataset
//!   identity token, and every later λ — and every later path over the
//!   same dataset through the same workspace — copies it back in `O(N+D)`
//!   instead of redoing the `O(N·S_c)` matvec.
//!
//! Reuse is **bit-exact**: a `run_in` on a dirty workspace must produce
//! output identical to a fresh `run` (enforced by
//! `tests/prop_equivalence.rs::prop_workspace_reuse_bit_identical`). The
//! pool is therefore purely an allocation cache; nothing about the
//! trajectory may depend on what a buffer previously held.
//!
//! One workspace per worker thread is the intended topology (see
//! `coordinator/scheduler.rs`); the type is deliberately `!Sync` — cheap
//! single-owner mutation, no locking.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::fw::cancel::CancelToken;
use crate::fw::config::SelectorKind;
use crate::fw::queue::{build_selector, CoordinateSelector};
use crate::sparse::sharded::{GammaEntry, ShardedDataset};
use crate::sparse::Dataset;

/// How a run sources its dense first iteration `α = Xᵀq̄` (DESIGN.md §6.5).
#[derive(Clone, Copy, PartialEq, Eq)]
pub(crate) enum Bootstrap {
    /// Compute it inside the run and leave no trace in the workspace —
    /// `run`/`run_in`'s behaviour, byte-for-byte what it was pre-path.
    PerRun,
    /// Consult the workspace's bootstrap cache: copy it back on a key hit
    /// (recording zero bootstrap FLOPs), compute-and-store on a miss —
    /// `run_path`'s mode.
    Shared,
}

/// Identity key for the cached path-engine bootstrap (DESIGN.md §6.5):
/// the dataset's construction token plus shape guards, and the loss whose
/// gradient-at-zero the cached `q̄₀`/`α₀` were computed from. Any mismatch
/// evicts the (single-slot) cache; a match guarantees bit-identical
/// bootstrap values because `α₀ = Xᵀq̄₀` is itself thread-invariant.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub(crate) struct BootKey {
    token: u64,
    n_rows: usize,
    n_cols: usize,
    nnz: usize,
    loss: &'static str,
}

impl BootKey {
    pub(crate) fn of(data: &Dataset, loss: &'static str) -> Self {
        Self {
            token: data.token(),
            n_rows: data.n_rows(),
            n_cols: data.n_cols(),
            nnz: data.nnz(),
            loss,
        }
    }
}

/// The cached dense bootstrap: the gradient at `w = 0` and `α₀ = Xᵀq̄₀`,
/// owned by the workspace so every λ of a path (and every later path over
/// the same dataset) skips the one `O(N·S_c)` phase of the fast solver.
pub(crate) struct BootstrapCache {
    key: BootKey,
    q0: Vec<f64>,
    alpha0: Vec<f64>,
}

impl BootstrapCache {
    pub(crate) fn q0(&self) -> &[f64] {
        &self.q0
    }

    pub(crate) fn alpha0(&self) -> &[f64] {
        &self.alpha0
    }
}

/// A published bootstrap payload on the [`BootHub`]: the gradient at
/// `w = 0` and `α₀ = Xᵀq̄₀`, shared by `Arc` so followers copy out of one
/// allocation instead of cloning per attach.
struct BootData {
    q0: Vec<f64>,
    alpha0: Vec<f64>,
}

/// One hub slot: claimed-but-unpublished, or ready to attach to.
enum HubSlot {
    /// A leader claimed this key and is computing the bootstrap. Followers
    /// wait on the hub condvar; if the slot *disappears* instead of
    /// turning `Ready`, the leader failed and a waiter must detach and
    /// re-lead (re-running the bootstrap itself, seed-free determinism —
    /// `α₀ = Xᵀq̄₀` depends only on the dataset and loss).
    Pending,
    Ready(Arc<BootData>),
}

/// Hub state behind one mutex: the slot map plus Ready-eviction order.
#[derive(Default)]
struct HubState {
    slots: HashMap<BootKey, HubSlot>,
    /// Insertion order of `Ready` entries, oldest first, for the capacity
    /// cap. `Pending` entries are never tracked here (and never evicted —
    /// a leader must always find its own slot when publishing).
    ready_order: Vec<BootKey>,
}

/// Ready-entry capacity: one entry is O(N + D) f64s, so a resident
/// ingress serving many datasets needs a bound. 32 comfortably covers a
/// bursty working set while capping hub memory.
const HUB_READY_CAP: usize = 32;

/// How long a follower sleeps per wait slice while its leader computes.
/// Each wake re-polls the follower's own cancel token, so a cancelled or
/// deadline-expired follower abandons the wait within one slice.
const HUB_WAIT_SLICE: Duration = Duration::from_millis(5);

/// What [`BootHub::attach_or_lead`] resolved to.
enum HubAttach {
    /// The bootstrap for this key is published: copy and go.
    Ready(Arc<BootData>),
    /// The caller claimed leadership: compute the bootstrap and publish
    /// via `FwWorkspace::bootstrap_put` (or abort the lease on failure).
    Lead,
    /// The caller's cancel token fired while waiting on a pending leader:
    /// compute locally without publishing (the run's own stop poll will
    /// end it almost immediately anyway).
    GiveUp,
}

/// Ingress-scoped bootstrap coalescing hub (DESIGN.md §6.10): the
/// cross-worker extension of the per-workspace [`BootstrapCache`].
/// Concurrent jobs whose [`BootKey`] matches fold into **one** dense
/// bootstrap `α = Xᵀq̄`: the first arrival claims the key (leader), every
/// other arrival either waits for the published payload (follower) or
/// copies it instantly if already published. Attach is bit-identical to
/// computing independently — the bootstrap is deterministic and
/// thread-invariant — and purely a FLOP/byte saving: each follower still
/// runs its own iterations, spends its own ε, and reports
/// `bootstrap_flops = 0` exactly like a warm path cell.
///
/// Failure protocol: a leader that dies mid-bootstrap has its pending
/// slot removed (by the worker's failure path or the workspace `Drop`
/// guard); woken followers find the key absent, **detach** (counted), and
/// the first of them re-leads. Followers never inherit a leader's
/// failure.
#[derive(Default)]
pub struct BootHub {
    state: Mutex<HubState>,
    cv: Condvar,
    leads: AtomicU64,
    attaches: AtomicU64,
    detaches: AtomicU64,
}

impl BootHub {
    pub fn new() -> Self {
        Self::default()
    }

    /// Bootstraps computed through the hub (one per distinct cold key,
    /// plus one per leader failure).
    pub fn leads(&self) -> u64 {
        self.leads.load(Ordering::Relaxed)
    }

    /// Bootstraps *skipped* by copying a published payload — the
    /// coalescing win.
    pub fn attaches(&self) -> u64 {
        self.attaches.load(Ordering::Relaxed)
    }

    /// Followers that woke to a vanished leader and re-led or re-waited.
    pub fn detaches(&self) -> u64 {
        self.detaches.load(Ordering::Relaxed)
    }

    /// Published entries currently resident.
    pub fn ready_len(&self) -> usize {
        self.state.lock().unwrap_or_else(|e| e.into_inner()).ready_order.len()
    }

    fn attach_or_lead(&self, key: BootKey, cancel: &CancelToken) -> HubAttach {
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        let mut waited = false;
        loop {
            match st.slots.get(&key) {
                Some(HubSlot::Ready(d)) => {
                    self.attaches.fetch_add(1, Ordering::Relaxed);
                    return HubAttach::Ready(Arc::clone(d));
                }
                Some(HubSlot::Pending) => {
                    if cancel.check().is_some() {
                        if waited {
                            self.detaches.fetch_add(1, Ordering::Relaxed);
                        }
                        return HubAttach::GiveUp;
                    }
                    waited = true;
                    let (guard, _timeout) = self
                        .cv
                        .wait_timeout(st, HUB_WAIT_SLICE)
                        .unwrap_or_else(|e| e.into_inner());
                    st = guard;
                }
                None => {
                    if waited {
                        // our leader vanished without publishing: detach
                        // and become the new leader ourselves
                        self.detaches.fetch_add(1, Ordering::Relaxed);
                    }
                    st.slots.insert(key, HubSlot::Pending);
                    self.leads.fetch_add(1, Ordering::Relaxed);
                    return HubAttach::Lead;
                }
            }
        }
    }

    /// Publish a computed bootstrap under `key` and wake every waiting
    /// follower. Called by the leader via `FwWorkspace::bootstrap_put`.
    fn publish(&self, key: BootKey, q0: &[f64], alpha0: &[f64]) {
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        st.slots.insert(
            key,
            HubSlot::Ready(Arc::new(BootData {
                q0: q0.to_vec(),
                alpha0: alpha0.to_vec(),
            })),
        );
        st.ready_order.retain(|k| k != &key);
        st.ready_order.push(key);
        while st.ready_order.len() > HUB_READY_CAP {
            let old = st.ready_order.remove(0);
            if matches!(st.slots.get(&old), Some(HubSlot::Ready(_))) {
                st.slots.remove(&old);
            }
        }
        drop(st);
        self.cv.notify_all();
    }

    /// Release a claimed-but-unpublished lease (leader failed before
    /// publishing). Waiting followers wake, find the key absent, and one
    /// of them re-leads. Removing only a `Pending` slot makes this safe to
    /// call defensively — a published entry is never torn down.
    fn abort(&self, key: BootKey) {
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        if matches!(st.slots.get(&key), Some(HubSlot::Pending)) {
            st.slots.remove(&key);
        }
        drop(st);
        self.cv.notify_all();
    }
}

/// Pop the pooled vector that serves a length-`len` request best: the
/// smallest capacity that already fits (no realloc), else the largest
/// available (one realloc now, and the pool converges on a buffer big
/// enough for the workload's largest shape instead of thrashing). A plain
/// LIFO pop could return a small buffer while a fitting one sits idle —
/// every mixed-shape sweep then reallocates once per run, forever.
/// `len = usize::MAX` is the "scratch" request: nothing fits, so it yields
/// the largest-capacity buffer.
fn take_best<T>(pool: &mut Vec<Vec<T>>, len: usize) -> Vec<T> {
    let mut best: Option<(usize, usize, bool)> = None; // (index, capacity, fits)
    for (i, v) in pool.iter().enumerate() {
        let cap = v.capacity();
        let fits = cap >= len;
        let better = match best {
            None => true,
            Some((_, bcap, bfits)) => {
                if fits != bfits {
                    fits
                } else if fits {
                    cap < bcap // best fit: smallest adequate capacity
                } else {
                    cap > bcap // nothing fits yet: keep the largest
                }
            }
        };
        if better {
            best = Some((i, cap, fits));
        }
    }
    match best {
        Some((i, _, _)) => pool.swap_remove(i),
        None => Vec::new(),
    }
}

/// A cached selector plus the configuration key it was built for.
struct CachedSelector {
    kind: SelectorKind,
    n_items: usize,
    /// Exponential-mechanism scale the selector was built with. Compared
    /// bitwise: a selector built for a different privacy budget must not
    /// be reused.
    exp_scale: u64,
    /// Noisy-max Laplace scale, compared bitwise like `exp_scale`.
    nm_scale: u64,
    sel: Box<dyn CoordinateSelector>,
}

/// Reusable buffer pool for [`crate::fw::fast::FastFrankWolfe`] and
/// [`crate::fw::standard::StandardFrankWolfe`] runs. See the module docs.
#[derive(Default)]
pub struct FwWorkspace {
    f64_pool: Vec<Vec<f64>>,
    u32_pool: Vec<Vec<u32>>,
    selector: Option<CachedSelector>,
    boot: Option<BootstrapCache>,
    /// Single-slot cache of the row-sharded substrate (DESIGN.md §6.8):
    /// building it is `O(nnz)`, so `run_path` and repeated sharded runs
    /// over the same dataset must not rebuild it per run. Keyed by the
    /// parent token plus the *requested* shard count
    /// ([`ShardedDataset::matches`]). Take/put move semantics — not a
    /// borrowing getter — because the solver holds it across `&mut self`
    /// pool calls.
    sharded: Option<ShardedDataset>,
    /// Pooled per-shard Phase A scratch (deferred γ entries + decode
    /// buffers), recycled like the scalar pools.
    shard_scratch: Vec<ShardScratch>,
    /// The ingress-scoped coalescing hub (DESIGN.md §6.10), installed by
    /// the scheduler when the pool runs behind an ingress. `None` (the
    /// default) keeps every behaviour byte-identical to the pre-hub
    /// workspace.
    hub: Option<Arc<BootHub>>,
    /// The hub key this workspace currently leads (claimed in
    /// [`FwWorkspace::bootstrap_attach`], released by `bootstrap_put` on
    /// success or [`FwWorkspace::boot_lease_abort`] / `Drop` on failure).
    lease: Option<BootKey>,
}

/// Per-shard scratch for the fast solver's sharded Phase A: the deferred
/// [`GammaEntry`] list the shard emits (replayed sequentially in Phase B)
/// and a `u32` decode buffer for the shard's compact column segments.
/// Pooled in the workspace so steady-state sharded iterations allocate
/// nothing.
#[derive(Default)]
pub(crate) struct ShardScratch {
    pub(crate) gammas: Vec<GammaEntry>,
    pub(crate) decode: Vec<u32>,
}

impl FwWorkspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// A length-`len` buffer filled with `fill`, reusing the best-fit
    /// pooled capacity when available (see [`take_best`]).
    pub(crate) fn take_f64(&mut self, len: usize, fill: f64) -> Vec<f64> {
        let mut v = take_best(&mut self.f64_pool, len);
        v.clear();
        v.resize(len, fill);
        v
    }

    /// A length-`len` `u32` buffer filled with `fill` (the stamp array,
    /// the `touched` scratch, and the compact-substrate decode buffers
    /// all live here).
    pub(crate) fn take_u32(&mut self, len: usize, fill: u32) -> Vec<u32> {
        let mut v = take_best(&mut self.u32_pool, len);
        v.clear();
        v.resize(len, fill);
        v
    }

    /// An empty `u32` scratch vector with retained capacity (the
    /// fused-scan `touched` list and the compact-substrate column/row
    /// decode buffers, all of which grow and clear every iteration).
    /// Picks the *largest* pooled buffer — scratch has no target length,
    /// so retained capacity is the whole point.
    pub(crate) fn take_u32_scratch(&mut self) -> Vec<u32> {
        let mut v = take_best(&mut self.u32_pool, usize::MAX);
        v.clear();
        v
    }

    /// Install the ingress-scoped coalescing hub. The scheduler calls this
    /// once per worker workspace when the pool runs behind an ingress;
    /// shared-bootstrap runs then consult the hub after the local cache.
    pub fn set_boot_hub(&mut self, hub: Arc<BootHub>) {
        self.hub = Some(hub);
    }

    /// Is a coalescing hub installed? (The scheduler uses this to decide
    /// whether single-cell jobs run in shared-bootstrap mode.)
    pub fn has_boot_hub(&self) -> bool {
        self.hub.is_some()
    }

    /// The cached bootstrap for `key`, if the workspace holds one.
    pub(crate) fn bootstrap_get(&self, key: &BootKey) -> Option<&BootstrapCache> {
        self.boot.as_ref().filter(|b| b.key == *key)
    }

    /// Shared-mode bootstrap resolution (DESIGN.md §6.5 / §6.10): fill
    /// `q`/`alpha` from the local single-slot cache, else from the
    /// coalescing hub when one is installed. Returns `true` when the
    /// buffers were filled (the caller skips the bootstrap compute and
    /// records zero bootstrap FLOPs). Returns `false` when the caller must
    /// compute — either because nothing cached (without a hub), because it
    /// just claimed hub **leadership** for `key` (its `bootstrap_put` will
    /// publish and wake followers), or because its cancel token fired
    /// while waiting on a pending leader (compute locally, no lease, no
    /// publish — the run's own stop poll ends it right after).
    pub(crate) fn bootstrap_attach(
        &mut self,
        key: &BootKey,
        q: &mut [f64],
        alpha: &mut [f64],
        cancel: &CancelToken,
    ) -> bool {
        // A leftover lease means a previous run aborted between attach and
        // put without its failure hooks running; release it so followers
        // of that key never wait on a ghost leader.
        if self.lease.is_some() {
            self.boot_lease_abort();
        }
        if let Some(b) = self.boot.as_ref().filter(|b| b.key == *key) {
            q.copy_from_slice(&b.q0);
            alpha.copy_from_slice(&b.alpha0);
            return true;
        }
        let Some(hub) = self.hub.clone() else { return false };
        match hub.attach_or_lead(*key, cancel) {
            HubAttach::Ready(d) => {
                q.copy_from_slice(&d.q0);
                alpha.copy_from_slice(&d.alpha0);
                // warm the local slot too: later runs on this worker skip
                // even the hub lock
                self.bootstrap_put(*key, &d.q0, &d.alpha0);
                true
            }
            HubAttach::Lead => {
                self.lease = Some(*key);
                false
            }
            HubAttach::GiveUp => false,
        }
    }

    /// Release a held hub leadership lease without publishing (the
    /// bootstrap failed). Called from the worker's job-failure path and
    /// the workspace `Drop` guard; no-op without a lease.
    pub(crate) fn boot_lease_abort(&mut self) {
        if let Some(key) = self.lease.take() {
            if let Some(hub) = &self.hub {
                hub.abort(key);
            }
        }
    }

    /// Store (or overwrite — the cache is single-slot, matching the
    /// one-dataset-per-path access pattern) the bootstrap for `key`,
    /// reusing the previous cache's allocations. When this workspace holds
    /// the hub leadership lease for `key` (see
    /// [`FwWorkspace::bootstrap_attach`]), the payload is also published
    /// to the hub, waking every waiting follower.
    pub(crate) fn bootstrap_put(&mut self, key: BootKey, q0: &[f64], alpha0: &[f64]) {
        let b = self.boot.get_or_insert_with(|| BootstrapCache {
            key,
            q0: Vec::new(),
            alpha0: Vec::new(),
        });
        b.key = key;
        b.q0.clear();
        b.q0.extend_from_slice(q0);
        b.alpha0.clear();
        b.alpha0.extend_from_slice(alpha0);
        if self.lease == Some(key) {
            self.lease = None;
            if let Some(hub) = &self.hub {
                hub.publish(key, q0, alpha0);
            }
        }
    }

    pub(crate) fn recycle_f64(&mut self, v: Vec<f64>) {
        self.f64_pool.push(v);
    }

    pub(crate) fn recycle_u32(&mut self, v: Vec<u32>) {
        self.u32_pool.push(v);
    }

    /// The selector for `(kind, n_items, scales)`: the cached one (reset to
    /// fresh logical state, allocations retained) when the key matches,
    /// otherwise a newly built one.
    pub(crate) fn take_selector(
        &mut self,
        kind: SelectorKind,
        n_items: usize,
        exp_scale: f64,
        nm_scale: f64,
    ) -> Box<dyn CoordinateSelector> {
        if let Some(c) = self.selector.take() {
            if c.kind == kind
                && c.n_items == n_items
                && c.exp_scale == exp_scale.to_bits()
                && c.nm_scale == nm_scale.to_bits()
            {
                let mut sel = c.sel;
                sel.reset();
                return sel;
            }
        }
        build_selector(kind, n_items, exp_scale, nm_scale)
    }

    /// The cached sharded substrate for `(data, requested)`, moved out of
    /// the workspace (single-slot; a key mismatch drops the stale one).
    /// `None` means the caller must [`ShardedDataset::build`] — and should
    /// hand the result back via [`FwWorkspace::put_sharded`] when done.
    pub(crate) fn take_sharded(
        &mut self,
        data: &Dataset,
        requested: usize,
    ) -> Option<ShardedDataset> {
        self.sharded.take().filter(|s| s.matches(data, requested))
    }

    /// Return (or install) the sharded substrate for the next run.
    pub(crate) fn put_sharded(&mut self, sharded: ShardedDataset) {
        self.sharded = Some(sharded);
    }

    /// `n_shards` pooled Phase A scratch slots, cleared but with retained
    /// capacity. Surplus pooled slots stay put; missing ones are fresh.
    pub(crate) fn take_shard_scratch(&mut self, n_shards: usize) -> Vec<ShardScratch> {
        let take = self.shard_scratch.len().min(n_shards);
        let mut out: Vec<ShardScratch> = self.shard_scratch.drain(..take).collect();
        for s in &mut out {
            s.gammas.clear();
            s.decode.clear();
        }
        out.resize_with(n_shards, ShardScratch::default);
        out
    }

    /// Return Phase A scratch slots to the pool.
    pub(crate) fn recycle_shard_scratch(&mut self, scratch: Vec<ShardScratch>) {
        self.shard_scratch.extend(scratch);
    }

    /// Scribble garbage over every pooled buffer and drop the caches —
    /// the fault-injection plane's `PoisonWorkspace` hook (DESIGN.md
    /// §6.9, `testkit::faults`). The bit-exact reuse contract says a
    /// dirty workspace is indistinguishable from a fresh one *because
    /// every taken buffer is fully reinitialized*; this makes "dirty" as
    /// hostile as possible (NaNs and saturated stamps rather than
    /// whatever the last run left), so the fault matrix catches any
    /// solver path that starts trusting pooled contents. Caches that are
    /// semantically meaningful across runs (bootstrap, selector, sharded
    /// substrate) are *dropped* rather than corrupted — poisoning them
    /// would violate their documented validity contract instead of
    /// testing it.
    pub fn poison_buffers(&mut self) {
        for v in &mut self.f64_pool {
            let cap = v.capacity();
            v.clear();
            v.resize(cap, f64::NAN);
        }
        for v in &mut self.u32_pool {
            let cap = v.capacity();
            v.clear();
            v.resize(cap, u32::MAX);
        }
        for s in &mut self.shard_scratch {
            s.gammas.clear();
            let cap = s.decode.capacity();
            s.decode.clear();
            s.decode.resize(cap, u32::MAX);
        }
        self.selector = None;
        self.boot = None;
        self.sharded = None;
        // defensive: poisoning between jobs must never leave a ghost
        // leader behind (the hub installation itself survives)
        self.boot_lease_abort();
    }

    /// Return a selector to the cache for the next run.
    pub(crate) fn recycle_selector(
        &mut self,
        sel: Box<dyn CoordinateSelector>,
        n_items: usize,
        exp_scale: f64,
        nm_scale: f64,
    ) {
        self.selector = Some(CachedSelector {
            kind: sel.kind(),
            n_items,
            exp_scale: exp_scale.to_bits(),
            nm_scale: nm_scale.to_bits(),
            sel,
        });
    }
}

impl Drop for FwWorkspace {
    /// Backstop for abrupt worker death (`DieAbruptly`, thread teardown):
    /// whatever kills a leader mid-bootstrap, its pending hub slot must
    /// not outlive the workspace, or followers would wait on a ghost.
    fn drop(&mut self) {
        self.boot_lease_abort();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffers_are_reused_not_reallocated() {
        let mut ws = FwWorkspace::new();
        let a = ws.take_f64(1000, 0.0);
        let ptr = a.as_ptr();
        ws.recycle_f64(a);
        // same-or-smaller sizes must come back from the pool (same block)
        let b = ws.take_f64(500, 1.0);
        assert_eq!(b.as_ptr(), ptr);
        assert!(b.iter().all(|&x| x == 1.0), "stale contents leaked");
        ws.recycle_f64(b);
        let c = ws.take_f64(1000, 2.0);
        assert_eq!(c.as_ptr(), ptr);
        assert!(c.iter().all(|&x| x == 2.0));
    }

    #[test]
    fn best_fit_beats_lifo_pool_order() {
        let mut ws = FwWorkspace::new();
        let big = ws.take_f64(1000, 0.0);
        let big_ptr = big.as_ptr();
        let small = ws.take_f64(10, 0.0);
        let small_ptr = small.as_ptr();
        ws.recycle_f64(big);
        ws.recycle_f64(small); // LIFO top is now the small buffer
        // a D-sized request must get the big buffer even though the small
        // one was recycled last (LIFO would realloc here)
        let d = ws.take_f64(1000, 0.0);
        assert_eq!(d.as_ptr(), big_ptr, "large request must find the large buffer");
        // and the small request gets the small buffer (best fit, not max)
        let s = ws.take_f64(10, 0.0);
        assert_eq!(s.as_ptr(), small_ptr, "small request must not consume a big buffer");
        ws.recycle_f64(d);
        ws.recycle_f64(s);
        // scratch request takes the *largest* capacity
        let mut wu = FwWorkspace::new();
        let a = wu.take_u32(512, 0);
        let a_ptr = a.as_ptr();
        let b = wu.take_u32(8, 0); // allocated while `a` is out
        wu.recycle_u32(a);
        wu.recycle_u32(b); // small buffer on the LIFO top
        let scratch = wu.take_u32_scratch();
        assert_eq!(scratch.as_ptr(), a_ptr, "scratch wants retained capacity");
    }

    #[test]
    fn bootstrap_cache_hits_on_key_match_only() {
        use crate::sparse::synth::SynthConfig;
        let ds = SynthConfig {
            name: "boot".into(),
            n_rows: 20,
            n_cols: 10,
            avg_row_nnz: 3.0,
            zipf_exponent: 1.2,
            n_informative: 4,
            n_dense: 0,
            label_noise: 0.0,
            bias_col: true,
        }
        .generate(1);
        let other = ds.clone(); // same token: clones alias the data
        let mut ws = FwWorkspace::new();
        let key = BootKey::of(&ds, "logistic");
        assert!(ws.bootstrap_get(&key).is_none());
        let q0 = vec![0.5; ds.n_rows()];
        let a0 = vec![1.0; ds.n_cols()];
        ws.bootstrap_put(key, &q0, &a0);
        assert_eq!(ws.bootstrap_get(&key).unwrap().q0(), &q0[..]);
        assert_eq!(ws.bootstrap_get(&BootKey::of(&other, "logistic")).unwrap().alpha0(), &a0[..]);
        // different loss: miss
        assert!(ws.bootstrap_get(&BootKey::of(&ds, "squared")).is_none());
        // different dataset (fresh token): miss, and put evicts
        let ds2 = ds.split(0.5).0;
        let key2 = BootKey::of(&ds2, "logistic");
        assert!(ws.bootstrap_get(&key2).is_none());
        ws.bootstrap_put(key2, &q0[..ds2.n_rows()], &a0);
        assert!(ws.bootstrap_get(&key).is_none(), "single-slot cache must evict");
        assert!(ws.bootstrap_get(&key2).is_some());
    }

    #[test]
    fn u32_scratch_keeps_capacity_and_clears() {
        let mut ws = FwWorkspace::new();
        let mut t = ws.take_u32_scratch();
        t.extend(0..256u32);
        let cap = t.capacity();
        ws.recycle_u32(t);
        let t2 = ws.take_u32_scratch();
        assert!(t2.is_empty());
        assert!(t2.capacity() >= cap);
    }

    #[test]
    fn sharded_cache_and_scratch_pool_round_trip() {
        use crate::sparse::synth::SynthConfig;
        let ds = SynthConfig {
            name: "shard-ws".into(),
            n_rows: 60,
            n_cols: 40,
            avg_row_nnz: 4.0,
            zipf_exponent: 1.2,
            n_informative: 8,
            n_dense: 0,
            label_noise: 0.0,
            bias_col: true,
        }
        .generate(2);
        let mut ws = FwWorkspace::new();
        assert!(ws.take_sharded(&ds, 3).is_none(), "cold workspace must miss");
        ws.put_sharded(ShardedDataset::build(&ds, 3));
        let sh = ws.take_sharded(&ds, 3).expect("same key must hit");
        assert!(ws.take_sharded(&ds, 3).is_none(), "take moves the slot out");
        ws.put_sharded(sh);
        assert!(ws.take_sharded(&ds, 4).is_none(), "different P must miss (and drop)");
        // scratch pool: capacity is retained, contents are cleared
        let mut sc = ws.take_shard_scratch(2);
        sc[0].gammas.push(GammaEntry { row: 7, gamma: 1.0, v_new: 0.5 });
        sc[1].decode.extend(0..64u32);
        let cap = sc[1].decode.capacity();
        ws.recycle_shard_scratch(sc);
        let sc2 = ws.take_shard_scratch(3);
        assert_eq!(sc2.len(), 3);
        assert!(sc2[0].gammas.is_empty() && sc2[1].decode.is_empty());
        assert!(sc2.iter().map(|s| s.decode.capacity()).max().unwrap() >= cap);
    }

    #[test]
    fn poison_fills_pools_and_drops_caches() {
        let mut ws = FwWorkspace::new();
        let v = ws.take_f64(64, 1.0);
        ws.recycle_f64(v);
        let u = ws.take_u32(64, 1);
        ws.recycle_u32(u);
        ws.bootstrap_put(
            BootKey { token: 1, n_rows: 2, n_cols: 2, nnz: 2, loss: "logistic" },
            &[0.0, 0.0],
            &[0.0, 0.0],
        );
        ws.poison_buffers();
        assert!(ws
            .bootstrap_get(&BootKey {
                token: 1,
                n_rows: 2,
                n_cols: 2,
                nnz: 2,
                loss: "logistic"
            })
            .is_none());
        // the pooled block survives (same allocation) but a fresh take
        // fully reinitializes it — the reuse contract the poison targets
        let v2 = ws.take_f64(64, 0.5);
        assert!(v2.iter().all(|&x| x == 0.5));
        let u2 = ws.take_u32(64, 0);
        assert!(u2.iter().all(|&x| x == 0));
    }

    fn hub_key(token: u64) -> BootKey {
        BootKey { token, n_rows: 3, n_cols: 2, nnz: 4, loss: "logistic" }
    }

    #[test]
    fn boot_hub_leader_publishes_and_followers_attach() {
        let hub = Arc::new(BootHub::new());
        let key = hub_key(9);
        let cancel = CancelToken::none();
        let mut leader = FwWorkspace::new();
        leader.set_boot_hub(Arc::clone(&hub));
        assert!(leader.has_boot_hub());
        let (mut q, mut a) = (vec![0.0; 3], vec![0.0; 2]);
        assert!(
            !leader.bootstrap_attach(&key, &mut q, &mut a, &cancel),
            "cold hub: the first arrival must lead"
        );
        assert_eq!(hub.leads(), 1);
        leader.bootstrap_put(key, &[1.0, 2.0, 3.0], &[4.0, 5.0]);
        assert_eq!(hub.ready_len(), 1);
        // a different workspace (another worker) attaches without computing
        let mut follower = FwWorkspace::new();
        follower.set_boot_hub(Arc::clone(&hub));
        let (mut q2, mut a2) = (vec![0.0; 3], vec![0.0; 2]);
        assert!(follower.bootstrap_attach(&key, &mut q2, &mut a2, &cancel));
        assert_eq!(q2, vec![1.0, 2.0, 3.0]);
        assert_eq!(a2, vec![4.0, 5.0]);
        assert_eq!(hub.attaches(), 1);
        // the attach warmed the follower's local slot: round two skips the hub
        assert!(follower.bootstrap_attach(&key, &mut q2, &mut a2, &cancel));
        assert_eq!(hub.attaches(), 1, "local cache hit must not touch the hub");
        // a hub-less workspace is byte-identical to the pre-hub behaviour
        let mut plain = FwWorkspace::new();
        assert!(!plain.bootstrap_attach(&key, &mut q, &mut a, &cancel));
    }

    #[test]
    fn boot_hub_aborted_lease_lets_next_arrival_re_lead() {
        let hub = Arc::new(BootHub::new());
        let key = hub_key(11);
        let cancel = CancelToken::none();
        let (mut q, mut a) = (vec![0.0; 3], vec![0.0; 2]);
        let mut leader = FwWorkspace::new();
        leader.set_boot_hub(Arc::clone(&hub));
        assert!(!leader.bootstrap_attach(&key, &mut q, &mut a, &cancel));
        // leader dies without publishing: the Drop guard aborts the lease
        drop(leader);
        let mut next = FwWorkspace::new();
        next.set_boot_hub(Arc::clone(&hub));
        assert!(
            !next.bootstrap_attach(&key, &mut q, &mut a, &cancel),
            "slot must be vacant again: the next arrival re-leads"
        );
        assert_eq!(hub.leads(), 2);
        // a cancelled follower gives up instead of waiting on the leader
        let expired = CancelToken::with_deadline(std::time::Instant::now());
        let mut hurried = FwWorkspace::new();
        hurried.set_boot_hub(Arc::clone(&hub));
        assert!(!hurried.bootstrap_attach(&key, &mut q, &mut a, &expired));
        assert_eq!(hub.leads(), 2, "a give-up must not claim leadership");
        // the give-up holds no lease, so publishing from it stays local
        hurried.bootstrap_put(key, &[9.0; 3], &[9.0; 2]);
        assert_eq!(hub.ready_len(), 0);
    }

    #[test]
    fn boot_hub_coalesces_across_threads_to_one_bootstrap() {
        use std::sync::Barrier;
        let hub = Arc::new(BootHub::new());
        let key = hub_key(13);
        let barrier = Arc::new(Barrier::new(4));
        let computes = Arc::new(AtomicU64::new(0));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let hub = Arc::clone(&hub);
            let barrier = Arc::clone(&barrier);
            let computes = Arc::clone(&computes);
            handles.push(std::thread::spawn(move || {
                let mut ws = FwWorkspace::new();
                ws.set_boot_hub(hub);
                let cancel = CancelToken::none();
                let (mut q, mut a) = (vec![0.0; 3], vec![0.0; 2]);
                barrier.wait();
                if !ws.bootstrap_attach(&key, &mut q, &mut a, &cancel) {
                    // leader: "compute" slowly so followers really wait
                    computes.fetch_add(1, Ordering::Relaxed);
                    std::thread::sleep(Duration::from_millis(20));
                    q.copy_from_slice(&[1.0, 2.0, 3.0]);
                    a.copy_from_slice(&[4.0, 5.0]);
                    ws.bootstrap_put(key, &q, &a);
                }
                (q, a)
            }));
        }
        for h in handles {
            let (q, a) = h.join().expect("hub worker panicked");
            assert_eq!(q, vec![1.0, 2.0, 3.0]);
            assert_eq!(a, vec![4.0, 5.0]);
        }
        assert_eq!(computes.load(Ordering::Relaxed), 1, "exactly one bootstrap");
        assert_eq!(hub.leads(), 1);
        assert_eq!(hub.attaches(), 3);
    }

    #[test]
    fn boot_hub_caps_ready_entries() {
        let hub = BootHub::new();
        for t in 0..(HUB_READY_CAP as u64 + 3) {
            hub.publish(hub_key(t), &[t as f64], &[t as f64]);
        }
        assert_eq!(hub.ready_len(), HUB_READY_CAP);
        // oldest entries were evicted; the newest survives
        let cancel = CancelToken::none();
        let (mut q, mut a) = (vec![0.0; 1], vec![0.0; 1]);
        let mut ws = FwWorkspace::new();
        ws.set_boot_hub(Arc::new(hub));
        assert!(!ws.bootstrap_attach(&hub_key(0), &mut q, &mut a, &cancel));
    }

    #[test]
    fn selector_cache_hits_on_matching_key_only() {
        let mut ws = FwWorkspace::new();
        let s = ws.take_selector(SelectorKind::FibHeap, 64, 0.0, 0.0);
        let ptr = &*s as *const dyn CoordinateSelector as *const u8;
        ws.recycle_selector(s, 64, 0.0, 0.0);
        // same key: cached instance comes back
        let s2 = ws.take_selector(SelectorKind::FibHeap, 64, 0.0, 0.0);
        assert_eq!(&*s2 as *const dyn CoordinateSelector as *const u8, ptr);
        ws.recycle_selector(s2, 64, 0.0, 0.0);
        // different D: rebuilt
        let s3 = ws.take_selector(SelectorKind::FibHeap, 65, 0.0, 0.0);
        assert_eq!(s3.kind(), SelectorKind::FibHeap);
        // different kind after recycling: rebuilt
        ws.recycle_selector(s3, 65, 0.0, 0.0);
        let s4 = ws.take_selector(SelectorKind::BinHeap, 65, 0.0, 0.0);
        assert_eq!(s4.kind(), SelectorKind::BinHeap);
    }
}
