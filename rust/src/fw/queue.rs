//! Coordinate selection — the abstract priority structure `Q` of the
//! paper's Algorithm 2 (lines 6, 13, 15, 29), with one implementation per
//! Table 3 configuration:
//!
//! * [`ArgmaxSelector`] — non-private `O(D)` dense argmax (Alg 1).
//! * [`HeapSelector`] — Algorithm 3's queue maintenance over either the
//!   Fibonacci heap or the indexed binary heap: priorities are **stale
//!   upper bounds** on `|α_j|` (keys only ever *decrease* in the negated
//!   min-heap, i.e. magnitudes only ratchet *up*), and `getNext` pops
//!   until the best true gradient beats the top stale priority.
//! * [`ExpMechSelector`] — the DP exponential mechanism over `|α_j|`
//!   scores, backed by either the BSLS sampler (Algorithm 4) or the naive
//!   `O(D)` Gumbel-max reference.
//! * [`NoisyMaxSelector`] — DP report-noisy-max (Alg 1's DP selection and
//!   Table 3's "Alg. 2 only" ablation).

use crate::fw::config::SelectorKind;
use crate::fw::flops::FlopCounter;
use crate::heap::binary::IndexedBinaryHeap;
use crate::heap::fibonacci::FibonacciHeap;
use crate::heap::DecreaseKeyHeap;
use crate::rng::Xoshiro256pp;
use crate::sampler::bsls::BslsSampler;
use crate::sampler::naive::NaiveExpSampler;
use crate::sampler::noisy_max;
use crate::sampler::WeightedSampler;

/// Telemetry every selector reports (Fig 3 needs `pops`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SelectorStats {
    /// `getNext` invocations.
    pub selects: u64,
    /// Heap pops across all selects (heap selectors only).
    pub pops: u64,
    /// Items re-inserted after pops (heap selectors only).
    pub reinserts: u64,
    /// Sampler big/little steps (BSLS only).
    pub big_steps: u64,
    pub little_steps: u64,
}

/// The abstract queue `Q`. `alpha` is always the solver's *current* dense
/// gradient vector; selectors that keep internal state (heaps, samplers)
/// learn about sparse changes through `notify`.
pub trait CoordinateSelector {
    /// Bulk-load after the first dense gradient computation (Alg 2 l.13).
    fn init(&mut self, alpha: &[f64], flops: &mut FlopCounter);
    /// Pick the coordinate to update this iteration (Alg 2 l.15).
    fn select(&mut self, alpha: &[f64], rng: &mut Xoshiro256pp, flops: &mut FlopCounter)
        -> usize;
    /// `α_k` changed to `alpha_k` (Alg 2 l.29). Idempotent per value.
    fn notify(&mut self, k: usize, alpha_k: f64, flops: &mut FlopCounter);
    /// Restore the exactly-fresh state of a newly built selector over the
    /// same item universe, retaining internal allocations (heap arenas,
    /// sampler group arrays). A reset selector followed by `init` must be
    /// bit-identically equivalent to a freshly constructed one — the
    /// workspace selector cache ([`crate::fw::workspace::FwWorkspace`])
    /// depends on this.
    fn reset(&mut self);
    fn stats(&self) -> SelectorStats;
    /// Overwrite the telemetry counters with a checkpoint snapshot
    /// (`fw::checkpoint`, DESIGN.md §6.11). A resumed run replays
    /// iterations without charging selection telemetry for skipped
    /// mechanism draws; restoring the recorded stats at the replay
    /// boundary makes the resumed run's reported counters identical to
    /// the uninterrupted run's. Telemetry only — never touches queue or
    /// sampler state.
    fn restore_stats(&mut self, stats: SelectorStats);
    fn kind(&self) -> SelectorKind;
    /// Can the solver compute this selector's choice externally (e.g. the
    /// shard-parallel tree-reduced argmax, DESIGN.md §6.8) and hand it in
    /// via [`CoordinateSelector::commit_precomputed`]? Only selectors
    /// whose `select` is a pure, stateless function of `alpha` — no RNG
    /// draws, no internal queue mutation — may answer `true`; anything
    /// else (DP mechanisms consume noise, heaps pop entries) must stay on
    /// the `select` path so the mechanism and its RNG stream remain
    /// global and sequential.
    fn supports_precomputed(&self) -> bool {
        false
    }
    /// Record an externally computed choice `j` exactly as `select`
    /// would have: same stats increments, same flop charges. The solver
    /// only calls this when [`CoordinateSelector::supports_precomputed`]
    /// is `true` and `j` is bit-identical to what `select` would return.
    fn commit_precomputed(&mut self, j: usize, n_items: usize, flops: &mut FlopCounter) {
        let _ = (j, n_items, flops);
        unreachable!("selector does not support precomputed selection");
    }
}

// ------------------------------------------------------------------------
// Non-private dense argmax (Algorithm 1's selection)
// ------------------------------------------------------------------------

#[derive(Debug, Default)]
pub struct ArgmaxSelector {
    stats: SelectorStats,
}

impl CoordinateSelector for ArgmaxSelector {
    fn init(&mut self, _alpha: &[f64], _flops: &mut FlopCounter) {}

    fn select(
        &mut self,
        alpha: &[f64],
        _rng: &mut Xoshiro256pp,
        flops: &mut FlopCounter,
    ) -> usize {
        self.stats.selects += 1;
        flops.add(2 * alpha.len() as u64); // abs + compare per item
        noisy_max::arg_abs_max(alpha)
    }

    fn notify(&mut self, _k: usize, _alpha_k: f64, _flops: &mut FlopCounter) {}

    fn reset(&mut self) {
        self.stats = SelectorStats::default();
    }

    fn stats(&self) -> SelectorStats {
        self.stats
    }

    fn restore_stats(&mut self, stats: SelectorStats) {
        self.stats = stats;
    }

    fn kind(&self) -> SelectorKind {
        SelectorKind::Argmax
    }

    // The dense argmax is a pure function of `alpha` with no RNG draws,
    // so the sharded solver may compute it via the tree reduction and
    // commit the result here — mirroring `select`'s accounting exactly.
    fn supports_precomputed(&self) -> bool {
        true
    }

    fn commit_precomputed(&mut self, _j: usize, n_items: usize, flops: &mut FlopCounter) {
        self.stats.selects += 1;
        flops.add(2 * n_items as u64); // abs + compare per item
    }
}

// ------------------------------------------------------------------------
// Algorithm 3: heap queue maintenance with stale upper bounds
// ------------------------------------------------------------------------

/// Generic over the heap so the Fibonacci / binary ablation shares the
/// exact queue-maintenance logic.
#[derive(Debug)]
pub struct HeapSelector<H: DecreaseKeyHeap> {
    heap: H,
    kind: SelectorKind,
    stats: SelectorStats,
    /// scratch: items popped during one `select`
    popped: Vec<usize>,
}

pub type FibHeapSelector = HeapSelector<FibonacciHeap>;
pub type BinHeapSelector = HeapSelector<IndexedBinaryHeap>;

impl FibHeapSelector {
    pub fn fibonacci(n_items: usize) -> Self {
        Self {
            heap: FibonacciHeap::with_capacity(n_items),
            kind: SelectorKind::FibHeap,
            stats: SelectorStats::default(),
            popped: Vec::new(),
        }
    }
}

impl BinHeapSelector {
    pub fn binary(n_items: usize) -> Self {
        Self {
            heap: IndexedBinaryHeap::with_capacity(n_items),
            kind: SelectorKind::BinHeap,
            stats: SelectorStats::default(),
            popped: Vec::new(),
        }
    }
}

impl<H: DecreaseKeyHeap> CoordinateSelector for HeapSelector<H> {
    fn init(&mut self, alpha: &[f64], _flops: &mut FlopCounter) {
        for (j, &a) in alpha.iter().enumerate() {
            // min-heap keyed on negated magnitude
            self.heap.push(j, -a.abs());
        }
    }

    fn select(
        &mut self,
        alpha: &[f64],
        _rng: &mut Xoshiro256pp,
        flops: &mut FlopCounter,
    ) -> usize {
        self.stats.selects += 1;
        self.popped.clear();
        // Alg 3 GETNEXT: pop until the best true |α| beats the staleness
        // bound at the top of the queue.
        let mut best: Option<usize> = None;
        let mut best_mag = f64::NEG_INFINITY;
        loop {
            let (c, _stale_key) = self
                .heap
                .pop_min()
                .expect("queue exhausted — D items cannot all be popped");
            self.stats.pops += 1;
            flops.add(2);
            self.popped.push(c);
            let mag = alpha[c].abs();
            if mag > best_mag {
                best_mag = mag;
                best = Some(c);
            }
            // stop when no stale upper bound can beat the current best
            match self.heap.peek_key() {
                Some(top_key) if -top_key > best_mag => continue,
                _ => break,
            }
        }
        // Re-insert popped items with their *true* current magnitudes
        // (restores exact priorities for everything we touched).
        for &c in &self.popped {
            self.heap.push(c, -alpha[c].abs());
            self.stats.reinserts += 1;
        }
        best.expect("at least one pop")
    }

    fn notify(&mut self, k: usize, alpha_k: f64, flops: &mut FlopCounter) {
        // decrease-key only when the magnitude *increased*: the stored
        // priority stays an upper bound on |α_k| (Alg 3 UPDATE).
        flops.add(2);
        self.heap.decrease_key(k, -alpha_k.abs());
    }

    fn reset(&mut self) {
        self.heap.clear();
        self.popped.clear();
        self.stats = SelectorStats::default();
    }

    fn stats(&self) -> SelectorStats {
        self.stats
    }

    fn restore_stats(&mut self, stats: SelectorStats) {
        self.stats = stats;
    }

    fn kind(&self) -> SelectorKind {
        self.kind
    }
}

// ------------------------------------------------------------------------
// DP: exponential mechanism (Algorithm 4 / naive reference)
// ------------------------------------------------------------------------

/// Exponential mechanism over scores `u_j = |α_j|`, log-weights
/// `scale · |α_j|` with `scale = ε′ / (2L)` (see `dp::accounting`).
pub struct ExpMechSelector<S: WeightedSampler> {
    sampler: S,
    scale: f64,
    kind: SelectorKind,
    stats: SelectorStats,
}

pub type BslsSelector = ExpMechSelector<BslsSampler>;
pub type NaiveExpSelector = ExpMechSelector<NaiveExpSampler>;

impl BslsSelector {
    pub fn bsls(n_items: usize, scale: f64) -> Self {
        Self {
            sampler: BslsSampler::new(n_items, 0.0),
            scale,
            kind: SelectorKind::Bsls,
            stats: SelectorStats::default(),
        }
    }
}

impl NaiveExpSelector {
    pub fn naive(n_items: usize, scale: f64) -> Self {
        Self {
            sampler: NaiveExpSampler::new(n_items, 0.0),
            scale,
            kind: SelectorKind::NaiveExp,
            stats: SelectorStats::default(),
        }
    }
}

impl<S: WeightedSampler> CoordinateSelector for ExpMechSelector<S> {
    fn init(&mut self, alpha: &[f64], flops: &mut FlopCounter) {
        flops.add(alpha.len() as u64 * 2);
        for (j, &a) in alpha.iter().enumerate() {
            self.sampler.update(j, a.abs() * self.scale);
        }
    }

    fn select(
        &mut self,
        _alpha: &[f64],
        rng: &mut Xoshiro256pp,
        flops: &mut FlopCounter,
    ) -> usize {
        self.stats.selects += 1;
        let j = self.sampler.sample(rng);
        // FLOP cost of the draw: for BSLS ≈ one exp per visited group/item;
        // for the naive sampler one Gumbel per item. Approximate via the
        // samplers' own telemetry where available.
        flops.add(self.draw_cost());
        j
    }

    fn notify(&mut self, k: usize, alpha_k: f64, flops: &mut FlopCounter) {
        flops.add(6); // two lse_replace updates (≈ exp + ln each)
        self.sampler.update(k, alpha_k.abs() * self.scale);
    }

    fn reset(&mut self) {
        self.sampler.reset();
        self.stats = SelectorStats::default();
    }

    fn stats(&self) -> SelectorStats {
        let mut s = self.stats;
        s.big_steps = self.big_steps();
        s.little_steps = self.little_steps();
        s
    }

    fn restore_stats(&mut self, stats: SelectorStats) {
        self.stats = stats;
    }

    fn kind(&self) -> SelectorKind {
        self.kind
    }
}

impl<S: WeightedSampler> ExpMechSelector<S> {
    fn draw_cost(&self) -> u64 {
        // amortized per-draw FLOPs; precise telemetry exists only for BSLS
        (self.sampler.len() as f64).sqrt() as u64 * 4
    }

    fn big_steps(&self) -> u64 {
        0
    }

    fn little_steps(&self) -> u64 {
        0
    }
}

impl BslsSelector {
    /// BSLS-specific telemetry passthrough.
    pub fn sampler_stats(&self) -> crate::sampler::bsls::BslsStats {
        self.sampler.stats
    }
}

// ------------------------------------------------------------------------
// DP: report-noisy-max (Alg 1 DP / Table 3 ablation)
// ------------------------------------------------------------------------

pub struct NoisyMaxSelector {
    /// Laplace scale `b = L / ε′` on unnormalized |α| scores.
    noise_scale: f64,
    stats: SelectorStats,
}

impl NoisyMaxSelector {
    pub fn new(noise_scale: f64) -> Self {
        assert!(noise_scale >= 0.0);
        Self { noise_scale, stats: SelectorStats::default() }
    }
}

impl CoordinateSelector for NoisyMaxSelector {
    fn init(&mut self, _alpha: &[f64], _flops: &mut FlopCounter) {}

    fn select(
        &mut self,
        alpha: &[f64],
        rng: &mut Xoshiro256pp,
        flops: &mut FlopCounter,
    ) -> usize {
        self.stats.selects += 1;
        // |α| + Laplace + compare per item; Laplace ≈ ln + arithmetic
        flops.add(alpha.len() as u64 * (2 + crate::fw::flops::FLOPS_LN + 2));
        noisy_max::noisy_max(alpha, self.noise_scale, rng).0
    }

    fn notify(&mut self, _k: usize, _alpha_k: f64, _flops: &mut FlopCounter) {}

    fn reset(&mut self) {
        self.stats = SelectorStats::default();
    }

    fn stats(&self) -> SelectorStats {
        self.stats
    }

    fn restore_stats(&mut self, stats: SelectorStats) {
        self.stats = stats;
    }

    fn kind(&self) -> SelectorKind {
        SelectorKind::NoisyMax
    }
}

// ------------------------------------------------------------------------
// Factory
// ------------------------------------------------------------------------

/// Build the selector for a config. `n_items = D`; `eps_step`/`lipschitz`
/// used by the DP kinds only.
pub fn build_selector(
    kind: SelectorKind,
    n_items: usize,
    exp_mech_scale: f64,
    noisy_max_scale: f64,
) -> Box<dyn CoordinateSelector> {
    match kind {
        SelectorKind::Argmax => Box::new(ArgmaxSelector::default()),
        SelectorKind::FibHeap => Box::new(FibHeapSelector::fibonacci(n_items)),
        SelectorKind::BinHeap => Box::new(BinHeapSelector::binary(n_items)),
        SelectorKind::NoisyMax => Box::new(NoisyMaxSelector::new(noisy_max_scale)),
        SelectorKind::Bsls => Box::new(BslsSelector::bsls(n_items, exp_mech_scale)),
        SelectorKind::NaiveExp => Box::new(NaiveExpSelector::naive(n_items, exp_mech_scale)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fc() -> FlopCounter {
        FlopCounter::new()
    }

    #[test]
    fn argmax_selects_largest_magnitude() {
        let mut s = ArgmaxSelector::default();
        let mut rng = Xoshiro256pp::seeded(1);
        let alpha = [0.5, -2.0, 1.0];
        assert_eq!(s.select(&alpha, &mut rng, &mut fc()), 1);
    }

    #[test]
    fn heap_selector_matches_argmax_exactly() {
        // With exact priorities the Alg 3 queue must return the argmax.
        let mut rng = Xoshiro256pp::seeded(2);
        let mut alpha = vec![0.0f64; 50];
        for (j, a) in alpha.iter_mut().enumerate() {
            *a = ((j * 31 % 17) as f64) - 8.0;
        }
        for mk in 0..2 {
            let mut s: Box<dyn CoordinateSelector> = if mk == 0 {
                Box::new(FibHeapSelector::fibonacci(50))
            } else {
                Box::new(BinHeapSelector::binary(50))
            };
            s.init(&alpha, &mut fc());
            let j = s.select(&alpha, &mut rng, &mut fc());
            assert_eq!(j, noisy_max::arg_abs_max(&alpha));
        }
    }

    #[test]
    fn heap_selector_with_stale_priorities() {
        // Decrease some α values *without* notifying (magnitude decreases
        // are deliberately not propagated — priorities become stale upper
        // bounds) and check the selector still returns the true argmax.
        let mut rng = Xoshiro256pp::seeded(3);
        let mut alpha = vec![1.0f64; 20];
        alpha[7] = 10.0;
        alpha[3] = 9.0;
        let mut s = FibHeapSelector::fibonacci(20);
        s.init(&alpha, &mut fc());
        // α_7 collapses; stale priority still says 10
        alpha[7] = 0.1;
        let j = s.select(&alpha, &mut rng, &mut fc());
        assert_eq!(j, 3);
        assert!(s.stats().pops >= 2, "must have popped the stale item");
        // next select: priorities were refreshed on re-insert
        alpha[5] = 20.0;
        s.notify(5, alpha[5], &mut fc());
        let j2 = s.select(&alpha, &mut rng, &mut fc());
        assert_eq!(j2, 5);
    }

    #[test]
    fn heap_notify_increase_then_select() {
        let mut rng = Xoshiro256pp::seeded(4);
        let alpha0 = vec![1.0f64; 10];
        let mut s = BinHeapSelector::binary(10);
        s.init(&alpha0, &mut fc());
        let mut alpha = alpha0.clone();
        alpha[6] = 5.0;
        s.notify(6, 5.0, &mut fc());
        assert_eq!(s.select(&alpha, &mut rng, &mut fc()), 6);
    }

    #[test]
    fn bsls_selector_prefers_big_gradients() {
        let mut rng = Xoshiro256pp::seeded(5);
        let mut alpha = vec![0.0f64; 100];
        alpha[42] = 1000.0;
        let mut s = BslsSelector::bsls(100, 1.0);
        s.init(&alpha, &mut fc());
        for _ in 0..50 {
            assert_eq!(s.select(&alpha, &mut rng, &mut fc()), 42);
        }
    }

    #[test]
    fn bsls_scale_zero_is_uniform() {
        // ε′→0 ⇒ scale→0 ⇒ all weights equal ⇒ uniform choice
        let mut rng = Xoshiro256pp::seeded(6);
        let mut alpha = vec![0.0f64; 16];
        alpha[3] = 100.0;
        let mut s = BslsSelector::bsls(16, 0.0);
        s.init(&alpha, &mut fc());
        let mut hits = 0;
        for _ in 0..3200 {
            hits += (s.select(&alpha, &mut rng, &mut fc()) == 3) as usize;
        }
        // expect ~200; a peaked sampler would give ~3200
        assert!(hits < 400, "hits={hits}");
    }

    #[test]
    fn noisy_max_zero_noise_is_argmax() {
        let mut rng = Xoshiro256pp::seeded(7);
        let alpha = [1.0, -4.0, 2.0];
        let mut s = NoisyMaxSelector::new(0.0);
        assert_eq!(s.select(&alpha, &mut rng, &mut fc()), 1);
    }

    #[test]
    fn factory_builds_all_kinds() {
        for kind in [
            SelectorKind::Argmax,
            SelectorKind::FibHeap,
            SelectorKind::BinHeap,
            SelectorKind::NoisyMax,
            SelectorKind::Bsls,
            SelectorKind::NaiveExp,
        ] {
            let s = build_selector(kind, 8, 0.1, 0.1);
            assert_eq!(s.kind(), kind);
        }
    }
}
