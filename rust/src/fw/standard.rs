//! Algorithm 1: the **standard sparse-aware Frank-Wolfe** baseline
//! (COPT-style). Sparse matvecs for `v̄ = Xw` and `z̄ = Xᵀq̄`, but every
//! iteration still does dense `O(D)` work for the gradient vector, the
//! selection, the direction, the gap, and the weight update — the
//! `O(T·N·S_c + T·D)` total the paper sets out to beat.
//!
//! The DP variant (Talwar et al.'s original DP-FW) replaces the argmax
//! with report-noisy-max at the per-step budget `ε′` from advanced
//! composition. Both variants are driven by the same selector abstraction
//! as Algorithm 2, so Table 3's four configurations are exactly
//! {StandardFrankWolfe, FastFrankWolfe} × {NoisyMax, BSLS}-appropriate
//! selectors.

use std::time::Instant;

use crate::fw::cancel::StopReason;
use crate::fw::checkpoint::{config_fingerprint, FwCheckpoint};
use crate::fw::config::{FwConfig, SelectorKind};
use crate::fw::flops::{
    FlopCounter, ShardCosts, BYTES_F32_READ, BYTES_F64_READ, BYTES_F64_RMW,
    FLOPS_SIGMOID,
};
use crate::fw::loss::{Logistic, Loss};
use crate::fw::queue::SelectorStats;
use crate::fw::sign;
use crate::fw::trace::{FwOutput, TraceRecord, WeightVector};
use crate::fw::workspace::{BootKey, Bootstrap, FwWorkspace};
use crate::rng::Xoshiro256pp;
use crate::sparse::sharded::{par_abs_argmax, ShardedDataset, SELECT_PAR_MIN_D};
use crate::sparse::Dataset;

pub struct StandardFrankWolfe<'a> {
    data: &'a Dataset,
    loss: Box<dyn Loss>,
    cfg: FwConfig,
}

impl<'a> StandardFrankWolfe<'a> {
    pub fn new(data: &'a Dataset, cfg: FwConfig) -> Self {
        cfg.validate();
        assert!(
            !matches!(cfg.selector, SelectorKind::FibHeap | SelectorKind::BinHeap),
            "heap selectors require Algorithm 2's sparse notifications; \
             use FastFrankWolfe"
        );
        Self { data, loss: Box::new(Logistic), cfg }
    }

    pub fn with_loss(mut self, loss: Box<dyn Loss>) -> Self {
        self.loss = loss;
        self
    }

    /// One-shot run with a private workspace; sweep drivers should prefer
    /// [`StandardFrankWolfe::run_in`].
    pub fn run(&self) -> FwOutput {
        self.run_in(&mut FwWorkspace::new())
    }

    /// Run inside a caller-supplied workspace (see
    /// [`crate::fw::workspace`]): the four dense state vectors and the
    /// selector are pooled across runs. Bit-exactly equivalent to `run`.
    pub fn run_in(&self, ws: &mut FwWorkspace) -> FwOutput {
        self.run_core(ws, self.cfg.lambda, Bootstrap::PerRun)
    }

    /// Like [`Self::run_in`], but with the dense bootstrap in `Shared`
    /// mode: eligible for the workspace cache and, when the workspace is
    /// connected to an ingress [`crate::fw::workspace::BootHub`], for
    /// cross-worker coalescing (DESIGN.md §6.10). Output is bit-identical
    /// to `run_in` except that a cache/hub hit moves the bootstrap cost
    /// out of `flops`/`bootstrap_flops` (the §6.5 invariant).
    pub(crate) fn run_in_shared(&self, ws: &mut FwWorkspace) -> FwOutput {
        self.run_core(ws, self.cfg.lambda, Bootstrap::Shared)
    }

    /// Train a regularization path — one run per λ in `lambdas` (the
    /// config's own `lambda` is ignored) — sharing the t = 1 dense
    /// recompute across the grid: at `w = 0` it is exactly the bootstrap
    /// `v̄ = 0, q̄ = ∇L(0, y), α = Xᵀq̄`, identical for every λ, so warm
    /// solves copy it from the workspace cache instead of redoing the two
    /// `O(nnz)` matvecs. Outputs are bit-identical to independent
    /// [`StandardFrankWolfe::run_in`] calls except that `flops` omits
    /// exactly the skipped bootstrap work (see
    /// [`FwOutput::bootstrap_flops`]).
    pub fn run_path(&self, lambdas: &[f64], ws: &mut FwWorkspace) -> Vec<FwOutput> {
        lambdas
            .iter()
            .map(|&lam| {
                assert!(lam > 0.0, "path lambda must be positive");
                self.run_core(ws, lam, Bootstrap::Shared)
            })
            .collect()
    }

    /// Package the current solver state as a crash-consistent snapshot
    /// (DESIGN.md §6.11). Algorithm 1 carries no incremental state beyond
    /// `w`, so unlike the fast solver its resume restores the sparse
    /// iterate directly instead of replaying.
    #[allow(clippy::too_many_arguments)]
    fn snapshot(
        &self,
        t: usize,
        w: &[f64],
        gap: f64,
        rng: &Xoshiro256pp,
        flops: &FlopCounter,
        stats: SelectorStats,
        history: &[(u32, i8)],
        trace: &[TraceRecord],
    ) -> FwCheckpoint {
        FwCheckpoint {
            fingerprint: config_fingerprint(&self.cfg),
            dataset_fp: self.data.fingerprint(),
            seed: self.cfg.seed,
            t_planned: self.cfg.iters as u64,
            iter: t as u64,
            rng: rng.state(),
            flops: flops.to_words(),
            stats,
            gap,
            history: history.to_vec(),
            weights: FwCheckpoint::sparse_weights(history, |j| w[j]),
            trace: trace.to_vec(),
        }
    }

    fn run_core(&self, ws: &mut FwWorkspace, lam: f64, boot: Bootstrap) -> FwOutput {
        // Sharded engine in a separate body (same structure as the fast
        // solver, DESIGN.md §6.8): the legacy path below is untouched for
        // `shards: None`.
        if let Some(requested) = self.cfg.effective_shards() {
            return self.run_core_sharded(ws, lam, boot, requested);
        }
        let start = Instant::now();
        let csr = &self.data.csr;
        let y = &self.data.labels;
        let n = csr.n_rows();
        let d = csr.n_cols();
        let t_total = self.cfg.iters;
        let lip = self.cfg.lipschitz.unwrap_or_else(|| self.loss.lipschitz());
        let boot_key = BootKey::of(self.data, self.loss.name());

        let (exp_scale, nm_scale) = match self.cfg.privacy {
            Some(p) => (p.exp_mech_scale(t_total, lip), p.noisy_max_scale(t_total, lip)),
            None => (0.0, 0.0),
        };
        let mut selector = ws.take_selector(self.cfg.selector, d, exp_scale, nm_scale);
        let mut rng = Xoshiro256pp::seeded(self.cfg.seed);
        let mut flops = FlopCounter::new();
        // segment-adaptive dispatcher (§6.7), plus the analytic
        // direct/scratch split of one full row sweep under it — the
        // per-iteration dense recompute runs two such sweeps, and this
        // precomputed triple is exactly what the dispatched kernels
        // execute (full-sweep convention, like the byte model below)
        let kern = self.cfg.scan_kernel();
        let (seg_direct, seg_scratch, seg_scratch_nnz) = csr.scan_split(kern);

        let mut w = ws.take_f64(d, 0.0);
        let mut v = ws.take_f64(n, 0.0);
        let mut q = ws.take_f64(n, 0.0);
        let mut alpha = ws.take_f64(d, 0.0);
        // pooled decode scratch for the compact substrate: keeps the
        // per-iteration matvec passes allocation-free (workspace contract)
        let mut scratch = ws.take_u32_scratch();
        let mut trace = Vec::new();
        let mut gap = f64::NAN;
        let mut initialized = false;

        // §6.11 durability/resume plumbing. Alg 1 recomputes its dense
        // state from `w` every iteration, so resume restores the sparse
        // iterate directly and continues at `replay_to + 1` — no replay.
        // The one cross-iteration structure is the selector: its `init`
        // saw the t = 1 alpha (the w = 0 bootstrap — the exponential-
        // mechanism kinds freeze their sampler on it), so resume rebuilds
        // exactly that alpha first.
        let resume = self.cfg.resume.as_deref();
        if let Some(ck) = resume {
            ck.validate_for(&self.cfg, self.data.fingerprint());
        }
        let replay_to = resume.map_or(0, |ck| ck.replay_to());
        let durability = self.cfg.durability.as_deref();
        let mut history: Vec<(u32, i8)> =
            resume.map(|ck| ck.history.clone()).unwrap_or_default();
        if let Some(ck) = resume {
            let cached = boot == Bootstrap::Shared
                && ws.bootstrap_attach(&boot_key, &mut q, &mut alpha, &self.cfg.cancel);
            if !cached {
                // w is still all-zero here: this is the t = 1 recompute
                csr.matvec_scan(&w, &mut v, &mut scratch, kern);
                for i in 0..n {
                    q[i] = self.loss.grad(v[i], y[i] as f64);
                }
                alpha.iter_mut().for_each(|a| *a = 0.0);
                csr.matvec_t_add_scan(&q, &mut alpha, &mut scratch, kern);
                if boot == Bootstrap::Shared {
                    ws.bootstrap_put(boot_key, &q, &alpha);
                }
            }
            selector.init(&alpha, &mut flops);
            initialized = true;
            for &(jj, wv) in &ck.weights {
                w[jj as usize] = wv;
            }
            // boundary restore: the rebuild work above is discarded from
            // the counters — the resumed run reports the logical
            // uninterrupted trajectory (see fw/checkpoint.rs)
            ck.restore_into(&mut rng, &mut flops, &mut *selector, &mut gap, &mut trace);
        }

        // §6.9 anytime contract: poll before the t-th iteration's work, so
        // a stop at t means exactly t−1 selections were released.
        let mut stopped = StopReason::IterBudget;
        let mut iters_done = t_total.saturating_sub(1);
        for t in (replay_to + 1)..t_total {
            if let Some(reason) = self.cfg.stop_check(t) {
                stopped = reason;
                iters_done = t - 1;
                break;
            }
            // ---- lines 4-7: dense recompute of the gradient -------------
            // At t = 1 (w = 0) this *is* the bootstrap — v̄ = 0,
            // q̄ = ∇L(0, y), α = Xᵀq̄ — identical for every λ, so path mode
            // copies it from the workspace cache when present (v keeps the
            // exact zeros it was taken with; the matvec at w = 0 would
            // write +0.0 into every slot anyway).
            let cached = t == 1
                && boot == Bootstrap::Shared
                && ws.bootstrap_attach(&boot_key, &mut q, &mut alpha, &self.cfg.cancel);
            if !cached {
                if t == 1 {
                    // in-bootstrap fault hook (tests): fires while this run
                    // holds any coalescing-hub leadership lease it claimed
                    self.cfg.fault.on_bootstrap();
                }
                csr.matvec_scan(&w, &mut v, &mut scratch, kern); // v̄ = X w
                for i in 0..n {
                    q[i] = self.loss.grad(v[i], y[i] as f64); // q̄ = ∇L(v̄)
                }
                alpha.iter_mut().for_each(|a| *a = 0.0);
                // α = Xᵀ q̄  (ȳ fused into q̄)
                csr.matvec_t_add_scan(&q, &mut alpha, &mut scratch, kern);
                let cost = 4 * csr.nnz() as u64 + n as u64 * FLOPS_SIGMOID + d as u64;
                // §6.6 traffic model: both matvec passes stream the index
                // and value structures; per nonzero a w gather (first
                // pass) and an α rmw (second); per row a v̄ write, the
                // grad sweep (v̄ + label reads, q̄ write), and a q̄ gather;
                // plus the α zeroing.
                let nnz_u = csr.nnz() as u64;
                let bytes = 2 * csr.index_bytes_total()
                    + 2 * BYTES_F32_READ * nnz_u
                    + (BYTES_F64_READ + BYTES_F64_RMW) * nnz_u
                    + (4 * BYTES_F64_READ + BYTES_F32_READ) * n as u64
                    + BYTES_F64_READ * d as u64;
                if t == 1 {
                    flops.add_boot(cost);
                    flops.add_boot_bytes(bytes);
                    if boot == Bootstrap::Shared {
                        ws.bootstrap_put(boot_key, &q, &alpha);
                    }
                } else {
                    flops.add(cost);
                    flops.add_bytes(bytes);
                    // both matvec passes sweep every row segment through
                    // the dispatcher (the t = 1 sweep is bootstrap work
                    // and stays out of the iteration-tier split, mirroring
                    // the §6.7 convention in the fast solver)
                    flops.add_segs(2 * seg_direct, 2 * seg_scratch, 2 * seg_scratch_nnz);
                }
            }
            if !initialized {
                selector.init(&alpha, &mut flops);
                initialized = true;
            }

            // ---- line 8: selection (argmax / noisy-max / exp-mech) ------
            let j = selector.select(&alpha, &mut rng, &mut flops);

            // ---- lines 9-11: direction and gap --------------------------
            // d = −w + λ·s·e_j with s = −sign(α_j);
            // g_t = −⟨α, d⟩ = ⟨α, w⟩ + λ|α_j| (at the selected j).
            let s = -lam * sign(alpha[j]);
            let aw: f64 = alpha.iter().zip(&w).map(|(&a, &wk)| a * wk).sum();
            flops.add(2 * d as u64);
            gap = aw - s * alpha[j];
            flops.add(2);

            // ---- lines 12-13: dense step --------------------------------
            let eta = 2.0 / (t as f64 + 2.0);
            for wk in w.iter_mut() {
                *wk *= 1.0 - eta;
            }
            w[j] += eta * s;
            flops.add(d as u64 + 2);
            // ⟨α,w⟩ streams both dense vectors; the shrink is a w rmw
            flops.add_bytes((2 * BYTES_F64_READ + BYTES_F64_RMW) * d as u64);

            if durability.is_some() {
                history.push((j as u32, if s >= 0.0 { 1 } else { -1 }));
            }
            if self.cfg.trace_every > 0 && t % self.cfg.trace_every == 0 {
                trace.push(TraceRecord {
                    iter: t,
                    gap,
                    flops: flops.total(),
                    bytes: flops.bytes(),
                    pops: selector.stats().pops,
                    selected: j,
                    wall_ns: start.elapsed().as_nanos(),
                });
            }
            // §6.11 cadence: ledger first (write-ahead), then the snapshot
            if let Some(dur) = durability {
                if dur.should_checkpoint(t) {
                    if let Some(pp) = &self.cfg.privacy {
                        dur.charge(
                            self.data.fingerprint(),
                            t_total,
                            t,
                            pp.spent_epsilon(t_total, t),
                        );
                    }
                    dur.persist(&self.snapshot(
                        t,
                        &w,
                        gap,
                        &rng,
                        &flops,
                        selector.stats(),
                        &history,
                        &trace,
                    ));
                }
            }
            if self.cfg.gap_converged(gap) {
                stopped = StopReason::Converged;
                iters_done = t;
                break;
            }
        }

        // §6.11: final ledger record ahead of releasing the results, then
        // a resume point at interruption stops (natural finishes need
        // none).
        if let Some(dur) = durability {
            if let Some(pp) = &self.cfg.privacy {
                dur.charge(
                    self.data.fingerprint(),
                    t_total,
                    iters_done,
                    pp.spent_epsilon(t_total, iters_done),
                );
            }
            if iters_done > 0
                && matches!(
                    stopped,
                    StopReason::Deadline | StopReason::Cancelled | StopReason::Brownout
                )
            {
                dur.persist(&self.snapshot(
                    iters_done,
                    &w,
                    gap,
                    &rng,
                    &flops,
                    selector.stats(),
                    &history,
                    &trace,
                ));
            }
        }

        let wall_ms = start.elapsed().as_secs_f64() * 1e3;
        trace.push(TraceRecord {
            iter: iters_done,
            gap,
            flops: flops.total(),
            bytes: flops.bytes(),
            pops: selector.stats().pops,
            selected: usize::MAX,
            wall_ns: start.elapsed().as_nanos(),
        });
        let out = FwOutput {
            // the weight vector escapes the run: clone it out of the pool
            // rather than surrendering the pooled buffer
            weights: WeightVector(w.clone()),
            final_gap: gap,
            flops: flops.total(),
            bootstrap_flops: flops.bootstrap(),
            bytes_moved: flops.bytes(),
            bootstrap_bytes: flops.bootstrap_bytes(),
            scratch_bytes: flops.scratch_bytes(),
            direct_segments: flops.direct_segments(),
            scratch_segments: flops.scratch_segments(),
            wall_ms,
            phase: None, // Alg 1 has no fused-scan phase breakdown
            selector_stats: selector.stats(),
            trace,
            iters_run: iters_done,
            stopped,
            eps_spent: self
                .cfg
                .privacy
                .map(|pp| pp.spent_epsilon(t_total, iters_done)),
            effective_threads: self.cfg.effective_threads(),
            effective_shards: 0,
            shard_flops: Vec::new(),
            shard_bytes: Vec::new(),
        };
        ws.recycle_f64(w);
        ws.recycle_f64(v);
        ws.recycle_f64(q);
        ws.recycle_f64(alpha);
        ws.recycle_u32(scratch);
        ws.recycle_selector(selector, d, exp_scale, nm_scale);
        out
    }

    /// The row-sharded Algorithm 1 (DESIGN.md §6.8). Per iteration:
    ///
    /// * **Pass 1** `v̄ = Xw` + the gradient sweep `q̄ = ∇L(v̄, y)` run
    ///   per shard into disjoint `v̄`/`q̄` slices — every `v̄_i` is one
    ///   row dot (row-local FP), so any schedule computes the same bits.
    /// * **Pass 2** `α = Xᵀq̄` runs through the *parent's*
    ///   column-partitioned sweep: per-column sequential sums, hence
    ///   bit-identical at any thread count, and the column-side FP
    ///   reduction order never depends on the row partition.
    /// * **Selection** uses the tree-reduced parallel argmax when the
    ///   selector supports precomputation, the sequential `select`
    ///   otherwise — exactly as in the fast solver.
    ///
    /// The FLOP model is the legacy formula unchanged. The byte model
    /// differs from the legacy path in exactly one P-invariant term:
    /// pass 2 streams the CSC index structure instead of a second CSR
    /// sweep (and splits its segments on the column side), because that
    /// is what the sharded engine executes. Trajectory, flops, and bytes
    /// are therefore bit-identical across any `(P, threads)` — but the
    /// byte/segment totals are compared sharded-vs-sharded, not against
    /// the `shards: None` path (documented in DESIGN.md §6.8).
    fn run_core_sharded(
        &self,
        ws: &mut FwWorkspace,
        lam: f64,
        boot: Bootstrap,
        requested: usize,
    ) -> FwOutput {
        let start = Instant::now();
        let csr = &self.data.csr;
        let csc = &self.data.csc;
        let n = csr.n_rows();
        let d = csr.n_cols();
        let t_total = self.cfg.iters;
        let lip = self.cfg.lipschitz.unwrap_or_else(|| self.loss.lipschitz());
        let boot_key = BootKey::of(self.data, self.loss.name());
        let eff_threads = self.cfg.effective_threads();
        let pass2_threads = if self.cfg.threads == 0 {
            crate::sparse::auto_threads(csr.nnz())
        } else {
            self.cfg.threads
        };

        let sharded = ws
            .take_sharded(self.data, requested)
            .unwrap_or_else(|| ShardedDataset::build(self.data, requested));
        let p = sharded.n_shards();
        let mut shard_scratch = ws.take_shard_scratch(p);
        let mut shard_costs = ShardCosts::new(p);

        let (exp_scale, nm_scale) = match self.cfg.privacy {
            Some(pp) => {
                (pp.exp_mech_scale(t_total, lip), pp.noisy_max_scale(t_total, lip))
            }
            None => (0.0, 0.0),
        };
        let mut selector = ws.take_selector(self.cfg.selector, d, exp_scale, nm_scale);
        let mut rng = Xoshiro256pp::seeded(self.cfg.seed);
        let mut flops = FlopCounter::new();
        let kern = self.cfg.scan_kernel();
        // full-sweep dispatcher splits of what this engine executes:
        // pass 1 sweeps the row segments, pass 2 the column segments —
        // both computed on the parent's canonical streams (P-invariant)
        let (r_direct, r_scratch, r_scratch_nnz) = csr.scan_split(kern);
        let (c_direct, c_scratch, c_scratch_nnz) = csc.scan_split(kern);

        let mut w = ws.take_f64(d, 0.0);
        let mut v = ws.take_f64(n, 0.0);
        let mut q = ws.take_f64(n, 0.0);
        let mut alpha = ws.take_f64(d, 0.0);
        let mut trace = Vec::new();
        let mut gap = f64::NAN;
        let mut initialized = false;
        let use_tree_select = selector.supports_precomputed();

        // §6.11 durability/resume plumbing (see the legacy body): rebuild
        // the t = 1 bootstrap alpha for `selector.init`, restore the
        // sparse iterate directly, and continue at `replay_to + 1`.
        let resume = self.cfg.resume.as_deref();
        if let Some(ck) = resume {
            ck.validate_for(&self.cfg, self.data.fingerprint());
        }
        let replay_to = resume.map_or(0, |ck| ck.replay_to());
        let durability = self.cfg.durability.as_deref();
        let mut history: Vec<(u32, i8)> =
            resume.map(|ck| ck.history.clone()).unwrap_or_default();
        if let Some(ck) = resume {
            let cached = boot == Bootstrap::Shared
                && ws.bootstrap_attach(&boot_key, &mut q, &mut alpha, &self.cfg.cancel);
            if !cached {
                // w = 0 ⇒ v̄ = 0 exactly (the pass-1 dots would write +0.0
                // into every slot v was taken with), so only the gradient
                // sweep and pass 2 are needed to rebuild the bootstrap α
                for i in 0..n {
                    q[i] = self.loss.grad(v[i], self.data.labels[i] as f64);
                }
                csc.matvec_t_par_scan(&q, &mut alpha, pass2_threads, kern);
                if boot == Bootstrap::Shared {
                    ws.bootstrap_put(boot_key, &q, &alpha);
                }
            }
            selector.init(&alpha, &mut flops);
            initialized = true;
            for &(jj, wv) in &ck.weights {
                w[jj as usize] = wv;
            }
            // boundary restore: the rebuild work above is discarded from
            // the counters — the resumed run reports the logical
            // uninterrupted trajectory (see fw/checkpoint.rs)
            ck.restore_into(&mut rng, &mut flops, &mut *selector, &mut gap, &mut trace);
        }

        // §6.9: same stop-poll placement as the legacy body.
        let mut stopped = StopReason::IterBudget;
        let mut iters_done = t_total.saturating_sub(1);
        for t in (replay_to + 1)..t_total {
            if let Some(reason) = self.cfg.stop_check(t) {
                stopped = reason;
                iters_done = t - 1;
                break;
            }
            let cached = t == 1
                && boot == Bootstrap::Shared
                && ws.bootstrap_attach(&boot_key, &mut q, &mut alpha, &self.cfg.cancel);
            if !cached {
                if t == 1 {
                    self.cfg.fault.on_bootstrap();
                }
                // ---- pass 1 + gradient sweep, per shard ----------------
                // each shard's rows are independent dots into its disjoint
                // v̄/q̄ slices; the shard scans its OWN CSR slab (local
                // rows, global columns) through the shared dispatcher
                if eff_threads > 1 && p > 1 && csr.nnz() >= crate::sparse::PAR_MIN_NNZ {
                    std::thread::scope(|scope| {
                        let mut v_rest = v.as_mut_slice();
                        let mut q_rest = q.as_mut_slice();
                        let loss = &*self.loss;
                        let w_ref = &w[..];
                        for (s, scr) in
                            sharded.shards().iter().zip(shard_scratch.iter_mut())
                        {
                            let (v_s, v_tail) =
                                std::mem::take(&mut v_rest).split_at_mut(s.n_rows());
                            let (q_s, q_tail) =
                                std::mem::take(&mut q_rest).split_at_mut(s.n_rows());
                            v_rest = v_tail;
                            q_rest = q_tail;
                            scope.spawn(move || {
                                s.csr.matvec_scan(w_ref, v_s, &mut scr.decode, kern);
                                for ((qi, &vi), &yi) in
                                    q_s.iter_mut().zip(v_s.iter()).zip(s.labels.iter())
                                {
                                    *qi = loss.grad(vi, yi as f64);
                                }
                            });
                        }
                    });
                } else {
                    for (s, scr) in sharded.shards().iter().zip(shard_scratch.iter_mut())
                    {
                        let r = s.rows.clone();
                        s.csr.matvec_scan(&w, &mut v[r.clone()], &mut scr.decode, kern);
                        for i in r {
                            q[i] = self.loss.grad(v[i], self.data.labels[i] as f64);
                        }
                    }
                }
                // ---- pass 2: α = Xᵀq̄ through the parent CSC ------------
                // column-partitioned, per-column sequential sums: the FP
                // reduction order is independent of both the row partition
                // and the thread count (bit-identical to the CSR-driven
                // `matvec_t_add` into a zeroed output — the counting sort
                // stores each column's rows ascending)
                csc.matvec_t_par_scan(&q, &mut alpha, pass2_threads, kern);
                let cost = 4 * csr.nnz() as u64 + n as u64 * FLOPS_SIGMOID + d as u64;
                // legacy §6.6 model with one substitution: pass 2 streams
                // the CSC index structure (that is the sweep this engine
                // runs), not a second CSR sweep — P- and thread-invariant
                let nnz_u = csr.nnz() as u64;
                let bytes = csr.index_bytes_total()
                    + csc.index_bytes_total()
                    + 2 * BYTES_F32_READ * nnz_u
                    + (BYTES_F64_READ + BYTES_F64_RMW) * nnz_u
                    + (4 * BYTES_F64_READ + BYTES_F32_READ) * n as u64
                    + BYTES_F64_READ * d as u64;
                if t == 1 {
                    flops.add_boot(cost);
                    flops.add_boot_bytes(bytes);
                    if boot == Bootstrap::Shared {
                        ws.bootstrap_put(boot_key, &q, &alpha);
                    }
                } else {
                    flops.add(cost);
                    flops.add_bytes(bytes);
                    flops.add_segs(
                        r_direct + c_direct,
                        r_scratch + c_scratch,
                        r_scratch_nnz + c_scratch_nnz,
                    );
                }
                // per-shard attribution: the genuinely shard-local part —
                // pass 1's dots and the gradient sweep (pass 2 and the
                // dense plane stay in the global bucket)
                for (si, s) in sharded.shards().iter().enumerate() {
                    let snnz = s.nnz() as u64;
                    let srows = s.n_rows() as u64;
                    shard_costs.add(si, 2 * snnz + srows * FLOPS_SIGMOID);
                    shard_costs.add_bytes(
                        si,
                        (BYTES_F32_READ + BYTES_F64_READ) * snnz
                            + (4 * BYTES_F64_READ + BYTES_F32_READ) * srows,
                    );
                }
            }
            if !initialized {
                selector.init(&alpha, &mut flops);
                initialized = true;
            }

            // ---- line 8: selection --------------------------------------
            let j = if use_tree_select && eff_threads > 1 && d >= SELECT_PAR_MIN_D {
                let j = par_abs_argmax(&alpha, eff_threads, eff_threads);
                selector.commit_precomputed(j, alpha.len(), &mut flops);
                j
            } else {
                selector.select(&alpha, &mut rng, &mut flops)
            };

            // ---- lines 9-11: direction and gap --------------------------
            let s = -lam * sign(alpha[j]);
            let aw: f64 = alpha.iter().zip(&w).map(|(&a, &wk)| a * wk).sum();
            flops.add(2 * d as u64);
            gap = aw - s * alpha[j];
            flops.add(2);

            // ---- lines 12-13: dense step --------------------------------
            let eta = 2.0 / (t as f64 + 2.0);
            for wk in w.iter_mut() {
                *wk *= 1.0 - eta;
            }
            w[j] += eta * s;
            flops.add(d as u64 + 2);
            flops.add_bytes((2 * BYTES_F64_READ + BYTES_F64_RMW) * d as u64);

            if durability.is_some() {
                history.push((j as u32, if s >= 0.0 { 1 } else { -1 }));
            }
            if self.cfg.trace_every > 0 && t % self.cfg.trace_every == 0 {
                trace.push(TraceRecord {
                    iter: t,
                    gap,
                    flops: flops.total(),
                    bytes: flops.bytes(),
                    pops: selector.stats().pops,
                    selected: j,
                    wall_ns: start.elapsed().as_nanos(),
                });
            }
            // §6.11 cadence: ledger first (write-ahead), then the snapshot
            if let Some(dur) = durability {
                if dur.should_checkpoint(t) {
                    if let Some(pp) = &self.cfg.privacy {
                        dur.charge(
                            self.data.fingerprint(),
                            t_total,
                            t,
                            pp.spent_epsilon(t_total, t),
                        );
                    }
                    dur.persist(&self.snapshot(
                        t,
                        &w,
                        gap,
                        &rng,
                        &flops,
                        selector.stats(),
                        &history,
                        &trace,
                    ));
                }
            }
            if self.cfg.gap_converged(gap) {
                stopped = StopReason::Converged;
                iters_done = t;
                break;
            }
        }

        // §6.11: final ledger record ahead of releasing the results, then
        // a resume point at interruption stops (natural finishes need
        // none).
        if let Some(dur) = durability {
            if let Some(pp) = &self.cfg.privacy {
                dur.charge(
                    self.data.fingerprint(),
                    t_total,
                    iters_done,
                    pp.spent_epsilon(t_total, iters_done),
                );
            }
            if iters_done > 0
                && matches!(
                    stopped,
                    StopReason::Deadline | StopReason::Cancelled | StopReason::Brownout
                )
            {
                dur.persist(&self.snapshot(
                    iters_done,
                    &w,
                    gap,
                    &rng,
                    &flops,
                    selector.stats(),
                    &history,
                    &trace,
                ));
            }
        }

        let wall_ms = start.elapsed().as_secs_f64() * 1e3;
        trace.push(TraceRecord {
            iter: iters_done,
            gap,
            flops: flops.total(),
            bytes: flops.bytes(),
            pops: selector.stats().pops,
            selected: usize::MAX,
            wall_ns: start.elapsed().as_nanos(),
        });
        let (shard_flops, shard_bytes) = shard_costs.into_parts();
        let out = FwOutput {
            weights: WeightVector(w.clone()),
            final_gap: gap,
            flops: flops.total(),
            bootstrap_flops: flops.bootstrap(),
            bytes_moved: flops.bytes(),
            bootstrap_bytes: flops.bootstrap_bytes(),
            scratch_bytes: flops.scratch_bytes(),
            direct_segments: flops.direct_segments(),
            scratch_segments: flops.scratch_segments(),
            wall_ms,
            phase: None,
            selector_stats: selector.stats(),
            trace,
            iters_run: iters_done,
            stopped,
            eps_spent: self
                .cfg
                .privacy
                .map(|pp| pp.spent_epsilon(t_total, iters_done)),
            effective_threads: eff_threads,
            effective_shards: p,
            shard_flops,
            shard_bytes,
        };
        ws.recycle_f64(w);
        ws.recycle_f64(v);
        ws.recycle_f64(q);
        ws.recycle_f64(alpha);
        ws.recycle_shard_scratch(shard_scratch);
        ws.put_sharded(sharded);
        ws.recycle_selector(selector, d, exp_scale, nm_scale);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dp::accounting::PrivacyParams;
    use crate::sparse::synth::{DatasetPreset, SynthConfig};

    fn small_ds() -> Dataset {
        SynthConfig {
            name: "unit".into(),
            n_rows: 120,
            n_cols: 64,
            avg_row_nnz: 10.0,
            zipf_exponent: 1.2,
            n_informative: 12,
            n_dense: 0,
            label_noise: 0.02,
            bias_col: true,
        }
        .generate(1234)
    }

    #[test]
    fn converges_nonprivate() {
        let ds = small_ds();
        let cfg = FwConfig {
            iters: 400,
            lambda: 10.0,
            trace_every: 1,
            ..Default::default()
        };
        let out = StandardFrankWolfe::new(&ds, cfg).run();
        let first_gap = out.trace.first().unwrap().gap;
        assert!(
            out.final_gap < first_gap * 0.2,
            "no convergence: {} -> {}",
            first_gap,
            out.final_gap
        );
        assert!(out.weights.l1_norm() <= 10.0 + 1e-9, "left the L1 ball");
    }

    #[test]
    fn solution_sparsity_bounded_by_iterations() {
        let ds = small_ds();
        let cfg = FwConfig { iters: 30, lambda: 5.0, ..Default::default() };
        let out = StandardFrankWolfe::new(&ds, cfg).run();
        // FW touches ≤ 1 new coordinate per iteration
        assert!(out.weights.nnz() <= 29);
    }

    #[test]
    fn dp_run_executes_and_stays_feasible() {
        let ds = small_ds();
        let cfg = FwConfig {
            iters: 120,
            lambda: 5.0,
            privacy: Some(PrivacyParams::new(1.0, 1e-6)),
            selector: SelectorKind::NoisyMax,
            seed: 9,
            ..Default::default()
        };
        let out = StandardFrankWolfe::new(&ds, cfg).run();
        assert!(out.weights.l1_norm() <= 5.0 + 1e-9);
        assert!(out.flops > 0);
    }

    /// The t = 1 dense recompute is shared across a λ-path: cold once,
    /// then zero bootstrap flops, with totals offset by exactly the
    /// skipped work and identical weights.
    #[test]
    fn run_path_shares_t1_bootstrap() {
        let ds = small_ds();
        let cfg = FwConfig { iters: 50, lambda: 1.0, ..Default::default() };
        let mut ws = FwWorkspace::new();
        let lambdas = [3.0, 6.0, 12.0];
        let outs = StandardFrankWolfe::new(&ds, cfg.clone()).run_path(&lambdas, &mut ws);
        assert!(outs[0].bootstrap_flops > 0);
        for o in &outs[1..] {
            assert_eq!(o.bootstrap_flops, 0);
        }
        for (o, &lam) in outs.iter().zip(&lambdas) {
            let fresh =
                StandardFrankWolfe::new(&ds, FwConfig { lambda: lam, ..cfg.clone() }).run();
            assert_eq!(fresh.weights, o.weights);
            assert_eq!(
                o.flops + (fresh.bootstrap_flops - o.bootstrap_flops),
                fresh.flops
            );
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = small_ds();
        let cfg = FwConfig {
            iters: 60,
            lambda: 5.0,
            privacy: Some(PrivacyParams::new(0.5, 1e-6)),
            selector: SelectorKind::NoisyMax,
            seed: 33,
            ..Default::default()
        };
        let a = StandardFrankWolfe::new(&ds, cfg.clone()).run();
        let b = StandardFrankWolfe::new(&ds, cfg).run();
        assert_eq!(a.weights, b.weights);
        assert_eq!(a.flops, b.flops);
    }

    #[test]
    #[should_panic(expected = "FastFrankWolfe")]
    fn rejects_heap_selectors() {
        let ds = small_ds();
        let cfg = FwConfig { selector: SelectorKind::FibHeap, ..Default::default() };
        StandardFrankWolfe::new(&ds, cfg);
    }
}
