//! Core library: the paper's Frank-Wolfe solver family.
//!
//! * [`standard`] — Algorithm 1, the standard sparse-aware baseline
//!   (sparse matvecs, dense `O(D)` per-iteration work, report-noisy-max
//!   for DP).
//! * [`fast`] — Algorithm 2, the fast sparse-aware solver: `O(1)` weight
//!   updates via the multiplicative scalar `w_m`, `O(S_r S_c)` sparse
//!   maintenance of `α`, `v̄` and the gap `g̃`, and selector-pluggable
//!   coordinate choice.
//! * [`queue`] — the selector abstraction: non-private argmax, Alg 3's
//!   Fibonacci-heap queue, Alg 4's BSLS exponential sampler, the noisy-max
//!   ablation, and the naive `O(D)` exponential mechanism.
//! * [`scan`] — the shared decode-and-gather kernel layer (DESIGN.md
//!   §6.6–§6.7): every hot sparse loop routes through it, consuming
//!   either the plain `u32` or the compact `u16-delta` index substrate
//!   with explicit software prefetch and bit-identical accumulation
//!   order; a segment-adaptive dispatcher ([`scan::ScanKernel`]) sends
//!   short compact segments down fused direct-decode kernels (two-cursor
//!   pipeline, no scratch round-trip) and long ones down the
//!   decode-to-scratch path.
//! * [`workspace`] — reusable run-to-run buffer pools ([`workspace::FwWorkspace`]):
//!   both solvers expose `run_in(&mut FwWorkspace)` so sweep drivers and
//!   the coordinator's workers execute repeated runs without allocating
//!   solver state or rebuilding selector storage, and
//!   `run_path(&[f64], &mut FwWorkspace)` to train whole regularization
//!   paths sharing one dense bootstrap through the workspace's cache
//!   (DESIGN.md §6.5). Reuse is bit-exact.
//! * [`loss`], [`flops`], [`trace`], [`config`] — losses with the DP
//!   Lipschitz constants, FLOP accounting (Figures 2 & 4), per-iteration
//!   traces (Figures 1 & 3), and run configuration (including the
//!   `threads` knob for the block-parallel bootstrap).
//! * [`cancel`] — cooperative cancellation/deadlines (DESIGN.md §6.9):
//!   both solvers poll a [`cancel::CancelToken`] once per iteration and,
//!   because Frank-Wolfe is anytime, a fired token degrades the run to a
//!   best-so-far result tagged with a [`cancel::StopReason`] instead of
//!   failing it; the ε ledger charges only the iterations actually run.
//! * [`checkpoint`] — crash-consistent O(t) solver snapshots and resume
//!   (DESIGN.md §6.11): sparse iterate + selection history + RNG stream
//!   position in an atomic framed binary file, such that
//!   checkpoint-then-resume is bitwise identical to the uninterrupted run
//!   at any (shards, threads); pairs with the write-ahead ε ledger in
//!   [`crate::dp::ledger`].

pub mod cancel;
pub mod checkpoint;
pub mod config;
pub mod fast;
pub mod flops;
pub mod loss;
pub mod queue;
pub mod scan;
pub mod standard;
pub mod trace;
pub mod workspace;

/// Three-valued sign (`sign(0) = 0`), shared with the data generator.
#[inline]
pub fn sign_pub(x: f64) -> f64 {
    sign(x)
}

#[inline]
pub(crate) fn sign(x: f64) -> f64 {
    if x > 0.0 {
        1.0
    } else if x < 0.0 {
        -1.0
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::sign;

    #[test]
    fn sign_is_three_valued() {
        assert_eq!(sign(3.5), 1.0);
        assert_eq!(sign(-0.1), -1.0);
        assert_eq!(sign(0.0), 0.0);
        assert_eq!(sign(-0.0), 0.0);
    }
}
