//! The shared scan-kernel layer (DESIGN.md §6.6–§6.7): every hot sparse
//! loop in the codebase — the fast solver's fused update+notify scan,
//! Alg 1's `matvec`/`matvec_t_add`, the CSC-driven bootstrap, the
//! coordinator's scorer — routes its decode-and-gather through this
//! module.
//!
//! Four ideas, one contract:
//!
//! * **Decode to scratch, gather from `u32`.** A compact
//!   ([`crate::sparse::compact`]) segment is first decoded into a
//!   caller-provided `u32` scratch buffer ([`resolve`]); the gather loops
//!   then run on plain `u32` indices either way. The scratch stays
//!   L1-resident (it is reused segment after segment), so DRAM index
//!   traffic is the half-width `u16` stream while the gather code — and
//!   therefore the accumulation order — is *identical* across substrates.
//!   On the `u32` substrate [`resolve`] is a zero-cost borrow.
//! * **Direct decode for short segments** (§6.7). The scratch round-trip
//!   is a store+load per index — a large constant fraction of per-segment
//!   work when the segment holds only `S_c ≈ 5–40` indices (the paper's
//!   row scans). The fused kernels ([`dot_gather_u16`],
//!   [`axpy_gather_u16`], [`update_touch_u16`]) instead consume the `u16`
//!   word stream directly through a **two-cursor software pipeline**
//!   ([`DirectScan`]): a decode cursor runs [`PF_DIST`] elements ahead of
//!   the gather cursor, materializing decoded indices into a small
//!   stack-resident ring while the just-decoded index drives the gather
//!   prefetches; the gather cursor drains the ring in the exact serial
//!   accumulation order of the scratch path. The [`ScanKernel`]
//!   dispatcher picks fused vs. scratch-decode per segment from its nnz
//!   against [`DIRECT_MAX_NNZ`].
//! * **Software prefetch.** The gather targets (`w[j]`, `α[k]`,
//!   `stamp[k]`, `v̂[i]`) are random-access into arrays far larger than
//!   cache; the index stream tells us the next addresses [`PF_DIST`]
//!   elements early, so each kernel issues explicit prefetches that far
//!   ahead ([`prefetch_read`], a portable shim over `_mm_prefetch` that
//!   compiles to nothing off x86_64). Prefetching is a pure hint: it
//!   cannot change any computed value.
//! * **Bit-identical by construction.** Every kernel accumulates in the
//!   exact serial order of the pre-existing loops (single accumulator,
//!   sequential adds — the manual 4× unrolls keep one dependency chain,
//!   and the fused pipeline gathers one element at a time in the same
//!   stream order), so routing a call site through this module never
//!   changes its output bits (property-tested compact-vs-u32, fused vs.
//!   scratch vs. u32, and against the old loops' golden outputs), per the
//!   DESIGN.md §2 convention.
//!
//! Layering note: this module lives in `fw/` (it is the solver family's
//! kernel layer) but depends only on `sparse::compact` — never on the
//! matrix types or solvers — while `sparse::{csr,csc}` call *into* it.
//! That one deliberate up-reference keeps a single copy of every gather
//! loop; see DESIGN.md §6.6.

use std::sync::OnceLock;

use crate::sparse::compact::{decode_words, IndexSeg, ESCAPE};

/// Prefetch lookahead distance, in stream elements. Far enough that a
/// DRAM fetch (~100 ns) completes before the gather loop (~1–2 ns/element
/// of ALU work) arrives; near enough not to thrash L1. Tuned for the
/// paper-preset shapes; see DESIGN.md §6.6.
pub const PF_DIST: usize = 16;

#[cfg(target_arch = "x86_64")]
#[inline(always)]
fn prefetch_ptr<T>(p: *const T) {
    // SAFETY: prefetch is a non-faulting hint; the pointer is derived
    // from an in-bounds slice element and never dereferenced here.
    unsafe {
        core::arch::x86_64::_mm_prefetch::<{ core::arch::x86_64::_MM_HINT_T0 }>(p.cast::<i8>())
    }
}

#[cfg(not(target_arch = "x86_64"))]
#[inline(always)]
fn prefetch_ptr<T>(p: *const T) {
    let _ = p;
}

/// Hint the cache to load `slice[i]`; a no-op when `i` is out of bounds
/// (stream tails) or the target has no prefetch instruction.
#[inline(always)]
pub fn prefetch_read<T>(slice: &[T], i: usize) {
    if let Some(r) = slice.get(i) {
        prefetch_ptr(r);
    }
}

/// Default nnz ceiling for the fused direct-decode tier: segments at or
/// below it skip the scratch round-trip ([`SegArm::Direct`]), longer ones
/// amortize the decode over a scratch that stays L1-hot
/// ([`SegArm::Scratch`]). 64 brackets the paper's row-scan lengths
/// (S_c ≈ 5–40, where the store+load per index is the largest constant
/// fraction of segment work) while leaving long column scans — whose
/// decode cost is amortized and whose 4× unrolled gather is faster from
/// scratch — on the scratch tier. The `benches/substrates.rs`
/// per-segment-length series (nnz ∈ {4, 8, 16, 40, 200, 2000}) measures
/// the crossover on CI hardware; override per run via
/// `FwConfig::direct_max_nnz` or process-wide via `DPFW_DIRECT_MAX_NNZ`.
pub const DIRECT_MAX_NNZ: usize = 64;

/// Ring capacity of the two-cursor pipeline — a power of two strictly
/// greater than [`PF_DIST`], so the decode cursor (at most `PF_DIST`
/// slots ahead of the gather cursor) can never overwrite an undrained
/// slot. 32 × 4 bytes lives comfortably in registers/L1 stack space.
const RING: usize = 32;
// The safety invariant above, enforced at compile time: retuning PF_DIST
// past the ring capacity must be a build error, not a silent corruption
// of undrained slots.
const _: () = assert!(RING > PF_DIST, "DirectScan ring must outsize the prefetch distance");

/// Which kernel arm a [`ScanKernel`] dispatches a segment to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SegArm {
    /// Compact segment, fused direct decode (no scratch round-trip).
    Direct,
    /// Compact segment, decode-to-scratch then `u32` gather.
    Scratch,
    /// Plain `u32` segment — nothing to decode.
    U32,
}

/// Materialize a segment's indices as `u32`: the borrowed stream itself
/// on the plain substrate, or a decode into `scratch` on the compact one.
/// `scratch` is only touched on the compact path, so passing a fresh
/// `Vec::new()` on the `u32` substrate allocates nothing.
///
/// This is the **scratch arm** of the kernel tier: callers scanning whole
/// matrices should route through a [`ScanKernel`] (or the matrix-level
/// `*_scan` entry points built on it), which sends short compact segments
/// down the fused direct-decode arm instead of pairing `resolve` with a
/// gather by hand.
#[inline]
pub fn resolve<'a>(seg: IndexSeg<'a>, scratch: &'a mut Vec<u32>) -> &'a [u32] {
    match seg {
        IndexSeg::U32(idx) => idx,
        IndexSeg::U16 { words, nnz } => {
            decode_words(words, nnz, scratch);
            &scratch[..]
        }
    }
}

/// Decode the next index from a delta word stream: one plain word, or a
/// 3-word escape block (`ESCAPE, lo16, hi16` — see
/// [`crate::sparse::compact`]). The accumulator `prev` carries the
/// running index exactly as [`decode_words`] does.
#[inline(always)]
fn decode_step(words: &[u16], cur: &mut usize, prev: &mut u32) -> u32 {
    let w0 = words[*cur];
    let delta = if w0 != ESCAPE {
        *cur += 1;
        w0 as u32
    } else {
        debug_assert!(*cur + 2 < words.len(), "truncated escape block");
        let lo = words[*cur + 1] as u32;
        let hi = words[*cur + 2] as u32;
        *cur += 3;
        lo | (hi << 16)
    };
    *prev = prev.wrapping_add(delta);
    *prev
}

/// The two-cursor software pipeline over one compact segment (§6.7): the
/// decode cursor runs [`PF_DIST`] indices ahead of the gather cursor,
/// parking decoded indices in a fixed stack ring ([`RING`] slots — never
/// the heap scratch), and [`DirectScan::next`] hands the caller each
/// index *in stream order* together with the index the decode cursor just
/// produced (`PF_DIST` positions ahead) so the caller can start that
/// element's gather-target cache fills now. Construction pre-decodes the
/// first `min(PF_DIST, nnz)` indices; [`DirectScan::lead`] exposes them
/// (valid until the first `next`) so kernels can prefetch the pipeline
/// warm-up too.
///
/// The gather order is exactly the decoded stream order, so any loop
/// drained through `next` is bit-identical to the same loop over a
/// [`resolve`]d scratch slice.
pub struct DirectScan<'a> {
    words: &'a [u16],
    nnz: usize,
    ring: [u32; RING],
    /// Word-stream position of the decode cursor.
    cur: usize,
    /// Running index accumulator of the decode cursor.
    prev: u32,
    /// Indices decoded so far (decode cursor, in elements).
    decoded: usize,
    /// Indices handed out so far (gather cursor).
    k: usize,
}

impl<'a> DirectScan<'a> {
    /// Start the pipeline over a segment's word stream holding `nnz`
    /// indices, pre-decoding the [`PF_DIST`]-element lead.
    #[inline]
    pub fn new(words: &'a [u16], nnz: usize) -> Self {
        let mut s = Self { words, nnz, ring: [0u32; RING], cur: 0, prev: 0, decoded: 0, k: 0 };
        while s.decoded < PF_DIST.min(nnz) {
            s.advance_decode();
        }
        s
    }

    #[inline(always)]
    fn advance_decode(&mut self) -> u32 {
        let j = decode_step(self.words, &mut self.cur, &mut self.prev);
        self.ring[self.decoded % RING] = j;
        self.decoded += 1;
        j
    }

    /// The pre-decoded pipeline lead, in stream order — for issuing the
    /// warm-up prefetches. Only meaningful before the first
    /// [`DirectScan::next`] call (later the ring has wrapped).
    #[inline]
    pub fn lead(&self) -> &[u32] {
        debug_assert!(self.k == 0, "lead() is a pre-drain accessor");
        &self.ring[..self.decoded]
    }

    /// The next index in stream order, plus — when the stream extends
    /// that far — the index just decoded [`PF_DIST`] positions ahead of
    /// it (the caller's prefetch handle). Returns `None` once all `nnz`
    /// indices have been handed out.
    #[inline(always)]
    pub fn next(&mut self) -> Option<(u32, Option<u32>)> {
        if self.k == self.nnz {
            debug_assert_eq!(self.cur, self.words.len(), "undrained escape words");
            return None;
        }
        let ahead = if self.decoded < self.nnz { Some(self.advance_decode()) } else { None };
        let j = self.ring[self.k % RING];
        self.k += 1;
        Some((j, ahead))
    }
}

/// Fused direct-decode counterpart of [`dot_gather`]: consumes the `u16`
/// word stream through a [`DirectScan`], prefetching `w` from the
/// just-decoded lookahead index. Single sequential accumulator in stream
/// order — bit-identical to `resolve` + [`dot_gather`] by construction.
#[inline]
pub fn dot_gather_u16(words: &[u16], nnz: usize, vals: &[f32], w: &[f64]) -> f64 {
    debug_assert_eq!(nnz, vals.len());
    let mut s = DirectScan::new(words, nnz);
    for &jp in s.lead() {
        prefetch_read(w, jp as usize);
    }
    let mut acc = 0.0f64;
    let mut k = 0;
    while let Some((j, ahead)) = s.next() {
        if let Some(jp) = ahead {
            prefetch_read(w, jp as usize);
        }
        acc += vals[k] as f64 * w[j as usize];
        k += 1;
    }
    acc
}

/// Fused direct-decode counterpart of [`axpy_gather`]: scattered AXPY
/// straight off the word stream, prefetching `out` from the lookahead
/// index. Stream order, so repeated indices accumulate exactly as the
/// scratch path does.
#[inline]
pub fn axpy_gather_u16(words: &[u16], nnz: usize, vals: &[f32], coef: f64, out: &mut [f64]) {
    debug_assert_eq!(nnz, vals.len());
    let mut s = DirectScan::new(words, nnz);
    for &jp in s.lead() {
        prefetch_read(out, jp as usize);
    }
    let mut k = 0;
    while let Some((j, ahead)) = s.next() {
        if let Some(jp) = ahead {
            prefetch_read(out, jp as usize);
        }
        out[j as usize] += vals[k] as f64 * coef;
        k += 1;
    }
}

/// Fused direct-decode counterpart of [`update_touch`]: the fast solver's
/// row kernel straight off the word stream, prefetching both `alpha` and
/// `stamp` from the lookahead index. Per-element operations in stream
/// order — α updates, stamp tests, and `touched` pushes are bit- and
/// order-identical to the scratch path.
#[inline]
#[allow(clippy::too_many_arguments)] // mirrors update_touch's signature
pub fn update_touch_u16(
    words: &[u16],
    nnz: usize,
    vals: &[f32],
    gamma: f64,
    alpha: &mut [f64],
    stamp: &mut [u32],
    epoch: u32,
    touched: &mut Vec<u32>,
) {
    debug_assert_eq!(nnz, vals.len());
    let mut s = DirectScan::new(words, nnz);
    for &jp in s.lead() {
        prefetch_read(alpha, jp as usize);
        prefetch_read(stamp, jp as usize);
    }
    let mut k = 0;
    while let Some((j, ahead)) = s.next() {
        if let Some(jp) = ahead {
            prefetch_read(alpha, jp as usize);
            prefetch_read(stamp, jp as usize);
        }
        let ju = j as usize;
        alpha[ju] += gamma * vals[k] as f64;
        if stamp[ju] != epoch {
            stamp[ju] = epoch;
            touched.push(j);
        }
        k += 1;
    }
}

/// The segment-adaptive dispatcher (§6.7): one value per run (or per
/// matrix sweep) deciding, segment by segment, whether a compact segment
/// rides the fused direct-decode arm or the decode-to-scratch arm. Both
/// arms — and the `u32` passthrough — are bit-identical, so the threshold
/// is purely a performance knob; the accounting layer
/// ([`crate::fw::flops::FlopCounter::count_seg`]) records which arm ran.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ScanKernel {
    /// Segments with `nnz <= direct_max_nnz` take the fused arm.
    pub direct_max_nnz: usize,
}

impl ScanKernel {
    /// A dispatcher with an explicit threshold (bench sweeps, tests, and
    /// `FwConfig::direct_max_nnz`). `0` pins every compact segment to the
    /// scratch arm; `usize::MAX` pins every one to the fused arm.
    #[inline]
    pub const fn with_threshold(direct_max_nnz: usize) -> Self {
        Self { direct_max_nnz }
    }

    /// The process-wide dispatcher: `DPFW_DIRECT_MAX_NNZ` if set and
    /// parseable, else [`DIRECT_MAX_NNZ`]. The environment is read
    /// **once per process** (leaf kernels like `row_dot` resolve this on
    /// every call, so it must stay cheap); in-process sweeps use
    /// [`ScanKernel::with_threshold`] / `FwConfig::direct_max_nnz`.
    #[inline]
    pub fn from_env() -> Self {
        static ENV_THRESHOLD: OnceLock<usize> = OnceLock::new();
        let t = *ENV_THRESHOLD.get_or_init(|| {
            std::env::var("DPFW_DIRECT_MAX_NNZ")
                .ok()
                .and_then(|v| v.parse().ok())
                .unwrap_or(DIRECT_MAX_NNZ)
        });
        Self { direct_max_nnz: t }
    }

    /// The active fused-vs-scratch crossover. Tooling that reports or
    /// retunes the threshold (`autotune_thresholds`) reads it through
    /// this accessor so the resolution order (explicit > env > default)
    /// stays in one place.
    #[inline]
    pub fn threshold(&self) -> usize {
        self.direct_max_nnz
    }

    /// Which arm this dispatcher sends `seg` down.
    #[inline]
    pub fn arm(&self, seg: &IndexSeg<'_>) -> SegArm {
        match seg {
            IndexSeg::U32(_) => SegArm::U32,
            IndexSeg::U16 { nnz, .. } => {
                if *nnz <= self.direct_max_nnz {
                    SegArm::Direct
                } else {
                    SegArm::Scratch
                }
            }
        }
    }

    /// How a full sweep of compact segments described by `indptr` (the
    /// standard CSR/CSC offset array) splits under this dispatcher:
    /// `(direct_segments, scratch_segments, scratch_nnz)`, empty segments
    /// uncounted — the analytic mirror of per-segment [`ScanKernel::arm`]
    /// dispatch, kept here so the threshold rule lives in exactly one
    /// type. Callers must only invoke this for matrices that actually
    /// carry a compact mirror (`u32` matrices have no arms to split).
    pub fn split_segments(&self, indptr: &[usize]) -> (u64, u64, u64) {
        let (mut direct, mut scratch, mut scratch_nnz) = (0u64, 0u64, 0u64);
        for w in indptr.windows(2) {
            let nnz = w[1] - w[0];
            if nnz == 0 {
                continue;
            }
            if nnz <= self.direct_max_nnz {
                direct += 1;
            } else {
                scratch += 1;
                scratch_nnz += nnz as u64;
            }
        }
        (direct, scratch, scratch_nnz)
    }

    /// Dispatched [`dot_gather`]: fused off the word stream for short
    /// compact segments, decode-to-`scratch` for long ones, straight
    /// gather on `u32`. Bit-identical across arms.
    #[inline]
    pub fn dot(&self, seg: IndexSeg<'_>, vals: &[f32], w: &[f64], scratch: &mut Vec<u32>) -> f64 {
        match seg {
            IndexSeg::U32(idx) => dot_gather(idx, vals, w),
            IndexSeg::U16 { words, nnz } => {
                if nnz <= self.direct_max_nnz {
                    dot_gather_u16(words, nnz, vals, w)
                } else {
                    decode_words(words, nnz, scratch);
                    dot_gather(&scratch[..], vals, w)
                }
            }
        }
    }

    /// Dispatched [`axpy_gather`]. Bit-identical across arms.
    #[inline]
    pub fn axpy(
        &self,
        seg: IndexSeg<'_>,
        vals: &[f32],
        coef: f64,
        out: &mut [f64],
        scratch: &mut Vec<u32>,
    ) {
        match seg {
            IndexSeg::U32(idx) => axpy_gather(idx, vals, coef, out),
            IndexSeg::U16 { words, nnz } => {
                if nnz <= self.direct_max_nnz {
                    axpy_gather_u16(words, nnz, vals, coef, out);
                } else {
                    decode_words(words, nnz, scratch);
                    axpy_gather(&scratch[..], vals, coef, out);
                }
            }
        }
    }

    /// Dispatched [`update_touch`]. Bit-identical across arms.
    #[inline]
    #[allow(clippy::too_many_arguments)] // mirrors update_touch's signature
    pub fn update_touch(
        &self,
        seg: IndexSeg<'_>,
        vals: &[f32],
        gamma: f64,
        alpha: &mut [f64],
        stamp: &mut [u32],
        epoch: u32,
        touched: &mut Vec<u32>,
        scratch: &mut Vec<u32>,
    ) {
        match seg {
            IndexSeg::U32(idx) => update_touch(idx, vals, gamma, alpha, stamp, epoch, touched),
            IndexSeg::U16 { words, nnz } => {
                if nnz <= self.direct_max_nnz {
                    update_touch_u16(words, nnz, vals, gamma, alpha, stamp, epoch, touched);
                } else {
                    decode_words(words, nnz, scratch);
                    update_touch(&scratch[..], vals, gamma, alpha, stamp, epoch, touched);
                }
            }
        }
    }
}

/// `Σ_k vals[k]·w[idx[k]]` — the sparse·dense dot product behind
/// `matvec`, `row_dot`, and the CSC column sweep. Single accumulator,
/// strictly sequential adds: bit-identical to the naive loop.
#[inline]
pub fn dot_gather(idx: &[u32], vals: &[f32], w: &[f64]) -> f64 {
    debug_assert_eq!(idx.len(), vals.len());
    let n = idx.len();
    let mut acc = 0.0f64;
    let mut k = 0;
    while k + 4 <= n {
        if k + PF_DIST + 4 <= n {
            prefetch_read(w, idx[k + PF_DIST] as usize);
            prefetch_read(w, idx[k + PF_DIST + 1] as usize);
            prefetch_read(w, idx[k + PF_DIST + 2] as usize);
            prefetch_read(w, idx[k + PF_DIST + 3] as usize);
        }
        acc += vals[k] as f64 * w[idx[k] as usize];
        acc += vals[k + 1] as f64 * w[idx[k + 1] as usize];
        acc += vals[k + 2] as f64 * w[idx[k + 2] as usize];
        acc += vals[k + 3] as f64 * w[idx[k + 3] as usize];
        k += 4;
    }
    while k < n {
        acc += vals[k] as f64 * w[idx[k] as usize];
        k += 1;
    }
    acc
}

/// `out[idx[k]] += vals[k]·coef` for every k — the scattered AXPY behind
/// `matvec_t_add`. Stream order, so repeated indices accumulate exactly
/// as the naive loop does.
#[inline]
pub fn axpy_gather(idx: &[u32], vals: &[f32], coef: f64, out: &mut [f64]) {
    debug_assert_eq!(idx.len(), vals.len());
    let n = idx.len();
    let mut k = 0;
    while k + 4 <= n {
        if k + PF_DIST + 4 <= n {
            prefetch_read(out, idx[k + PF_DIST] as usize);
            prefetch_read(out, idx[k + PF_DIST + 1] as usize);
            prefetch_read(out, idx[k + PF_DIST + 2] as usize);
            prefetch_read(out, idx[k + PF_DIST + 3] as usize);
        }
        out[idx[k] as usize] += vals[k] as f64 * coef;
        out[idx[k + 1] as usize] += vals[k + 1] as f64 * coef;
        out[idx[k + 2] as usize] += vals[k + 2] as f64 * coef;
        out[idx[k + 3] as usize] += vals[k + 3] as f64 * coef;
        k += 4;
    }
    while k < n {
        out[idx[k] as usize] += vals[k] as f64 * coef;
        k += 1;
    }
}

/// The fast solver's fused row kernel (Alg 2 lines 26–28 + the line 29
/// touched-list recording): `α[k] += γ·x_ik` along one CSR row, stamping
/// each coordinate's *first* touch of the iteration into `touched` so the
/// notify drain can run afterwards on final α values. Prefetches both
/// `alpha[k]` and `stamp[k]` [`PF_DIST`] elements ahead — the two gather
/// streams this loop is bound on.
#[inline]
pub fn update_touch(
    idx: &[u32],
    vals: &[f32],
    gamma: f64,
    alpha: &mut [f64],
    stamp: &mut [u32],
    epoch: u32,
    touched: &mut Vec<u32>,
) {
    debug_assert_eq!(idx.len(), vals.len());
    let n = idx.len();
    // one element of the strictly sequential scan — the macro keeps the
    // 4× unrolled and tail loops textually identical
    macro_rules! step {
        ($k:expr) => {{
            let j = idx[$k];
            let ju = j as usize;
            alpha[ju] += gamma * vals[$k] as f64;
            if stamp[ju] != epoch {
                stamp[ju] = epoch;
                touched.push(j);
            }
        }};
    }
    let mut k = 0;
    while k + 4 <= n {
        if k + PF_DIST + 4 <= n {
            prefetch_read(alpha, idx[k + PF_DIST] as usize);
            prefetch_read(stamp, idx[k + PF_DIST] as usize);
            prefetch_read(alpha, idx[k + PF_DIST + 1] as usize);
            prefetch_read(stamp, idx[k + PF_DIST + 1] as usize);
            prefetch_read(alpha, idx[k + PF_DIST + 2] as usize);
            prefetch_read(stamp, idx[k + PF_DIST + 2] as usize);
            prefetch_read(alpha, idx[k + PF_DIST + 3] as usize);
            prefetch_read(stamp, idx[k + PF_DIST + 3] as usize);
        }
        step!(k);
        step!(k + 1);
        step!(k + 2);
        step!(k + 3);
        k += 4;
    }
    while k < n {
        step!(k);
        k += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::compact::CompactIndices;

    fn naive_dot(idx: &[u32], vals: &[f32], w: &[f64]) -> f64 {
        let mut acc = 0.0;
        for (&j, &v) in idx.iter().zip(vals) {
            acc += v as f64 * w[j as usize];
        }
        acc
    }

    fn stream(n: usize, seed: u64) -> (Vec<u32>, Vec<f32>, Vec<f64>) {
        let mut state = seed;
        let mut idx = Vec::new();
        let mut vals = Vec::new();
        let mut j = 0u32;
        for _ in 0..n {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
            j += 1 + (state >> 40) as u32 % 7;
            idx.push(j);
            vals.push(((state >> 20) as f32 / 2.0_f32.powi(30)) - 2.0);
        }
        let dim = j as usize + 1;
        let w: Vec<f64> = (0..dim).map(|k| (k as f64 * 0.13).sin()).collect();
        (idx, vals, w)
    }

    #[test]
    fn dot_gather_bit_identical_to_naive_all_tail_lengths() {
        // cover every `n mod 4` remainder and the sub-PF_DIST sizes
        for n in [0usize, 1, 2, 3, 4, 5, 7, 15, 16, 17, 63, 64, 100] {
            let (idx, vals, w) = stream(n, 42 + n as u64);
            let a = dot_gather(&idx, &vals, &w);
            let b = naive_dot(&idx, &vals, &w);
            assert_eq!(a.to_bits(), b.to_bits(), "n={n}");
        }
    }

    #[test]
    fn axpy_gather_bit_identical_to_naive() {
        for n in [0usize, 3, 16, 33, 100] {
            let (idx, vals, w) = stream(n, 7 + n as u64);
            let mut a = w.clone();
            let mut b = w;
            axpy_gather(&idx, &vals, 1.7, &mut a);
            for (&j, &v) in idx.iter().zip(&vals) {
                b[j as usize] += v as f64 * 1.7;
            }
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.to_bits(), y.to_bits(), "n={n}");
            }
        }
    }

    #[test]
    fn update_touch_matches_naive_stamp_loop() {
        let (idx, vals, w) = stream(50, 99);
        let dim = w.len();
        let (mut a1, mut s1, mut t1) = (vec![0.0f64; dim], vec![0u32; dim], Vec::new());
        let (mut a2, mut s2, mut t2) = (vec![0.0f64; dim], vec![0u32; dim], Vec::new());
        update_touch(&idx, &vals, 0.37, &mut a1, &mut s1, 5, &mut t1);
        for (&j, &v) in idx.iter().zip(&vals) {
            let ju = j as usize;
            a2[ju] += 0.37 * v as f64;
            if s2[ju] != 5 {
                s2[ju] = 5;
                t2.push(j);
            }
        }
        assert_eq!(t1, t2);
        assert_eq!(s1, s2);
        for (x, y) in a1.iter().zip(&a2) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    /// Delta-encode one segment's indices by the compact rules (escape
    /// blocks included) without the matrix-level qualifier, so kernel
    /// tests can exercise escape-heavy and tiny segments the qualifier
    /// would reject at matrix granularity.
    fn encode_seg(indices: &[u32]) -> Vec<u16> {
        let mut words = Vec::new();
        let mut prev = 0u32;
        for &j in indices {
            let delta = j - prev;
            if delta < ESCAPE as u32 {
                words.push(delta as u16);
            } else {
                words.push(ESCAPE);
                words.push(delta as u16);
                words.push((delta >> 16) as u16);
            }
            prev = j;
        }
        words
    }

    /// Indices for a length-`n` segment whose deltas include escapes
    /// (≥ 2¹⁶) at deterministic positions, so every `n mod 4` tail length
    /// is crossed with escape blocks at the head, middle, and tail.
    fn escape_stream(n: usize, seed: u64) -> (Vec<u32>, Vec<f32>, Vec<f64>) {
        let mut state = seed;
        let mut idx = Vec::new();
        let mut vals = Vec::new();
        let mut j = 0u32;
        for k in 0..n {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
            // escapes at the first, a middle, and the last position;
            // small deltas elsewhere
            if k == 0 || k == n / 2 || k + 1 == n {
                j += 70_000 + (state >> 50) as u32; // delta ≥ 2^16
            } else {
                j += 1 + (state >> 40) as u32 % 7;
            }
            idx.push(j);
            vals.push(((state >> 20) as f32 / 2.0_f32.powi(30)) - 2.0);
        }
        // size the gather target to the stream (≤ ~300k slots here)
        let dim = idx.last().map_or(1, |&m| m as usize + 1);
        let w: Vec<f64> = (0..dim).map(|k| (k as f64 * 0.13).sin()).collect();
        (idx, vals, w)
    }

    /// The §6.7 contract at kernel granularity: fused direct decode,
    /// decode-to-scratch, and the raw u32 gather are bit-identical for
    /// every tail length `n mod 4` (n = 0..13 and PF_DIST±1 sizes) on
    /// segments containing escape blocks at head/middle/tail.
    #[test]
    fn fused_scratch_u32_bit_identical_with_escapes_all_tails() {
        for n in [0usize, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 15, 16, 17, 40, 100] {
            let (idx, vals, w) = escape_stream(n, 1000 + n as u64);
            let words = encode_seg(&idx);
            // u32 reference
            let d_u32 = dot_gather(&idx, &vals, &w);
            // scratch arm
            let mut scratch = Vec::new();
            decode_words(&words, n, &mut scratch);
            assert_eq!(&scratch[..], &idx[..], "n={n}: decode disagreed");
            let d_scr = dot_gather(&scratch, &vals, &w);
            // fused arm
            let d_fus = dot_gather_u16(&words, n, &vals, &w);
            assert_eq!(d_u32.to_bits(), d_scr.to_bits(), "n={n}: scratch dot");
            assert_eq!(d_u32.to_bits(), d_fus.to_bits(), "n={n}: fused dot");

            let mut a_u32 = w.clone();
            let mut a_fus = w.clone();
            axpy_gather(&idx, &vals, -0.73, &mut a_u32);
            axpy_gather_u16(&words, n, &vals, -0.73, &mut a_fus);
            for (k, (x, y)) in a_u32.iter().zip(&a_fus).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "n={n}: axpy slot {k}");
            }

            let dim = w.len();
            let (mut al1, mut s1, mut t1) = (vec![0.0f64; dim], vec![0u32; dim], Vec::new());
            let (mut al2, mut s2, mut t2) = (vec![0.0f64; dim], vec![0u32; dim], Vec::new());
            update_touch(&idx, &vals, 0.41, &mut al1, &mut s1, 9, &mut t1);
            update_touch_u16(&words, n, &vals, 0.41, &mut al2, &mut s2, 9, &mut t2);
            assert_eq!(t1, t2, "n={n}: touched order");
            assert_eq!(s1, s2, "n={n}: stamps");
            for (k, (x, y)) in al1.iter().zip(&al2).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "n={n}: alpha slot {k}");
            }
        }
    }

    #[test]
    fn direct_scan_pipeline_order_and_lookahead() {
        let (idx, _, _) = stream(40, 77);
        let words = encode_seg(&idx);
        let mut s = DirectScan::new(&words, idx.len());
        // the pre-decoded lead is the first PF_DIST indices in order
        assert_eq!(s.lead(), &idx[..PF_DIST]);
        let mut got = Vec::new();
        let mut aheads = Vec::new();
        while let Some((j, ahead)) = s.next() {
            got.push(j);
            aheads.push(ahead);
        }
        assert_eq!(got, idx, "drain order must be stream order");
        // while k + PF_DIST < nnz the lookahead is exactly idx[k+PF_DIST]
        for (k, a) in aheads.iter().enumerate() {
            if k + PF_DIST < idx.len() {
                assert_eq!(*a, Some(idx[k + PF_DIST]), "k={k}");
            } else {
                assert_eq!(*a, None, "k={k}: tail must stop decoding");
            }
        }
        // segments shorter than the pipeline lead drain correctly too
        let short = &idx[..3];
        let words = encode_seg(short);
        let mut s = DirectScan::new(&words, 3);
        assert_eq!(s.lead(), short);
        let mut got = Vec::new();
        while let Some((j, ahead)) = s.next() {
            assert_eq!(ahead, None);
            got.push(j);
        }
        assert_eq!(got, short);
        // empty segment
        let mut s = DirectScan::new(&[], 0);
        assert!(s.lead().is_empty());
        assert!(s.next().is_none());
    }

    #[test]
    fn kernel_dispatch_arms_and_equivalence() {
        let (idx, vals, w) = stream(40, 5);
        let indptr = [0usize, idx.len()];
        let c = CompactIndices::build(&indptr, &idx).expect("qualifies");
        let seg16 = IndexSeg::U16 { words: c.seg_words(0), nnz: idx.len() };
        let seg32 = IndexSeg::U32(&idx);
        let fused = ScanKernel::with_threshold(usize::MAX);
        let scratchy = ScanKernel::with_threshold(0);
        assert_eq!(fused.arm(&seg16), SegArm::Direct);
        assert_eq!(scratchy.arm(&seg16), SegArm::Scratch);
        assert_eq!(ScanKernel::with_threshold(40).arm(&seg16), SegArm::Direct, "boundary is <=");
        assert_eq!(ScanKernel::with_threshold(39).arm(&seg16), SegArm::Scratch);
        assert_eq!(fused.arm(&seg32), SegArm::U32);
        let mut scratch = Vec::new();
        let want = dot_gather(&idx, &vals, &w);
        for k in [fused, scratchy, ScanKernel::from_env()] {
            assert_eq!(k.dot(seg16, &vals, &w, &mut scratch).to_bits(), want.to_bits());
            assert_eq!(k.dot(seg32, &vals, &w, &mut scratch).to_bits(), want.to_bits());
        }
        // the fused arm must never touch the scratch
        let mut virgin = Vec::new();
        fused.dot(seg16, &vals, &w, &mut virgin);
        assert_eq!(virgin.capacity(), 0, "direct arm must not allocate scratch");
    }

    #[test]
    fn resolve_borrows_u32_and_decodes_u16() {
        let (idx, _, _) = stream(40, 11);
        let mut scratch = Vec::new();
        let got = resolve(IndexSeg::U32(&idx), &mut scratch);
        assert_eq!(got, &idx[..]);
        assert!(scratch.capacity() == 0, "u32 path must not touch scratch");
        let indptr = [0usize, idx.len()];
        let c = CompactIndices::build(&indptr, &idx).expect("small deltas must qualify");
        let mut scratch = Vec::new();
        let seg = IndexSeg::U16 { words: c.seg_words(0), nnz: idx.len() };
        assert_eq!(seg.nnz(), idx.len());
        let got = resolve(seg, &mut scratch);
        assert_eq!(got, &idx[..]);
    }
}
