//! The shared scan-kernel layer (DESIGN.md §6.6): every hot sparse loop in
//! the codebase — the fast solver's fused update+notify scan, Alg 1's
//! `matvec`/`matvec_t_add`, the CSC-driven bootstrap, the coordinator's
//! scorer — routes its decode-and-gather through this module.
//!
//! Three ideas, one contract:
//!
//! * **Decode to scratch, gather from `u32`.** A compact
//!   ([`crate::sparse::compact`]) segment is first decoded into a
//!   caller-provided `u32` scratch buffer ([`resolve`]); the gather loops
//!   then run on plain `u32` indices either way. The scratch stays
//!   L1-resident (it is reused segment after segment), so DRAM index
//!   traffic is the half-width `u16` stream while the gather code — and
//!   therefore the accumulation order — is *identical* across substrates.
//!   On the `u32` substrate [`resolve`] is a zero-cost borrow.
//! * **Software prefetch.** The gather targets (`w[j]`, `α[k]`,
//!   `stamp[k]`, `v̂[i]`) are random-access into arrays far larger than
//!   cache; the index stream tells us the next addresses [`PF_DIST`]
//!   elements early, so each kernel issues explicit prefetches that far
//!   ahead ([`prefetch_read`], a portable shim over `_mm_prefetch` that
//!   compiles to nothing off x86_64). Prefetching is a pure hint: it
//!   cannot change any computed value.
//! * **Bit-identical by construction.** Every kernel accumulates in the
//!   exact serial order of the pre-existing loops (single accumulator,
//!   sequential adds — the manual 4× unrolls keep one dependency chain),
//!   so routing a call site through this module never changes its output
//!   bits (property-tested compact-vs-u32 and against the old loops'
//!   golden outputs), per the DESIGN.md §2 convention.
//!
//! Layering note: this module lives in `fw/` (it is the solver family's
//! kernel layer) but depends only on `sparse::compact` — never on the
//! matrix types or solvers — while `sparse::{csr,csc}` call *into* it.
//! That one deliberate up-reference keeps a single copy of every gather
//! loop; see DESIGN.md §6.6.

use crate::sparse::compact::{decode_words, IndexSeg};

/// Prefetch lookahead distance, in stream elements. Far enough that a
/// DRAM fetch (~100 ns) completes before the gather loop (~1–2 ns/element
/// of ALU work) arrives; near enough not to thrash L1. Tuned for the
/// paper-preset shapes; see DESIGN.md §6.6.
pub const PF_DIST: usize = 16;

#[cfg(target_arch = "x86_64")]
#[inline(always)]
fn prefetch_ptr<T>(p: *const T) {
    // SAFETY: prefetch is a non-faulting hint; the pointer is derived
    // from an in-bounds slice element and never dereferenced here.
    unsafe {
        core::arch::x86_64::_mm_prefetch::<{ core::arch::x86_64::_MM_HINT_T0 }>(p.cast::<i8>())
    }
}

#[cfg(not(target_arch = "x86_64"))]
#[inline(always)]
fn prefetch_ptr<T>(p: *const T) {
    let _ = p;
}

/// Hint the cache to load `slice[i]`; a no-op when `i` is out of bounds
/// (stream tails) or the target has no prefetch instruction.
#[inline(always)]
pub fn prefetch_read<T>(slice: &[T], i: usize) {
    if let Some(r) = slice.get(i) {
        prefetch_ptr(r);
    }
}

/// Materialize a segment's indices as `u32`: the borrowed stream itself
/// on the plain substrate, or a decode into `scratch` on the compact one.
/// `scratch` is only touched on the compact path, so passing a fresh
/// `Vec::new()` on the `u32` substrate allocates nothing.
#[inline]
pub fn resolve<'a>(seg: IndexSeg<'a>, scratch: &'a mut Vec<u32>) -> &'a [u32] {
    match seg {
        IndexSeg::U32(idx) => idx,
        IndexSeg::U16 { words, nnz } => {
            decode_words(words, nnz, scratch);
            &scratch[..]
        }
    }
}

/// `Σ_k vals[k]·w[idx[k]]` — the sparse·dense dot product behind
/// `matvec`, `row_dot`, and the CSC column sweep. Single accumulator,
/// strictly sequential adds: bit-identical to the naive loop.
#[inline]
pub fn dot_gather(idx: &[u32], vals: &[f32], w: &[f64]) -> f64 {
    debug_assert_eq!(idx.len(), vals.len());
    let n = idx.len();
    let mut acc = 0.0f64;
    let mut k = 0;
    while k + 4 <= n {
        if k + PF_DIST + 4 <= n {
            prefetch_read(w, idx[k + PF_DIST] as usize);
            prefetch_read(w, idx[k + PF_DIST + 1] as usize);
            prefetch_read(w, idx[k + PF_DIST + 2] as usize);
            prefetch_read(w, idx[k + PF_DIST + 3] as usize);
        }
        acc += vals[k] as f64 * w[idx[k] as usize];
        acc += vals[k + 1] as f64 * w[idx[k + 1] as usize];
        acc += vals[k + 2] as f64 * w[idx[k + 2] as usize];
        acc += vals[k + 3] as f64 * w[idx[k + 3] as usize];
        k += 4;
    }
    while k < n {
        acc += vals[k] as f64 * w[idx[k] as usize];
        k += 1;
    }
    acc
}

/// `out[idx[k]] += vals[k]·coef` for every k — the scattered AXPY behind
/// `matvec_t_add`. Stream order, so repeated indices accumulate exactly
/// as the naive loop does.
#[inline]
pub fn axpy_gather(idx: &[u32], vals: &[f32], coef: f64, out: &mut [f64]) {
    debug_assert_eq!(idx.len(), vals.len());
    let n = idx.len();
    let mut k = 0;
    while k + 4 <= n {
        if k + PF_DIST + 4 <= n {
            prefetch_read(out, idx[k + PF_DIST] as usize);
            prefetch_read(out, idx[k + PF_DIST + 1] as usize);
            prefetch_read(out, idx[k + PF_DIST + 2] as usize);
            prefetch_read(out, idx[k + PF_DIST + 3] as usize);
        }
        out[idx[k] as usize] += vals[k] as f64 * coef;
        out[idx[k + 1] as usize] += vals[k + 1] as f64 * coef;
        out[idx[k + 2] as usize] += vals[k + 2] as f64 * coef;
        out[idx[k + 3] as usize] += vals[k + 3] as f64 * coef;
        k += 4;
    }
    while k < n {
        out[idx[k] as usize] += vals[k] as f64 * coef;
        k += 1;
    }
}

/// The fast solver's fused row kernel (Alg 2 lines 26–28 + the line 29
/// touched-list recording): `α[k] += γ·x_ik` along one CSR row, stamping
/// each coordinate's *first* touch of the iteration into `touched` so the
/// notify drain can run afterwards on final α values. Prefetches both
/// `alpha[k]` and `stamp[k]` [`PF_DIST`] elements ahead — the two gather
/// streams this loop is bound on.
#[inline]
pub fn update_touch(
    idx: &[u32],
    vals: &[f32],
    gamma: f64,
    alpha: &mut [f64],
    stamp: &mut [u32],
    epoch: u32,
    touched: &mut Vec<u32>,
) {
    debug_assert_eq!(idx.len(), vals.len());
    let n = idx.len();
    // one element of the strictly sequential scan — the macro keeps the
    // 4× unrolled and tail loops textually identical
    macro_rules! step {
        ($k:expr) => {{
            let j = idx[$k];
            let ju = j as usize;
            alpha[ju] += gamma * vals[$k] as f64;
            if stamp[ju] != epoch {
                stamp[ju] = epoch;
                touched.push(j);
            }
        }};
    }
    let mut k = 0;
    while k + 4 <= n {
        if k + PF_DIST + 4 <= n {
            prefetch_read(alpha, idx[k + PF_DIST] as usize);
            prefetch_read(stamp, idx[k + PF_DIST] as usize);
            prefetch_read(alpha, idx[k + PF_DIST + 1] as usize);
            prefetch_read(stamp, idx[k + PF_DIST + 1] as usize);
            prefetch_read(alpha, idx[k + PF_DIST + 2] as usize);
            prefetch_read(stamp, idx[k + PF_DIST + 2] as usize);
            prefetch_read(alpha, idx[k + PF_DIST + 3] as usize);
            prefetch_read(stamp, idx[k + PF_DIST + 3] as usize);
        }
        step!(k);
        step!(k + 1);
        step!(k + 2);
        step!(k + 3);
        k += 4;
    }
    while k < n {
        step!(k);
        k += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::compact::CompactIndices;

    fn naive_dot(idx: &[u32], vals: &[f32], w: &[f64]) -> f64 {
        let mut acc = 0.0;
        for (&j, &v) in idx.iter().zip(vals) {
            acc += v as f64 * w[j as usize];
        }
        acc
    }

    fn stream(n: usize, seed: u64) -> (Vec<u32>, Vec<f32>, Vec<f64>) {
        let mut state = seed;
        let mut idx = Vec::new();
        let mut vals = Vec::new();
        let mut j = 0u32;
        for _ in 0..n {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
            j += 1 + (state >> 40) as u32 % 7;
            idx.push(j);
            vals.push(((state >> 20) as f32 / 2.0_f32.powi(30)) - 2.0);
        }
        let dim = j as usize + 1;
        let w: Vec<f64> = (0..dim).map(|k| (k as f64 * 0.13).sin()).collect();
        (idx, vals, w)
    }

    #[test]
    fn dot_gather_bit_identical_to_naive_all_tail_lengths() {
        // cover every `n mod 4` remainder and the sub-PF_DIST sizes
        for n in [0usize, 1, 2, 3, 4, 5, 7, 15, 16, 17, 63, 64, 100] {
            let (idx, vals, w) = stream(n, 42 + n as u64);
            let a = dot_gather(&idx, &vals, &w);
            let b = naive_dot(&idx, &vals, &w);
            assert_eq!(a.to_bits(), b.to_bits(), "n={n}");
        }
    }

    #[test]
    fn axpy_gather_bit_identical_to_naive() {
        for n in [0usize, 3, 16, 33, 100] {
            let (idx, vals, w) = stream(n, 7 + n as u64);
            let mut a = w.clone();
            let mut b = w;
            axpy_gather(&idx, &vals, 1.7, &mut a);
            for (&j, &v) in idx.iter().zip(&vals) {
                b[j as usize] += v as f64 * 1.7;
            }
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.to_bits(), y.to_bits(), "n={n}");
            }
        }
    }

    #[test]
    fn update_touch_matches_naive_stamp_loop() {
        let (idx, vals, w) = stream(50, 99);
        let dim = w.len();
        let (mut a1, mut s1, mut t1) = (vec![0.0f64; dim], vec![0u32; dim], Vec::new());
        let (mut a2, mut s2, mut t2) = (vec![0.0f64; dim], vec![0u32; dim], Vec::new());
        update_touch(&idx, &vals, 0.37, &mut a1, &mut s1, 5, &mut t1);
        for (&j, &v) in idx.iter().zip(&vals) {
            let ju = j as usize;
            a2[ju] += 0.37 * v as f64;
            if s2[ju] != 5 {
                s2[ju] = 5;
                t2.push(j);
            }
        }
        assert_eq!(t1, t2);
        assert_eq!(s1, s2);
        for (x, y) in a1.iter().zip(&a2) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn resolve_borrows_u32_and_decodes_u16() {
        let (idx, _, _) = stream(40, 11);
        let mut scratch = Vec::new();
        let got = resolve(IndexSeg::U32(&idx), &mut scratch);
        assert_eq!(got, &idx[..]);
        assert!(scratch.capacity() == 0, "u32 path must not touch scratch");
        let indptr = [0usize, idx.len()];
        let c = CompactIndices::build(&indptr, &idx).expect("small deltas must qualify");
        let mut scratch = Vec::new();
        let seg = IndexSeg::U16 { words: c.seg_words(0), nnz: idx.len() };
        assert_eq!(seg.nnz(), idx.len());
        let got = resolve(seg, &mut scratch);
        assert_eq!(got, &idx[..]);
    }
}
