//! Cooperative cancellation and deadlines for anytime solves (DESIGN.md §6.9).
//!
//! Frank-Wolfe is an *anytime* algorithm: after any number of update steps
//! the iterate is a valid point in the λ-ball whose suboptimality bound
//! only improves with more steps. Stopping early therefore degrades
//! gracefully — the solver returns its best-so-far weights instead of
//! failing — which is exactly the behaviour a deadline-bound serving tier
//! needs. A [`CancelToken`] carries the two stop signals (an explicit
//! cancel flag and an optional wall-clock deadline); both solvers poll it
//! once per iteration via [`crate::fw::config::FwConfig::stop_check`].
//!
//! Privacy note: stopping at iteration k means only k noisy-max /
//! exponential-mechanism selections were *released*, so the ε actually
//! spent is the k-step composition — see
//! [`crate::dp::accounting::PrivacyParams::spent_epsilon`]. The per-step
//! noise scale is still calibrated for the *planned* T, so a truncated
//! run spends strictly less than the configured ε.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Why a solve returned (`FwOutput::stopped`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    /// Ran the full iteration budget `T` (the default outcome — every
    /// pre-§6.9 run reported this implicitly).
    IterBudget,
    /// The duality-gap estimate dropped to `FwConfig::gap_tol` before the
    /// budget ran out.
    Converged,
    /// The token's wall-clock deadline passed mid-run.
    Deadline,
    /// [`CancelToken::cancel`] was called from another thread.
    Cancelled,
    /// The ingress brownout controller capped this run's iteration count
    /// below the planned budget (`FwConfig::iter_cap`, DESIGN.md §6.10).
    /// Like `Deadline`/`Cancelled` this is an anytime partial result —
    /// best-so-far weights, and `eps_spent` charging exactly the capped
    /// number of mechanism releases at the noise scale calibrated for the
    /// *planned* T.
    Brownout,
}

impl StopReason {
    pub fn name(&self) -> &'static str {
        match self {
            StopReason::IterBudget => "iter-budget",
            StopReason::Converged => "converged",
            StopReason::Deadline => "deadline",
            StopReason::Cancelled => "cancelled",
            StopReason::Brownout => "brownout",
        }
    }

    /// Did the run stop before its natural end (budget or convergence)?
    pub fn is_early(&self) -> bool {
        matches!(
            self,
            StopReason::Deadline | StopReason::Cancelled | StopReason::Brownout
        )
    }
}

#[derive(Debug)]
struct CancelInner {
    cancelled: AtomicBool,
    deadline: Option<Instant>,
}

/// Shared stop signal: an atomic cancel flag plus an optional deadline.
///
/// Cloning is cheap (an `Arc` bump) and every clone observes the same
/// flag, so the coordinator can hold one half while the worker's solver
/// polls the other. The default token is **disarmed** (`None` inner):
/// [`CancelToken::check`] is then a single `Option` discriminant test, so
/// configs that never cancel pay one predictable branch per iteration —
/// noise next to the O(S_r·S_c) iteration body.
#[derive(Clone, Debug, Default)]
pub struct CancelToken {
    inner: Option<Arc<CancelInner>>,
}

impl CancelToken {
    /// The disarmed token: never cancels, never expires.
    pub fn none() -> Self {
        Self { inner: None }
    }

    /// An armed token with no deadline — stops only via [`Self::cancel`].
    pub fn new() -> Self {
        Self {
            inner: Some(Arc::new(CancelInner {
                cancelled: AtomicBool::new(false),
                deadline: None,
            })),
        }
    }

    /// An armed token that expires at `deadline`.
    pub fn with_deadline(deadline: Instant) -> Self {
        Self {
            inner: Some(Arc::new(CancelInner {
                cancelled: AtomicBool::new(false),
                deadline: Some(deadline),
            })),
        }
    }

    /// An armed token that expires `budget` from now.
    pub fn deadline_in(budget: Duration) -> Self {
        Self::with_deadline(Instant::now() + budget)
    }

    /// Request cancellation. Every clone of this token observes it on its
    /// next [`Self::check`]. No-op on a disarmed token.
    pub fn cancel(&self) {
        if let Some(inner) = &self.inner {
            inner.cancelled.store(true, Ordering::Release);
        }
    }

    /// Is this token capable of stopping a run at all?
    pub fn is_armed(&self) -> bool {
        self.inner.is_some()
    }

    /// Poll the stop signal. `Some(reason)` means the caller should stop
    /// now; explicit cancellation wins over a simultaneous deadline (it is
    /// the more specific signal).
    #[inline]
    pub fn check(&self) -> Option<StopReason> {
        let inner = self.inner.as_deref()?;
        if inner.cancelled.load(Ordering::Acquire) {
            return Some(StopReason::Cancelled);
        }
        match inner.deadline {
            Some(d) if Instant::now() >= d => Some(StopReason::Deadline),
            _ => None,
        }
    }

    /// Has the signal already fired? Used by the scheduler to shed
    /// expired-while-queued jobs without spending any solver work.
    pub fn expired(&self) -> bool {
        self.check().is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disarmed_token_never_stops() {
        let t = CancelToken::none();
        assert!(!t.is_armed());
        assert_eq!(t.check(), None);
        t.cancel(); // no-op
        assert_eq!(t.check(), None);
        assert!(!t.expired());
    }

    #[test]
    fn cancel_is_visible_to_clones() {
        let t = CancelToken::new();
        let c = t.clone();
        assert_eq!(c.check(), None);
        t.cancel();
        assert_eq!(c.check(), Some(StopReason::Cancelled));
        assert!(c.expired());
    }

    #[test]
    fn deadline_fires_after_expiry() {
        let t = CancelToken::with_deadline(Instant::now() - Duration::from_millis(1));
        assert_eq!(t.check(), Some(StopReason::Deadline));
        let far = CancelToken::deadline_in(Duration::from_secs(3600));
        assert_eq!(far.check(), None);
    }

    #[test]
    fn cancel_wins_over_expired_deadline() {
        let t = CancelToken::with_deadline(Instant::now() - Duration::from_millis(1));
        t.cancel();
        assert_eq!(t.check(), Some(StopReason::Cancelled));
    }

    #[test]
    fn stop_reason_names() {
        for (r, n) in [
            (StopReason::IterBudget, "iter-budget"),
            (StopReason::Converged, "converged"),
            (StopReason::Deadline, "deadline"),
            (StopReason::Cancelled, "cancelled"),
            (StopReason::Brownout, "brownout"),
        ] {
            assert_eq!(r.name(), n);
        }
        assert!(StopReason::Deadline.is_early());
        assert!(StopReason::Cancelled.is_early());
        assert!(StopReason::Brownout.is_early());
        assert!(!StopReason::IterBudget.is_early());
        assert!(!StopReason::Converged.is_early());
    }
}
