//! Algorithm 2: the **fast sparse-aware Frank-Wolfe** — the paper's core
//! contribution. After a single dense first iteration, every quantity the
//! solver needs is maintained *incrementally*:
//!
//! * **Sparse `w` update** (lines 19-20): the global shrink
//!   `w ← (1−η)w` becomes one multiply on the co-scalar `w_m`
//!   (`w = w_m·ŵ`), and only coordinate `j` of `ŵ` is touched. `O(1)`.
//! * **Fused sparse `v̄`/`α`/notify maintenance** (lines 22-29): changing
//!   `w_j` perturbs `v̄_i` only for the `S_r` rows with feature `j` (one
//!   CSC column scan); each such row's gradient change `γ_i` propagates to
//!   `α` along that row's `S_c` nonzero columns (one CSR row scan). The
//!   same scan records each *first-touched* coordinate into a reusable
//!   `touched` list (epoch-stamp dedup), and the line-29 queue
//!   notifications are driven off that list afterwards — the paper's
//!   footnote-2 re-iteration without its second full CSC-column + CSR-row
//!   traversal. One pass over the gathers instead of two: `O(S_r·S_c)`
//!   touched memory once per iteration, which matters because the scan is
//!   memory-bound (see `sparse/csr.rs`). The scan itself runs through the
//!   shared [`crate::fw::scan`] kernels: compact `u16-delta` index
//!   streams when the dataset carries them (half the index traffic),
//!   software prefetch on the `α`/`stamp`/`v̂`/`q̄` gathers, and modeled
//!   byte-traffic accounting (`FwOutput::bytes_moved`, DESIGN.md §6.6).
//! * **Sparse gap maintenance** (lines 17, 21, 27): `g̃ = ⟨α, w⟩` is
//!   rescaled by `(1−η)`, bumped by the single-coordinate term, and — one
//!   step beyond the paper's `O(S_c)` line 27 — each row's contribution
//!   `γ_i·⟨X[i,:], w⟩` is exactly `γ_i·w_m·v̂_i`, already at hand: `O(1)`
//!   (documented deviation; identical arithmetic value — DESIGN.md §4.2).
//!
//! Iteration cost is therefore `selection + O(S_r·S_c)` with a *single*
//! traversal of the touched nonzeros, and selection `O(‖w*‖₀ log D)`
//! (Fibonacci heap, non-private) or `O(√D)` (BSLS, DP) — the paper's
//! headline complexities.
//!
//! Two engine-level additions on top of the paper (DESIGN.md §6):
//!
//! * **Workspaces**: [`FastFrankWolfe::run_in`] executes inside a caller
//!   -supplied [`FwWorkspace`], so repeated runs (grid sweeps, benches,
//!   the coordinator's workers) reuse every solver buffer and the
//!   selector's internal storage instead of reallocating. `run()` keeps
//!   its signature via a private per-call workspace. Reuse is bit-exact.
//! * **Parallel bootstrap**: the `O(N·S_c)` dense first iteration
//!   `α = Xᵀq̄` fans out over contiguous CSC column blocks
//!   (`CscMatrix::matvec_t_par`, disjoint output slices, no atomics),
//!   gated by [`FwConfig::threads`]. The block sums are per-column
//!   sequential either way, so any thread count produces bit-identical
//!   results.
//! * **Regularization paths**: [`FastFrankWolfe::run_path`] trains a whole
//!   λ-grid through one workspace, computing that bootstrap **once** — it
//!   is identical for every λ at fixed data — and caching it in the
//!   workspace keyed by dataset identity (DESIGN.md §6.5). Warm per-λ
//!   solves do zero `O(N·S_c)` work; [`FwOutput::bootstrap_flops`]
//!   records exactly what was skipped.

use std::time::Instant;

use crate::fw::cancel::StopReason;
use crate::fw::checkpoint::{config_fingerprint, FwCheckpoint};
use crate::fw::config::FwConfig;
use crate::fw::flops::{
    FlopCounter, ShardCosts, BYTES_F32_READ, BYTES_F64_READ, BYTES_F64_RMW,
    BYTES_U32_RMW, FLOPS_SIGMOID,
};
use crate::fw::loss::{Logistic, Loss};
use crate::fw::queue::SelectorStats;
use crate::fw::scan;
use crate::fw::sign;
use crate::fw::trace::{FwOutput, PhaseTiming, TraceRecord, WeightVector};
use crate::fw::workspace::{BootKey, Bootstrap, FwWorkspace, ShardScratch};
use crate::rng::Xoshiro256pp;
use crate::sparse::compact::IndexSeg;
use crate::sparse::sharded::{
    par_abs_argmax, GammaEntry, Shard, ShardedDataset, SELECT_PAR_MIN_D,
};
use crate::sparse::Dataset;

/// Renormalization threshold for the multiplicative scalar. With
/// `η_t = 2/(t+2)`, `w_m ≈ 6/T²` — even T = 4×10⁵ only reaches ~4e-11, so
/// this effectively never fires; it exists to make the invariant
/// unconditional.
const WM_RENORM_THRESHOLD: f64 = 1e-120;

/// Minimum *column* nnz before the sharded Phase A fans out over threads.
/// On Zipf-shaped text data most columns hold a handful of rows — thread
/// spawn would dwarf the scan — but the hot head columns (the dense/bias
/// columns Alg 2 keeps reselecting) carry thousands, and those are where
/// the row-parallel scan pays. The gate changes scheduling only: the
/// serial path runs the identical per-shard scans in shard order, and
/// Phase A is row-local (no cross-row FP reduction), so values are
/// bit-identical either way.
const FAST_COL_PAR_MIN_NNZ: u64 = 1 << 12;

/// Phase A of the sharded fast iteration (DESIGN.md §6.8): scan *this
/// shard's own* CSC column `j`, updating the shard's slices of `v̂`/`q̄`
/// (row-local — decomposition-invariant FP) and deferring each nonzero
/// gradient move as a [`GammaEntry`] (ascending local row order). The
/// order-sensitive work — the `α` scatter and `g̃` accumulation — happens
/// later in sequential Phase B, which replays the entries in ascending
/// shard order, i.e. exactly the legacy ascending-row op sequence.
/// Accounting is deliberately absent here: workers cannot share the flop
/// counter, and the per-iteration charges are analytic (they depend only
/// on segment shapes), so the solver charges them afterwards from the
/// parent's canonical streams — identical amounts to the legacy path.
#[allow(clippy::too_many_arguments)]
fn scan_shard_column(
    shard: &Shard,
    j: usize,
    vcoef: f64,
    w_m: f64,
    loss: &dyn Loss,
    kern: scan::ScanKernel,
    hat_v: &mut [f64],
    q: &mut [f64],
    scratch: &mut ShardScratch,
) {
    let ShardScratch { gammas, decode } = scratch;
    gammas.clear();
    let (col_seg, xvals) = shard.csc.col_seg(j);
    let base = shard.rows.start as u32;
    let y = &shard.labels;
    let mut scan_row = |i: usize, xij: f32, ahead: Option<u32>| {
        if let Some(ip) = ahead {
            scan::prefetch_read(hat_v, ip as usize);
            scan::prefetch_read(q, ip as usize);
        }
        // identical arithmetic to the monolithic scan — same ops, same
        // order, just indexed shard-locally
        hat_v[i] += vcoef * xij as f64;
        let v_new = w_m * hat_v[i];
        let gamma = loss.grad(v_new, y[i] as f64) - q[i];
        if gamma == 0.0 {
            return;
        }
        q[i] += gamma;
        gammas.push(GammaEntry { row: base + i as u32, gamma, v_new });
    };
    match (kern.arm(&col_seg), col_seg) {
        (scan::SegArm::Direct, IndexSeg::U16 { words, nnz }) => {
            let mut sc = scan::DirectScan::new(words, nnz);
            let mut r = 0usize;
            while let Some((i, ahead)) = sc.next() {
                scan_row(i as usize, xvals[r], ahead);
                r += 1;
            }
        }
        _ => {
            let rows = scan::resolve(col_seg, decode);
            for (r, (&i_u32, &xij)) in rows.iter().zip(xvals).enumerate() {
                scan_row(i_u32 as usize, xij, rows.get(r + scan::PF_DIST).copied());
            }
        }
    }
}

pub struct FastFrankWolfe<'a> {
    data: &'a Dataset,
    loss: Box<dyn Loss>,
    cfg: FwConfig,
}

/// Internal mutable state, exposed (crate-visible) for the equivalence
/// property tests, which verify after every step that the incrementally
/// maintained state matches a dense recompute.
pub(crate) struct FastState {
    /// `w = w_m · ŵ`
    pub hat_w: Vec<f64>,
    pub w_m: f64,
    /// `v̄_i = w_m · v̂_i = x_i · w`
    pub hat_v: Vec<f64>,
    /// cached margin gradients `q̄_i = ∂L(v_i, y_i)/∂v`
    pub q: Vec<f64>,
    /// coordinate gradients `α = Xᵀ q̄`
    pub alpha: Vec<f64>,
    /// maintained gap base `g̃ = ⟨α, w⟩`
    pub g_base: f64,
}

impl FastState {
    pub fn weights(&self) -> Vec<f64> {
        self.hat_w.iter().map(|&h| h * self.w_m).collect()
    }
}

impl<'a> FastFrankWolfe<'a> {
    pub fn new(data: &'a Dataset, cfg: FwConfig) -> Self {
        cfg.validate();
        Self { data, loss: Box::new(Logistic), cfg }
    }

    pub fn with_loss(mut self, loss: Box<dyn Loss>) -> Self {
        self.loss = loss;
        self
    }

    /// One-shot run (the public entry point). Allocates a private
    /// workspace; sweep drivers should prefer [`FastFrankWolfe::run_in`].
    pub fn run(&self) -> FwOutput {
        self.run_in(&mut FwWorkspace::new())
    }

    /// Run inside a caller-supplied workspace: all solver state (ŵ, v̂, q̄,
    /// α, the notify stamp/touched scratch, and the selector's internal
    /// storage) is drawn from — and returned to — `ws`, so repeated runs
    /// allocate nothing beyond the escaping output. A dirty workspace is
    /// bit-exactly equivalent to a fresh one (property-tested).
    pub fn run_in(&self, ws: &mut FwWorkspace) -> FwOutput {
        self.run_in_with_observer(ws, |_, _| {})
    }

    /// Like [`Self::run_in`], but with the dense bootstrap in `Shared`
    /// mode: eligible for the workspace cache and, when the workspace is
    /// connected to an ingress [`crate::fw::workspace::BootHub`], for
    /// cross-worker coalescing (DESIGN.md §6.10). Output is bit-identical
    /// to `run_in` except that a cache/hub hit moves the bootstrap cost
    /// out of `flops`/`bootstrap_flops` (the §6.5 invariant).
    pub(crate) fn run_in_shared(&self, ws: &mut FwWorkspace) -> FwOutput {
        self.run_core(ws, self.cfg.lambda, Bootstrap::Shared, |_, _| {})
    }

    /// Train an entire regularization path — one run per λ in `lambdas`,
    /// everything else taken from the solver's config (whose own `lambda`
    /// is ignored) — sharing the dense bootstrap `α = Xᵀq̄` across the
    /// whole grid through the workspace's [`BootKey`]-keyed cache. The
    /// first λ (on a workspace that has not seen this dataset) computes
    /// and caches it; every later λ copies it back in `O(N+D)`, so warm
    /// per-λ solves do zero `O(N·S_c)` bootstrap work and zero
    /// solver-state allocation ([`FwOutput::bootstrap_flops`] proves it).
    /// Each output is bit-identical to an independent
    /// [`FastFrankWolfe::run_in`] at that λ, except that `flops` omits
    /// exactly the skipped bootstrap work (property-tested).
    pub fn run_path(&self, lambdas: &[f64], ws: &mut FwWorkspace) -> Vec<FwOutput> {
        lambdas
            .iter()
            .map(|&lam| {
                assert!(lam > 0.0, "path lambda must be positive");
                self.run_core(ws, lam, Bootstrap::Shared, |_, _| {})
            })
            .collect()
    }

    /// Run, invoking `observe(t, &state)` after every iteration — the hook
    /// the equivalence property tests use. Zero-cost when the closure is
    /// empty.
    pub(crate) fn run_with_observer(
        &self,
        observe: impl FnMut(usize, &FastState),
    ) -> FwOutput {
        self.run_in_with_observer(&mut FwWorkspace::new(), observe)
    }

    pub(crate) fn run_in_with_observer(
        &self,
        ws: &mut FwWorkspace,
        observe: impl FnMut(usize, &FastState),
    ) -> FwOutput {
        self.run_core(ws, self.cfg.lambda, Bootstrap::PerRun, observe)
    }

    /// Package the current solver state as a crash-consistent snapshot
    /// (DESIGN.md §6.11). O(t): the sparse iterate is collected from the
    /// selection history, never from the dense `ŵ`.
    #[allow(clippy::too_many_arguments)]
    fn snapshot(
        &self,
        t: usize,
        st: &FastState,
        gap: f64,
        rng: &Xoshiro256pp,
        flops: &FlopCounter,
        stats: SelectorStats,
        history: &[(u32, i8)],
        trace: &[TraceRecord],
    ) -> FwCheckpoint {
        FwCheckpoint {
            fingerprint: config_fingerprint(&self.cfg),
            dataset_fp: self.data.fingerprint(),
            seed: self.cfg.seed,
            t_planned: self.cfg.iters as u64,
            iter: t as u64,
            rng: rng.state(),
            flops: flops.to_words(),
            stats,
            gap,
            history: history.to_vec(),
            weights: FwCheckpoint::sparse_weights(history, |j| st.hat_w[j] * st.w_m),
            trace: trace.to_vec(),
        }
    }

    fn run_core(
        &self,
        ws: &mut FwWorkspace,
        lam: f64,
        boot: Bootstrap,
        mut observe: impl FnMut(usize, &FastState),
    ) -> FwOutput {
        // The sharded engine (DESIGN.md §6.8) is a separate body rather
        // than a parameterized one: the legacy monolithic path below stays
        // byte-for-byte what it was, and the property tests prove the two
        // bodies produce bit-identical output at every shard count.
        if let Some(requested) = self.cfg.effective_shards() {
            return self.run_core_sharded(ws, lam, boot, observe, requested);
        }
        let start = Instant::now();
        let csr = &self.data.csr;
        let csc = &self.data.csc;
        let y = &self.data.labels;
        let n = csr.n_rows();
        let d = csr.n_cols();
        let t_total = self.cfg.iters;
        let lip = self.cfg.lipschitz.unwrap_or_else(|| self.loss.lipschitz());

        let (exp_scale, nm_scale) = match self.cfg.privacy {
            Some(p) => (p.exp_mech_scale(t_total, lip), p.noisy_max_scale(t_total, lip)),
            None => (0.0, 0.0),
        };
        let mut selector = ws.take_selector(self.cfg.selector, d, exp_scale, nm_scale);
        let mut rng = Xoshiro256pp::seeded(self.cfg.seed);
        let mut flops = FlopCounter::new();
        // the segment-adaptive dispatcher (§6.7): one threshold for every
        // scan of this run, so the recorded direct/scratch split always
        // matches the kernel arms that actually executed
        let kern = self.cfg.scan_kernel();

        // ---- lines 8-14: dense first iteration --------------------------
        // w = 0 ⇒ v̄ = 0, q̄_i = ∇L(0, y_i), α = Xᵀq̄, g̃ = ⟨α, 0⟩ = 0.
        let mut st = FastState {
            hat_w: ws.take_f64(d, 0.0),
            w_m: 1.0,
            hat_v: ws.take_f64(n, 0.0),
            q: ws.take_f64(n, 0.0),
            alpha: ws.take_f64(d, 0.0),
            g_base: 0.0,
        };
        let boot_key = BootKey::of(self.data, self.loss.name());
        let cached = boot == Bootstrap::Shared
            && ws.bootstrap_attach(&boot_key, &mut st.q, &mut st.alpha, &self.cfg.cancel);
        if !cached {
            // in-bootstrap fault hook (tests): fires while this run holds
            // any coalescing-hub leadership lease it just claimed
            self.cfg.fault.on_bootstrap();
            for (qi, &yi) in st.q.iter_mut().zip(y.iter()) {
                *qi = self.loss.grad(0.0, yi as f64);
            }
            flops.add_boot(n as u64 * FLOPS_SIGMOID);
            // label reads + q̄ writes
            flops.add_boot_bytes((BYTES_F32_READ + BYTES_F64_READ) * n as u64);
            // The one O(N·S_c) pass of the whole run: column-block parallel,
            // bit-identical to the serial CSR-driven product (see
            // `CscMatrix::matvec_t_par`, which also owns the PAR_MIN_NNZ
            // serial-fallback gate — tiny problems never pay thread-spawn
            // overhead regardless of the requested count).
            let boot_threads = if self.cfg.threads == 0 {
                crate::sparse::auto_threads(csr.nnz())
            } else {
                self.cfg.threads
            };
            csc.matvec_t_par_scan(&st.q, &mut st.alpha, boot_threads, kern);
            flops.add_boot(2 * csr.nnz() as u64);
            // full CSC sweep: index + value streams, q̄ gathers, α writes
            flops.add_boot_bytes(
                csc.index_bytes_total()
                    + (BYTES_F32_READ + BYTES_F64_READ) * csr.nnz() as u64
                    + BYTES_F64_READ * d as u64,
            );
            if boot == Bootstrap::Shared {
                ws.bootstrap_put(boot_key, &st.q, &st.alpha);
            }
        }
        selector.init(&st.alpha, &mut flops);

        // §6.11 durability/resume plumbing. A resume replays the recorded
        // selections (t ≤ replay_to) to rebuild the incremental state,
        // then restores the recorded RNG/counters at the replay→live
        // boundary — see fw/checkpoint.rs for the contract.
        let resume = self.cfg.resume.as_deref();
        if let Some(ck) = resume {
            ck.validate_for(&self.cfg, self.data.fingerprint());
        }
        let replay_to = resume.map_or(0, |ck| ck.replay_to());
        let durability = self.cfg.durability.as_deref();
        let mut history: Vec<(u32, i8)> =
            resume.map(|ck| ck.history.clone()).unwrap_or_default();
        // DP mechanisms and the pure argmax skip `select` during replay
        // (the recorded coordinate stands in; the RNG position comes back
        // at the boundary); heap selectors re-run `select` live — it is
        // deterministic, uses no randomness, and pops/reinserts are how
        // their internal structure gets rebuilt.
        let replay_skip_select =
            self.cfg.selector.is_private() || selector.supports_precomputed();
        let mut restored = false;

        let mut trace = Vec::new();
        let mut gap = f64::NAN;
        // §Perf: first-touch dedup for the fused update+notify scan — rows
        // sharing popular columns would otherwise notify the same
        // coordinate once per row (the paper's "naive re-iteration",
        // footnote 2). One u32 epoch per coordinate, cleared implicitly by
        // the epoch bump; `touched` collects each deduped coordinate so
        // notifications can fire *after* its α value is final.
        let mut stamp = ws.take_u32(d, 0);
        let mut epoch = 0u32;
        let mut touched = ws.take_u32_scratch();
        // decode scratch for the compact u16-delta substrate (DESIGN.md
        // §6.6): the column's row indices and each row's column indices
        // are decoded into these before the gather loops. Pooled like
        // every other buffer; untouched on the u32 substrate.
        let mut col_scratch = ws.take_u32_scratch();
        let mut row_scratch = ws.take_u32_scratch();

        // Phase timers (set DPFW_PHASE_TIMING=1): where iteration time
        // goes — selection vs the fused sparse scan vs draining the
        // touched-list into the queue. The §Perf pass drives its decisions
        // off this breakdown, which lands structured on
        // `FwOutput::phase` (and from there in the bench JSON).
        // Pre-fusion, `notify` was a second traversal of the same nonzeros
        // and cost about as much as `update`; it is now the O(touched)
        // drain only.
        let timing = std::env::var_os("DPFW_PHASE_TIMING").is_some();
        let (mut ns_select, mut ns_update, mut ns_notify) = (0u128, 0u128, 0u128);

        // §6.9 anytime contract: the stop poll sits *before* the t-th
        // selection, so a stop at t means exactly t−1 mechanism releases
        // happened — `iters_done` (and the ε charge) stays exact.
        let mut stopped = StopReason::IterBudget;
        let mut iters_done = t_total.saturating_sub(1);
        for t in 1..t_total {
            let replaying = t <= replay_to;
            if !replaying {
                if !restored {
                    if let Some(ck) = resume {
                        ck.restore_into(
                            &mut rng,
                            &mut flops,
                            &mut *selector,
                            &mut gap,
                            &mut trace,
                        );
                    }
                    restored = true;
                }
                if let Some(reason) = self.cfg.stop_check(t) {
                    stopped = reason;
                    iters_done = t - 1;
                    break;
                }
            }
            // ---- line 15: selection -------------------------------------
            let p0 = timing.then(Instant::now);
            let j = if replaying {
                let jr = history[t - 1].0 as usize;
                if replay_skip_select {
                    jr
                } else {
                    let jl = selector.select(&st.alpha, &mut rng, &mut flops);
                    debug_assert_eq!(jl, jr, "replay diverged at t={t}");
                    jl
                }
            } else {
                selector.select(&st.alpha, &mut rng, &mut flops)
            };
            if let Some(p) = p0 {
                ns_select += p.elapsed().as_nanos();
            }

            // ---- lines 16-18: direction scalar and gap ------------------
            let s = -lam * sign(st.alpha[j]); // d̃
            gap = st.g_base - s * st.alpha[j]; // g_t = ⟨α,w⟩ + λ|α_j|
            let eta = 2.0 / (t as f64 + 2.0);
            flops.add(6);
            if !replaying && durability.is_some() {
                history.push((j as u32, if s >= 0.0 { 1 } else { -1 }));
            }

            // ---- lines 19-21: O(1) weight & gap updates -----------------
            let step = eta * s;
            st.w_m *= 1.0 - eta;
            // loop-invariant: η·s/w_m, hoisted out of the row scan below
            let vcoef = step / st.w_m;
            st.hat_w[j] += vcoef;
            st.g_base = (1.0 - eta) * st.g_base + step * st.alpha[j];
            flops.add(8);

            // ---- lines 22-29 fused: one scan updates v̄/α/g̃ AND records
            // the first touch of every perturbed coordinate ---------------
            let p0 = timing.then(Instant::now);
            epoch = epoch.wrapping_add(1);
            if epoch == 0 {
                stamp.fill(0);
                epoch = 1;
            }
            touched.clear();
            let (col_seg, xvals) = csc.col_seg(j);
            let col_nnz = xvals.len() as u64;
            // §6.6 traffic model — column scan: index + value streams,
            // then per row a v̂ read-modify-write, a q̄ read, a label read.
            flops.add_bytes(
                col_seg.index_bytes()
                    + (2 * BYTES_F32_READ + BYTES_F64_RMW + BYTES_F64_READ) * col_nnz,
            );
            flops.count_seg(kern.arm(&col_seg), col_nnz);
            {
                // One row of the column scan, shared verbatim by both
                // dispatcher arms below. `ahead` is the row index the
                // decode/lookahead cursor just produced PF_DIST rows out:
                // start its v̂/q̄ cache fills now to hide the gather
                // latency.
                let mut scan_row = |i: usize, xij: f32, ahead: Option<u32>| {
                    if let Some(ip) = ahead {
                        scan::prefetch_read(&st.hat_v, ip as usize);
                        scan::prefetch_read(&st.q, ip as usize);
                    }
                    // v̂_i += η·s·X[i,j]/w_m   (so v_i = w_m·v̂_i is exact)
                    st.hat_v[i] += vcoef * xij as f64;
                    let v_new = st.w_m * st.hat_v[i];
                    let gamma = self.loss.grad(v_new, y[i] as f64) - st.q[i];
                    flops.add(6 + FLOPS_SIGMOID);
                    if gamma == 0.0 {
                        return;
                    }
                    st.q[i] += gamma;
                    // α += γ · X[i,:]; the kernel stamps coordinates whose
                    // α changes this iteration (rows with γ = 0 leave α —
                    // and hence the queue — untouched, so skipping them
                    // here is exactly the old second-pass behaviour:
                    // notify was a no-op for unchanged values).
                    let (row_seg, rvals) = csr.row_seg(i);
                    let row_nnz = rvals.len() as u64;
                    // q̄ write-back + row streams + per entry an α rmw and
                    // a stamp rmw
                    flops.add_bytes(
                        BYTES_F64_READ
                            + row_seg.index_bytes()
                            + (BYTES_F32_READ + BYTES_F64_RMW + BYTES_U32_RMW) * row_nnz,
                    );
                    flops.count_seg(kern.arm(&row_seg), row_nnz);
                    kern.update_touch(
                        row_seg,
                        rvals,
                        gamma,
                        &mut st.alpha,
                        &mut stamp,
                        epoch,
                        &mut touched,
                        &mut row_scratch,
                    );
                    flops.add(2 * row_nnz + 1);
                    // g̃ += γ·⟨X[i,:], w⟩ = γ·v_i  (see module docs)
                    st.g_base += gamma * v_new;
                    flops.add(2);
                };
                match (kern.arm(&col_seg), col_seg) {
                    // short compact column: fused direct decode — the
                    // two-cursor pipeline feeds rows (and their prefetch
                    // lookahead) straight off the u16 word stream
                    (scan::SegArm::Direct, IndexSeg::U16 { words, nnz }) => {
                        let mut sc = scan::DirectScan::new(words, nnz);
                        let mut r = 0usize;
                        while let Some((i, ahead)) = sc.next() {
                            scan_row(i as usize, xvals[r], ahead);
                            r += 1;
                        }
                    }
                    // long compact column (decode to L1 scratch) or u32:
                    // gather from the resolved slice with slice lookahead
                    _ => {
                        let rows = scan::resolve(col_seg, &mut col_scratch);
                        for (r, (&i_u32, &xij)) in rows.iter().zip(xvals).enumerate() {
                            scan_row(i_u32 as usize, xij, rows.get(r + scan::PF_DIST).copied());
                        }
                    }
                }
            }
            if let Some(p) = p0 {
                ns_update += p.elapsed().as_nanos();
            }

            // ---- line 29: drain the touched-list into the queue, with
            // final α values (no re-traversal of the matrix) --------------
            let p0 = timing.then(Instant::now);
            for &k in touched.iter() {
                selector.notify(k as usize, st.alpha[k as usize], &mut flops);
            }
            // touched-list reads + the α re-reads handed to the selector
            flops.add_bytes((4 + BYTES_F64_READ) * touched.len() as u64);
            if let Some(p) = p0 {
                ns_notify += p.elapsed().as_nanos();
            }

            // ---- guard: renormalize w_m (never fires at paper scales) ---
            if st.w_m.abs() < WM_RENORM_THRESHOLD {
                for h in st.hat_w.iter_mut() {
                    *h *= st.w_m;
                }
                for v in st.hat_v.iter_mut() {
                    *v *= st.w_m;
                }
                st.w_m = 1.0;
            }

            if !replaying && self.cfg.trace_every > 0 && t % self.cfg.trace_every == 0 {
                trace.push(TraceRecord {
                    iter: t,
                    gap,
                    flops: flops.total(),
                    bytes: flops.bytes(),
                    pops: selector.stats().pops,
                    selected: j,
                    wall_ns: start.elapsed().as_nanos(),
                });
            }
            // §6.11 cadence: charge the ledger ahead of the releases it
            // covers, then persist the snapshot (either order is
            // crash-safe — see dp/ledger.rs on max-merge + seed-pinned
            // replay — but ledger-first keeps the write-ahead reading).
            if !replaying {
                if let Some(dur) = durability {
                    if dur.should_checkpoint(t) {
                        if let Some(pp) = &self.cfg.privacy {
                            dur.charge(
                                self.data.fingerprint(),
                                t_total,
                                t,
                                pp.spent_epsilon(t_total, t),
                            );
                        }
                        dur.persist(&self.snapshot(
                            t,
                            &st,
                            gap,
                            &rng,
                            &flops,
                            selector.stats(),
                            &history,
                            &trace,
                        ));
                    }
                }
            }
            observe(t, &st);
            if !replaying && self.cfg.gap_converged(gap) {
                stopped = StopReason::Converged;
                iters_done = t;
                break;
            }
        }

        // §6.11: a resume whose every iteration was replay (checkpoint at
        // the final update step) never crossed the boundary in-loop —
        // restore before output assembly so the reported counters are the
        // logical uninterrupted trajectory's.
        if let Some(ck) = resume.filter(|_| !restored) {
            ck.restore_into(&mut rng, &mut flops, &mut *selector, &mut gap, &mut trace);
        }
        // §6.11: final ledger record, written ahead of this run's results
        // being released to the caller; then a resume point at
        // interruption stops (a natural finish needs none).
        if let Some(dur) = durability {
            if let Some(pp) = &self.cfg.privacy {
                dur.charge(
                    self.data.fingerprint(),
                    t_total,
                    iters_done,
                    pp.spent_epsilon(t_total, iters_done),
                );
            }
            if iters_done > 0
                && matches!(
                    stopped,
                    StopReason::Deadline | StopReason::Cancelled | StopReason::Brownout
                )
            {
                dur.persist(&self.snapshot(
                    iters_done,
                    &st,
                    gap,
                    &rng,
                    &flops,
                    selector.stats(),
                    &history,
                    &trace,
                ));
            }
        }

        let wall_ms = start.elapsed().as_secs_f64() * 1e3;
        if timing {
            let tot = start.elapsed().as_nanos().max(1) as f64;
            eprintln!(
                "[phase-timing] select {:.1}% update+touch(fused) {:.1}% \
                 notify-drain {:.1}% other {:.1}% (total {:.1} ms, {} iters)",
                100.0 * ns_select as f64 / tot,
                100.0 * ns_update as f64 / tot,
                100.0 * ns_notify as f64 / tot,
                100.0 * (tot - (ns_select + ns_update + ns_notify) as f64) / tot,
                tot / 1e6,
                iters_done
            );
        }
        trace.push(TraceRecord {
            iter: iters_done,
            gap,
            flops: flops.total(),
            bytes: flops.bytes(),
            pops: selector.stats().pops,
            selected: usize::MAX,
            wall_ns: start.elapsed().as_nanos(),
        });
        let out = FwOutput {
            weights: WeightVector(st.weights()),
            final_gap: gap,
            flops: flops.total(),
            bootstrap_flops: flops.bootstrap(),
            bytes_moved: flops.bytes(),
            bootstrap_bytes: flops.bootstrap_bytes(),
            scratch_bytes: flops.scratch_bytes(),
            direct_segments: flops.direct_segments(),
            scratch_segments: flops.scratch_segments(),
            wall_ms,
            phase: timing.then(|| PhaseTiming {
                select_ns: ns_select as u64,
                update_ns: ns_update as u64,
                notify_ns: ns_notify as u64,
            }),
            selector_stats: selector.stats(),
            trace,
            iters_run: iters_done,
            stopped,
            eps_spent: self
                .cfg
                .privacy
                .map(|pp| pp.spent_epsilon(t_total, iters_done)),
            effective_threads: self.cfg.effective_threads(),
            effective_shards: 0,
            shard_flops: Vec::new(),
            shard_bytes: Vec::new(),
        };
        // ---- return every buffer to the workspace for the next run -----
        ws.recycle_f64(st.hat_w);
        ws.recycle_f64(st.hat_v);
        ws.recycle_f64(st.q);
        ws.recycle_f64(st.alpha);
        ws.recycle_u32(stamp);
        ws.recycle_u32(touched);
        ws.recycle_u32(col_scratch);
        ws.recycle_u32(row_scratch);
        ws.recycle_selector(selector, d, exp_scale, nm_scale);
        out
    }

    /// The row-sharded engine (DESIGN.md §6.8). Each iteration splits
    /// into:
    ///
    /// * **Phase A** (shard-parallel above [`FAST_COL_PAR_MIN_NNZ`]):
    ///   every shard scans *its own* CSC column `j`, updating its
    ///   disjoint `v̂`/`q̄` slices and deferring `(row, γ, v)` entries —
    ///   all row-local arithmetic, so any schedule computes the same
    ///   bits.
    /// * **Phase B** (sequential): the deferred entries replay in
    ///   ascending shard order — which, shards being contiguous ascending
    ///   row ranges, *is* the legacy ascending-row order — through the
    ///   same `update_touch` kernel, so the order-sensitive `α`/`g̃` sums
    ///   keep the exact legacy FP op sequence.
    /// * **Selection**: selectors that declare
    ///   `supports_precomputed` (the pure argmax) go through the
    ///   tree-reduced parallel argmax — exactly associative, hence
    ///   bit-identical — and commit the choice with `select`'s own
    ///   accounting; everything else (DP mechanisms, heaps) stays on the
    ///   sequential `select` path with the global RNG stream.
    ///
    /// All global charges are made from the *parent's* canonical streams
    /// in the legacy amounts, so trajectory, flops, and modeled bytes are
    /// bit-identical to the monolithic path for any shard count and any
    /// thread count (property-tested). Per-shard attribution goes to the
    /// separate [`ShardCosts`] ledger.
    fn run_core_sharded(
        &self,
        ws: &mut FwWorkspace,
        lam: f64,
        boot: Bootstrap,
        mut observe: impl FnMut(usize, &FastState),
        requested: usize,
    ) -> FwOutput {
        let start = Instant::now();
        let csr = &self.data.csr;
        let csc = &self.data.csc;
        let y = &self.data.labels;
        let n = csr.n_rows();
        let d = csr.n_cols();
        let t_total = self.cfg.iters;
        let lip = self.cfg.lipschitz.unwrap_or_else(|| self.loss.lipschitz());
        let eff_threads = self.cfg.effective_threads();

        // the sharded substrate: cached in the workspace (building is
        // O(nnz) — a path over K λs must not pay it K times)
        let sharded = ws
            .take_sharded(self.data, requested)
            .unwrap_or_else(|| ShardedDataset::build(self.data, requested));
        let p = sharded.n_shards();
        let mut shard_scratch = ws.take_shard_scratch(p);
        let mut shard_costs = ShardCosts::new(p);

        let (exp_scale, nm_scale) = match self.cfg.privacy {
            Some(pp) => {
                (pp.exp_mech_scale(t_total, lip), pp.noisy_max_scale(t_total, lip))
            }
            None => (0.0, 0.0),
        };
        let mut selector = ws.take_selector(self.cfg.selector, d, exp_scale, nm_scale);
        let mut rng = Xoshiro256pp::seeded(self.cfg.seed);
        let mut flops = FlopCounter::new();
        let kern = self.cfg.scan_kernel();

        // ---- lines 8-14: dense first iteration --------------------------
        let mut st = FastState {
            hat_w: ws.take_f64(d, 0.0),
            w_m: 1.0,
            hat_v: ws.take_f64(n, 0.0),
            q: ws.take_f64(n, 0.0),
            alpha: ws.take_f64(d, 0.0),
            g_base: 0.0,
        };
        let boot_key = BootKey::of(self.data, self.loss.name());
        let cached = boot == Bootstrap::Shared
            && ws.bootstrap_attach(&boot_key, &mut st.q, &mut st.alpha, &self.cfg.cancel);
        if !cached {
            self.cfg.fault.on_bootstrap();
            // q̄ at w = 0, computed per shard over disjoint q̄/label
            // slices — row-local, hence bit-identical to the monolithic
            // sweep on any schedule. Parallel only when the row count is
            // worth the spawns.
            if eff_threads > 1 && p > 1 && n >= crate::sparse::PAR_MIN_NNZ {
                std::thread::scope(|scope| {
                    let mut rest = st.q.as_mut_slice();
                    let loss = &*self.loss;
                    for s in sharded.shards() {
                        let (q_s, tail) =
                            std::mem::take(&mut rest).split_at_mut(s.n_rows());
                        rest = tail;
                        scope.spawn(move || {
                            for (qi, &yi) in q_s.iter_mut().zip(s.labels.iter()) {
                                *qi = loss.grad(0.0, yi as f64);
                            }
                        });
                    }
                });
            } else {
                for (qi, &yi) in st.q.iter_mut().zip(y.iter()) {
                    *qi = self.loss.grad(0.0, yi as f64);
                }
            }
            flops.add_boot(n as u64 * FLOPS_SIGMOID);
            flops.add_boot_bytes((BYTES_F32_READ + BYTES_F64_READ) * n as u64);
            for (si, s) in sharded.shards().iter().enumerate() {
                shard_costs.add(si, s.n_rows() as u64 * FLOPS_SIGMOID);
                shard_costs
                    .add_bytes(si, (BYTES_F32_READ + BYTES_F64_READ) * s.n_rows() as u64);
            }
            // α = Xᵀq̄ through the parent's column-partitioned sweep —
            // per-column sequential sums, so bit-identical to the legacy
            // bootstrap at any thread count, and charged identically. (A
            // row-sharded Σₛ Xₛᵀq̄ₛ would regroup each column's FP sum by
            // shard boundary — the one reduction order sharding must NOT
            // change.)
            let boot_threads = if self.cfg.threads == 0 {
                crate::sparse::auto_threads(csr.nnz())
            } else {
                self.cfg.threads
            };
            csc.matvec_t_par_scan(&st.q, &mut st.alpha, boot_threads, kern);
            flops.add_boot(2 * csr.nnz() as u64);
            flops.add_boot_bytes(
                csc.index_bytes_total()
                    + (BYTES_F32_READ + BYTES_F64_READ) * csr.nnz() as u64
                    + BYTES_F64_READ * d as u64,
            );
            if boot == Bootstrap::Shared {
                ws.bootstrap_put(boot_key, &st.q, &st.alpha);
            }
        }
        selector.init(&st.alpha, &mut flops);

        // §6.11 durability/resume plumbing — same contract as the legacy
        // body (the two engines are bit-identical, so a checkpoint written
        // by either resumes under either, at any shard count).
        let resume = self.cfg.resume.as_deref();
        if let Some(ck) = resume {
            ck.validate_for(&self.cfg, self.data.fingerprint());
        }
        let replay_to = resume.map_or(0, |ck| ck.replay_to());
        let durability = self.cfg.durability.as_deref();
        let mut history: Vec<(u32, i8)> =
            resume.map(|ck| ck.history.clone()).unwrap_or_default();
        let replay_skip_select =
            self.cfg.selector.is_private() || selector.supports_precomputed();
        let mut restored = false;

        let mut trace = Vec::new();
        let mut gap = f64::NAN;
        let mut stamp = ws.take_u32(d, 0);
        let mut epoch = 0u32;
        let mut touched = ws.take_u32_scratch();
        let mut row_scratch = ws.take_u32_scratch();
        let use_tree_select = selector.supports_precomputed();

        let timing = std::env::var_os("DPFW_PHASE_TIMING").is_some();
        let (mut ns_select, mut ns_update, mut ns_notify) = (0u128, 0u128, 0u128);

        // §6.9: same stop-poll placement as the legacy body — before the
        // t-th selection, so the release count (and ε charge) is exact.
        let mut stopped = StopReason::IterBudget;
        let mut iters_done = t_total.saturating_sub(1);
        for t in 1..t_total {
            let replaying = t <= replay_to;
            if !replaying {
                if !restored {
                    if let Some(ck) = resume {
                        ck.restore_into(
                            &mut rng,
                            &mut flops,
                            &mut *selector,
                            &mut gap,
                            &mut trace,
                        );
                    }
                    restored = true;
                }
                if let Some(reason) = self.cfg.stop_check(t) {
                    stopped = reason;
                    iters_done = t - 1;
                    break;
                }
            }
            // ---- line 15: selection -------------------------------------
            let p0 = timing.then(Instant::now);
            let j = if replaying {
                let jr = history[t - 1].0 as usize;
                if replay_skip_select {
                    jr
                } else {
                    let jl = selector.select(&st.alpha, &mut rng, &mut flops);
                    debug_assert_eq!(jl, jr, "replay diverged at t={t}");
                    jl
                }
            } else if use_tree_select && eff_threads > 1 && d >= SELECT_PAR_MIN_D {
                // block partials + fixed-shape tree reduction: exactly
                // associative, so bit-identical to the serial scan
                let j = par_abs_argmax(&st.alpha, eff_threads, eff_threads);
                selector.commit_precomputed(j, st.alpha.len(), &mut flops);
                j
            } else {
                selector.select(&st.alpha, &mut rng, &mut flops)
            };
            if let Some(pt) = p0 {
                ns_select += pt.elapsed().as_nanos();
            }

            // ---- lines 16-18: direction scalar and gap ------------------
            let s = -lam * sign(st.alpha[j]);
            gap = st.g_base - s * st.alpha[j];
            let eta = 2.0 / (t as f64 + 2.0);
            flops.add(6);
            if !replaying && durability.is_some() {
                history.push((j as u32, if s >= 0.0 { 1 } else { -1 }));
            }

            // ---- lines 19-21: O(1) weight & gap updates -----------------
            let step = eta * s;
            st.w_m *= 1.0 - eta;
            let vcoef = step / st.w_m;
            st.hat_w[j] += vcoef;
            st.g_base = (1.0 - eta) * st.g_base + step * st.alpha[j];
            flops.add(8);

            // ---- Phase A: per-shard v̂/q̄ updates + γ collection ---------
            let p0 = timing.then(Instant::now);
            epoch = epoch.wrapping_add(1);
            if epoch == 0 {
                stamp.fill(0);
                epoch = 1;
            }
            touched.clear();
            let (col_seg, xvals) = csc.col_seg(j);
            let col_nnz = xvals.len() as u64;
            let w_m = st.w_m;
            if eff_threads > 1 && p > 1 && col_nnz >= FAST_COL_PAR_MIN_NNZ {
                std::thread::scope(|scope| {
                    let mut hv = st.hat_v.as_mut_slice();
                    let mut qq = st.q.as_mut_slice();
                    let loss = &*self.loss;
                    for (s, scr) in sharded.shards().iter().zip(shard_scratch.iter_mut())
                    {
                        let (hv_s, hv_rest) =
                            std::mem::take(&mut hv).split_at_mut(s.n_rows());
                        let (q_s, q_rest) =
                            std::mem::take(&mut qq).split_at_mut(s.n_rows());
                        hv = hv_rest;
                        qq = q_rest;
                        scope.spawn(move || {
                            scan_shard_column(s, j, vcoef, w_m, loss, kern, hv_s, q_s, scr)
                        });
                    }
                });
            } else {
                for (s, scr) in sharded.shards().iter().zip(shard_scratch.iter_mut()) {
                    scan_shard_column(
                        s,
                        j,
                        vcoef,
                        w_m,
                        &*self.loss,
                        kern,
                        &mut st.hat_v[s.rows.clone()],
                        &mut st.q[s.rows.clone()],
                        scr,
                    );
                }
            }
            // Phase A charges, from the *parent's* canonical column
            // streams — the legacy amounts exactly (the per-row grad
            // evals are bulk-charged: integer adds commute, so the
            // iteration total is unchanged). Per-shard attribution mirrors
            // the nnz-proportional part of the model.
            flops.add_bytes(
                col_seg.index_bytes()
                    + (2 * BYTES_F32_READ + BYTES_F64_RMW + BYTES_F64_READ) * col_nnz,
            );
            flops.count_seg(kern.arm(&col_seg), col_nnz);
            flops.add((6 + FLOPS_SIGMOID) * col_nnz);
            for (si, s) in sharded.shards().iter().enumerate() {
                let snnz = s.csc.col_nnz(j) as u64;
                if snnz > 0 {
                    shard_costs.add(si, (6 + FLOPS_SIGMOID) * snnz);
                    shard_costs.add_bytes(
                        si,
                        (2 * BYTES_F32_READ + BYTES_F64_RMW + BYTES_F64_READ) * snnz,
                    );
                }
            }

            // ---- Phase B: sequential replay in ascending shard order —
            // the legacy ascending-row α-scatter/g̃ op sequence ------------
            for (si, scr) in shard_scratch.iter().enumerate() {
                for e in scr.gammas.iter() {
                    let i = e.row as usize;
                    let (row_seg, rvals) = csr.row_seg(i);
                    let row_nnz = rvals.len() as u64;
                    flops.add_bytes(
                        BYTES_F64_READ
                            + row_seg.index_bytes()
                            + (BYTES_F32_READ + BYTES_F64_RMW + BYTES_U32_RMW) * row_nnz,
                    );
                    flops.count_seg(kern.arm(&row_seg), row_nnz);
                    kern.update_touch(
                        row_seg,
                        rvals,
                        e.gamma,
                        &mut st.alpha,
                        &mut stamp,
                        epoch,
                        &mut touched,
                        &mut row_scratch,
                    );
                    flops.add(2 * row_nnz + 1);
                    st.g_base += e.gamma * e.v_new;
                    flops.add(2);
                    shard_costs.add(si, 2 * row_nnz + 3);
                    shard_costs.add_bytes(
                        si,
                        BYTES_F64_READ
                            + (BYTES_F32_READ + BYTES_F64_RMW + BYTES_U32_RMW) * row_nnz,
                    );
                }
            }
            if let Some(pt) = p0 {
                ns_update += pt.elapsed().as_nanos();
            }

            // ---- line 29: drain the touched-list into the queue ---------
            let p0 = timing.then(Instant::now);
            for &k in touched.iter() {
                selector.notify(k as usize, st.alpha[k as usize], &mut flops);
            }
            flops.add_bytes((4 + BYTES_F64_READ) * touched.len() as u64);
            if let Some(pt) = p0 {
                ns_notify += pt.elapsed().as_nanos();
            }

            // ---- guard: renormalize w_m (never fires at paper scales) ---
            if st.w_m.abs() < WM_RENORM_THRESHOLD {
                for h in st.hat_w.iter_mut() {
                    *h *= st.w_m;
                }
                for v in st.hat_v.iter_mut() {
                    *v *= st.w_m;
                }
                st.w_m = 1.0;
            }

            if !replaying && self.cfg.trace_every > 0 && t % self.cfg.trace_every == 0 {
                trace.push(TraceRecord {
                    iter: t,
                    gap,
                    flops: flops.total(),
                    bytes: flops.bytes(),
                    pops: selector.stats().pops,
                    selected: j,
                    wall_ns: start.elapsed().as_nanos(),
                });
            }
            // §6.11 cadence: charge the ledger ahead of the releases it
            // covers, then persist the snapshot (either order is
            // crash-safe — see dp/ledger.rs on max-merge + seed-pinned
            // replay — but ledger-first keeps the write-ahead reading).
            if !replaying {
                if let Some(dur) = durability {
                    if dur.should_checkpoint(t) {
                        if let Some(pp) = &self.cfg.privacy {
                            dur.charge(
                                self.data.fingerprint(),
                                t_total,
                                t,
                                pp.spent_epsilon(t_total, t),
                            );
                        }
                        dur.persist(&self.snapshot(
                            t,
                            &st,
                            gap,
                            &rng,
                            &flops,
                            selector.stats(),
                            &history,
                            &trace,
                        ));
                    }
                }
            }
            observe(t, &st);
            if !replaying && self.cfg.gap_converged(gap) {
                stopped = StopReason::Converged;
                iters_done = t;
                break;
            }
        }

        // §6.11: boundary restore for an all-replay resume, then the final
        // write-ahead ledger record and interruption-stop resume point —
        // identical contract to the legacy body.
        if let Some(ck) = resume.filter(|_| !restored) {
            ck.restore_into(&mut rng, &mut flops, &mut *selector, &mut gap, &mut trace);
        }
        if let Some(dur) = durability {
            if let Some(pp) = &self.cfg.privacy {
                dur.charge(
                    self.data.fingerprint(),
                    t_total,
                    iters_done,
                    pp.spent_epsilon(t_total, iters_done),
                );
            }
            if iters_done > 0
                && matches!(
                    stopped,
                    StopReason::Deadline | StopReason::Cancelled | StopReason::Brownout
                )
            {
                dur.persist(&self.snapshot(
                    iters_done,
                    &st,
                    gap,
                    &rng,
                    &flops,
                    selector.stats(),
                    &history,
                    &trace,
                ));
            }
        }

        let wall_ms = start.elapsed().as_secs_f64() * 1e3;
        if timing {
            let tot = start.elapsed().as_nanos().max(1) as f64;
            eprintln!(
                "[phase-timing] select {:.1}% update+touch(fused) {:.1}% \
                 notify-drain {:.1}% other {:.1}% (total {:.1} ms, {} iters, {} shards)",
                100.0 * ns_select as f64 / tot,
                100.0 * ns_update as f64 / tot,
                100.0 * ns_notify as f64 / tot,
                100.0 * (tot - (ns_select + ns_update + ns_notify) as f64) / tot,
                tot / 1e6,
                iters_done,
                p
            );
        }
        trace.push(TraceRecord {
            iter: iters_done,
            gap,
            flops: flops.total(),
            bytes: flops.bytes(),
            pops: selector.stats().pops,
            selected: usize::MAX,
            wall_ns: start.elapsed().as_nanos(),
        });
        let (shard_flops, shard_bytes) = shard_costs.into_parts();
        let out = FwOutput {
            weights: WeightVector(st.weights()),
            final_gap: gap,
            flops: flops.total(),
            bootstrap_flops: flops.bootstrap(),
            bytes_moved: flops.bytes(),
            bootstrap_bytes: flops.bootstrap_bytes(),
            scratch_bytes: flops.scratch_bytes(),
            direct_segments: flops.direct_segments(),
            scratch_segments: flops.scratch_segments(),
            wall_ms,
            phase: timing.then(|| PhaseTiming {
                select_ns: ns_select as u64,
                update_ns: ns_update as u64,
                notify_ns: ns_notify as u64,
            }),
            selector_stats: selector.stats(),
            trace,
            iters_run: iters_done,
            stopped,
            eps_spent: self
                .cfg
                .privacy
                .map(|pp| pp.spent_epsilon(t_total, iters_done)),
            effective_threads: eff_threads,
            effective_shards: p,
            shard_flops,
            shard_bytes,
        };
        // ---- return every buffer (and the substrate) to the workspace --
        ws.recycle_f64(st.hat_w);
        ws.recycle_f64(st.hat_v);
        ws.recycle_f64(st.q);
        ws.recycle_f64(st.alpha);
        ws.recycle_u32(stamp);
        ws.recycle_u32(touched);
        ws.recycle_u32(row_scratch);
        ws.recycle_shard_scratch(shard_scratch);
        ws.put_sharded(sharded);
        ws.recycle_selector(selector, d, exp_scale, nm_scale);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dp::accounting::PrivacyParams;
    use crate::fw::config::SelectorKind;
    use crate::fw::standard::StandardFrankWolfe;
    use crate::sparse::synth::SynthConfig;
    use crate::testkit::assert_slices_close;

    fn small_ds(seed: u64) -> Dataset {
        SynthConfig {
            name: "unit".into(),
            n_rows: 150,
            n_cols: 80,
            avg_row_nnz: 8.0,
            zipf_exponent: 1.2,
            n_informative: 10,
            n_dense: 0,
            label_noise: 0.02,
            bias_col: true,
        }
        .generate(seed)
    }

    /// On *dense-column* data every row is refreshed every iteration, so
    /// the paper's lazy gradient cache is always fresh and Alg 2 must take
    /// the exact same steps as Alg 1 — identical weights and gaps up to FP
    /// noise. (On sparse data the cache is lazily refreshed; see
    /// `lazy_gradient_stays_close_on_sparse_data` and DESIGN.md §Lazy.)
    #[test]
    fn matches_standard_trajectory_exactly_on_dense_data() {
        let ds = SynthConfig {
            name: "dense".into(),
            n_rows: 60,
            n_cols: 24,
            avg_row_nnz: 24.0,
            zipf_exponent: 1.2,
            n_informative: 8,
            n_dense: 24, // every column dense ⇒ every row touched each iter
            label_noise: 0.02,
            bias_col: true,
        }
        .generate(7);
        let cfg = FwConfig { iters: 200, lambda: 8.0, trace_every: 1, ..Default::default() };
        let fast = FastFrankWolfe::new(&ds, cfg.clone()).run();
        let std_ = StandardFrankWolfe::new(&ds, cfg).run();
        assert_slices_close(fast.weights.as_slice(), std_.weights.as_slice(), 1e-6, 1e-9);
        for (a, b) in fast.trace.iter().zip(&std_.trace) {
            assert_eq!(a.iter, b.iter);
            if a.selected != usize::MAX {
                assert_eq!(a.selected, b.selected, "diverged at t={}", a.iter);
            }
            assert!((a.gap - b.gap).abs() < 1e-6 * (1.0 + b.gap.abs()));
        }
    }

    /// On sparse data Alg 2's gradient cache is lazily refreshed (the
    /// paper's footnote 3: "mild disagreement on update order"): early
    /// selections agree exactly, and both solvers converge to solutions of
    /// the same quality (the paper's Figure 1 claim).
    #[test]
    fn lazy_gradient_stays_close_on_sparse_data() {
        let ds = small_ds(7);
        let cfg = FwConfig { iters: 300, lambda: 8.0, trace_every: 1, ..Default::default() };
        let fast = FastFrankWolfe::new(&ds, cfg.clone()).run();
        let std_ = StandardFrankWolfe::new(&ds, cfg).run();
        // earliest steps identical (cache fresh while v̂ ≈ 0); staleness can
        // flip near-tie argmaxes soon after because early η_t is large
        for (a, b) in fast.trace.iter().zip(&std_.trace).take(3) {
            assert_eq!(a.selected, b.selected, "early divergence at t={}", a.iter);
        }
        // final model quality matches: mean logloss within 2% relative
        let loss = Logistic;
        let mll = |w: &[f64]| -> f64 {
            let mut v = vec![0.0; ds.n_rows()];
            ds.csr.matvec(w, &mut v);
            v.iter()
                .zip(&ds.labels)
                .map(|(&vi, &yi)| loss.value(vi, yi as f64))
                .sum::<f64>()
                / ds.n_rows() as f64
        };
        let lf = mll(fast.weights.as_slice());
        let ls = mll(std_.weights.as_slice());
        assert!(
            (lf - ls).abs() < 0.02 * ls.max(1e-9),
            "final losses diverged: fast={lf} std={ls}"
        );
    }

    /// The *actual* invariants Algorithm 2 maintains, checked after every
    /// iteration against a from-scratch recompute:
    ///   1. v̂ tracking is exact for every row: `w_m·v̂_i = x_i·w`.
    ///   2. α is exactly `Xᵀ q̄` for the *stored* (lazily refreshed) q̄.
    ///   3. q̄_i is the margin gradient at the row's last-touched margin —
    ///      in particular exact (= grad at current v) for touched rows.
    ///   4. g̃ is exactly `⟨α, w⟩` for the stored α.
    #[test]
    fn state_matches_dense_recompute() {
        let ds = small_ds(21);
        let cfg = FwConfig { iters: 120, lambda: 6.0, ..Default::default() };
        FastFrankWolfe::new(&ds, cfg).run_with_observer(|t, st| {
            let w = st.weights();
            // (1) v exact
            let mut v = vec![0.0; ds.n_rows()];
            ds.csr.matvec(&w, &mut v);
            for i in 0..ds.n_rows() {
                assert!(
                    (st.w_m * st.hat_v[i] - v[i]).abs() < 1e-8 * (1.0 + v[i].abs()),
                    "t={t} row {i}: v̂ drifted"
                );
            }
            // (2) alpha consistent with stored q̄
            let mut alpha = vec![0.0; ds.n_cols()];
            ds.csr.matvec_t_add(&st.q, &mut alpha);
            assert_slices_close(&st.alpha, &alpha, 1e-7, 1e-9);
            // (4) g̃ = ⟨α, w⟩ for stored α
            let aw: f64 = st.alpha.iter().zip(&w).map(|(&a, &wk)| a * wk).sum();
            assert!(
                (st.g_base - aw).abs() < 1e-7 * (1.0 + aw.abs()) + 1e-9,
                "t={t}: g̃={} vs ⟨α,w⟩={}",
                st.g_base,
                aw
            );
        });
    }

    #[test]
    fn fibheap_selector_matches_argmax_run() {
        let ds = small_ds(5);
        let base = FwConfig { iters: 250, lambda: 8.0, trace_every: 1, ..Default::default() };
        let am = FastFrankWolfe::new(&ds, base.clone()).run();
        let fh = FastFrankWolfe::new(
            &ds,
            FwConfig { selector: SelectorKind::FibHeap, ..base.clone() },
        )
        .run();
        let bh = FastFrankWolfe::new(
            &ds,
            FwConfig { selector: SelectorKind::BinHeap, ..base },
        )
        .run();
        assert_slices_close(am.weights.as_slice(), fh.weights.as_slice(), 1e-9, 1e-12);
        assert_slices_close(am.weights.as_slice(), bh.weights.as_slice(), 1e-9, 1e-12);
        assert!(fh.selector_stats.pops > 0);
    }

    #[test]
    fn stays_in_l1_ball_and_sparse() {
        let ds = small_ds(3);
        let cfg = FwConfig { iters: 50, lambda: 4.0, ..Default::default() };
        let out = FastFrankWolfe::new(&ds, cfg).run();
        assert!(out.weights.l1_norm() <= 4.0 + 1e-9);
        assert!(out.weights.nnz() <= 49);
    }

    #[test]
    fn dp_bsls_runs_and_converges_roughly() {
        let ds = small_ds(11);
        let cfg = FwConfig {
            iters: 400,
            lambda: 8.0,
            privacy: Some(PrivacyParams::new(2.0, 1e-6)),
            selector: SelectorKind::Bsls,
            seed: 4,
            trace_every: 50,
            ..Default::default()
        };
        let out = FastFrankWolfe::new(&ds, cfg).run();
        assert!(out.weights.l1_norm() <= 8.0 + 1e-9);
        assert!(out.flops > 0);
    }

    /// A K-λ path performs exactly one bootstrap `α = Xᵀq̄`: the flops
    /// counter's bootstrap category is positive for the first (cold) λ and
    /// zero for every warm one, and each warm total is lower than the
    /// corresponding independent run's by exactly the skipped bootstrap.
    #[test]
    fn run_path_shares_one_bootstrap() {
        let ds = small_ds(9);
        let cfg = FwConfig { iters: 80, lambda: 1.0, trace_every: 0, ..Default::default() };
        let mut ws = FwWorkspace::new();
        let lambdas = [2.0, 4.0, 8.0];
        let outs = FastFrankWolfe::new(&ds, cfg.clone()).run_path(&lambdas, &mut ws);
        assert!(outs[0].bootstrap_flops > 0, "cold λ must perform the bootstrap");
        for o in &outs[1..] {
            assert_eq!(o.bootstrap_flops, 0, "warm λ must record zero bootstrap work");
        }
        for (o, &lam) in outs.iter().zip(&lambdas) {
            let fresh = FastFrankWolfe::new(&ds, FwConfig { lambda: lam, ..cfg.clone() }).run();
            assert_eq!(fresh.weights, o.weights);
            assert_eq!(
                o.flops + (fresh.bootstrap_flops - o.bootstrap_flops),
                fresh.flops,
                "warm totals must differ by exactly the skipped bootstrap"
            );
        }
        // a second path through the same workspace is warm from its first λ
        let outs2 = FastFrankWolfe::new(&ds, cfg).run_path(&lambdas, &mut ws);
        assert!(outs2.iter().all(|o| o.bootstrap_flops == 0));
        for (a, b) in outs.iter().zip(&outs2) {
            assert_eq!(a.weights, b.weights);
        }
    }

    /// The compact u16-delta substrate is invisible to the trajectory:
    /// stripping it changes the reported byte traffic (strictly down on
    /// the compact side) and *nothing else*, bit for bit.
    #[test]
    fn compact_substrate_bit_identical_to_u32() {
        let ds = small_ds(19);
        assert_eq!(ds.index_kind(), "u16-delta");
        let mut plain = ds.clone();
        plain.strip_compact();
        let cfg = FwConfig { iters: 150, lambda: 6.0, trace_every: 10, ..Default::default() };
        let a = FastFrankWolfe::new(&ds, cfg.clone()).run();
        let b = FastFrankWolfe::new(&plain, cfg).run();
        assert_eq!(a.weights, b.weights);
        assert_eq!(a.final_gap.to_bits(), b.final_gap.to_bits());
        assert_eq!(a.flops, b.flops, "FLOP accounting is substrate-invariant");
        assert!(
            a.bytes_moved < b.bytes_moved,
            "compact must move fewer bytes: {} vs {}",
            a.bytes_moved,
            b.bytes_moved
        );
        assert!(a.bootstrap_bytes < b.bootstrap_bytes);
    }

    #[test]
    fn phase_timing_env_var_populates_structured_output() {
        let ds = small_ds(23);
        let cfg = FwConfig { iters: 60, lambda: 5.0, ..Default::default() };
        assert!(
            FastFrankWolfe::new(&ds, cfg.clone()).run().phase.is_none(),
            "timing off by default"
        );
        // set_var is safe (and race-free enough) here: rust 2021, and the
        // briefly-visible var only toggles instrumentation
        std::env::set_var("DPFW_PHASE_TIMING", "1");
        let out = FastFrankWolfe::new(&ds, cfg).run();
        std::env::remove_var("DPFW_PHASE_TIMING");
        let phase = out.phase.expect("timing enabled");
        assert!(phase.select_ns + phase.update_ns + phase.notify_ns > 0);
    }

    #[test]
    fn dp_deterministic_given_seed() {
        let ds = small_ds(13);
        let cfg = FwConfig {
            iters: 100,
            lambda: 5.0,
            privacy: Some(PrivacyParams::new(1.0, 1e-6)),
            selector: SelectorKind::Bsls,
            seed: 77,
            ..Default::default()
        };
        let a = FastFrankWolfe::new(&ds, cfg.clone()).run();
        let b = FastFrankWolfe::new(&ds, cfg).run();
        assert_eq!(a.weights, b.weights);
    }

    #[test]
    fn uses_fewer_flops_than_standard() {
        // Fig 2's claim at unit-test scale: the sparse solver does
        // meaningfully fewer FLOPs than the dense recompute.
        let ds = SynthConfig {
            name: "flops".into(),
            n_rows: 300,
            n_cols: 2000,
            avg_row_nnz: 12.0,
            zipf_exponent: 1.2,
            n_informative: 20,
            n_dense: 0,
            label_noise: 0.02,
            bias_col: true,
        }
        .generate(17);
        // Alg 2 + Alg 3 (fibheap), as in the paper's Fig 2, vs Alg 1.
        let fast = FastFrankWolfe::new(
            &ds,
            FwConfig {
                iters: 200,
                lambda: 8.0,
                selector: SelectorKind::FibHeap,
                ..Default::default()
            },
        )
        .run();
        let std_ = StandardFrankWolfe::new(
            &ds,
            FwConfig { iters: 200, lambda: 8.0, ..Default::default() },
        )
        .run();
        assert!(
            (std_.flops as f64) > 3.0 * fast.flops as f64,
            "std {} vs fast {}",
            std_.flops,
            fast.flops
        );
    }
}
