//! Loss functions with the margin-gradient form the paper's algorithms
//! consume: `q_i = ∂L(v_i, y_i)/∂v_i` for `v_i = x_i · w`.
//!
//! We fuse the paper's `ȳ = Xᵀy` bookkeeping into the gradient
//! (`σ(v) − y` instead of tracking `Xᵀσ(v)` and `Xᵀy` separately) — the
//! resulting `α` is identical (`Xᵀσ(v) − Xᵀy = Xᵀ(σ(v) − y)`), it is what
//! the L1/L2 Pallas oracle computes, and it removes a `D`-length state
//! vector without changing any step the algorithm takes.

/// A per-margin loss: everything the FW solvers need from `L`.
pub trait Loss: Send + Sync {
    /// `∂L(v, y)/∂v`.
    fn grad(&self, v: f64, y: f64) -> f64;
    /// `L(v, y)`.
    fn value(&self, v: f64, y: f64) -> f64;
    /// L1-Lipschitz constant of the margin gradient: `sup |∂L/∂v|`, the
    /// `L` in the paper's sensitivity bounds (features are ∞-normalized).
    fn lipschitz(&self) -> f64;
    fn name(&self) -> &'static str;
}

/// Logistic loss, labels in {0,1}: `L(v,y) = softplus(v) − y·v`,
/// gradient `σ(v) − y`, Lipschitz constant 1.
#[derive(Clone, Copy, Debug, Default)]
pub struct Logistic;

#[inline]
pub fn sigmoid(v: f64) -> f64 {
    if v >= 0.0 {
        1.0 / (1.0 + (-v).exp())
    } else {
        let e = v.exp();
        e / (1.0 + e)
    }
}

/// Numerically-stable `log(1 + e^v)`.
#[inline]
pub fn softplus(v: f64) -> f64 {
    if v > 30.0 {
        v
    } else if v < -30.0 {
        v.exp()
    } else {
        v.exp().ln_1p()
    }
}

impl Loss for Logistic {
    #[inline]
    fn grad(&self, v: f64, y: f64) -> f64 {
        sigmoid(v) - y
    }

    #[inline]
    fn value(&self, v: f64, y: f64) -> f64 {
        softplus(v) - y * v
    }

    fn lipschitz(&self) -> f64 {
        1.0
    }

    fn name(&self) -> &'static str {
        "logistic"
    }
}

/// Squared loss `½(v − y)²` — the paper notes its results transfer to
/// linear regression; provided for the non-private path. Its margin
/// gradient is unbounded, so the Lipschitz constant is only valid under a
/// caller-supplied bound on `|v − y|` (we use 1.0 and document that DP
/// with squared loss additionally requires clipping; the DP experiments
/// all use logistic loss, matching the paper).
#[derive(Clone, Copy, Debug, Default)]
pub struct Squared;

impl Loss for Squared {
    #[inline]
    fn grad(&self, v: f64, y: f64) -> f64 {
        v - y
    }

    #[inline]
    fn value(&self, v: f64, y: f64) -> f64 {
        0.5 * (v - y) * (v - y)
    }

    fn lipschitz(&self) -> f64 {
        1.0 // valid only with margins clipped to |v - y| <= 1; see docs
    }

    fn name(&self) -> &'static str {
        "squared"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sigmoid_basics() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-15);
        assert!(sigmoid(40.0) > 1.0 - 1e-15);
        assert!(sigmoid(-40.0) < 1e-15);
        // stable at extremes
        assert!(sigmoid(-1000.0) >= 0.0);
        assert!(sigmoid(1000.0) <= 1.0);
    }

    #[test]
    fn logistic_grad_is_derivative() {
        let loss = Logistic;
        for &(v, y) in &[(0.3, 1.0), (-2.0, 0.0), (5.0, 1.0), (0.0, 0.0)] {
            let h = 1e-6;
            let fd = (loss.value(v + h, y) - loss.value(v - h, y)) / (2.0 * h);
            assert!(
                (loss.grad(v, y) - fd).abs() < 1e-6,
                "v={v} y={y}: {} vs {}",
                loss.grad(v, y),
                fd
            );
        }
    }

    #[test]
    fn logistic_grad_bounded_by_lipschitz() {
        let loss = Logistic;
        for i in -100..=100 {
            let v = i as f64 / 5.0;
            for &y in &[0.0, 1.0] {
                assert!(loss.grad(v, y).abs() <= loss.lipschitz() + 1e-12);
            }
        }
    }

    #[test]
    fn logistic_value_nonnegative() {
        let loss = Logistic;
        for i in -50..=50 {
            let v = i as f64 / 5.0;
            assert!(loss.value(v, 0.0) >= 0.0);
            assert!(loss.value(v, 1.0) >= -1e-12);
        }
    }

    #[test]
    fn softplus_stable() {
        assert!((softplus(0.0) - (2.0f64).ln()).abs() < 1e-12);
        assert_eq!(softplus(1000.0), 1000.0);
        assert!(softplus(-1000.0) >= 0.0);
    }

    #[test]
    fn squared_grad_is_derivative() {
        let loss = Squared;
        let h = 1e-6;
        let fd = (loss.value(2.0 + h, 0.5) - loss.value(2.0 - h, 0.5)) / (2.0 * h);
        assert!((loss.grad(2.0, 0.5) - fd).abs() < 1e-6);
    }
}
