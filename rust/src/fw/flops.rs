//! FLOP and byte-traffic accounting — the measurements behind the paper's
//! Figures 2 and 4, and the bandwidth series of DESIGN.md §6.6.
//!
//! The counters use one fixed convention across both solvers so ratios are
//! meaningful: multiply/add/compare = 1 FLOP each, transcendentals
//! (`exp`, `ln`) = 4. Counting is by block (`add(n)` at the top of each
//! loop) rather than per-op instrumentation, so the counted code is the
//! same code that the wall-clock benches time.
//!
//! **Bytes moved** is tracked alongside FLOPs because the Alg 2 hot loop's
//! cost *is* memory traffic: the byte counts follow the analytic model of
//! DESIGN.md §6.6 (index + value stream bytes per scanned segment, plus
//! [`BYTES_F64_READ`]/[`BYTES_F64_RMW`]-style costs per dense slot
//! touched), accumulated at the same call sites as the FLOP blocks. The
//! model is deterministic — independent of thread count, workspace state,
//! and wall clock — so byte totals participate in the same bit-identity
//! property tests as everything else.

/// Cost convention constants.
pub const FLOPS_SIGMOID: u64 = 6; // exp(4) + add + div
pub const FLOPS_EXP: u64 = 4;
pub const FLOPS_LN: u64 = 4;

/// Byte-traffic convention (DESIGN.md §6.6).
pub const BYTES_F64_READ: u64 = 8;
pub const BYTES_F64_RMW: u64 = 16; // read + write back
pub const BYTES_F32_READ: u64 = 4;
pub const BYTES_U32_RMW: u64 = 8; // stamp words: read + (amortized) write
/// The scratch round-trip a decode-to-scratch segment pays per index: a
/// `u32` store into the scratch plus the re-read the gather performs
/// (DESIGN.md §6.7). This is **L1 traffic**, not DRAM — the scratch stays
/// cache-resident by construction — so it is tracked in its own
/// [`FlopCounter::scratch_bytes`] category rather than folded into the
/// DRAM-model `bytes`; the fused direct-decode arm charges zero here,
/// which is exactly the saving the §6.7 tier exists to harvest.
pub const BYTES_U32_SCRATCH_RT: u64 = 8;

#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FlopCounter {
    total: u64,
    /// The slice of `total` attributable to the dense bootstrap (the
    /// `O(N·S_c)` `α = Xᵀq̄` at `w = 0`). Tracked separately so the path
    /// engine can *prove* that warm per-λ solves skipped it: a run that
    /// drew the bootstrap from the workspace cache reports
    /// `bootstrap() == 0` and a `total` lower than a cold run by exactly
    /// the cold run's `bootstrap()`.
    boot: u64,
    /// Modeled bytes moved (DESIGN.md §6.6).
    bytes: u64,
    /// The slice of `bytes` attributable to the dense bootstrap — the
    /// traffic analogue of `boot`, with the same warm-run contract.
    boot_bytes: u64,
    /// Modeled L1 scratch round-trip bytes (DESIGN.md §6.7): the
    /// store+load per index that decode-to-scratch segments pay and fused
    /// direct-decode segments do not. Iteration-tier only — the one-off
    /// bootstrap sweep is deliberately unmodeled here, keeping the
    /// warm-path `run_path` contract untouched.
    scratch: u64,
    /// Compact segments scanned through the fused direct-decode arm
    /// (iteration tier; empty segments are not counted).
    direct_segs: u64,
    /// Compact segments scanned through the decode-to-scratch arm
    /// (iteration tier; empty segments are not counted).
    scratch_segs: u64,
}

impl FlopCounter {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn add(&mut self, n: u64) {
        self.total += n;
    }

    /// Record `n` FLOPs of bootstrap work (counted into `total` *and* the
    /// bootstrap category). Only the solvers' `α = Xᵀq̄` phase uses this.
    #[inline]
    pub fn add_boot(&mut self, n: u64) {
        self.total += n;
        self.boot += n;
    }

    #[inline]
    pub fn total(&self) -> u64 {
        self.total
    }

    /// FLOPs recorded through [`FlopCounter::add_boot`].
    #[inline]
    pub fn bootstrap(&self) -> u64 {
        self.boot
    }

    /// Record `n` modeled bytes of memory traffic.
    #[inline]
    pub fn add_bytes(&mut self, n: u64) {
        self.bytes += n;
    }

    /// Record `n` bytes of bootstrap traffic (counted into the total
    /// *and* the bootstrap category — mirrors [`FlopCounter::add_boot`]).
    #[inline]
    pub fn add_boot_bytes(&mut self, n: u64) {
        self.bytes += n;
        self.boot_bytes += n;
    }

    #[inline]
    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    /// Bytes recorded through [`FlopCounter::add_boot_bytes`].
    #[inline]
    pub fn bootstrap_bytes(&self) -> u64 {
        self.boot_bytes
    }

    /// Record a batch of scanned compact segments: `direct` fused
    /// segments, `scratch` decode-to-scratch segments covering
    /// `scratch_nnz` indices (each charged [`BYTES_U32_SCRATCH_RT`] of L1
    /// round-trip traffic). `u32` segments are not recorded — they have
    /// no decode arm to split.
    #[inline]
    pub fn add_segs(&mut self, direct: u64, scratch: u64, scratch_nnz: u64) {
        self.direct_segs += direct;
        self.scratch_segs += scratch;
        self.scratch += BYTES_U32_SCRATCH_RT * scratch_nnz;
    }

    /// Record one scanned segment by the dispatcher arm that ran it
    /// (empty segments move nothing and are skipped).
    #[inline]
    pub fn count_seg(&mut self, arm: crate::fw::scan::SegArm, nnz: u64) {
        use crate::fw::scan::SegArm;
        if nnz == 0 {
            return;
        }
        match arm {
            SegArm::Direct => self.add_segs(1, 0, 0),
            SegArm::Scratch => self.add_segs(0, 1, nnz),
            SegArm::U32 => {}
        }
    }

    /// L1 scratch round-trip bytes recorded through
    /// [`FlopCounter::add_segs`] / [`FlopCounter::count_seg`].
    #[inline]
    pub fn scratch_bytes(&self) -> u64 {
        self.scratch
    }

    /// Compact segments that rode the fused direct-decode arm.
    #[inline]
    pub fn direct_segments(&self) -> u64 {
        self.direct_segs
    }

    /// Compact segments that rode the decode-to-scratch arm.
    #[inline]
    pub fn scratch_segments(&self) -> u64 {
        self.scratch_segs
    }

    pub fn reset(&mut self) {
        *self = Self::default();
    }

    /// Serialize the counter into a fixed word array for the checkpoint
    /// frame (`fw::checkpoint`, DESIGN.md §6.11). The order is part of
    /// the on-disk format — append new categories, never reorder.
    #[inline]
    pub fn to_words(&self) -> [u64; 7] {
        [
            self.total,
            self.boot,
            self.bytes,
            self.boot_bytes,
            self.scratch,
            self.direct_segs,
            self.scratch_segs,
        ]
    }

    /// Rebuild a counter from a [`FlopCounter::to_words`] snapshot. A
    /// resumed run restores this at the replay boundary so its reported
    /// flop/byte trajectory is the uninterrupted run's, whatever the
    /// replay itself happened to charge.
    #[inline]
    pub fn from_words(w: [u64; 7]) -> Self {
        Self {
            total: w[0],
            boot: w[1],
            bytes: w[2],
            boot_bytes: w[3],
            scratch: w[4],
            direct_segs: w[5],
            scratch_segs: w[6],
        }
    }
}

/// Per-shard attribution ledger for the sharded solve path (DESIGN.md
/// §6.8). Deliberately a separate type: [`FlopCounter`] stays a small
/// `Copy` value participating in the bit-identity property tests, while
/// shard attribution is P-shaped telemetry — the same run at P=1 and P=16
/// attributes identical global totals differently, so these vectors are
/// excluded from output-equality comparisons. The solver charges the
/// global counter at the legacy call sites and mirrors the shard-local
/// slices here; by construction `flops_per_shard().sum() ≤ total` with
/// the remainder being the global plane (selection, axis updates,
/// bootstrap reduction).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ShardCosts {
    flops: Vec<u64>,
    bytes: Vec<u64>,
}

impl ShardCosts {
    pub fn new(n_shards: usize) -> Self {
        Self { flops: vec![0; n_shards], bytes: vec![0; n_shards] }
    }

    #[inline]
    pub fn add(&mut self, shard: usize, n: u64) {
        self.flops[shard] += n;
    }

    #[inline]
    pub fn add_bytes(&mut self, shard: usize, n: u64) {
        self.bytes[shard] += n;
    }

    pub fn flops_per_shard(&self) -> &[u64] {
        &self.flops
    }

    pub fn bytes_per_shard(&self) -> &[u64] {
        &self.bytes
    }

    /// Consume the ledger into `(flops, bytes)` vectors for `FwOutput`.
    pub fn into_parts(self) -> (Vec<u64>, Vec<u64>) {
        (self.flops, self.bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let mut f = FlopCounter::new();
        f.add(10);
        f.add(5);
        assert_eq!(f.total(), 15);
        f.reset();
        assert_eq!(f.total(), 0);
    }

    #[test]
    fn bootstrap_category_counts_into_total() {
        let mut f = FlopCounter::new();
        f.add(10);
        f.add_boot(7);
        assert_eq!(f.total(), 17);
        assert_eq!(f.bootstrap(), 7);
        f.reset();
        assert_eq!(f.bootstrap(), 0);
    }

    #[test]
    fn byte_categories_mirror_flop_categories() {
        let mut f = FlopCounter::new();
        f.add_bytes(100);
        f.add_boot_bytes(40);
        assert_eq!(f.bytes(), 140);
        assert_eq!(f.bootstrap_bytes(), 40);
        assert_eq!(f.total(), 0, "bytes must not leak into FLOPs");
        f.reset();
        assert_eq!(f.bytes(), 0);
        assert_eq!(f.bootstrap_bytes(), 0);
    }

    #[test]
    fn word_round_trip_is_lossless() {
        let mut f = FlopCounter::new();
        f.add(11);
        f.add_boot(7);
        f.add_bytes(100);
        f.add_boot_bytes(40);
        f.add_segs(3, 2, 9);
        let g = FlopCounter::from_words(f.to_words());
        assert_eq!(f, g);
    }

    #[test]
    fn shard_costs_attribute_per_shard() {
        let mut s = ShardCosts::new(3);
        s.add(0, 10);
        s.add(2, 5);
        s.add_bytes(1, 64);
        assert_eq!(s.flops_per_shard(), &[10, 0, 5]);
        assert_eq!(s.bytes_per_shard(), &[0, 64, 0]);
        let (f, b) = s.into_parts();
        assert_eq!(f.iter().sum::<u64>(), 15);
        assert_eq!(b.iter().sum::<u64>(), 64);
    }

    #[test]
    fn segment_split_tracks_arms_and_scratch_round_trips() {
        use crate::fw::scan::SegArm;
        let mut f = FlopCounter::new();
        f.count_seg(SegArm::Direct, 10);
        f.count_seg(SegArm::Scratch, 100);
        f.count_seg(SegArm::U32, 50); // no decode arm: not recorded
        f.count_seg(SegArm::Direct, 0); // empty: skipped
        f.count_seg(SegArm::Scratch, 0); // empty: skipped
        assert_eq!(f.direct_segments(), 1);
        assert_eq!(f.scratch_segments(), 1);
        assert_eq!(f.scratch_bytes(), BYTES_U32_SCRATCH_RT * 100);
        assert_eq!(f.bytes(), 0, "scratch L1 traffic must not leak into the DRAM model");
        f.add_segs(3, 2, 7);
        assert_eq!(f.direct_segments(), 4);
        assert_eq!(f.scratch_segments(), 3);
        assert_eq!(f.scratch_bytes(), BYTES_U32_SCRATCH_RT * 107);
        f.reset();
        assert_eq!(f.direct_segments(), 0);
        assert_eq!(f.scratch_segments(), 0);
        assert_eq!(f.scratch_bytes(), 0);
    }
}
