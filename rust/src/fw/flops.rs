//! FLOP accounting — the measurement behind the paper's Figures 2 and 4.
//!
//! The counters use one fixed convention across both solvers so ratios are
//! meaningful: multiply/add/compare = 1 FLOP each, transcendentals
//! (`exp`, `ln`) = 4. Counting is by block (`add(n)` at the top of each
//! loop) rather than per-op instrumentation, so the counted code is the
//! same code that the wall-clock benches time.

/// Cost convention constants.
pub const FLOPS_SIGMOID: u64 = 6; // exp(4) + add + div
pub const FLOPS_EXP: u64 = 4;
pub const FLOPS_LN: u64 = 4;

#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FlopCounter {
    total: u64,
    /// The slice of `total` attributable to the dense bootstrap (the
    /// `O(N·S_c)` `α = Xᵀq̄` at `w = 0`). Tracked separately so the path
    /// engine can *prove* that warm per-λ solves skipped it: a run that
    /// drew the bootstrap from the workspace cache reports
    /// `bootstrap() == 0` and a `total` lower than a cold run by exactly
    /// the cold run's `bootstrap()`.
    boot: u64,
}

impl FlopCounter {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn add(&mut self, n: u64) {
        self.total += n;
    }

    /// Record `n` FLOPs of bootstrap work (counted into `total` *and* the
    /// bootstrap category). Only the solvers' `α = Xᵀq̄` phase uses this.
    #[inline]
    pub fn add_boot(&mut self, n: u64) {
        self.total += n;
        self.boot += n;
    }

    #[inline]
    pub fn total(&self) -> u64 {
        self.total
    }

    /// FLOPs recorded through [`FlopCounter::add_boot`].
    #[inline]
    pub fn bootstrap(&self) -> u64 {
        self.boot
    }

    pub fn reset(&mut self) {
        self.total = 0;
        self.boot = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let mut f = FlopCounter::new();
        f.add(10);
        f.add(5);
        assert_eq!(f.total(), 15);
        f.reset();
        assert_eq!(f.total(), 0);
    }

    #[test]
    fn bootstrap_category_counts_into_total() {
        let mut f = FlopCounter::new();
        f.add(10);
        f.add_boot(7);
        assert_eq!(f.total(), 17);
        assert_eq!(f.bootstrap(), 7);
        f.reset();
        assert_eq!(f.bootstrap(), 0);
    }
}
