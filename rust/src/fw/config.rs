//! Run configuration shared by both solvers.

use std::sync::Arc;

use crate::dp::accounting::PrivacyParams;
use crate::fw::cancel::{CancelToken, StopReason};
use crate::fw::checkpoint::{FwCheckpoint, PathDurability, RunDurability};
use crate::fw::scan::ScanKernel;
use crate::testkit::faults::FaultPlan;

/// Which coordinate-selection structure to use (Table 3's rows/columns).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SelectorKind {
    /// Non-private dense argmax over |α| (Algorithm 1's selection).
    Argmax,
    /// Non-private Fibonacci-heap queue maintenance (Algorithm 3).
    FibHeap,
    /// Non-private queue maintenance on an indexed binary heap (ablation:
    /// same stale-upper-bound logic as Alg 3, cache-friendly structure).
    BinHeap,
    /// DP report-noisy-max, O(D) per iteration (Alg 1's DP selection and
    /// Table 3's "Alg. 2" ablation column).
    NoisyMax,
    /// DP Big-Step Little-Step exponential sampler (Algorithm 4).
    Bsls,
    /// DP exponential mechanism via O(D) Gumbel-max (distribution-exact
    /// reference for BSLS).
    NaiveExp,
}

impl SelectorKind {
    pub fn name(&self) -> &'static str {
        match self {
            SelectorKind::Argmax => "argmax",
            SelectorKind::FibHeap => "fibheap",
            SelectorKind::BinHeap => "binheap",
            SelectorKind::NoisyMax => "noisymax",
            SelectorKind::Bsls => "bsls",
            SelectorKind::NaiveExp => "naive-exp",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        Some(match s {
            "argmax" => SelectorKind::Argmax,
            "fibheap" => SelectorKind::FibHeap,
            "binheap" => SelectorKind::BinHeap,
            "noisymax" => SelectorKind::NoisyMax,
            "bsls" => SelectorKind::Bsls,
            "naive-exp" | "naiveexp" => SelectorKind::NaiveExp,
            _ => return None,
        })
    }

    /// Does this selector implement a DP mechanism (and therefore require
    /// `FwConfig::privacy`)?
    pub fn is_private(&self) -> bool {
        matches!(
            self,
            SelectorKind::NoisyMax | SelectorKind::Bsls | SelectorKind::NaiveExp
        )
    }
}

/// Solver configuration. `Default` gives the paper's main settings
/// (T=4000, λ=50, non-private argmax).
#[derive(Clone, Debug)]
pub struct FwConfig {
    /// Iteration budget `T` (the paper runs T−1 update steps, t = 1..T−1).
    pub iters: usize,
    /// L1-ball radius λ.
    pub lambda: f64,
    /// Privacy target; `None` = non-private training.
    pub privacy: Option<PrivacyParams>,
    pub selector: SelectorKind,
    /// RNG seed (mechanism noise; ignored by non-private selectors).
    pub seed: u64,
    /// Record a trace point every `trace_every` iterations (0 = only the
    /// final state).
    pub trace_every: usize,
    /// Override the loss Lipschitz constant (None = take it from the loss).
    pub lipschitz: Option<f64>,
    /// Worker threads for the solver's block-parallel phases (the dense
    /// bootstrap `α = Xᵀq̄`). `0` = automatic (available parallelism).
    /// The parallel kernels themselves fall back to serial below
    /// `sparse::PAR_MIN_NNZ`, where thread-spawn overhead dominates —
    /// the gate lives inside the `_par` entry points, so any requested
    /// count is safe on tiny inputs. Any value produces **bit-identical**
    /// output — the parallel kernels partition work so each f64 is summed
    /// in the same order regardless of thread count (property-tested) —
    /// so this is purely a performance/oversubscription knob (e.g. the
    /// coordinator pins its workers' jobs to 1).
    pub threads: usize,
    /// Dispatcher threshold for the direct-decode kernel tier (DESIGN.md
    /// §6.7): compact segments with `nnz` at or below it take the fused
    /// decode-gather arm, longer ones decode to scratch. `None` (the
    /// default) resolves process-wide — `DPFW_DIRECT_MAX_NNZ` if set,
    /// else [`crate::fw::scan::DIRECT_MAX_NNZ`]. Every arm is
    /// bit-identical (property-tested), so this is purely a performance
    /// knob; bench sweeps set `Some(0)` (all-scratch) / `Some(usize::MAX)`
    /// (all-fused) to measure the tier.
    pub direct_max_nnz: Option<usize>,
    /// Row-shard count for the sharded solve path (DESIGN.md §6.8).
    /// `None` (the default) resolves process-wide — `DPFW_SHARDS` if set,
    /// else the legacy monolithic path. `Some(p)` partitions the dataset
    /// into ≤ p contiguous nnz-balanced row shards and runs the hot loop
    /// through the per-shard substrate. The trajectory, flops, and modeled
    /// bytes are **bit-identical** at any shard count (property-tested;
    /// the sharded byte model is anchored to the parent's canonical
    /// streams), so like `threads` this is purely a performance/topology
    /// knob. `Some(1)` exercises the sharded code path with one shard.
    pub shards: Option<usize>,
    /// Cooperative stop signal (DESIGN.md §6.9): cancel flag + optional
    /// wall-clock deadline, polled once per iteration. The default token
    /// is disarmed — a single `Option` discriminant test per iteration.
    /// When it fires, the solver returns best-so-far weights with
    /// `iters_run < iters` and `FwOutput::stopped` naming the reason;
    /// the ε ledger charges only the iterations actually run.
    pub cancel: CancelToken,
    /// Early-exit tolerance on the per-iteration duality-gap estimate:
    /// stop with `StopReason::Converged` once `gap <= gap_tol`. `None`
    /// (the default) never converge-stops, preserving the historical
    /// fixed-T trajectories bit-for-bit.
    pub gap_tol: Option<f64>,
    /// Deterministic fault injection for tests/benches only
    /// (`testkit::faults`). Disarmed by default; production configs never
    /// arm it.
    pub fault: FaultPlan,
    /// Brownout cap on the number of update steps actually run (DESIGN.md
    /// §6.10). `None` (the default) runs the full planned budget. `Some(c)`
    /// stops the loop with [`StopReason::Brownout`] before the `(c+1)`-th
    /// selection, so exactly `c` update steps — and `c` mechanism releases
    /// — happen. Crucially this does **not** touch [`FwConfig::iters`]:
    /// the per-step noise scale stays calibrated for the planned T, the
    /// first `c` steps are bit-identical to an uncapped run's prefix, and
    /// `FwOutput::eps_spent` reports exactly `ε·√(c/T)` (the anytime
    /// accounting of `dp/accounting.rs`). A cap of `iters − 1` or more
    /// never fires (the paper's loop runs T−1 update steps).
    pub iter_cap: Option<usize>,
    /// Durability plumbing (DESIGN.md §6.11): when armed, the solver
    /// writes a crash-consistent [`FwCheckpoint`] every
    /// `durability.every_k` completed iterations and at every early-stop
    /// point (`Deadline`/`Cancelled`/`Brownout`), and charges the
    /// write-ahead ε ledger ahead of each release point. `None` (the
    /// default) adds zero work to the loop.
    pub durability: Option<Arc<RunDurability>>,
    /// Resume from a snapshot (DESIGN.md §6.11): the solver validates the
    /// checkpoint against this config + dataset (panicking on mismatch),
    /// replays iterations `1..=checkpoint.iter` to rebuild incremental
    /// state, restores the recorded RNG/counters at the boundary, and
    /// continues — bitwise identical to the uninterrupted run. `None`
    /// (the default) runs from scratch.
    pub resume: Option<Arc<FwCheckpoint>>,
    /// λ-path durability plan (DESIGN.md §6.12): when armed on a path
    /// job's config, `PathJob::run_in` gives each grid point its own
    /// [`RunDurability`] (durable request id, `ckpt-<req>-<k>.bin`
    /// snapshot) and per-cell resume, so a crashed path restarts at its
    /// last completed λ with exactly-once ε accounting. Ignored by
    /// single-cell solves; `None` (the default) runs the path unarmed.
    pub path_durability: Option<Arc<PathDurability>>,
}

/// Process-wide `DPFW_SHARDS` resolution (read once; same pattern as
/// `DPFW_DIRECT_MAX_NNZ` in `fw::scan`). Unset, empty, `0`, or
/// unparseable → `None` (the legacy monolithic path).
fn shards_from_env() -> Option<usize> {
    static SHARDS: std::sync::OnceLock<Option<usize>> = std::sync::OnceLock::new();
    *SHARDS.get_or_init(|| {
        std::env::var("DPFW_SHARDS")
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .filter(|&p| p >= 1)
    })
}

impl Default for FwConfig {
    fn default() -> Self {
        Self {
            iters: 4000,
            lambda: 50.0,
            privacy: None,
            selector: SelectorKind::Argmax,
            seed: 0,
            trace_every: 0,
            lipschitz: None,
            threads: 0,
            direct_max_nnz: None,
            shards: None,
            cancel: CancelToken::none(),
            gap_tol: None,
            fault: FaultPlan::none(),
            iter_cap: None,
            durability: None,
            resume: None,
            path_durability: None,
        }
    }
}

impl FwConfig {
    /// The scan-kernel dispatcher this run uses: the explicit
    /// [`FwConfig::direct_max_nnz`] threshold, or the process-wide
    /// env/default resolution. Both solvers route every segment scan of
    /// the run — iteration loops *and* the dense bootstrap — plus the
    /// matching per-segment accounting through this one value, so the
    /// recorded direct/scratch split always reflects what actually ran.
    /// (Leaf accessors outside a run, like `CsrMatrix::row_dot`, resolve
    /// process-wide instead — they never see a config.)
    pub fn scan_kernel(&self) -> ScanKernel {
        match self.direct_max_nnz {
            Some(n) => ScanKernel::with_threshold(n),
            None => ScanKernel::from_env(),
        }
    }

    /// Resolve [`FwConfig::threads`]: the explicit count, or available
    /// parallelism when 0.
    pub fn effective_threads(&self) -> usize {
        if self.threads == 0 {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
        } else {
            self.threads
        }
    }

    /// Resolve [`FwConfig::shards`]: the explicit count, or the
    /// process-wide `DPFW_SHARDS` resolution when `None`. A result of
    /// `None` means the legacy monolithic path.
    pub fn effective_shards(&self) -> Option<usize> {
        self.shards.or_else(shards_from_env)
    }

    /// Per-iteration stop poll, shared by both solvers (all four loop
    /// bodies call this at the top of iteration `t`, *before* the t-th
    /// selection — so a stop at `t` means exactly `t - 1` mechanism
    /// releases happened and the ε charge is exact). Fires any armed
    /// iteration fault first (tests/benches), then checks the cancel
    /// token. Cost when both are disarmed: two `Option` discriminant
    /// tests — negligible next to the O(S_r·S_c) iteration body; an armed
    /// deadline adds one `Instant::now()` per iteration. The brownout cap
    /// is checked last: a cancel/deadline is the more specific signal, and
    /// the cap firing at `t = cap + 1` means exactly `cap` update steps
    /// ran (poll-before-selection, like every other stop).
    #[inline]
    pub fn stop_check(&self, t: usize) -> Option<StopReason> {
        self.fault.on_iteration(t);
        self.cancel.check().or_else(|| {
            matches!(self.iter_cap, Some(cap) if t > cap)
                .then_some(StopReason::Brownout)
        })
    }

    /// Has the configured gap tolerance been met?
    #[inline]
    pub fn gap_converged(&self, gap: f64) -> bool {
        self.gap_tol.is_some_and(|tol| gap <= tol)
    }

    /// Panics on inconsistent combinations (DP selector without privacy
    /// params and vice versa) — failing loudly beats silently training
    /// with the wrong guarantee.
    pub fn validate(&self) {
        assert!(self.iters >= 2, "need at least 2 iterations");
        assert!(self.lambda > 0.0, "lambda must be positive");
        if self.selector.is_private() {
            assert!(
                self.privacy.is_some(),
                "selector {:?} is a DP mechanism; set FwConfig::privacy",
                self.selector
            );
        } else {
            assert!(
                self.privacy.is_none(),
                "privacy params set but selector {:?} is non-private; \
                 the run would NOT be differentially private",
                self.selector
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selector_name_roundtrip() {
        for k in [
            SelectorKind::Argmax,
            SelectorKind::FibHeap,
            SelectorKind::BinHeap,
            SelectorKind::NoisyMax,
            SelectorKind::Bsls,
            SelectorKind::NaiveExp,
        ] {
            assert_eq!(SelectorKind::from_name(k.name()), Some(k));
        }
        assert_eq!(SelectorKind::from_name("bogus"), None);
    }

    #[test]
    #[should_panic(expected = "DP mechanism")]
    fn dp_selector_requires_privacy() {
        FwConfig { selector: SelectorKind::Bsls, ..Default::default() }.validate();
    }

    #[test]
    #[should_panic(expected = "NOT be differentially private")]
    fn privacy_requires_dp_selector() {
        FwConfig {
            privacy: Some(PrivacyParams::new(1.0, 1e-6)),
            ..Default::default()
        }
        .validate();
    }

    #[test]
    fn default_is_paper_settings() {
        let c = FwConfig::default();
        assert_eq!(c.iters, 4000);
        assert_eq!(c.lambda, 50.0);
        c.validate();
    }

    #[test]
    fn effective_threads_resolves_auto() {
        assert!(FwConfig::default().effective_threads() >= 1);
        let c = FwConfig { threads: 3, ..Default::default() };
        assert_eq!(c.effective_threads(), 3);
    }

    #[test]
    fn effective_shards_prefers_explicit_count() {
        let c = FwConfig { shards: Some(4), ..Default::default() };
        assert_eq!(c.effective_shards(), Some(4));
        // None resolves process-wide; with DPFW_SHARDS unset in the test
        // environment that is the legacy monolithic path. (The OnceLock
        // makes the resolution read-once, so we only pin the explicit
        // branch here rather than mutating the process environment.)
        assert_eq!(
            FwConfig::default().effective_shards(),
            std::env::var("DPFW_SHARDS")
                .ok()
                .and_then(|s| s.trim().parse::<usize>().ok())
                .filter(|&p| p >= 1)
        );
    }

    #[test]
    fn stop_check_reports_cancel_and_deadline() {
        let cfg = FwConfig::default();
        assert_eq!(cfg.stop_check(1), None, "disarmed default must never stop");
        let armed = FwConfig { cancel: CancelToken::new(), ..Default::default() };
        assert_eq!(armed.stop_check(1), None);
        armed.cancel.cancel();
        assert_eq!(armed.stop_check(2), Some(StopReason::Cancelled));
        let expired = FwConfig {
            cancel: CancelToken::with_deadline(std::time::Instant::now()),
            ..Default::default()
        };
        assert_eq!(expired.stop_check(1), Some(StopReason::Deadline));
    }

    #[test]
    fn iter_cap_stops_with_brownout_after_exactly_cap_steps() {
        let cfg = FwConfig { iter_cap: Some(3), ..Default::default() };
        // poll happens at the top of iteration t, before the t-th
        // selection: t = 1..=cap proceeds, t = cap + 1 stops
        assert_eq!(cfg.stop_check(1), None);
        assert_eq!(cfg.stop_check(3), None);
        assert_eq!(cfg.stop_check(4), Some(StopReason::Brownout));
        // a cancel signal wins over the cap (more specific)
        let both = FwConfig {
            iter_cap: Some(3),
            cancel: CancelToken::new(),
            ..Default::default()
        };
        both.cancel.cancel();
        assert_eq!(both.stop_check(4), Some(StopReason::Cancelled));
        // no cap → never brownout
        assert_eq!(FwConfig::default().stop_check(usize::MAX), None);
    }

    #[test]
    fn gap_converged_requires_explicit_tolerance() {
        assert!(!FwConfig::default().gap_converged(0.0));
        let cfg = FwConfig { gap_tol: Some(1e-3), ..Default::default() };
        assert!(cfg.gap_converged(1e-4));
        assert!(cfg.gap_converged(1e-3));
        assert!(!cfg.gap_converged(2e-3));
    }

    #[test]
    fn scan_kernel_prefers_explicit_threshold() {
        let c = FwConfig { direct_max_nnz: Some(7), ..Default::default() };
        assert_eq!(c.scan_kernel(), ScanKernel::with_threshold(7));
        // None resolves process-wide (env or the compile-time default) —
        // just pin that it matches the shared resolution.
        assert_eq!(FwConfig::default().scan_kernel(), ScanKernel::from_env());
    }
}
