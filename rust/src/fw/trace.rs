//! Per-iteration traces and solver outputs — the raw material for every
//! figure in the paper (gap curves for Fig 1, FLOP ratios for Figs 2 & 4,
//! heap-pop ratios for Fig 3).

use crate::fw::cancel::StopReason;
use crate::fw::queue::SelectorStats;

/// One trace point.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceRecord {
    /// Iteration index t.
    pub iter: usize,
    /// The paper's convergence gap `g_t = −⟨α_t, d_t⟩` at the *selected*
    /// coordinate (noisy under DP, exactly Fig 1's y-axis otherwise).
    pub gap: f64,
    /// Cumulative FLOPs when this point was recorded.
    pub flops: u64,
    /// Cumulative modeled bytes moved (the DESIGN.md §6.6 traffic model)
    /// when this point was recorded.
    pub bytes: u64,
    /// Cumulative queue pops (Fibonacci/binary heap selectors; 0 others).
    pub pops: u64,
    /// Selected coordinate.
    pub selected: usize,
    /// Wall-clock nanoseconds since the run started.
    pub wall_ns: u128,
}

/// Wall-clock nanoseconds spent in each phase of the fast solver's
/// iteration loop, accumulated across all iterations. Populated only when
/// phase timing is enabled (`DPFW_PHASE_TIMING`, see `fw/fast.rs`) — the
/// per-phase `Instant` reads are not free, so the default run path skips
/// them. Consumed by the bench JSON emitters so the breakdown lands in
/// `BENCH_iteration_cost.json` instead of only on stderr.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PhaseTiming {
    /// Selection (line 15: argmax / heap pop / BSLS draw).
    pub select_ns: u64,
    /// The fused update+touch scan (lines 22–28).
    pub update_ns: u64,
    /// The touched-list notify drain (line 29).
    pub notify_ns: u64,
}

/// Result of one solver run.
#[derive(Clone, Debug)]
pub struct FwOutput {
    /// Final dense weight vector (length D).
    pub weights: WeightVector,
    /// Final convergence gap `g_{T−1}`.
    pub final_gap: f64,
    /// Total FLOPs for the run (per the convention in [`crate::fw::flops`]).
    pub flops: u64,
    /// The slice of `flops` spent on the dense bootstrap `α = Xᵀq̄`. A run
    /// whose bootstrap came from the workspace path cache
    /// (see [`crate::fw::workspace::FwWorkspace`] and `run_path`) reports
    /// `0` here, and its `flops` is lower than a cold run's by exactly the
    /// cold run's `bootstrap_flops` — the accounting stays honest instead
    /// of pretending the cached work was redone.
    pub bootstrap_flops: u64,
    /// Modeled bytes of memory traffic for the run (DESIGN.md §6.6): the
    /// quantity that actually governs the Alg 2 iteration cost. Like
    /// `flops`, deterministic — substrate-dependent (the compact `u16`
    /// index streams report genuinely fewer bytes than `u32`), but
    /// invariant to threads, workspace state, and wall clock.
    pub bytes_moved: u64,
    /// The slice of `bytes_moved` spent on the dense bootstrap; `0` for a
    /// warm path run, with the same exact-offset contract as
    /// [`FwOutput::bootstrap_flops`].
    pub bootstrap_bytes: u64,
    /// Modeled L1 scratch round-trip bytes (DESIGN.md §6.7): the per-index
    /// store+load that decode-to-scratch compact segments pay and the
    /// fused direct-decode tier eliminates. Iteration-tier only (the
    /// one-off bootstrap sweep is excluded, so the warm-path contract is
    /// untouched); zero on the `u32` substrate and on an all-fused run.
    pub scratch_bytes: u64,
    /// Compact segments the iteration loop scanned through the fused
    /// direct-decode arm (DESIGN.md §6.7; empty segments uncounted, `u32`
    /// substrate reports 0). With `scratch_segments`, the dispatcher
    /// split the bench JSON tracks.
    pub direct_segments: u64,
    /// Compact segments the iteration loop scanned through the
    /// decode-to-scratch arm.
    pub scratch_segments: u64,
    /// Wall-clock milliseconds.
    pub wall_ms: f64,
    /// Per-phase wall-clock breakdown (fast solver, only when
    /// `DPFW_PHASE_TIMING` is set; `None` otherwise and for Alg 1).
    pub phase: Option<PhaseTiming>,
    /// Selector telemetry (pops / draws / step counts).
    pub selector_stats: SelectorStats,
    /// Trace points (at `trace_every` cadence plus the final iteration).
    pub trace: Vec<TraceRecord>,
    /// Iterations actually executed. Equals `iters − 1` (the paper runs
    /// T−1 update steps) unless the run stopped early — see
    /// [`FwOutput::stopped`].
    pub iters_run: usize,
    /// Why the run returned (DESIGN.md §6.9). `IterBudget` for every
    /// full-budget run; `Deadline`/`Cancelled` mark anytime partial
    /// results (best-so-far weights, `iters_run < iters − 1`);
    /// `Converged` means `FwConfig::gap_tol` was met early.
    pub stopped: StopReason,
    /// Privacy actually spent: the ε of composing only the `iters_run`
    /// mechanism releases that happened, at the per-step budget calibrated
    /// for the *planned* T
    /// ([`crate::dp::accounting::PrivacyParams::spent_epsilon`]), i.e.
    /// `ε·√(iters_run / T)`. `None` for non-private runs. A full-budget
    /// run reports `ε·√((T−1)/T)` (the calibration budgets T steps but
    /// the paper's loop releases T−1 selections — conservative by
    /// construction); an early stop spends strictly less.
    pub eps_spent: Option<f64>,
    /// Worker threads this run actually resolved to
    /// (`FwConfig::effective_threads`) — surfaced so bench JSON rows are
    /// attributable to the real count, not the requested one (`threads: 0`
    /// means "auto", and the parallel kernels' internal gates may still
    /// serialize small inputs without changing this number).
    pub effective_threads: usize,
    /// Row shards the run actually built (≤ the requested count — the
    /// partition never splits below one row per shard); `0` on the legacy
    /// monolithic path (`FwConfig::shards` resolved to `None`).
    pub effective_shards: usize,
    /// Per-shard FLOP attribution (index = shard id; empty on the legacy
    /// path). Sums to ≤ [`FwOutput::flops`]; the remainder is the global
    /// plane (selection, axis updates, bootstrap). Telemetry only —
    /// excluded from the bit-identity contract, which compares the global
    /// totals (P=1 and P=16 runs attribute the same totals differently).
    pub shard_flops: Vec<u64>,
    /// Per-shard modeled-byte attribution, same contract as
    /// [`FwOutput::shard_flops`].
    pub shard_bytes: Vec<u64>,
}

impl FwOutput {
    /// Package a scoring-only run (a [`crate::coordinator::PredictJob`]):
    /// no iterations, no selections, no privacy spend — just the frozen
    /// weights plus the §6.6 cost model of the single matvec sweep, so
    /// ingress bytes-per-request accounting covers predictions uniformly.
    pub fn scored(
        weights: Vec<f64>,
        flops: u64,
        bytes: u64,
        wall_ms: f64,
        threads: usize,
    ) -> Self {
        FwOutput {
            weights: WeightVector(weights),
            final_gap: 0.0,
            flops,
            bootstrap_flops: 0,
            bytes_moved: bytes,
            bootstrap_bytes: 0,
            scratch_bytes: 0,
            direct_segments: 0,
            scratch_segments: 0,
            wall_ms,
            phase: None,
            selector_stats: SelectorStats::default(),
            trace: Vec::new(),
            iters_run: 0,
            stopped: StopReason::IterBudget,
            eps_spent: None,
            effective_threads: threads,
            effective_shards: 0,
            shard_flops: Vec::new(),
            shard_bytes: Vec::new(),
        }
    }
}

/// Dense weight vector with sparsity helpers.
#[derive(Clone, Debug, PartialEq)]
pub struct WeightVector(pub Vec<f64>);

impl WeightVector {
    pub fn dim(&self) -> usize {
        self.0.len()
    }

    pub fn nnz(&self) -> usize {
        self.0.iter().filter(|&&v| v != 0.0).count()
    }

    pub fn l1_norm(&self) -> f64 {
        self.0.iter().map(|v| v.abs()).sum()
    }

    pub fn as_slice(&self) -> &[f64] {
        &self.0
    }

    /// Nonzero entries as `(index, value)`.
    pub fn nonzeros(&self) -> impl Iterator<Item = (usize, f64)> + '_ {
        self.0.iter().enumerate().filter(|(_, &v)| v != 0.0).map(|(i, &v)| (i, v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weight_vector_helpers() {
        let w = WeightVector(vec![0.0, 2.0, -3.0, 0.0]);
        assert_eq!(w.dim(), 4);
        assert_eq!(w.nnz(), 2);
        assert!((w.l1_norm() - 5.0).abs() < 1e-12);
        assert_eq!(w.nonzeros().collect::<Vec<_>>(), vec![(1, 2.0), (2, -3.0)]);
    }
}
