//! `autotune_thresholds` — turn the substrates bench's per-segment-length
//! series into a measured `DIRECT_MAX_NNZ` recommendation.
//!
//! The §6.7 dispatcher routes compact segments with `nnz ≤ threshold`
//! down the fused direct-decode arm and the rest through decode-to-scratch.
//! The compile-time default (`scan::DIRECT_MAX_NNZ`) was picked analytically;
//! `cargo bench --bench substrates` measures both arms at
//! nnz ∈ {4, 8, 16, 40, 200, 2000} on the actual hardware and persists the
//! series to `BENCH_substrates.json`. This tool reads that file, finds the
//! fused-vs-scratch crossover per kernel (`dot`, `update_touch`), and
//! reports the measured threshold next to the active one
//! (`DPFW_DIRECT_MAX_NNZ` / default), closing the loop:
//!
//! ```text
//! cargo bench --bench substrates
//! cargo run --bin autotune_thresholds            # reads BENCH_substrates.json
//! DPFW_DIRECT_MAX_NNZ=<rec> cargo bench ...      # apply without rebuilding
//! ```
//!
//! JSON parsing is hand-rolled against the flat `dpfw-bench-v1` schema the
//! bench harness emits (serde is not in the offline crate set); unknown
//! fields are ignored, so the tool tolerates schema growth.

use std::process::ExitCode;

use dpfw::fw::scan::{ScanKernel, DIRECT_MAX_NNZ};

/// One `results[]` row, reduced to the fields the crossover needs.
#[derive(Debug)]
struct Row {
    kernel: String,
    arm: String,
    seg_nnz: usize,
    mean_ns: f64,
}

/// Split the top-level `results` array into object bodies. The harness
/// emits flat objects (no nesting), so scanning for brace pairs outside
/// string literals is sufficient — and strings still need the scan to
/// honor escapes, since `git describe` output lands in one.
fn object_bodies(doc: &str) -> Vec<&str> {
    let Some(results_at) = doc.find("\"results\"") else { return Vec::new() };
    let body = &doc[results_at..];
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut start = 0usize;
    let mut in_str = false;
    let mut escaped = false;
    for (i, c) in body.char_indices() {
        if in_str {
            match c {
                _ if escaped => escaped = false,
                '\\' => escaped = true,
                '"' => in_str = false,
                _ => {}
            }
            continue;
        }
        match c {
            '"' => in_str = true,
            '{' => {
                if depth == 0 {
                    start = i + 1;
                }
                depth += 1;
            }
            '}' => {
                depth = depth.saturating_sub(1);
                if depth == 0 {
                    out.push(&body[start..i]);
                }
            }
            ']' if depth == 0 => break, // end of the results array
            _ => {}
        }
    }
    out
}

/// Extract `"key": <value>` from a flat object body; returns the raw value
/// text (quotes stripped for strings). Good enough for the harness's own
/// output — keys never collide with value text because values containing
/// `":` never occur in the fields we read.
fn field<'a>(body: &'a str, key: &str) -> Option<&'a str> {
    let pat = format!("\"{key}\":");
    let at = body.find(&pat)? + pat.len();
    let rest = body[at..].trim_start();
    if let Some(stripped) = rest.strip_prefix('"') {
        stripped.split('"').next()
    } else {
        rest.split([',', '}']).next().map(str::trim)
    }
}

fn parse_rows(doc: &str) -> Vec<Row> {
    object_bodies(doc)
        .into_iter()
        .filter_map(|b| {
            Some(Row {
                kernel: field(b, "kernel")?.to_string(),
                arm: field(b, "arm")?.to_string(),
                seg_nnz: field(b, "seg_nnz")?.parse().ok()?,
                mean_ns: field(b, "mean_ns")?.parse().ok()?,
            })
        })
        .collect()
}

/// The measured crossover for one kernel: the largest bench point where
/// the fused arm still beats scratch, and the first where it loses —
/// the recommended threshold is their geometric midpoint, snapped to an
/// integer (conservative when the fused arm wins everywhere: the largest
/// measured point stands in, since beyond it there is no data).
fn crossover(series: &mut [(usize, f64, f64)]) -> Option<(usize, String)> {
    if series.is_empty() {
        return None;
    }
    series.sort_by_key(|&(nnz, _, _)| nnz);
    let mut last_fused_win: Option<usize> = None;
    let mut first_scratch_win: Option<usize> = None;
    for &(nnz, fused_ns, scratch_ns) in series.iter() {
        if fused_ns <= scratch_ns {
            if first_scratch_win.is_none() {
                last_fused_win = Some(nnz);
            }
        } else if first_scratch_win.is_none() {
            first_scratch_win = Some(nnz);
        }
    }
    match (last_fused_win, first_scratch_win) {
        (Some(lo), Some(hi)) => {
            let rec = ((lo as f64) * (hi as f64)).sqrt().round() as usize;
            Some((rec, format!("fused wins ≤ {lo}, loses ≥ {hi}")))
        }
        (Some(lo), None) => {
            Some((lo, format!("fused wins at every measured point (≤ {lo})")))
        }
        (None, Some(hi)) => Some((0, format!("scratch wins from the start (≥ {hi})"))),
        (None, None) => None,
    }
}

fn main() -> ExitCode {
    let path = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "BENCH_substrates.json".to_string());
    let doc = match std::fs::read_to_string(&path) {
        Ok(d) => d,
        Err(e) => {
            eprintln!(
                "autotune_thresholds: cannot read {path}: {e}\n\
                 run `cargo bench --bench substrates` first, or pass the JSON path"
            );
            return ExitCode::from(2);
        }
    };
    let rows = parse_rows(&doc);
    let active = ScanKernel::from_env().threshold();
    println!("active DIRECT_MAX_NNZ: {active} (compile-time default {DIRECT_MAX_NNZ})");

    let mut recommendations = Vec::new();
    for kernel in ["dot", "update_touch"] {
        // pair fused vs scratch rows by segment length
        let mut series: Vec<(usize, f64, f64)> = Vec::new();
        for r in rows.iter().filter(|r| r.kernel == kernel && r.arm == "fused") {
            let scratch = rows
                .iter()
                .find(|s| s.kernel == kernel && s.arm == "scratch" && s.seg_nnz == r.seg_nnz);
            if let Some(s) = scratch {
                series.push((r.seg_nnz, r.mean_ns, s.mean_ns));
            }
        }
        match crossover(&mut series) {
            Some((rec, why)) => {
                println!("{kernel:>14}: recommend {rec:>5}  ({why})");
                for &(nnz, f, s) in &series {
                    let winner = if f <= s { "fused" } else { "scratch" };
                    println!(
                        "{:>14}  nnz={nnz:<5} fused {:>12.0} ns  scratch {:>12.0} ns  -> {winner}",
                        "", f, s
                    );
                }
                recommendations.push(rec);
            }
            None => println!(
                "{kernel:>14}: no fused/scratch series in {path} — \
                 was the bench run with this schema?"
            ),
        }
    }

    match recommendations.iter().min() {
        Some(&rec) => {
            // one threshold serves both kernels: take the conservative
            // (smaller) crossover so neither arm regresses
            println!("\nrecommended DIRECT_MAX_NNZ: {rec}");
            if rec == active {
                println!("matches the active threshold — nothing to change");
            } else {
                println!(
                    "apply with DPFW_DIRECT_MAX_NNZ={rec}, per run via \
                     FwConfig.direct_max_nnz, or update scan::DIRECT_MAX_NNZ"
                );
            }
            ExitCode::SUCCESS
        }
        None => {
            eprintln!("no usable series found in {path}");
            ExitCode::from(2)
        }
    }
}
