//! Hand-rolled CLI argument parsing (clap is not in the offline crate
//! set). Supports `command [subcommand] --flag value --switch` grammar.

use std::collections::HashMap;

use anyhow::{bail, Result};

#[derive(Clone, Debug, Default)]
pub struct Args {
    /// Positional arguments in order (first is the command).
    pub positional: Vec<String>,
    /// `--key value` pairs; bare `--switch` maps to "true".
    pub flags: HashMap<String, String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (program name excluded).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Self> {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(key) = tok.strip_prefix("--") {
                if key.is_empty() {
                    bail!("bare `--` not supported");
                }
                if let Some((k, v)) = key.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    // value = next token unless it is another flag
                    match iter.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = iter.next().unwrap();
                            out.flags.insert(key.to_string(), v);
                        }
                        _ => {
                            out.flags.insert(key.to_string(), "true".to_string());
                        }
                    }
                }
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    pub fn command(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{key}: bad number {v:?}")),
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{key}: bad integer {v:?}")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{key}: bad integer {v:?}")),
        }
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn positional_and_flags() {
        let a = parse("exp table3 --scale 0.5 --out dir --quick");
        assert_eq!(a.command(), Some("exp"));
        assert_eq!(a.positional[1], "table3");
        assert_eq!(a.get("scale"), Some("0.5"));
        assert_eq!(a.get("out"), Some("dir"));
        assert!(a.has("quick"));
        assert_eq!(a.get("quick"), Some("true"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse("train --eps=0.1 --iters=100");
        assert_eq!(a.get_f64("eps", 1.0).unwrap(), 0.1);
        assert_eq!(a.get_usize("iters", 0).unwrap(), 100);
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse("train --eps abc");
        assert_eq!(a.get_f64("missing", 2.5).unwrap(), 2.5);
        assert!(a.get_f64("eps", 1.0).is_err());
    }

    #[test]
    fn switch_before_flag() {
        let a = parse("run --verbose --n 3");
        assert!(a.has("verbose"));
        assert_eq!(a.get_usize("n", 0).unwrap(), 3);
    }
}
