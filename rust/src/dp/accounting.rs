//! Privacy accounting for DP Frank-Wolfe (paper §B.2).
//!
//! Composition: running `T` exponential-mechanism (or report-noisy-max)
//! selections, each `ε'`-DP, yields `(ε, δ)`-DP overall with
//! `ε = 2 ε' √(2T log(1/δ))` by advanced composition for pure DP —
//! rearranged, the per-step budget is `ε' = ε / √(8T log(1/δ))`.
//!
//! Sensitivity: each selection scores the L1-ball vertices
//! `s = ±λ e_j` by `⟨s, ∇L(w)⟩ = ±λ α_j`. On neighbouring datasets the
//! unnormalized gradient coordinates move by at most `L · ‖x‖_∞ ≤ L`
//! (the loaders normalize features to `‖x‖_∞ ≤ 1`), so the vertex-score
//! sensitivity is `Δu = λ L` unnormalized, i.e. `λ L / N` for the
//! mean-scaled objective in the paper's Eq. (1).
//!
//! The two derived constants, matching the paper's pseudocode verbatim:
//! * Algorithm 1 (report-noisy-max): Laplace scale
//!   `b = λ L √(8T log(1/δ)) / (N ε)` on the *mean-scaled* scores — we
//!   work with unnormalized `α`, so the implementation multiplies by `N`.
//! * Algorithm 2 line 5 (exponential mechanism): weight multiplier
//!   `scale = L N ε / (2 λ √(8T log(1/δ))) = ε' N L / (2λ)` applied to
//!   `|α_j|/N`-style scores; applied to our unnormalized `|α_j|` it is
//!   `scale = ε' / (2 λ L)` scaled by … — see [`PrivacyParams::exp_mech_scale`]
//!   which keeps the algebra in one audited place.

/// User-facing privacy target.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PrivacyParams {
    pub epsilon: f64,
    pub delta: f64,
}

impl PrivacyParams {
    pub fn new(epsilon: f64, delta: f64) -> Self {
        assert!(epsilon > 0.0, "epsilon must be positive");
        assert!((0.0..1.0).contains(&delta) && delta > 0.0, "delta in (0,1)");
        Self { epsilon, delta }
    }

    /// Per-iteration pure-DP budget under advanced composition:
    /// `ε' = ε / √(8 T log(1/δ))`.
    pub fn per_step_epsilon(&self, t_iters: usize) -> f64 {
        assert!(t_iters > 0);
        self.epsilon / (8.0 * t_iters as f64 * (1.0 / self.delta).ln()).sqrt()
    }

    /// Laplace scale for Algorithm 1's report-noisy-max on **unnormalized**
    /// scores `λ|α_j|` (sensitivity `λ L`): `b = λ L / ε'`.
    /// Equals the paper's `λ L √(8T log(1/δ)) / (N ε)` once scores are
    /// divided by `N`; we keep `α` unnormalized so `N` cancels.
    ///
    /// Callers score `|α_j|` (not `λ|α_j|`) so the λ cancels too; the
    /// effective scale on `|α_j|` is `L / ε'`.
    pub fn noisy_max_scale(&self, t_iters: usize, lipschitz: f64) -> f64 {
        lipschitz / self.per_step_epsilon(t_iters)
    }

    /// Exponential-mechanism weight multiplier on **unnormalized** scores
    /// `u_j = |α_j|`: weight `∝ exp(ε' u_j / (2 Δu))` with `Δu = L`, i.e.
    /// multiplier `ε' / (2L)`. Identical to the paper's Algorithm 2 line 5
    /// (`L N ε / (2 λ √(8T log(1/δ)))`) after converting their mean-scaled,
    /// λ-multiplied vertex scores to our unnormalized `|α_j|`.
    pub fn exp_mech_scale(&self, t_iters: usize, lipschitz: f64) -> f64 {
        self.per_step_epsilon(t_iters) / (2.0 * lipschitz)
    }

    /// Privacy actually spent by a run that *planned* `t_planned`
    /// iterations but *released* only `iters_run` mechanism outputs
    /// (anytime stop, DESIGN.md §6.9). The per-step budget
    /// `ε' = per_step_epsilon(t_planned)` is fixed at calibration time,
    /// so composing `k = iters_run` of those steps under the same
    /// advanced-composition form costs
    /// `2 ε' √(2 k log(1/δ)) = ε √(k / T)`.
    ///
    /// Consequences the resilience layer relies on (property-tested):
    /// * `spent_epsilon(T, T) == ε` — a full run spends the target;
    /// * monotone in `iters_run` — stopping earlier never spends more;
    /// * a **seed-pinned retry spends nothing extra**: it replays the
    ///   identical mechanism stream (same seed → same noise → same
    ///   releases), so by post-processing the total release set is that
    ///   of one run and this function already accounts it.
    pub fn spent_epsilon(&self, t_planned: usize, iters_run: usize) -> f64 {
        assert!(t_planned > 0);
        assert!(
            iters_run <= t_planned,
            "ran {iters_run} iterations of a {t_planned}-iteration plan"
        );
        self.epsilon * (iters_run as f64 / t_planned as f64).sqrt()
    }
}

/// Inverse direction: maximum iterations affordable at a per-step budget.
pub fn max_iters_for_step_budget(eps_total: f64, delta: f64, eps_step: f64) -> usize {
    let t = (eps_total / eps_step).powi(2) / (8.0 * (1.0 / delta).ln());
    t.floor() as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_step_formula() {
        let p = PrivacyParams::new(1.0, 1e-6);
        let t = 4000;
        let want = 1.0 / (8.0 * 4000.0 * (1e6f64).ln()).sqrt();
        assert!((p.per_step_epsilon(t) - want).abs() < 1e-15);
    }

    #[test]
    fn per_step_shrinks_with_t_like_sqrt() {
        let p = PrivacyParams::new(0.5, 1e-5);
        let e1 = p.per_step_epsilon(100);
        let e4 = p.per_step_epsilon(400);
        assert!((e1 / e4 - 2.0).abs() < 1e-12);
    }

    #[test]
    fn scales_move_correctly_with_privacy() {
        let tight = PrivacyParams::new(0.1, 1e-6);
        let loose = PrivacyParams::new(1.0, 1e-6);
        // tighter privacy -> bigger Laplace noise, smaller exp-mech scale
        assert!(tight.noisy_max_scale(100, 1.0) > loose.noisy_max_scale(100, 1.0));
        assert!(tight.exp_mech_scale(100, 1.0) < loose.exp_mech_scale(100, 1.0));
        let ratio = loose.noisy_max_scale(100, 1.0) / tight.noisy_max_scale(100, 1.0);
        assert!((ratio - 0.1).abs() < 1e-12);
    }

    #[test]
    fn roundtrip_with_max_iters() {
        let p = PrivacyParams::new(1.0, 1e-6);
        let t = 5000;
        let step = p.per_step_epsilon(t);
        let t_back = max_iters_for_step_budget(1.0, 1e-6, step);
        assert!((t_back as i64 - t as i64).abs() <= 1);
    }

    #[test]
    fn spent_epsilon_full_run_hits_target() {
        let p = PrivacyParams::new(0.7, 1e-6);
        assert!((p.spent_epsilon(4000, 4000) - 0.7).abs() < 1e-15);
        assert_eq!(p.spent_epsilon(4000, 0), 0.0);
    }

    #[test]
    fn spent_epsilon_is_monotone_and_sqrt_shaped() {
        let p = PrivacyParams::new(1.0, 1e-6);
        let t = 1000;
        let mut prev = 0.0;
        for k in [1, 10, 250, 500, 999, 1000] {
            let s = p.spent_epsilon(t, k);
            assert!(s > prev, "spend must grow with iterations run");
            prev = s;
        }
        // quarter of the steps -> half the spend (√ composition)
        let ratio = p.spent_epsilon(t, 250) / p.spent_epsilon(t, 1000);
        assert!((ratio - 0.5).abs() < 1e-12);
        // consistency with the per-step calibration: k steps at
        // ε' = per_step_epsilon(T) compose to 2ε'√(2k log(1/δ))
        let k = 123;
        let composed =
            2.0 * p.per_step_epsilon(t) * (2.0 * k as f64 * (1e6f64).ln()).sqrt();
        assert!((p.spent_epsilon(t, k) - composed).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "ran 11 iterations")]
    fn spent_epsilon_rejects_overrun() {
        PrivacyParams::new(1.0, 1e-6).spent_epsilon(10, 11);
    }

    #[test]
    #[should_panic]
    fn rejects_nonpositive_epsilon() {
        PrivacyParams::new(0.0, 1e-6);
    }

    #[test]
    #[should_panic]
    fn rejects_bad_delta() {
        PrivacyParams::new(1.0, 1.5);
    }
}
