//! Substrate: differential privacy mechanisms and accounting.
//!
//! * [`accounting`] — the paper's §B.2: per-step budget via advanced
//!   composition (`ε' = ε / √(8T log(1/δ))`), the sensitivity of the FW
//!   linear-minimization scores, and the Algorithm 1/2 noise constants.
//! * [`mechanisms`] — Laplace and exponential mechanisms as standalone,
//!   testable primitives (the samplers in [`crate::sampler`] are their
//!   scaled-up implementations).

pub mod accounting;
pub mod ledger;
pub mod mechanisms;
