//! Write-ahead ε ledger — the durable half of the privacy accountant
//! (DESIGN.md §6.11, recovery lifecycle §6.12).
//!
//! Everything the serving tier knew about spent budget before this module
//! lived in process memory: a crash mid-solve lost the record of which
//! exponential-mechanism selections were already *released*, and a
//! restarted service could not prove it wasn't double-spending ε — the one
//! unreplenishable resource a DP service manages. [`EpsLedger`] is an
//! append-only log of CRC-framed spend records, written **ahead of** the
//! release it accounts for (the solver appends at every checkpoint
//! boundary and immediately before its results leave the worker), so at
//! any crash point the log covers at least every selection an observer
//! could have seen.
//!
//! Five properties carry the crash-safety argument:
//!
//! * **Idempotency by request id (max-merge).** One logical request may be
//!   recorded many times — at each checkpoint cadence, again at
//!   completion, and yet again when a crash-resumed run replays the
//!   cadence. Records for the same request id merge by *maximum released
//!   count*: cumulative dataset spend is the sum over request-id maxima,
//!   so replay after a crash never double-counts. (The re-released
//!   selections themselves are covered by the seed-pinned replay argument
//!   of §6.9: a resumed run reproduces bit-identical mechanism outputs,
//!   which is post-processing of the already-charged releases — zero
//!   additional ε.)
//! * **Torn-tail recovery.** A crash mid-append can leave a partial or
//!   corrupt final frame. [`EpsLedger::open`] decodes every fixed-size
//!   frame slot: the trailing invalid region (a torn or corrupt tail) is
//!   counted in [`EpsLedger::truncated_frames`] and physically cut back
//!   to the last valid frame boundary, while a corrupt frame *inside* the
//!   log (bit rot with valid frames after it) is dropped from the replay
//!   and counted in [`EpsLedger::rejected_records`] — it stays on disk as
//!   evidence until the next [`EpsLedger::compact`] rewrites the log.
//!   Either way a loss is *accounted*, never silent, and a dropped record
//!   can only under-state spend, never inflate it.
//! * **Fail-closed writes.** A failed append or fsync (disk full, torn
//!   write, injected fault) marks the ledger [`EpsLedger::failed`]; from
//!   then on every append is refused until a fresh `open`. The ingress
//!   budget gate treats a failed ledger as "cannot meter" and sheds
//!   private work rather than run it unmetered (DESIGN.md §6.12
//!   degradation contract). Before failing, the append path restores the
//!   frame alignment it can (truncating any torn bytes), so a later
//!   reopen recovers every acknowledged record.
//! * **Compaction.** The log grows by one frame per cadence checkpoint
//!   forever; [`EpsLedger::compact`] atomically rewrites it as one
//!   max-merged frame per request id (tmp + fsync + rename + dir-fsync),
//!   crash-safe at every kill point, preserving `spent_for_dataset`
//!   totals and the request-id high-water mark bit-for-bit.
//! * **Configurable durability.** [`FsyncPolicy`] trades append latency
//!   against the window of records an OS crash can lose: `Always` fsyncs
//!   every frame, `EveryN(n)` amortizes, `Never` leaves flushing to the
//!   OS (process-crash-safe only; the pool fsyncs it on graceful
//!   shutdown). `benches/durability.rs` measures the sweep.

use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::testkit::io_faults::IoFaultPlane;

/// One frame: req(8) + token(8) + planned(4) + released(4) + eps(8) +
/// crc32(4). Fixed-size so the torn-tail scan is a simple stride.
pub const LEDGER_FRAME_LEN: usize = 36;

/// CRC-32 (IEEE, reflected 0xEDB88320) — self-contained so the ledger has
/// no dependencies; shared with the checkpoint frame via `pub(crate)`.
pub(crate) fn crc32(data: &[u8]) -> u32 {
    let mut crc = !0u32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// When appends reach the disk (DESIGN.md §6.11).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FsyncPolicy {
    /// `fsync` after every frame: a record acknowledged is a record
    /// durable, even through an OS crash.
    Always,
    /// `fsync` every N frames: bounds the loss window to N−1 records.
    EveryN(u32),
    /// Never fsync explicitly: durable against process death (the write
    /// reached the page cache) but not OS/power failure.
    Never,
}

/// One spend record as read back from the log.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LedgerRecord {
    /// Request id — the idempotency key. Allocated via
    /// [`EpsLedger::allocate_request_id`] so ids are unique across process
    /// lifetimes (the log is durable; a reused id would be max-merged as a
    /// stale replay).
    pub request: u64,
    /// Dataset identity the spend charges against: the *stable content
    /// fingerprint* ([`crate::sparse::Dataset::fingerprint`]), not the
    /// process-local token — recorded spend must follow the data across
    /// restarts, not one process's handle to it.
    pub token: u64,
    /// Planned iteration budget T (the noise scale's calibration).
    pub planned: u32,
    /// Mechanism selections released so far (monotone per request).
    pub released: u32,
    /// Cumulative ε spent by this request at `released` releases.
    pub eps: f64,
}

impl LedgerRecord {
    fn encode(&self) -> [u8; LEDGER_FRAME_LEN] {
        let mut buf = [0u8; LEDGER_FRAME_LEN];
        buf[0..8].copy_from_slice(&self.request.to_le_bytes());
        buf[8..16].copy_from_slice(&self.token.to_le_bytes());
        buf[16..20].copy_from_slice(&self.planned.to_le_bytes());
        buf[20..24].copy_from_slice(&self.released.to_le_bytes());
        buf[24..32].copy_from_slice(&self.eps.to_bits().to_le_bytes());
        let crc = crc32(&buf[0..32]);
        buf[32..36].copy_from_slice(&crc.to_le_bytes());
        buf
    }

    fn decode(buf: &[u8]) -> Option<Self> {
        if buf.len() < LEDGER_FRAME_LEN {
            return None;
        }
        let crc = u32::from_le_bytes(buf[32..36].try_into().unwrap());
        if crc != crc32(&buf[0..32]) {
            return None;
        }
        Some(Self {
            request: u64::from_le_bytes(buf[0..8].try_into().unwrap()),
            token: u64::from_le_bytes(buf[8..16].try_into().unwrap()),
            planned: u32::from_le_bytes(buf[16..20].try_into().unwrap()),
            released: u32::from_le_bytes(buf[20..24].try_into().unwrap()),
            eps: f64::from_bits(u64::from_le_bytes(buf[24..32].try_into().unwrap())),
        })
    }
}

/// Statistics from one [`EpsLedger::compact`] pass.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CompactionStats {
    /// Valid frames in the log before the rewrite.
    pub frames_before: u64,
    /// Frames after: exactly one max-merged frame per recorded request id.
    pub frames_after: u64,
    /// Bytes the rewrite reclaimed (old on-disk length − new length).
    pub bytes_reclaimed: u64,
}

/// The sibling scratch file one compaction pass writes before its atomic
/// rename; a stale one (crash before the rename) is swept at `open`.
fn compact_tmp_path(path: &Path) -> PathBuf {
    let name = path
        .file_name()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "ledger".into());
    path.with_file_name(format!("{name}.compact-tmp"))
}

/// Per-request merged state: the maximum-released record seen.
#[derive(Clone, Copy, Debug)]
struct ReqState {
    token: u64,
    planned: u32,
    released: u32,
    eps: f64,
}

#[derive(Debug)]
struct LedgerInner {
    file: File,
    policy: FsyncPolicy,
    unsynced: u32,
    /// request id → max-merged state.
    requests: HashMap<u64, ReqState>,
    /// dataset token → Σ over request maxima of eps. A cache rebuilt
    /// lazily in *canonical order* (ascending request id): floating-point
    /// addition is not associative, so summing in the same order the
    /// compacted log replays in is what makes `spent_for_dataset`
    /// bit-identical before a compaction, after it, and after any reopen.
    spend: HashMap<u64, f64>,
    spend_dirty: bool,
    /// valid frames currently on disk (after any tail truncation).
    frames: u64,
    /// frames lost to torn/corrupt-*tail* truncation at the last `open`.
    truncated: u64,
    /// records dropped from the replay without truncation: a CRC-corrupt
    /// frame *inside* the log (valid frames follow it), or a record whose
    /// dataset token disagrees with the one its request id is already
    /// charged against (a malformed or cross-wired record — merging it
    /// would corrupt both datasets' totals).
    rejected: u64,
    /// Next request id this ledger will hand out
    /// ([`EpsLedger::allocate_request_id`]): one past the highest id ever
    /// seen on disk, so ids stay unique across process lifetimes — a
    /// restarted service can never reuse a dead process's id and have its
    /// charge swallowed as a stale replay by the max-merge.
    next_request: u64,
    /// Current on-disk length in bytes (frame-aligned after open; kept in
    /// step by appends so a failed write can cut back to the last good
    /// frame boundary).
    len: u64,
    /// Set by any write/fsync failure; every later append is refused
    /// until a fresh `open` (fail closed — the §6.12 degradation
    /// contract: the budget gate sheds rather than run unmetered).
    failed: bool,
    /// Storage-fault injection hooks (disarmed in production).
    io: IoFaultPlane,
}

impl LedgerInner {
    /// Does `r` claim a dataset other than the one its request id is
    /// already recorded against? A request charges exactly one dataset for
    /// its whole lifetime; anything else is a corrupt or cross-wired
    /// record.
    fn token_conflict(&self, r: &LedgerRecord) -> bool {
        self.requests.get(&r.request).is_some_and(|st| st.token != r.token)
    }

    /// Merge a record into the in-memory view. Max-merge: only a strictly
    /// larger released count for a known request moves that request's
    /// state (and dirties the spend cache); duplicates and stale replays
    /// are no-ops, and a record whose token disagrees with the request's
    /// recorded dataset is rejected outright (applying it to a
    /// *different* token would corrupt both datasets' totals).
    fn merge(&mut self, r: &LedgerRecord) -> bool {
        self.next_request = self.next_request.max(r.request.saturating_add(1));
        match self.requests.get_mut(&r.request) {
            Some(st) => {
                if st.token != r.token {
                    self.rejected += 1;
                    eprintln!(
                        "[dpfw] eps ledger: record for request {} charges dataset \
                         {:#x} but the request is recorded against {:#x}; dropped",
                        r.request, r.token, st.token
                    );
                    return false;
                }
                if r.released <= st.released {
                    return false;
                }
                st.planned = r.planned;
                st.released = r.released;
                st.eps = r.eps;
                self.spend_dirty = true;
                true
            }
            None => {
                self.requests.insert(
                    r.request,
                    ReqState {
                        token: r.token,
                        planned: r.planned,
                        released: r.released,
                        eps: r.eps,
                    },
                );
                self.spend_dirty = true;
                true
            }
        }
    }

    /// Rebuild the per-dataset spend cache in canonical order (ascending
    /// request id). Deterministic given the merged request map, so every
    /// path to the same set of maxima — live appends, crash replay,
    /// compaction + reopen — reports bit-identical totals.
    fn rebuild_spend(&mut self) {
        if !self.spend_dirty {
            return;
        }
        self.spend.clear();
        let mut ids: Vec<u64> = self.requests.keys().copied().collect();
        ids.sort_unstable();
        for id in &ids {
            let st = &self.requests[id];
            *self.spend.entry(st.token).or_insert(0.0) += st.eps;
        }
        self.spend_dirty = false;
    }
}

/// The append-only write-ahead ε ledger. All methods take `&self` — one
/// ledger is shared across the worker pool and the ingress via `Arc`.
#[derive(Debug)]
pub struct EpsLedger {
    path: PathBuf,
    inner: Mutex<LedgerInner>,
}

impl EpsLedger {
    /// Open (or create) the ledger at `path`, replaying every valid frame
    /// into the in-memory spend view. Every fixed-size frame slot is
    /// decoded: the trailing invalid region (torn or corrupt tail) is
    /// counted as [`Self::truncated_frames`] and physically cut back to
    /// the last valid frame boundary; a corrupt frame *inside* the log is
    /// dropped from the replay, counted as [`Self::rejected_records`],
    /// and left on disk as evidence. A stale compaction temp file (crash
    /// before its rename) is swept.
    pub fn open(path: impl AsRef<Path>, policy: FsyncPolicy) -> std::io::Result<Self> {
        let path = path.as_ref().to_path_buf();
        let tmp = compact_tmp_path(&path);
        if tmp.exists() {
            eprintln!(
                "[dpfw] eps ledger: sweeping stale compaction temp {}",
                tmp.display()
            );
            let _ = std::fs::remove_file(&tmp);
        }
        let mut file =
            OpenOptions::new().read(true).write(true).create(true).open(&path)?;
        let mut bytes = Vec::new();
        file.read_to_end(&mut bytes)?;
        let mut inner = LedgerInner {
            file,
            policy,
            unsynced: 0,
            requests: HashMap::new(),
            spend: HashMap::new(),
            spend_dirty: true,
            frames: 0,
            truncated: 0,
            rejected: 0,
            next_request: 0,
            len: 0,
            failed: false,
            io: IoFaultPlane::none(),
        };
        let n_slots = bytes.len() / LEDGER_FRAME_LEN;
        let decoded: Vec<Option<LedgerRecord>> = (0..n_slots)
            .map(|k| {
                LedgerRecord::decode(&bytes[k * LEDGER_FRAME_LEN..(k + 1) * LEDGER_FRAME_LEN])
            })
            .collect();
        let last_valid_end = decoded
            .iter()
            .rposition(|d| d.is_some())
            .map_or(0, |k| (k + 1) * LEDGER_FRAME_LEN);
        for (k, d) in decoded.iter().take(last_valid_end / LEDGER_FRAME_LEN).enumerate()
        {
            match d {
                Some(r) => {
                    inner.merge(r);
                    inner.frames += 1;
                }
                None => {
                    // corrupt frame with valid frames after it: bit rot,
                    // not a torn tail — drop it from the replay (spend can
                    // only be under-stated, never inflated) and leave the
                    // bytes in place for forensics / the next compaction
                    inner.rejected += 1;
                    eprintln!(
                        "[dpfw] eps ledger: CRC-corrupt frame at slot {k} inside \
                         {}; dropped from replay, left on disk",
                        path.display()
                    );
                }
            }
        }
        if (last_valid_end) < bytes.len() {
            // torn or corrupt tail: cut back to the last valid boundary
            inner.truncated = (bytes.len() - last_valid_end).div_ceil(LEDGER_FRAME_LEN) as u64;
            inner.file.set_len(last_valid_end as u64)?;
        }
        inner.len = last_valid_end as u64;
        inner.file.seek(SeekFrom::End(0))?;
        Ok(Self { path, inner: Mutex::new(inner) })
    }

    /// Append one spend record, durable per the fsync policy, and merge it
    /// into the live view. Write-ahead contract: callers append **before**
    /// releasing the selections the record accounts for. Returns `true`
    /// when the record advanced the merged state (i.e. it was not a
    /// replayed duplicate). A write or fsync failure marks the ledger
    /// [`Self::failed`] — after restoring what frame alignment it can —
    /// and every later append is refused (fail closed).
    pub fn append(&self, r: LedgerRecord) -> std::io::Result<bool> {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let inner = &mut *g;
        if inner.failed {
            return Err(std::io::Error::other(
                "eps ledger failed on an earlier write; appends refused (fail closed)",
            ));
        }
        if inner.token_conflict(&r) {
            // refuse before the write: a cross-wired record must corrupt
            // neither the durable log nor the in-memory totals
            inner.rejected += 1;
            let recorded = inner.requests[&r.request].token;
            eprintln!(
                "[dpfw] eps ledger: refusing append for request {}: dataset \
                 {:#x} conflicts with recorded {:#x}",
                r.request, r.token, recorded
            );
            return Ok(false);
        }
        if let Err(e) = inner.io.write_all(&mut inner.file, &r.encode()) {
            // a torn prefix of the frame may have landed: cut back to the
            // last good boundary so an eventual reopen replays cleanly,
            // then fail closed regardless of whether the cut succeeded
            let _ = inner.file.set_len(inner.len);
            let _ = inner.file.seek(SeekFrom::End(0));
            inner.failed = true;
            return Err(e);
        }
        inner.len += LEDGER_FRAME_LEN as u64;
        inner.frames += 1;
        let sync_due = match inner.policy {
            FsyncPolicy::Always => true,
            FsyncPolicy::EveryN(n) => {
                inner.unsynced += 1;
                inner.unsynced >= n.max(1)
            }
            FsyncPolicy::Never => false,
        };
        if sync_due {
            if let Err(e) = inner.io.on_fsync().and_then(|()| inner.file.sync_data()) {
                // the frame is written but its durability barrier failed;
                // a dropped page cache could lose it, so no later success
                // can be trusted — fail closed
                inner.failed = true;
                return Err(e);
            }
            inner.unsynced = 0;
        }
        Ok(inner.merge(&r))
    }

    /// Force everything appended so far to disk regardless of policy
    /// (the graceful-shutdown flush for `Never`/`EveryN`).
    pub fn sync(&self) -> std::io::Result<()> {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let inner = &mut *g;
        if inner.failed {
            return Err(std::io::Error::other(
                "eps ledger failed on an earlier write; nothing further to sync",
            ));
        }
        if let Err(e) = inner.io.on_fsync().and_then(|()| inner.file.sync_data()) {
            inner.failed = true;
            return Err(e);
        }
        inner.unsynced = 0;
        Ok(())
    }

    /// Atomically rewrite the log as one max-merged frame per request id,
    /// in ascending request-id order (the canonical spend order, so the
    /// compacted log replays to bit-identical `spent_for_dataset` totals
    /// and the same `allocate_request_id` high-water mark).
    ///
    /// Crash-safe at every kill point of the tmp + fsync + rename +
    /// dir-fsync sequence: before the rename the live log is untouched
    /// (a stale temp is swept at the next `open`); after the rename the
    /// log *is* the compacted content. The pass drops from disk what the
    /// replay already dropped from accounting — corrupt mid-log frames
    /// and token-conflicted records.
    pub fn compact(&self) -> std::io::Result<CompactionStats> {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let inner = &mut *g;
        if inner.failed {
            return Err(std::io::Error::other(
                "eps ledger failed on an earlier write; refusing to compact",
            ));
        }
        let frames_before = inner.frames;
        let bytes_before = inner.len;
        let mut ids: Vec<u64> = inner.requests.keys().copied().collect();
        ids.sort_unstable();
        let mut buf = Vec::with_capacity(ids.len() * LEDGER_FRAME_LEN);
        for id in &ids {
            let st = &inner.requests[id];
            buf.extend_from_slice(
                &LedgerRecord {
                    request: *id,
                    token: st.token,
                    planned: st.planned,
                    released: st.released,
                    eps: st.eps,
                }
                .encode(),
            );
        }
        let tmp = compact_tmp_path(&self.path);
        // phase 1: materialize + fsync the replacement beside the live log
        let write_tmp = (|| -> std::io::Result<()> {
            let mut f = OpenOptions::new()
                .write(true)
                .create(true)
                .truncate(true)
                .open(&tmp)?;
            inner.io.write_all(&mut f, &buf)?;
            inner.io.on_fsync()?;
            f.sync_all()
        })();
        if let Err(e) = write_tmp {
            let _ = std::fs::remove_file(&tmp);
            return Err(e); // live log untouched: the ledger stays healthy
        }
        // phase 2: the commit point
        if let Err(e) = inner.io.before_rename() {
            // "died before the rename": the finished temp survives on
            // disk for the next open() to sweep; the live log is intact
            return Err(e);
        }
        if let Err(e) = std::fs::rename(&tmp, &self.path) {
            let _ = std::fs::remove_file(&tmp);
            return Err(e);
        }
        // the rename unlinked the inode our old handle points at: swap in
        // a handle on the new file before anything else can append
        let nf = OpenOptions::new().read(true).write(true).open(&self.path);
        let mut nf = match nf {
            Ok(f) => f,
            Err(e) => {
                // the on-disk log is the (correct) compacted one, but this
                // process can no longer reach it: fail closed
                inner.failed = true;
                return Err(e);
            }
        };
        if let Err(e) = nf.seek(SeekFrom::End(0)) {
            inner.failed = true;
            return Err(e);
        }
        inner.file = nf;
        inner.frames = ids.len() as u64;
        inner.len = buf.len() as u64;
        inner.unsynced = 0;
        // phase 3: post-commit. An injected crash-after-rename dies here,
        // which is safe — the rename is the correctness boundary; the dir
        // fsync below only makes the *name change* power-loss durable.
        inner.io.after_rename()?;
        if let Some(dir) = self.path.parent() {
            if let Ok(d) = File::open(dir) {
                let _ = d.sync_all();
            }
        }
        Ok(CompactionStats {
            frames_before,
            frames_after: ids.len() as u64,
            bytes_reclaimed: bytes_before.saturating_sub(buf.len() as u64),
        })
    }

    /// Has a write/fsync failure put this ledger in the fail-closed state
    /// (appends refused until a fresh `open`)?
    pub fn failed(&self) -> bool {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).failed
    }

    /// Arm storage-fault injection on this ledger's write/fsync/rename
    /// paths (tests and benches; production ledgers stay disarmed).
    pub fn arm_io_faults(&self, plane: IoFaultPlane) {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).io = plane;
    }

    /// Cumulative ε charged against a dataset token: the sum over request
    /// ids (ascending — the canonical order) of each request's maximum
    /// recorded spend.
    pub fn spent_for_dataset(&self, token: u64) -> f64 {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        g.rebuild_spend();
        g.spend.get(&token).copied().unwrap_or(0.0)
    }

    /// The merged (released, eps) state for one request id, if recorded.
    pub fn spent_for_request(&self, request: u64) -> Option<(u32, f64)> {
        let g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        g.requests.get(&request).map(|st| (st.released, st.eps))
    }

    /// The dataset token a request id's spend is recorded against, if
    /// any. Restart-time recovery cross-checks an orphaned checkpoint's
    /// `dataset_fp` against this before trusting the snapshot: a
    /// disagreement means the file cannot belong to the WAL's request.
    pub fn token_for_request(&self, request: u64) -> Option<u64> {
        let g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        g.requests.get(&request).map(|st| st.token)
    }

    /// Valid frames currently in the log (appends since open included).
    pub fn frames(&self) -> u64 {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).frames
    }

    /// Frames discarded by torn/corrupt-tail truncation at the last
    /// `open`.
    pub fn truncated_frames(&self) -> u64 {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).truncated
    }

    /// Records dropped without truncation: CRC-corrupt frames inside the
    /// log (at the last `open`) plus records whose dataset token
    /// conflicted with the one their request id is already recorded
    /// against (replay + appends since open).
    pub fn rejected_records(&self) -> u64 {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).rejected
    }

    /// Allocate a request id that is unique across process lifetimes:
    /// strictly above every id this ledger has ever seen on disk (replayed
    /// at `open`) or handed out in this process. The coordinator uses this
    /// — not its per-process result counter — as the ledger idempotency
    /// key, so a restarted service can never collide with a dead process's
    /// recorded request and have a fresh charge silently max-merged away.
    pub fn allocate_request_id(&self) -> u64 {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let id = g.next_request;
        g.next_request += 1;
        id
    }

    /// Distinct request ids recorded.
    pub fn n_requests(&self) -> usize {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).requests.len()
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::io_faults::{IoFaultKind, IoFaultPlane};
    use std::io::Write;

    fn tmp(name: &str) -> PathBuf {
        let p = std::env::temp_dir()
            .join(format!("dpfw-ledger-{}-{}", std::process::id(), name));
        let _ = std::fs::remove_file(&p);
        let _ = std::fs::remove_file(compact_tmp_path(&p));
        p
    }

    fn rec(request: u64, token: u64, released: u32, eps: f64) -> LedgerRecord {
        LedgerRecord { request, token, planned: 100, released, eps }
    }

    #[test]
    fn crc32_reference_vector() {
        // the canonical IEEE check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn append_and_reopen_round_trip() {
        let p = tmp("round-trip");
        {
            let l = EpsLedger::open(&p, FsyncPolicy::Always).unwrap();
            assert!(l.append(rec(1, 7, 10, 0.1)).unwrap());
            assert!(l.append(rec(2, 7, 20, 0.3)).unwrap());
            assert!(l.append(rec(3, 8, 5, 0.05)).unwrap());
            assert!((l.spent_for_dataset(7) - 0.4).abs() < 1e-12);
        }
        let l = EpsLedger::open(&p, FsyncPolicy::Always).unwrap();
        assert_eq!(l.frames(), 3);
        assert_eq!(l.truncated_frames(), 0);
        assert!((l.spent_for_dataset(7) - 0.4).abs() < 1e-12);
        assert!((l.spent_for_dataset(8) - 0.05).abs() < 1e-12);
        assert_eq!(l.spent_for_request(2), Some((20, 0.3)));
        assert_eq!(l.spent_for_dataset(999), 0.0);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn max_merge_is_idempotent_per_request() {
        let p = tmp("max-merge");
        let l = EpsLedger::open(&p, FsyncPolicy::Never).unwrap();
        assert!(l.append(rec(1, 7, 10, 0.1)).unwrap());
        // progress record: the request's maximum moves the dataset spend
        assert!(l.append(rec(1, 7, 30, 0.25)).unwrap());
        assert!((l.spent_for_dataset(7) - 0.25).abs() < 1e-12);
        // exact replay and stale replay are both no-ops
        assert!(!l.append(rec(1, 7, 30, 0.25)).unwrap());
        assert!(!l.append(rec(1, 7, 10, 0.1)).unwrap());
        assert!((l.spent_for_dataset(7) - 0.25).abs() < 1e-12);
        assert_eq!(l.spent_for_request(1), Some((30, 0.25)));
        assert_eq!(l.n_requests(), 1);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn torn_tail_truncates_to_last_valid_frame() {
        let p = tmp("torn-tail");
        {
            let l = EpsLedger::open(&p, FsyncPolicy::Always).unwrap();
            l.append(rec(1, 7, 10, 0.1)).unwrap();
            l.append(rec(2, 7, 20, 0.2)).unwrap();
        }
        // simulate a crash mid-append: half a frame dangling
        {
            let mut f = OpenOptions::new().append(true).open(&p).unwrap();
            f.write_all(&[0xAB; LEDGER_FRAME_LEN / 2]).unwrap();
        }
        let l = EpsLedger::open(&p, FsyncPolicy::Always).unwrap();
        assert_eq!(l.frames(), 2);
        assert_eq!(l.truncated_frames(), 1);
        assert!((l.spent_for_dataset(7) - 0.3).abs() < 1e-12);
        // the truncation is physical: a fresh reopen sees a clean log
        let l2 = EpsLedger::open(&p, FsyncPolicy::Always).unwrap();
        assert_eq!(l2.truncated_frames(), 0);
        assert_eq!(l2.frames(), 2);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn corrupt_tail_byte_drops_only_the_last_frame() {
        let p = tmp("corrupt-tail");
        {
            let l = EpsLedger::open(&p, FsyncPolicy::Always).unwrap();
            l.append(rec(1, 7, 10, 0.1)).unwrap();
            l.append(rec(2, 7, 20, 0.2)).unwrap();
        }
        // flip one byte inside the last frame's payload
        {
            let mut bytes = std::fs::read(&p).unwrap();
            let off = LEDGER_FRAME_LEN + 5;
            bytes[off] ^= 0xFF;
            std::fs::write(&p, &bytes).unwrap();
        }
        let l = EpsLedger::open(&p, FsyncPolicy::Always).unwrap();
        assert_eq!(l.frames(), 1);
        assert_eq!(l.truncated_frames(), 1);
        assert!((l.spent_for_dataset(7) - 0.1).abs() < 1e-12);
        // replaying the lost record after recovery charges it exactly once
        assert!(l.append(rec(2, 7, 20, 0.2)).unwrap());
        assert!(!l.append(rec(2, 7, 20, 0.2)).unwrap());
        assert!((l.spent_for_dataset(7) - 0.3).abs() < 1e-12);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn corrupt_frame_inside_the_log_is_rejected_not_truncated() {
        let p = tmp("corrupt-mid");
        {
            let l = EpsLedger::open(&p, FsyncPolicy::Always).unwrap();
            l.append(rec(1, 7, 10, 0.1)).unwrap();
            l.append(rec(2, 7, 20, 0.2)).unwrap();
            l.append(rec(3, 8, 5, 0.05)).unwrap();
        }
        // bit rot in the FIRST frame — valid frames follow it
        {
            let mut bytes = std::fs::read(&p).unwrap();
            bytes[5] ^= 0xFF;
            std::fs::write(&p, &bytes).unwrap();
        }
        let l = EpsLedger::open(&p, FsyncPolicy::Always).unwrap();
        assert_eq!(l.frames(), 2, "the two valid frames replay");
        assert_eq!(l.rejected_records(), 1, "the rotten frame is accounted");
        assert_eq!(l.truncated_frames(), 0, "no tail was cut");
        // the loss only ever under-states spend
        assert!((l.spent_for_dataset(7) - 0.2).abs() < 1e-12);
        assert_eq!(l.spent_for_request(1), None);
        // the rotten bytes stay on disk as evidence until compaction
        assert_eq!(
            std::fs::metadata(&p).unwrap().len(),
            3 * LEDGER_FRAME_LEN as u64
        );
        l.compact().unwrap();
        drop(l);
        let l = EpsLedger::open(&p, FsyncPolicy::Always).unwrap();
        assert_eq!(l.rejected_records(), 0, "compaction rewrote the log clean");
        assert_eq!(l.frames(), 2);
        assert!((l.spent_for_dataset(7) - 0.2).abs() < 1e-12);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn request_ids_allocate_above_the_durable_high_water_mark() {
        let p = tmp("req-ids");
        {
            let l = EpsLedger::open(&p, FsyncPolicy::Always).unwrap();
            // fresh log: ids start at 0 and never repeat in-process
            assert_eq!(l.allocate_request_id(), 0);
            assert_eq!(l.allocate_request_id(), 1);
            l.append(rec(1, 7, 10, 0.1)).unwrap();
            // an externally chosen id raises the mark past itself
            l.append(rec(40, 7, 5, 0.05)).unwrap();
            assert_eq!(l.allocate_request_id(), 41);
        }
        // "process restart": only recorded ids survive (the unrecorded
        // allocation 0 is free again — no record means no replay hazard),
        // and new ids land strictly above every recorded one
        let l = EpsLedger::open(&p, FsyncPolicy::Always).unwrap();
        assert_eq!(l.allocate_request_id(), 41);
        assert_eq!(l.allocate_request_id(), 42);
        // a fresh charge under the new id is a real charge, not a replay
        assert!(l.append(rec(41, 7, 10, 0.1)).unwrap());
        assert!((l.spent_for_dataset(7) - 0.25).abs() < 1e-12);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn token_conflict_records_are_rejected_not_merged() {
        let p = tmp("token-conflict");
        {
            let l = EpsLedger::open(&p, FsyncPolicy::Always).unwrap();
            assert!(l.append(rec(1, 7, 10, 0.1)).unwrap());
            // same request, different dataset: refused before the write,
            // neither dataset's total moves
            assert!(!l.append(rec(1, 8, 20, 0.2)).unwrap());
            assert_eq!(l.rejected_records(), 1);
            assert!((l.spent_for_dataset(7) - 0.1).abs() < 1e-12);
            assert_eq!(l.spent_for_dataset(8), 0.0);
            assert_eq!(l.spent_for_request(1), Some((10, 0.1)));
            // the refused record was never persisted
            assert_eq!(l.frames(), 1);
        }
        // replay-side guard: hand-craft a log whose second frame
        // cross-wires the request onto another dataset
        {
            let mut f = OpenOptions::new().append(true).open(&p).unwrap();
            f.write_all(&rec(1, 9, 30, 0.3).encode()).unwrap();
        }
        let l = EpsLedger::open(&p, FsyncPolicy::Always).unwrap();
        assert_eq!(l.rejected_records(), 1);
        assert!((l.spent_for_dataset(7) - 0.1).abs() < 1e-12);
        assert_eq!(l.spent_for_dataset(9), 0.0);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn fsync_policies_all_reach_disk_on_sync() {
        for (name, policy) in [
            ("always", FsyncPolicy::Always),
            ("every4", FsyncPolicy::EveryN(4)),
            ("never", FsyncPolicy::Never),
        ] {
            let p = tmp(&format!("policy-{name}"));
            let l = EpsLedger::open(&p, policy).unwrap();
            for k in 0..10u64 {
                l.append(rec(k, 7, 10, 0.01)).unwrap();
            }
            l.sync().unwrap();
            drop(l);
            let l = EpsLedger::open(&p, policy).unwrap();
            assert_eq!(l.frames(), 10);
            assert!((l.spent_for_dataset(7) - 0.1).abs() < 1e-9);
            let _ = std::fs::remove_file(&p);
        }
    }

    // ---- §6.12: compaction --------------------------------------------

    /// Fill a log with cadence-style replays (many frames per request)
    /// plus one cross-dataset request, and return the ledger.
    fn populated(p: &Path) -> EpsLedger {
        let l = EpsLedger::open(p, FsyncPolicy::Always).unwrap();
        for req in 0..6u64 {
            for step in 1..=5u32 {
                let released = step * 10;
                l.append(rec(req, 7 + req % 2, released, released as f64 * 1e-3))
                    .unwrap();
            }
        }
        l
    }

    #[test]
    fn compaction_preserves_totals_and_high_water_bit_exactly() {
        let p = tmp("compact-exact");
        let l = populated(&p);
        let before7 = l.spent_for_dataset(7);
        let before8 = l.spent_for_dataset(8);
        let req3 = l.spent_for_request(3).unwrap();
        let stats = l.compact().unwrap();
        assert_eq!(stats.frames_before, 30);
        assert_eq!(stats.frames_after, 6, "one frame per request id");
        assert_eq!(stats.bytes_reclaimed, 24 * LEDGER_FRAME_LEN as u64);
        // live view after the rewrite: identical bits
        assert_eq!(l.spent_for_dataset(7).to_bits(), before7.to_bits());
        assert_eq!(l.spent_for_dataset(8).to_bits(), before8.to_bits());
        assert_eq!(l.frames(), 6);
        // the compacted log replays to the same state
        drop(l);
        let l = EpsLedger::open(&p, FsyncPolicy::Always).unwrap();
        assert_eq!(l.frames(), 6);
        assert_eq!(l.truncated_frames(), 0);
        assert_eq!(l.spent_for_dataset(7).to_bits(), before7.to_bits());
        assert_eq!(l.spent_for_dataset(8).to_bits(), before8.to_bits());
        let after3 = l.spent_for_request(3).unwrap();
        assert_eq!(after3.0, req3.0);
        assert_eq!(after3.1.to_bits(), req3.1.to_bits());
        assert_eq!(l.allocate_request_id(), 6, "high-water mark preserved");
        // appends keep flowing after a compaction (handle swap worked)
        assert!(l.append(rec(6, 7, 10, 0.01)).unwrap());
        drop(l);
        let l = EpsLedger::open(&p, FsyncPolicy::Always).unwrap();
        assert_eq!(l.frames(), 7);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn compaction_survives_every_injected_kill_point() {
        use IoFaultKind::*;
        for (name, kind) in [
            ("short-write", ShortWrite),
            ("fsync", FsyncFail),
            ("enospc", Enospc),
            ("pre-rename", CrashBeforeRename),
            ("post-rename", CrashAfterRename),
        ] {
            let p = tmp(&format!("compact-kill-{name}"));
            let l = populated(&p);
            let want7 = l.spent_for_dataset(7);
            let want8 = l.spent_for_dataset(8);
            l.arm_io_faults(IoFaultPlane::once(kind));
            let res = l.compact();
            assert!(res.is_err(), "{name}: injected fault must surface");
            // "the process died here": reopen the same path cold
            drop(l);
            let l = EpsLedger::open(&p, FsyncPolicy::Always).unwrap();
            assert_eq!(
                l.spent_for_dataset(7).to_bits(),
                want7.to_bits(),
                "{name}: dataset-7 total must survive the kill"
            );
            assert_eq!(l.spent_for_dataset(8).to_bits(), want8.to_bits(), "{name}");
            assert_eq!(l.truncated_frames(), 0, "{name}: no torn tail");
            assert_eq!(l.allocate_request_id(), 6, "{name}: high-water mark");
            assert!(
                !compact_tmp_path(&p).exists(),
                "{name}: open() sweeps any stale compaction temp"
            );
            // post-rename kills committed the rewrite; the others left the
            // original log — either way the retry compacts cleanly
            let stats = l.compact().unwrap();
            assert_eq!(stats.frames_after, 6, "{name}");
            drop(l);
            let l = EpsLedger::open(&p, FsyncPolicy::Always).unwrap();
            assert_eq!(l.spent_for_dataset(7).to_bits(), want7.to_bits(), "{name}");
            let _ = std::fs::remove_file(&p);
        }
    }

    // ---- §6.12: fuzz-style torn/corrupt logs --------------------------
    //
    // The two structured recovery tests above pick one representative
    // tear each; these sweep the whole space — every byte offset a crash
    // could shear the file at, every bit a disk could flip — and hold the
    // recovery invariants at each point: reopen never panics, spend is
    // never inflated, and every lost record shows up in
    // `truncated_frames` or `rejected_records`.

    /// Five distinct requests on one dataset, eps (k+1)·0.01 each.
    fn fuzz_base(p: &Path) -> Vec<u8> {
        {
            let l = EpsLedger::open(p, FsyncPolicy::Always).unwrap();
            for k in 0..5u64 {
                l.append(rec(k, 7, 10 * (k as u32 + 1), (k as f64 + 1.0) * 0.01))
                    .unwrap();
            }
        }
        std::fs::read(p).unwrap()
    }

    /// The ledger's canonical spend fold (ascending request id), over the
    /// first `m` fuzz records with `skip` (if any) removed — the
    /// bit-exact expectation for a partially surviving log.
    fn fuzz_expected(m: usize, skip: Option<usize>) -> f64 {
        (0..m)
            .filter(|k| Some(*k) != skip)
            .fold(0.0f64, |acc, k| acc + (k as f64 + 1.0) * 0.01)
    }

    #[test]
    fn truncation_at_every_byte_offset_recovers_accounted_and_uninflated() {
        let p = tmp("fuzz-truncate-base");
        let bytes = fuzz_base(&p);
        assert_eq!(bytes.len(), 5 * LEDGER_FRAME_LEN);
        let scratch = tmp("fuzz-truncate");
        for cut in 0..=bytes.len() {
            std::fs::write(&scratch, &bytes[..cut]).unwrap();
            let l = EpsLedger::open(&scratch, FsyncPolicy::Always).unwrap();
            let whole = cut / LEDGER_FRAME_LEN;
            let shorn = cut % LEDGER_FRAME_LEN;
            assert_eq!(l.frames(), whole as u64, "cut={cut}");
            assert_eq!(
                l.truncated_frames(),
                (shorn > 0) as u64,
                "cut={cut}: every torn byte is accounted"
            );
            assert_eq!(l.rejected_records(), 0, "cut={cut}");
            assert_eq!(
                l.spent_for_dataset(7).to_bits(),
                fuzz_expected(whole, None).to_bits(),
                "cut={cut}: exactly the surviving prefix, nothing inflated"
            );
            assert_eq!(
                l.allocate_request_id(),
                whole as u64,
                "cut={cut}: high-water mark follows the survivors"
            );
            // physical recovery: the same file reopens clean
            drop(l);
            let l = EpsLedger::open(&scratch, FsyncPolicy::Always).unwrap();
            assert_eq!(l.truncated_frames(), 0, "cut={cut}: tail was cut back");
            assert_eq!(l.frames(), whole as u64, "cut={cut}");
        }
        let _ = std::fs::remove_file(&p);
        let _ = std::fs::remove_file(&scratch);
    }

    #[test]
    fn single_bit_flips_anywhere_never_panic_and_never_inflate_spend() {
        let p = tmp("fuzz-bitflip-base");
        let bytes = fuzz_base(&p);
        let full = fuzz_expected(5, None);
        let scratch = tmp("fuzz-bitflip");
        for byte in 0..bytes.len() {
            for bit in 0..8 {
                let mut mutated = bytes.clone();
                mutated[byte] ^= 1u8 << bit;
                std::fs::write(&scratch, &mutated).unwrap();
                let l = EpsLedger::open(&scratch, FsyncPolicy::Always).unwrap();
                let ctx = format!("byte={byte} bit={bit}");
                // CRC-32 detects every single-bit error, so exactly the
                // flipped frame drops: as a truncated tail when it is the
                // last frame, as a rejected mid-log record otherwise.
                let slot = byte / LEDGER_FRAME_LEN;
                assert_eq!(l.frames(), 4, "{ctx}");
                assert_eq!(
                    l.truncated_frames() + l.rejected_records(),
                    1,
                    "{ctx}: the loss is accounted"
                );
                assert_eq!(l.truncated_frames(), (slot == 4) as u64, "{ctx}");
                let spent = l.spent_for_dataset(7);
                assert!(spent < full, "{ctx}: a loss may only under-state spend");
                assert_eq!(
                    spent.to_bits(),
                    fuzz_expected(5, Some(slot)).to_bits(),
                    "{ctx}: survivors replay bit-exactly"
                );
                assert_eq!(l.spent_for_request(slot as u64), None, "{ctx}");
                // the ledger stays writable: re-charging the lost request
                // lands exactly once
                let lost = rec(slot as u64, 7, 10 * (slot as u32 + 1), (slot as f64 + 1.0) * 0.01);
                assert!(l.append(lost).unwrap(), "{ctx}");
                assert_eq!(l.spent_for_dataset(7).to_bits(), full.to_bits(), "{ctx}");
            }
        }
        let _ = std::fs::remove_file(&p);
        let _ = std::fs::remove_file(&scratch);
    }

    // ---- §6.12: fail-closed writes ------------------------------------

    #[test]
    fn write_failure_fails_closed_and_restores_alignment() {
        for kind in [IoFaultKind::ShortWrite, IoFaultKind::Enospc] {
            let p = tmp(&format!("fail-closed-{kind:?}"));
            let l = EpsLedger::open(&p, FsyncPolicy::Always).unwrap();
            l.append(rec(1, 7, 10, 0.1)).unwrap();
            l.arm_io_faults(IoFaultPlane::once(kind));
            assert!(!l.failed());
            l.append(rec(2, 7, 20, 0.2)).unwrap_err();
            assert!(l.failed(), "{kind:?}: failure latches");
            // fail closed: even though the fault budget is spent, the
            // ledger refuses to meter anything further
            l.append(rec(3, 7, 30, 0.3)).unwrap_err();
            l.sync().unwrap_err();
            l.compact().unwrap_err();
            // the failed append never reached the merged view
            assert_eq!(l.spent_for_request(2), None);
            assert!((l.spent_for_dataset(7) - 0.1).abs() < 1e-12);
            drop(l);
            // the torn prefix was cut: a reopen replays only whole,
            // acknowledged frames
            let l = EpsLedger::open(&p, FsyncPolicy::Always).unwrap();
            assert!(!l.failed(), "a fresh open starts healthy");
            assert_eq!(l.frames(), 1);
            assert_eq!(l.truncated_frames(), 0, "{kind:?}: alignment restored");
            assert!((l.spent_for_dataset(7) - 0.1).abs() < 1e-12);
            assert!(l.append(rec(2, 7, 20, 0.2)).unwrap());
            let _ = std::fs::remove_file(&p);
        }
    }

    #[test]
    fn fsync_failure_fails_closed() {
        let p = tmp("fsync-fails-closed");
        let l = EpsLedger::open(&p, FsyncPolicy::Always).unwrap();
        l.arm_io_faults(IoFaultPlane::once(IoFaultKind::FsyncFail));
        l.append(rec(1, 7, 10, 0.1)).unwrap_err();
        assert!(l.failed());
        l.append(rec(2, 7, 10, 0.1)).unwrap_err();
        // the frame itself reached the file before the barrier failed, so
        // a reopen may legitimately see it — what matters is that the
        // failed ledger stopped accepting new spend
        let _ = std::fs::remove_file(&p);
    }
}
