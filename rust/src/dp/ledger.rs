//! Write-ahead ε ledger — the durable half of the privacy accountant
//! (DESIGN.md §6.11).
//!
//! Everything the serving tier knew about spent budget before this module
//! lived in process memory: a crash mid-solve lost the record of which
//! exponential-mechanism selections were already *released*, and a
//! restarted service could not prove it wasn't double-spending ε — the one
//! unreplenishable resource a DP service manages. [`EpsLedger`] is an
//! append-only log of CRC-framed spend records, written **ahead of** the
//! release it accounts for (the solver appends at every checkpoint
//! boundary and immediately before its results leave the worker), so at
//! any crash point the log covers at least every selection an observer
//! could have seen.
//!
//! Three properties carry the crash-safety argument:
//!
//! * **Idempotency by request id (max-merge).** One logical request may be
//!   recorded many times — at each checkpoint cadence, again at
//!   completion, and yet again when a crash-resumed run replays the
//!   cadence. Records for the same request id merge by *maximum released
//!   count*: cumulative dataset spend is the sum over request-id maxima,
//!   so replay after a crash never double-counts. (The re-released
//!   selections themselves are covered by the seed-pinned replay argument
//!   of §6.9: a resumed run reproduces bit-identical mechanism outputs,
//!   which is post-processing of the already-charged releases — zero
//!   additional ε.)
//! * **Torn-tail recovery.** A crash mid-append can leave a partial or
//!   corrupt final frame. [`EpsLedger::open`] scans frames until the first
//!   CRC/length failure and truncates the file there — everything before
//!   the torn frame is intact by construction (frames are fixed-size and
//!   self-checksummed), and the torn record is at most the one append that
//!   had not yet been acknowledged.
//! * **Configurable durability.** [`FsyncPolicy`] trades append latency
//!   against the window of records an OS crash can lose: `Always` fsyncs
//!   every frame, `EveryN(n)` amortizes, `Never` leaves flushing to the
//!   OS (process-crash-safe only). `benches/durability.rs` measures the
//!   sweep.

use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// One frame: req(8) + token(8) + planned(4) + released(4) + eps(8) +
/// crc32(4). Fixed-size so the torn-tail scan is a simple stride.
pub const LEDGER_FRAME_LEN: usize = 36;

/// CRC-32 (IEEE, reflected 0xEDB88320) — self-contained so the ledger has
/// no dependencies; shared with the checkpoint frame via `pub(crate)`.
pub(crate) fn crc32(data: &[u8]) -> u32 {
    let mut crc = !0u32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// When appends reach the disk (DESIGN.md §6.11).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FsyncPolicy {
    /// `fsync` after every frame: a record acknowledged is a record
    /// durable, even through an OS crash.
    Always,
    /// `fsync` every N frames: bounds the loss window to N−1 records.
    EveryN(u32),
    /// Never fsync explicitly: durable against process death (the write
    /// reached the page cache) but not OS/power failure.
    Never,
}

/// One spend record as read back from the log.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LedgerRecord {
    /// Request id — the idempotency key. Allocated via
    /// [`EpsLedger::allocate_request_id`] so ids are unique across process
    /// lifetimes (the log is durable; a reused id would be max-merged as a
    /// stale replay).
    pub request: u64,
    /// Dataset identity the spend charges against: the *stable content
    /// fingerprint* ([`crate::sparse::Dataset::fingerprint`]), not the
    /// process-local token — recorded spend must follow the data across
    /// restarts, not one process's handle to it.
    pub token: u64,
    /// Planned iteration budget T (the noise scale's calibration).
    pub planned: u32,
    /// Mechanism selections released so far (monotone per request).
    pub released: u32,
    /// Cumulative ε spent by this request at `released` releases.
    pub eps: f64,
}

impl LedgerRecord {
    fn encode(&self) -> [u8; LEDGER_FRAME_LEN] {
        let mut buf = [0u8; LEDGER_FRAME_LEN];
        buf[0..8].copy_from_slice(&self.request.to_le_bytes());
        buf[8..16].copy_from_slice(&self.token.to_le_bytes());
        buf[16..20].copy_from_slice(&self.planned.to_le_bytes());
        buf[20..24].copy_from_slice(&self.released.to_le_bytes());
        buf[24..32].copy_from_slice(&self.eps.to_bits().to_le_bytes());
        let crc = crc32(&buf[0..32]);
        buf[32..36].copy_from_slice(&crc.to_le_bytes());
        buf
    }

    fn decode(buf: &[u8]) -> Option<Self> {
        if buf.len() < LEDGER_FRAME_LEN {
            return None;
        }
        let crc = u32::from_le_bytes(buf[32..36].try_into().unwrap());
        if crc != crc32(&buf[0..32]) {
            return None;
        }
        Some(Self {
            request: u64::from_le_bytes(buf[0..8].try_into().unwrap()),
            token: u64::from_le_bytes(buf[8..16].try_into().unwrap()),
            planned: u32::from_le_bytes(buf[16..20].try_into().unwrap()),
            released: u32::from_le_bytes(buf[20..24].try_into().unwrap()),
            eps: f64::from_bits(u64::from_le_bytes(buf[24..32].try_into().unwrap())),
        })
    }
}

/// Per-request merged state: the maximum-released record seen.
#[derive(Clone, Copy, Debug)]
struct ReqState {
    token: u64,
    released: u32,
    eps: f64,
}

#[derive(Debug)]
struct LedgerInner {
    file: File,
    policy: FsyncPolicy,
    unsynced: u32,
    /// request id → max-merged state.
    requests: HashMap<u64, ReqState>,
    /// dataset token → Σ over request maxima of eps.
    spend: HashMap<u64, f64>,
    /// valid frames currently on disk (after any tail truncation).
    frames: u64,
    /// frames dropped by torn-tail truncation at the last `open`.
    truncated: u64,
    /// records refused because their dataset token disagreed with the one
    /// their request id is already charged against (a malformed or
    /// cross-wired record — merging it would corrupt both datasets'
    /// totals, so it is dropped instead).
    rejected: u64,
    /// Next request id this ledger will hand out
    /// ([`EpsLedger::allocate_request_id`]): one past the highest id ever
    /// seen on disk, so ids stay unique across process lifetimes — a
    /// restarted service can never reuse a dead process's id and have its
    /// charge swallowed as a stale replay by the max-merge.
    next_request: u64,
}

impl LedgerInner {
    /// Does `r` claim a dataset other than the one its request id is
    /// already recorded against? A request charges exactly one dataset for
    /// its whole lifetime; anything else is a corrupt or cross-wired
    /// record.
    fn token_conflict(&self, r: &LedgerRecord) -> bool {
        self.requests.get(&r.request).is_some_and(|st| st.token != r.token)
    }

    /// Merge a record into the in-memory view. Max-merge: only a strictly
    /// larger released count for a known request moves the dataset spend
    /// (by the eps delta); duplicates and stale replays are no-ops, and a
    /// record whose token disagrees with the request's recorded dataset
    /// is rejected outright (applying its delta to a *different* token
    /// would corrupt both datasets' totals).
    fn merge(&mut self, r: &LedgerRecord) -> bool {
        self.next_request = self.next_request.max(r.request.saturating_add(1));
        match self.requests.get_mut(&r.request) {
            Some(st) => {
                if st.token != r.token {
                    self.rejected += 1;
                    eprintln!(
                        "[dpfw] eps ledger: record for request {} charges dataset \
                         {:#x} but the request is recorded against {:#x}; dropped",
                        r.request, r.token, st.token
                    );
                    return false;
                }
                if r.released <= st.released {
                    return false;
                }
                let delta = r.eps - st.eps;
                st.released = r.released;
                st.eps = r.eps;
                *self.spend.entry(r.token).or_insert(0.0) += delta;
                true
            }
            None => {
                self.requests
                    .insert(r.request, ReqState { token: r.token, released: r.released, eps: r.eps });
                *self.spend.entry(r.token).or_insert(0.0) += r.eps;
                true
            }
        }
    }
}

/// The append-only write-ahead ε ledger. All methods take `&self` — one
/// ledger is shared across the worker pool and the ingress via `Arc`.
#[derive(Debug)]
pub struct EpsLedger {
    path: PathBuf,
    inner: Mutex<LedgerInner>,
}

impl EpsLedger {
    /// Open (or create) the ledger at `path`, replaying every valid frame
    /// into the in-memory spend view and truncating a torn tail: the scan
    /// stops at the first frame whose CRC fails or whose length is short,
    /// and the file is cut back to the last valid frame boundary.
    pub fn open(path: impl AsRef<Path>, policy: FsyncPolicy) -> std::io::Result<Self> {
        let path = path.as_ref().to_path_buf();
        let mut file =
            OpenOptions::new().read(true).write(true).create(true).open(&path)?;
        let mut bytes = Vec::new();
        file.read_to_end(&mut bytes)?;
        let mut inner = LedgerInner {
            file,
            policy,
            unsynced: 0,
            requests: HashMap::new(),
            spend: HashMap::new(),
            frames: 0,
            truncated: 0,
            rejected: 0,
            next_request: 0,
        };
        let mut off = 0usize;
        while off + LEDGER_FRAME_LEN <= bytes.len() {
            match LedgerRecord::decode(&bytes[off..off + LEDGER_FRAME_LEN]) {
                Some(r) => {
                    inner.merge(&r);
                    inner.frames += 1;
                    off += LEDGER_FRAME_LEN;
                }
                None => break,
            }
        }
        if off < bytes.len() {
            // torn or corrupt tail: cut back to the last valid boundary
            inner.truncated =
                (bytes.len() - off).div_ceil(LEDGER_FRAME_LEN) as u64;
            inner.file.set_len(off as u64)?;
        }
        inner.file.seek(SeekFrom::End(0))?;
        Ok(Self { path, inner: Mutex::new(inner) })
    }

    /// Append one spend record, durable per the fsync policy, and merge it
    /// into the live view. Write-ahead contract: callers append **before**
    /// releasing the selections the record accounts for. Returns `true`
    /// when the record advanced the merged state (i.e. it was not a
    /// replayed duplicate).
    pub fn append(&self, r: LedgerRecord) -> std::io::Result<bool> {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        if g.token_conflict(&r) {
            // refuse before the write: a cross-wired record must corrupt
            // neither the durable log nor the in-memory totals
            g.rejected += 1;
            let recorded = g.requests[&r.request].token;
            eprintln!(
                "[dpfw] eps ledger: refusing append for request {}: dataset \
                 {:#x} conflicts with recorded {:#x}",
                r.request, r.token, recorded
            );
            return Ok(false);
        }
        g.file.write_all(&r.encode())?;
        g.frames += 1;
        match g.policy {
            FsyncPolicy::Always => g.file.sync_data()?,
            FsyncPolicy::EveryN(n) => {
                g.unsynced += 1;
                if g.unsynced >= n.max(1) {
                    g.file.sync_data()?;
                    g.unsynced = 0;
                }
            }
            FsyncPolicy::Never => {}
        }
        Ok(g.merge(&r))
    }

    /// Force everything appended so far to disk regardless of policy.
    pub fn sync(&self) -> std::io::Result<()> {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        g.file.sync_data()?;
        g.unsynced = 0;
        Ok(())
    }

    /// Cumulative ε charged against a dataset token: the sum over request
    /// ids of each request's maximum recorded spend.
    pub fn spent_for_dataset(&self, token: u64) -> f64 {
        let g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        g.spend.get(&token).copied().unwrap_or(0.0)
    }

    /// The merged (released, eps) state for one request id, if recorded.
    pub fn spent_for_request(&self, request: u64) -> Option<(u32, f64)> {
        let g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        g.requests.get(&request).map(|st| (st.released, st.eps))
    }

    /// Valid frames currently in the log (appends since open included).
    pub fn frames(&self) -> u64 {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).frames
    }

    /// Frames discarded by torn-tail truncation at the last `open`.
    pub fn truncated_frames(&self) -> u64 {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).truncated
    }

    /// Records refused because their dataset token conflicted with the
    /// one their request id is already recorded against (replay + appends
    /// since open).
    pub fn rejected_records(&self) -> u64 {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).rejected
    }

    /// Allocate a request id that is unique across process lifetimes:
    /// strictly above every id this ledger has ever seen on disk (replayed
    /// at `open`) or handed out in this process. The coordinator uses this
    /// — not its per-process result counter — as the ledger idempotency
    /// key, so a restarted service can never collide with a dead process's
    /// recorded request and have a fresh charge silently max-merged away.
    pub fn allocate_request_id(&self) -> u64 {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let id = g.next_request;
        g.next_request += 1;
        id
    }

    /// Distinct request ids recorded.
    pub fn n_requests(&self) -> usize {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).requests.len()
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let p = std::env::temp_dir()
            .join(format!("dpfw-ledger-{}-{}", std::process::id(), name));
        let _ = std::fs::remove_file(&p);
        p
    }

    fn rec(request: u64, token: u64, released: u32, eps: f64) -> LedgerRecord {
        LedgerRecord { request, token, planned: 100, released, eps }
    }

    #[test]
    fn crc32_reference_vector() {
        // the canonical IEEE check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn append_and_reopen_round_trip() {
        let p = tmp("round-trip");
        {
            let l = EpsLedger::open(&p, FsyncPolicy::Always).unwrap();
            assert!(l.append(rec(1, 7, 10, 0.1)).unwrap());
            assert!(l.append(rec(2, 7, 20, 0.3)).unwrap());
            assert!(l.append(rec(3, 8, 5, 0.05)).unwrap());
            assert!((l.spent_for_dataset(7) - 0.4).abs() < 1e-12);
        }
        let l = EpsLedger::open(&p, FsyncPolicy::Always).unwrap();
        assert_eq!(l.frames(), 3);
        assert_eq!(l.truncated_frames(), 0);
        assert!((l.spent_for_dataset(7) - 0.4).abs() < 1e-12);
        assert!((l.spent_for_dataset(8) - 0.05).abs() < 1e-12);
        assert_eq!(l.spent_for_request(2), Some((20, 0.3)));
        assert_eq!(l.spent_for_dataset(999), 0.0);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn max_merge_is_idempotent_per_request() {
        let p = tmp("max-merge");
        let l = EpsLedger::open(&p, FsyncPolicy::Never).unwrap();
        assert!(l.append(rec(1, 7, 10, 0.1)).unwrap());
        // progress record: only the delta moves the dataset spend
        assert!(l.append(rec(1, 7, 30, 0.25)).unwrap());
        assert!((l.spent_for_dataset(7) - 0.25).abs() < 1e-12);
        // exact replay and stale replay are both no-ops
        assert!(!l.append(rec(1, 7, 30, 0.25)).unwrap());
        assert!(!l.append(rec(1, 7, 10, 0.1)).unwrap());
        assert!((l.spent_for_dataset(7) - 0.25).abs() < 1e-12);
        assert_eq!(l.spent_for_request(1), Some((30, 0.25)));
        assert_eq!(l.n_requests(), 1);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn torn_tail_truncates_to_last_valid_frame() {
        let p = tmp("torn-tail");
        {
            let l = EpsLedger::open(&p, FsyncPolicy::Always).unwrap();
            l.append(rec(1, 7, 10, 0.1)).unwrap();
            l.append(rec(2, 7, 20, 0.2)).unwrap();
        }
        // simulate a crash mid-append: half a frame dangling
        {
            let mut f = OpenOptions::new().append(true).open(&p).unwrap();
            f.write_all(&[0xAB; LEDGER_FRAME_LEN / 2]).unwrap();
        }
        let l = EpsLedger::open(&p, FsyncPolicy::Always).unwrap();
        assert_eq!(l.frames(), 2);
        assert_eq!(l.truncated_frames(), 1);
        assert!((l.spent_for_dataset(7) - 0.3).abs() < 1e-12);
        // the truncation is physical: a fresh reopen sees a clean log
        let l2 = EpsLedger::open(&p, FsyncPolicy::Always).unwrap();
        assert_eq!(l2.truncated_frames(), 0);
        assert_eq!(l2.frames(), 2);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn corrupt_tail_byte_drops_only_the_last_frame() {
        let p = tmp("corrupt-tail");
        {
            let l = EpsLedger::open(&p, FsyncPolicy::Always).unwrap();
            l.append(rec(1, 7, 10, 0.1)).unwrap();
            l.append(rec(2, 7, 20, 0.2)).unwrap();
        }
        // flip one byte inside the last frame's payload
        {
            let mut bytes = std::fs::read(&p).unwrap();
            let off = LEDGER_FRAME_LEN + 5;
            bytes[off] ^= 0xFF;
            std::fs::write(&p, &bytes).unwrap();
        }
        let l = EpsLedger::open(&p, FsyncPolicy::Always).unwrap();
        assert_eq!(l.frames(), 1);
        assert_eq!(l.truncated_frames(), 1);
        assert!((l.spent_for_dataset(7) - 0.1).abs() < 1e-12);
        // replaying the lost record after recovery charges it exactly once
        assert!(l.append(rec(2, 7, 20, 0.2)).unwrap());
        assert!(!l.append(rec(2, 7, 20, 0.2)).unwrap());
        assert!((l.spent_for_dataset(7) - 0.3).abs() < 1e-12);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn request_ids_allocate_above_the_durable_high_water_mark() {
        let p = tmp("req-ids");
        {
            let l = EpsLedger::open(&p, FsyncPolicy::Always).unwrap();
            // fresh log: ids start at 0 and never repeat in-process
            assert_eq!(l.allocate_request_id(), 0);
            assert_eq!(l.allocate_request_id(), 1);
            l.append(rec(1, 7, 10, 0.1)).unwrap();
            // an externally chosen id raises the mark past itself
            l.append(rec(40, 7, 5, 0.05)).unwrap();
            assert_eq!(l.allocate_request_id(), 41);
        }
        // "process restart": only recorded ids survive (the unrecorded
        // allocation 0 is free again — no record means no replay hazard),
        // and new ids land strictly above every recorded one
        let l = EpsLedger::open(&p, FsyncPolicy::Always).unwrap();
        assert_eq!(l.allocate_request_id(), 41);
        assert_eq!(l.allocate_request_id(), 42);
        // a fresh charge under the new id is a real charge, not a replay
        assert!(l.append(rec(41, 7, 10, 0.1)).unwrap());
        assert!((l.spent_for_dataset(7) - 0.25).abs() < 1e-12);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn token_conflict_records_are_rejected_not_merged() {
        let p = tmp("token-conflict");
        {
            let l = EpsLedger::open(&p, FsyncPolicy::Always).unwrap();
            assert!(l.append(rec(1, 7, 10, 0.1)).unwrap());
            // same request, different dataset: refused before the write,
            // neither dataset's total moves
            assert!(!l.append(rec(1, 8, 20, 0.2)).unwrap());
            assert_eq!(l.rejected_records(), 1);
            assert!((l.spent_for_dataset(7) - 0.1).abs() < 1e-12);
            assert_eq!(l.spent_for_dataset(8), 0.0);
            assert_eq!(l.spent_for_request(1), Some((10, 0.1)));
            // the refused record was never persisted
            assert_eq!(l.frames(), 1);
        }
        // replay-side guard: hand-craft a log whose second frame
        // cross-wires the request onto another dataset
        {
            let mut f = OpenOptions::new().append(true).open(&p).unwrap();
            f.write_all(&rec(1, 9, 30, 0.3).encode()).unwrap();
        }
        let l = EpsLedger::open(&p, FsyncPolicy::Always).unwrap();
        assert_eq!(l.rejected_records(), 1);
        assert!((l.spent_for_dataset(7) - 0.1).abs() < 1e-12);
        assert_eq!(l.spent_for_dataset(9), 0.0);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn fsync_policies_all_reach_disk_on_sync() {
        for (name, policy) in [
            ("always", FsyncPolicy::Always),
            ("every4", FsyncPolicy::EveryN(4)),
            ("never", FsyncPolicy::Never),
        ] {
            let p = tmp(&format!("policy-{name}"));
            let l = EpsLedger::open(&p, policy).unwrap();
            for k in 0..10u64 {
                l.append(rec(k, 7, 10, 0.01)).unwrap();
            }
            l.sync().unwrap();
            drop(l);
            let l = EpsLedger::open(&p, policy).unwrap();
            assert_eq!(l.frames(), 10);
            assert!((l.spent_for_dataset(7) - 0.1).abs() < 1e-9);
            let _ = std::fs::remove_file(&p);
        }
    }
}
