//! Standalone DP mechanism primitives: the Laplace mechanism for numeric
//! queries and the exponential mechanism for selection. The FW solvers use
//! the scaled-up implementations in [`crate::sampler`]; these exist as
//! small, independently-auditable reference implementations plus the
//! statistical tests that pin down the DP guarantee empirically.

use crate::rng::{dist, Xoshiro256pp};
use crate::sampler::log_sum_exp;

/// Laplace mechanism: release `value + Laplace(sensitivity / epsilon)`.
pub fn laplace_mechanism(
    value: f64,
    sensitivity: f64,
    epsilon: f64,
    rng: &mut Xoshiro256pp,
) -> f64 {
    assert!(sensitivity >= 0.0 && epsilon > 0.0);
    value + dist::laplace(rng, sensitivity / epsilon)
}

/// Exponential mechanism: sample index `j ∝ exp(ε u_j / (2 Δu))` by exact
/// inverse-CDF at log scale (the O(D) reference the BSLS sampler scales
/// up).
pub fn exponential_mechanism(
    utilities: &[f64],
    sensitivity: f64,
    epsilon: f64,
    rng: &mut Xoshiro256pp,
) -> usize {
    assert!(!utilities.is_empty() && sensitivity > 0.0 && epsilon > 0.0);
    let k = epsilon / (2.0 * sensitivity);
    let logw: Vec<f64> = utilities.iter().map(|&u| k * u).collect();
    let z = log_sum_exp(&logw);
    let target = rng.next_f64_open0();
    let mut cum = 0.0;
    for (j, &lw) in logw.iter().enumerate() {
        cum += (lw - z).exp();
        if cum >= target {
            return j;
        }
    }
    logw.len() - 1 // FP residue
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn laplace_mechanism_is_unbiased() {
        let mut rng = Xoshiro256pp::seeded(41);
        let n = 100_000;
        let mean: f64 = (0..n)
            .map(|_| laplace_mechanism(10.0, 1.0, 0.5, &mut rng))
            .sum::<f64>()
            / n as f64;
        assert!((mean - 10.0).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn exp_mech_prefers_high_utility() {
        let mut rng = Xoshiro256pp::seeded(42);
        let u = [0.0, 0.0, 10.0];
        let mut wins = 0;
        for _ in 0..1000 {
            wins += (exponential_mechanism(&u, 1.0, 2.0, &mut rng) == 2) as usize;
        }
        assert!(wins > 990, "wins={wins}");
    }

    /// Empirical ε-DP check: for two neighbouring utility vectors (scores
    /// shifted by ≤ Δu), every outcome's probability ratio must be within
    /// e^ε (sampling tolerance added). This is the mechanism-level privacy
    /// property the whole paper rests on.
    #[test]
    fn exp_mech_probability_ratio_bounded() {
        let mut rng = Xoshiro256pp::seeded(43);
        let eps = 1.0;
        let du = 1.0;
        let u1 = [1.0, 2.0, 3.0, 2.5];
        let u2 = [2.0, 1.0, 2.0, 3.5]; // each coordinate moved by ≤ Δu=1
        let trials = 400_000;
        let mut c1 = [0f64; 4];
        let mut c2 = [0f64; 4];
        for _ in 0..trials {
            c1[exponential_mechanism(&u1, du, eps, &mut rng)] += 1.0;
            c2[exponential_mechanism(&u2, du, eps, &mut rng)] += 1.0;
        }
        for j in 0..4 {
            let p1 = c1[j] / trials as f64;
            let p2 = c2[j] / trials as f64;
            if p1 > 5e-3 && p2 > 5e-3 {
                let ratio = p1 / p2;
                assert!(
                    ratio < (eps as f64).exp() * 1.15 && ratio > (-(eps as f64)).exp() / 1.15,
                    "outcome {j}: ratio {ratio}"
                );
            }
        }
    }

    #[test]
    fn uniform_utilities_uniform_choice() {
        let mut rng = Xoshiro256pp::seeded(44);
        let u = [5.0; 4];
        let mut counts = [0usize; 4];
        for _ in 0..40_000 {
            counts[exponential_mechanism(&u, 1.0, 1.0, &mut rng)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "{counts:?}");
        }
    }
}
