//! Mini property-testing kit. The offline crate cache has no `proptest`,
//! so this module provides the two pieces our invariant tests need:
//! seeded random case generation with automatic seed reporting on failure,
//! and a shrinking-lite retry that narrows numeric sizes.
//!
//! Usage (`no_run`: doctest binaries don't inherit the xla rpath):
//! ```no_run
//! use dpfw::testkit::forall;
//! forall(100, |rng| {
//!     let n = 1 + rng.next_below(20) as usize;
//!     let v: Vec<f64> = (0..n).map(|_| rng.next_f64()).collect();
//!     let sum: f64 = v.iter().sum();
//!     assert!(sum >= 0.0);
//! });
//! ```

pub mod faults;
pub mod io_faults;

use crate::rng::Xoshiro256pp;

/// Run `prop` on `cases` independently-seeded generators. Panics from the
/// property are re-raised with the failing case's seed so it can be
/// replayed exactly (`DPFW_PROP_SEED=<seed>` reruns only that case).
pub fn forall(cases: u64, prop: impl Fn(&mut Xoshiro256pp) + std::panic::RefUnwindSafe) {
    let base: u64 = std::env::var("DPFW_PROP_BASE_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xDEFA_17_5EED);
    if let Ok(one) = std::env::var("DPFW_PROP_SEED") {
        let seed: u64 = one.parse().expect("DPFW_PROP_SEED must be a u64");
        let mut rng = Xoshiro256pp::seeded(seed);
        prop(&mut rng);
        return;
    }
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Xoshiro256pp::seeded(seed);
            prop(&mut rng);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property failed on case {case} (replay with DPFW_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

/// Assert two floats agree to a relative-or-absolute tolerance.
#[track_caller]
pub fn assert_close(a: f64, b: f64, rel: f64, abs: f64) {
    let diff = (a - b).abs();
    let scale = a.abs().max(b.abs());
    assert!(
        diff <= abs + rel * scale,
        "not close: {a} vs {b} (diff {diff}, allowed {})",
        abs + rel * scale
    );
}

/// Assert two slices agree elementwise.
#[track_caller]
pub fn assert_slices_close(a: &[f64], b: &[f64], rel: f64, abs: f64) {
    assert_eq!(a.len(), b.len(), "length mismatch {} vs {}", a.len(), b.len());
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let diff = (x - y).abs();
        let scale = x.abs().max(y.abs());
        assert!(
            diff <= abs + rel * scale,
            "slices differ at {i}: {x} vs {y} (diff {diff})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_all_cases() {
        let count = std::sync::atomic::AtomicU64::new(0);
        forall(25, |_| {
            count.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        });
        assert_eq!(count.load(std::sync::atomic::Ordering::Relaxed), 25);
    }

    #[test]
    fn forall_reports_seed_on_failure() {
        let result = std::panic::catch_unwind(|| {
            forall(10, |rng| {
                // deterministically fails on every case
                let v = rng.next_f64();
                assert!(v < 0.0, "draw {v} is nonnegative");
            });
        });
        let err = result.expect_err("should fail");
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("DPFW_PROP_SEED="), "{msg}");
    }

    #[test]
    fn assert_close_tolerances() {
        assert_close(1.0, 1.0 + 1e-9, 1e-8, 0.0);
        assert_close(0.0, 1e-12, 0.0, 1e-9);
        let r = std::panic::catch_unwind(|| assert_close(1.0, 2.0, 1e-3, 1e-3));
        assert!(r.is_err());
    }
}
