//! Disk-fault injection for the durability plane (DESIGN.md §6.12).
//!
//! [`FaultPlan`](super::faults::FaultPlan) kills *computation* at chosen
//! iterations; [`IoFaultPlane`] kills *storage* at chosen syscalls. The
//! ε ledger ([`crate::dp::ledger::EpsLedger`]) and the checkpoint writer
//! ([`crate::fw::checkpoint`]) thread every write, fsync, and rename
//! through an (optionally armed) plane, so tests can hold the durable
//! plane's invariants under a hostile disk:
//!
//! * [`IoFaultKind::ShortWrite`] — only a prefix of the buffer reaches
//!   the file before the write errors (a torn append: the bytes that
//!   landed must be recovered or truncated, never trusted).
//! * [`IoFaultKind::FsyncFail`] — the data may be in the page cache but
//!   the durability barrier itself failed; after fsync fails once, no
//!   later success may be trusted (the kernel may have dropped the dirty
//!   pages), so the consumers here fail closed permanently.
//! * [`IoFaultKind::Enospc`] — the disk is full before a single byte
//!   lands (`ENOSPC`, raw OS error 28).
//! * [`IoFaultKind::CrashBeforeRename`] / [`CrashAfterRename`] — the
//!   process dies around the atomic-rename commit point of a
//!   tmp+fsync+rename sequence; the survivor must see either the old
//!   state (before) or the new state (after), never a blend.
//!
//! The plane mirrors `FaultPlan`'s shape: disarmed by default (one
//! `Option` discriminant test per hook), a firing budget shared across
//! clones so a retried operation deterministically succeeds, and
//! kind-scoped hooks that never cross-trigger.
//!
//! **Degradation contract** (DESIGN.md §6.12): when a ledger write fails
//! under this plane — or for real — the ledger marks itself failed and
//! the ingress budget gate *fails closed*: private work against a
//! budgeted dataset is shed, never run unmetered.

use std::io::{self, Write};
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;

/// Which storage syscall to break, and how.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IoFaultKind {
    /// The next guarded write persists only the first half of its buffer,
    /// then errors — a torn frame on disk.
    ShortWrite,
    /// The next guarded fsync reports failure (data possibly lost in the
    /// page cache).
    FsyncFail,
    /// The next guarded write fails with `ENOSPC` before any byte lands.
    Enospc,
    /// Abort a tmp+fsync+rename commit just *before* the rename: the tmp
    /// file is complete on disk but the target was never replaced.
    CrashBeforeRename,
    /// Abort just *after* the rename committed: the target is the new
    /// content, but post-commit bookkeeping (dir fsync, in-memory swap)
    /// never ran in the dying process.
    CrashAfterRename,
}

#[derive(Debug)]
struct IoFaultInner {
    kind: IoFaultKind,
    /// Firings before the plane disarms itself.
    times: u32,
    /// Shared across clones, so a reopened/retried consumer sees the
    /// spent budget and runs clean.
    fired: AtomicU32,
}

impl IoFaultInner {
    fn fire(&self) -> bool {
        self.fired
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |n| {
                (n < self.times).then_some(n + 1)
            })
            .is_ok()
    }
}

/// A deterministic storage-fault plane; the default plane is disarmed and
/// passes every operation through untouched.
#[derive(Clone, Debug, Default)]
pub struct IoFaultPlane {
    inner: Option<Arc<IoFaultInner>>,
}

impl IoFaultPlane {
    /// The disarmed plane (what every production ledger/checkpoint runs
    /// with).
    pub fn none() -> Self {
        Self { inner: None }
    }

    /// Arm `kind` to fire exactly once.
    pub fn once(kind: IoFaultKind) -> Self {
        Self::times(kind, 1)
    }

    /// Arm `kind` to fire on the first `times` opportunities, then disarm.
    pub fn times(kind: IoFaultKind, times: u32) -> Self {
        Self {
            inner: Some(Arc::new(IoFaultInner {
                kind,
                times,
                fired: AtomicU32::new(0),
            })),
        }
    }

    pub fn is_armed(&self) -> bool {
        self.inner.is_some()
    }

    /// How many times this plane has fired (across all clones).
    pub fn firings(&self) -> u32 {
        self.inner.as_deref().map_or(0, |i| i.fired.load(Ordering::SeqCst))
    }

    /// Guarded `write_all`: injects [`IoFaultKind::Enospc`] (no byte
    /// lands) or [`IoFaultKind::ShortWrite`] (half the buffer lands, then
    /// the error) when armed; otherwise a plain `write_all`.
    pub fn write_all(&self, w: &mut impl Write, buf: &[u8]) -> io::Result<()> {
        if let Some(inner) = self.inner.as_deref() {
            match inner.kind {
                IoFaultKind::Enospc if inner.fire() => {
                    // ENOSPC before any byte reaches the file
                    return Err(io::Error::from_raw_os_error(28));
                }
                IoFaultKind::ShortWrite if inner.fire() => {
                    w.write_all(&buf[..buf.len() / 2])?;
                    return Err(io::Error::new(
                        io::ErrorKind::WriteZero,
                        "injected short write (torn frame)",
                    ));
                }
                _ => {}
            }
        }
        w.write_all(buf)
    }

    /// Guarded fsync barrier: callers invoke this *before* the real
    /// `sync_data`/`sync_all`; an injected [`IoFaultKind::FsyncFail`]
    /// stands in for the kernel reporting the barrier failed.
    pub fn on_fsync(&self) -> io::Result<()> {
        match self.inner.as_deref() {
            Some(inner) if inner.kind == IoFaultKind::FsyncFail && inner.fire() => {
                Err(io::Error::other("injected fsync failure"))
            }
            _ => Ok(()),
        }
    }

    /// Commit-point hook, called immediately before the `rename` of a
    /// tmp+fsync+rename sequence. An error simulates the process dying
    /// here: the caller must abandon the commit with the tmp file left on
    /// disk (a restarted process cleans it up).
    pub fn before_rename(&self) -> io::Result<()> {
        match self.inner.as_deref() {
            Some(inner)
                if inner.kind == IoFaultKind::CrashBeforeRename && inner.fire() =>
            {
                Err(io::Error::other("injected crash before rename"))
            }
            _ => Ok(()),
        }
    }

    /// Commit-point hook, called immediately after the `rename`
    /// committed. An error simulates the process dying here: the rename
    /// is durable (the target *is* the new content) but nothing after it
    /// ran in the dying process.
    pub fn after_rename(&self) -> io::Result<()> {
        match self.inner.as_deref() {
            Some(inner)
                if inner.kind == IoFaultKind::CrashAfterRename && inner.fire() =>
            {
                Err(io::Error::other("injected crash after rename"))
            }
            _ => Ok(()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disarmed_plane_is_inert() {
        let p = IoFaultPlane::none();
        assert!(!p.is_armed());
        let mut sink = Vec::new();
        p.write_all(&mut sink, &[1, 2, 3, 4]).unwrap();
        assert_eq!(sink, vec![1, 2, 3, 4]);
        p.on_fsync().unwrap();
        p.before_rename().unwrap();
        p.after_rename().unwrap();
        assert_eq!(p.firings(), 0);
    }

    #[test]
    fn short_write_lands_half_then_errors_once() {
        let p = IoFaultPlane::once(IoFaultKind::ShortWrite);
        let mut sink = Vec::new();
        let err = p.write_all(&mut sink, &[1, 2, 3, 4]).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::WriteZero);
        assert_eq!(sink, vec![1, 2], "exactly the torn prefix landed");
        // budget spent: the retry goes through whole
        p.write_all(&mut sink, &[5, 6]).unwrap();
        assert_eq!(sink, vec![1, 2, 5, 6]);
        assert_eq!(p.firings(), 1);
    }

    #[test]
    fn enospc_fails_before_any_byte() {
        let p = IoFaultPlane::once(IoFaultKind::Enospc);
        let mut sink = Vec::new();
        let err = p.write_all(&mut sink, &[1, 2, 3]).unwrap_err();
        assert_eq!(err.raw_os_error(), Some(28), "ENOSPC");
        assert!(sink.is_empty(), "no byte reached the file");
        p.write_all(&mut sink, &[1]).unwrap();
        assert_eq!(sink, vec![1]);
    }

    #[test]
    fn fsync_and_rename_hooks_are_kind_scoped() {
        let f = IoFaultPlane::once(IoFaultKind::FsyncFail);
        let mut sink = Vec::new();
        f.write_all(&mut sink, &[9]).unwrap(); // writes unaffected
        f.before_rename().unwrap();
        assert!(f.on_fsync().is_err());
        f.on_fsync().unwrap(); // disarmed after one firing

        let b = IoFaultPlane::once(IoFaultKind::CrashBeforeRename);
        b.on_fsync().unwrap();
        b.after_rename().unwrap();
        assert!(b.before_rename().is_err());
        b.before_rename().unwrap();

        let a = IoFaultPlane::once(IoFaultKind::CrashAfterRename);
        a.before_rename().unwrap();
        assert!(a.after_rename().is_err());
        a.after_rename().unwrap();
    }

    #[test]
    fn budget_is_shared_across_clones() {
        let p = IoFaultPlane::times(IoFaultKind::Enospc, 2);
        let clone = p.clone();
        let mut sink = Vec::new();
        assert!(p.write_all(&mut sink, &[1]).is_err());
        assert!(clone.write_all(&mut sink, &[1]).is_err());
        assert!(p.write_all(&mut sink, &[1]).is_ok(), "budget of 2 spent");
        assert_eq!(clone.firings(), 2);
    }
}
