//! Deterministic fault injection for the coordinator/solver resilience
//! tests and benches (DESIGN.md §6.9).
//!
//! A [`FaultPlan`] rides inside `FwConfig` (default: disarmed, a single
//! `Option` discriminant test per iteration — same zero-cost shape as
//! `CancelToken`). Tests arm it with one [`FaultKind`] and a firing
//! budget; once the budget is spent the plan disarms itself, so a
//! seed-pinned retry of the same job deterministically succeeds. The
//! firing counter is shared across clones (`Arc`), which is what makes
//! that work: the retried job carries a *clone* of the config, so its
//! plan sees the already-spent budget.
//!
//! The four kinds cover the failure shapes the serving tier must survive:
//!
//! * [`FaultKind::PanicAt`] — unwind out of the solver mid-iteration
//!   (caught by the worker's `catch_unwind`; exercises retries).
//! * [`FaultKind::StallAt`] — sleep inside an iteration (exercises
//!   deadlines firing *while running*, and drain timeouts).
//! * [`FaultKind::PoisonWorkspace`] — scribble the pooled buffers before
//!   the job runs (exercises the workspace bit-exact-reuse contract: a
//!   correct solver must fully reinitialize what it takes).
//! * [`FaultKind::DieAbruptly`] — the worker thread returns without
//!   unwinding and without sending results (exercises supervision:
//!   respawn + owed-id failure).
//! * [`FaultKind::PanicInBootstrap`] / [`FaultKind::StallInBootstrap`] —
//!   fire inside the dense bootstrap `α = Xᵀq̄` itself (the
//!   [`FaultPlan::on_bootstrap`] hook), while the run may hold the
//!   ingress-scoped bootstrap-hub leadership lease (DESIGN.md §6.10).
//!   The stall holds the lease long enough for followers to attach
//!   deterministically; the panic exercises follower detach-and-re-lead.
//! * [`FaultKind::CrashAt`] — simulated process crash mid-solve: unwinds
//!   with the typed [`CrashPayload`] marker so the worker loop can tell
//!   "this worker is dead, recover from the durable checkpoint" apart
//!   from an ordinary caught panic (DESIGN.md §6.11). The module also
//!   exposes [`truncate_file`]/[`corrupt_byte`] for torn-write injection
//!   against the ε ledger and checkpoint files.

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// What to inject, and where.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Panic at the start of solver iteration `iter` (1-based, like the
    /// paper's t index).
    PanicAt { iter: usize },
    /// Sleep `ms` milliseconds at the start of solver iteration `iter`.
    StallAt { iter: usize, ms: u64 },
    /// Fill the worker's pooled workspace buffers with garbage before
    /// running the job.
    PoisonWorkspace,
    /// The worker thread dies without unwinding (no results sent, no
    /// panic to catch) before running the job.
    DieAbruptly,
    /// Sleep `after_ms`, then panic, *inside* the dense bootstrap — after
    /// the run claimed bootstrap-hub leadership but before it published.
    /// The sleep gives concurrently-submitted followers a deterministic
    /// window to attach to the doomed leader.
    PanicInBootstrap { after_ms: u64 },
    /// Sleep `ms` inside the dense bootstrap, then continue normally —
    /// holds hub leadership long enough for followers to observe the
    /// pending slot and take the wait path.
    StallInBootstrap { ms: u64 },
    /// Simulated crash at the start of solver iteration `iter` (1-based):
    /// unwinds with the typed [`CrashPayload`] marker instead of a plain
    /// message. The pool's worker loop recognizes the marker and treats
    /// the worker as *dead* — no results, no retry — so the supervisor's
    /// respawn path must recover the job from its durable checkpoint
    /// (DESIGN.md §6.11). Budget-gated like every other kind, so the
    /// resumed attempt (a config clone sharing this plan) runs clean.
    CrashAt { iter: usize },
}

/// The panic payload [`FaultKind::CrashAt`] unwinds with. Catchers
/// downcast to this type to distinguish a simulated crash (worker died;
/// recover from the checkpoint) from an ordinary solver panic (worker
/// survives; seed-pinned retry).
#[derive(Clone, Copy, Debug)]
pub struct CrashPayload {
    /// The 1-based iteration the crash fired at.
    pub iter: usize,
}

#[derive(Debug)]
struct FaultInner {
    kind: FaultKind,
    /// How many times the fault fires before disarming.
    times: u32,
    /// Firings so far — shared across clones so retries observe the
    /// spent budget.
    fired: AtomicU32,
}

impl FaultInner {
    /// Try to consume one firing; `false` once the budget is spent.
    fn fire(&self) -> bool {
        self.fired
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |n| {
                (n < self.times).then_some(n + 1)
            })
            .is_ok()
    }
}

/// A deterministic fault plan; the default plan is disarmed and injects
/// nothing.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    inner: Option<Arc<FaultInner>>,
}

impl FaultPlan {
    /// The disarmed plan (what every production config carries).
    pub fn none() -> Self {
        Self { inner: None }
    }

    /// Arm `kind` to fire exactly once.
    pub fn once(kind: FaultKind) -> Self {
        Self::times(kind, 1)
    }

    /// Arm `kind` to fire on the first `times` opportunities, then disarm.
    pub fn times(kind: FaultKind, times: u32) -> Self {
        Self {
            inner: Some(Arc::new(FaultInner { kind, times, fired: AtomicU32::new(0) })),
        }
    }

    pub fn is_armed(&self) -> bool {
        self.inner.is_some()
    }

    /// How many times this plan has fired (across all clones).
    pub fn firings(&self) -> u32 {
        self.inner.as_deref().map_or(0, |i| i.fired.load(Ordering::SeqCst))
    }

    /// Solver hook, polled at the top of each iteration `t` (1-based).
    /// Panics (PanicAt) or sleeps (StallAt) when armed for this iteration
    /// and the firing budget allows.
    #[inline]
    pub fn on_iteration(&self, t: usize) {
        let Some(inner) = self.inner.as_deref() else { return };
        match inner.kind {
            FaultKind::PanicAt { iter } if iter == t => {
                if inner.fire() {
                    panic!("fault injection: panic at iteration {t}");
                }
            }
            FaultKind::StallAt { iter, ms } if iter == t => {
                if inner.fire() {
                    std::thread::sleep(Duration::from_millis(ms));
                }
            }
            FaultKind::CrashAt { iter } if iter == t => {
                if inner.fire() {
                    std::panic::panic_any(CrashPayload { iter: t });
                }
            }
            _ => {}
        }
    }

    /// Solver hook, called once from inside each dense-bootstrap compute
    /// block (all four solver bodies), after the run has claimed hub
    /// leadership for the bootstrap but before it publishes. Panics
    /// (PanicInBootstrap, after its stall window) or sleeps
    /// (StallInBootstrap) when armed and the firing budget allows.
    pub fn on_bootstrap(&self) {
        let Some(inner) = self.inner.as_deref() else { return };
        match inner.kind {
            FaultKind::PanicInBootstrap { after_ms } => {
                if inner.fire() {
                    if after_ms > 0 {
                        std::thread::sleep(Duration::from_millis(after_ms));
                    }
                    panic!("fault injection: panic in bootstrap");
                }
            }
            FaultKind::StallInBootstrap { ms } => {
                if inner.fire() {
                    std::thread::sleep(Duration::from_millis(ms));
                }
            }
            _ => {}
        }
    }

    /// Worker hook: should the pooled workspace be poisoned before this
    /// job runs? Consumes one firing.
    pub fn take_poison(&self) -> bool {
        match self.inner.as_deref() {
            Some(inner) if inner.kind == FaultKind::PoisonWorkspace => inner.fire(),
            _ => false,
        }
    }

    /// Worker hook: should the worker thread die (return without sending
    /// results) instead of running this job? Consumes one firing.
    pub fn take_worker_death(&self) -> bool {
        match self.inner.as_deref() {
            Some(inner) if inner.kind == FaultKind::DieAbruptly => inner.fire(),
            _ => false,
        }
    }
}

/// Torn-write injection: truncate `path` to `len` bytes, simulating a
/// crash mid-append (the tail of the last record never reached disk).
/// Recovery tests point the ε ledger / checkpoint readers at the result.
pub fn truncate_file(path: &std::path::Path, len: u64) -> std::io::Result<()> {
    let f = std::fs::OpenOptions::new().write(true).open(path)?;
    f.set_len(len)
}

/// Bit-rot injection: XOR the byte at `offset` in `path` with `0xFF`,
/// simulating in-place corruption that framing CRCs must catch.
pub fn corrupt_byte(path: &std::path::Path, offset: u64) -> std::io::Result<()> {
    use std::io::{Read, Seek, SeekFrom, Write};
    let mut f = std::fs::OpenOptions::new().read(true).write(true).open(path)?;
    let mut b = [0u8; 1];
    f.seek(SeekFrom::Start(offset))?;
    f.read_exact(&mut b)?;
    b[0] ^= 0xFF;
    f.seek(SeekFrom::Start(offset))?;
    f.write_all(&b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disarmed_plan_is_inert() {
        let p = FaultPlan::none();
        assert!(!p.is_armed());
        p.on_iteration(1);
        assert!(!p.take_poison());
        assert!(!p.take_worker_death());
        assert_eq!(p.firings(), 0);
    }

    #[test]
    fn panic_at_fires_once_then_disarms() {
        let p = FaultPlan::once(FaultKind::PanicAt { iter: 3 });
        p.on_iteration(1);
        p.on_iteration(2); // wrong iteration: no firing
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            p.on_iteration(3);
        }))
        .expect_err("must panic at iter 3");
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("iteration 3"), "{msg}");
        assert_eq!(p.firings(), 1);
        p.on_iteration(3); // budget spent: the retry sails through
        assert_eq!(p.firings(), 1);
    }

    #[test]
    fn budget_is_shared_across_clones() {
        let p = FaultPlan::times(FaultKind::DieAbruptly, 2);
        let clone = p.clone();
        assert!(p.take_worker_death());
        assert!(clone.take_worker_death());
        assert!(!p.take_worker_death(), "budget of 2 spent across clones");
        assert_eq!(clone.firings(), 2);
    }

    #[test]
    fn kinds_do_not_cross_trigger() {
        let p = FaultPlan::once(FaultKind::PoisonWorkspace);
        p.on_iteration(1); // not an iteration fault: no-op
        assert!(!p.take_worker_death());
        assert!(p.take_poison());
        assert!(!p.take_poison(), "single firing");
    }

    #[test]
    fn bootstrap_hooks_fire_only_in_bootstrap() {
        let p = FaultPlan::once(FaultKind::PanicInBootstrap { after_ms: 0 });
        p.on_iteration(1); // iteration hook must not cross-trigger
        assert_eq!(p.firings(), 0);
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            p.on_bootstrap();
        }))
        .expect_err("must panic in bootstrap");
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("bootstrap"), "{msg}");
        assert_eq!(p.firings(), 1);
        p.on_bootstrap(); // budget spent: the retry's bootstrap succeeds
        assert_eq!(p.firings(), 1);

        let s = FaultPlan::once(FaultKind::StallInBootstrap { ms: 1 });
        let start = std::time::Instant::now();
        s.on_bootstrap();
        assert!(start.elapsed() >= Duration::from_millis(1));
        s.on_bootstrap(); // disarmed now
        assert_eq!(s.firings(), 1);
        // the plain-iteration kinds are inert on the bootstrap hook
        let q = FaultPlan::once(FaultKind::PanicAt { iter: 1 });
        q.on_bootstrap();
        assert_eq!(q.firings(), 0);
    }

    #[test]
    fn crash_at_unwinds_with_the_typed_marker() {
        let p = FaultPlan::once(FaultKind::CrashAt { iter: 2 });
        p.on_iteration(1); // wrong iteration: no firing
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            p.on_iteration(2);
        }))
        .expect_err("must crash at iter 2");
        let payload = err.downcast_ref::<CrashPayload>().expect("typed marker");
        assert_eq!(payload.iter, 2);
        assert_eq!(p.firings(), 1);
        p.on_iteration(2); // budget spent: the resumed attempt runs clean
        assert_eq!(p.firings(), 1);
    }

    #[test]
    fn torn_write_helpers_mutate_the_file() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("fw-faults-helpers-{}.bin", std::process::id()));
        std::fs::write(&path, [1u8, 2, 3, 4, 5, 6]).unwrap();
        super::corrupt_byte(&path, 2).unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), vec![1, 2, !3u8, 4, 5, 6]);
        super::truncate_file(&path, 4).unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), vec![1, 2, !3u8, 4]);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn stall_at_sleeps_without_panicking() {
        let p = FaultPlan::once(FaultKind::StallAt { iter: 1, ms: 1 });
        let start = std::time::Instant::now();
        p.on_iteration(1);
        assert!(start.elapsed() >= Duration::from_millis(1));
        let start = std::time::Instant::now();
        p.on_iteration(1); // disarmed now
        assert!(start.elapsed() < Duration::from_millis(1));
    }
}
