//! Fibonacci heap (min-heap, f64 keys, `usize` items) — Algorithm 3's
//! backing structure.
//!
//! Arena-allocated: nodes live in a `Vec`, linked by `u32` indices instead
//! of pointers. A slot map from item id → node index supports
//! `decrease_key(item, …)` in O(1) lookups; the arena recycles freed slots
//! so a full train run does not grow memory beyond the live node count.
//!
//! This *is* the cache-hostile structure the paper measures: pops chase
//! parent/child/sibling links all over the arena. The benches
//! (`benches/selectors.rs`) show exactly the constant-factor gap vs the
//! binary heap and the BSLS sampler that the paper reports.

use super::DecreaseKeyHeap;

const NIL: u32 = u32::MAX;

#[derive(Clone, Debug)]
struct Node {
    key: f64,
    item: usize,
    parent: u32,
    child: u32,
    left: u32,
    right: u32,
    degree: u32,
    mark: bool,
}

#[derive(Clone, Debug, Default)]
pub struct FibonacciHeap {
    arena: Vec<Node>,
    free: Vec<u32>,
    /// item id -> arena index (NIL when absent)
    slot: Vec<u32>,
    min: u32,
    len: usize,
    /// scratch for consolidate, kept to avoid realloc
    degree_table: Vec<u32>,
}

impl FibonacciHeap {
    pub fn new() -> Self {
        Self { arena: vec![], free: vec![], slot: vec![], min: NIL, len: 0, degree_table: vec![] }
    }

    /// Pre-size the item slot map for items in `[0, n_items)`.
    pub fn with_capacity(n_items: usize) -> Self {
        let mut h = Self::new();
        h.slot = vec![NIL; n_items];
        h.arena.reserve(n_items);
        h
    }

    pub fn contains(&self, item: usize) -> bool {
        item < self.slot.len() && self.slot[item] != NIL
    }

    fn alloc(&mut self, item: usize, key: f64) -> u32 {
        let node = Node {
            key,
            item,
            parent: NIL,
            child: NIL,
            left: NIL,
            right: NIL,
            degree: 0,
            mark: false,
        };
        let idx = if let Some(i) = self.free.pop() {
            self.arena[i as usize] = node;
            i
        } else {
            self.arena.push(node);
            (self.arena.len() - 1) as u32
        };
        if item >= self.slot.len() {
            self.slot.resize(item + 1, NIL);
        }
        self.slot[item] = idx;
        idx
    }

    /// Splice node `x` into the circular list containing `at` (as `at`'s
    /// right neighbor). If `at == NIL`, makes `x` a singleton list.
    fn splice(&mut self, x: u32, at: u32) {
        if at == NIL {
            self.arena[x as usize].left = x;
            self.arena[x as usize].right = x;
        } else {
            let r = self.arena[at as usize].right;
            self.arena[x as usize].left = at;
            self.arena[x as usize].right = r;
            self.arena[at as usize].right = x;
            self.arena[r as usize].left = x;
        }
    }

    /// Remove node `x` from its sibling list (does not touch parent.child).
    fn unsplice(&mut self, x: u32) {
        let l = self.arena[x as usize].left;
        let r = self.arena[x as usize].right;
        self.arena[l as usize].right = r;
        self.arena[r as usize].left = l;
    }

    /// Make `y` a child of `x` (both roots, key[y] >= key[x]).
    fn link(&mut self, y: u32, x: u32) {
        self.unsplice(y);
        let child = self.arena[x as usize].child;
        self.arena[y as usize].parent = x;
        self.arena[y as usize].mark = false;
        if child == NIL {
            self.arena[y as usize].left = y;
            self.arena[y as usize].right = y;
            self.arena[x as usize].child = y;
        } else {
            self.splice(y, child);
        }
        self.arena[x as usize].degree += 1;
    }

    fn consolidate(&mut self) {
        if self.min == NIL {
            return;
        }
        let max_degree = (self.len as f64).log2() as usize + 3;
        self.degree_table.clear();
        self.degree_table.resize(max_degree, NIL);
        // collect current roots
        let mut roots: Vec<u32> = Vec::with_capacity(16);
        let start = self.min;
        let mut cur = start;
        loop {
            roots.push(cur);
            cur = self.arena[cur as usize].right;
            if cur == start {
                break;
            }
        }
        let mut table = std::mem::take(&mut self.degree_table);
        for &mut mut x in roots.iter_mut() {
            let mut d = self.arena[x as usize].degree as usize;
            while table[d] != NIL {
                let mut y = table[d];
                if self.arena[y as usize].key < self.arena[x as usize].key {
                    std::mem::swap(&mut x, &mut y);
                }
                self.link(y, x);
                table[d] = NIL;
                d += 1;
                if d >= table.len() {
                    table.resize(d + 1, NIL);
                }
            }
            table[d] = x;
        }
        // rebuild root list from the table, track min
        self.min = NIL;
        for &t in table.iter() {
            if t == NIL {
                continue;
            }
            self.arena[t as usize].parent = NIL;
            if self.min == NIL {
                self.splice(t, NIL);
                self.min = t;
            } else {
                self.splice(t, self.min);
                if self.arena[t as usize].key < self.arena[self.min as usize].key {
                    self.min = t;
                }
            }
        }
        table.clear();
        self.degree_table = table;
    }

    fn cut(&mut self, x: u32, parent: u32) {
        // remove x from parent's child list
        if self.arena[parent as usize].child == x {
            let r = self.arena[x as usize].right;
            self.arena[parent as usize].child = if r == x { NIL } else { r };
        }
        self.unsplice(x);
        self.arena[parent as usize].degree -= 1;
        // add to root list
        self.splice(x, self.min);
        self.arena[x as usize].parent = NIL;
        self.arena[x as usize].mark = false;
    }

    fn cascading_cut(&mut self, mut y: u32) {
        loop {
            let z = self.arena[y as usize].parent;
            if z == NIL {
                break;
            }
            if !self.arena[y as usize].mark {
                self.arena[y as usize].mark = true;
                break;
            }
            self.cut(y, z);
            y = z;
        }
    }
}

impl DecreaseKeyHeap for FibonacciHeap {
    fn push(&mut self, item: usize, key: f64) {
        debug_assert!(!self.contains(item), "item {item} already in heap");
        let x = self.alloc(item, key);
        self.splice(x, self.min);
        if self.min == NIL || key < self.arena[self.min as usize].key {
            self.min = x;
        }
        self.len += 1;
    }

    fn pop_min(&mut self) -> Option<(usize, f64)> {
        if self.min == NIL {
            return None;
        }
        let z = self.min;
        let (item, key) = {
            let n = &self.arena[z as usize];
            (n.item, n.key)
        };
        // promote children to the root list
        let mut child = self.arena[z as usize].child;
        if child != NIL {
            // walk the child ring, collecting first (can't splice while walking)
            let mut kids = Vec::with_capacity(self.arena[z as usize].degree as usize);
            let start = child;
            loop {
                kids.push(child);
                child = self.arena[child as usize].right;
                if child == start {
                    break;
                }
            }
            for k in kids {
                self.arena[k as usize].parent = NIL;
                self.splice(k, self.min);
            }
        }
        // remove z from root list
        let right = self.arena[z as usize].right;
        self.unsplice(z);
        if right == z {
            self.min = NIL;
        } else {
            self.min = right;
            self.consolidate();
        }
        self.len -= 1;
        self.slot[item] = NIL;
        self.free.push(z);
        Some((item, key))
    }

    fn peek_key(&self) -> Option<f64> {
        if self.min == NIL {
            None
        } else {
            Some(self.arena[self.min as usize].key)
        }
    }

    fn decrease_key(&mut self, item: usize, key: f64) {
        let x = self.slot.get(item).copied().unwrap_or(NIL);
        assert!(x != NIL, "decrease_key on absent item {item}");
        if key >= self.arena[x as usize].key {
            return; // not a decrease — Alg 3 ignores these by design
        }
        self.arena[x as usize].key = key;
        let parent = self.arena[x as usize].parent;
        if parent != NIL && key < self.arena[parent as usize].key {
            self.cut(x, parent);
            self.cascading_cut(parent);
        }
        if key < self.arena[self.min as usize].key {
            self.min = x;
        }
    }

    fn key_of(&self, item: usize) -> Option<f64> {
        let x = self.slot.get(item).copied().unwrap_or(NIL);
        if x == NIL {
            None
        } else {
            Some(self.arena[x as usize].key)
        }
    }

    fn clear(&mut self) {
        self.arena.clear();
        self.free.clear();
        self.slot.fill(NIL);
        self.min = NIL;
        self.len = 0;
        self.degree_table.clear();
    }

    fn len(&self) -> usize {
        self.len
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    #[test]
    fn push_pop_sorted() {
        let mut h = FibonacciHeap::new();
        for (i, k) in [5.0, 1.0, 3.0, 2.0, 4.0].into_iter().enumerate() {
            h.push(i, k);
        }
        let mut out = vec![];
        while let Some((_, k)) = h.pop_min() {
            out.push(k);
        }
        assert_eq!(out, vec![1.0, 2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn decrease_key_reorders() {
        let mut h = FibonacciHeap::new();
        h.push(0, 10.0);
        h.push(1, 20.0);
        h.push(2, 30.0);
        assert_eq!(h.pop_min(), Some((0, 10.0))); // forces consolidate
        h.decrease_key(2, 5.0);
        assert_eq!(h.pop_min(), Some((2, 5.0)));
        assert_eq!(h.pop_min(), Some((1, 20.0)));
        assert_eq!(h.pop_min(), None);
    }

    #[test]
    fn decrease_key_ignores_increases() {
        let mut h = FibonacciHeap::new();
        h.push(0, 1.0);
        h.decrease_key(0, 5.0);
        assert_eq!(h.key_of(0), Some(1.0));
    }

    #[test]
    fn reuse_after_pop() {
        let mut h = FibonacciHeap::with_capacity(4);
        h.push(0, 1.0);
        assert_eq!(h.pop_min(), Some((0, 1.0)));
        assert!(!h.contains(0));
        h.push(0, 2.0); // reinsert same item id (Alg 3 does this constantly)
        assert_eq!(h.key_of(0), Some(2.0));
        assert_eq!(h.pop_min(), Some((0, 2.0)));
    }

    /// Randomized differential test against a sorted-vec reference model —
    /// the load-bearing correctness check for the heap.
    #[test]
    fn random_ops_match_reference() {
        let mut rng = Xoshiro256pp::seeded(42);
        for trial in 0..20 {
            let mut h = FibonacciHeap::new();
            let n_items = 200;
            let mut model: Vec<Option<f64>> = vec![None; n_items]; // item -> key
            for step in 0..2000 {
                let op = rng.next_below(10);
                match op {
                    0..=4 => {
                        // push a random absent item
                        let item = rng.next_below(n_items as u64) as usize;
                        if model[item].is_none() {
                            let key = (rng.next_below(1000) as f64) / 10.0;
                            h.push(item, key);
                            model[item] = Some(key);
                        }
                    }
                    5..=7 => {
                        // decrease a random present item
                        let item = rng.next_below(n_items as u64) as usize;
                        if let Some(k) = model[item] {
                            let nk = k - (rng.next_below(100) as f64) / 10.0;
                            h.decrease_key(item, nk);
                            if nk < k {
                                model[item] = Some(nk);
                            }
                        }
                    }
                    _ => {
                        // pop and compare with model min
                        let got = h.pop_min();
                        let want = model
                            .iter()
                            .enumerate()
                            .filter_map(|(i, k)| k.map(|k| (k, i)))
                            .min_by(|a, b| a.partial_cmp(b).unwrap());
                        match (got, want) {
                            (None, None) => {}
                            (Some((gi, gk)), Some((wk, _))) => {
                                assert_eq!(
                                    gk, wk,
                                    "trial {trial} step {step}: popped key {gk} != model min {wk}"
                                );
                                // ties may differ on item; key must match item's model entry
                                assert_eq!(model[gi], Some(gk));
                                model[gi] = None;
                            }
                            other => panic!("trial {trial} step {step}: mismatch {other:?}"),
                        }
                    }
                }
                assert_eq!(h.len(), model.iter().flatten().count());
            }
        }
    }

    #[test]
    fn large_sequence_heapsort() {
        let mut rng = Xoshiro256pp::seeded(9);
        let mut h = FibonacciHeap::with_capacity(5000);
        let mut keys: Vec<f64> = (0..5000).map(|_| rng.next_f64()).collect();
        for (i, &k) in keys.iter().enumerate() {
            h.push(i, k);
        }
        keys.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for want in keys {
            let (_, got) = h.pop_min().unwrap();
            assert_eq!(got, want);
        }
    }
}
