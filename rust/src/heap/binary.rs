//! Indexed binary min-heap with `decrease-key` — the classic, cache-friendly
//! baseline. `O(log n)` for everything, but contiguous storage: this is the
//! structure the empirical priority-queue literature ([33], [34] in the
//! paper) finds beats Fibonacci heaps in practice. Exposed so the benches
//! can quantify that constant-factor story on our workload too.

use super::DecreaseKeyHeap;

const ABSENT: u32 = u32::MAX;

#[derive(Clone, Debug, Default)]
pub struct IndexedBinaryHeap {
    /// (key, item), heap-ordered by key.
    heap: Vec<(f64, usize)>,
    /// item -> position in `heap` (ABSENT when not present).
    pos: Vec<u32>,
}

impl IndexedBinaryHeap {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(n_items: usize) -> Self {
        Self { heap: Vec::with_capacity(n_items), pos: vec![ABSENT; n_items] }
    }

    pub fn contains(&self, item: usize) -> bool {
        item < self.pos.len() && self.pos[item] != ABSENT
    }

    #[inline]
    fn swap_nodes(&mut self, a: usize, b: usize) {
        self.heap.swap(a, b);
        self.pos[self.heap[a].1] = a as u32;
        self.pos[self.heap[b].1] = b as u32;
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if self.heap[i].0 < self.heap[parent].0 {
                self.swap_nodes(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        let n = self.heap.len();
        loop {
            let l = 2 * i + 1;
            let r = 2 * i + 2;
            let mut smallest = i;
            if l < n && self.heap[l].0 < self.heap[smallest].0 {
                smallest = l;
            }
            if r < n && self.heap[r].0 < self.heap[smallest].0 {
                smallest = r;
            }
            if smallest == i {
                break;
            }
            self.swap_nodes(i, smallest);
            i = smallest;
        }
    }
}

impl DecreaseKeyHeap for IndexedBinaryHeap {
    fn push(&mut self, item: usize, key: f64) {
        debug_assert!(!self.contains(item), "item {item} already in heap");
        if item >= self.pos.len() {
            self.pos.resize(item + 1, ABSENT);
        }
        self.heap.push((key, item));
        self.pos[item] = (self.heap.len() - 1) as u32;
        self.sift_up(self.heap.len() - 1);
    }

    fn pop_min(&mut self) -> Option<(usize, f64)> {
        if self.heap.is_empty() {
            return None;
        }
        let (key, item) = self.heap[0];
        let last = self.heap.len() - 1;
        self.swap_nodes(0, last);
        self.heap.pop();
        self.pos[item] = ABSENT;
        if !self.heap.is_empty() {
            self.sift_down(0);
        }
        Some((item, key))
    }

    fn peek_key(&self) -> Option<f64> {
        self.heap.first().map(|&(k, _)| k)
    }

    fn decrease_key(&mut self, item: usize, key: f64) {
        let p = self.pos.get(item).copied().unwrap_or(ABSENT);
        assert!(p != ABSENT, "decrease_key on absent item {item}");
        let p = p as usize;
        if key >= self.heap[p].0 {
            return;
        }
        self.heap[p].0 = key;
        self.sift_up(p);
    }

    fn key_of(&self, item: usize) -> Option<f64> {
        let p = self.pos.get(item).copied().unwrap_or(ABSENT);
        if p == ABSENT {
            None
        } else {
            Some(self.heap[p as usize].0)
        }
    }

    fn clear(&mut self) {
        self.heap.clear();
        self.pos.fill(ABSENT);
    }

    fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::heap::fibonacci::FibonacciHeap;
    use crate::rng::Xoshiro256pp;

    #[test]
    fn heapsort() {
        let mut h = IndexedBinaryHeap::new();
        for (i, k) in [3.0, 1.0, 4.0, 1.5, 5.0].into_iter().enumerate() {
            h.push(i, k);
        }
        let mut out = vec![];
        while let Some((_, k)) = h.pop_min() {
            out.push(k);
        }
        assert_eq!(out, vec![1.0, 1.5, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn decrease_key() {
        let mut h = IndexedBinaryHeap::with_capacity(3);
        h.push(0, 10.0);
        h.push(1, 20.0);
        h.push(2, 30.0);
        h.decrease_key(2, 1.0);
        assert_eq!(h.pop_min(), Some((2, 1.0)));
        h.decrease_key(1, 25.0); // increase → ignored
        assert_eq!(h.key_of(1), Some(20.0));
    }

    /// Differential test: binary heap and Fibonacci heap must agree on the
    /// popped key sequence under identical random workloads.
    #[test]
    fn agrees_with_fibonacci() {
        let mut rng = Xoshiro256pp::seeded(77);
        let n_items = 100;
        let mut bin = IndexedBinaryHeap::with_capacity(n_items);
        let mut fib = FibonacciHeap::with_capacity(n_items);
        let mut present = vec![false; n_items];
        for _ in 0..5000 {
            match rng.next_below(8) {
                0..=3 => {
                    let item = rng.next_below(n_items as u64) as usize;
                    if !present[item] {
                        let key = rng.next_f64();
                        bin.push(item, key);
                        fib.push(item, key);
                        present[item] = true;
                    }
                }
                4..=5 => {
                    let item = rng.next_below(n_items as u64) as usize;
                    if present[item] {
                        let key = bin.key_of(item).unwrap() - rng.next_f64();
                        bin.decrease_key(item, key);
                        fib.decrease_key(item, key);
                    }
                }
                _ => {
                    let a = bin.pop_min();
                    let b = fib.pop_min();
                    match (a, b) {
                        (None, None) => {}
                        (Some((ia, ka)), Some((ib, kb))) => {
                            assert_eq!(ka, kb);
                            present[ia] = false;
                            if ia != ib {
                                // tie on key: both must hold the same key
                                assert_eq!(bin.key_of(ib), Some(kb));
                                // fix divergence: re-align by removing the
                                // same item from both
                                // (keys are continuous so ties are ~impossible)
                                panic!("tie divergence with continuous keys");
                            }
                        }
                        other => panic!("length divergence {other:?}"),
                    }
                    assert_eq!(bin.len(), fib.len());
                }
            }
        }
    }
}
