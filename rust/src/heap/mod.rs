//! Substrate: priority queues with `decrease-key`.
//!
//! Algorithm 3 needs a min-heap over (negated) gradient magnitudes with
//! amortized `O(1)` `decrease-key` and `O(log D)` `pop` — that is the
//! Fibonacci heap ([`fibonacci`]). The indexed binary heap ([`binary`])
//! is the ablation baseline the paper alludes to when citing the classic
//! "Fibonacci heaps lose in practice" results [33, 34]: `O(log D)` for
//! both ops but far better constants/locality.

pub mod binary;
pub mod fibonacci;

/// Common interface so Alg 3's queue maintenance can run over either heap.
pub trait DecreaseKeyHeap {
    /// Insert `item` with `key`; item must not currently be in the heap.
    fn push(&mut self, item: usize, key: f64);
    /// Remove and return the minimum-key entry.
    fn pop_min(&mut self) -> Option<(usize, f64)>;
    /// Smallest key without removing it.
    fn peek_key(&self) -> Option<f64>;
    /// Lower `item`'s key to `key` (no-op if not smaller). Item must be in
    /// the heap.
    fn decrease_key(&mut self, item: usize, key: f64);
    /// Current key of `item`, if present.
    fn key_of(&self, item: usize) -> Option<f64>;
    /// Remove every entry, retaining allocations. After `clear` the heap
    /// behaves exactly like a freshly constructed one over the same item
    /// universe — the workspace selector cache relies on this for
    /// bit-exact run reuse.
    fn clear(&mut self);
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}
