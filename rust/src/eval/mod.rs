//! Evaluation metrics for Table 4: accuracy, AUC, solution sparsity.

/// Classification accuracy (%) of scores `p` (threshold 0.5) against
/// binary labels `y` in {0,1}.
pub fn accuracy(p: &[f64], y: &[f32]) -> f64 {
    assert_eq!(p.len(), y.len());
    assert!(!p.is_empty());
    let correct = p
        .iter()
        .zip(y)
        .filter(|(&pi, &yi)| (pi >= 0.5) == (yi >= 0.5))
        .count();
    100.0 * correct as f64 / p.len() as f64
}

/// Area under the ROC curve (%) via the Mann-Whitney U statistic (rank
/// formulation, ties averaged) — O(n log n).
pub fn auc(p: &[f64], y: &[f32]) -> f64 {
    assert_eq!(p.len(), y.len());
    let n_pos = y.iter().filter(|&&v| v >= 0.5).count();
    let n_neg = y.len() - n_pos;
    if n_pos == 0 || n_neg == 0 {
        return 50.0; // undefined; convention: chance level
    }
    // rank scores (average ranks for ties)
    let mut idx: Vec<usize> = (0..p.len()).collect();
    idx.sort_by(|&a, &b| p[a].partial_cmp(&p[b]).unwrap());
    let mut ranks = vec![0.0f64; p.len()];
    let mut i = 0;
    while i < idx.len() {
        let mut jj = i;
        while jj + 1 < idx.len() && p[idx[jj + 1]] == p[idx[i]] {
            jj += 1;
        }
        let avg_rank = (i + jj) as f64 / 2.0 + 1.0;
        for k in i..=jj {
            ranks[idx[k]] = avg_rank;
        }
        i = jj + 1;
    }
    let rank_sum_pos: f64 = ranks
        .iter()
        .zip(y)
        .filter(|(_, &yi)| yi >= 0.5)
        .map(|(&r, _)| r)
        .sum();
    let u = rank_sum_pos - (n_pos * (n_pos + 1)) as f64 / 2.0;
    100.0 * u / (n_pos as f64 * n_neg as f64)
}

/// Percentage of *zero* coefficients — the paper's Table 4 "Sparsity (%)"
/// column (higher = sparser solution).
pub fn sparsity_pct(w: &[f64]) -> f64 {
    if w.is_empty() {
        return 0.0;
    }
    100.0 * w.iter().filter(|&&v| v == 0.0).count() as f64 / w.len() as f64
}

/// Mean logistic loss of scores under labels (reporting only).
pub fn mean_logloss(p: &[f64], y: &[f32]) -> f64 {
    assert_eq!(p.len(), y.len());
    let eps = 1e-12;
    p.iter()
        .zip(y)
        .map(|(&pi, &yi)| {
            let pi = pi.clamp(eps, 1.0 - eps);
            -(yi as f64 * pi.ln() + (1.0 - yi as f64) * (1.0 - pi).ln())
        })
        .sum::<f64>()
        / p.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_basic() {
        let p = [0.9, 0.1, 0.8, 0.3];
        let y = [1.0, 0.0, 0.0, 0.0];
        assert!((accuracy(&p, &y) - 75.0).abs() < 1e-12);
    }

    #[test]
    fn auc_perfect_and_inverted() {
        let p = [0.1, 0.2, 0.8, 0.9];
        let y = [0.0, 0.0, 1.0, 1.0];
        assert!((auc(&p, &y) - 100.0).abs() < 1e-12);
        let y_inv = [1.0, 1.0, 0.0, 0.0];
        assert!((auc(&p, &y_inv) - 0.0).abs() < 1e-12);
    }

    #[test]
    fn auc_chance_for_random_scores() {
        let p: Vec<f64> = (0..1000).map(|i| ((i * 37) % 101) as f64 / 101.0).collect();
        let y: Vec<f32> = (0..1000).map(|i| ((i * 53) % 2) as f32).collect();
        let a = auc(&p, &y);
        assert!((a - 50.0).abs() < 6.0, "auc={a}");
    }

    #[test]
    fn auc_handles_ties() {
        let p = [0.5, 0.5, 0.5, 0.5];
        let y = [1.0, 0.0, 1.0, 0.0];
        assert!((auc(&p, &y) - 50.0).abs() < 1e-12);
    }

    #[test]
    fn auc_degenerate_labels() {
        assert_eq!(auc(&[0.3, 0.7], &[1.0, 1.0]), 50.0);
    }

    #[test]
    fn sparsity() {
        assert!((sparsity_pct(&[0.0, 1.0, 0.0, 2.0]) - 50.0).abs() < 1e-12);
        assert_eq!(sparsity_pct(&[]), 0.0);
    }

    #[test]
    fn logloss_confident_correct_is_small() {
        let good = mean_logloss(&[0.99, 0.01], &[1.0, 0.0]);
        let bad = mean_logloss(&[0.01, 0.99], &[1.0, 0.0]);
        assert!(good < 0.02 && bad > 4.0);
    }
}
