//! Tiny CSV/JSON writers (serde is unavailable offline; our needs are
//! write-only export of experiment tables and metrics).

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

use anyhow::Result;

/// Minimal CSV table: header + rows of stringified cells, RFC-4180 quoting.
#[derive(Clone, Debug, Default)]
pub struct CsvTable {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl CsvTable {
    pub fn new<S: Into<String>>(header: impl IntoIterator<Item = S>) -> Self {
        Self { header: header.into_iter().map(Into::into).collect(), rows: vec![] }
    }

    pub fn push_row<S: Into<String>>(&mut self, row: impl IntoIterator<Item = S>) {
        let row: Vec<String> = row.into_iter().map(Into::into).collect();
        assert_eq!(row.len(), self.header.len(), "row arity != header arity");
        self.rows.push(row);
    }

    fn quote(cell: &str) -> String {
        if cell.contains([',', '"', '\n']) {
            format!("\"{}\"", cell.replace('"', "\"\""))
        } else {
            cell.to_string()
        }
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        let fmt_row = |row: &[String]| {
            row.iter().map(|c| Self::quote(c)).collect::<Vec<_>>().join(",")
        };
        let _ = writeln!(out, "{}", fmt_row(&self.header));
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row));
        }
        out
    }

    pub fn write_file(&self, path: impl AsRef<Path>) -> Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_string().as_bytes())?;
        Ok(())
    }

    /// Render as an aligned, monospace console table.
    pub fn to_pretty(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt = |row: &[String], widths: &[usize]| {
            row.iter()
                .zip(widths)
                .map(|(c, &w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", fmt(&self.header, &widths));
        let _ = writeln!(out, "{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt(row, &widths));
        }
        out
    }
}

/// Minimal JSON value + writer (objects preserve insertion order).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Self {
        Json::Obj(vec![])
    }

    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Self {
        if let Json::Obj(ref mut pairs) = self {
            pairs.push((key.to_string(), val.into()));
        } else {
            panic!("set on non-object");
        }
        self
    }

    fn escape(s: &str, out: &mut String) {
        out.push('"');
        for ch in s.chars() {
            match ch {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                '\r' => out.push_str("\\r"),
                '\t' => out.push_str("\\t"),
                c if (c as u32) < 0x20 => {
                    let _ = write!(out, "\\u{:04x}", c as u32);
                }
                c => out.push(c),
            }
        }
        out.push('"');
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out);
        out
    }

    fn render_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.is_finite() {
                    let _ = write!(out, "{v}");
                } else {
                    out.push_str("null"); // JSON has no inf/nan
                }
            }
            Json::Str(s) => Self::escape(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.render_into(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Self::escape(k, out);
                    out.push(':');
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }

    pub fn write_file(&self, path: impl AsRef<Path>) -> Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.render())?;
        Ok(())
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Self {
        Json::Arr(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip_quoting() {
        let mut t = CsvTable::new(["a", "b"]);
        t.push_row(["1", "plain"]);
        t.push_row(["2", "has,comma"]);
        t.push_row(["3", "has\"quote"]);
        let s = t.to_string();
        assert!(s.contains("\"has,comma\""));
        assert!(s.contains("\"has\"\"quote\""));
        assert_eq!(s.lines().count(), 4);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn csv_arity_checked() {
        let mut t = CsvTable::new(["a", "b"]);
        t.push_row(["only-one"]);
    }

    #[test]
    fn pretty_is_aligned() {
        let mut t = CsvTable::new(["name", "v"]);
        t.push_row(["x", "1"]);
        t.push_row(["longer", "22"]);
        let p = t.to_pretty();
        assert!(p.contains("longer"));
        assert_eq!(p.lines().count(), 4);
    }

    #[test]
    fn json_rendering() {
        let j = Json::obj()
            .set("name", "rcv1")
            .set("n", 20242usize)
            .set("ok", true)
            .set("items", Json::Arr(vec![Json::Num(1.0), Json::Null]));
        assert_eq!(
            j.render(),
            r#"{"name":"rcv1","n":20242,"ok":true,"items":[1,null]}"#
        );
    }

    #[test]
    fn json_escapes() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.render(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn json_nonfinite_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
        assert_eq!(Json::Num(f64::INFINITY).render(), "null");
    }
}
