//! Substrate: deterministic, seedable random number generation.
//!
//! The offline build has no `rand`/`rand_distr`, so this module implements
//! the generators the paper's algorithms need from scratch:
//! [`SplitMix64`] for seeding, [`Xoshiro256pp`] as the workhorse generator,
//! and the distributions in [`dist`] (uniform, Laplace for the report-noisy-
//! max mechanism, Gumbel for the exponential mechanism via the Gumbel-max
//! trick, exponential, and normal).
//!
//! Everything is deterministic given a seed — experiment reproducibility is
//! a hard requirement for the paper's trajectory-equivalence claims.

pub mod dist;

/// SplitMix64: used to expand a single `u64` seed into generator state.
/// (Vigna's reference constants.)
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256++ — fast, high-quality, 2^256-1 period. The main generator
/// used by every stochastic component (samplers, mechanisms, synth data).
#[derive(Clone, Debug)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    /// Seed via SplitMix64 (the recommended seeding procedure).
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        // All-zero state is invalid; SplitMix64 cannot produce 4 zeros from
        // any seed, but guard anyway.
        let s = if s == [0; 4] { [1, 2, 3, 4] } else { s };
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in (0, 1] — safe as a `ln()` argument.
    #[inline]
    pub fn next_f64_open0(&mut self) -> f64 {
        1.0 - self.next_f64()
    }

    /// Uniform integer in `[0, n)` via Lemire's multiply-shift (unbiased
    /// enough for our non-cryptographic uses; rejection for exactness).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // rejection sampling on the top bits
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Derive an independent child generator (for per-worker streams).
    pub fn fork(&mut self) -> Self {
        Self::seeded(self.next_u64())
    }

    /// Snapshot the raw generator state — the checkpoint plane
    /// (`fw::checkpoint`) persists this so a resumed run continues the
    /// *same* stream, which is what makes crash-resumed DP releases
    /// bit-identical to the uninterrupted run.
    #[inline]
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a [`Xoshiro256pp::state`] snapshot. The
    /// all-zero state is invalid for xoshiro; it cannot arise from a real
    /// snapshot, but guard anyway rather than produce a stuck stream.
    #[inline]
    pub fn from_state(s: [u64; 4]) -> Self {
        let s = if s == [0; 4] { [1, 2, 3, 4] } else { s };
        Self { s }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splitmix_reference_vector() {
        // First outputs for seed 1234567 (computed from the reference
        // implementation semantics above; locks the constants).
        let mut sm = SplitMix64::new(0);
        let first = sm.next_u64();
        // seed 0 first output of SplitMix64 is well-known:
        assert_eq!(first, 0xE220_A839_7B1D_CDAF);
    }

    #[test]
    fn xoshiro_differs_by_seed() {
        let mut a = Xoshiro256pp::seeded(1);
        let mut b = Xoshiro256pp::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut g = Xoshiro256pp::seeded(9);
        for _ in 0..10_000 {
            let v = g.next_f64();
            assert!((0.0..1.0).contains(&v));
            let w = g.next_f64_open0();
            assert!(w > 0.0 && w <= 1.0);
        }
    }

    #[test]
    fn f64_mean_is_half() {
        let mut g = Xoshiro256pp::seeded(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| g.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut g = Xoshiro256pp::seeded(4);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = g.next_below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn state_round_trip_continues_the_stream() {
        let mut g = Xoshiro256pp::seeded(11);
        for _ in 0..17 {
            g.next_u64();
        }
        let snap = g.state();
        let expect: Vec<u64> = (0..32).map(|_| g.next_u64()).collect();
        let mut h = Xoshiro256pp::from_state(snap);
        let got: Vec<u64> = (0..32).map(|_| h.next_u64()).collect();
        assert_eq!(expect, got, "restored stream must continue identically");
        // the all-zero guard produces a working generator
        let mut z = Xoshiro256pp::from_state([0; 4]);
        assert_ne!(z.next_u64(), z.next_u64());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut g = Xoshiro256pp::seeded(5);
        let mut c1 = g.fork();
        let mut c2 = g.fork();
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 4);
    }
}
