//! Distributions over [`Xoshiro256pp`] needed by the DP mechanisms and the
//! synthetic data generators.
//!
//! * [`laplace`] — the Laplace mechanism / report-noisy-max (Alg 1's DP
//!   selection and the paper's §B.2 accounting).
//! * [`gumbel`] — Gumbel-max trick: `argmax_j (u_j + Gumbel)` samples
//!   `j ∝ exp(u_j)`, i.e. exactly the exponential mechanism. Used by the
//!   naive `O(D)` exponential sampler that the BSLS sampler is verified
//!   against.
//! * [`exponential`], [`normal`], [`zipf_like`] — synthetic data shaping.

use super::Xoshiro256pp;

/// Laplace(0, scale): inverse-CDF sampling.
#[inline]
pub fn laplace(rng: &mut Xoshiro256pp, scale: f64) -> f64 {
    debug_assert!(scale >= 0.0);
    let u = rng.next_f64() - 0.5; // (-0.5, 0.5)
    let s = if u >= 0.0 { 1.0 } else { -1.0 };
    -scale * s * (1.0 - 2.0 * u.abs()).max(f64::MIN_POSITIVE).ln()
}

/// Standard Gumbel(0, 1): `-ln(-ln U)`.
#[inline]
pub fn gumbel(rng: &mut Xoshiro256pp) -> f64 {
    -(-rng.next_f64_open0().ln()).max(f64::MIN_POSITIVE).ln()
}

/// Exponential(rate): `-ln(U)/rate`.
#[inline]
pub fn exponential(rng: &mut Xoshiro256pp, rate: f64) -> f64 {
    debug_assert!(rate > 0.0);
    -rng.next_f64_open0().ln() / rate
}

/// Standard normal via Box-Muller (the cos branch).
#[inline]
pub fn normal(rng: &mut Xoshiro256pp) -> f64 {
    let u1 = rng.next_f64_open0();
    let u2 = rng.next_f64();
    (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
}

/// A Zipf-ish heavy-tailed rank distribution over `[0, n)` with exponent
/// `s`, used to give synthetic datasets realistic word-frequency column
/// popularity (text datasets like RCV1/News20 are strongly Zipfian).
/// Sampled by inverse-CDF on the (approximated) continuous Zipf measure.
#[inline]
pub fn zipf_like(rng: &mut Xoshiro256pp, n: usize, s: f64) -> usize {
    debug_assert!(n > 0 && s > 0.0 && s != 1.0);
    // Continuous approximation: P(X <= x) ~ (x^(1-s) - 1) / (n^(1-s) - 1)
    let u = rng.next_f64();
    let p = 1.0 - s;
    // x ∈ [1, n]; shift to 0-based rank
    let x = ((n as f64).powf(p) * u + (1.0 - u)).powf(1.0 / p);
    (x as usize).saturating_sub(1).min(n - 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn moments(vals: &[f64]) -> (f64, f64) {
        let n = vals.len() as f64;
        let mean = vals.iter().sum::<f64>() / n;
        let var = vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n;
        (mean, var)
    }

    #[test]
    fn laplace_moments() {
        let mut g = Xoshiro256pp::seeded(11);
        let b = 2.5;
        let v: Vec<f64> = (0..200_000).map(|_| laplace(&mut g, b)).collect();
        let (mean, var) = moments(&v);
        assert!(mean.abs() < 0.05, "mean={mean}");
        // Var[Laplace(b)] = 2 b^2 = 12.5
        assert!((var - 2.0 * b * b).abs() < 0.5, "var={var}");
    }

    #[test]
    fn laplace_zero_scale_is_zero() {
        let mut g = Xoshiro256pp::seeded(12);
        for _ in 0..100 {
            assert_eq!(laplace(&mut g, 0.0), 0.0);
        }
    }

    #[test]
    fn gumbel_moments() {
        let mut g = Xoshiro256pp::seeded(13);
        let v: Vec<f64> = (0..200_000).map(|_| gumbel(&mut g)).collect();
        let (mean, var) = moments(&v);
        // E = Euler-Mascheroni, Var = pi^2/6
        assert!((mean - 0.5772).abs() < 0.02, "mean={mean}");
        assert!((var - std::f64::consts::PI.powi(2) / 6.0).abs() < 0.05);
    }

    #[test]
    fn exponential_moments() {
        let mut g = Xoshiro256pp::seeded(14);
        let rate = 3.0;
        let v: Vec<f64> = (0..200_000).map(|_| exponential(&mut g, rate)).collect();
        let (mean, _) = moments(&v);
        assert!((mean - 1.0 / rate).abs() < 0.01);
        assert!(v.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn normal_moments() {
        let mut g = Xoshiro256pp::seeded(15);
        let v: Vec<f64> = (0..200_000).map(|_| normal(&mut g)).collect();
        let (mean, var) = moments(&v);
        assert!(mean.abs() < 0.02);
        assert!((var - 1.0).abs() < 0.05);
    }

    #[test]
    fn gumbel_max_is_exponential_mechanism() {
        // argmax(u_j + G_j) must sample ∝ exp(u_j): check empirically on a
        // 3-way distribution with known ratios.
        let mut g = Xoshiro256pp::seeded(16);
        let u = [0.0_f64, (2.0_f64).ln(), (4.0_f64).ln()]; // weights 1:2:4
        let mut counts = [0usize; 3];
        let trials = 140_000;
        for _ in 0..trials {
            let mut best = 0;
            let mut bestv = f64::NEG_INFINITY;
            for (j, &uj) in u.iter().enumerate() {
                let v = uj + gumbel(&mut g);
                if v > bestv {
                    bestv = v;
                    best = j;
                }
            }
            counts[best] += 1;
        }
        let p: Vec<f64> = counts.iter().map(|&c| c as f64 / trials as f64).collect();
        assert!((p[0] - 1.0 / 7.0).abs() < 0.01, "{p:?}");
        assert!((p[1] - 2.0 / 7.0).abs() < 0.01, "{p:?}");
        assert!((p[2] - 4.0 / 7.0).abs() < 0.01, "{p:?}");
    }

    #[test]
    fn zipf_is_heavy_headed() {
        let mut g = Xoshiro256pp::seeded(17);
        let n = 1000;
        let mut counts = vec![0usize; n];
        for _ in 0..100_000 {
            counts[zipf_like(&mut g, n, 1.2)] += 1;
        }
        // rank 0 must dominate rank 100 heavily
        assert!(counts[0] > 20 * counts[100].max(1));
        assert!(counts.iter().all(|&c| c < 100_000));
    }
}
