//! # dpfw — Differentially Private LASSO Logistic Regression via Fast Frank-Wolfe
//!
//! Full-system reproduction of *"Scaling Up Differentially Private LASSO
//! Regularized Logistic Regression via Faster Frank-Wolfe Iterations"*
//! (Raff, Khanna, Lu — NeurIPS 2023).
//!
//! The paper makes each iteration of the (DP) Frank-Wolfe solver for
//! L1-constrained logistic regression **sub-linear in the feature count D**
//! on sparse data, via three pieces that map onto this crate:
//!
//! * [`fw::standard`] — Algorithm 1, the standard sparse-aware Frank-Wolfe
//!   baseline (COPT-style): sparse matvecs, dense `O(D)` per-iteration work.
//! * [`fw::fast`] — Algorithm 2, the fast sparse-aware Frank-Wolfe: the
//!   multiplicative-scalar `w_m` trick plus sparse `α`/`v̄`/`g̃` maintenance,
//!   `O(S_r · S_c)` state update per iteration.
//! * [`heap::fibonacci`] + [`fw::queue`] — Algorithm 3, queue maintenance
//!   with stale-upper-bound priorities (non-private selection in
//!   `O(‖w*‖₀ log D)`).
//! * [`sampler::bsls`] — Algorithm 4, the Big-Step Little-Step exponential
//!   sampler (private selection in `O(√D log D)`, `O(1)` updates, all at
//!   log scale).
//!
//! Everything the paper's evaluation depends on is also here: LIBSVM-format
//! I/O and synthetic sparse dataset generators shaped like the paper's five
//! datasets ([`sparse::synth`]), DP mechanisms and advanced-composition
//! accounting ([`dp`]), FLOP accounting ([`fw::flops`]), evaluation metrics
//! ([`eval`]), a PJRT runtime that loads the JAX/Pallas-AOT'd dense oracle
//! ([`runtime`]), and a multi-threaded training coordinator ([`coordinator`]).
//!
//! Python (JAX + Pallas) exists only on the build path: `python/compile/`
//! lowers the dense gradient / prediction / loss-gap computations to HLO
//! text under `artifacts/`, which [`runtime`] loads through the PJRT C API.
//! Nothing Python runs at training or serving time.
//!
//! ## Quickstart
//!
//! ```no_run
//! use dpfw::prelude::*;
//!
//! // A News20-like synthetic sparse dataset (scaled down).
//! let ds = SynthConfig::preset(DatasetPreset::News20).scale(0.02).generate(42);
//! let cfg = FwConfig {
//!     iters: 500,
//!     lambda: 50.0,
//!     privacy: Some(PrivacyParams { epsilon: 1.0, delta: 1e-6 }),
//!     selector: SelectorKind::Bsls,
//!     seed: 7,
//!     ..Default::default()
//! };
//! let out = FastFrankWolfe::new(&ds, cfg).run();
//! println!("gap={:.4} nnz={}", out.final_gap, out.weights.nnz());
//! ```

pub mod cli;
pub mod coordinator;
pub mod dp;
pub mod eval;
pub mod experiments;
pub mod fw;
pub mod heap;
pub mod rng;
pub mod runtime;
pub mod sampler;
pub mod sparse;
pub mod testkit;
pub mod textio;

/// Convenience re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::dp::accounting::PrivacyParams;
    pub use crate::dp::ledger::{EpsLedger, FsyncPolicy};
    pub use crate::eval::{accuracy, auc, sparsity_pct};
    pub use crate::fw::cancel::{CancelToken, StopReason};
    pub use crate::fw::checkpoint::{FwCheckpoint, RunDurability};
    pub use crate::fw::config::{FwConfig, SelectorKind};
    pub use crate::fw::fast::FastFrankWolfe;
    pub use crate::fw::standard::StandardFrankWolfe;
    pub use crate::fw::trace::{FwOutput, PhaseTiming, TraceRecord};
    pub use crate::fw::workspace::FwWorkspace;
    pub use crate::sparse::csr::CsrMatrix;
    pub use crate::sparse::synth::{DatasetPreset, SynthConfig};
    pub use crate::sparse::{Dataset, DatasetError};
}
