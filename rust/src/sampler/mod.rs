//! Substrate: weighted samplers implementing the exponential mechanism's
//! selection step.
//!
//! The DP Frank-Wolfe selection problem: draw coordinate `j` with
//! probability proportional to `exp(u_j)` where `u_j = |α_j| · scale` is a
//! log-weight that changes sparsely between draws. Three implementations:
//!
//! * [`bsls`] — the paper's Algorithm 4 **Big-Step Little-Step** sampler:
//!   `O(√D)` per draw, `O(1)` per update, log-scale throughout, cache-
//!   friendly linear scans.
//! * [`naive`] — `O(D)` Gumbel-max reference (exact exponential mechanism,
//!   used to validate BSLS's distribution and as the "what you'd do
//!   without Alg 4" baseline).
//! * [`noisy_max`] — report-noisy-max via Laplace noise, the selection rule
//!   of Talwar et al.'s original DP Frank-Wolfe (Algorithm 1's DP variant
//!   and the paper's Table 3 "Alg 2" ablation column).

pub mod bsls;
pub mod naive;
pub mod noisy_max;

use crate::rng::Xoshiro256pp;

/// A dynamic weighted sampler over items `0..len` with log-scale weights.
pub trait WeightedSampler {
    /// Replace item `j`'s log-weight.
    fn update(&mut self, j: usize, log_weight: f64);
    /// Restore the exactly-fresh state of `new(len, init)` (same item
    /// count, same initial log-weight, telemetry zeroed) while retaining
    /// internal allocations. Powers workspace selector reuse.
    fn reset(&mut self);
    /// Draw one item with `P(j) ∝ exp(log_weight_j)`.
    fn sample(&mut self, rng: &mut Xoshiro256pp) -> usize;
    /// Current log-weight of `j`.
    fn log_weight(&self, j: usize) -> f64;
    /// log Σ_j exp(log_weight_j) (up to the sampler's internal drift bound).
    fn log_total(&self) -> f64;
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Numerically-stable log(Σ exp(v_i)) over a slice.
pub fn log_sum_exp(v: &[f64]) -> f64 {
    let m = v.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    if !m.is_finite() {
        return m; // empty or all -inf
    }
    let s: f64 = v.iter().map(|&x| (x - m).exp()).sum();
    m + s.ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lse_basic() {
        let v = [0.0, 0.0];
        assert!((log_sum_exp(&v) - (2.0f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn lse_handles_huge_values() {
        let v = [1000.0, 1000.0 + (3.0f64).ln()];
        assert!((log_sum_exp(&v) - (1000.0 + (4.0f64).ln())).abs() < 1e-9);
    }

    #[test]
    fn lse_handles_neg_inf() {
        let v = [f64::NEG_INFINITY, 0.0];
        assert!((log_sum_exp(&v) - 0.0).abs() < 1e-12);
        assert_eq!(log_sum_exp(&[f64::NEG_INFINITY]), f64::NEG_INFINITY);
        assert_eq!(log_sum_exp(&[]), f64::NEG_INFINITY);
    }
}
