//! Report-noisy-max: the selection rule of the *original* DP Frank-Wolfe
//! (Talwar, Thakurta, Zhang — "Nearly Optimal Private LASSO", NeurIPS
//! 2015), used by Algorithm 1's DP variant and the Table 3 "Alg 2 only"
//! ablation: add independent `Laplace(b)` noise to every coordinate's
//! score `|α_j|` and return the argmax. Inherently `O(D)` per selection —
//! exactly the cost Algorithm 4 removes.

use crate::rng::{dist, Xoshiro256pp};

/// One noisy-max selection over the magnitude scores of `alpha`.
///
/// `noise_scale` is the Laplace scale `b`; the paper's Algorithm 1 uses
/// `b = λ L √(8T log(1/δ)) / (N ε)` (see [`crate::dp::accounting`]).
/// Returns `(argmax_j, noisy_score)`.
pub fn noisy_max(alpha: &[f64], noise_scale: f64, rng: &mut Xoshiro256pp) -> (usize, f64) {
    assert!(!alpha.is_empty());
    let mut best = 0usize;
    let mut best_val = f64::NEG_INFINITY;
    for (j, &a) in alpha.iter().enumerate() {
        let s = a.abs() + dist::laplace(rng, noise_scale);
        if s > best_val {
            best_val = s;
            best = j;
        }
    }
    (best, best_val)
}

/// Non-private argmax of |α_j| (noise scale 0 short-circuit, used by the
/// non-private Algorithm 1 baseline).
pub fn arg_abs_max(alpha: &[f64]) -> usize {
    let mut best = 0usize;
    let mut best_val = f64::NEG_INFINITY;
    for (j, &a) in alpha.iter().enumerate() {
        let s = a.abs();
        if s > best_val {
            best_val = s;
            best = j;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_noise_is_argmax() {
        let alpha = [0.1, -3.0, 2.0, 0.0];
        let mut rng = Xoshiro256pp::seeded(31);
        let (j, _) = noisy_max(&alpha, 0.0, &mut rng);
        assert_eq!(j, 1);
        assert_eq!(arg_abs_max(&alpha), 1);
    }

    #[test]
    fn noise_randomizes_near_ties() {
        let alpha = [1.0, 1.0];
        let mut rng = Xoshiro256pp::seeded(32);
        let mut first = 0;
        for _ in 0..1000 {
            let (j, _) = noisy_max(&alpha, 1.0, &mut rng);
            first += (j == 0) as usize;
        }
        assert!(first > 350 && first < 650, "first={first}");
    }

    #[test]
    fn large_gap_resists_small_noise() {
        let alpha = [100.0, 0.0, 0.0];
        let mut rng = Xoshiro256pp::seeded(33);
        for _ in 0..500 {
            let (j, _) = noisy_max(&alpha, 0.5, &mut rng);
            assert_eq!(j, 0);
        }
    }

    #[test]
    fn arg_abs_max_handles_negatives_and_empty_guard() {
        assert_eq!(arg_abs_max(&[-5.0, 4.0]), 0);
        assert_eq!(arg_abs_max(&[0.0]), 0);
    }
}
