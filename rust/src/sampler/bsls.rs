//! Algorithm 4: the **Big-Step Little-Step** exponential sampler.
//!
//! Samples `j ∝ exp(v_j)` over a fixed set of `D` log-weights in `O(√D)`
//! per draw with `O(1)` updates. The key idea from the paper: partition the
//! `D` items into `⌈√D⌉` contiguous groups of `⌈√D⌉` items and keep each
//! group's log-sum-weight (`c[g]`) plus the global log-sum (`z_Σ`). A draw
//! walks the groups linearly — if the whole group's mass falls below the
//! remaining threshold the group is skipped in one comparison (**Big
//! Step**), otherwise its members are scanned individually (**Little
//! Steps**). Both scans are over contiguous arrays, so prefetching works
//! and the only cache misses are the `O(1)` group transitions — this is
//! exactly the cache-friendliness argument of the paper's §3.3 (in
//! contrast to the pointer-chasing Fibonacci heap).
//!
//! ### Deviation from the paper's pseudocode (documented per DESIGN.md)
//!
//! The paper phrases the draw as a log-scale adaptation of the streaming
//! A-ExpJ reservoir sampler (Efraimidis-Spirakis), whose exponential-jump
//! machinery exists to avoid *one random variate per stream item* when the
//! item set is unknown ahead of time. Our item set is fixed and indexable,
//! so we use the mathematically-equivalent inverse-CDF formulation: draw
//! one uniform `u`, walk groups/items until the cumulative (normalized)
//! weight passes `u`. The sampled distribution is *identical* — exactly
//! `P(j) = exp(v_j − z_Σ)`, i.e. the exponential mechanism — while the
//! complexity improves from `O(√D log D)` to `O(√D)` per draw and the
//! big-step/little-step scan structure (and hence the cache behaviour the
//! paper measures) is preserved verbatim. Distributional equality against
//! the `O(D)` Gumbel-max reference is enforced by a χ² test in this
//! module's tests and `rust/tests/prop_equivalence.rs`.
//!
//! ### Numerical stability
//!
//! Per-item weights stay log-scale; each *group* sum is kept in the
//! linear domain relative to a per-group anchor (see the `gsum` field
//! docs) — arithmetically equal to the paper's lines 34-35 log-sum-exp
//! replacement (`c[k] += log(1 − e^{v_old−c[k]} + e^{v_new−c[k]})`) but
//! with the `ln` amortized out of the update path (§Perf). Catastrophic
//! cancellation (an update leaving no mass), anchor overflow (a weight
//! rising above the group anchor), and FP drift are all repaired by an
//! exact `O(√D)` group rebuild; a global exact rebuild runs every
//! `rebuild_every` updates so drift cannot accumulate over a
//! 400k-iteration train run. Weights below `z_Σ − 700` underflow `exp`
//! to 0 — per the paper's footnote 4 these items' selection probability
//! is astronomically small and a tiny floor keeps them technically
//! selectable.

use super::WeightedSampler;
use crate::rng::Xoshiro256pp;

/// Relative log-floor: items more than this far below the max never win;
/// flooring them keeps exp() finite and guarantees nonzero mass (paper
/// footnote 4 adds 1e-15 for the same reason).
const LOG_FLOOR_BELOW_MAX: f64 = 700.0;

#[derive(Clone, Debug)]
pub struct BslsSampler {
    /// Per-item log-weights `v_j`.
    v: Vec<f64>,
    /// Per-group reference level (≥ every `v_j` in the group; reset on
    /// group rebuild). The group's log-sum-weight is
    /// `c[g] = gmax[g] + ln(gsum[g])`.
    gmax: Vec<f64>,
    /// Per-group *linear-domain* sums `Σ_{j∈g} exp(v_j − gmax[g])`.
    ///
    /// §Perf: the paper's per-update log-sum-exp replace (Alg 4 lines
    /// 34-35) costs 2 exp + 1 ln per update; keeping the group sums in the
    /// linear domain relative to a fixed per-group max makes an update
    /// 2 exp + 1 add, and the `ln` is paid only `√D`-times per *draw* when
    /// the global sum is refreshed. Same arithmetic value, 2-3× fewer
    /// transcendentals on the Alg 2 notify path (the training hot spot).
    gsum: Vec<f64>,
    /// Global log-sum `z_Σ = logΣ_j exp(v_j)`; lazily refreshed from the
    /// group sums at the next draw.
    z: f64,
    z_dirty: bool,
    group_size: usize,
    /// Initial log-weight from construction, restored by
    /// [`WeightedSampler::reset`].
    init: f64,
    /// Updates since the last exact global rebuild.
    updates_since_rebuild: usize,
    /// Exact-rebuild cadence (defaults to D — amortized O(1) per update).
    rebuild_every: usize,
    /// Telemetry: draws, big steps, little steps, group/global rebuilds.
    pub stats: BslsStats,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct BslsStats {
    pub draws: u64,
    pub big_steps: u64,
    pub little_steps: u64,
    pub group_rebuilds: u64,
    pub global_rebuilds: u64,
}

impl BslsSampler {
    /// Create with all log-weights = `init` (Alg 2 bulk-adds all D items at
    /// t=1; starting uniform then updating is equivalent and O(D) once).
    pub fn new(n: usize, init: f64) -> Self {
        assert!(n > 0, "empty sampler");
        let group_size = (n as f64).sqrt().ceil() as usize;
        let n_groups = n.div_ceil(group_size);
        let mut s = Self {
            v: vec![init; n],
            gmax: vec![f64::NEG_INFINITY; n_groups],
            gsum: vec![0.0; n_groups],
            z: f64::NEG_INFINITY,
            z_dirty: false,
            group_size,
            init,
            updates_since_rebuild: 0,
            rebuild_every: n.max(1024),
            stats: BslsStats::default(),
        };
        s.rebuild_all();
        s
    }

    /// Bulk-initialize from a weight slice.
    pub fn from_weights(weights: &[f64]) -> Self {
        let mut s = Self::new(weights.len(), 0.0);
        s.v.copy_from_slice(weights);
        s.rebuild_all();
        s
    }

    #[inline]
    fn group_of(&self, j: usize) -> usize {
        j / self.group_size
    }

    fn group_range(&self, g: usize) -> std::ops::Range<usize> {
        let lo = g * self.group_size;
        let hi = ((g + 1) * self.group_size).min(self.v.len());
        lo..hi
    }

    /// Re-anchor headroom: the anchor is set `ANCHOR_PAD` above the group
    /// max so weight *increases* of up to e^PAD don't force an O(√D)
    /// re-anchor (gradient magnitudes ratchet up constantly during FW's
    /// zig-zag phase — without headroom the active group re-anchors nearly
    /// every iteration).
    const ANCHOR_PAD: f64 = 3.0;

    fn rebuild_group(&mut self, g: usize) {
        let r = self.group_range(g);
        let m = self.v[r.clone()].iter().copied().fold(f64::NEG_INFINITY, f64::max);
        self.gmax[g] = m + Self::ANCHOR_PAD;
        self.gsum[g] = if m.is_finite() {
            let anchor = self.gmax[g];
            self.v[r].iter().map(|&x| (x - anchor).exp()).sum()
        } else {
            0.0
        };
        self.stats.group_rebuilds += 1;
    }

    fn rebuild_all(&mut self) {
        for g in 0..self.gmax.len() {
            let r = self.group_range(g);
            let m = self.v[r.clone()].iter().copied().fold(f64::NEG_INFINITY, f64::max);
            self.gmax[g] = m + Self::ANCHOR_PAD;
            self.gsum[g] = if m.is_finite() {
                let anchor = self.gmax[g];
                self.v[r].iter().map(|&x| (x - anchor).exp()).sum()
            } else {
                0.0
            };
        }
        self.z = self.compute_z();
        self.z_dirty = false;
        self.updates_since_rebuild = 0;
        self.stats.global_rebuilds += 1;
    }

    /// `z = logΣ_g exp(gmax[g])·gsum[g]`, stably (one ln total).
    fn compute_z(&self) -> f64 {
        let mut m = f64::NEG_INFINITY;
        for (g, &gm) in self.gmax.iter().enumerate() {
            if self.gsum[g] > 0.0 && gm > m {
                m = gm;
            }
        }
        if !m.is_finite() {
            return f64::NEG_INFINITY;
        }
        let s: f64 = self
            .gmax
            .iter()
            .zip(&self.gsum)
            .map(|(&gm, &gs)| if gs > 0.0 { (gm - m).exp() * gs } else { 0.0 })
            .sum();
        m + s.ln()
    }

    #[inline]
    fn refresh_z(&mut self) {
        if self.z_dirty {
            self.z = self.compute_z();
            self.z_dirty = false;
        }
    }

    /// Log-sum-weight of group `g` (diagnostics/tests).
    pub fn group_log_sum(&self, g: usize) -> f64 {
        if self.gsum[g] > 0.0 {
            self.gmax[g] + self.gsum[g].ln()
        } else {
            f64::NEG_INFINITY
        }
    }
}

impl WeightedSampler for BslsSampler {
    fn reset(&mut self) {
        // Exactly the state `new(len, init)` leaves behind: uniform
        // log-weights, fresh telemetry, then one exact global rebuild
        // (whose counter bump `new` also performs).
        self.v.fill(self.init);
        self.stats = BslsStats::default();
        self.rebuild_all();
    }

    fn update(&mut self, j: usize, log_weight: f64) {
        let old = self.v[j];
        if old == log_weight {
            return;
        }
        self.v[j] = log_weight;
        let g = self.group_of(j);
        if log_weight > self.gmax[g] {
            // new group maximum: re-anchor the linear sum (O(√D), rare —
            // gradient magnitudes mostly shrink as FW converges)
            self.rebuild_group(g);
        } else {
            // the hot path: 2 exps, no ln (see field docs)
            let delta = (log_weight - self.gmax[g]).exp() - (old - self.gmax[g]).exp();
            self.gsum[g] += delta;
            if !(self.gsum[g] > 1e-12) || !self.gsum[g].is_finite() {
                self.rebuild_group(g); // cancellation → exact recompute
            }
        }
        self.z_dirty = true; // refreshed from group sums at the next draw
        self.updates_since_rebuild += 1;
        if self.updates_since_rebuild >= self.rebuild_every {
            self.rebuild_all();
        }
    }

    fn sample(&mut self, rng: &mut Xoshiro256pp) -> usize {
        self.stats.draws += 1;
        self.refresh_z();
        let z = self.z;
        // Inverse-CDF at normalized scale: target mass u ∈ (0,1).
        let u = rng.next_f64_open0();
        let mut cum = 0.0f64;
        let mut last_nonzero = None;
        for g in 0..self.gmax.len() {
            let gw = if self.gsum[g] > 0.0 {
                (self.gmax[g] - z).exp() * self.gsum[g]
            } else {
                0.0
            };
            if cum + gw < u {
                // ---- Big Step: skip the whole group in one comparison
                cum += gw;
                self.stats.big_steps += 1;
                continue;
            }
            // ---- Little Steps: scan the group's members
            let floor = z - LOG_FLOOR_BELOW_MAX;
            for j in self.group_range(g) {
                self.stats.little_steps += 1;
                let lw = self.v[j].max(floor);
                cum += (lw - z).exp();
                last_nonzero = Some(j);
                if cum >= u {
                    return j;
                }
            }
        }
        // FP residue: total normalized mass summed to slightly below u.
        // Fall back to the last item with mass (probability O(ulp)).
        if let Some(j) = last_nonzero {
            return j;
        }
        // Degenerate (all weights -inf after floor): uniform fallback keeps
        // the mechanism total and well-defined.
        rng.next_below(self.v.len() as u64) as usize
    }

    fn log_weight(&self, j: usize) -> f64 {
        self.v[j]
    }

    fn log_total(&self) -> f64 {
        if self.z_dirty {
            self.compute_z()
        } else {
            self.z
        }
    }

    fn len(&self) -> usize {
        self.v.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::log_sum_exp;
    use crate::sampler::naive::NaiveExpSampler;

    fn chi_square_uniformity(counts: &[u64], probs: &[f64]) -> f64 {
        let n: u64 = counts.iter().sum();
        counts
            .iter()
            .zip(probs)
            .map(|(&c, &p)| {
                let e = n as f64 * p;
                if e < 1e-12 {
                    0.0
                } else {
                    (c as f64 - e).powi(2) / e
                }
            })
            .sum()
    }

    #[test]
    fn uniform_weights_sample_uniformly() {
        let mut s = BslsSampler::new(64, 0.0);
        let mut rng = Xoshiro256pp::seeded(1);
        let mut counts = vec![0u64; 64];
        let trials = 64_000;
        for _ in 0..trials {
            counts[s.sample(&mut rng)] += 1;
        }
        let probs = vec![1.0 / 64.0; 64];
        let chi2 = chi_square_uniformity(&counts, &probs);
        // df=63; 99.9th percentile ≈ 103
        assert!(chi2 < 110.0, "chi2={chi2}");
    }

    #[test]
    fn matches_exact_distribution_after_updates() {
        let d = 100;
        let mut s = BslsSampler::new(d, 0.0);
        let mut rng = Xoshiro256pp::seeded(2);
        // random weight profile, applied via update()
        let mut w = vec![0.0f64; d];
        for j in 0..d {
            w[j] = (j % 7) as f64 * 0.5;
            s.update(j, w[j]);
        }
        let z = log_sum_exp(&w);
        let probs: Vec<f64> = w.iter().map(|&x| (x - z).exp()).collect();
        let mut counts = vec![0u64; d];
        let trials = 200_000;
        for _ in 0..trials {
            counts[s.sample(&mut rng)] += 1;
        }
        let chi2 = chi_square_uniformity(&counts, &probs);
        // df=99; 99.9th percentile ≈ 149
        assert!(chi2 < 160.0, "chi2={chi2}");
    }

    #[test]
    fn agrees_with_naive_sampler() {
        let d = 50;
        let mut bsls = BslsSampler::new(d, 0.0);
        let mut naive = NaiveExpSampler::new(d, 0.0);
        let mut rng = Xoshiro256pp::seeded(3);
        for j in 0..d {
            let w = ((j * 13) % 11) as f64 * 0.7 - 2.0;
            bsls.update(j, w);
            naive.update(j, w);
        }
        let trials = 150_000;
        let mut cb = vec![0u64; d];
        let mut cn = vec![0u64; d];
        let mut r1 = Xoshiro256pp::seeded(4);
        let mut r2 = Xoshiro256pp::seeded(5);
        for _ in 0..trials {
            cb[bsls.sample(&mut r1)] += 1;
            cn[naive.sample(&mut r2)] += 1;
            let _ = &mut rng;
        }
        // two-sample chi-square
        let chi2: f64 = (0..d)
            .map(|j| {
                let a = cb[j] as f64;
                let b = cn[j] as f64;
                if a + b == 0.0 {
                    0.0
                } else {
                    (a - b).powi(2) / (a + b)
                }
            })
            .sum();
        // df=49; 99.9th percentile ≈ 86
        assert!(chi2 < 95.0, "chi2={chi2}");
    }

    #[test]
    fn extreme_dynamic_range_is_stable() {
        // gradients spanning >4 orders of magnitude after exponentiation —
        // the exact scenario the paper's log-scale design targets
        let d = 30;
        let mut s = BslsSampler::new(d, 0.0);
        for j in 0..d {
            s.update(j, -((j * 50) as f64)); // weights e^0 .. e^-1450
        }
        s.update(7, 200.0); // one dominant item
        let mut rng = Xoshiro256pp::seeded(6);
        for _ in 0..1000 {
            assert_eq!(s.sample(&mut rng), 7);
        }
        assert!(s.log_total().is_finite());
    }

    #[test]
    fn many_updates_do_not_drift() {
        let d = 64;
        let mut s = BslsSampler::new(d, 0.0);
        let mut rng = Xoshiro256pp::seeded(7);
        let mut w = vec![0.0f64; d];
        for _ in 0..50_000 {
            let j = rng.next_below(d as u64) as usize;
            w[j] = (rng.next_f64() - 0.5) * 20.0;
            s.update(j, w[j]);
        }
        let exact = log_sum_exp(&w);
        assert!(
            (s.log_total() - exact).abs() < 1e-6,
            "drift: {} vs {}",
            s.log_total(),
            exact
        );
    }

    #[test]
    fn big_steps_dominate_on_peaked_distributions() {
        // With one dominant group, draws should mostly big-step past the
        // others: the O(√D) claim in action.
        let d = 10_000;
        let mut s = BslsSampler::new(d, 0.0);
        s.update(5_000, 50.0);
        let mut rng = Xoshiro256pp::seeded(8);
        for _ in 0..100 {
            s.sample(&mut rng);
        }
        let st = s.stats;
        assert!(st.big_steps > 0);
        // little steps bounded by ~2 group scans per draw
        assert!(
            st.little_steps <= st.draws * 2 * (s.group_size as u64 + 1),
            "{st:?}"
        );
    }

    #[test]
    fn ragged_last_group() {
        // n not a perfect square — last group is short
        let mut s = BslsSampler::new(10, 0.0);
        let mut rng = Xoshiro256pp::seeded(9);
        s.update(9, 30.0);
        for _ in 0..100 {
            assert_eq!(s.sample(&mut rng), 9);
        }
    }

    #[test]
    fn single_item() {
        let mut s = BslsSampler::new(1, -5.0);
        let mut rng = Xoshiro256pp::seeded(10);
        assert_eq!(s.sample(&mut rng), 0);
    }
}
