//! `O(D)` exponential-mechanism reference sampler via the Gumbel-max trick:
//! `argmax_j (v_j + Gumbel_j)` is distributed exactly `∝ exp(v_j)`.
//!
//! This is the "no Algorithm 4" baseline: correct, simple, and linear in D
//! per draw — the thing BSLS is differentially tested against and the cost
//! model the paper's Table 3 ablation implies when only Alg 2 is used with
//! a dense selection pass.

use super::WeightedSampler;
use crate::rng::{dist, Xoshiro256pp};

#[derive(Clone, Debug)]
pub struct NaiveExpSampler {
    v: Vec<f64>,
    /// The `init` the sampler was constructed with, so [`WeightedSampler::reset`]
    /// restores the exactly-fresh state.
    init: f64,
}

impl NaiveExpSampler {
    pub fn new(n: usize, init: f64) -> Self {
        assert!(n > 0);
        Self { v: vec![init; n], init }
    }

    pub fn from_weights(weights: &[f64]) -> Self {
        Self { v: weights.to_vec(), init: 0.0 }
    }
}

impl WeightedSampler for NaiveExpSampler {
    fn update(&mut self, j: usize, log_weight: f64) {
        self.v[j] = log_weight;
    }

    fn reset(&mut self) {
        self.v.fill(self.init);
    }

    fn sample(&mut self, rng: &mut Xoshiro256pp) -> usize {
        let mut best = 0usize;
        let mut best_val = f64::NEG_INFINITY;
        for (j, &vj) in self.v.iter().enumerate() {
            let g = vj + dist::gumbel(rng);
            if g > best_val {
                best_val = g;
                best = j;
            }
        }
        best
    }

    fn log_weight(&self, j: usize) -> f64 {
        self.v[j]
    }

    fn log_total(&self) -> f64 {
        super::log_sum_exp(&self.v)
    }

    fn len(&self) -> usize {
        self.v.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn respects_weight_ratios() {
        let mut s = NaiveExpSampler::new(3, 0.0);
        s.update(1, (3.0f64).ln());
        // weights 1 : 3 : 1
        let mut rng = Xoshiro256pp::seeded(21);
        let mut counts = [0u64; 3];
        let trials = 100_000;
        for _ in 0..trials {
            counts[s.sample(&mut rng)] += 1;
        }
        let p1 = counts[1] as f64 / trials as f64;
        assert!((p1 - 0.6).abs() < 0.01, "p1={p1}");
    }

    #[test]
    fn dominant_item_always_wins() {
        let mut s = NaiveExpSampler::new(10, 0.0);
        s.update(4, 100.0);
        let mut rng = Xoshiro256pp::seeded(22);
        for _ in 0..200 {
            assert_eq!(s.sample(&mut rng), 4);
        }
    }

    #[test]
    fn neg_inf_items_never_selected() {
        let mut s = NaiveExpSampler::new(4, 0.0);
        s.update(0, f64::NEG_INFINITY);
        let mut rng = Xoshiro256pp::seeded(23);
        for _ in 0..1000 {
            assert_ne!(s.sample(&mut rng), 0);
        }
    }
}
