//! Compressed Sparse Column matrix: the `X[:,j]` view.
//!
//! Algorithm 2's inner loop is "for all rows i of X with feature j" — that
//! is exactly one CSC column scan (`S_r` entries on average). Built once
//! from the CSR view at dataset load; the two views share nothing so each
//! stays contiguous for its own scan direction.

use super::csr::CsrMatrix;

#[derive(Clone, Debug, PartialEq)]
pub struct CscMatrix {
    n_rows: usize,
    n_cols: usize,
    /// Column start offsets, length `n_cols + 1`.
    indptr: Vec<usize>,
    /// Row index of each stored value, length `nnz`.
    indices: Vec<u32>,
    /// Stored values, length `nnz`.
    values: Vec<f32>,
}

impl CscMatrix {
    /// Transpose-convert a CSR matrix with a counting sort: O(nnz + D).
    pub fn from_csr(csr: &CsrMatrix) -> Self {
        let n_rows = csr.n_rows();
        let n_cols = csr.n_cols();
        let nnz = csr.nnz();
        let mut indptr = vec![0usize; n_cols + 1];
        for i in 0..n_rows {
            let (idx, _) = csr.row_raw(i);
            for &j in idx {
                indptr[j as usize + 1] += 1;
            }
        }
        for j in 0..n_cols {
            indptr[j + 1] += indptr[j];
        }
        let mut cursor = indptr.clone();
        let mut indices = vec![0u32; nnz];
        let mut values = vec![0.0f32; nnz];
        for i in 0..n_rows {
            let (idx, val) = csr.row_raw(i);
            for (&j, &v) in idx.iter().zip(val) {
                let p = cursor[j as usize];
                indices[p] = i as u32;
                values[p] = v;
                cursor[j as usize] = p + 1;
            }
        }
        Self { n_rows, n_cols, indptr, indices, values }
    }

    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    pub fn n_cols(&self) -> usize {
        self.n_cols
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn col_nnz(&self, j: usize) -> usize {
        self.indptr[j + 1] - self.indptr[j]
    }

    /// Iterate the nonzeros of column `j` as `(row, value)`.
    #[inline]
    pub fn col(&self, j: usize) -> impl Iterator<Item = (usize, f32)> + '_ {
        let lo = self.indptr[j];
        let hi = self.indptr[j + 1];
        self.indices[lo..hi]
            .iter()
            .zip(&self.values[lo..hi])
            .map(|(&i, &v)| (i as usize, v))
    }

    /// Raw slices of column `j` — hot-path accessor.
    #[inline]
    pub fn col_raw(&self, j: usize) -> (&[u32], &[f32]) {
        let lo = self.indptr[j];
        let hi = self.indptr[j + 1];
        (&self.indices[lo..hi], &self.values[lo..hi])
    }

    /// `out[j] = Σ_i X[i,j] · q[i]` for every column — the `Xᵀq` product
    /// driven from the column side (used by tests to cross-check CSR).
    pub fn matvec_t(&self, q: &[f64], out: &mut [f64]) {
        assert_eq!(q.len(), self.n_rows);
        assert_eq!(out.len(), self.n_cols);
        for j in 0..self.n_cols {
            let (idx, val) = self.col_raw(j);
            let mut acc = 0.0f64;
            for (&i, &v) in idx.iter().zip(val) {
                acc += v as f64 * q[i as usize];
            }
            out[j] = acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_csr() -> CsrMatrix {
        // [[1,0,2],[0,3,0],[4,0,5]]
        CsrMatrix::from_parts(
            3,
            3,
            vec![0, 2, 3, 5],
            vec![0, 2, 1, 0, 2],
            vec![1.0, 2.0, 3.0, 4.0, 5.0],
        )
    }

    #[test]
    fn conversion_preserves_entries() {
        let csr = sample_csr();
        let csc = CscMatrix::from_csr(&csr);
        assert_eq!(csc.nnz(), 5);
        let c0: Vec<_> = csc.col(0).collect();
        assert_eq!(c0, vec![(0, 1.0), (2, 4.0)]);
        let c1: Vec<_> = csc.col(1).collect();
        assert_eq!(c1, vec![(1, 3.0)]);
        let c2: Vec<_> = csc.col(2).collect();
        assert_eq!(c2, vec![(0, 2.0), (2, 5.0)]);
    }

    #[test]
    fn rows_within_column_are_sorted() {
        // from_csr visits rows in order, so each column's rows come out
        // ascending — the Alg 2 inner loop relies on this for locality.
        let csc = CscMatrix::from_csr(&sample_csr());
        for j in 0..3 {
            let rows: Vec<_> = csc.col(j).map(|(i, _)| i).collect();
            let mut sorted = rows.clone();
            sorted.sort_unstable();
            assert_eq!(rows, sorted);
        }
    }

    #[test]
    fn matvec_t_matches_csr() {
        let csr = sample_csr();
        let csc = CscMatrix::from_csr(&csr);
        let q = [1.0, 2.0, 3.0];
        let mut a = vec![0.0; 3];
        let mut b = vec![0.0; 3];
        csr.matvec_t_add(&q, &mut a);
        csc.matvec_t(&q, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn empty_column() {
        let csr = CsrMatrix::from_parts(2, 4, vec![0, 1, 2], vec![0, 3], vec![1.0, 2.0]);
        let csc = CscMatrix::from_csr(&csr);
        assert_eq!(csc.col_nnz(1), 0);
        assert_eq!(csc.col_nnz(2), 0);
        assert_eq!(csc.col(1).count(), 0);
    }
}
